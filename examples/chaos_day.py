"""Chaos day: a blackhole site, and the circuit breaker that contains it.

One site in a small homogeneous grid fails ~90% of its jobs.  Under
``least_loaded`` that site becomes a blackhole: its jobs fail fast, so it
always looks like the most drained site and keeps winning the assignment —
and with resubmission backoff every round-trip through it burns real wall
clock.  The run is repeated with the adaptive blacklist armed (EWMA failure
score + circuit breaker with cooldown and a half-open probe, DESIGN.md §13):
the breaker trips the flaky site out of the feasibility mask, work reroutes
to the healthy sites, and the makespan drops by roughly half.

    PYTHONPATH=src python examples/chaos_day.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    compute_metrics,
    flaky_grid,
    get_policy,
    make_faults,
    simulate,
    synthetic_panda_jobs,
)
from repro.core.events import fault_rows
from repro.core.monitor import blacklist_timeline, fault_score_timeline, sparkline


def build_workload(n_jobs, n_sites, seed=7):
    # homogeneous small sites + trickle arrivals: this is what makes
    # least_loaded chase the flaky site (a heterogeneous grid or a single
    # arrival wave would just pile everything on the biggest site)
    sites, flaky_idx = flaky_grid(
        n_sites, n_flaky=1, seed=12, cores_range=(8, 8), speed_range=(10.0, 10.0)
    )
    rng = np.random.default_rng(seed)
    jobs = synthetic_panda_jobs(n_jobs, seed=seed, capacity=n_jobs + 3)
    jobs = jobs._replace(
        arrival=jnp.asarray(
            np.pad(np.sort(rng.uniform(0.0, 400.0, n_jobs)), (0, 3),
                   constant_values=np.inf),
            jnp.float32,
        ),
        work=jnp.asarray(
            np.pad(rng.lognormal(np.log(800.0), 0.6, n_jobs), (0, 3)), jnp.float32
        ),
        cores=jnp.ones((jobs.capacity,), jnp.int32),
        memory=jnp.full((jobs.capacity,), 2.0),
    )
    return jobs, sites, flaky_idx


def run(jobs, sites, n_sites, *, blacklist, log_rows=0):
    kw = dict(job_backoff=120.0)  # each failed attempt costs backed-off wall clock
    if blacklist:
        kw.update(blacklist_threshold=0.6, blacklist_alpha=0.5,
                  blacklist_cooldown=600.0)
    fl = make_faults(n_sites, jobs, **kw)
    return simulate(
        jobs, sites, get_policy("least_loaded"), jax.random.PRNGKey(1),
        max_retries=6, faults=fl, log_rows=log_rows,
    )


def main():
    n_jobs, n_sites = 120, 4
    jobs, sites, flaky_idx = build_workload(n_jobs, n_sites)

    print(f"{'scenario':>16s} | {'makespan':>9s} | {'retries':>7s} | "
          f"{'flaky fails':>11s} | {'time lost':>10s}")
    results = {}
    for name, bl in (("no blacklist", False), ("blacklist", True)):
        res = run(jobs, sites, n_sites, blacklist=bl, log_rows=4096)
        results[name] = res
        fs = res.ext["faults"]
        retries = int(np.asarray(res.jobs.retries)[np.asarray(res.jobs.valid)].sum())
        flaky_fails = int(np.asarray(res.sites.n_failed)[flaky_idx[0]])
        print(f"{name:>16s} | {float(res.makespan):>8.0f}s | {retries:>7d} | "
              f"{flaky_fails:>11d} | {float(fs.time_lost):>9.0f}s")

    off, on = results["no blacklist"], results["blacklist"]
    win = 1.0 - float(on.makespan) / float(off.makespan)
    print(f"\nblacklisting cuts the makespan by {100 * win:.0f}%")

    fs = on.ext["faults"]
    print(f"breaker: {int(fs.n_bl_trips)} trip(s), {int(fs.n_probes)} probe(s)")
    print("\nper-site breaker state at drain:")
    for r in fault_rows(on):
        print(f"  site {r['site']}: score={r['fault_score']:.2f} "
              f"state={r['blacklist']} kills={r['n_kills']}")

    # replay the flaky site's EWMA score and breaker state from the recorder
    score = fault_score_timeline(on)[:, flaky_idx[0]]
    tripped = blacklist_timeline(on)[:, flaky_idx[0]]
    print(f"\nflaky site failure score over time (peak {score.max():.2f}):")
    print("  " + sparkline(score))
    print(f"tripped for {100 * (tripped == 1).mean():.0f}% of logged rounds")

    m_on, m_off = compute_metrics(on), compute_metrics(off)
    print(f"\np99 resubmission backoff wait: {float(m_off.p99_backoff_wait):.0f}s "
          f"-> {float(m_on.p99_backoff_wait):.0f}s")


if __name__ == "__main__":
    main()
