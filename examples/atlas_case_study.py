"""The paper's case study (§4): simulate an ATLAS-like 50-site WLCG grid,
calibrate per-site CPU speeds against "historical" walltimes, and export the
event-level ML dataset.

    PYTHONPATH=src python examples/atlas_case_study.py
"""
import json

import jax
import numpy as np

from repro.core import (
    atlas_like_platform,
    compute_metrics,
    dump_platform,
    get_policy,
    simulate,
    summary_str,
    synthetic_panda_jobs,
)
from repro.core.calibration import calibrate, closed_form_objective, make_synthetic_problem
from repro.core.events import ml_dataset, to_csv, transition_rows


def main():
    # --- platform + 6 "months" of workload (paper: Jan-Jun 2024 PanDA) ------
    sites = atlas_like_platform(50, seed=1)
    jobs = synthetic_panda_jobs(4000, seed=0, duration=14 * 86400.0)

    # --- calibration (paper Fig. 1c / Fig. 3) --------------------------------
    problem = make_synthetic_problem(jobs, sites, seed=2, misconfig_sigma=1.05)
    _, _, err0 = closed_form_objective(problem, problem.sites0.speed)
    print(f"uncalibrated geomean relative MAE: {float(err0):.1%}")
    for method in ("random", "cma_es"):
        r = calibrate(problem, method, seed=3)
        print(f"  {method:8s}: {float(r.err0):.1%} -> {float(r.err):.1%}")
    best = calibrate(problem, "random", seed=3)

    # --- replay with calibrated speeds ---------------------------------------
    calibrated = sites._replace(speed=best.speeds)
    res = simulate(jobs, calibrated, get_policy("panda_dispatch"), jax.random.PRNGKey(0))
    print("\ncalibrated-grid replay:", summary_str(compute_metrics(res)))

    # --- outputs: platform JSON round trip + Table-1 events + ML dataset -----
    platform_json = dump_platform(calibrated)
    rows = transition_rows(res)
    ds = ml_dataset(res)
    with open("/tmp/atlas_platform.json", "w") as f:
        f.write(platform_json)
    with open("/tmp/atlas_events.csv", "w") as f:
        f.write(to_csv(rows[:10000]))
    np.savez("/tmp/atlas_ml_dataset.npz", **{k: v for k, v in ds.items()})
    print(f"\nwrote /tmp/atlas_platform.json ({len(json.loads(platform_json)['sites'])} sites), "
          f"/tmp/atlas_events.csv ({len(rows)} events), "
          f"/tmp/atlas_ml_dataset.npz ({ds['walltime'].shape[0]} samples)")


if __name__ == "__main__":
    main()
