"""A third-party engine subsystem, written without touching ``engine.py``.

The DESIGN.md §7 protocol demo: a *scratch-disk leak* model.  Every starting
job deposits its output volume on its site's scratch disk; completions clean
up all but a leaked fraction (crashed attempts leave temp files behind), and
a nightly cron purges the leaks.  A site whose scratch disk is full stops
accepting new work — so under a high leak rate the dispatcher visibly routes
around clogged sites until the next purge.

The whole model is ~80 lines of hooks on the ``Subsystem`` protocol:

  event_times     -> purge ticks join the engine clock's min-reduction
  on_completions  -> completed jobs free their scratch (minus the leak)
  pre_assign      -> full scratch disks become infeasible for assignment
  on_start        -> starting jobs deposit scratch
  log_columns     -> per-site scratch occupancy in the monitoring feed
  finalize        -> final state lands in ``SimResult.ext["scratch"]``

Run:  PYTHONPATH=src python examples/custom_subsystem.py
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Subsystem,
    atlas_like_platform,
    get_policy,
    simulate,
    synthetic_panda_jobs,
)
from repro.core.engine import _site_sum


class ScratchState(NamedTuple):
    """Per-site scratch-disk occupancy (dynamic state: lives in ext)."""

    used: jax.Array      # f32[S] bytes resident (live + leaked)
    leaked: jax.Array    # f32[S] bytes orphaned by completed attempts
    capacity: jax.Array  # f32[S] scratch-disk size
    n_purges: jax.Array  # i32[] cron purges that fired


class ScratchConfig(NamedTuple):
    """Compile-time constants (static: rides in ``Subsystem.config``)."""

    leak_frac: float = 0.3       # fraction of scratch orphaned per completion
    purge_every: float = 21600.0  # cron period (6h)


def make_scratch(capacity_bytes, n_sites: int) -> ScratchState:
    cap = jnp.broadcast_to(jnp.asarray(capacity_bytes, jnp.float32), (n_sites,))
    return ScratchState(
        used=jnp.zeros((n_sites,), jnp.float32),
        leaked=jnp.zeros((n_sites,), jnp.float32),
        capacity=cap,
        n_purges=jnp.zeros((), jnp.int32),
    )


def _next_purge(sub, ctx):
    # the next cron tick is an event source: rounds land exactly on purges
    period = sub.config.purge_every
    return (jnp.floor(ctx.clock_prev / period) + 1.0) * period


def _on_completions(sub, ctx):
    st: ScratchState = ctx.ext["scratch"]
    jobs = ctx.jobs
    # completions clean their scratch up, minus the leaked fraction
    comp_site = jnp.where(ctx.comp, jobs.site, ctx.S)
    scratch = jnp.where(ctx.comp, jobs.bytes_out, 0.0)
    freed = _site_sum(scratch * (1.0 - sub.config.leak_frac), comp_site, ctx.S)
    leak = _site_sum(scratch * sub.config.leak_frac, comp_site, ctx.S)
    used = st.used - freed
    leaked = st.leaked + leak
    # cron purge: when this round crossed a period boundary, orphans vanish
    period = sub.config.purge_every
    fired = jnp.floor(ctx.clock / period) > jnp.floor(ctx.clock_prev / period)
    used = jnp.where(fired, used - leaked, used)
    leaked = jnp.where(fired, 0.0, leaked)
    ctx.ext["scratch"] = st._replace(
        used=used, leaked=leaked, n_purges=st.n_purges + fired.astype(jnp.int32)
    )


def _pre_assign(sub, ctx):
    st: ScratchState = ctx.ext["scratch"]
    # a clogged scratch disk takes the site out of the dispatch pool
    ctx.feasible = ctx.feasible & (st.used < st.capacity)[None, :]


def _on_start(sub, ctx):
    st: ScratchState = ctx.ext["scratch"]
    dep = _site_sum(jnp.where(ctx.started, ctx.jobs.bytes_out, 0.0), ctx.start_site, ctx.S)
    ctx.ext["scratch"] = st._replace(used=st.used + dep)


def _log_spec(sub, st, jobs, sites):
    return {"site_scratch": st.used}


def _log_columns(sub, ctx, write):
    return {"site_scratch": ctx.ext["scratch"].used}


def scratch_subsystem(leak_frac: float = 0.3, purge_every: float = 21600.0) -> Subsystem:
    return Subsystem(
        name="scratch",
        config=ScratchConfig(leak_frac=leak_frac, purge_every=purge_every),
        event_times=_next_purge,
        on_completions=_on_completions,
        pre_assign=_pre_assign,
        on_start=_on_start,
        log_spec=_log_spec,
        log_columns=_log_columns,
    )


def main():
    jobs = synthetic_panda_jobs(300, seed=0, duration=6 * 3600.0)
    sites = atlas_like_platform(4, seed=1)
    pol = get_policy("panda_dispatch")
    key = jax.random.PRNGKey(0)

    base = simulate(jobs, sites, pol, key)
    print(f"no scratch model:      makespan {float(base.makespan):>10.0f}s")

    # tight scratch disks + heavy leak: sites clog until the 6h purge
    sub = scratch_subsystem(leak_frac=0.5, purge_every=6 * 3600.0)
    state0 = make_scratch(4e10, sites.capacity)
    res = simulate(jobs, sites, pol, key, subsystems=((sub, state0),), log_rows=256)
    scr = res.ext["scratch"]
    print(
        f"leaky scratch (40GB):  makespan {float(res.makespan):>10.0f}s  "
        f"purges={int(scr.n_purges)}  leaked_now={float(scr.leaked.sum()) / 1e9:.1f}GB"
    )
    assert float(res.makespan) >= float(base.makespan)

    # the subsystem's log column feeds the monitor like any built-in one
    from repro.core.monitor import extra_timeline

    tl = extra_timeline(res, "site_scratch")
    peak = tl.max(axis=0) / 1e9
    print("peak scratch per site: " + "  ".join(f"{p:.0f}GB" for p in peak))
    print("OK: a clogging scratch disk stretches the makespan, engine.py untouched")


if __name__ == "__main__":
    main()
