"""Writing a custom allocation policy — the paper's plugin mechanism (§3.3).

Two styles: a pure score function via ``make_policy`` / ``@register``, and a
subclass of the Fig.-2-style ``AllocationPlugin`` abstract class.  Both are
ordinary JAX code: jit/vmap-compatible, no simulator-core changes.

    PYTHONPATH=src python examples/custom_policy_plugin.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    AllocationPlugin,
    atlas_like_platform,
    compute_metrics,
    get_policy,
    make_policy,
    register,
    simulate,
    synthetic_panda_jobs,
)
from repro.core.policies import site_backlog


# --- style 1: a score function registered under a name -----------------------
@register("cost_aware")
def cost_aware(price_weight: float = 0.5):
    """Prefer fast sites but penalize 'expensive' (big) ones — a toy
    cost/performance broker."""

    def score(jobs, sites, state, clock, rng):
        norm_speed = sites.speed / jnp.maximum(sites.speed.max(), 1e-9)
        price = sites.cores.astype(jnp.float32) / jnp.maximum(sites.cores.max(), 1)
        s = norm_speed - price_weight * price
        return jnp.broadcast_to(s[None, :], (jobs.capacity, sites.capacity))

    return make_policy("cost_aware", score)


# --- style 2: the abstract-class API (paper Fig. 2) ---------------------------
class DeadlineAware(AllocationPlugin):
    """Jobs with higher priority go to emptier queues; tracks per-site
    completions through the onJobEnd hook."""

    name = "deadline_aware"

    def get_resource_information(self, jobs, sites):
        return jnp.zeros((sites.capacity,), jnp.int32)  # completions per site

    def assign_job(self, jobs, sites, state, clock, rng):
        q_cores, _ = site_backlog(jobs, sites)
        drain = q_cores / jnp.maximum(
            sites.speed * sites.cores.astype(jnp.float32), 1e-9
        )
        urgency = jobs.priority[:, None]
        return -drain[None, :] * (1.0 + urgency)

    def on_job_end(self, state, jobs, sites, completed, started, clock):
        from repro.core.types import DONE

        comp_site = jnp.where(completed, jobs.site, sites.capacity)
        return state + jax.ops.segment_sum(
            completed.astype(jnp.int32), comp_site, num_segments=sites.capacity + 1
        )[: sites.capacity]


def main():
    jobs = synthetic_panda_jobs(800, seed=0, duration=7200.0)
    sites = atlas_like_platform(12, seed=1)
    print(f"{'policy':>16s} {'makespan':>10s} {'mean queue':>10s} {'util':>6s}")
    for pol in (
        get_policy("random"),
        get_policy("panda_dispatch"),
        get_policy("cost_aware"),
        DeadlineAware().build(),
    ):
        res = simulate(jobs, sites, pol, jax.random.PRNGKey(0))
        m = compute_metrics(res)
        print(f"{pol.name:>16s} {float(m.makespan):>9.0f}s {float(m.mean_queue_time):>9.0f}s "
              f"{float(m.core_utilization):>6.2f}")


if __name__ == "__main__":
    main()
