"""Batched serving demo: prefill a prompt batch, decode greedily.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b --small
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.models import build_model
from repro.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.small else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    batch = {"tokens": jax.random.randint(ks[0], (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[1], (args.batch, cfg.n_frames, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16
        )

    t0 = time.time()
    out = generate(
        model, params, batch, max_new=args.max_new,
        cache_len=args.prompt_len + args.max_new + 8,
    )
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"arch={cfg.name}  generated {out.shape} in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("sample token ids:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
