"""Quickstart: simulate a small computing grid and inspect the results.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (
    atlas_like_platform,
    compute_metrics,
    get_policy,
    simulate,
    summary_str,
    synthetic_panda_jobs,
)
from repro.core.events import log_frames, transition_rows
from repro.core.monitor import render_frame, sparkline, utilization_timeline


def main():
    # 1. a 10-site grid and a day of PanDA-shaped jobs
    sites = atlas_like_platform(10, seed=1)
    jobs = synthetic_panda_jobs(1000, seed=0, duration=86400.0)

    # 2. pick an allocation policy (the paper's plugin mechanism)
    policy = get_policy("panda_dispatch")

    # 3. simulate, with the monitoring ring buffer enabled
    result = simulate(jobs, sites, policy, jax.random.PRNGKey(0), log_rows=512)

    # 4. operational metrics (queue time, utilization, throughput, ...)
    print(summary_str(compute_metrics(result)))

    # 5. live-dashboard-style frame (paper Fig. 5) + utilization sparkline
    frames = log_frames(result)
    print()
    print(render_frame(frames[len(frames) // 2], result.sites.cores))
    tl = utilization_timeline(result)
    print("\nmean grid utilization over time:")
    print("  " + sparkline(tl.mean(axis=1)))

    # 6. event-level dataset (paper Table 1)
    rows = transition_rows(result)
    print(f"\ncaptured {len(rows)} job-transition events; first three:")
    for r in rows[:3]:
        print(" ", r)


if __name__ == "__main__":
    main()
