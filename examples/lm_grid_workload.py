"""The DESIGN.md §4 bridge: schedule the LM fleet on the simulated grid.

Each (arch x shape) roofline record becomes a batch of grid jobs (FLOPs ->
work, checkpoint volume -> stage-in bytes); CGSim-JAX then answers a real
capacity-planning question: how does the training/serving fleet behave on a
WLCG-like platform under different allocation policies?

    PYTHONPATH=src python examples/lm_grid_workload.py [results/roofline]
"""
import glob
import json
import sys

import jax

from repro.core import (
    atlas_like_platform,
    compute_metrics,
    from_records,
    get_policy,
    simulate,
    summary_str,
)
from repro.core.workload import lm_job_records


def load_cells(roofline_dir: str) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(f"{roofline_dir}/*.json")):
        rec = json.load(open(path))
        if not rec.get("ok"):
            continue
        cells.append(
            dict(
                name=f"{rec['arch']}:{rec['shape']}",
                flops=rec["hlo_flops"] * rec["n_devices"],  # global per step
                bytes=rec["hlo_bytes"],
                cores=8,
                memory_gb=32.0,
                bytes_in=5e9,   # checkpoint + data shard stage-in
                steps=20,
            )
        )
    return cells


def main():
    roofline_dir = sys.argv[1] if len(sys.argv) > 1 else "results/roofline"
    cells = load_cells(roofline_dir)
    if not cells:  # sweep not run yet: synthesize a representative fleet
        cells = [
            dict(name="llama3-405b:train_4k", flops=2.5e18, cores=8, memory_gb=32,
                 bytes_in=5e9, steps=20),
            dict(name="kimi-k2:train_4k", flops=2.0e17, cores=8, memory_gb=32,
                 bytes_in=5e9, steps=20),
            dict(name="mamba2:decode_32k", flops=5e13, cores=1, memory_gb=8,
                 bytes_in=1e9, steps=100),
        ]
    print(f"fleet: {len(cells)} cells -> grid jobs")

    records = lm_job_records(cells, jobs_per_cell=6, seed=0)
    jobs = from_records(records)
    sites = atlas_like_platform(25, seed=1)
    for policy in ("random", "shortest_wait", "data_locality"):
        res = simulate(jobs, sites, get_policy(policy), jax.random.PRNGKey(0),
                       max_rounds=200_000)
        print(f"  {policy:>14s}: {summary_str(compute_metrics(res))}")


if __name__ == "__main__":
    main()
