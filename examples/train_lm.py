"""End-to-end training driver: data pipeline -> model -> fault-tolerant loop
with async checkpointing (and optional failure injection).

Default: a ~100M-parameter mamba2-family model for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 300            # full
    PYTHONPATH=src python examples/train_lm.py --small --steps 10     # smoke
    PYTHONPATH=src python examples/train_lm.py --arch deepseek-7b --small
    PYTHONPATH=src python examples/train_lm.py --inject 50,120        # chaos
"""
import argparse
import tempfile

import numpy as np

from repro.configs import get_config, get_smoke
from repro.data import DataConfig, TokenPipeline
from repro.ft import FailureInjector, train_with_restarts
from repro.models import build_model, param_count
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--small", action="store_true", help="reduced smoke config")
    ap.add_argument("--inject", default="", help="comma-separated failure steps")
    ap.add_argument("--compress", action="store_true", help="int8 grad compression")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.small else get_config(args.arch)
    if args.arch == "mamba2-130m" and not args.small:
        # ~100M-param training target on CPU: trim depth, keep the family
        cfg = cfg.replace(n_layers=12)
    model = build_model(cfg)
    pipe = TokenPipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)
    )
    injector = None
    if args.inject:
        injector = FailureInjector(at_steps=tuple(int(s) for s in args.inject.split(",")))

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    print(f"arch={cfg.name} steps={args.steps} ckpt={ckpt_dir}")
    report = train_with_restarts(
        model,
        pipe,
        total_steps=args.steps,
        ckpt_dir=ckpt_dir,
        ckpt_every=max(args.steps // 10, 5),
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                            total_steps=args.steps),
        compress=args.compress,
        injector=injector,
    )
    n_params = param_count(model.init(__import__("jax").random.PRNGKey(0)))
    losses = np.asarray(report.losses)
    print(
        f"\nparams={n_params:,}  steps={report.steps_done}  restarts={report.restarts}\n"
        f"loss: first={losses[0]:.3f} min={losses.min():.3f} last={losses[-1]:.3f}\n"
        f"step time: median={np.median(report.step_times):.2f}s  "
        f"slow-step watchdog hits={report.slow_steps}"
    )
    assert losses[-1] < losses[0], "training did not reduce the loss"


if __name__ == "__main__":
    main()
