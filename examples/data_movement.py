"""Data movement & replica management: compare data policies on a Zipf workload.

A grid where a few hot datasets dominate reads (Zipf popularity), sites have
finite storage elements, and the WAN is a tiered topology.  Three data
policies — always_remote, cache_on_read, pre_place_hot — run on the identical
workload; caching cuts WAN traffic and, when staging sits on the critical
path, the makespan.

    PYTHONPATH=src python examples/data_movement.py
"""
import jax
import numpy as np

from repro.core import (
    atlas_like_network,
    atlas_like_platform,
    get_data_policy,
    get_policy,
    make_replicas,
    simulate,
    synthetic_panda_jobs,
    zipf_dataset_sizes,
)
from repro.core.events import log_frames, transfer_rows
from repro.core.monitor import render_frame, sparkline, storage_timeline


def main():
    n_sites, n_datasets = 8, 64

    # 1. platform + WAN topology + storage elements with pinned origin copies
    sites = atlas_like_platform(n_sites, seed=1)
    net = atlas_like_network(n_sites, seed=2)
    replicas = make_replicas(
        zipf_dataset_sizes(n_datasets, seed=3, mean_bytes=30e9),
        disk_capacity=np.asarray(sites.memory) * 2e9,
        seed=4,
    )

    # 2. a day of PanDA-shaped jobs reading Zipf-popular datasets
    jobs = synthetic_panda_jobs(800, seed=0, duration=86400.0, n_datasets=n_datasets)
    policy = get_policy("panda_dispatch")

    print(f"{'data policy':>24s} | {'makespan':>10s} | {'WAN moved':>10s} | "
          f"{'hits':>5s} | {'xfers':>5s}")
    results = {}
    for name in ("always_remote", "cache_on_read", "pre_place_hot"):
        res = simulate(
            jobs, sites, policy, jax.random.PRNGKey(0),
            data_policy=get_data_policy(name), network=net, replicas=replicas,
            log_rows=256,
        )
        results[name] = res
        rep = res.replicas
        print(f"{name:>24s} | {float(res.makespan):>9.0f}s | "
              f"{float(rep.bytes_moved) / 1e12:>8.2f}TB | "
              f"{int(rep.n_hits):>5d} | {int(rep.n_transfers):>5d}")

    # 3. storage/network pressure view for the caching run (paper Fig. 5 style)
    res = results["cache_on_read"]
    frames = log_frames(res)
    print()
    print(render_frame(frames[-1], res.sites.cores, disk_cap=np.asarray(replicas.disk_cap)))
    st = storage_timeline(res)
    print("\ntotal cached bytes over time:")
    print("  " + sparkline(st.sum(axis=1)))

    # 4. the transfer stream feeds the ML dataset (Table-1 companion)
    rows = transfer_rows(res)
    print(f"\ncaptured {len(rows)} stage-in transfers; first three:")
    for r in rows[:3]:
        print(" ", r)


if __name__ == "__main__":
    main()
