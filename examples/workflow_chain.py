"""Workflow DAG demo: ATLAS-like 4-stage MC production chains.

Two experiments on the same DAG machinery (DESIGN.md §6):

1. *Data-aware workflow scheduling.*  Each chain's stages hand multi-GB
   intermediate datasets down the chain; the producing stage materializes its
   output at the site it ran on.  ``workflow_locality`` steers children to
   their parents' sites (local cache hits), while a placement-blind schedule
   with ``always_remote`` drags every intermediate across a thin WAN —
   locality-aware beats remote-always on makespan.

2. *Critical-path-first start order.*  One deep chain competes with a
   backlog of independent filler jobs on a small site.  FIFO strands each
   chain stage behind the backlog; ``critical_path_first`` ranks the site
   queue by upward rank, pulling the chain to the head — beating FIFO on
   makespan.

    PYTHONPATH=src python examples/workflow_chain.py
"""
import jax
import numpy as np

from repro.core import (
    atlas_mc_workflows,
    get_data_policy,
    get_policy,
    make_jobs,
    make_sites,
    make_workflow,
    scenario_replicas,
    simulate,
    uniform_network,
)
from repro.core.events import transfer_rows, workflow_rows
from repro.core.monitor import render_workflows


def locality_vs_remote():
    n_sites, n_tasks = 4, 8
    sites = make_sites(
        cores=[32] * n_sites,
        speed=[10.0, 9.0, 11.0, 10.0],
        memory=[512.0] * n_sites,
        bw_in=[1e9] * n_sites,
        bw_out=[1e9] * n_sites,
    )
    # thin WAN: hauling a 4 GB HITS file across it costs ~400 s per hop
    net = uniform_network(n_sites, bw=1e7, latency=0.05)
    scn = atlas_mc_workflows(n_tasks, seed=0, arrival_span=600.0)

    print("=== 1. data-aware workflow scheduling (ATLAS 4-stage chains) ===")
    print(f"{'schedule':>42s} | {'makespan':>9s} | {'WAN moved':>9s} | {'hits':>4s}")
    results = {}
    for label, policy, dpol in (
        ("remote-always (placement-blind)", get_policy("round_robin"), "always_remote"),
        ("locality-aware (workflow_locality)",
         get_policy("workflow_locality", workflow=scn.workflow, base="round_robin"),
         "cache_on_read"),
    ):
        res = simulate(
            scn.jobs, sites, policy, jax.random.PRNGKey(0),
            workflow=scn.workflow, data_policy=get_data_policy(dpol),
            network=net, replicas=scenario_replicas(scn, np.full(n_sites, 1e14)),
        )
        results[label] = res
        rep = res.replicas
        print(f"{label:>42s} | {float(res.makespan):>8.0f}s | "
              f"{float(rep.bytes_moved) / 1e9:>7.1f}GB | {int(rep.n_hits):>4d}")
    remote = results["remote-always (placement-blind)"]
    local = results["locality-aware (workflow_locality)"]
    speedup = float(remote.makespan) / float(local.makespan)
    saved = (float(remote.replicas.bytes_moved) - float(local.replicas.bytes_moved)) / 1e9
    print(f"locality-aware speedup: {speedup:.2f}x  (WAN traffic cut by {saved:.1f} GB)")

    print("\nstage-in transfers of produced datasets (remote-always, first 4):")
    for r in transfer_rows(remote)[:4]:
        print(f"  t={r['time']:>8.1f}s  job {r['job_id']:>3d} reads dataset {r['dataset']:>3d} "
              f"{r['src']} -> {r['dst']}  {r['bytes'] / 1e9:.2f} GB in {r['duration']:.1f}s")

    print("\nper-workflow timeline (locality-aware):")
    print(render_workflows(local, max_rows=6))
    return speedup


def critical_path_vs_fifo():
    n_fill, n_stages = 48, 6
    n = n_fill + n_stages
    jobs = make_jobs(
        job_id=np.arange(n),
        arrival=np.concatenate([np.zeros(n_fill), np.full(n_stages, 1.0)]),
        work=np.full(n, 1000.0),
        cores=np.ones(n),
        memory=np.ones(n),
        bytes_in=np.zeros(n),
        bytes_out=np.zeros(n),
    )
    jobs, wf = make_workflow(
        jobs, [(n_fill + k, n_fill + k + 1) for k in range(n_stages - 1)]
    )
    sites = make_sites(cores=[8], speed=[10.0], memory=[1e4], bw_in=[1e12], bw_out=[1e12])

    print("\n=== 2. critical-path-first vs FIFO (deep chain + backlog) ===")
    out = {}
    for label, pol in (
        ("fifo (arrival order)", get_policy("panda_dispatch")),
        ("critical_path_first", get_policy("critical_path_first")),
    ):
        res = simulate(jobs, sites, pol, jax.random.PRNGKey(0), workflow=wf)
        out[label] = float(res.makespan)
        rows = workflow_rows(res)
        chain = max(rows, key=lambda r: r["dag_depth"])
        print(f"{label:>24s} | makespan {out[label]:>7.0f}s | "
              f"chain finished @ {chain['t_end']:>7.0f}s")
    speedup = out["fifo (arrival order)"] / out["critical_path_first"]
    print(f"critical-path-first speedup: {speedup:.2f}x")
    return speedup


def main():
    s1 = locality_vs_remote()
    s2 = critical_path_vs_fifo()
    assert s1 > 1.0, "locality-aware should beat remote-always on makespan"
    assert s2 > 1.0, "critical-path-first should beat FIFO on makespan"


if __name__ == "__main__":
    main()
