"""FTS-style transfer queues: per-link concurrency caps on a hot data lake.

A data-lake grid where every job stages its input off site 0's storage
element.  The same workload runs under the instantaneous equal-share WAN
model (`transfers=None`) and under the queued mover at several per-link
concurrency caps: flows wait for a slot, bandwidth is shared only by flows
on the wire, and queue-wait shows up per job and per link.

    PYTHONPATH=src python examples/transfer_queue.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    atlas_like_platform,
    compute_metrics,
    get_data_policy,
    get_policy,
    make_replicas,
    make_transfers,
    simulate,
    synthetic_panda_jobs,
    uniform_network,
    zipf_dataset_sizes,
)
from repro.core.monitor import link_occupancy_timeline, sparkline, transfer_queue_timeline


def main():
    n_sites, n_datasets, n_jobs = 4, 48, 400

    # 1. platform + a flat WAN + every dataset homed at site 0's data lake
    sites = atlas_like_platform(n_sites, seed=1)
    net = uniform_network(n_sites, bw=4e8, latency=0.05)
    replicas = make_replicas(
        zipf_dataset_sizes(n_datasets, seed=3, mean_bytes=20e9),
        disk_capacity=np.array([1e14] + [4e11] * (n_sites - 1)),
        origin=np.zeros(n_datasets, np.int32),
    )
    # a tight arrival burst (~0.5h) of fat reads: the lake egress saturates
    jobs = synthetic_panda_jobs(n_jobs, seed=0, duration=2000.0, n_datasets=n_datasets)
    policy = get_policy("round_robin")  # spread jobs so the lake egress queues

    def run(transfers=None):
        return simulate(
            jobs, sites, policy, jax.random.PRNGKey(0),
            data_policy=get_data_policy("cache_on_read"), network=net,
            replicas=replicas, transfers=transfers, log_rows=512,
        )

    # 2. instantaneous model vs queued mover at increasing per-link caps
    print(f"{'WAN model':>22s} | {'makespan':>10s} | {'p95 wait':>9s} | "
          f"{'flows':>5s} | {'cancel':>6s}")
    base = run()
    print(f"{'instantaneous':>22s} | {float(base.makespan):>9.0f}s | "
          f"{'-':>9s} | {'-':>5s} | {'-':>6s}")
    results = {}
    for cap in (1, 2, 8):
        res = run(make_transfers(n_sites, jobs.capacity, max_active=cap))
        results[cap] = res
        m = compute_metrics(res)
        tse = res.ext["transfers"]
        print(f"{f'queued, max_active={cap}':>22s} | {float(res.makespan):>9.0f}s | "
              f"{float(m.p95_xfer_wait):>8.1f}s | {int(tse.n_enq):>5d} | "
              f"{int(tse.n_cancel):>6d}")

    # 3. the hot egress links: occupancy pinned at the cap while backlog drains
    res = results[2]
    occ = link_occupancy_timeline(res)   # [T, S, S] active flows per link
    qd = transfer_queue_timeline(res)    # [T, S, S] queued flows per link
    print("\nsite-0 egress, cap=2 (active flows / queued backlog over time):")
    for dst in range(1, n_sites):
        print(f"  0 -> {dst}  active " + sparkline(occ[:, 0, dst]))
        print(f"          queued " + sparkline(qd[:, 0, dst]))

    # 4. per-job queue-wait distribution (exported via events.transfer_rows
    #    and as ml_dataset features on transfers-on runs)
    moved = np.asarray(res.jobs.valid) & (np.asarray(res.jobs.xfer_bytes) > 0)
    waits = np.asarray(res.jobs.xfer_wait)[moved]
    print(f"\n{moved.sum()} staged jobs; queue-wait mean={waits.mean():.1f}s "
          f"max={waits.max():.1f}s")


if __name__ == "__main__":
    main()
