"""Site availability dynamics: the same workload under four operating regimes.

A WLCG-flavoured grid replays one day of PanDA-shaped jobs (1) on a clean
grid, (2) with a rolling maintenance calendar (announced drains), (3) with
flaky Tier-2s whose unannounced outages preempt running jobs, and (4) under a
rolling brown-out that halves each site's speed and cores in turn.
Maintenance and brown-outs stretch the makespan; the flaky run fills the
preemption/retry counters and dents utilization — and because preempted and
queued work is re-routed off dead sites, it can even rebalance a greedy
dispatcher's load.  All of it shows up in the availability timeline
(DESIGN.md §5).

    PYTHONPATH=src python examples/site_downtime.py
"""
import jax
import numpy as np

from repro.core import (
    atlas_like_platform,
    compute_metrics,
    flaky_sites,
    get_policy,
    maintenance_calendar,
    rolling_brownout,
    simulate,
    synthetic_panda_jobs,
)
from repro.core.events import availability_rows
from repro.core.monitor import availability_timeline, sparkline


def main():
    # a deliberately loaded grid (small sites, day-long backlog) so lost
    # capacity actually moves the makespan
    n_sites = 8
    sites = atlas_like_platform(n_sites, seed=1, cores_range=(32, 128))
    jobs = synthetic_panda_jobs(1500, seed=0, duration=86400.0)
    policy = get_policy("panda_dispatch")
    horizon = 3 * 86400.0

    # Tier-2s = the smaller half of the grid; they get the flaky treatment
    t2 = np.argsort(np.asarray(sites.cores)[:n_sites])[: n_sites // 2]
    scenarios = {
        "clean grid": None,
        "maintenance calendar": maintenance_calendar(
            n_sites, horizon=horizon, period=86400.0, duration=6 * 3600.0
        ),
        "flaky tier-2s": flaky_sites(
            n_sites, t2, horizon=horizon, mtbf=6 * 3600.0, mean_down=3600.0, seed=2
        ),
        "rolling brown-out": rolling_brownout(
            n_sites, horizon=horizon, factor=0.5
        ),
    }

    print(f"{'scenario':>22s} | {'makespan':>10s} | {'preempted':>9s} | "
          f"{'retries':>7s} | {'util':>5s}")
    results = {}
    for name, av in scenarios.items():
        res = simulate(
            jobs, sites, policy, jax.random.PRNGKey(0), availability=av, log_rows=512
        )
        results[name] = res
        m = compute_metrics(res)
        n_pre = int(np.asarray(res.avail.n_preempted).sum()) if res.avail is not None else 0
        retries = int(np.asarray(res.jobs.retries)[np.asarray(res.jobs.valid)].sum())
        print(f"{name:>22s} | {float(res.makespan):>9.0f}s | {n_pre:>9d} | "
              f"{retries:>7d} | {float(m.core_utilization):>5.3f}")

    # the flaky run's availability timeline: mean grid capacity over time
    res = results["flaky tier-2s"]
    tl = availability_timeline(res)
    print("\nmean availability factor over the flaky run:")
    print("  " + sparkline(tl.mean(axis=1)))

    rows = availability_rows(res)
    print(f"\n{len(rows)} outage windows; first three:")
    for r in rows[:3]:
        print(" ", r)


if __name__ == "__main__":
    main()
