"""Availability-dynamics throughput (DESIGN.md §5): engine rounds and round
rate as the downtime calendar grows.

Every window start/end is an event source, so rounds scale as
O(job events + window edges); the per-round cost adds O(S·W) window algebra.
This bench sweeps windows-per-site W at fixed workload to measure both, plus
the preemption cost of a flaky-grid scenario.  ``--tiny`` runs a
seconds-sized smoke configuration for CI.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.core import (
    atlas_like_platform,
    flaky_sites,
    get_policy,
    maintenance_calendar,
    simulate,
    synthetic_panda_jobs,
)

from .common import csv_row

HORIZON = 40 * 3600.0


def one_case(n_jobs: int, n_sites: int, availability, *, iters=2):
    jobs = synthetic_panda_jobs(n_jobs, seed=0, duration=6 * 3600.0)
    sites = atlas_like_platform(n_sites, seed=1)
    kw = dict(availability=availability, max_rounds=200_000)
    res = simulate(jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0), **kw)
    jax.block_until_ready(res.makespan)
    ts = []
    for i in range(iters):
        t0 = time.perf_counter()
        res = simulate(jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(i), **kw)
        jax.block_until_ready(res.makespan)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), int(res.rounds), res


def main():
    tiny = "--tiny" in sys.argv
    if tiny:
        win_grid = (0, 4, 8)
        n_jobs, n_sites = 200, 4
    else:
        win_grid = (0, 4, 16, 64)
        n_jobs, n_sites = 2000, 16

    print("# rounds & round rate vs windows per site W (maintenance calendar)")
    for w in win_grid:
        av = (
            maintenance_calendar(
                n_sites, horizon=HORIZON, period=HORIZON / w, duration=HORIZON / (4 * w)
            )
            if w
            else None
        )
        wall, rounds, _ = one_case(n_jobs, n_sites, av)
        print(csv_row(f"avail_W{w}_S{n_sites}", wall / max(rounds, 1) * 1e6,
                      f"rounds={rounds};wall_s={wall:.3f}"))

    print("# preemption churn (flaky grid: every site short-fails)")
    av = flaky_sites(
        n_sites, np.arange(n_sites), horizon=HORIZON, mtbf=4 * 3600.0,
        mean_down=1800.0, seed=2,
    )
    wall, rounds, res = one_case(n_jobs, n_sites, av)
    n_pre = int(np.asarray(res.avail.n_preempted).sum())
    print(csv_row(f"avail_flaky_S{n_sites}", wall / max(rounds, 1) * 1e6,
                  f"rounds={rounds};wall_s={wall:.3f};preempted={n_pre}"))


if __name__ == "__main__":
    main()
