"""Paper Fig. 4(a): job-scaling — simulator wall time vs jobs per site.

CGSim: <100 s for 1,000 jobs -> ~2,500 s for 10,000 jobs (sub-quadratic) on
an i9 laptop.  The vectorized engine is compared on the same axis.

Every bucket is padded to the largest J in the sweep (inert job rows) with a
shared static round bound, so the whole curve runs through ONE jitted
program: the sweep measures executed rounds, not per-bucket recompilation
(the pre-PR-9 version re-jitted each bucket, so small buckets timed XLA, not
the engine).  A ``*_slope`` row reports the fitted scaling exponent alpha
(wall ~ J^alpha) mirroring the paper's sub-quadratic claim.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import atlas_like_platform, get_policy, simulate, synthetic_panda_jobs
from repro.core.types import pad_jobs_capacity

from .common import csv_row


def run(job_counts=(1000, 2500, 5000, 10000), n_sites: int = 1, iters: int = 2,
        quantum: float = 0.0):
    sites = atlas_like_platform(max(n_sites, 1), seed=1, cores_range=(1000, 2000))
    pol = get_policy("panda_dispatch")
    n_max = max(job_counts)
    max_rounds = 4 * n_max + 16  # shared static bound: one compiled program
    rows = []
    for n in job_counts:
        jobs = pad_jobs_capacity(
            synthetic_panda_jobs(n, seed=0, duration=86400.0), n_max
        )
        # compile excluded (paper measures steady-state runs)
        res = simulate(jobs, sites, pol, jax.random.PRNGKey(0), max_rounds=max_rounds,
                       quantum=quantum)
        jax.block_until_ready(res.makespan)
        ts = []
        for i in range(iters):
            t0 = time.perf_counter()
            res = simulate(jobs, sites, pol, jax.random.PRNGKey(i), max_rounds=max_rounds,
                           quantum=quantum)
            jax.block_until_ready(res.makespan)
            ts.append(time.perf_counter() - t0)
        wall = float(np.median(ts))
        rows.append((n, wall, int(res.rounds)))
    return rows


def main():
    import sys

    counts = (250, 1000) if "--tiny" in sys.argv else (1000, 2500, 5000, 10000)
    print("# Fig 4(a) job scaling (1 site, one jitted program)")
    for mode, quantum in (("exact", 0.0), ("quantum30s", 30.0)):
        rows = run(job_counts=counts, quantum=quantum)
        base_n, base_t, _ = rows[0]
        for n, wall, rounds in rows:
            alpha = np.log(wall / base_t) / np.log(n / base_n) if n > base_n else 1.0
            print(csv_row(f"job_scaling_{mode}_n{n}", wall * 1e6,
                          f"rounds={rounds};alpha={alpha:.2f}"))
        n_hi, t_hi, _ = rows[-1]
        alpha = np.log(t_hi / base_t) / np.log(n_hi / base_n)
        # Fig. 4 slope row: the fitted exponent itself (dimensionless, scaled
        # into the us column so the bench gate tracks drift across commits)
        print(csv_row(f"job_scaling_{mode}_slope", alpha * 1e6, f"alpha={alpha:.2f}"))
        print(f"# {mode}: exponent {alpha:.2f} ({n_hi} jobs in {t_hi:.2f}s; "
              f"paper ~2500s, sub-quadratic)")


if __name__ == "__main__":
    main()
