"""Paper Fig. 3 + §4.2: calibration across 50 WLCG-like sites.

Headline reproduction: geometric-mean relative MAE of job walltime for
single-core and multi-core jobs, before -> after calibration (paper: 76% ->
17%), and the four-optimizer comparison (brute force / random / BO / CMA-ES;
paper: random search wins)."""
from __future__ import annotations

import time

import jax

from repro.core import atlas_like_platform, synthetic_panda_jobs
from repro.core.calibration import calibrate, closed_form_objective, make_synthetic_problem

from .common import csv_row


def run(n_jobs: int = 3000, n_sites: int = 50, seed: int = 2):
    jobs = synthetic_panda_jobs(n_jobs, seed=0, duration=30 * 86400.0)
    sites = atlas_like_platform(n_sites, seed=1)
    # misconfig_sigma tuned so the uncalibrated error sits at the paper's ~76%
    prob = make_synthetic_problem(jobs, sites, seed=seed, misconfig_sigma=1.05,
                                  noise_sigma=0.15)
    _, _, e0 = closed_form_objective(prob, prob.sites0.speed)
    out = {"initial": (float(e0), 0.0)}
    for method in ("grid", "random", "cma_es", "gp_bo"):
        t0 = time.perf_counter()
        r = calibrate(prob, method, seed=seed + 1)
        jax.block_until_ready(r.err)
        out[method] = (float(r.err), time.perf_counter() - t0)
    return out


def main():
    import sys

    tiny = "--tiny" in sys.argv
    out = run(n_jobs=400, n_sites=8) if tiny else run()
    print(f"# Fig 3 calibration: geomean relative MAE across {8 if tiny else 50} sites")
    e0 = out["initial"][0]
    print(csv_row("calibration_initial", 0.0, f"geomean_err={e0:.3f}"))
    for m in ("grid", "random", "cma_es", "gp_bo"):
        err, wall = out[m]
        print(csv_row(f"calibration_{m}", wall * 1e6, f"geomean_err={err:.3f}"))
    best = min(("grid", "random", "cma_es", "gp_bo"), key=lambda m: out[m][0])
    print(f"# paper: 76% -> 17%, random search best.  ours: {e0*100:.0f}% -> "
          f"{out['random'][0]*100:.0f}% (random); best method: {best}")


if __name__ == "__main__":
    main()
