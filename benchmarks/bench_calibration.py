"""Paper Fig. 3 + §4.2: calibration across 50 WLCG-like sites.

Headline reproduction: geometric-mean relative MAE of job walltime for
single-core and multi-core jobs, before -> after calibration (paper: 76% ->
17%), and the four-optimizer comparison (brute force / random / BO / CMA-ES;
paper: random search wins)."""
from __future__ import annotations

import time

import jax

from repro.core import atlas_like_platform, synthetic_panda_jobs
from repro.core.calibration import calibrate, closed_form_objective, make_synthetic_problem

from .common import csv_row


def run_platform(n_jobs: int = 400, n_sites: int = 6, seed: int = 2):
    """ISSUE 7: multi-parameter ``calibrate_platform`` + lane-batched vs
    looped candidate throughput on the engine-replay objective."""
    import time as _time

    import jax.random as jrandom
    import numpy as np

    from repro.core.calibration import (
        calibrate_platform,
        decode_params,
        engine_platform_objective,
        make_population_objective,
        make_synthetic_platform_problem,
        pinned_policy,
        recovery_error,
    )

    problem, truth = make_synthetic_platform_problem(
        n_jobs=n_jobs, n_sites=n_sites, seed=seed, include=("speed", "bw"),
        trace="engine", wan_frac=0.5, misconfig_sigma=0.7,
    )
    out = {}
    # method rows run the fast differentiable objective (the engine-replay
    # path is priced separately below as candidate throughput)
    for method, kw in (
        ("spsa", dict(objective="closed_form", n_iters=200, spsa_dirs=6,
                      a0=0.25, c0=0.1)),
        ("grad", dict(objective="closed_form", n_iters=150, lr=0.1)),
        ("cma_es", dict(objective="closed_form", n_iters=40)),
    ):
        t0 = _time.perf_counter()
        r = calibrate_platform(problem, method=method, include=("speed", "bw"),
                               seed=seed + 1, **kw)
        jax.block_until_ready(r.err)
        wall = _time.perf_counter() - t0
        out[f"platform_{method}"] = (
            wall, f"recov_err={recovery_error(problem, r.params, truth):.3f}")

    # candidate throughput: one compiled lane-batched program vs a Python
    # loop of solo engine objective calls (the pre-ISSUE-7 baseline)
    K = 8
    be = make_population_objective(problem, objective="engine",
                                   include=("speed", "bw"), max_rounds=6000)
    zs = be.z0[None, :] + 0.2 * jrandom.normal(
        jrandom.PRNGKey(0), (K, be.z0.shape[0]))
    rng = jrandom.PRNGKey(1)
    jax.block_until_ready(be(zs, rng))  # compile
    t0 = _time.perf_counter()
    jax.block_until_ready(be(zs, rng))
    lane_wall = _time.perf_counter() - t0
    out["platform_pop_lanes"] = (lane_wall, f"cands_per_s={K / lane_wall:.1f}")

    policy = pinned_policy(problem.hist_site)
    keys = jrandom.split(rng, K)
    loop = lambda: np.array([
        float(engine_platform_objective(
            problem, decode_params(be.unravel(z), be.bounds), keys[i],
            max_rounds=6000, policy=policy))
        for i, z in enumerate(zs)])
    loop()  # compile
    t0 = _time.perf_counter()
    loop()
    loop_wall = _time.perf_counter() - t0
    out["platform_pop_looped"] = (loop_wall, f"cands_per_s={K / loop_wall:.1f}")
    out["platform_lane_speedup"] = (loop_wall / lane_wall, "ratio_vs_loop")
    return out


def run(n_jobs: int = 3000, n_sites: int = 50, seed: int = 2):
    jobs = synthetic_panda_jobs(n_jobs, seed=0, duration=30 * 86400.0)
    sites = atlas_like_platform(n_sites, seed=1)
    # misconfig_sigma tuned so the uncalibrated error sits at the paper's ~76%
    prob = make_synthetic_problem(jobs, sites, seed=seed, misconfig_sigma=1.05,
                                  noise_sigma=0.15)
    _, _, e0 = closed_form_objective(prob, prob.sites0.speed)
    out = {"initial": (float(e0), 0.0)}
    for method in ("grid", "random", "cma_es", "gp_bo"):
        t0 = time.perf_counter()
        r = calibrate(prob, method, seed=seed + 1)
        jax.block_until_ready(r.err)
        out[method] = (float(r.err), time.perf_counter() - t0)
    return out


def main():
    import sys

    tiny = "--tiny" in sys.argv
    out = run(n_jobs=400, n_sites=8) if tiny else run()
    print(f"# Fig 3 calibration: geomean relative MAE across {8 if tiny else 50} sites")
    e0 = out["initial"][0]
    print(csv_row("calibration_initial", 0.0, f"geomean_err={e0:.3f}"))
    for m in ("grid", "random", "cma_es", "gp_bo"):
        err, wall = out[m]
        print(csv_row(f"calibration_{m}", wall * 1e6, f"geomean_err={err:.3f}"))
    best = min(("grid", "random", "cma_es", "gp_bo"), key=lambda m: out[m][0])
    print(f"# paper: 76% -> 17%, random search best.  ours: {e0*100:.0f}% -> "
          f"{out['random'][0]*100:.0f}% (random); best method: {best}")
    print("# ISSUE 7: multi-param calibrate_platform + lane-batched populations")
    pf = run_platform(n_jobs=200, n_sites=4) if tiny else run_platform()
    for name, (wall, derived) in pf.items():
        if name.endswith("speedup"):
            print(csv_row(f"calibration_{name}", wall, derived))
        else:
            print(csv_row(f"calibration_{name}", wall * 1e6, derived))


if __name__ == "__main__":
    main()
