"""Paper abstract claim: "distributed workloads achieving 6x better
performance compared to single-site execution" — simulated makespan of a
fixed PanDA-like workload on 1 site vs spread over 50 sites."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import (
    atlas_like_platform,
    compute_metrics,
    get_policy,
    simulate,
    synthetic_panda_jobs,
)

from .common import csv_row


def run(n_jobs: int = 2000):
    jobs = synthetic_panda_jobs(n_jobs, seed=0, duration=3600.0)
    pol = get_policy("shortest_wait")
    grid50 = atlas_like_platform(50, seed=1)
    # single MEDIAN site (atlas_like_platform(1) would make it a Tier-1):
    # the paper compares the grid against a representative single site
    from repro.core import make_sites
    import numpy as np
    cores = int(np.median(np.asarray(grid50.cores)))
    single = make_sites(cores=[cores], speed=[float(np.median(np.asarray(grid50.speed)))],
                        memory=[2.0 * cores], bw_in=[1.25e9], bw_out=[1.25e9])
    res1 = simulate(jobs, single, pol, jax.random.PRNGKey(0), max_rounds=5 * n_jobs)
    res50 = simulate(jobs, grid50, pol, jax.random.PRNGKey(0), max_rounds=5 * n_jobs)
    return res1, res50


def main():
    import sys

    res1, res50 = run(n_jobs=400 if "--tiny" in sys.argv else 2000)
    m1, m50 = compute_metrics(res1), compute_metrics(res50)
    speedup = float(res1.makespan) / float(res50.makespan)
    print("# distributed vs single-site (fixed workload)")
    print(csv_row("single_site_makespan", float(res1.makespan) * 1e6,
                  f"util={float(m1.core_utilization):.2f}"))
    print(csv_row("grid50_makespan", float(res50.makespan) * 1e6,
                  f"util={float(m50.core_utilization):.2f}"))
    print(csv_row("distributed_speedup", 0.0, f"x{speedup:.1f}"))
    print(f"# paper: ~6x; ours: {speedup:.1f}x")


if __name__ == "__main__":
    main()
