"""Workflow DAG throughput (DESIGN.md §6): the cost of the dependency gate
and the coupled data-movement path.

The gate adds one ``[J, P]`` gather per round plus a second after
completions; rounds grow because stages serialize.  This bench measures
(a) per-round overhead of ``workflow=`` on an identical workload (DAG edges
vs. ``workflow=None``), (b) DAG scaling in chain count, and (c) the full
coupled path: ATLAS-like 4-stage MC with output materialization through the
replica catalog.  ``--tiny`` is the seconds-sized CI smoke configuration.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.core import (
    atlas_like_network,
    atlas_like_platform,
    atlas_mc_workflows,
    chain_workflows,
    get_data_policy,
    get_policy,
    scenario_replicas,
    simulate,
)

from .common import csv_row


def one_case(jobs, sites, policy, *, iters=2, **kw):
    kw.setdefault("max_rounds", 200_000)
    res = simulate(jobs, sites, policy, jax.random.PRNGKey(0), **kw)
    jax.block_until_ready(res.makespan)
    ts = []
    for i in range(iters):
        t0 = time.perf_counter()
        res = simulate(jobs, sites, policy, jax.random.PRNGKey(i), **kw)
        jax.block_until_ready(res.makespan)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), int(res.rounds), res


def main():
    tiny = "--tiny" in sys.argv
    if tiny:
        chain_grid = (8, 32)
        n_stages, n_sites, n_mc = 4, 4, 8
    else:
        chain_grid = (32, 128, 512)
        n_stages, n_sites, n_mc = 4, 16, 64
    pol = get_policy("panda_dispatch")

    print("# dependency-gate overhead: same jobs, DAG edges vs workflow=None")
    for n_chains in chain_grid:
        scn = chain_workflows(n_chains, n_stages, seed=0, arrival_span=3600.0)
        sites = atlas_like_platform(n_sites, seed=1)
        w_flat, r_flat, _ = one_case(scn.jobs, sites, pol)
        w_dag, r_dag, _ = one_case(scn.jobs, sites, pol, workflow=scn.workflow)
        print(csv_row(
            f"wf_gate_C{n_chains}x{n_stages}_S{n_sites}",
            w_dag / max(r_dag, 1) * 1e6,
            f"rounds={r_dag};wall_s={w_dag:.3f};flat_rounds={r_flat};flat_wall_s={w_flat:.3f}",
        ))

    print("# coupled path: ATLAS 4-stage MC, outputs through the replica catalog")
    scn = atlas_mc_workflows(n_mc, seed=0, arrival_span=3600.0)
    sites = atlas_like_platform(n_sites, seed=1)
    net = atlas_like_network(n_sites, seed=2)
    rep = scenario_replicas(scn, disk_capacity=np.full(n_sites, 1e15))
    # round_robin base scatters stages across sites, so the bench actually
    # pays WAN materialize->stage-in traffic instead of all-local cache hits
    wall, rounds, res = one_case(
        scn.jobs, sites, get_policy("critical_path_first", base="round_robin"),
        workflow=scn.workflow, data_policy=get_data_policy("cache_on_read"),
        network=net, replicas=rep,
    )
    print(csv_row(
        f"wf_atlas_mc_T{n_mc}_S{n_sites}",
        wall / max(rounds, 1) * 1e6,
        f"rounds={rounds};wall_s={wall:.3f};produced={int(res.wf.n_produced)};"
        f"xfers={int(res.replicas.n_transfers)}",
    ))


if __name__ == "__main__":
    main()
