"""Beyond-paper: vmapped calibration ensembles — K independent simulations in
one device program (the paper runs candidates sequentially)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import atlas_like_platform, get_policy, simulate, synthetic_panda_jobs
from repro.core.engine import simulate_ensemble

from .common import csv_row


def main():
    import sys

    n_jobs, n_sites = (120, 4) if "--tiny" in sys.argv else (400, 10)
    jobs = synthetic_panda_jobs(n_jobs, seed=0, duration=3600.0)
    sites = atlas_like_platform(n_sites, seed=1)
    pol = get_policy("panda_dispatch")
    K = 16
    cands = sites.speed[None, :] * jnp.exp(
        0.3 * jax.random.normal(jax.random.PRNGKey(0), (K, sites.capacity))
    )

    # sequential (paper-style)
    r = simulate(jobs, sites, pol, jax.random.PRNGKey(0))  # compile
    jax.block_until_ready(r.makespan)
    t0 = time.perf_counter()
    for i in range(K):
        r = simulate(jobs, sites._replace(speed=cands[i]), pol, jax.random.PRNGKey(1))
        jax.block_until_ready(r.makespan)
    t_seq = time.perf_counter() - t0

    res = simulate_ensemble(jobs, sites, pol, jax.random.PRNGKey(1), speed_candidates=cands)
    jax.block_until_ready(res.makespan)
    t0 = time.perf_counter()
    res = simulate_ensemble(jobs, sites, pol, jax.random.PRNGKey(2), speed_candidates=cands)
    jax.block_until_ready(res.makespan)
    t_vmap = time.perf_counter() - t0

    print("# calibration ensemble: sequential vs vmapped (K=16)")
    print(csv_row("ensemble_sequential", t_seq * 1e6, ""))
    print(csv_row("ensemble_vmapped", t_vmap * 1e6, f"speedup=x{t_seq / t_vmap:.1f}"))


if __name__ == "__main__":
    main()
