"""Transfer-queue subsystem throughput (DESIGN.md §11): engine round rate
with queued, rate-limited WAN flows, swept over the per-link concurrency cap
and the queue pressure (flows contending per lake egress link), plus the
per-round overhead of the queue machinery vs the instantaneous equal-share
model.  ``--tiny`` runs a seconds-sized smoke configuration for CI.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.core import (
    atlas_like_platform,
    get_data_policy,
    get_policy,
    make_replicas,
    make_transfers,
    simulate,
    synthetic_panda_jobs,
    uniform_network,
    zipf_dataset_sizes,
)

from .common import csv_row

N_DS = 32


def one_case(n_jobs: int, n_sites: int, cap: int | None, *, iters=2):
    """One timed run: every read is a WAN flow off the site-0 data lake, so
    the egress links carry ~n_jobs/n_sites flows each.  ``cap=None`` runs the
    instantaneous model (no transfer queue) as the overhead reference."""
    jobs = synthetic_panda_jobs(
        n_jobs, seed=0, duration=3600.0, n_datasets=N_DS, zipf_alpha=1.1
    )
    sites = atlas_like_platform(n_sites, seed=1)
    net = uniform_network(n_sites, bw=2e8, latency=0.05)
    rep = make_replicas(
        zipf_dataset_sizes(N_DS, seed=3),
        disk_capacity=np.array([1e13] + [2e10] * (n_sites - 1)),
        origin=np.zeros(N_DS, np.int32),
    )
    kw = dict(
        data_policy=get_data_policy("always_remote"),
        network=net,
        replicas=rep,
        max_rounds=8 * n_jobs + 64,
    )
    if cap is not None:
        kw["transfers"] = make_transfers(n_sites, jobs.capacity, max_active=cap)
    res = simulate(jobs, sites, get_policy("round_robin"), jax.random.PRNGKey(0), **kw)
    jax.block_until_ready(res.makespan)
    ts = []
    for i in range(iters):
        t0 = time.perf_counter()
        res = simulate(
            jobs, sites, get_policy("round_robin"), jax.random.PRNGKey(i), **kw
        )
        jax.block_until_ready(res.makespan)
        ts.append(time.perf_counter() - t0)
    wall = float(np.median(ts))
    return wall, int(res.rounds), res


def main():
    tiny = "--tiny" in sys.argv
    if tiny:
        cap_grid = (1, 4)
        depth_grid = (100, 200)
        n_jobs, n_sites = 200, 4
    else:
        cap_grid = (1, 2, 8, 64)
        depth_grid = (500, 1500, 3000)
        n_jobs, n_sites = 1500, 8

    print("# round throughput vs per-link concurrency cap (J fixed)")
    for c in cap_grid:
        wall, rounds, res = one_case(n_jobs, n_sites, c)
        tse = res.ext["transfers"]
        print(csv_row(
            f"transfers_cap{c}_J{n_jobs}", wall / max(rounds, 1) * 1e6,
            f"rounds={rounds};wall_s={wall:.3f};n_enq={int(tse.n_enq)}",
        ))

    print("# round throughput vs queue depth (flows per egress link, cap fixed)")
    for j in depth_grid:
        wall, rounds, res = one_case(j, n_sites, 2)
        tse = res.ext["transfers"]
        print(csv_row(
            f"transfers_depth_J{j}", wall / max(rounds, 1) * 1e6,
            f"rounds={rounds};wall_s={wall:.3f};"
            f"flows_per_link={j // max(n_sites - 1, 1)};n_enq={int(tse.n_enq)}",
        ))

    print("# queue machinery overhead vs the instantaneous equal-share model")
    wall_on, rounds_on, _ = one_case(n_jobs, n_sites, 4)
    wall_off, rounds_off, _ = one_case(n_jobs, n_sites, None)
    us_on = wall_on / max(rounds_on, 1) * 1e6
    us_off = wall_off / max(rounds_off, 1) * 1e6
    print(csv_row(
        "transfers_round_overhead", us_on,
        f"instant_us={us_off:.1f};ratio={us_on / max(us_off, 1e-9):.2f};"
        f"rounds_on={rounds_on};rounds_off={rounds_off}",
    ))


if __name__ == "__main__":
    main()
