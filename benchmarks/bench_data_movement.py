"""Data-movement subsystem throughput (DESIGN.md §3): engine round rate vs
number of sites S and catalog size D, plus the replica-cache payoff
(cache_on_read vs always_remote WAN bytes on a Zipf workload).

The replica path adds O(D·S) catalog algebra and an O(S²) link segment-sum per
round — this bench measures how those scale.  ``--tiny`` runs a seconds-sized
smoke configuration for CI.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.core import (
    atlas_like_network,
    atlas_like_platform,
    get_data_policy,
    get_policy,
    make_replicas,
    simulate,
    synthetic_panda_jobs,
    zipf_dataset_sizes,
)

from .common import csv_row


def one_case(n_sites: int, n_datasets: int, n_jobs: int, *, policy="cache_on_read", iters=2):
    jobs = synthetic_panda_jobs(
        n_jobs, seed=0, duration=6 * 3600.0, n_datasets=n_datasets, zipf_alpha=1.2
    )
    sites = atlas_like_platform(n_sites, seed=1)
    net = atlas_like_network(n_sites, seed=2)
    rep = make_replicas(
        zipf_dataset_sizes(n_datasets, seed=3),
        disk_capacity=np.asarray(sites.memory) * 1e9,  # ~GB RAM -> bytes of disk
        seed=4,
    )
    dp = get_data_policy(policy)
    kw = dict(data_policy=dp, network=net, replicas=rep, max_rounds=4 * n_jobs + 16)
    res = simulate(jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0), **kw)
    jax.block_until_ready(res.makespan)
    ts = []
    for i in range(iters):
        t0 = time.perf_counter()
        res = simulate(jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(i), **kw)
        jax.block_until_ready(res.makespan)
        ts.append(time.perf_counter() - t0)
    wall = float(np.median(ts))
    rounds = int(res.rounds)
    return wall, rounds, res


def main():
    tiny = "--tiny" in sys.argv
    if tiny:
        site_grid = (4, 8)
        ds_grid = (16, 64)
        n_jobs = 200
    else:
        site_grid = (10, 25, 50, 100)
        ds_grid = (64, 256, 1024)
        n_jobs = 2000

    print("# round throughput vs sites S (D fixed)")
    D0 = ds_grid[0]
    for s in site_grid:
        wall, rounds, _ = one_case(s, D0, n_jobs)
        print(csv_row(f"data_mvmt_S{s}_D{D0}", wall / max(rounds, 1) * 1e6,
                      f"rounds={rounds};wall_s={wall:.3f}"))

    print("# round throughput vs catalog size D (S fixed)")
    S0 = site_grid[0]
    for d in ds_grid:
        wall, rounds, _ = one_case(S0, d, n_jobs)
        print(csv_row(f"data_mvmt_S{S0}_D{d}", wall / max(rounds, 1) * 1e6,
                      f"rounds={rounds};wall_s={wall:.3f}"))

    print("# cache payoff (Zipf reads)")
    _, _, remote = one_case(site_grid[0], D0, n_jobs, policy="always_remote", iters=1)
    _, _, cached = one_case(site_grid[0], D0, n_jobs, policy="cache_on_read", iters=1)
    rb, cb = float(remote.replicas.bytes_moved), float(cached.replicas.bytes_moved)
    print(csv_row("data_mvmt_cache_payoff", 0.0,
                  f"remote_TB={rb / 1e12:.2f};cached_TB={cb / 1e12:.2f};"
                  f"saved={100 * (1 - cb / max(rb, 1e-9)):.0f}%"))


if __name__ == "__main__":
    main()
