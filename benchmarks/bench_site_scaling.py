"""Paper Fig. 4(b): multi-site scaling — wall time vs number of sites at a
fixed density of 200 jobs/site (1..50 sites; paper: <50 s -> ~400 s,
near-linear).

Every bucket is padded to the largest (S, J) in the sweep — inert job rows
and inactive site rows — so the whole curve runs through ONE jitted program:
the sweep measures executed rounds, not per-bucket recompilation (the
pre-PR-9 version re-jitted per bucket, so small buckets timed XLA, not the
engine).  A ``*_slope`` row reports the fitted scaling exponent alpha
(wall ~ S^alpha) mirroring the paper's near-linear claim.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import atlas_like_platform, get_policy, simulate, synthetic_panda_jobs
from repro.core.types import pad_jobs_capacity

from .common import csv_row


def run(site_counts=(1, 5, 10, 25, 50), jobs_per_site: int = 200, iters: int = 2,
        quantum: float = 0.0):
    pol = get_policy("panda_dispatch")
    s_max = max(site_counts)
    n_max = s_max * jobs_per_site
    max_rounds = 4 * n_max + 16  # shared static bound: one compiled program
    rows = []
    for s in site_counts:
        n = s * jobs_per_site
        jobs = pad_jobs_capacity(
            synthetic_panda_jobs(n, seed=0, duration=6 * 3600.0), n_max
        )
        sites = atlas_like_platform(s, seed=1, capacity=s_max)
        res = simulate(jobs, sites, pol, jax.random.PRNGKey(0), max_rounds=max_rounds,
                       quantum=quantum)
        jax.block_until_ready(res.makespan)
        ts = []
        for i in range(iters):
            t0 = time.perf_counter()
            res = simulate(jobs, sites, pol, jax.random.PRNGKey(i), max_rounds=max_rounds,
                           quantum=quantum)
            jax.block_until_ready(res.makespan)
            ts.append(time.perf_counter() - t0)
        rows.append((s, float(np.median(ts)), float(res.makespan)))
    return rows


def main():
    import sys

    tiny = "--tiny" in sys.argv
    counts = (1, 4, 10) if tiny else (1, 5, 10, 25, 50)
    per_site = 50 if tiny else 200
    print(f"# Fig 4(b) multi-site scaling ({per_site} jobs/site, one jitted program)")
    for mode, quantum in (("exact", 0.0), ("quantum30s", 30.0)):
        rows = run(site_counts=counts, jobs_per_site=per_site, quantum=quantum)
        s0, t0, _ = rows[0]
        for s, wall, makespan in rows:
            alpha = np.log(wall / t0) / np.log(s / s0) if s > s0 else 1.0
            print(csv_row(f"site_scaling_{mode}_s{s}", wall * 1e6, f"alpha={alpha:.2f}"))
        s_hi, t_hi, _ = rows[-1]
        alpha = np.log(t_hi / t0) / np.log(s_hi / s0)
        # Fig. 4 slope row: the fitted exponent itself (dimensionless, scaled
        # into the us column so the bench gate tracks drift across commits)
        print(csv_row(f"site_scaling_{mode}_slope", alpha * 1e6, f"alpha={alpha:.2f}"))
        print(f"# {mode}: exponent {alpha:.2f} ({s_hi} sites in {t_hi:.2f}s; "
              f"paper ~400s, near-linear)")


if __name__ == "__main__":
    main()
