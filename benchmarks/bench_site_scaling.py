"""Paper Fig. 4(b): multi-site scaling — wall time vs number of sites at a
fixed density of 200 jobs/site (1..50 sites; paper: <50 s -> ~400 s,
near-linear)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import atlas_like_platform, get_policy, simulate, synthetic_panda_jobs

from .common import csv_row


def run(site_counts=(1, 5, 10, 25, 50), jobs_per_site: int = 200, iters: int = 2,
        quantum: float = 0.0):
    pol = get_policy("panda_dispatch")
    rows = []
    for s in site_counts:
        n = s * jobs_per_site
        jobs = synthetic_panda_jobs(n, seed=0, duration=6 * 3600.0)
        sites = atlas_like_platform(s, seed=1)
        res = simulate(jobs, sites, pol, jax.random.PRNGKey(0), max_rounds=4 * n + 16,
                       quantum=quantum)
        jax.block_until_ready(res.makespan)
        ts = []
        for i in range(iters):
            t0 = time.perf_counter()
            res = simulate(jobs, sites, pol, jax.random.PRNGKey(i), max_rounds=4 * n + 16,
                           quantum=quantum)
            jax.block_until_ready(res.makespan)
            ts.append(time.perf_counter() - t0)
        rows.append((s, float(np.median(ts)), float(res.makespan)))
    return rows


def main():
    import sys

    tiny = "--tiny" in sys.argv
    counts = (1, 4, 10) if tiny else (1, 5, 10, 25, 50)
    per_site = 50 if tiny else 200
    print(f"# Fig 4(b) multi-site scaling ({per_site} jobs/site)")
    for mode, quantum in (("exact", 0.0), ("quantum30s", 30.0)):
        rows = run(site_counts=counts, jobs_per_site=per_site, quantum=quantum)
        s0, t0, _ = rows[0]
        for s, wall, makespan in rows:
            alpha = np.log(wall / t0) / np.log(s / s0) if s > s0 else 1.0
            print(csv_row(f"site_scaling_{mode}_s{s}", wall * 1e6, f"alpha={alpha:.2f}"))
        s_hi, t_hi, _ = rows[-1]
        alpha = np.log(t_hi / t0) / np.log(s_hi / s0)
        print(f"# {mode}: exponent {alpha:.2f} (50 sites in {t_hi:.2f}s; "
              f"paper ~400s, near-linear)")


if __name__ == "__main__":
    main()
