"""Shared benchmark harness utilities."""
from __future__ import annotations

import time

import jax


def timed(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time of fn(*args) with compile excluded."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
