"""Engine round-loop throughput + scenario-ensemble scaling (ISSUE 4).

Numbers the perf trajectory tracks across commits:

- ``rounds_per_sec``: raw event-round throughput of one ``simulate`` call —
  the denominator every subsystem's overhead is priced against.
- ``ensemble_speedup_16``: end-to-end throughput of ``simulate_many`` over a
  Python loop of ``simulate`` calls for the same 16-scenario ensemble.  The
  ensemble is *ragged* — every scenario has a different workload size, the
  normal shape of surrogate-dataset generation — so the loop retraces and
  recompiles per scenario while ``stack_scenarios`` pads the batch to one
  static shape and the whole ensemble runs from a single compile (the ISSUE 4
  acceptance row; target >= 3x, measured end-to-end including compilation,
  which dominates exactly like it does in real sweep workloads).
- ``ensemble_steady_*``: the same-shape warm-cache comparison, reported for
  transparency.  On a single CPU device the round loop is compute-bound, so
  lockstep vmap rounds buy little there; the batched program pays off on
  accelerators and sharded ensembles (``simulate_ensemble_distributed``).

``--tiny`` is the seconds-sized CI smoke configuration.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Scenario,
    atlas_like_platform,
    get_policy,
    simulate,
    simulate_many,
    stack_scenarios,
    synthetic_panda_jobs,
)

from .common import csv_row

K = 16


def _timed(fn, iters=3):
    fn()  # compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _once(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main():
    tiny = "--tiny" in sys.argv
    n_jobs, n_sites = (120, 4) if tiny else (400, 8)
    # ragged ensemble: every scenario a different workload size (all distinct
    # static shapes), the natural raggedness of scenario sweeps
    rag_sizes = range(48, 48 + 2 * K, 2) if tiny else range(200, 200 + 8 * K, 8)
    pol = get_policy("panda_dispatch")
    sites = atlas_like_platform(n_sites, seed=1)

    # --- ragged 16-scenario ensemble, end-to-end (compile included) -------
    factors = jnp.linspace(0.5, 2.0, K)
    scenarios = [
        Scenario(
            synthetic_panda_jobs(n, seed=10 + i, duration=1800.0),
            sites._replace(speed=sites.speed * factors[i]),
        )
        for i, n in enumerate(rag_sizes)
    ]
    keys = jax.random.split(jax.random.PRNGKey(2), K)

    t_loop = _once(
        lambda: [
            jax.block_until_ready(simulate(s.jobs, s.sites, pol, keys[i]).makespan)
            for i, s in enumerate(scenarios)
        ]
    )
    stacked = stack_scenarios(scenarios)  # pads ragged jobs to one shape
    t_many = _once(
        lambda: jax.block_until_ready(
            simulate_many(stacked, pol, jax.random.PRNGKey(2)).makespan
        )
    )
    speedup = t_loop / t_many
    print(f"# ragged ensemble (K={K}, jobs {rag_sizes.start}..{rag_sizes[-1]}): "
          "loop recompiles per size, simulate_many compiles once")
    print(csv_row("ensemble_loop_16", t_loop * 1e6, f"compiles={K}"))
    print(csv_row("ensemble_simulate_many_16", t_many * 1e6, "compiles=1"))
    print(csv_row("ensemble_speedup_16", speedup,
                  f"target>=3.0 {'OK' if speedup >= 3.0 else 'MISS'}"))

    # --- same-shape steady state (warm jit cache), for transparency -------
    warm = [jax.tree.map(lambda x: x[i], Scenario(stacked.jobs, stacked.sites, {}))
            for i in range(K)]

    def seq():
        for i in range(K):
            jax.block_until_ready(
                simulate(warm[i].jobs, warm[i].sites, pol, keys[i]).makespan
            )

    def many():
        jax.block_until_ready(
            simulate_many(stacked, pol, jax.random.PRNGKey(2)).makespan
        )

    t_seq = _timed(seq)
    t_m = _timed(many)
    print(csv_row("ensemble_steady_loop_16", t_seq * 1e6, ""))
    print(csv_row("ensemble_steady_many_16", t_m * 1e6, f"ratio=x{t_seq / t_m:.2f}"))

    # --- single-run round throughput -------------------------------------
    jobs = synthetic_panda_jobs(n_jobs, seed=0, duration=1800.0)
    res = simulate(jobs, sites, pol, jax.random.PRNGKey(0))
    rounds = int(res.rounds)
    t_one = _timed(
        lambda: jax.block_until_ready(
            simulate(jobs, sites, pol, jax.random.PRNGKey(1)).makespan
        )
    )
    print(f"# engine rounds: J={n_jobs} S={n_sites}, {rounds} rounds/run")
    print(csv_row("simulate_one", t_one * 1e6, f"rounds_per_sec={rounds / t_one:.0f}"))


if __name__ == "__main__":
    main()
