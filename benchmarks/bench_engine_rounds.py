"""Engine round-loop throughput + scenario-ensemble scaling (ISSUE 4/5).

Numbers the perf trajectory tracks across commits:

- ``rounds_per_sec``: raw event-round throughput of one ``simulate`` call —
  the denominator every subsystem's overhead is priced against.
- ``ensemble_speedup_16``: end-to-end throughput of ``simulate_many`` over a
  Python loop of ``simulate`` calls for the same 16-scenario ensemble.  The
  ensemble is *ragged* — every scenario has a different workload size, the
  normal shape of surrogate-dataset generation — so the loop retraces and
  recompiles per scenario while ``stack_scenarios`` pads the batch to one
  static shape and the whole ensemble runs from a single compile (the ISSUE 4
  acceptance row; target >= 3x, measured end-to-end including compilation,
  which dominates exactly like it does in real sweep workloads).
- ``ensemble_bucketed_16``: the same ragged ensemble through
  ``stack_scenarios(buckets=4)`` — a few padded shape buckets instead of one
  global-max pad, trading a handful of compiles for fewer wasted dense rows
  (DESIGN.md §8).
- ``ensemble_steady_*`` and ``ensemble_sharded_*``: the warm-cache steady
  state, measured in a subprocess whose host platform is forced to
  ``--devices`` (default 4) CPU devices.  ``ensemble_steady_many_16`` runs
  the ensemble through ``simulate_many_sharded`` on the full mesh — each
  device retires its own lane block in its own while_loop (no global
  lock-step) — and its ratio against the solo-``simulate`` loop *measured in
  the same process* is the ISSUE 5 acceptance row (target >= 1.0).  The
  ``ensemble_sharded_{n}dev`` rows scale the mesh 1 -> ``--devices`` inside
  that fixed environment to show the near-linear shard scaling.

``--tiny`` is the seconds-sized CI smoke configuration.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Scenario,
    atlas_like_platform,
    get_policy,
    simulate,
    simulate_many,
    stack_scenarios,
    synthetic_panda_jobs,
)

from .common import csv_row

K = 16
N_BUCKETS = 4


def _timed(fn, iters=3):
    fn()  # compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _once(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _arg_after(flag: str, default: str) -> str:
    if flag in sys.argv:
        i = sys.argv.index(flag)
        if i + 1 < len(sys.argv):
            return sys.argv[i + 1]
    return default


def _ragged_ensemble(tiny: bool):
    """The shared ragged 16-scenario ensemble: every scenario a different
    workload size (all distinct static shapes), the natural raggedness of
    scenario sweeps."""
    n_sites = 4 if tiny else 8
    rag_sizes = range(48, 48 + 2 * K, 2) if tiny else range(200, 200 + 8 * K, 8)
    sites = atlas_like_platform(n_sites, seed=1)
    factors = jnp.linspace(0.5, 2.0, K)
    scenarios = [
        Scenario(
            synthetic_panda_jobs(n, seed=10 + i, duration=1800.0),
            sites._replace(speed=sites.speed * factors[i]),
        )
        for i, n in enumerate(rag_sizes)
    ]
    return scenarios, rag_sizes


def _ensemble_worker(tiny: bool) -> None:
    """Runs in a subprocess whose host platform is forced to N devices: the
    steady-state (warm jit cache) ensemble rows, all measured in this one
    fixed environment so loop / vmap / sharded compare apples-to-apples.

    - ``ensemble_sharded_{d}dev`` rows share the *same* flat stacked input
      across mesh sizes — pure device scaling, nothing else varies.
    - ``ensemble_steady_many_16`` is the recommended ensemble configuration
      (bucketed stacking + sharding over the full mesh + lane-sequential
      lock-step-free execution), compared against both the solo-``simulate``
      loop (the ISSUE 5 >=1.0 ratio) and the 1-device ensemble run (the
      >=2x sharded-scaling acceptance).
    """
    from repro.core.distributed import simulate_many_sharded

    n_dev = jax.device_count()
    pol = get_policy("panda_dispatch")
    scenarios, _ = _ragged_ensemble(tiny)
    stacked = stack_scenarios(scenarios)
    bucketed = stack_scenarios(scenarios, buckets=N_BUCKETS)
    keys = jax.random.split(jax.random.PRNGKey(2), K)
    iters = 2 if tiny else 5

    warm = [jax.tree.map(lambda x: x[i], Scenario(stacked.jobs, stacked.sites, {}))
            for i in range(K)]

    def loop():
        for i in range(K):
            jax.block_until_ready(
                simulate(warm[i].jobs, warm[i].sites, pol, keys[i]).makespan
            )

    t_loop = _timed(loop, iters)
    print(csv_row("ensemble_steady_loop_16", t_loop * 1e6, f"devices={n_dev}"))

    # the status-quo single-device ensemble: plain vmapped simulate_many
    # (global lock-step, batched rounds) — the "1 device" the sharded stack
    # is measured against
    t_vmap1 = _timed(
        lambda: jax.block_until_ready(
            simulate_many(stacked, pol, jax.random.PRNGKey(2)).makespan
        ),
        iters,
    )
    print(csv_row(
        "ensemble_steady_vmap_1dev", t_vmap1 * 1e6,
        f"ratio_vs_loop=x{t_loop / t_vmap1:.2f}",
    ))

    # mesh scaling 1 -> n_dev: same flat stacked input over each mesh size
    t_by_dev = {}
    d = 1
    sizes = []
    while d <= n_dev:
        sizes.append(d)
        d *= 2
    if sizes[-1] != n_dev:
        sizes.append(n_dev)
    # donate=False + pre-placed inputs throughout: steady-state throughput
    # reuses the stacked lane buffers call-to-call, so the on-mesh placement
    # is paid once instead of re-copied (for donation) every iteration
    from jax.sharding import NamedSharding, PartitionSpec

    def place(tree, mesh):
        sh = NamedSharding(mesh, PartitionSpec("data"))
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)

    for d in sizes:
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:d]), ("data",))
        placed = place(stacked, mesh)
        t = _timed(
            lambda: jax.block_until_ready(
                simulate_many_sharded(
                    placed, pol, jax.random.PRNGKey(2), mesh, donate=False
                ).makespan
            ),
            iters,
        )
        t_by_dev[d] = t
        print(csv_row(
            f"ensemble_sharded_{d}dev", t * 1e6,
            f"speedup_vs_1dev=x{t_by_dev[1] / t:.2f}",
        ))

    # the full ISSUE 5 stack: bucketed + sharded + lane-sequential
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    bucketed = type(bucketed)(
        tuple(place(b, mesh) for b in bucketed.buckets), bucketed.index
    )
    t_many = _timed(
        lambda: jax.block_until_ready(
            simulate_many_sharded(
                bucketed, pol, jax.random.PRNGKey(2), mesh, donate=False
            ).makespan
        ),
        iters,
    )
    r_loop = t_loop / t_many
    r_1dev = t_vmap1 / t_many
    if tiny:
        # the acceptance targets apply to the full configuration (the tiny
        # smoke's lanes are too small for sharding to pay) — print the
        # ratios without a verdict
        derived = (f"bucketed+sharded_{n_dev}dev;ratio_vs_loop=x{r_loop:.2f};"
                   f"vs_1dev_vmap=x{r_1dev:.2f}")
    else:
        derived = (
            f"bucketed+sharded_{n_dev}dev;ratio_vs_loop=x{r_loop:.2f} target>=1.0 "
            f"{'OK' if r_loop >= 1.0 else 'MISS'};vs_1dev_vmap=x{r_1dev:.2f} target>=2.0 "
            f"{'OK' if r_1dev >= 2.0 else 'MISS'}"
        )
    print(csv_row("ensemble_steady_many_16", t_many * 1e6, derived))

    # --- per-lane occupancy: the lock-step/padding tax, measured ----------
    # informational row (us=0 rows are skipped by the perf gate): quantifies
    # what the bucketed+sharded configuration saves on this exact ensemble
    from repro.core.telemetry import lane_occupancy

    res = simulate_many_sharded(
        bucketed, pol, jax.random.PRNGKey(2), mesh, donate=False
    )
    occ = lane_occupancy(res, buckets=bucketed)
    s, pad = occ["summary"], occ["buckets"]["summary"]
    print(csv_row(
        "ensemble_lane_occupancy", 0.0,
        f"active_frac_mean={s['active_frac_mean']:.3f};"
        f"lockstep_waste={s['lockstep_waste_frac']:.3f};"
        f"bucket_pad_waste={pad['waste_frac']:.3f};"
        f"flat_pad_waste={pad['flat_waste_frac']:.3f};"
        f"saved_rows={pad['saved_rows']}",
    ))


def main():
    tiny = "--tiny" in sys.argv
    if "--ensemble-worker" in sys.argv:
        _ensemble_worker(tiny)
        return
    n_dev = int(_arg_after("--devices", "4"))
    n_jobs, n_sites = (120, 4) if tiny else (400, 8)
    pol = get_policy("panda_dispatch")
    scenarios, rag_sizes = _ragged_ensemble(tiny)
    sites = atlas_like_platform(n_sites, seed=1)
    keys = jax.random.split(jax.random.PRNGKey(2), K)

    # --- ragged 16-scenario ensemble, end-to-end (compile included) -------
    t_loop = _once(
        lambda: [
            jax.block_until_ready(simulate(s.jobs, s.sites, pol, keys[i]).makespan)
            for i, s in enumerate(scenarios)
        ]
    )
    stacked = stack_scenarios(scenarios)  # pads ragged jobs to one shape
    t_many = _once(
        lambda: jax.block_until_ready(
            simulate_many(stacked, pol, jax.random.PRNGKey(2)).makespan
        )
    )
    speedup = t_loop / t_many
    print(f"# ragged ensemble (K={K}, jobs {rag_sizes.start}..{rag_sizes[-1]}): "
          "loop recompiles per size, simulate_many compiles once")
    print(csv_row("ensemble_loop_16", t_loop * 1e6, f"compiles={K}"))
    print(csv_row("ensemble_simulate_many_16", t_many * 1e6, "compiles=1"))
    print(csv_row("ensemble_speedup_16", speedup,
                  f"target>=3.0 {'OK' if speedup >= 3.0 else 'MISS'}"))

    # --- bucketed stacking: a few padded shapes instead of one global max --
    buckets = stack_scenarios(scenarios, buckets=N_BUCKETS)
    dense_flat = K * max(rag_sizes)
    dense_buck = sum(len(ix) * s.jobs.capacity
                     for s, ix in zip(buckets.buckets, buckets.index))
    t_buck = _once(
        lambda: jax.block_until_ready(
            simulate_many(buckets, pol, jax.random.PRNGKey(2)).makespan
        )
    )
    print(csv_row(
        "ensemble_bucketed_16", t_buck * 1e6,
        f"compiles={N_BUCKETS};padded_rows={dense_buck}vs{dense_flat};"
        f"speedup_vs_loop=x{t_loop / t_buck:.2f}",
    ))

    # --- steady state + shard scaling, on an N-device host (subprocess: the
    # host platform device count must be fixed before jax initializes) ------
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split() if "host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(flags + [f"--xla_force_host_platform_device_count={n_dev}"])
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.bench_engine_rounds", "--ensemble-worker"]
    if tiny:
        cmd.append("--tiny")
    out = subprocess.run(
        cmd, env=env, capture_output=True, text=True, timeout=1800,
        cwd=os.path.dirname(src),
    )
    if out.returncode != 0:
        print(f"# ensemble worker FAILED (devices={n_dev}):")
        sys.stdout.write(out.stderr[-2000:] + "\n")
    else:
        sys.stdout.write(out.stdout)

    # --- single-run round throughput -------------------------------------
    jobs = synthetic_panda_jobs(n_jobs, seed=0, duration=1800.0)
    res = simulate(jobs, sites, pol, jax.random.PRNGKey(0))
    rounds = int(res.rounds)
    t_one = _timed(
        lambda: jax.block_until_ready(
            simulate(jobs, sites, pol, jax.random.PRNGKey(1)).makespan
        )
    )
    print(f"# engine rounds: J={n_jobs} S={n_sites}, {rounds} rounds/run")
    print(csv_row("simulate_one", t_one * 1e6, f"rounds_per_sec={rounds / t_one:.0f}"))

    # --- telemetry overhead: recorder on vs off on the same warm run ------
    # ``*_overhead_pct`` rows gate on their fresh value (<= 5% budget) in
    # ``summarize_results --check-bench`` — the flight recorder must be
    # effectively free around the jit boundary (ISSUE 6)
    from repro.core.telemetry import TraceRecorder

    def run_plain():
        jax.block_until_ready(simulate(jobs, sites, pol, jax.random.PRNGKey(1)).makespan)

    def run_rec():
        jax.block_until_ready(
            simulate(jobs, sites, pol, jax.random.PRNGKey(1),
                     recorder=TraceRecorder()).makespan
        )

    # interleave the two variants and compare minima, so cache-warmth and
    # host jitter hit both sides equally
    run_plain(), run_rec()
    # a tiny run is ~20ms, so ms-scale host jitter flakes a 5% gate on
    # single-call samples: each sample aggregates ``reps`` calls and the two
    # variants interleave, then compare minima
    iters, reps = 10, 3
    t_off, t_on = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        for _ in range(reps):
            run_plain()
        t_off.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        for _ in range(reps):
            run_rec()
        t_on.append(time.perf_counter() - t0)
    t_off_m, t_on_m = min(t_off) / reps, min(t_on) / reps
    overhead = (t_on_m / t_off_m - 1.0) * 100.0
    print(csv_row("telemetry_overhead_pct", overhead,
                  f"recorder_on={t_on_m * 1e6:.0f}us;off={t_off_m * 1e6:.0f}us"))


if __name__ == "__main__":
    main()
