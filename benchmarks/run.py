"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV rows.  With ``--json`` each suite
additionally writes a ``BENCH_<name>.json`` result file (parsed rows +
status) so the perf trajectory is machine-readable across commits:

    python -m benchmarks.run [suite] [--json] [--out DIR]
"""
from __future__ import annotations

import contextlib
import io
import json
import pathlib
import sys
import time
import traceback

from . import (
    bench_assign_kernel,
    bench_availability,
    bench_calibration,
    bench_data_movement,
    bench_distributed,
    bench_engine_rounds,
    bench_ensemble,
    bench_events,
    bench_faults,
    bench_job_scaling,
    bench_site_scaling,
    bench_transfers,
    bench_wlcg_scale,
    bench_workflow,
)

SUITES = {
    "fig4a_job_scaling": bench_job_scaling.main,
    "fig4b_site_scaling": bench_site_scaling.main,
    "fig3_calibration": bench_calibration.main,
    "abstract_6x_distributed": bench_distributed.main,
    "table1_events": bench_events.main,
    "assign_kernel": bench_assign_kernel.main,
    "engine_rounds": bench_engine_rounds.main,
    "ensemble_vmap": bench_ensemble.main,
    "data_movement": bench_data_movement.main,
    "transfers": bench_transfers.main,
    "faults": bench_faults.main,
    "availability": bench_availability.main,
    "workflow": bench_workflow.main,
    "wlcg_scale": bench_wlcg_scale.main,
}


def parse_rows(text: str) -> list[dict]:
    """Recover structured rows from the ``csv_row`` lines a suite printed."""
    rows = []
    for line in text.splitlines():
        parts = line.split(",", 2)
        if len(parts) < 2 or line.startswith(("#", "=")):
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append(dict(name=parts[0], us_per_call=us,
                         derived=parts[2] if len(parts) > 2 else ""))
    return rows


def env_manifest() -> dict:
    """The telemetry ``RunManifest`` for this bench process: backend, device
    count, package versions — embedded in every ``BENCH_*.json`` so the perf
    gate can tell env drift from perf drift."""
    from repro.core.telemetry import run_manifest

    return run_manifest(extra=dict(tiny="--tiny" in sys.argv))


def write_json(name: str, fn, out_dir: pathlib.Path, manifest=None) -> list[str]:
    """Run one suite with stdout captured; write ``BENCH_<name>.json``."""
    buf = io.StringIO()
    t0 = time.perf_counter()
    err = None
    try:
        with contextlib.redirect_stdout(buf):
            fn()
    except Exception as e:  # noqa: BLE001
        err = f"{type(e).__name__}: {e}"
        traceback.print_exc()
    text = buf.getvalue()
    sys.stdout.write(text)
    payload = dict(
        suite=name,
        status="failed" if err else "ok",
        error=err,
        wall_s=round(time.perf_counter() - t0, 3),
        manifest=manifest,
        rows=parse_rows(text),
    )
    path = out_dir / f"BENCH_{name}.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {path} ({len(payload['rows'])} rows)")
    return [name] if err else []


def main() -> None:
    args = [a for a in sys.argv[1:]]
    as_json = "--json" in args
    out_dir = pathlib.Path(".")
    if "--out" in args:
        i = args.index("--out")
        if i + 1 >= len(args) or args[i + 1].startswith("-"):
            raise SystemExit("--out needs a directory argument")
        out_dir = pathlib.Path(args[i + 1])
        out_dir.mkdir(parents=True, exist_ok=True)
        del args[i: i + 2]
    # --tiny stays visible in sys.argv: each suite reads it there for its
    # seconds-sized CI smoke configuration
    args = [a for a in args if a not in ("--json", "--tiny")]
    only = args[0] if args else None
    failures = []
    manifest = None
    if as_json:
        manifest = env_manifest()
        mpath = out_dir / "RUN_MANIFEST.json"
        mpath.write_text(json.dumps(manifest, indent=2) + "\n")
        print(f"wrote {mpath}")
    for name, fn in SUITES.items():
        if only and only != name:
            continue
        print(f"\n=== {name} ===")
        if as_json:
            failures += write_json(name, fn, out_dir, manifest)
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"FAILED {name}: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
