"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys
import traceback

from . import (
    bench_assign_kernel,
    bench_availability,
    bench_calibration,
    bench_data_movement,
    bench_distributed,
    bench_ensemble,
    bench_events,
    bench_job_scaling,
    bench_site_scaling,
)

SUITES = {
    "fig4a_job_scaling": bench_job_scaling.main,
    "fig4b_site_scaling": bench_site_scaling.main,
    "fig3_calibration": bench_calibration.main,
    "abstract_6x_distributed": bench_distributed.main,
    "table1_events": bench_events.main,
    "assign_kernel": bench_assign_kernel.main,
    "ensemble_vmap": bench_ensemble.main,
    "data_movement": bench_data_movement.main,
    "availability": bench_availability.main,
}


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = []
    for name, fn in SUITES.items():
        if only and only != name:
            continue
        print(f"\n=== {name} ===")
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"FAILED {name}: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
