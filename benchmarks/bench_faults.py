"""Fault-injection subsystem cost (DESIGN.md §13): per-round overhead of an
armed-but-inert faults state vs ``faults=None`` (the exactness contract says
the *results* are byte-identical; this row prices the extra round work), the
cost with every channel firing, and the blacklist-recovery win on the
blackhole-site scenario.  ``--tiny`` runs a seconds-sized smoke for CI.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    flaky_grid,
    get_policy,
    lossy_links,
    make_faults,
    simulate,
    synthetic_panda_jobs,
)

from .common import csv_row


def timed(jobs, sites, *, faults=None, iters=2, seed0=0, **kw):
    res = simulate(jobs, sites, get_policy("least_loaded"),
                   jax.random.PRNGKey(seed0), faults=faults, **kw)
    jax.block_until_ready(res.makespan)
    ts = []
    for i in range(iters):
        t0 = time.perf_counter()
        res = simulate(jobs, sites, get_policy("least_loaded"),
                       jax.random.PRNGKey(seed0 + i), faults=faults, **kw)
        jax.block_until_ready(res.makespan)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), int(res.rounds), res


def flaky_workload(n_jobs, n_sites, *, arrival_span, seed=7):
    """The blackhole-site scenario (examples/chaos_day.py): homogeneous small
    sites plus trickle arrivals, so ``least_loaded`` chases the flaky site."""
    sites, flaky_idx = flaky_grid(
        n_sites, n_flaky=1, seed=12, cores_range=(8, 8), speed_range=(10.0, 10.0)
    )
    rng = np.random.default_rng(seed)
    jobs = synthetic_panda_jobs(n_jobs, seed=seed, capacity=n_jobs + 3)
    jobs = jobs._replace(
        arrival=jnp.asarray(
            np.pad(np.sort(rng.uniform(0.0, arrival_span, n_jobs)), (0, 3),
                   constant_values=np.inf),
            jnp.float32,
        ),
        work=jnp.asarray(
            np.pad(rng.lognormal(np.log(800.0), 0.6, n_jobs), (0, 3)), jnp.float32
        ),
        cores=jnp.ones((jobs.capacity,), jnp.int32),
        memory=jnp.full((jobs.capacity,), 2.0),
    )
    return jobs, sites, flaky_idx


def main():
    tiny = "--tiny" in sys.argv
    if tiny:
        n_jobs, n_sites = 200, 4
        flaky_jobs, span = 120, 400.0
    else:
        n_jobs, n_sites = 1500, 8
        flaky_jobs, span = 600, 2000.0

    # 1. armed-but-inert round overhead vs faults=None — the price of the
    # fifth phase pipeline stage when every channel is off
    jobs = synthetic_panda_jobs(n_jobs, seed=0, duration=3600.0)
    sites, _ = flaky_grid(n_sites, n_flaky=0, seed=1)
    inert = make_faults(n_sites, jobs.capacity)
    wall_on, rounds_on, _ = timed(jobs, sites, faults=inert)
    wall_off, rounds_off, _ = timed(jobs, sites, faults=None)
    us_on = wall_on / max(rounds_on, 1) * 1e6
    us_off = wall_off / max(rounds_off, 1) * 1e6
    print("# inert faults state vs faults=None (results are byte-identical)")
    print(csv_row(
        "faults_round_overhead", us_on,
        f"off_us={us_off:.1f};ratio={us_on / max(us_off, 1e-9):.2f};"
        f"rounds_on={rounds_on};rounds_off={rounds_off}",
    ))

    # 2. every channel armed and firing
    armed = make_faults(
        n_sites, jobs.capacity,
        link_fail_p=lossy_links(n_sites, p=0.05, seed=3),
        xfer_backoff=30.0, job_backoff=60.0, walltime=4 * 3600.0,
        replica_loss=[(600.0, 0, s) for s in range(1, n_sites)],
        blacklist_threshold=0.7,
    )
    wall_all, rounds_all, res = timed(jobs, sites, faults=armed, max_retries=4)
    fs = res.ext["faults"]
    print("# all four channels armed")
    print(csv_row(
        "faults_all_channels", wall_all / max(rounds_all, 1) * 1e6,
        f"rounds={rounds_all};n_kills={int(fs.n_kills)};"
        f"time_lost_s={float(fs.time_lost):.0f}",
    ))

    # 3. blacklist recovery: the breaker must beat the blackhole site
    jobs, sites, flaky_idx = flaky_workload(flaky_jobs, 4, arrival_span=span)
    base = dict(job_backoff=120.0)
    fl_off = make_faults(4, jobs.capacity, **base)
    fl_on = make_faults(4, jobs.capacity, blacklist_threshold=0.6,
                        blacklist_alpha=0.5, blacklist_cooldown=600.0, **base)
    kw = dict(max_retries=6, iters=1, seed0=1)
    _, _, r_off = timed(jobs, sites, faults=fl_off, **kw)
    _, _, r_on = timed(jobs, sites, faults=fl_on, **kw)
    mk_off, mk_on = float(r_off.makespan), float(r_on.makespan)
    win_pct = 100.0 * (1.0 - mk_on / mk_off)
    print("# blacklist recovery on the blackhole-site scenario")
    print(csv_row(
        "faults_blacklist_recovery", mk_on,
        f"no_blacklist_makespan_s={mk_off:.0f};win_pct={win_pct:.1f};"
        f"trips={int(r_on.ext['faults'].n_bl_trips)};"
        f"flaky_fails={int(np.asarray(r_on.sites.n_failed)[flaky_idx[0]])}",
    ))
    if win_pct <= 0.0:
        raise SystemExit(
            f"blacklisting did not improve the flaky-grid makespan "
            f"({mk_on:.0f}s vs {mk_off:.0f}s)"
        )


if __name__ == "__main__":
    main()
