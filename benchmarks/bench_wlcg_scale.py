"""WLCG-scale single scenario: S=300 sites, J=100k jobs (DESIGN.md §12).

Headline row for the sparse top-k path (engine ``topk=``): steady-state
rounds/sec at WLCG scale, sparse (k=16 candidates) vs the dense ``[J, S]``
scoring path, on the ``data_locality`` policy whose dense score does real
per-pair arithmetic.

Two methodology points:

- **Marginal rate, not total wall.**  Candidate-set construction
  (``lax.top_k`` over ``[J, S]`` at init) costs seconds at this scale but is
  paid once per simulation, while rounds number in the thousands.  Timing a
  short run would charge the whole init to a handful of rounds, so each mode
  is run at two round budgets and the per-round cost is the slope
  ``(wall_hi - wall_lo) / (mr_hi - mr_lo)``.  The init cost itself is
  reported as its own row (the intercept).
- **Ratio row.**  ``*_speedup_*`` is machine-independent (same host, same
  scenario, two code paths) and is the row the perf gate holds to a floor;
  absolute timings only gate loosely.

``--tiny`` shrinks to S=24 / J=2000 / k=8 for the CI smoke configuration
(committed baselines under ``benchmarks/baselines``).
"""
from __future__ import annotations

import time

import jax

from repro.core import atlas_like_platform, get_policy, simulate, synthetic_panda_jobs

from .common import csv_row


def _wall(jobs, sites, pol, *, max_rounds: int, topk: int | None) -> float:
    # warmup compiles + primes caches; timed run measures execution only
    for key in (0, 1):
        t0 = time.perf_counter()
        res = simulate(jobs, sites, pol, jax.random.PRNGKey(key),
                       max_rounds=max_rounds, topk=topk)
        jax.block_until_ready(res.makespan)
        wall = time.perf_counter() - t0
    return wall


def measure(n_sites: int, n_jobs: int, k: int, mr_lo: int, mr_hi: int):
    sites = atlas_like_platform(n_sites, seed=1)
    jobs = synthetic_panda_jobs(n_jobs, seed=0, duration=6 * 3600.0)
    pol = get_policy("data_locality")
    out = {}
    for label, topk in (("dense", None), ("sparse", k)):
        lo = _wall(jobs, sites, pol, max_rounds=mr_lo, topk=topk)
        hi = _wall(jobs, sites, pol, max_rounds=mr_hi, topk=topk)
        per_round = max((hi - lo) / (mr_hi - mr_lo), 1e-9)
        init = max(lo - mr_lo * per_round, 0.0)
        out[label] = (per_round, init)
    return out


def main():
    import sys

    tiny = "--tiny" in sys.argv
    S, J, k = (24, 2000, 8) if tiny else (300, 100_000, 16)
    mr_lo, mr_hi = (4, 20) if tiny else (8, 40)
    tag = f"S{S}_J{J // 1000}k" if J % 1000 == 0 else f"S{S}_J{J}"
    print(f"# WLCG-scale scenario: {S} sites x {J} jobs, data_locality policy, "
          f"marginal rate over rounds {mr_lo}->{mr_hi}")
    res = measure(S, J, k, mr_lo, mr_hi)
    dense_pr, dense_init = res["dense"]
    sparse_pr, sparse_init = res["sparse"]
    speedup = dense_pr / sparse_pr
    print(csv_row(f"scaling_rounds_per_sec_{tag}", sparse_pr * 1e6,
                  f"rounds_per_sec={1.0 / sparse_pr:.2f};k={k}"))
    print(csv_row(f"wlcg_dense_round_{tag}", dense_pr * 1e6,
                  f"rounds_per_sec={1.0 / dense_pr:.2f}"))
    print(csv_row(f"wlcg_candidate_init_{tag}", sparse_init * 1e6,
                  f"dense_init_s={dense_init:.2f}"))
    print(csv_row(f"wlcg_sparse_speedup_{tag}", speedup, f"k={k};target>=3x" if not tiny else f"k={k}"))
    print(f"# sparse {1.0 / sparse_pr:.2f} rounds/s vs dense {1.0 / dense_pr:.2f} "
          f"rounds/s -> {speedup:.2f}x")


if __name__ == "__main__":
    main()
