"""Paper Table 1 + §4.3.2: event-level dataset generation throughput and a
sample of the captured schema."""
from __future__ import annotations

import time

import jax

from repro.core import atlas_like_platform, get_policy, simulate, synthetic_panda_jobs
from repro.core.events import ml_dataset, transition_rows

from .common import csv_row


def main():
    import sys

    n = 400 if "--tiny" in sys.argv else 2000
    jobs = synthetic_panda_jobs(n, seed=0, duration=6 * 3600.0)
    sites = atlas_like_platform(20, seed=1)
    res = simulate(jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0),
                   max_rounds=5 * n)
    jax.block_until_ready(res.makespan)
    t0 = time.perf_counter()
    rows = transition_rows(res)
    t_rows = time.perf_counter() - t0
    t0 = time.perf_counter()
    ds = ml_dataset(res)
    t_ds = time.perf_counter() - t0
    print("# Table 1 event-level dataset")
    print(csv_row("transition_rows", t_rows * 1e6, f"n_events={len(rows)}"))
    print(csv_row("ml_dataset", t_ds * 1e6,
                  f"n={ds['walltime'].shape[0]};features={ds['features'].shape[1]}"))
    print("# sample rows (cf. paper Table 1):")
    for r in rows[len(rows) // 2: len(rows) // 2 + 4]:
        print("#", {k: r[k] for k in ("event_id", "job_id", "state", "site",
                                      "avail_cores", "pending_jobs",
                                      "assigned_jobs", "finished_jobs")})


if __name__ == "__main__":
    main()
