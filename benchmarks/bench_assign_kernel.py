"""Beyond-paper: the assignment kernel family (CGSim assignJob == MoE router,
DESIGN.md §3) — jnp oracle vs Pallas(interpret) on simulator- and
router-shaped problems.  On CPU the interpret-mode kernel measures semantics,
not speed; the oracle timing is the deployable-jnp datapoint."""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.assign.ops import assign, make_capacity_assign
from repro.kernels.assign.ref import assign_ref

from .common import csv_row, timed


def main():
    tiny = "--tiny" in sys.argv
    cases = [
        ("jobs_x_sites", 4096, 64, 1),      # simulator dispatch shape
        ("tokens_x_experts_granite", 8192, 32, 8),
        ("tokens_x_experts_kimi", 4096, 384, 8),
    ]
    if tiny:
        # seconds-sized CI smoke: still drives the Pallas kernel (interpret
        # mode on CPU) against the jnp oracle, just on a small shape
        cases = [("tiny_smoke", 256, 8, 1)]
    print("# assignment kernel (jobs->sites == tokens->experts)")
    for name, N, E, k in cases:
        rng = np.random.default_rng(0)
        scores = jnp.asarray(rng.normal(size=(N, E)).astype(np.float32))
        sizes = jnp.ones((N,), jnp.float32)
        caps = jnp.full((E,), max(4.0, N * k / E * 1.25), jnp.float32)
        f_ref = jax.jit(lambda s: assign_ref(s, sizes, caps, k=k))
        t_ref = timed(f_ref, scores)
        print(csv_row(f"assign_ref_{name}", t_ref * 1e6, f"N={N};E={E};k={k}"))
        # interpret-mode correctness spot check vs oracle on this shape
        out_k = assign(scores, sizes, caps, k=k, use_kernel=True)
        out_r = assign(scores, sizes, caps, k=k, use_kernel=False)
        ok = all(
            np.allclose(np.asarray(a), np.asarray(b), atol=1e-5)
            for a, b in zip(out_k, out_r)
        )
        print(csv_row(f"assign_pallas_match_{name}", 0.0, f"allclose={ok}"))

    if tiny:
        # the engine-facing combinator: backend-aware default (kernel on TPU,
        # jnp oracle elsewhere) plus a forced-kernel interpret-mode row so CI
        # exercises the Pallas path end-to-end through the Policy API
        from repro.core import get_policy, simulate, with_capacity_assign
        from repro.core.platform import atlas_like_platform
        from repro.core.workload import synthetic_panda_jobs

        jobs = synthetic_panda_jobs(48, seed=0, duration=300.0)
        sites = atlas_like_platform(3, seed=1)
        auto = jax.default_backend() == "tpu"
        results = {}
        for tag, flag in (("backend_default", None), ("forced_kernel", True)):
            pol = with_capacity_assign(
                get_policy("panda_dispatch"),
                make_capacity_assign(jobs_cores=jobs.cores, use_kernel=flag),
            )
            t0 = time.perf_counter()
            res = simulate(jobs, sites, pol, jax.random.PRNGKey(0))
            ms = float(res.makespan)
            results[tag] = ms
            print(csv_row(
                f"capacity_assign_{tag}", (time.perf_counter() - t0) * 1e6,
                f"use_kernel={'tpu-auto' if flag is None else flag};"
                f"backend={jax.default_backend()};auto_resolves={auto}",
            ))
        match = results["backend_default"] == results["forced_kernel"]
        print(csv_row("capacity_assign_kernel_match", 0.0, f"equal={match}"))

        # fused candidate-set kernel (sparse top-k path, fused.py): interpret
        # -mode smoke through the engine — topk=S with the fused assign must
        # reproduce the dense makespan bit-for-bit, kernel and oracle alike
        from repro.core import with_fused_assign
        from repro.kernels.assign.ops import make_fused_capacity_assign

        dense_pol = with_capacity_assign(
            get_policy("panda_dispatch"),
            make_capacity_assign(jobs_cores=jobs.cores, use_kernel=False),
        )
        res_d = simulate(jobs, sites, dense_pol, jax.random.PRNGKey(0))
        ms_dense = float(res_d.makespan)
        fused = {}
        for tag, flag in (("oracle", False), ("interpret_kernel", True)):
            pol = with_fused_assign(
                get_policy("panda_dispatch"),
                make_fused_capacity_assign(jobs_cores=jobs.cores, use_kernel=flag),
            )
            t0 = time.perf_counter()
            res = simulate(jobs, sites, pol, jax.random.PRNGKey(0),
                           topk=sites.capacity)
            fused[tag] = float(res.makespan)
            print(csv_row(
                f"fused_assign_{tag}", (time.perf_counter() - t0) * 1e6,
                f"use_kernel={flag};topk={sites.capacity}",
            ))
        ok = all(v == ms_dense for v in fused.values())
        print(csv_row("fused_assign_match", 0.0, f"equal_dense={ok}"))


if __name__ == "__main__":
    main()
