"""Beyond-paper: the assignment kernel family (CGSim assignJob == MoE router,
DESIGN.md §3) — jnp oracle vs Pallas(interpret) on simulator- and
router-shaped problems.  On CPU the interpret-mode kernel measures semantics,
not speed; the oracle timing is the deployable-jnp datapoint."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.assign.ops import assign
from repro.kernels.assign.ref import assign_ref

from .common import csv_row, timed


def main():
    cases = [
        ("jobs_x_sites", 4096, 64, 1),      # simulator dispatch shape
        ("tokens_x_experts_granite", 8192, 32, 8),
        ("tokens_x_experts_kimi", 4096, 384, 8),
    ]
    print("# assignment kernel (jobs->sites == tokens->experts)")
    for name, N, E, k in cases:
        rng = np.random.default_rng(0)
        scores = jnp.asarray(rng.normal(size=(N, E)).astype(np.float32))
        sizes = jnp.ones((N,), jnp.float32)
        caps = jnp.full((E,), max(4.0, N * k / E * 1.25), jnp.float32)
        f_ref = jax.jit(lambda s: assign_ref(s, sizes, caps, k=k))
        t_ref = timed(f_ref, scores)
        print(csv_row(f"assign_ref_{name}", t_ref * 1e6, f"N={N};E={E};k={k}"))
        # interpret-mode correctness spot check vs oracle on this shape
        out_k = assign(scores, sizes, caps, k=k, use_kernel=True)
        out_r = assign(scores, sizes, caps, k=k, use_kernel=False)
        ok = all(
            np.allclose(np.asarray(a), np.asarray(b), atol=1e-5)
            for a, b in zip(out_k, out_r)
        )
        print(csv_row(f"assign_pallas_match_{name}", 0.0, f"allclose={ok}"))


if __name__ == "__main__":
    main()
