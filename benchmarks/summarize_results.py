"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from results/,
and gate CI on benchmark regressions.

    PYTHONPATH=src python -m benchmarks.summarize_results [--dryrun DIR] [--roofline DIR]

Perf-regression gate (ISSUE 5): diff freshly produced tiny-suite
``BENCH_*.json`` rows against the committed baselines with a tolerance band
and exit non-zero on large regressions:

    python -m benchmarks.summarize_results --check-bench bench-results \
        [--baselines benchmarks/baselines] [--tol-time 1.5] [--tol-speedup 0.5]

Timing rows (``us_per_call``) fail when more than ``(1 + tol_time)`` times
the baseline; rows whose name contains ``speedup`` are ratios (higher is
better, machine-independent) and fail below ``(1 - tol_speedup)`` times the
baseline.  The deliberately generous default bands absorb shared-runner
jitter and runner-class differences (baselines are committed from one
machine; absolute timings — especially compile-dominated rows — routinely
vary 2-3x across hosts): the gate is for *large* regressions (a suite
erroring out, an accidental recompile in a hot loop, a 4x slowdown), not
micro-noise.  Sub-millisecond rows are skipped outright (``--min-us``) —
they measure dispatch overhead, not the simulator.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024 or unit == "TB":
            return f"{b:.1f}{unit}" if unit != "B" else f"{int(b)}B"
        b /= 1024


def dryrun_table(d):
    rows = []
    for f in sorted(glob.glob(f"{d}/*.json")):
        if f.endswith("skips.json"):
            continue
        r = json.load(open(f))
        if not r.get("ok"):
            rows.append((r.get("mesh", "?"), r["arch"], r["shape"], "FAIL", "", "", ""))
            continue
        m = re.search(r"argument_size_in_bytes=(\d+)", r["memory_analysis"])
        t = re.search(r"temp_size_in_bytes=(\d+)", r["memory_analysis"])
        args_gb = int(m.group(1)) / 2**30 if m else -1
        temp_gb = int(t.group(1)) / 2**30 if t else -1
        coll = r.get("coll_breakdown", {})
        sched = " ".join(
            f"{k.split('-')[0][:2]}{k.split('-')[1][:1] if '-' in k else ''}:{fmt_bytes(v)}"
            for k, v in coll.items() if v > 0
        )
        rows.append((r["mesh"], r["arch"], r["shape"], "ok",
                     f"{args_gb:.2f}", f"{temp_gb:.2f}", sched))
    out = ["| mesh | arch | shape | compile | args GB/dev | temp GB/dev | collective schedule (module-once) |",
           "|---|---|---|---|---|---|---|"]
    for r in sorted(rows):
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def roofline_table(d):
    out = ["| arch | shape | kind | compute s | memory s | collective s | bound | step s | roofline frac | useful ratio |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    rows = []
    for f in sorted(glob.glob(f"{d}/*.json")):
        r = json.load(open(f))
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['bottleneck']} | "
            f"{r['step_s']:.4f} | {r['roofline_frac']:.3f} | {r['useful_ratio']:.2f} |"
        )
    return "\n".join(out + sorted(rows))


def perf_table(d):
    out = []
    for f in sorted(glob.glob(f"{d}/*.jsonl")):
        out.append(f"\n**{f.split('/')[-1].replace('.jsonl','').replace('__',' x ')}**\n")
        out.append("| variant | compute s | memory s | collective s | bound | step s | frac |")
        out.append("|---|---|---|---|---|---|---|")
        for line in open(f):
            r = json.loads(line)
            if not r.get("ok"):
                out.append(f"| {r.get('variant','?')} | FAIL: {r.get('error','')[:60]} | | | | | |")
                continue
            out.append(
                f"| {r['variant']} {r.get('overrides','')} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['bottleneck']} | "
                f"{r['step_s']:.4f} | {r['roofline_frac']:.3f} |"
            )
    return "\n".join(out)


def _is_speedup_row(name: str) -> bool:
    return "speedup" in name


def _is_overhead_row(name: str) -> bool:
    return name.endswith("_overhead_pct")


# perf-relevant manifest keys (mirrors telemetry._DRIFT_KEYS — kept local so
# the gate runs without repro on the path)
_DRIFT_KEYS = (
    ("jax", "version"),
    ("jax", "backend"),
    ("jax", "device_count"),
    ("jax", "device_kinds"),
    ("versions", "python"),
    ("versions", "numpy"),
)


def _manifest_drift(fresh: dict, baseline: dict) -> list[str]:
    diffs = []
    for section, key in _DRIFT_KEYS:
        a = (fresh.get(section) or {}).get(key)
        b = (baseline.get(section) or {}).get(key)
        if a != b:
            diffs.append(f"{section}.{key}: fresh={a!r} baseline={b!r}")
    return diffs


def warn_manifest_drift(new: dict, base: dict, suite: str) -> None:
    """Env drift between a fresh run and the committed baseline explains perf
    drift — surface it next to the verdicts, but never fail on it (baselines
    are committed from a different machine by design)."""
    fresh, baseline = new.get("manifest"), base.get("manifest")
    if not fresh or not baseline:
        return
    for d in _manifest_drift(fresh, baseline):
        print(f"{suite:<22} WARNING manifest drift — {d}")


def check_bench(
    new_dir: str, base_dir: str, tol_time: float, tol_speedup: float, min_us: float,
    tol_overhead_pct: float = 5.0,
) -> int:
    """Compare fresh BENCH_*.json rows against committed baselines.

    Returns the number of violations (0 = gate passes).  Rows with
    ``us_per_call <= 0`` are correctness markers (e.g. ``*_match``), not
    timings, and are skipped, as are timing rows whose baseline is under
    ``min_us`` (microbenchmarks dominated by dispatch noise); rows new in
    this commit pass by definition and become gated once the baselines are
    regenerated.

    Two further checks: fresh-vs-baseline manifest drift prints warnings
    (env drift explains perf drift — never fatal), and ``*_overhead_pct``
    rows gate on their *fresh* value alone (absolute budget, e.g. the
    telemetry recorder must stay within ``tol_overhead_pct`` of free) —
    machine-independent, so no baseline is needed.
    """
    failures = 0
    baselines = sorted(glob.glob(os.path.join(base_dir, "BENCH_*.json")))
    if not baselines:
        print(f"perf gate: no baselines under {base_dir}", file=sys.stderr)
        return 1
    print(f"{'suite':<22} {'row':<34} {'base':>12} {'new':>12}  verdict")
    for bf in baselines:
        base = json.load(open(bf))
        suite = base["suite"]
        nf = os.path.join(new_dir, os.path.basename(bf))
        if not os.path.exists(nf):
            print(f"{suite:<22} {'<suite missing>':<34} {'':>12} {'':>12}  FAIL")
            failures += 1
            continue
        new = json.load(open(nf))
        if new.get("status") != "ok":
            print(f"{suite:<22} {'<suite errored>':<34} {'':>12} {'':>12}  "
                  f"FAIL ({new.get('error')})")
            failures += 1
            continue
        warn_manifest_drift(new, base, suite)
        new_rows = {r["name"]: r for r in new["rows"]}
        for row in base["rows"]:
            name, old_v = row["name"], row["us_per_call"]
            if old_v <= 0 or _is_overhead_row(name):
                continue
            if not _is_speedup_row(name) and old_v < min_us:
                continue
            if name not in new_rows:
                print(f"{suite:<22} {name:<34} {old_v:>12.1f} {'<gone>':>12}  FAIL")
                failures += 1
                continue
            new_v = new_rows[name]["us_per_call"]
            if _is_speedup_row(name):
                ok = new_v >= old_v * (1.0 - tol_speedup)
                verdict = "ok" if ok else f"FAIL (< x{1.0 - tol_speedup:.2f} of baseline)"
            else:
                ok = new_v <= old_v * (1.0 + tol_time)
                verdict = "ok" if ok else f"FAIL (> x{1.0 + tol_time:.2f} of baseline)"
            failures += 0 if ok else 1
            print(f"{suite:<22} {name:<34} {old_v:>12.1f} {new_v:>12.1f}  {verdict}")
        # absolute-budget rows gate on the fresh run alone
        for name, row in sorted(new_rows.items()):
            if not _is_overhead_row(name):
                continue
            v = row["us_per_call"]
            ok = v <= tol_overhead_pct
            verdict = "ok" if ok else f"FAIL (> {tol_overhead_pct:.1f}% budget)"
            failures += 0 if ok else 1
            print(f"{suite:<22} {name:<34} {'<=' + format(tol_overhead_pct, '.1f') + '%':>12} "
                  f"{v:>11.1f}%  {verdict}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--roofline", default="results/roofline")
    ap.add_argument("--perf", default="results/perf")
    ap.add_argument("--section", default="all")
    ap.add_argument("--check-bench", default=None, metavar="DIR",
                    help="gate: diff DIR/BENCH_*.json against --baselines")
    ap.add_argument("--baselines", default="benchmarks/baselines")
    ap.add_argument("--tol-time", type=float, default=3.0,
                    help="timing rows fail above (1+tol)*baseline")
    ap.add_argument("--tol-speedup", type=float, default=0.5,
                    help="speedup rows fail below (1-tol)*baseline")
    ap.add_argument("--min-us", type=float, default=1000.0,
                    help="skip timing rows whose baseline is below this")
    ap.add_argument("--tol-overhead-pct", type=float, default=5.0,
                    help="*_overhead_pct rows fail above this fresh value")
    args = ap.parse_args()
    if args.check_bench:
        n = check_bench(args.check_bench, args.baselines, args.tol_time,
                        args.tol_speedup, args.min_us, args.tol_overhead_pct)
        if n:
            raise SystemExit(f"perf gate: {n} regression(s) beyond tolerance")
        print("perf gate: ok")
        return
    if args.section in ("all", "dryrun"):
        print("## §Dry-run\n")
        print(dryrun_table(args.dryrun))
    if args.section in ("all", "roofline"):
        print("\n## §Roofline\n")
        print(roofline_table(args.roofline))
    if args.section in ("all", "perf"):
        print("\n## §Perf variants\n")
        print(perf_table(args.perf))


if __name__ == "__main__":
    main()
