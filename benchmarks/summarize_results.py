"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from results/.

    PYTHONPATH=src python -m benchmarks.summarize_results [--dryrun DIR] [--roofline DIR]
"""
from __future__ import annotations

import argparse
import glob
import json
import re


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024 or unit == "TB":
            return f"{b:.1f}{unit}" if unit != "B" else f"{int(b)}B"
        b /= 1024


def dryrun_table(d):
    rows = []
    for f in sorted(glob.glob(f"{d}/*.json")):
        if f.endswith("skips.json"):
            continue
        r = json.load(open(f))
        if not r.get("ok"):
            rows.append((r.get("mesh", "?"), r["arch"], r["shape"], "FAIL", "", "", ""))
            continue
        m = re.search(r"argument_size_in_bytes=(\d+)", r["memory_analysis"])
        t = re.search(r"temp_size_in_bytes=(\d+)", r["memory_analysis"])
        args_gb = int(m.group(1)) / 2**30 if m else -1
        temp_gb = int(t.group(1)) / 2**30 if t else -1
        coll = r.get("coll_breakdown", {})
        sched = " ".join(
            f"{k.split('-')[0][:2]}{k.split('-')[1][:1] if '-' in k else ''}:{fmt_bytes(v)}"
            for k, v in coll.items() if v > 0
        )
        rows.append((r["mesh"], r["arch"], r["shape"], "ok",
                     f"{args_gb:.2f}", f"{temp_gb:.2f}", sched))
    out = ["| mesh | arch | shape | compile | args GB/dev | temp GB/dev | collective schedule (module-once) |",
           "|---|---|---|---|---|---|---|"]
    for r in sorted(rows):
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def roofline_table(d):
    out = ["| arch | shape | kind | compute s | memory s | collective s | bound | step s | roofline frac | useful ratio |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    rows = []
    for f in sorted(glob.glob(f"{d}/*.json")):
        r = json.load(open(f))
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['bottleneck']} | "
            f"{r['step_s']:.4f} | {r['roofline_frac']:.3f} | {r['useful_ratio']:.2f} |"
        )
    return "\n".join(out + sorted(rows))


def perf_table(d):
    out = []
    for f in sorted(glob.glob(f"{d}/*.jsonl")):
        out.append(f"\n**{f.split('/')[-1].replace('.jsonl','').replace('__',' x ')}**\n")
        out.append("| variant | compute s | memory s | collective s | bound | step s | frac |")
        out.append("|---|---|---|---|---|---|---|")
        for line in open(f):
            r = json.loads(line)
            if not r.get("ok"):
                out.append(f"| {r.get('variant','?')} | FAIL: {r.get('error','')[:60]} | | | | | |")
                continue
            out.append(
                f"| {r['variant']} {r.get('overrides','')} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['bottleneck']} | "
                f"{r['step_s']:.4f} | {r['roofline_frac']:.3f} |"
            )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--roofline", default="results/roofline")
    ap.add_argument("--perf", default="results/perf")
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    if args.section in ("all", "dryrun"):
        print("## §Dry-run\n")
        print(dryrun_table(args.dryrun))
    if args.section in ("all", "roofline"):
        print("\n## §Roofline\n")
        print(roofline_table(args.roofline))
    if args.section in ("all", "perf"):
        print("\n## §Perf variants\n")
        print(perf_table(args.perf))


if __name__ == "__main__":
    main()
