"""Distributed simulator: sharded == unsharded, collectives present."""
import os
import subprocess
import sys

import pytest


def test_sharded_equivalence_in_process_tiny_mesh():
    """Non-subprocess sharded-equivalence check: the distributed entry point
    (device_put + NamedSharding + mesh context) must reproduce the plain
    engine exactly on whatever mesh this process has — including the
    availability path, whose calendar is replicated like ``sites``."""
    import jax
    import numpy as np

    from repro.core import (
        atlas_like_platform,
        get_policy,
        make_availability,
        simulate,
        synthetic_panda_jobs,
    )
    from repro.core.distributed import simulate_distributed

    jobs = synthetic_panda_jobs(64, seed=0, duration=600.0)
    sites = atlas_like_platform(4, seed=1)
    pol = get_policy("shortest_wait")
    av = make_availability(4, [dict(site=0, start=50.0, end=5000.0, preempt=True)])
    mesh = jax.make_mesh((jax.device_count(),), ("data",))

    for kw in ({}, {"availability": av}):
        r1 = simulate(jobs, sites, pol, jax.random.PRNGKey(0), max_rounds=20_000, **kw)
        r2 = simulate_distributed(
            jobs, sites, pol, jax.random.PRNGKey(0), mesh, max_rounds=20_000, **kw
        )
        assert float(r1.makespan) == float(r2.makespan)
        assert int(r1.rounds) == int(r2.rounds)
        J = jobs.capacity
        np.testing.assert_array_equal(
            np.asarray(r1.jobs.state), np.asarray(r2.jobs.state)[:J]
        )
        np.testing.assert_allclose(
            np.asarray(r1.jobs.t_start), np.asarray(r2.jobs.t_start)[:J], rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(r1.jobs.t_finish), np.asarray(r2.jobs.t_finish)[:J], rtol=1e-6
        )
        np.testing.assert_array_equal(
            np.asarray(r1.sites.n_finished), np.asarray(r2.sites.n_finished)
        )
    assert int(r2.avail.n_preempted.sum()) == int(r1.avail.n_preempted.sum())


def test_sharded_equivalence_in_process_with_workflow_dag():
    """A DAG workload through the distributed entry point reproduces the
    plain engine exactly: the parent matrix is replicated aux (and padded to
    the sharded job capacity), the gating gather shards with the jobs."""
    import jax
    import numpy as np

    from repro.core import (
        DONE,
        chain_workflows,
        get_data_policy,
        get_policy,
        scenario_replicas,
        simulate,
        uniform_network,
    )
    from repro.core import make_sites
    from repro.core.distributed import simulate_distributed

    # 30 rows: not a multiple of the mesh axis, so the workflow pads too
    scn = chain_workflows(10, 3, seed=0, arrival_span=200.0)
    sites = make_sites(
        cores=[16, 8, 8], speed=[10.0, 8.0, 12.0], memory=[256.0] * 3,
        bw_in=[1e9] * 3, bw_out=[1e9] * 3,
    )
    net = uniform_network(3, bw=2e8, latency=0.02)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    kw = dict(
        workflow=scn.workflow,
        data_policy=get_data_policy("cache_on_read"),
        network=net,
        replicas=scenario_replicas(scn, disk_capacity=np.full(3, 1e12)),
        max_rounds=20_000,
    )
    # workflow_locality closes over the *unpadded* parent matrix: it must
    # re-pad inside score when the distributed path grows the job capacity
    for pol in (
        get_policy("critical_path_first"),
        get_policy("workflow_locality", workflow=scn.workflow),
    ):
        r1 = simulate(scn.jobs, sites, pol, jax.random.PRNGKey(0), **kw)
        r2 = simulate_distributed(scn.jobs, sites, pol, jax.random.PRNGKey(0), mesh, **kw)
        J = scn.jobs.capacity
        assert float(r1.makespan) == float(r2.makespan)
        assert int(r1.rounds) == int(r2.rounds)
        np.testing.assert_array_equal(np.asarray(r1.jobs.state), np.asarray(r2.jobs.state)[:J])
        np.testing.assert_allclose(
            np.asarray(r1.jobs.t_start), np.asarray(r2.jobs.t_start)[:J], rtol=1e-6
        )
        assert (np.asarray(r2.jobs.state)[:J] == DONE).all()
        assert int(r1.wf.n_produced) == int(r2.wf.n_produced) == 30  # every stage materializes
        np.testing.assert_array_equal(
            np.asarray(r1.replicas.present), np.asarray(r2.replicas.present)
        )


SCRIPT = r"""
import jax, numpy as np
from jax.sharding import Mesh
from repro.core import synthetic_panda_jobs, atlas_like_platform, get_policy, simulate
from repro.core.distributed import (simulate_distributed, lower_distributed,
                                    simulate_ensemble_distributed)

assert len(jax.devices()) == 8, jax.devices()
jobs = synthetic_panda_jobs(256, seed=0, duration=1800.0)
sites = atlas_like_platform(6, seed=1)
pol = get_policy("shortest_wait")
mesh = jax.make_mesh((8,), ("data",))

r1 = simulate(jobs, sites, pol, jax.random.PRNGKey(0), max_rounds=20000)
r2 = simulate_distributed(jobs, sites, pol, jax.random.PRNGKey(0), mesh, max_rounds=20000)
assert abs(float(r1.makespan) - float(r2.makespan)) < 1e-3, (float(r1.makespan), float(r2.makespan))
assert np.allclose(np.asarray(r1.jobs.t_start), np.asarray(r2.jobs.t_start), rtol=1e-5)

lowered, compiled = lower_distributed(jobs, sites, pol, mesh, max_rounds=500)
txt = compiled.as_text()
assert txt.count("all-reduce") > 0, "expected SPMD all-reduces in the engine"

# ensemble: 8 candidate speed vectors across 8 devices
import jax.numpy as jnp
cands = sites.speed[None, :] * jnp.exp(0.2 * jax.random.normal(jax.random.PRNGKey(1), (8, sites.capacity)))
re = simulate_ensemble_distributed(jobs, sites, pol, jax.random.PRNGKey(2), cands, mesh, max_rounds=20000)
assert re.makespan.shape == (8,)
assert np.isfinite(np.asarray(re.makespan)).all()

# workflow DAG with job padding (15 rows over 8 devices -> 16) through a
# policy that closes over the unpadded parent matrix
from repro.core import DONE, chain_workflows, make_sites
scn = chain_workflows(5, 3, seed=0)
sites3 = make_sites(cores=[16]*3, speed=[10.0]*3, memory=[256.0]*3,
                    bw_in=[1e9]*3, bw_out=[1e9]*3)
wpol = get_policy("workflow_locality", workflow=scn.workflow)
rw1 = simulate(scn.jobs, sites3, wpol, jax.random.PRNGKey(0),
               workflow=scn.workflow, max_rounds=20000)
rw2 = simulate_distributed(scn.jobs, sites3, wpol, jax.random.PRNGKey(0), mesh,
                           workflow=scn.workflow, max_rounds=20000)
assert float(rw1.makespan) == float(rw2.makespan)
assert (np.asarray(rw2.jobs.state)[:15] == DONE).all()

# sharded scenario ensemble (ISSUE 5): 6 ragged lanes over 8 devices (lane
# padding path) must be bit-for-bit equal to the vmapped ensemble per lane
from repro.core import Scenario, simulate_many, stack_scenarios
from repro.core.distributed import simulate_many_sharded
scens = [Scenario(synthetic_panda_jobs(n, seed=20 + i, duration=600.0),
                  sites._replace(speed=sites.speed * (0.8 + 0.05 * i)))
         for i, n in enumerate([40, 52, 64, 48, 56, 44])]
rv = simulate_many(scens, pol, jax.random.PRNGKey(5))
rs = simulate_many_sharded(scens, pol, jax.random.PRNGKey(5), mesh)
for a, b in zip(jax.tree.leaves(rv), jax.tree.leaves(rs)):
    x, y = np.asarray(a), np.asarray(b)
    both_nan = (np.isnan(x) & np.isnan(y)) if np.issubdtype(x.dtype, np.floating) else False
    assert ((x == y) | both_nan).all()
# bucketed + sharded composes and stays exact
rb = simulate_many_sharded(stack_scenarios(scens, buckets=3), pol,
                           jax.random.PRNGKey(5), mesh)
assert float(np.abs(np.asarray(rb.makespan) - np.asarray(rv.makespan)).max()) == 0.0
print("DIST-OK")
"""


@pytest.mark.slow
def test_distributed_equivalence_subprocess():
    """Runs in a subprocess: the sharded engine needs >1 device, which must be
    configured before jax initializes (host-platform device count)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True, timeout=900
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "DIST-OK" in out.stdout
