"""Substrate tests: optimizer, checkpointing, data pipeline, compression,
microbatching, fault-tolerant driver."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_smoke
from repro.data import DataConfig, TokenPipeline, prefetch
from repro.ft import FailureInjector, train_with_restarts
from repro.models import build_model
from repro.train import (
    AdamWConfig,
    TrainState,
    adamw_update,
    compress_grads,
    init_error_state,
    init_opt_state,
    init_train_state,
    make_train_step,
    schedule,
)


def test_adamw_converges_quadratic():
    p = {"w": jnp.array([5.0, -3.0])}
    s = init_opt_state(p)
    cfg = AdamWConfig(lr=0.3, warmup_steps=1, total_steps=200, weight_decay=0.0)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(p)
        p, s, _ = adamw_update(cfg, p, g, s)
    assert float(jnp.abs(p["w"]).max()) < 0.05


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-2)


def test_grad_clip_applied():
    p = {"w": jnp.zeros(4)}
    s = init_opt_state(p)
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    _, _, metrics = adamw_update(cfg, p, {"w": jnp.full(4, 100.0)}, s)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


# ------------------------------------------------------------- checkpoint ---


def tree_eq(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(8, dtype=jnp.bfloat16), "b": {"c": jnp.ones((3, 2))}}
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3, 4):
            save(d, step, tree, keep_last=2)
        assert latest_step(d) == 4
        assert sorted(os.listdir(d)) == ["step_00000003", "step_00000004"]
        restored, step = restore(d, tree)
        assert step == 4
        assert tree_eq(tree, restored)
        assert restored["a"].dtype == jnp.bfloat16


def test_async_checkpointer_overlap():
    tree = {"w": jnp.ones((64, 64))}
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        ck.save(10, tree)
        ck.save(20, jax.tree.map(lambda x: x * 2, tree))  # waits for the first
        ck.wait()
        restored, step = restore(d, tree)
        assert step == 20
        assert float(restored["w"][0, 0]) == 2.0


def test_checkpoint_atomicity_no_tmp_left():
    tree = {"w": jnp.zeros(4)}
    with tempfile.TemporaryDirectory() as d:
        save(d, 7, tree)
        assert not any(f.endswith(".tmp") for f in os.listdir(d))


# ------------------------------------------------------------------ data ----


def test_pipeline_deterministic_and_host_sharded():
    base = dict(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    p0 = TokenPipeline(DataConfig(**base))
    p0b = TokenPipeline(DataConfig(**base))
    np.testing.assert_array_equal(p0.batch_at(5)["tokens"], p0b.batch_at(5)["tokens"])
    # host shards are disjoint slices of the same global batch distribution
    h0 = TokenPipeline(DataConfig(**base, n_hosts=2, host_id=0))
    h1 = TokenPipeline(DataConfig(**base, n_hosts=2, host_id=1))
    b0, b1 = h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"]
    assert b0.shape == (4, 64) and b1.shape == (4, 64)
    assert not np.array_equal(np.asarray(b0), np.asarray(b1))
    # tokens in range
    assert int(b0.max()) < 1000 and int(b0.min()) >= 0


def test_prefetch_preserves_order():
    p = TokenPipeline(DataConfig(vocab_size=100, seq_len=8, global_batch=2))
    it = prefetch(iter([p.batch_at(i) for i in range(5)]), depth=2)
    outs = [b["tokens"] for b in it]
    assert len(outs) == 5
    np.testing.assert_array_equal(outs[3], p.batch_at(3)["tokens"])


# -------------------------------------------------------------- compress ----


def test_compression_error_feedback_is_unbiased_over_time():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    err = init_error_state({"g": g_true})["g"] * 0
    err = {"g": err}
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, err = compress_grads({"g": g_true}, err)
        acc = acc + deq["g"]
    # error feedback: long-run average converges to the true gradient
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true), atol=2e-3)


def test_compressed_training_still_learns():
    cfg = get_smoke("deepseek-7b")
    m = build_model(cfg)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=48, global_batch=4))
    step = jax.jit(make_train_step(m, AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=20),
                                   compress=True))
    state = init_train_state(m, jax.random.PRNGKey(0), compress=True)
    losses = []
    for i in range(12):
        state, metrics = step(state, pipe.batch_at(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


# ---------------------------------------------------------- microbatching ---


def test_grad_accum_matches_single_batch():
    cfg = get_smoke("qwen2.5-32b").replace(dtype="float32")
    m = build_model(cfg)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))
    batch = pipe.batch_at(0)
    opt = AdamWConfig(lr=0.0, warmup_steps=0)  # lr 0: inspect metrics only
    s1 = init_train_state(m, jax.random.PRNGKey(0))
    s4 = TrainState(s1.params, s1.opt, s1.err)
    step1 = jax.jit(make_train_step(m, opt, microbatches=1))
    step4 = jax.jit(make_train_step(m, opt, microbatches=4))
    _, m1 = step1(s1, batch)
    _, m4 = step4(s4, batch)
    # same data => same mean loss and (approximately) same grad norm
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    assert float(m1["grad_norm"]) == pytest.approx(float(m4["grad_norm"]), rel=1e-3)


# -------------------------------------------------------------------- ft ----


def test_restart_resumes_deterministically():
    cfg = get_smoke("mamba2-130m")
    m = build_model(cfg)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
    opt = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=10)
    with tempfile.TemporaryDirectory() as d1:
        clean = train_with_restarts(m, pipe, total_steps=10, ckpt_dir=d1, ckpt_every=2,
                                    opt_cfg=opt)
    with tempfile.TemporaryDirectory() as d2:
        faulty = train_with_restarts(m, pipe, total_steps=10, ckpt_dir=d2, ckpt_every=2,
                                     opt_cfg=opt, injector=FailureInjector(at_steps=(5,)))
    assert faulty.restarts == 1
    # post-restart losses replay the same trajectory (pure-function pipeline)
    assert clean.losses[-1] == pytest.approx(faulty.losses[-1], rel=1e-5)
