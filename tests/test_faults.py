"""Fault injection & recovery subsystem (ISSUE 10, DESIGN.md §13).

Pins the subsystem's contract from four sides:

- exactness: a default-constructed (all-channels-off) ``FaultState`` is
  bit-for-bit identical to ``faults=None`` — alone and with the other four
  built-in subsystems attached;
- channel behavior: lossy links fail and re-enqueue FTS flows under the
  extended conservation ledger, exhausted stage-ins take the engine's retry
  path, resubmission backoff pushes arrivals, walltime kills bound DONE
  durations, the loss calendar drops only non-pinned replicas, and the
  blacklist circuit breaker trips / probes / recovers;
- the acceptance demo: adaptive blacklisting beats no-blacklisting on a
  ``flaky_grid`` when failures cost backoff time;
- composition: lane ≡ solo under ``simulate_many`` and sharded ≡ vmapped
  with all five subsystems attached, plus metrics/rows/ML-export schemas.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DONE,
    FAILED,
    BL_CLOSED,
    BL_HALF_OPEN,
    BL_TRIPPED,
    Scenario,
    catalog_invariants,
    compute_metrics,
    flaky_grid,
    get_data_policy,
    get_policy,
    load_faults,
    lossy_links,
    make_faults,
    make_replicas,
    make_transfers,
    replica_loss_calendar,
    simulate,
    simulate_many,
    summary_str,
    synthetic_panda_jobs,
    uniform_network,
    zipf_dataset_sizes,
)
from repro.core.events import fault_rows, log_frames, ml_dataset
from repro.core.faults import faults_subsystem
from repro.core.monitor import blacklist_timeline, fault_score_timeline
from repro.core.platform import atlas_like_platform

from test_ensemble_lanes import lane, tree_equal
from test_transfers import hot_link_scenario, quad_scenarios, run


def _terminated(res):
    valid = np.asarray(res.jobs.valid)
    state = np.asarray(res.jobs.state)[valid]
    return np.isin(state, [DONE, FAILED]).all()


# --------------------------------------------------------------------------
# exactness: zeroed config ≡ faults off, bit for bit
# --------------------------------------------------------------------------


def test_default_state_is_bitstream_inert():
    jobs = synthetic_panda_jobs(80, seed=3)
    sites = atlas_like_platform(4, seed=12, fail_rate=0.1)
    pol = get_policy("panda_dispatch")
    key = jax.random.PRNGKey(0)
    off = simulate(jobs, sites, pol, key)
    on = simulate(jobs, sites, pol, key, faults=make_faults(4, jobs))
    assert tree_equal(off.jobs, on.jobs) == []
    assert tree_equal(off.sites, on.sites) == []
    assert float(off.makespan) == float(on.makespan)
    assert int(off.rounds) == int(on.rounds)
    # the inert run really did carry the subsystem (and injected nothing —
    # time_lost still observes the engine's own fail_rate failures)
    fs = on.ext["faults"]
    for c in ("n_xfer_fail", "n_kills", "n_lost_replicas", "n_bl_trips"):
        assert int(getattr(fs, c)) == 0
    assert float(fs.time_lost) > 0.0


def test_default_state_inert_with_all_subsystems():
    """Five-subsystem stack: a zeroed faults state changes nothing about an
    availability+workflow+data+transfers run."""
    scens, _, solo_kw = quad_scenarios(K=1)
    s = scens[0]
    pol = get_policy("panda_dispatch")
    key = jax.random.PRNGKey(5)
    off = simulate(s.jobs, s.sites, pol, key, **solo_kw[0])
    on = simulate(s.jobs, s.sites, pol, key,
                  faults=make_faults(3, s.jobs), **solo_kw[0])
    assert tree_equal(off.jobs, on.jobs) == []
    assert tree_equal(off.sites, on.sites) == []
    assert tree_equal(off.replicas, on.replicas) == []
    assert tree_equal(off.ext["transfers"], on.ext["transfers"]) == []
    assert float(off.makespan) == float(on.makespan)
    assert int(off.rounds) == int(on.rounds)


# --------------------------------------------------------------------------
# channel 1: transfer-failure injection + exponential backoff
# --------------------------------------------------------------------------


def test_lossy_links_extend_transfer_ledger():
    jobs, sites, net, rep = hot_link_scenario(n_jobs=16, n_sites=3, cores_per_site=32)
    fl = make_faults(3, jobs, link_fail_p=lossy_links(3, p=0.4, seed=1),
                     xfer_backoff=30.0, max_xfer_attempts=4)
    res = run(jobs, sites, net, rep,
              transfers=make_transfers(3, jobs.capacity, max_active=2), faults=fl)
    fs, ts = res.ext["faults"], res.ext["transfers"]
    assert int(fs.n_xfer_fail) > 0
    assert int(fs.n_xfer_retry) > 0
    # conservation: every enqueue completes, cancels, or was failed by us
    assert int(ts.n_enq) == int(ts.n_done) + int(ts.n_cancel) + int(fs.n_xfer_fail)
    # queues drained, no retry left pending, workload finished
    assert (np.asarray(ts.stat) == 0).all()
    assert (np.asarray(ts.active) == 0).all()
    assert not np.isfinite(np.asarray(fs.retry_at)).any()
    assert _terminated(res)
    # injected failures delayed staging: jobs waited out backoff windows
    assert float(np.asarray(fs.backoff_wait).sum()) > 0.0


def test_exhausted_transfers_fail_the_job_attempt():
    """p=1 links: every stage-in burns through max_xfer_attempts and fails
    the attempt; engine retries re-stage until the job goes terminal."""
    jobs, sites, net, rep = hot_link_scenario(n_jobs=6, n_sites=2, cores_per_site=16)
    fl = make_faults(2, jobs, link_fail_p=1.0, xfer_backoff=5.0, max_xfer_attempts=2)
    res = run(jobs, sites, net, rep,
              transfers=make_transfers(2, jobs.capacity, max_active=2), faults=fl)
    fs, ts = res.ext["faults"], res.ext["transfers"]
    valid = np.asarray(res.jobs.valid)
    assert (np.asarray(res.jobs.state)[valid] == FAILED).all()
    assert int(fs.n_xfer_exhaust) > 0
    assert int(ts.n_done) == 0
    assert int(ts.n_enq) == int(ts.n_cancel) + int(fs.n_xfer_fail)
    # each engine attempt consumed exactly max_xfer_attempts transfer failures
    retries = np.asarray(res.jobs.retries)[valid]
    assert int(fs.n_xfer_fail) == 2 * (int(retries.sum()) + int(valid.sum()))


# --------------------------------------------------------------------------
# channel 2: resubmission backoff
# --------------------------------------------------------------------------


def test_job_backoff_pushes_resubmission_arrivals():
    jobs = synthetic_panda_jobs(60, seed=3)
    sites = atlas_like_platform(4, seed=12, fail_rate=0.25)
    pol = get_policy("least_loaded")
    key = jax.random.PRNGKey(0)
    fl = make_faults(4, jobs, job_backoff=120.0)
    res = simulate(jobs, sites, pol, key, faults=fl)
    fs = res.ext["faults"]
    valid = np.asarray(res.jobs.valid)
    retried = (np.asarray(res.jobs.retries) > 0) & valid
    assert retried.any()
    assert _terminated(res)
    # scheduled delays accumulated, and each retried job's arrival moved
    assert float(np.asarray(fs.backoff_wait).sum()) > 0.0
    arr0 = np.asarray(jobs.arrival)
    arr1 = np.asarray(res.jobs.arrival)
    assert (arr1[retried] > arr0[retried]).all()
    assert (arr1[~retried & valid] == arr0[~retried & valid]).all()
    # jobs still start only after their (pushed) arrival
    s = np.asarray(res.jobs.t_start)[valid]
    assert (arr1[valid] <= s + 1e-5).all()


# --------------------------------------------------------------------------
# walltime kills
# --------------------------------------------------------------------------


def test_walltime_kills_bound_done_durations():
    jobs = synthetic_panda_jobs(60, seed=3)
    sites = atlas_like_platform(4, seed=12)
    pol = get_policy("least_loaded")
    fl = make_faults(4, jobs, walltime=600.0)
    res = simulate(jobs, sites, pol, jax.random.PRNGKey(0), faults=fl)
    fs = res.ext["faults"]
    assert int(fs.n_kills) > 0
    assert float(fs.time_lost) > 0.0
    assert _terminated(res)
    valid = np.asarray(res.jobs.valid)
    # kills are preemptions (not machine failures): the per-job counter
    # accounts for every one, and resources came back (free cores == cores)
    assert int(np.asarray(res.jobs.preempted)[valid].sum()) == int(fs.n_kills)
    np.testing.assert_array_equal(
        np.asarray(res.sites.free_cores), np.asarray(sites.cores)
    )
    # no DONE attempt exceeded the limit
    done = (np.asarray(res.jobs.state) == DONE) & valid
    dur = (np.asarray(res.jobs.t_finish) - np.asarray(res.jobs.t_start))[done]
    assert (dur <= 600.0 * (1 + 1e-5)).all()


# --------------------------------------------------------------------------
# channel 3: replica-loss calendar
# --------------------------------------------------------------------------


def test_replica_loss_drops_only_unpinned_copies():
    jobs = synthetic_panda_jobs(120, seed=3, n_datasets=8)
    sites = atlas_like_platform(4, seed=12)
    net = uniform_network(4, bw=1e6, latency=0.05)  # slow WAN: caches matter
    sizes = zipf_dataset_sizes(8, seed=3, mean_bytes=2e9)
    rep = make_replicas(sizes, disk_capacity=np.full(4, 1e13),
                        origin=np.zeros(8, np.int32))
    events = [(5000.0, d, s) for d in range(8) for s in (1, 2, 3)]
    fl = make_faults(4, jobs, replica_loss=events)
    res = simulate(
        jobs, sites, get_policy("least_loaded"), jax.random.PRNGKey(0),
        data_policy=get_data_policy("cache_on_read"), network=net, replicas=rep,
        faults=fl,
    )
    fs = res.ext["faults"]
    assert int(fs.n_lost_replicas) > 0
    # every finite calendar entry fired
    lt = np.asarray(fs.loss_t)
    assert np.asarray(fs.loss_done)[np.isfinite(lt)].all()
    # catalog stays exact and origins stay pinned
    inv = catalog_invariants(res.replicas)
    assert inv["capacity_ok"] and inv["accounting_ok"] and inv["origins_ok"]
    present = np.asarray(res.replicas.present)
    origin = np.asarray(res.replicas.origin)
    assert present[np.arange(8), origin].all()
    assert _terminated(res)


def test_replica_loss_calendar_builder():
    cal = replica_loss_calendar(8, 4, horizon=1e5, rate=1e-4, seed=2)
    assert cal and cal == sorted(cal)
    assert all(0 <= d < 8 and 0 <= s < 4 and 0 <= t < 1e5 for t, d, s in cal)
    # accepts a ReplicaState for the dataset axis
    rep = make_replicas(zipf_dataset_sizes(8, seed=3), np.full(4, 1e13),
                        origin=np.zeros(8, np.int32))
    cal2 = replica_loss_calendar(rep, 4, horizon=1e5, rate=1e-4, seed=2)
    assert cal2 == cal
    # the calendar feeds make_faults directly
    make_faults(4, 16, replica_loss=cal)


# --------------------------------------------------------------------------
# channel 4: adaptive blacklisting (circuit breaker)
# --------------------------------------------------------------------------


def _flaky_run(blacklist, *, n_jobs=120, n_sites=4, seed=7, log_rows=0,
               job_backoff=0.0, cooldown=600.0):
    # homogeneous small sites + trickle arrivals: least_loaded is attracted
    # to the flaky site because failing fast looks like draining fast (the
    # classic blackhole-site dynamic blacklisting exists to break)
    sites, flaky_idx = flaky_grid(n_sites, n_flaky=1, seed=12,
                                  cores_range=(8, 8), speed_range=(10.0, 10.0))
    rng = np.random.default_rng(seed)
    jobs = synthetic_panda_jobs(n_jobs, seed=seed, capacity=n_jobs + 3)
    jobs = jobs._replace(
        arrival=jnp.asarray(
            np.pad(np.sort(rng.uniform(0, 400.0, n_jobs)), (0, 3),
                   constant_values=np.inf), jnp.float32),
        work=jnp.asarray(
            np.pad(rng.lognormal(np.log(800.0), 0.6, n_jobs), (0, 3)),
            jnp.float32),
        cores=jnp.ones((jobs.capacity,), jnp.int32),
        memory=jnp.full((jobs.capacity,), 2.0),
    )
    kw = dict(job_backoff=job_backoff)
    if blacklist:
        kw.update(blacklist_threshold=0.6, blacklist_alpha=0.5,
                  blacklist_cooldown=cooldown)
    fl = make_faults(n_sites, jobs, **kw)
    res = simulate(jobs, sites, get_policy("least_loaded"),
                   jax.random.PRNGKey(1), max_retries=6, faults=fl,
                   log_rows=log_rows)
    return res, flaky_idx


def test_blacklist_trips_and_probes():
    # cooldown well under the run length so half-open probes fire mid-run
    res, flaky_idx = _flaky_run(True, log_rows=8192, cooldown=150.0)
    fs = res.ext["faults"]
    assert int(fs.n_bl_trips) >= 1
    assert int(fs.n_probes) >= 1
    assert _terminated(res)
    # the breaker tripped on the flaky site, and its score actually climbed
    bl = blacklist_timeline(res)
    score = fault_score_timeline(res)
    s = int(flaky_idx[0])
    assert (bl[:, s] == BL_TRIPPED).any()
    assert score[:, s].max() >= 0.6
    # healthy sites never trip
    healthy = [i for i in range(bl.shape[1]) if i != s]
    assert (bl[:, healthy] == BL_CLOSED).all()

    # zero starts while tripped: across consecutive logged rounds that both
    # end TRIPPED, the site's running count can only drain (the log ring did
    # not wrap, so this covers the whole run)
    assert int(np.asarray(res.log.cursor)) <= 8192
    frames = log_frames(res)
    running = np.asarray([f["site_running"] for f in frames])
    both = (bl[:-1, s] == BL_TRIPPED) & (bl[1:, s] == BL_TRIPPED)
    assert both.any()
    assert (running[1:, s][both] <= running[:-1, s][both]).all()


def test_blacklist_probe_resolution_leaves_legal_state():
    """The breaker re-opens mid-run and admits probes; the flaky site's
    probes mostly fail (fail_rate 0.9) and re-trip it, but the run
    terminates with every breaker accounted for in a legal state."""
    res, flaky_idx = _flaky_run(True, cooldown=150.0)
    fs = res.ext["faults"]
    assert int(fs.n_probes) >= 1
    bl_end = np.asarray(fs.bl_state)
    assert np.isin(bl_end, [BL_CLOSED, BL_TRIPPED, BL_HALF_OPEN]).all()
    # a closed breaker carries no cooldown timer; a tripped one always does
    until = np.asarray(fs.bl_until)
    assert not np.isfinite(until[bl_end == BL_CLOSED]).any()
    assert np.isfinite(until[bl_end == BL_TRIPPED]).all()


def test_blacklisting_improves_flaky_grid_makespan():
    """The acceptance demo: when failures cost real time (resubmission
    backoff), routing around the flaky site wins the makespan."""
    off, _ = _flaky_run(False, job_backoff=120.0)
    on, flaky_idx = _flaky_run(True, job_backoff=120.0)
    assert _terminated(off) and _terminated(on)
    assert float(on.makespan) < float(off.makespan)
    # and it won by sending less work into the woodchipper
    s = int(flaky_idx[0])
    assert int(on.sites.n_failed[s]) < int(off.sites.n_failed[s])


# --------------------------------------------------------------------------
# ensembles: five-subsystem lane ≡ solo, sharded ≡ vmapped
# --------------------------------------------------------------------------


def quint_scenarios(K=3):
    """quad_scenarios plus a per-lane faults state — all five built-ins."""
    scens, subs, solo_kw = quad_scenarios(K=K)
    subs = subs + (faults_subsystem(job_backoff=True, blacklist=True),)
    out = []
    for k, s in enumerate(scens):
        fl = make_faults(
            3, s.jobs, link_fail_p=0.15 + 0.1 * k, xfer_backoff=20.0,
            job_backoff=30.0, walltime=5000.0 + 500.0 * k,
            replica_loss=[(400.0 * (k + 1), 1 + k, (k + 1) % 3)],
            blacklist_threshold=0.7, blacklist_alpha=0.4,
            blacklist_cooldown=400.0,
        )
        out.append(Scenario(s.jobs, s.sites, {**s.ext, "faults": fl}))
        solo_kw[k]["faults"] = fl
    return out, subs, solo_kw


def test_five_subsystem_lanes_equal_solo():
    scens, subs, solo_kw = quint_scenarios()
    pol = get_policy("least_loaded")
    keys = jax.random.split(jax.random.PRNGKey(4), len(scens))
    res = simulate_many(scens, pol, jax.random.PRNGKey(4), subsystems=subs)
    for i, s in enumerate(scens):
        solo = simulate(s.jobs, s.sites, pol, keys[i], **solo_kw[i])
        assert tree_equal(lane(res, i), solo) == []
    # the lanes actually exercised the fault channels
    assert int(np.asarray(res.ext["faults"].n_xfer_fail).sum()) > 0


def test_five_subsystem_sharded_equals_vmapped():
    from repro.core.distributed import simulate_many_sharded

    scens, subs, _ = quint_scenarios()
    pol = get_policy("least_loaded")
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    r_v = simulate_many(scens, pol, jax.random.PRNGKey(4), subsystems=subs)
    r_s = simulate_many_sharded(scens, pol, jax.random.PRNGKey(4), mesh,
                                subsystems=subs)
    assert tree_equal(r_s, r_v) == []


# --------------------------------------------------------------------------
# metrics / events / export schema / JSON loader
# --------------------------------------------------------------------------


def test_metrics_rows_and_ml_features():
    sites, _ = flaky_grid(4, n_flaky=1, seed=12)
    jobs = synthetic_panda_jobs(100, seed=3)
    fl = make_faults(4, jobs, job_backoff=90.0, walltime=2500.0,
                     blacklist_threshold=0.6, blacklist_alpha=0.5,
                     blacklist_cooldown=500.0)
    pol = get_policy("least_loaded")
    r_on = simulate(jobs, sites, pol, jax.random.PRNGKey(0), faults=fl)
    r_off = simulate(jobs, sites, pol, jax.random.PRNGKey(0))

    m_on, m_off = compute_metrics(r_on), compute_metrics(r_off)
    assert float(m_on.time_lost_failures) > 0.0
    assert float(m_on.p99_backoff_wait) > 0.0
    assert float(m_on.p50_retries) <= float(m_on.p95_retries) <= float(m_on.p99_retries)
    # defined (0) when the subsystem is off; retry tails exist regardless
    assert float(m_off.time_lost_failures) == 0.0
    assert float(m_off.p99_backoff_wait) == 0.0
    assert float(m_off.p99_retries) >= 0.0
    assert "time_lost=" in summary_str(m_on)

    rows_on, rows_off = fault_rows(r_on), fault_rows(r_off)
    assert rows_off == []
    assert len(rows_on) == 4
    assert {"site", "fault_score", "blacklist", "n_kills", "time_lost"} <= set(rows_on[0])
    assert {r["blacklist"] for r in rows_on} <= {"closed", "tripped", "half-open"}

    ds_on, ds_off = ml_dataset(r_on), ml_dataset(r_off)
    base = list(ds_off["feature_names"])
    assert "fault_backoff_wait" not in base
    assert list(ds_on["feature_names"]) == base + [
        "fault_backoff_wait", "fault_retries", "site_fault_score"
    ]
    assert ds_on["features"].shape[1] == len(ds_on["feature_names"])
    assert ds_on["features"][:, len(base)].max() > 0.0  # backoff waits recorded


def test_load_faults_json():
    names = ["CERN", "BNL", "FZK"]
    spec = {
        "link_fail_p": {"default": 0.01,
                        "links": [{"src": "CERN", "dst": "BNL", "p": 0.5},
                                  {"src": 2, "dst": 0, "p": 0.25}]},
        "xfer_backoff": 45.0,
        "max_xfer_attempts": 5,
        "job_backoff": 30.0,
        "walltime": 7200.0,
        "replica_loss": [{"t": 100.0, "dataset": 2, "site": "FZK"}],
        "blacklist": {"threshold": 0.5, "alpha": 0.3, "cooldown": 900.0},
    }
    fl = load_faults(spec, names, job_capacity=16)
    p = np.asarray(fl.link_fail_p).reshape(3, 3)
    assert p[0, 1] == np.float32(0.5) and p[2, 0] == np.float32(0.25)
    assert p[1, 2] == np.float32(0.01)
    assert float(fl.xfer_backoff) == 45.0
    assert int(fl.max_xfer_attempts) == 5
    assert float(fl.job_backoff) == 30.0
    assert (np.asarray(fl.walltime) == 7200.0).all()
    assert float(fl.loss_t[0]) == 100.0 and int(fl.loss_s[0]) == 2
    assert float(fl.bl_threshold) == 0.5
    with pytest.raises(ValueError, match="job_capacity"):
        load_faults(spec, names)
    with pytest.raises(ValueError, match="unknown site"):
        load_faults({"replica_loss": [{"t": 1.0, "dataset": 0, "site": "nope"}]},
                    names, job_capacity=4)


def test_validation_errors():
    with pytest.raises(ValueError, match="link_fail_p"):
        make_faults(3, 8, link_fail_p=np.zeros((2, 2)))
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        make_faults(3, 8, link_fail_p=1.5)
    with pytest.raises(ValueError, match="out of range"):
        make_faults(3, 8, replica_loss=[(1.0, 0, 7)])
    jobs = synthetic_panda_jobs(10, seed=0)
    sites = atlas_like_platform(3, seed=0)
    wrong = make_faults(3, jobs.capacity + 5)
    with pytest.raises(ValueError, match="sized for"):
        simulate(jobs, sites, get_policy("least_loaded"), jax.random.PRNGKey(0),
                 faults=wrong)
