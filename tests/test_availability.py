"""Availability dynamics (DESIGN.md §5): downtime, preemption, degradation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DONE,
    FAILED,
    QUEUED,
    atlas_like_platform,
    availability_factor,
    downtime_fraction,
    flaky_sites,
    get_policy,
    load_availability,
    load_platform,
    maintenance_calendar,
    make_availability,
    make_jobs,
    make_sites,
    next_window_edge,
    rolling_brownout,
    sample_correlated_outages,
    simulate,
    simulate_ensemble,
    synthetic_panda_jobs,
)
from repro.core.events import availability_rows, log_frames, ml_dataset
from repro.core.monitor import availability_timeline, render_frame


def mini_jobs(n=8, cores=1, arrival=None, work=100.0):
    return make_jobs(
        job_id=np.arange(n),
        arrival=arrival if arrival is not None else np.zeros(n),
        work=np.full(n, work),
        cores=np.full(n, cores),
        memory=np.full(n, 1.0),
        bytes_in=np.zeros(n),
        bytes_out=np.zeros(n),
    )


def one_site(cores=4, speed=10.0):
    return make_sites(cores=[cores], speed=[speed], memory=[64.0], bw_in=[1e12], bw_out=[1e12])


def run(jobs, sites, av=None, policy="fastest_site", **kw):
    return simulate(jobs, sites, get_policy(policy), jax.random.PRNGKey(0), availability=av, **kw)


# --------------------------------------------------------------------------
# state & pure helpers
# --------------------------------------------------------------------------


def test_make_availability_shapes_and_validation():
    av = make_availability(3, [dict(site=1, start=10.0, end=20.0, factor=0.5)])
    assert av.win_start.shape == (3, 1)
    assert float(av.win_start[1, 0]) == 10.0
    assert not np.isfinite(np.asarray(av.win_start)[[0, 2]]).any()
    with pytest.raises(ValueError):
        make_availability(2, [dict(site=5, start=0.0, end=1.0)])
    with pytest.raises(ValueError):
        make_availability(2, [dict(site=0, start=5.0, end=5.0)])
    with pytest.raises(ValueError):
        make_availability(2, [dict(site=0, start=0.0, end=1.0, factor=2.0)])
    with pytest.raises(ValueError):
        make_availability(2, [(0, 0.0, 1.0), (0, 2.0, 3.0)], max_windows=1)


def test_availability_factor_half_open_and_overlap():
    av = make_availability(
        2,
        [
            dict(site=0, start=10.0, end=20.0, factor=0.0),
            dict(site=0, start=15.0, end=30.0, factor=0.5),
        ],
    )
    f = lambda t: np.asarray(availability_factor(av, jnp.float32(t)))
    np.testing.assert_allclose(f(5.0), [1.0, 1.0])
    np.testing.assert_allclose(f(10.0), [0.0, 1.0])   # start inclusive
    np.testing.assert_allclose(f(17.0), [0.0, 1.0])   # overlap: most severe wins
    np.testing.assert_allclose(f(20.0), [0.5, 1.0])   # end exclusive
    np.testing.assert_allclose(f(30.0), [1.0, 1.0])


def test_next_window_edge_is_strictly_ahead():
    av = make_availability(2, [(0, 10.0, 20.0), (1, 15.0, 25.0)])
    edge = lambda t: float(next_window_edge(av, jnp.float32(t)))
    assert edge(0.0) == 10.0
    assert edge(10.0) == 15.0  # the edge at t itself no longer counts
    assert edge(20.0) == 25.0
    assert edge(25.0) == float("inf")


def test_downtime_fraction_clips_to_horizon():
    av = make_availability(
        2,
        [
            dict(site=0, start=50.0, end=150.0),               # half inside [0, 100]
            dict(site=1, start=0.0, end=40.0, factor=0.5),     # brown-out: not downtime
        ],
    )
    np.testing.assert_allclose(downtime_fraction(av, 100.0), [0.5, 0.0])


def test_downtime_fraction_merges_overlapping_windows():
    # correlated incidents can overlap on one site: [100, 500) u [300, 700)
    # covers 600s, not 800s
    av = make_availability(
        1, [dict(site=0, start=100.0, end=500.0), dict(site=0, start=300.0, end=700.0)]
    )
    np.testing.assert_allclose(downtime_fraction(av, 1000.0), [0.6])


# --------------------------------------------------------------------------
# engine semantics
# --------------------------------------------------------------------------


def test_no_availability_vs_empty_calendar_bit_for_bit():
    """The §5 no-op guarantee: an empty calendar reproduces the plain engine
    exactly — same arrays, same clock, same round count."""
    jobs = synthetic_panda_jobs(120, seed=0, duration=900.0)
    sites = atlas_like_platform(4, seed=1, fail_rate=0.05)
    r0 = simulate(jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0), log_rows=64)
    r1 = simulate(
        jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0), log_rows=64,
        availability=make_availability(4),
    )
    for k in r0.jobs._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(r0.jobs, k)), np.asarray(getattr(r1.jobs, k)), err_msg=f"jobs.{k}"
        )
    for k in r0.sites._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(r0.sites, k)), np.asarray(getattr(r1.sites, k)), err_msg=f"sites.{k}"
        )
    for k in r0.log._fields:
        if k == "extra":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(r0.log, k)), np.asarray(getattr(r1.log, k)), err_msg=f"log.{k}"
        )
    # subsystem-declared log columns exist only when the subsystem is attached
    # (DESIGN.md §7); an empty calendar's factor column is identically 1
    assert "site_avail" not in r0.log.extra
    np.testing.assert_array_equal(np.asarray(r1.log.extra["site_avail"]), 1.0)
    assert float(r0.makespan) == float(r1.makespan)
    assert int(r0.rounds) == int(r1.rounds)
    assert r0.avail is None and r1.avail is not None


def test_outage_blocks_starts_until_window_end():
    # 8 jobs / 4 cores: wave 2 would start at t=10 but the site is down
    # [5, 50) -> it starts exactly at the window end
    av = make_availability(1, [dict(site=0, start=5.0, end=50.0)])
    res = run(mini_jobs(8), one_site(), av)
    starts = np.sort(np.asarray(res.jobs.t_start)[:8])
    np.testing.assert_allclose(starts[:4], 0.0, atol=1e-5)
    np.testing.assert_allclose(starts[4:], 50.0, atol=1e-4)
    assert float(res.makespan) == pytest.approx(60.0, abs=1e-3)
    assert int(res.avail.n_preempted[0]) == 0  # drain: nobody was killed


def test_drain_lets_running_jobs_finish_inside_window():
    av = make_availability(1, [dict(site=0, start=5.0, end=50.0, preempt=False)])
    res = run(mini_jobs(4), one_site(), av)
    # all 4 started at 0, finish at 10 *inside* the outage window
    np.testing.assert_allclose(np.asarray(res.jobs.t_finish)[:4], 10.0, atol=1e-4)
    assert (np.asarray(res.jobs.preempted)[:4] == 0).all()


def test_preemption_requeues_with_retry_and_loses_progress():
    av = make_availability(1, [dict(site=0, start=5.0, end=50.0, preempt=True)])
    res = run(mini_jobs(8), one_site(), av)
    jobs = res.jobs
    state = np.asarray(jobs.state)[:8]
    assert (state == DONE).all()
    # the 4 running jobs were killed at t=5, requeued, and rerun from scratch
    assert (np.asarray(jobs.retries)[:8] == [1, 1, 1, 1, 0, 0, 0, 0]).all()
    assert (np.asarray(jobs.preempted)[:8] == [1, 1, 1, 1, 0, 0, 0, 0]).all()
    assert int(res.avail.n_preempted[0]) == 4
    starts = np.sort(np.asarray(jobs.t_start)[:8])
    np.testing.assert_allclose(starts[:4], 50.0, atol=1e-4)  # first restart wave
    assert float(res.makespan) == pytest.approx(70.0, abs=1e-3)


def test_preemption_exhausts_retries_to_failed():
    av = make_availability(1, [dict(site=0, start=5.0, end=50.0, preempt=True)])
    res = run(mini_jobs(4), one_site(), av, max_retries=0)
    jobs = res.jobs
    assert (np.asarray(jobs.state)[:4] == FAILED).all()
    np.testing.assert_allclose(np.asarray(jobs.t_finish)[:4], 5.0, atol=1e-5)
    assert int(res.avail.n_preempted[0]) == 4
    # terminal preemptions are not machine failures: n_failed stays clean
    assert int(res.sites.n_failed[0]) == 0


def test_job_finishing_exactly_at_window_start_is_not_preempted():
    # work 50 @ speed 10 -> t_finish = 5.0 == window start: completions run
    # before preemption in the round, so the job finishes
    av = make_availability(1, [dict(site=0, start=5.0, end=50.0, preempt=True)])
    res = run(mini_jobs(1, work=50.0), one_site(), av)
    assert int(res.jobs.state[0]) == DONE
    assert float(res.jobs.t_finish[0]) == pytest.approx(5.0, abs=1e-5)
    assert int(res.jobs.preempted[0]) == 0


def test_preempted_jobs_reroute_to_surviving_site():
    sites = make_sites(
        cores=[4, 4], speed=[10.0, 5.0], memory=[64.0, 64.0],
        bw_in=[1e12, 1e12], bw_out=[1e12, 1e12],
    )
    # fastest_site puts everything on site 0; an open-ended preempting outage
    # forces the retry onto the slow site 1
    av = make_availability(2, [dict(site=0, start=5.0, end=1e9, preempt=True)])
    res = run(mini_jobs(2), sites, av)
    jobs = res.jobs
    assert (np.asarray(jobs.state)[:2] == DONE).all()
    assert (np.asarray(jobs.site)[:2] == 1).all()
    np.testing.assert_allclose(np.asarray(jobs.t_start)[:2], 5.0, atol=1e-4)
    assert float(res.makespan) == pytest.approx(5.0 + 100.0 / 5.0, abs=1e-3)


def test_assigned_jobs_bounce_off_preempted_site():
    # job 1 sits ASSIGNED behind job 0 on the fast 1-core site when the
    # preempting outage hits: both must re-route to the slow site instead of
    # job 1 stranding in the dead site's queue for the whole window
    sites = make_sites(
        cores=[1, 1], speed=[10.0, 5.0], memory=[64.0, 64.0],
        bw_in=[1e12, 1e12], bw_out=[1e12, 1e12],
    )
    av = make_availability(2, [dict(site=0, start=5.0, end=1000.0, preempt=True)])
    res = run(mini_jobs(2), sites, av)
    jobs = res.jobs
    assert (np.asarray(jobs.state)[:2] == DONE).all()
    assert (np.asarray(jobs.site)[:2] == 1).all()
    np.testing.assert_allclose(np.sort(np.asarray(jobs.t_start)[:2]), [5.0, 25.0], atol=1e-4)
    # only the running job burned an attempt; the queued one just moved
    assert np.asarray(jobs.preempted)[:2].tolist() == [1, 0]
    assert np.asarray(jobs.retries)[:2].tolist() == [1, 0]
    assert float(res.makespan) == pytest.approx(45.0, abs=1e-3)


def test_down_site_is_infeasible_until_window_ends():
    # the only site is down [0, 100): the arriving job waits at the server and
    # the window end is the *only* event that wakes the engine
    av = make_availability(1, [dict(site=0, start=0.0, end=100.0)])
    res = run(mini_jobs(1), one_site(), av)
    assert int(res.jobs.state[0]) == DONE
    assert float(res.jobs.t_start[0]) == pytest.approx(100.0, abs=1e-4)
    assert int(res.rounds) <= 6


def test_permanent_outage_halts_cleanly():
    av = make_availability(1, [dict(site=0, start=0.0, end=float("inf"))])
    res = run(mini_jobs(1), one_site(), av, max_rounds=50)
    assert int(res.jobs.state[0]) == QUEUED  # stuck, but no spin
    assert int(res.rounds) < 10


def test_brownout_scales_speed_and_caps_cores():
    av = make_availability(1, [dict(site=0, start=0.0, end=1000.0, factor=0.5)])
    res = run(mini_jobs(4), one_site(), av)
    # cap floor(4 * 0.5) = 2 usable cores; speed halved -> 20s per wave
    starts = np.sort(np.asarray(res.jobs.t_start)[:4])
    np.testing.assert_allclose(starts, [0.0, 0.0, 20.0, 20.0], atol=1e-4)
    wall = np.asarray(res.jobs.t_finish - res.jobs.t_start)[:4]
    np.testing.assert_allclose(wall, 20.0, atol=1e-3)
    assert float(res.makespan) == pytest.approx(40.0, abs=1e-3)


def test_brownout_flooring_cores_to_zero_routes_like_outage():
    # factor 0.1 on a 4-core site floors usable cores to 0: a de facto
    # outage, so the dispatcher must route to the slower-but-up site instead
    # of queueing jobs behind a site that cannot start anything
    sites = make_sites(
        cores=[4, 4], speed=[10.0, 5.0], memory=[64.0, 64.0],
        bw_in=[1e12, 1e12], bw_out=[1e12, 1e12],
    )
    av = make_availability(2, [dict(site=0, start=0.0, end=10000.0, factor=0.1)])
    res = run(mini_jobs(4), sites, av)
    assert (np.asarray(res.jobs.site)[:4] == 1).all()
    assert float(res.makespan) == pytest.approx(100.0 / 5.0, abs=1e-3)


def test_quantum_does_not_skip_short_preempting_windows():
    # jobs start at the first quantum tick (t=300) and run 2000s; the window
    # [500, 700) is shorter than the quantum, so the next round's clock (800)
    # steps clean over it — the jobs running through it must still lose the
    # attempt (interval-overlap preemption), not sail on untouched
    av = make_availability(1, [dict(site=0, start=500.0, end=700.0, preempt=True)])
    jobs = mini_jobs(4, work=20000.0)
    res = run(jobs, one_site(), av, quantum=300.0)
    assert int(res.avail.n_preempted[0]) == 4
    assert (np.asarray(res.jobs.retries)[:4] == 1).all()
    assert (np.asarray(res.jobs.state)[:4] == DONE).all()


def test_quantum_preempts_job_finishing_inside_skipped_window():
    # job starts at the first quantum tick (300) with a 250s service time, so
    # t_finish=550 falls inside the preempting window [500, 700) that the
    # next round (clock 800) steps over: the outage killed it at 500, so it
    # must be preempted and rerun, not retired DONE at 550
    av = make_availability(1, [dict(site=0, start=500.0, end=700.0, preempt=True)])
    res = run(mini_jobs(1, work=2500.0), one_site(cores=1), av, quantum=300.0)
    assert int(res.jobs.preempted[0]) == 1
    assert int(res.jobs.retries[0]) == 1
    assert int(res.jobs.state[0]) == DONE
    assert float(res.jobs.t_start[0]) >= 700.0  # rerun after the window
    # and a finish safely before the window is untouched by the kill mask
    res2 = run(mini_jobs(1, work=1500.0), one_site(cores=1), av, quantum=300.0)
    assert int(res2.jobs.preempted[0]) == 0
    assert float(res2.jobs.t_finish[0]) == pytest.approx(450.0, abs=1e-4)


def test_brownout_ends_restore_full_speed_for_new_starts():
    av = make_availability(1, [dict(site=0, start=0.0, end=15.0, factor=0.5)])
    res = run(mini_jobs(4), one_site(), av)
    starts = np.sort(np.asarray(res.jobs.t_start)[:4])
    # wave 1 (2 jobs, degraded 20s) holds 2 cores; the window end at 15 is an
    # event round that restores the core cap, so wave 2 starts at 15 on the
    # other 2 cores at full speed (10s) and service pricing is per-start
    np.testing.assert_allclose(starts, [0.0, 0.0, 15.0, 15.0], atol=1e-4)
    wall = np.asarray(res.jobs.t_finish - res.jobs.t_start)
    order = np.argsort(np.asarray(res.jobs.t_start)[:4])
    np.testing.assert_allclose(wall[:4][order], [20.0, 20.0, 10.0, 10.0], atol=1e-3)
    assert float(res.makespan) == pytest.approx(25.0, abs=1e-3)


def test_acceptance_midrun_outage_changes_outcome_baseline_intact():
    """ISSUE acceptance: a mid-run outage on the loaded site strictly
    increases makespan and produces nonzero preemption counters, while the
    same seed with no windows reproduces the no-availability baseline
    bit-for-bit."""
    jobs = synthetic_panda_jobs(150, seed=7, duration=1200.0)
    sites = atlas_like_platform(3, seed=8)
    pol = get_policy("panda_dispatch")
    key = jax.random.PRNGKey(0)

    base = simulate(jobs, sites, pol, key)
    # hit the most-loaded site mid-run with a preempting outage
    loaded = int(np.argmax(np.asarray(base.sites.n_finished)))
    t_mid = float(base.makespan) * 0.5
    av = make_availability(
        3, [dict(site=loaded, start=t_mid, end=t_mid + float(base.makespan), preempt=True)]
    )
    hit = simulate(jobs, sites, pol, key, availability=av)
    assert float(hit.makespan) > float(base.makespan)
    assert int(hit.avail.n_preempted.sum()) > 0
    assert (np.asarray(hit.jobs.state)[:150] == DONE).all()

    # same seed, empty calendar == baseline, bit for bit
    clean = simulate(jobs, sites, pol, key, availability=make_availability(3))
    for k in base.jobs._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(base.jobs, k)), np.asarray(getattr(clean.jobs, k)), err_msg=k
        )
    assert float(base.makespan) == float(clean.makespan)


def test_quantum_rounds_still_terminate_with_windows():
    jobs = synthetic_panda_jobs(60, seed=2, duration=600.0)
    sites = atlas_like_platform(3, seed=3)
    av = maintenance_calendar(3, horizon=40_000.0, period=9_000.0, duration=1_500.0)
    res = simulate(
        jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0),
        availability=av, quantum=50.0,
    )
    assert (np.asarray(res.jobs.state)[:60] == DONE).all()


def test_ensemble_vmap_jit_smoke_with_availability():
    jobs = synthetic_panda_jobs(50, seed=4, duration=600.0)
    sites = atlas_like_platform(3, seed=5)
    av = make_availability(3, [dict(site=0, start=100.0, end=4000.0, preempt=True)])
    cands = sites.speed[None, :] * jnp.array([[0.5], [1.0], [2.0]])
    res = simulate_ensemble(
        jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(1),
        speed_candidates=cands, availability=av,
    )
    assert res.makespan.shape == (3,)
    assert np.isfinite(np.asarray(res.makespan)).all()
    assert res.avail.n_preempted.shape == (3, 3)


def test_availability_composes_with_data_policy():
    from repro.core import get_data_policy, make_replicas, uniform_network, zipf_dataset_sizes

    rng = np.random.default_rng(0)
    jobs = make_jobs(
        job_id=np.arange(32), arrival=np.zeros(32), work=np.full(32, 50.0),
        cores=np.ones(32, np.int32), memory=np.full(32, 1.0),
        bytes_in=np.zeros(32), bytes_out=np.zeros(32),
        dataset=rng.integers(0, 6, 32),
    )
    sites = make_sites(
        cores=np.full(3, 8), speed=np.full(3, 10.0), memory=np.full(3, 64.0),
        bw_in=np.full(3, 1e12), bw_out=np.full(3, 1e12),
    )
    net = uniform_network(3, bw=1e9, latency=0.01)
    rep = make_replicas(
        zipf_dataset_sizes(6, seed=1, mean_bytes=1e9), disk_capacity=np.full(3, 1e12), seed=2
    )
    av = make_availability(3, [dict(site=0, start=2.0, end=30.0, preempt=True)])
    res = simulate(
        jobs, sites, get_policy("round_robin"), jax.random.PRNGKey(0),
        data_policy=get_data_policy("cache_on_read"), network=net, replicas=rep,
        availability=av,
    )
    state = np.asarray(res.jobs.state)[np.asarray(res.jobs.valid)]
    assert (state == DONE).all()
    assert int(res.avail.n_preempted.sum()) > 0
    assert float(res.replicas.bytes_moved) > 0


# --------------------------------------------------------------------------
# scenario builders & input layer
# --------------------------------------------------------------------------


def test_maintenance_calendar_staggers_and_repeats():
    av = maintenance_calendar(4, horizon=15 * 86400.0, period=7 * 86400.0, duration=3600.0)
    start = np.asarray(av.win_start)
    assert (np.isfinite(start).sum(axis=1) >= 1).all()  # every site gets a slot
    assert np.isfinite(start[0]).sum() == 2             # unstaggered site: 2 periods fit
    firsts = np.sort(start[:, 0])
    assert (np.diff(firsts) > 0).all()  # staggered, no simultaneous downtime
    assert not np.asarray(av.win_preempt).any()  # maintenance drains


def test_flaky_sites_only_hits_flagged_sites():
    av = flaky_sites(5, [1, 3], horizon=86400.0, mtbf=7200.0, seed=0)
    finite = np.isfinite(np.asarray(av.win_start))
    assert finite[[1, 3]].any()
    assert not finite[[0, 2, 4]].any()
    preempt = np.asarray(av.win_preempt)
    assert preempt[finite].all()
    # bool-mask selection and an empty selection both work
    av_mask = flaky_sites(5, np.array([False, True, False, True, False]),
                          horizon=86400.0, mtbf=7200.0, seed=0)
    np.testing.assert_array_equal(np.asarray(av_mask.win_start), np.asarray(av.win_start))
    av_none = flaky_sites(4, [], horizon=86400.0)
    assert not np.isfinite(np.asarray(av_none.win_start)).any()


def test_rolling_brownout_tiles_the_horizon():
    av = rolling_brownout(4, horizon=4000.0, factor=0.25)
    start, end = np.asarray(av.win_start), np.asarray(av.win_end)
    order = np.argsort(start[:, 0])
    np.testing.assert_allclose(start[order, 0], [0.0, 1000.0, 2000.0, 3000.0])
    np.testing.assert_allclose(end[order, 0], [1000.0, 2000.0, 3000.0, 4000.0])
    assert np.allclose(np.asarray(av.win_factor)[:, 0], 0.25)


def test_correlated_outages_share_tier_event_times():
    tier = np.array([0, 0, 0, 1, 1, 1])
    av = sample_correlated_outages(
        6, tier, horizon=86400.0, events_per_tier=3.0, p_follow=1.0, jitter=0.0, seed=1
    )
    start = np.asarray(av.win_start)
    for t in (0, 1):
        members = np.flatnonzero(tier == t)
        ref = start[members[0]][np.isfinite(start[members[0]])]
        for m in members[1:]:
            got = start[m][np.isfinite(start[m])]
            np.testing.assert_allclose(got, ref)  # p_follow=1, no jitter: identical
    assert np.isfinite(start).any()


def test_load_availability_json_roundtrip():
    sites, names, _ = load_platform(
        {"sites": [{"name": "CERN", "cores": 100}, {"name": "BNL", "cores": 50}]}
    )
    av = load_availability(
        '{"windows": [{"site": "BNL", "start": 10, "end": 20, "preempt": true},'
        ' {"site": 0, "start": 5, "end": 8, "factor": 0.5}]}',
        names,
    )
    assert float(av.win_start[1, 0]) == 10.0 and bool(av.win_preempt[1, 0])
    assert float(av.win_factor[0, 0]) == 0.5
    with pytest.raises(ValueError):
        load_availability({"windows": [{"site": "FNAL", "start": 0, "end": 1}]}, names)


# --------------------------------------------------------------------------
# events / monitor export
# --------------------------------------------------------------------------


def test_availability_rows_schema_and_order():
    av = make_availability(
        2,
        [
            dict(site=1, start=5.0, end=9.0, preempt=True),
            dict(site=0, start=2.0, end=4.0, factor=0.5),
        ],
    )
    res = run(mini_jobs(4), make_sites(
        cores=[4, 4], speed=[10.0, 10.0], memory=[64.0, 64.0],
        bw_in=[1e12, 1e12], bw_out=[1e12, 1e12]), av)
    rows = availability_rows(res, site_names=["CERN", "BNL"])
    assert [r["site"] for r in rows] == ["CERN", "BNL"]
    assert rows[0]["kind"] == "brownout" and rows[1]["kind"] == "outage"
    assert {"time", "site", "kind", "start", "end", "factor", "preempt", "n_preempted"} == set(
        rows[0]
    )
    times = [r["time"] for r in rows]
    assert times == sorted(times)


def test_availability_rows_empty_without_state():
    res = run(mini_jobs(2), one_site())
    assert availability_rows(res) == []


def test_ml_dataset_availability_features():
    av = make_availability(1, [dict(site=0, start=5.0, end=50.0, preempt=True)])
    res = run(mini_jobs(8), one_site(), av)
    ds = ml_dataset(res)
    names = list(ds["feature_names"])
    assert names[-3:] == ["n_preempted", "site_downtime_frac", "site_log_preempted"]
    assert ds["features"].shape == (8, len(names))
    assert np.isfinite(ds["features"]).all()
    pre_col = ds["features"][:, names.index("n_preempted")]
    assert pre_col.sum() == 4  # the preempted first wave
    # without availability the schema is unchanged
    assert "n_preempted" not in list(ml_dataset(run(mini_jobs(2), one_site()))["feature_names"])


def test_monitor_availability_timeline_and_frame():
    av = make_availability(1, [dict(site=0, start=5.0, end=50.0)])
    res = run(mini_jobs(8), one_site(), av, log_rows=64)
    tl = availability_timeline(res)
    assert tl.shape[1] == 1
    assert tl.min() == 0.0 and tl.max() == 1.0  # saw both down and up rounds
    frames = log_frames(res)
    down = [f for f in frames if f["site_avail"][0] == 0.0]
    assert down
    txt = render_frame(down[0], np.asarray(res.sites.cores))
    assert "DOWN" in txt
    av_b = make_availability(1, [dict(site=0, start=0.0, end=1000.0, factor=0.5)])
    res_b = run(mini_jobs(4), one_site(), av_b, log_rows=16)
    txt_b = render_frame(log_frames(res_b)[0], np.asarray(res_b.sites.cores))
    assert "avail=x0.50" in txt_b
