"""Per-lane ensemble equivalence (ISSUE 5): every ``simulate_many`` /
sharded / bucketed lane is bit-for-bit equal to the corresponding solo
``simulate`` — including subsystem combinations — and the phase-skip guard
is invisible to results.

These tests pin the contract that makes ensembles trustworthy for
calibration and surrogate-dataset sweeps: batching, bucketing, and sharding
change *how* lanes are executed, never *what* any lane computes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Scenario,
    ScenarioBuckets,
    get_data_policy,
    get_policy,
    make_availability,
    make_replicas,
    make_subsystem,
    make_workflow,
    simulate,
    simulate_many,
    stack_scenarios,
    synthetic_panda_jobs,
    uniform_network,
    zipf_dataset_sizes,
)
from repro.core.availability import availability_subsystem
from repro.core.datapolicies import data_subsystem
from repro.core.platform import atlas_like_platform
from repro.core.types import pad_jobs_capacity
from repro.core.workflows import workflow_subsystem


def tree_equal(a, b, ignore_shape_prefix=False):
    """Exact pytree equality (NaN == NaN); returns list of differing paths."""
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    bad = []
    for (k1, v1), (_, v2) in zip(fa, fb):
        x, y = np.asarray(v1), np.asarray(v2)
        if x.shape != y.shape or not ((x == y) | (_bothnan(x, y))).all():
            bad.append(jax.tree_util.keystr(k1))
    return bad


def _bothnan(x, y):
    if not np.issubdtype(x.dtype, np.floating):
        return np.zeros(x.shape, bool)
    return np.isnan(x) & np.isnan(y)


def lane(res, i):
    return jax.tree.map(lambda x: x[i], res)


def ragged_scenarios(sizes, n_sites=4, seed0=10):
    sites = atlas_like_platform(n_sites, seed=1)
    return [
        Scenario(
            synthetic_panda_jobs(n, seed=seed0 + i, duration=600.0),
            sites._replace(speed=sites.speed * (0.7 + 0.1 * i)),
        )
        for i, n in enumerate(sizes)
    ]


# --------------------------------------------------------------------------
# plain ensembles
# --------------------------------------------------------------------------


def test_vmapped_lanes_equal_solo_ragged():
    sizes = [40, 72, 46, 58]
    scens = ragged_scenarios(sizes)
    pol = get_policy("panda_dispatch")
    keys = jax.random.split(jax.random.PRNGKey(2), len(scens))
    res = simulate_many(scens, pol, jax.random.PRNGKey(2))
    cap = max(sizes)
    for i, s in enumerate(scens):
        solo = simulate(pad_jobs_capacity(s.jobs, cap), s.sites, pol, keys[i])
        assert tree_equal(lane(res, i), solo) == []


def test_bucketed_equals_flat_and_solo():
    sizes = [40, 72, 46, 90, 58, 33, 61]
    scens = ragged_scenarios(sizes)
    pol = get_policy("shortest_wait")
    flat = simulate_many(scens, pol, jax.random.PRNGKey(3))
    sb = stack_scenarios(scens, buckets=3)
    assert isinstance(sb, ScenarioBuckets)
    assert sorted(i for ix in sb.index for i in ix) == list(range(len(sizes)))
    # each bucket pads only to its own max, not the global one
    assert sorted(s.jobs.capacity for s in sb.buckets)[0] < max(sizes)
    res = simulate_many(sb, pol, jax.random.PRNGKey(3))
    assert tree_equal(res, flat) == []
    keys = jax.random.split(jax.random.PRNGKey(3), len(scens))
    solo = simulate(
        pad_jobs_capacity(scens[4].jobs, max(sizes)), scens[4].sites, pol, keys[4]
    )
    assert tree_equal(lane(res, 4), solo) == []


def test_sharded_equals_vmapped_in_process():
    """On whatever mesh this process has (1 device in plain CI): the
    shard_map entry point, including the lane-padding path (K=3 lanes)."""
    from repro.core.distributed import simulate_many_sharded

    scens = ragged_scenarios([40, 64, 52])
    pol = get_policy("panda_dispatch")
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    r_v = simulate_many(scens, pol, jax.random.PRNGKey(2))
    r_s = simulate_many_sharded(scens, pol, jax.random.PRNGKey(2), mesh)
    assert tree_equal(r_s, r_v) == []
    # bucketed + sharded composes
    sb = stack_scenarios(scens, buckets=2)
    r_bs = simulate_many_sharded(sb, pol, jax.random.PRNGKey(2), mesh)
    assert tree_equal(r_bs, r_v) == []


# --------------------------------------------------------------------------
# subsystem combinations
# --------------------------------------------------------------------------

N_DS = 8


def combo_scenarios(K=3, n=44, n_sites=3):
    """K same-shape scenarios with availability + workflow + data subsystems
    (per-scenario calendars/catalogs/DAGs)."""
    sites = atlas_like_platform(n_sites, seed=7)
    net = uniform_network(n_sites, bw=5e8, latency=0.05)
    dp = get_data_policy("cache_on_read")
    subs = (availability_subsystem(), workflow_subsystem(), data_subsystem(dp))
    scens, solo_kw = [], []
    for k in range(K):
        jobs = synthetic_panda_jobs(n, seed=30 + k, duration=600.0, n_datasets=N_DS)
        av = make_availability(
            n_sites,
            [
                dict(site=k % n_sites, start=100.0 * (k + 1), end=900.0, preempt=True),
                dict(site=(k + 1) % n_sites, start=50.0, end=400.0, factor=0.5),
            ],
        )
        rep = make_replicas(
            zipf_dataset_sizes(N_DS, seed=3 + k, mean_bytes=1e9),
            disk_capacity=np.full(n_sites, 1e12),
            origin=np.zeros(N_DS, np.int32),
        )
        edges = [(j - 1, j) for j in range(1, n, 2)]
        out_ds = np.where(np.arange(n) % 2 == 0, np.arange(n) % N_DS, -1)
        jobs_wf, wf = make_workflow(jobs, edges, out_dataset=out_ds)
        scens.append(
            Scenario(
                jobs_wf,
                sites._replace(speed=sites.speed * (0.8 + 0.2 * k)),
                {"availability": av, "workflow": wf, "data": (net, rep)},
            )
        )
        solo_kw.append(
            dict(availability=av, workflow=wf, data_policy=dp, network=net, replicas=rep)
        )
    return scens, subs, solo_kw


def test_subsystem_combo_lanes_equal_solo():
    scens, subs, solo_kw = combo_scenarios()
    pol = get_policy("critical_path_first")
    K = len(scens)
    keys = jax.random.split(jax.random.PRNGKey(4), K)
    res = simulate_many(scens, pol, jax.random.PRNGKey(4), subsystems=subs)
    for i, s in enumerate(scens):
        solo = simulate(s.jobs, s.sites, pol, keys[i], **solo_kw[i])
        assert tree_equal(lane(res, i), solo) == []
        assert int(res.wf.n_produced[i]) > 0  # the DAGs actually materialize


def test_subsystem_combo_sharded_equals_vmapped():
    from repro.core.distributed import simulate_many_sharded

    scens, subs, _ = combo_scenarios()
    pol = get_policy("panda_dispatch")
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    r_v = simulate_many(scens, pol, jax.random.PRNGKey(4), subsystems=subs)
    r_s = simulate_many_sharded(scens, pol, jax.random.PRNGKey(4), mesh, subsystems=subs)
    assert tree_equal(r_s, r_v) == []


# --------------------------------------------------------------------------
# phase-skip guard
# --------------------------------------------------------------------------


def test_phase_skip_guard_bit_for_bit_solo():
    jobs = synthetic_panda_jobs(70, seed=0, duration=900.0)
    sites = atlas_like_platform(4, seed=1)
    av = make_availability(4, [dict(site=0, start=100.0, end=2000.0, preempt=True)])
    for kw in ({}, {"availability": av}, {"quantum": 30.0}):
        for pol_name in ("panda_dispatch", "shortest_wait"):
            pol = get_policy(pol_name)
            r1 = simulate(jobs, sites, pol, jax.random.PRNGKey(0), **kw)
            r0 = simulate(jobs, sites, pol, jax.random.PRNGKey(0), phase_skip=False, **kw)
            assert tree_equal(r1, r0) == []


def test_phase_skip_guard_bit_for_bit_ensemble():
    scens = ragged_scenarios([40, 64, 52])
    pol = get_policy("panda_dispatch")
    r1 = simulate_many(scens, pol, jax.random.PRNGKey(2))
    r0 = simulate_many(scens, pol, jax.random.PRNGKey(2), phase_skip=False)
    assert tree_equal(r1, r0) == []


# --------------------------------------------------------------------------
# subsystem RNG streams (ROADMAP: per-subsystem fold-in keys)
# --------------------------------------------------------------------------


def _noise_on_completions(sub, ctx):
    # draw per-round randomness from this subsystem's own stream; a second
    # named stream must be independent of the first
    u = jax.random.uniform(ctx.subkey("noise"))
    v = jax.random.uniform(ctx.subkey("noise", salt=1))
    ctx.ext["noise"] = {
        "sum": ctx.ext["noise"]["sum"] + u,
        "sum2": ctx.ext["noise"]["sum2"] + v,
    }


def test_subsystem_rng_streams_do_not_perturb_engine():
    """A stochastic subsystem drawing via ``ctx.subkey`` leaves the engine's
    own bitstream untouched: jobs/sites/makespan are bit-for-bit identical to
    the run without the subsystem, while its draws are deterministic and
    per-stream independent."""
    jobs = synthetic_panda_jobs(50, seed=0, duration=600.0)
    sites = atlas_like_platform(3, seed=1)
    pol = get_policy("panda_dispatch")
    noise = make_subsystem("noise", on_completions=_noise_on_completions)
    state0 = {"sum": jnp.float32(0.0), "sum2": jnp.float32(0.0)}

    base = simulate(jobs, sites, pol, jax.random.PRNGKey(0))
    with_noise = simulate(
        jobs, sites, pol, jax.random.PRNGKey(0), subsystems=((noise, state0),)
    )
    assert tree_equal(base.jobs, with_noise.jobs) == []
    assert tree_equal(base.sites, with_noise.sites) == []
    assert float(base.makespan) == float(with_noise.makespan)
    assert int(base.rounds) == int(with_noise.rounds)

    s1 = float(with_noise.ext["noise"]["sum"])
    s2 = float(with_noise.ext["noise"]["sum2"])
    assert s1 > 0.0 and s2 > 0.0 and s1 != s2  # streams drew, independently
    again = simulate(
        jobs, sites, pol, jax.random.PRNGKey(0), subsystems=((noise, state0),)
    )
    assert float(again.ext["noise"]["sum"]) == s1  # deterministic stream
    other_key = simulate(
        jobs, sites, pol, jax.random.PRNGKey(9), subsystems=((noise, state0),)
    )
    assert float(other_key.ext["noise"]["sum"]) != s1  # keyed by the run key


def test_ensemble_keys_match_solo_keys():
    """Lane i of an ensemble uses split(rng, K)[i] — pinned so bucketing and
    sharding can permute execution order without changing any lane's draws."""
    scens = ragged_scenarios([40, 40])
    pol = get_policy("random")  # scores drawn from the per-round policy key
    keys = jax.random.split(jax.random.PRNGKey(11), 2)
    res = simulate_many(scens, pol, jax.random.PRNGKey(11))
    for i, s in enumerate(scens):
        solo = simulate(s.jobs, s.sites, pol, keys[i])
        assert float(res.makespan[i]) == float(solo.makespan)
