"""Mini multi-pod dry-run: the launch/dryrun plumbing (specs, shardings,
lower+compile, roofline extraction) on an 8-device (2,2,2) pod/data/model
mesh with smoke configs — CI-sized proof that the 512-device path is
coherent."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

assert len(jax.devices()) == 8
if hasattr(jax.sharding, "AxisType"):
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
else:  # jax <= 0.4.x: no explicit-sharding axis types
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
from repro.core.distributed import use_mesh

from repro.configs import get_smoke
from repro.models import build_model
from repro.parallel.sharding import cache_shardings, params_shardings
from repro.launch.roofline import collective_bytes
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step
from repro.serve.serve_step import make_decode_step

for arch in ["deepseek-7b", "kimi-k2-1t-a32b", "mamba2-130m", "recurrentgemma-2b"]:
    cfg = get_smoke(arch).replace(vocab_size=512)
    if cfg.family == "moe":
        cfg = cfg.replace(router_groups=4)
    model = build_model(cfg)

    # ---- train step, sharded state, donated ------------------------------
    abs_state = jax.eval_shape(lambda: init_train_state(model, jax.random.PRNGKey(0)))
    psh = params_shardings(abs_state.params, mesh)
    state_sh = type(abs_state)(params=psh,
                               opt={"m": psh, "v": psh, "count": NamedSharding(mesh, P())},
                               err=None)
    state_structs = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), abs_state, state_sh)
    B, S = 8, 64
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32,
             sharding=NamedSharding(mesh, P(("pod", "data"), None)))}
    step = make_train_step(model, AdamWConfig(), microbatches=2)
    with use_mesh(mesh):
        lowered = jax.jit(step, donate_argnums=0).lower(state_structs, batch)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    assert sum(coll.values()) > 0, (arch, "expected collectives in train step")
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert float(ca.get("flops", 0)) > 0

    # ---- decode step with sharded cache -----------------------------------
    abs_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pstructs = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abs_params, params_shardings(abs_params, mesh))
    abs_cache = jax.eval_shape(lambda: model.init_cache(B, 128))
    cstructs = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abs_cache, cache_shardings(abs_cache, mesh, batch=("pod", "data")))
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                               sharding=NamedSharding(mesh, P(("pod", "data"), None)))
    decode = make_decode_step(model)
    with use_mesh(mesh):
        dec_compiled = jax.jit(lambda p, t, c: decode(p, t, c),
                               donate_argnums=2).lower(pstructs, tok, cstructs).compile()
    assert dec_compiled.memory_analysis() is not None
    print("MINI-OK", arch)
print("ALL-OK")
"""


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True, timeout=1800
    )
    assert out.returncode == 0, out.stderr[-5000:]
    assert "ALL-OK" in out.stdout
