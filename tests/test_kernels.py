"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
shape/dtype sweeps (EXAMPLE.md contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.assign.assign import assign_pallas
from repro.kernels.assign.ops import assign, make_capacity_assign, moe_route
from repro.kernels.assign.ref import assign_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ops import chunked_attention, decode_attention
from repro.kernels.flash_attention.ref import attention_ref


# ---------------------------------------------------------------- assign ---

ASSIGN_CASES = [
    # (N, E, k, block_n)
    (64, 8, 1, 32),
    (128, 16, 2, 64),
    (256, 384, 8, 256),   # kimi-k2 router shape class
    (100, 50, 1, 256),    # jobs x sites, single block
    (33, 7, 3, 16),       # ragged tail
    (512, 32, 8, 128),    # granite router shape class
]


@pytest.mark.parametrize("N,E,k,bn", ASSIGN_CASES)
def test_assign_matches_ref(N, E, k, bn):
    rng = np.random.default_rng(N * 31 + E)
    scores = rng.normal(size=(N, E)).astype(np.float32)
    scores[rng.random((N, E)) < 0.1] = -1e30
    sizes = rng.choice([1.0, 2.0, 8.0], size=N).astype(np.float32)
    caps = rng.uniform(2, 40, size=E).astype(np.float32)
    r = assign_ref(jnp.array(scores), jnp.array(sizes), jnp.array(caps), k=k, block_n=bn)
    p = assign_pallas(
        jnp.array(scores), jnp.array(sizes), jnp.array(caps), k=k, block_n=bn, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(p[0]))  # idx
    np.testing.assert_allclose(np.asarray(r[1]), np.asarray(p[1]), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(r[2]), np.asarray(p[2]))  # admit
    np.testing.assert_allclose(np.asarray(r[3]), np.asarray(p[3]), rtol=1e-5, atol=1e-5)


def test_assign_respects_capacity_exactly():
    # all items want bin 0; capacity 10 units; sizes 3 => exactly 3 admitted
    N = 16
    scores = jnp.zeros((N, 4)).at[:, 0].set(10.0)
    sizes = jnp.full((N,), 3.0)
    caps = jnp.array([10.0, 100.0, 100.0, 100.0])
    idx, gate, admit, pos = assign(scores, sizes, caps, k=1, use_kernel=True)
    assert int(admit.sum()) == 3
    assert (np.asarray(idx)[:, 0] == 0).all()
    np.testing.assert_allclose(np.asarray(pos)[:4, 0], [0.0, 3.0, 6.0, 9.0])


def test_assign_infeasible_rows():
    scores = jnp.full((8, 4), -1e30)
    idx, gate, admit, pos = assign(scores, jnp.ones(8), jnp.full(4, 100.0), k=2)
    assert (np.asarray(idx) == -1).all()
    assert not np.asarray(admit).any()
    assert (np.asarray(gate) == 0).all()


def test_moe_route_slots_unique_per_expert():
    T, E, k, cap = 256, 16, 2, 24
    logits = jax.random.normal(jax.random.PRNGKey(0), (T, E))
    idx, combine, slot, keep = moe_route(logits, k=k, capacity=cap)
    idx, slot, keep = map(np.asarray, (idx, slot, keep))
    # kept (expert, slot) pairs must be unique and < capacity
    pairs = [(int(e), int(s)) for e, s, kp in
             zip(idx.ravel(), slot.ravel(), keep.ravel()) if kp]
    assert len(pairs) == len(set(pairs))
    assert all(0 <= s < cap for _, s in pairs)
    assert np.asarray(combine).min() >= 0


def test_capacity_assign_engine_combinator():
    from repro.core import make_sites

    sites = make_sites(cores=[4, 2], speed=[10.0, 10.0], memory=[64.0, 64.0],
                       bw_in=[1e9, 1e9], bw_out=[1e9, 1e9])
    J = 6
    scores = jnp.zeros((J, 2)).at[:, 0].set(1.0)  # all prefer site 0 (4 cores)
    queued = jnp.ones((J,), bool)
    feasible = jnp.ones((J, 2), bool)
    fn = make_capacity_assign(jobs_cores=jnp.full((J,), 2, jnp.int32))
    site, ok = fn(scores, queued, feasible, sites)
    assert int(ok.sum()) == 2          # 2x 2-core jobs fit site 0
    assert (np.asarray(site)[np.asarray(ok)] == 0).all()


# ------------------------------------------------------- flash attention ---

FLASH_CASES = [
    # (B, Hq, Hkv, S, D, window, dtype)
    (1, 4, 4, 256, 64, 0, jnp.float32),
    (2, 8, 2, 128, 64, 0, jnp.float32),      # GQA 4:1
    (1, 4, 1, 384, 128, 0, jnp.float32),     # MQA, ragged seq -> padding
    (1, 4, 2, 256, 64, 64, jnp.float32),     # sliding window
    (1, 8, 8, 256, 64, 0, jnp.bfloat16),
    (2, 4, 2, 200, 64, 96, jnp.bfloat16),    # window + padding
]


@pytest.mark.parametrize("B,Hq,Hkv,S,D,window,dtype", FLASH_CASES)
def test_flash_matches_ref(B, Hq, Hkv, S, D, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * 131 + S), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, window=window, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("window", [0, 128])
def test_chunked_attention_matches_ref(window):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, Hq, Hkv, S, D = 2, 8, 2, 320, 64
    q = jax.random.normal(ks[0], (B, Hq, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    out = chunked_attention(q, k, v, causal=True, window=window, chunk=128)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_chunked_attention_is_differentiable():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32))
    k = jax.random.normal(ks[1], (1, 2, 64, 32))
    v = jax.random.normal(ks[2], (1, 2, 64, 32))
    g = jax.grad(lambda q: chunked_attention(q, k, v, chunk=32).sum())(q)
    assert np.isfinite(np.asarray(g)).all()


def test_decode_attention_matches_full_prefix():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, Hq, Hkv, Skv, D = 2, 4, 2, 96, 64
    cache_k = jax.random.normal(ks[0], (B, Hkv, Skv, D))
    cache_v = jax.random.normal(ks[1], (B, Hkv, Skv, D))
    q = jax.random.normal(ks[2], (B, Hq, 1, D))
    kv_len = jnp.array([64, 96])
    out = decode_attention(q, cache_k, cache_v, kv_len=kv_len)
    for b in range(B):
        L = int(kv_len[b])
        ref = attention_ref(
            q[b : b + 1], cache_k[b : b + 1, :, :L], cache_v[b : b + 1, :, :L], causal=True
        )
        np.testing.assert_allclose(
            np.asarray(out[b]), np.asarray(ref[0]), rtol=2e-5, atol=2e-5
        )


def test_decode_attention_window_matches_windowed_ref():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    B, Hq, Hkv, Skv, D, W = 1, 4, 1, 128, 32, 32
    cache_k = jax.random.normal(ks[0], (B, Hkv, Skv, D))
    cache_v = jax.random.normal(ks[1], (B, Hkv, Skv, D))
    q = jax.random.normal(ks[2], (B, Hq, 1, D))
    out = decode_attention(q, cache_k, cache_v, kv_len=Skv, window=W)
    ref = attention_ref(q, cache_k[:, :, -W:], cache_v[:, :, -W:], causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
