"""FTS transfer-queue subsystem (ISSUE 8, DESIGN.md §11).

Pins the subsystem's contract from three sides:

- queue mechanics: per-link caps serialize flows FIFO, queue-wait is
  recorded, occupancy never exceeds the cap;
- the acceptance demo: a capped hot link changes the makespan vs. the
  instantaneous equal-share model, and converges back to it as
  ``max_active -> inf`` (single wave, equal flows — the two models are
  algebraically identical there);
- composition: lane ≡ solo under ``simulate_many`` (incl. ragged/bucketed
  capacity padding) and sharded ≡ vmapped, with all four built-in
  subsystems attached.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DONE,
    Scenario,
    compute_metrics,
    get_data_policy,
    link_caps,
    make_availability,
    make_replicas,
    make_sites,
    make_transfers,
    make_workflow,
    simulate,
    simulate_many,
    stack_scenarios,
    synthetic_panda_jobs,
    uniform_network,
    zipf_dataset_sizes,
)
from repro.core.availability import availability_subsystem
from repro.core.datapolicies import data_subsystem
from repro.core.events import ml_dataset, transfer_rows
from repro.core.monitor import link_occupancy_timeline, transfer_queue_timeline
from repro.core.platform import atlas_like_platform
from repro.core.policies import get_policy
from repro.core.transfers import transfers_subsystem
from repro.core.types import pad_jobs_capacity
from repro.core.workflows import workflow_subsystem

from test_ensemble_lanes import lane, tree_equal


def hot_link_scenario(n_jobs=24, n_sites=3, *, bw=1e8, ds_bytes=2e9, work=None,
                      cores_per_site=64, seed=0):
    """Every job reads its own equal-sized dataset homed at site 0 — the
    classic data-lake fan-out that saturates the egress links."""
    jobs = synthetic_panda_jobs(n_jobs, seed=seed, duration=1.0)
    # single wave at t=0, small single-core compute
    jobs = jobs._replace(
        arrival=jnp.zeros((jobs.capacity,)),
        cores=jnp.ones((jobs.capacity,), jnp.int32),
        memory=jnp.full((jobs.capacity,), 1.0),
        work=jnp.full((jobs.capacity,), float(work if work is not None else 50.0)),
        bytes_in=jnp.zeros((jobs.capacity,)),
        bytes_out=jnp.zeros((jobs.capacity,)),
        dataset=jnp.arange(jobs.capacity, dtype=jnp.int32) % n_jobs,
    )
    # site 0 is a pure data lake (no memory -> infeasible for compute), so
    # every job lands on a remote site and stages over a 0 -> dst link
    sites = make_sites(
        cores=[cores_per_site] * n_sites, speed=[1.0] * n_sites,
        fail_rate=[0.0] * n_sites, memory=[0.0] + [1e9] * (n_sites - 1),
        bw_in=[1e12] * n_sites, bw_out=[1e12] * n_sites,
    )
    net = uniform_network(n_sites, bw=bw, latency=0.05)
    rep = make_replicas(
        np.full(n_jobs, ds_bytes, np.float32), np.full(n_sites, 1e15),
        origin=np.zeros(n_jobs, np.int32),
    )
    return jobs, sites, net, rep


def run(jobs, sites, net, rep, *, transfers=None, policy="least_loaded", seed=0, **kw):
    return simulate(
        jobs, sites, get_policy(policy), jax.random.PRNGKey(seed),
        data_policy=get_data_policy("always_remote"), network=net, replicas=rep,
        transfers=transfers, **kw,
    )


# --------------------------------------------------------------------------
# queue mechanics
# --------------------------------------------------------------------------


def test_capped_link_serializes_fifo():
    jobs, sites, net, rep = hot_link_scenario(n_jobs=12, n_sites=2, cores_per_site=32)
    ts = make_transfers(2, jobs.capacity, max_active=1)
    res = run(jobs, sites, net, rep, transfers=ts, log_rows=512)

    tse = res.ext["transfers"]
    assert int(tse.n_enq) > 1
    assert int(tse.n_enq) == int(tse.n_done)
    assert int(tse.n_cancel) == 0 and int(tse.n_overflow) == 0
    # queues drained, slots released
    assert (np.asarray(tse.stat) == 0).all()
    assert (np.asarray(tse.active) == 0).all()

    # cap=1 serializes: the k-th transfer on the link waits ~ (k-1) full
    # transfer times, so the recorded queue-waits are strictly spread out
    moved = (np.asarray(res.jobs.xfer_bytes) > 0) & np.asarray(res.jobs.valid)
    waits = np.sort(np.asarray(res.jobs.xfer_wait)[moved])
    assert waits[0] == 0.0  # someone went straight to the wire
    assert waits[-1] > 0.0  # and someone queued behind it
    assert len(np.unique(np.round(waits, 3))) > len(waits) // 2
    # queue depth seen at enqueue was recorded
    assert int(np.asarray(res.jobs.xfer_qdepth)[moved].max()) > 0

    # link occupancy never exceeds the cap, and the queue actually built up
    occ = link_occupancy_timeline(res)
    qd = transfer_queue_timeline(res)
    assert occ.shape[1:] == (2, 2) and qd.shape == occ.shape
    assert occ.max() <= 1.0
    assert qd.max() >= 1.0


def test_transfers_requires_data_subsystem():
    jobs, sites, net, rep = hot_link_scenario(n_jobs=4, n_sites=2)
    ts = make_transfers(2, jobs.capacity)
    with pytest.raises(ValueError, match="transfers="):
        simulate(jobs, sites, get_policy("least_loaded"), jax.random.PRNGKey(0),
                 transfers=ts)


def test_link_caps_overrides():
    caps = link_caps(3, 4, {(0, 1): 1, (0, 2): 2})
    m = np.asarray(caps).reshape(3, 3)
    assert m[0, 1] == 1 and m[0, 2] == 2 and m[1, 2] == 4
    full = link_caps(2, 0, np.array([[9, 8], [7, 6]]))
    assert np.asarray(full).tolist() == [9, 8, 7, 6]
    with pytest.raises(ValueError):
        link_caps(3, 1, np.zeros((2, 2)))


# --------------------------------------------------------------------------
# acceptance demo: capped hot link vs. the equal-share model
# --------------------------------------------------------------------------


def test_hot_link_cap_changes_makespan_and_converges():
    # transfer-dominated fan-out with limited cores: a cap=1 hot link runs a
    # genuinely different trajectory than wave-batched equal share (FIFO
    # staggers releases and pipelines staging against compute, equal share
    # batches whole waves) — the makespan moves materially
    jobs, sites, net, rep = hot_link_scenario(
        n_jobs=24, n_sites=3, cores_per_site=4, work=20.0
    )
    flat = run(jobs, sites, net, rep)
    capped = run(jobs, sites, net, rep,
                 transfers=make_transfers(3, jobs.capacity, max_active=1))
    assert int((np.asarray(capped.jobs.state) == DONE).sum()) == 24
    rel = abs(float(capped.makespan) - float(flat.makespan)) / float(flat.makespan)
    assert rel > 0.05, (float(flat.makespan), float(capped.makespan))
    # and jobs demonstrably waited in the link queue
    assert float(np.asarray(capped.jobs.xfer_wait).max()) > 0.0

    # single wave with ample cores and equal-sized flows: equal-share and an
    # uncapped queue are the same closed form -> the makespans converge
    jobs, sites, net, rep = hot_link_scenario(n_jobs=24, n_sites=3, cores_per_site=64)
    flat = run(jobs, sites, net, rep)
    uncapped = run(jobs, sites, net, rep,
                   transfers=make_transfers(3, jobs.capacity, max_active=10_000))
    rel = abs(float(uncapped.makespan) - float(flat.makespan)) / float(flat.makespan)
    assert rel < 2e-2, (float(flat.makespan), float(uncapped.makespan))


# --------------------------------------------------------------------------
# preemption: cancelled transfers, tombstones, retries
# --------------------------------------------------------------------------


def test_preempted_staging_jobs_cancel_and_retry():
    jobs, sites, net, rep = hot_link_scenario(n_jobs=16, n_sites=2, cores_per_site=32)
    av = make_availability(2, [dict(site=1, start=5.0, end=200.0, preempt=True)])
    ts = make_transfers(2, jobs.capacity, max_active=2)
    res = simulate(
        jobs, sites, get_policy("least_loaded"), jax.random.PRNGKey(0),
        data_policy=get_data_policy("always_remote"), network=net, replicas=rep,
        availability=av, transfers=ts,
    )
    tse = res.ext["transfers"]
    # every enqueue terminated exactly once, in bytes too
    assert int(tse.n_enq) == int(tse.n_done) + int(tse.n_cancel)
    assert int(tse.n_cancel) > 0  # the outage really cut staging jobs down
    np.testing.assert_allclose(
        float(tse.bytes_enq), float(tse.bytes_done) + float(tse.bytes_cancel),
        rtol=1e-5,
    )
    # queues drained despite the tombstones, and the workload finished
    assert (np.asarray(tse.stat) == 0).all()
    assert (np.asarray(tse.active) == 0).all()
    st = np.asarray(res.jobs.state)[np.asarray(res.jobs.valid)]
    assert (st == DONE).all()


# --------------------------------------------------------------------------
# metrics / events / export schema
# --------------------------------------------------------------------------


def test_metrics_and_export_features():
    jobs, sites, net, rep = hot_link_scenario(n_jobs=12, n_sites=2, cores_per_site=32)
    ts = make_transfers(2, jobs.capacity, max_active=1)
    r_on = run(jobs, sites, net, rep, transfers=ts)
    r_off = run(jobs, sites, net, rep)

    m_on, m_off = compute_metrics(r_on), compute_metrics(r_off)
    assert float(m_on.p99_xfer_wait) > 0.0
    assert float(m_on.p50_xfer_time) > 0.0
    assert float(m_off.p99_xfer_wait) == 0.0  # defined (0) when the subsystem is off
    assert float(m_on.p50_xfer_wait) <= float(m_on.p95_xfer_wait) <= float(m_on.p99_xfer_wait)

    rows_on, rows_off = transfer_rows(r_on), transfer_rows(r_off)
    assert {"queue_wait", "queue_depth"} <= set(rows_on[0])
    assert max(r["queue_wait"] for r in rows_on) > 0.0
    # off: defaults only, schema unchanged
    assert all(r["queue_wait"] == 0.0 and r["queue_depth"] == -1 for r in rows_off)

    ds_on, ds_off = ml_dataset(r_on), ml_dataset(r_off)
    base = list(ds_off["feature_names"])
    assert "xfer_queue_wait" not in base
    assert list(ds_on["feature_names"]) == base + [
        "xfer_queue_wait", "xfer_queue_depth", "src_link_log_bw"
    ]
    assert ds_on["features"].shape[1] == len(ds_on["feature_names"])
    wait_col = ds_on["features"][:, base.__len__()]
    assert wait_col.max() > 0.0


# --------------------------------------------------------------------------
# ensembles: lane ≡ solo, sharded ≡ vmapped, ragged padding
# --------------------------------------------------------------------------

N_DS = 8


def quad_scenarios(K=3, n=44, n_sites=3, sizes=None):
    """K scenarios running all four built-in subsystems
    (availability + workflow + data + transfers)."""
    sites = atlas_like_platform(n_sites, seed=7)
    net = uniform_network(n_sites, bw=5e8, latency=0.05)
    dp = get_data_policy("cache_on_read")
    subs = (
        availability_subsystem(), workflow_subsystem(), data_subsystem(dp),
        transfers_subsystem(),
    )
    scens, solo_kw = [], []
    for k in range(K):
        nk = n if sizes is None else sizes[k]
        jobs = synthetic_panda_jobs(nk, seed=30 + k, duration=600.0, n_datasets=N_DS)
        av = make_availability(
            n_sites,
            [
                dict(site=k % n_sites, start=100.0 * (k + 1), end=900.0, preempt=True),
                dict(site=(k + 1) % n_sites, start=50.0, end=400.0, factor=0.5),
            ],
        )
        rep = make_replicas(
            zipf_dataset_sizes(N_DS, seed=3 + k, mean_bytes=1e9),
            disk_capacity=np.full(n_sites, 1e12),
            origin=np.zeros(N_DS, np.int32),
        )
        edges = [(j - 1, j) for j in range(1, nk, 2)]
        out_ds = np.where(np.arange(nk) % 2 == 0, np.arange(nk) % N_DS, -1)
        jobs_wf, wf = make_workflow(jobs, edges, out_dataset=out_ds)
        ts = make_transfers(n_sites, jobs_wf.capacity, max_active=1 + k)
        scens.append(
            Scenario(
                jobs_wf,
                sites._replace(speed=sites.speed * (0.8 + 0.2 * k)),
                {"availability": av, "workflow": wf, "data": (net, rep), "transfers": ts},
            )
        )
        solo_kw.append(
            dict(availability=av, workflow=wf, data_policy=dp, network=net,
                 replicas=rep, transfers=ts)
        )
    return scens, subs, solo_kw


def test_quad_subsystem_lanes_equal_solo():
    scens, subs, solo_kw = quad_scenarios()
    pol = get_policy("critical_path_first")
    keys = jax.random.split(jax.random.PRNGKey(4), len(scens))
    res = simulate_many(scens, pol, jax.random.PRNGKey(4), subsystems=subs)
    for i, s in enumerate(scens):
        solo = simulate(s.jobs, s.sites, pol, keys[i], **solo_kw[i])
        assert tree_equal(lane(res, i), solo) == []
        assert int(res.ext["transfers"].n_enq[i]) > 0  # queues actually used


def test_quad_subsystem_sharded_equals_vmapped():
    from repro.core.distributed import simulate_many_sharded

    scens, subs, _ = quad_scenarios()
    pol = get_policy("panda_dispatch")
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    r_v = simulate_many(scens, pol, jax.random.PRNGKey(4), subsystems=subs)
    r_s = simulate_many_sharded(scens, pol, jax.random.PRNGKey(4), mesh, subsystems=subs)
    assert tree_equal(r_s, r_v) == []


def test_ragged_lanes_pad_transfer_state():
    """Ragged lanes exercise the pad_jobs hook; a solo run on the same
    padded ext state is bit-for-bit identical."""
    from repro.core import pad_ext_jobs

    sizes = [36, 52, 44]
    scens, subs, solo_kw = quad_scenarios(sizes=sizes)
    cap = max(sizes)
    pol = get_policy("panda_dispatch")
    keys = jax.random.split(jax.random.PRNGKey(6), len(scens))
    res = simulate_many(scens, pol, jax.random.PRNGKey(6), subsystems=subs)
    i = 0  # the most-padded lane
    ext_p = pad_ext_jobs(subs, scens[i].ext, sizes[i], cap)
    kw = dict(solo_kw[i])
    kw.update(availability=ext_p["availability"], workflow=ext_p["workflow"],
              transfers=ext_p["transfers"])
    solo = simulate(pad_jobs_capacity(scens[i].jobs, cap), scens[i].sites, pol, keys[i], **kw)
    assert tree_equal(lane(res, i), solo) == []
