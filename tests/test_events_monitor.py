"""Event dataset (Table 1) + monitor rendering."""
import io
import json

import jax
import numpy as np

from repro.core import atlas_like_platform, get_policy, simulate, synthetic_panda_jobs
from repro.core.events import (
    iter_frames,
    iter_transitions,
    log_frames,
    ml_dataset,
    stream_rows,
    to_csv,
    to_json,
    transition_rows,
    write_ml_dataset,
)
from repro.core.monitor import frames_json, render_frame, sparkline, utilization_timeline
from repro.core.telemetry import MemorySink


def small_run(log_rows=0):
    jobs = synthetic_panda_jobs(120, seed=0, duration=1200.0)
    sites = atlas_like_platform(5, seed=1)
    return simulate(
        jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0), log_rows=log_rows
    )


def test_transition_rows_table1_schema():
    rows = transition_rows(small_run())
    assert rows, "no events captured"
    expect = {"event_id", "time", "job_id", "state", "site",
              "avail_cores", "pending_jobs", "assigned_jobs", "finished_jobs"}
    assert expect == set(rows[0])
    # three transitions per finished job
    assert len(rows) == 3 * 120


def test_event_stream_is_time_ordered_and_capacity_safe():
    rows = transition_rows(small_run())
    times = [r["time"] for r in rows]
    assert times == sorted(times)
    assert min(r["avail_cores"] for r in rows) >= 0
    assert min(r["pending_jobs"] for r in rows) >= 0
    finished = [r for r in rows if r["state"] in ("finished", "failed")]
    assert len(finished) == 120


def test_csv_json_roundtrip():
    rows = transition_rows(small_run())
    csv_text = to_csv(rows)
    assert csv_text.splitlines()[0].startswith("event_id,")
    assert len(csv_text.splitlines()) == len(rows) + 1
    import json

    assert json.loads(to_json(rows))[0]["event_id"] == rows[0]["event_id"]


def test_ml_dataset_shapes_and_finiteness():
    ds = ml_dataset(small_run())
    n = ds["walltime"].shape[0]
    assert n == 120
    assert ds["features"].shape == (n, len(ds["feature_names"]))
    assert np.isfinite(ds["features"]).all()
    assert (ds["walltime"] > 0).all()
    assert (ds["queue_time"] >= 0).all()


def test_iterators_match_list_forms():
    res = small_run(log_rows=128)
    assert list(iter_transitions(res)) == transition_rows(res)
    assert list(iter_frames(res)) == log_frames(res)


def test_stream_rows_matches_lists_and_tags_types():
    res = small_run(log_rows=64)
    sink = MemorySink()
    n = stream_rows(res, sink, kinds=("transition", "frame", "job"))
    assert n == len(sink.records)
    by_type = {}
    for r in sink.records:
        by_type.setdefault(r.pop("type"), []).append(r)
    assert by_type["transition"] == transition_rows(res)
    assert by_type["frame"] == log_frames(res)
    assert len(by_type["job"]) == 120
    import pytest

    with pytest.raises(ValueError):
        stream_rows(res, sink, kinds=("nope",))


def test_streamed_ml_dataset_byte_identical():
    """ISSUE 6 acceptance: chunked export emits the exact bytes of the
    in-memory dataset at any segment size (peak memory per segment)."""
    res = small_run()
    ds = ml_dataset(res)
    bufs = {}
    for seg in (0, 7, 1):
        buf = io.StringIO()
        n = write_ml_dataset(res, buf, segment=seg)
        assert n == ds["walltime"].shape[0]
        bufs[seg] = buf.getvalue()
    assert bufs[0] == bufs[7] == bufs[1]
    lines = bufs[0].splitlines()
    head = json.loads(lines[0])
    assert head["type"] == "ml_header"
    assert head["feature_names"] == list(ds["feature_names"])
    # row values round-trip exactly against the in-memory matrices
    row0 = json.loads(lines[1])
    np.testing.assert_array_equal(
        np.asarray(row0["features"], np.float32), ds["features"][0]
    )
    assert np.float32(row0["walltime"]) == ds["walltime"][0]


def test_write_ml_dataset_to_path(tmp_path):
    res = small_run()
    p = tmp_path / "ml.ndjson"
    n = write_ml_dataset(res, p, segment=11)
    assert len(p.read_text().splitlines()) == n + 1  # header + rows


def test_render_frame_schema_snapshot():
    """The frame dict contract any dashboard consumes (schema snapshot)."""
    res = small_run(log_rows=64)
    frames = log_frames(res)
    core_keys = {
        "round", "time", "counts", "started", "completed",
        "site_free", "site_queued", "site_running",
    }
    assert core_keys <= set(frames[0])
    from repro.core import STATE_NAMES

    assert set(frames[0]["counts"]) == set(STATE_NAMES)
    S = res.sites.capacity
    for col in ("site_free", "site_queued", "site_running"):
        assert len(frames[0][col]) == S
    txt = render_frame(frames[-1], np.asarray(res.sites.cores), max_sites=3)
    assert txt.splitlines()[0].startswith("t=")


def test_frames_json_schema_snapshot():
    res = small_run(log_rows=512)  # larger than the round count: no ring wrap
    payload = json.loads(frames_json(res))
    assert isinstance(payload, list) and payload
    assert payload == log_frames(res)
    rounds = [f["round"] for f in payload]
    assert rounds == sorted(rounds)


def test_log_frames_and_monitor():
    res = small_run(log_rows=128)
    frames = log_frames(res)
    assert frames
    txt = render_frame(frames[-1], np.asarray(res.sites.cores))
    assert "t=" in txt and "cores" in txt
    tl = utilization_timeline(res)
    assert tl.shape[1] == res.sites.capacity
    assert (tl >= 0).all() and (tl <= 1.0 + 1e-6).all()
    assert isinstance(frames_json(res), str)
    assert sparkline(tl.mean(axis=1))
