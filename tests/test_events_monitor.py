"""Event dataset (Table 1) + monitor rendering."""
import jax
import numpy as np

from repro.core import atlas_like_platform, get_policy, simulate, synthetic_panda_jobs
from repro.core.events import log_frames, ml_dataset, to_csv, to_json, transition_rows
from repro.core.monitor import frames_json, render_frame, sparkline, utilization_timeline


def small_run(log_rows=0):
    jobs = synthetic_panda_jobs(120, seed=0, duration=1200.0)
    sites = atlas_like_platform(5, seed=1)
    return simulate(
        jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0), log_rows=log_rows
    )


def test_transition_rows_table1_schema():
    rows = transition_rows(small_run())
    assert rows, "no events captured"
    expect = {"event_id", "time", "job_id", "state", "site",
              "avail_cores", "pending_jobs", "assigned_jobs", "finished_jobs"}
    assert expect == set(rows[0])
    # three transitions per finished job
    assert len(rows) == 3 * 120


def test_event_stream_is_time_ordered_and_capacity_safe():
    rows = transition_rows(small_run())
    times = [r["time"] for r in rows]
    assert times == sorted(times)
    assert min(r["avail_cores"] for r in rows) >= 0
    assert min(r["pending_jobs"] for r in rows) >= 0
    finished = [r for r in rows if r["state"] in ("finished", "failed")]
    assert len(finished) == 120


def test_csv_json_roundtrip():
    rows = transition_rows(small_run())
    csv_text = to_csv(rows)
    assert csv_text.splitlines()[0].startswith("event_id,")
    assert len(csv_text.splitlines()) == len(rows) + 1
    import json

    assert json.loads(to_json(rows))[0]["event_id"] == rows[0]["event_id"]


def test_ml_dataset_shapes_and_finiteness():
    ds = ml_dataset(small_run())
    n = ds["walltime"].shape[0]
    assert n == 120
    assert ds["features"].shape == (n, len(ds["feature_names"]))
    assert np.isfinite(ds["features"]).all()
    assert (ds["walltime"] > 0).all()
    assert (ds["queue_time"] >= 0).all()


def test_log_frames_and_monitor():
    res = small_run(log_rows=128)
    frames = log_frames(res)
    assert frames
    txt = render_frame(frames[-1], np.asarray(res.sites.cores))
    assert "t=" in txt and "cores" in txt
    tl = utilization_timeline(res)
    assert tl.shape[1] == res.sites.capacity
    assert (tl >= 0).all() and (tl <= 1.0 + 1e-6).all()
    assert isinstance(frames_json(res), str)
    assert sparkline(tl.mean(axis=1))
