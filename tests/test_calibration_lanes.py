"""ISSUE 7 equivalence harness: the lane-batched population objective must
equal a Python loop of solo ``engine_platform_objective`` calls per candidate
— including with availability + data subsystems attached — while the whole
population runs as ONE compiled program (no per-candidate recompiles)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.availability import make_availability
from repro.core.calibration import (
    PlatformParams,
    decode_params,
    engine_platform_objective,
    make_population_objective,
    make_synthetic_platform_problem,
    pinned_policy,
    platform_params,
    ravel_params,
    encode_params,
)
from repro.core.engine import _simulate


def _candidates(be, n, seed=0, scale=0.3):
    """n log-space candidates around the starting point."""
    noise = jax.random.normal(jax.random.PRNGKey(seed), (n, be.z0.shape[0]))
    return be.z0[None, :] + scale * noise


def _solo_losses(problem, be, zs, rng, *, loss="mape", max_rounds=6000):
    """Reference: candidate-at-a-time engine runs with the lane RNG keys
    (``simulate_many`` gives lane i ``split(rng, K)[i]``)."""
    policy = pinned_policy(problem.hist_site)  # shared: keep the loop warm
    keys = jax.random.split(rng, zs.shape[0])
    return np.array(
        [
            float(
                engine_platform_objective(
                    problem,
                    decode_params(be.unravel(z), be.bounds),
                    keys[i],
                    loss=loss,
                    max_rounds=max_rounds,
                    policy=policy,
                )
            )
            for i, z in enumerate(zs)
        ]
    )


def test_lane_batched_equals_solo_loop_plain():
    """Population lanes == solo loop, plain engine (no subsystems)."""
    problem, _ = make_synthetic_platform_problem(
        n_jobs=40, n_sites=3, seed=0, trace="engine", wan_frac=0.0,
        include=("speed", "overhead"),
    )
    assert problem.data_policy is None
    be = make_population_objective(
        problem, objective="engine", include=("speed", "overhead"), max_rounds=6000
    )
    zs = _candidates(be, 4)
    rng = jax.random.PRNGKey(7)
    lane = np.asarray(be(zs, rng))
    solo = _solo_losses(problem, be, zs, rng)
    np.testing.assert_allclose(lane, solo, rtol=1e-5, atol=1e-6)


def test_lane_batched_equals_solo_loop_with_avail_and_data():
    """Population lanes == solo loop with availability + data subsystems on
    (the full ext pipeline: outage calendars broadcast per lane, per-lane
    candidate WAN matrices in the data slot)."""
    problem, _ = make_synthetic_platform_problem(
        n_jobs=40, n_sites=3, seed=1, trace="engine", wan_frac=0.5
    )
    assert problem.data_policy is not None
    windows = [
        dict(site=0, start=50.0, end=400.0, factor=0.0, preempt=True),
        dict(site=1, start=200.0, end=900.0, factor=0.5, preempt=False),
    ]
    problem = problem._replace(availability=make_availability(3, windows))
    be = make_population_objective(problem, objective="engine", max_rounds=6000)
    zs = _candidates(be, 3, seed=5)
    rng = jax.random.PRNGKey(11)
    lane = np.asarray(be(zs, rng))
    solo = _solo_losses(problem, be, zs, rng)
    np.testing.assert_allclose(lane, solo, rtol=1e-5, atol=1e-6)


def test_population_compiles_once_per_shape():
    """ISSUE 7 acceptance: the whole population is one compiled program —
    fresh candidate values never retrace (trace-count + jit cache check)."""
    problem, _ = make_synthetic_platform_problem(
        n_jobs=32, n_sites=3, seed=2, trace="engine", wan_frac=0.5
    )
    be = make_population_objective(problem, objective="engine", max_rounds=6000)
    zs = _candidates(be, 5, seed=1)
    be(zs, jax.random.PRNGKey(0))
    assert be.trace_count() == 1
    cache = getattr(_simulate, "_cache_size", None)
    n0 = cache() if cache is not None else None
    # new candidate values + new rng: same program, zero new traces
    be(zs + 0.2, jax.random.PRNGKey(1))
    be(zs * 0.9 - 0.1, jax.random.PRNGKey(2))
    assert be.trace_count() == 1
    if cache is not None:
        assert cache() == n0
    # a different population size is a new shape -> exactly one more trace
    be(zs[:2], jax.random.PRNGKey(3))
    assert be.trace_count() == 2


def test_sharded_lanes_match_solo_loop():
    """The mesh path (``simulate_many_sharded``) scores lanes identically."""
    problem, _ = make_synthetic_platform_problem(
        n_jobs=32, n_sites=3, seed=3, trace="engine", wan_frac=0.5
    )
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    be = make_population_objective(
        problem, objective="engine", mesh=mesh, max_rounds=6000
    )
    zs = _candidates(be, 4, seed=9)
    rng = jax.random.PRNGKey(13)
    lane = np.asarray(be(zs, rng))
    solo = _solo_losses(problem, be, zs, rng)
    np.testing.assert_allclose(lane, solo, rtol=1e-5, atol=1e-6)
    be(zs + 0.1, jax.random.PRNGKey(1))
    assert be.trace_count() == 1


def test_closed_form_population_matches_scalar_objective():
    """The vmapped closed-form population equals per-candidate scalar calls
    (and is where ``jax.grad`` fits plug in)."""
    from repro.core.calibration import platform_objective

    problem, _ = make_synthetic_platform_problem(
        n_jobs=48, n_sites=4, seed=4, trace="closed_form", wan_frac=0.5
    )
    be = make_population_objective(problem, objective="closed_form")
    zs = _candidates(be, 6, seed=2)
    lane = np.asarray(be(zs))
    solo = np.array(
        [
            float(
                platform_objective(
                    problem, decode_params(be.unravel(z), be.bounds), loss="mape"
                )
            )
            for z in zs
        ]
    )
    np.testing.assert_allclose(lane, solo, rtol=1e-6, atol=1e-7)


def test_quantile_loss_lane_equivalence():
    problem, _ = make_synthetic_platform_problem(
        n_jobs=40, n_sites=3, seed=6, trace="engine", wan_frac=0.5
    )
    be = make_population_objective(
        problem, objective="engine", loss="quantile", max_rounds=6000
    )
    zs = _candidates(be, 3, seed=3)
    rng = jax.random.PRNGKey(17)
    lane = np.asarray(be(zs, rng))
    solo = _solo_losses(problem, be, zs, rng, loss="quantile")
    np.testing.assert_allclose(lane, solo, rtol=1e-5, atol=1e-6)
