"""Sparse top-k scoring (engine ``topk=``, DESIGN.md §12).

Exactness contract under test:

- ``topk=None`` is the dense path — untouched, covered by the golden tests.
- ``topk=k`` with ``k >= S`` must be *bit-for-bit* equal to dense, across
  every subsystem combination of the golden matrix scenario: the candidate
  index then enumerates all statically feasible sites in dense scan order.
- ``k < S`` is a documented approximation, gated here by a ≤1% makespan
  drift on a WLCG-shaped scenario and by the membership property that the
  candidate set always contains the dense pre-rank argmax when any site is
  feasible (hypothesis-tested).
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Scenario,
    atlas_like_platform,
    build_candidates,
    bytes_per_round,
    get_policy,
    simulate,
    static_feasibility,
    synthetic_panda_jobs,
)
from repro.core.engine import (
    _packed_order_ok,
    _start_order,
    _start_order_packed,
    _static_start_rank,
)

from test_golden_trace import combo_kwargs, matrix_scenario


def assert_trees_equal(a, b):
    """Bitwise pytree equality, NaN-aware (NaN == NaN in padded float rows)."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.shape == y.shape and x.dtype == y.dtype
        assert np.array_equal(x, y, equal_nan=np.issubdtype(x.dtype, np.floating))


def test_topk_full_k_bitwise_equals_dense_all_matrix_combos():
    """topk(k=S) ≡ dense per-round across the 8 golden-matrix combos (plus
    per-round log rows, so any intermediate divergence is visible too)."""
    scn = matrix_scenario()
    pol = get_policy("panda_dispatch")
    key = jax.random.PRNGKey(0)
    S = scn["sites"].capacity
    for data, avail, wf in itertools.product((False, True), repeat=3):
        jobs, kw = combo_kwargs(scn, data, avail, wf)
        dense = simulate(jobs, scn["sites"], pol, key, log_rows=64, **kw)
        sparse = simulate(jobs, scn["sites"], pol, key, log_rows=64, topk=S, **kw)
        assert_trees_equal(dense, sparse)


def test_topk_full_k_bitwise_equals_dense_with_refresh():
    """Rebuilding the (already-complete) candidate index mid-run must not
    perturb anything: the refresh path only recomputes, never re-draws."""
    jobs = synthetic_panda_jobs(60, seed=11, duration=900.0)
    sites = atlas_like_platform(4, seed=12, fail_rate=0.05)
    pol = get_policy("panda_dispatch")
    key = jax.random.PRNGKey(0)
    dense = simulate(jobs, sites, pol, key)
    sparse = simulate(jobs, sites, pol, key, topk=sites.capacity, topk_refresh=7)
    assert_trees_equal(dense, sparse)


def test_topk_small_k_makespan_drift_under_1pct():
    """The k<S approximation acceptance gate: a WLCG-shaped scenario (many
    jobs racing for few sites, locality-driven policy) must land within 1%
    of the dense makespan at k = S/3."""
    jobs = synthetic_panda_jobs(400, seed=0, duration=3600.0)
    sites = atlas_like_platform(24, seed=1)
    pol = get_policy("data_locality")
    key = jax.random.PRNGKey(0)
    dense = simulate(jobs, sites, pol, key)
    sparse = simulate(jobs, sites, pol, key, topk=8)
    drift = abs(float(sparse.makespan) - float(dense.makespan))
    assert drift <= 0.01 * float(dense.makespan)


def test_sharded_ensemble_accepts_topk_with_ragged_lanes():
    """simulate_many_sharded(topk=) — ragged lane sizes through the sparse
    path, bit-for-bit equal per lane to solo sparse runs."""
    from jax.sharding import Mesh

    from repro.core import pad_jobs_capacity
    from repro.core.distributed import simulate_many_sharded

    sites = atlas_like_platform(4, seed=1)
    pol = get_policy("panda_dispatch")
    sizes = [24, 17, 31]
    cap = max(sizes)
    scens = [
        Scenario(
            pad_jobs_capacity(synthetic_panda_jobs(n, seed=30 + i, duration=600.0), cap),
            sites._replace(speed=sites.speed * (0.9 + 0.05 * i)),
        )
        for i, n in enumerate(sizes)
    ]
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    rs = simulate_many_sharded(scens, pol, jax.random.PRNGKey(5), mesh, topk=4)
    keys = jax.random.split(jax.random.PRNGKey(5), len(scens))
    for i, s in enumerate(scens):
        solo = simulate(s.jobs, s.sites, pol, keys[i], topk=4)
        assert float(solo.makespan) == float(np.asarray(rs.makespan)[i])
        assert (np.asarray(solo.jobs.state) == np.asarray(rs.jobs.state)[i]).all()


def check_candidates_contain_dense_argmax(seed: int, k: int, policy: str):
    """Membership guarantee behind the k<S gate: whenever a job has any
    feasible site, the candidate row contains the dense pre-rank argmax.
    Shared with the hypothesis-driven property in test_properties.py."""
    jobs = synthetic_panda_jobs(20, seed=seed, duration=600.0)
    sites = atlas_like_platform(6, seed=seed + 1)
    pol = get_policy(policy)
    key = jax.random.PRNGKey(seed)
    S = sites.capacity
    cand = np.asarray(build_candidates(jobs, sites, pol, None, 0.0, key, {}, k))
    feas = np.asarray(static_feasibility(jobs, sites))
    pre_fn = getattr(pol, "pre_rank", None) or pol.score
    masked = np.where(feas, np.asarray(pre_fn(jobs, sites, None, 0.0, key)), -np.inf)
    best = masked.argmax(-1)
    any_feas = feas.any(-1)
    # rows sorted ascending, sentinel S pads the tail
    assert (np.sort(cand, -1) == cand).all()
    in_range = np.clip(cand, 0, S - 1)
    assert ((cand == S) | feas[np.arange(len(cand))[:, None], in_range]).all()
    assert (cand[any_feas] == best[any_feas, None]).any(-1).all()


@pytest.mark.parametrize("policy", ["data_locality", "fastest_site", "least_loaded"])
@pytest.mark.parametrize("k", [1, 3, 6])
def test_candidates_always_contain_dense_argmax(policy, k):
    for seed in (0, 7, 123):
        check_candidates_contain_dense_argmax(seed, k, policy)


def test_packed_start_order_matches_lexsort():
    """The packed single-key start order (engine fast path) must reproduce
    the 5-key lexsort permutation exactly, solo and under vmap."""
    jobs = synthetic_panda_jobs(50, seed=3, duration=600.0)
    J, S = jobs.capacity, 5
    assert _packed_order_ok(get_policy("panda_dispatch"), J, S)
    srank = _static_start_rank(jobs)
    key = jax.random.PRNGKey(0)
    zeros = jnp.zeros((J,), jnp.float32)
    for i in range(4):
        sort_site = jax.random.randint(jax.random.fold_in(key, i), (J,), 0, S + 1)
        ref = _start_order(sort_site.astype(jnp.int32), jobs.priority, zeros, jobs.arrival)
        packed = _start_order_packed(sort_site.astype(jnp.int32) * J + srank)
        assert (np.asarray(ref) == np.asarray(packed)).all()
    # batched (ensemble) path: custom_vmap batch rule agrees with per-lane solo
    sort_b = jax.random.randint(key, (3, J), 0, S + 1).astype(jnp.int32)
    batched = jax.vmap(lambda ss: _start_order_packed(ss * J + srank))(sort_b)
    for lane in range(3):
        solo = _start_order_packed(sort_b[lane] * J + srank)
        assert (np.asarray(batched[lane]) == np.asarray(solo)).all()


def test_rank_policy_disables_packed_order():
    """Policies with a dynamic rank hook must keep the general lexsort."""
    pol = get_policy("critical_path_first")
    if getattr(pol, "rank", None) is not None:
        assert not _packed_order_ok(pol, 100, 4)
    # key-width overflow also disables the fast path
    assert not _packed_order_ok(get_policy("panda_dispatch"), 2**28, 300)


def test_bytes_per_round_model():
    m = bytes_per_round(100_000, 300, 16)
    assert m["dense"] == 100_000 * 300 * 9
    assert m["sparse"] == 100_000 * 16 * 9 + 300
    assert m["ratio"] > 18
    assert bytes_per_round(10, 4, None)["sparse"] is None
