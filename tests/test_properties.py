"""Hypothesis property tests on simulator invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the 'dev' extra")
from hypothesis import given, settings, strategies as st

from repro.core import DONE, FAILED, get_policy, make_jobs, make_sites, simulate
from repro.core.events import transition_rows

POLICIES = ["random", "round_robin", "least_loaded", "shortest_wait", "panda_dispatch"]


def build(n_jobs, n_sites, seed, multicore_frac, policy):
    rng = np.random.default_rng(seed)
    cores = np.where(rng.random(n_jobs) < multicore_frac, 8, 1)
    jobs = make_jobs(
        job_id=np.arange(n_jobs),
        arrival=np.sort(rng.uniform(0, 100.0, n_jobs)),
        work=rng.lognormal(np.log(500.0), 1.0, n_jobs),
        cores=cores,
        memory=np.where(cores > 1, 16.0, 2.0),
        bytes_in=rng.lognormal(np.log(1e8), 1.0, n_jobs),
        bytes_out=rng.lognormal(np.log(1e7), 1.0, n_jobs),
    )
    sites = make_sites(
        cores=rng.integers(8, 64, n_sites),
        speed=rng.uniform(1.0, 30.0, n_sites),
        memory=rng.uniform(64.0, 512.0, n_sites),
        bw_in=rng.uniform(1e8, 1e10, n_sites),
        bw_out=rng.uniform(1e8, 1e10, n_sites),
    )
    return simulate(jobs, sites, get_policy(policy), jax.random.PRNGKey(seed))


@settings(max_examples=12, deadline=None)
@given(
    n_jobs=st.integers(5, 80),
    n_sites=st.integers(1, 8),
    seed=st.integers(0, 2**16),
    multicore_frac=st.floats(0.0, 1.0),
    policy=st.sampled_from(POLICIES),
)
def test_conservation_and_timestamps(n_jobs, n_sites, seed, multicore_frac, policy):
    res = build(n_jobs, n_sites, seed, multicore_frac, policy)
    jobs = res.jobs
    valid = np.asarray(jobs.valid)
    state = np.asarray(jobs.state)[valid]
    # conservation: every valid job terminates (sites are always feasible here)
    assert np.isin(state, [DONE, FAILED]).all()
    # timestamp ordering: arrival <= assign <= start <= finish
    a = np.asarray(jobs.arrival)[valid]
    g = np.asarray(jobs.t_assign)[valid]
    s = np.asarray(jobs.t_start)[valid]
    f = np.asarray(jobs.t_finish)[valid]
    assert (a <= g + 1e-5).all()
    assert (g <= s + 1e-5).all()
    assert (s < f).all()


@settings(max_examples=8, deadline=None)
@given(
    n_jobs=st.integers(10, 60),
    n_sites=st.integers(1, 6),
    seed=st.integers(0, 2**16),
    policy=st.sampled_from(POLICIES),
)
def test_capacity_never_exceeded(n_jobs, n_sites, seed, policy):
    res = build(n_jobs, n_sites, seed, 0.5, policy)
    # replaying the transition stream keeps available cores non-negative
    rows = transition_rows(res)
    assert min((r["avail_cores"] for r in rows), default=0) >= 0


@settings(max_examples=8, deadline=None)
@given(n_jobs=st.integers(5, 40), seed=st.integers(0, 2**16))
def test_single_core_fifo_order(n_jobs, seed):
    """Equal-priority single-core jobs on one site start in arrival order."""
    rng = np.random.default_rng(seed)
    jobs = make_jobs(
        job_id=np.arange(n_jobs),
        arrival=np.sort(rng.uniform(0, 10.0, n_jobs)),
        work=rng.uniform(10.0, 100.0, n_jobs),
        cores=np.ones(n_jobs),
        memory=np.ones(n_jobs),
        bytes_in=np.zeros(n_jobs),
        bytes_out=np.zeros(n_jobs),
    )
    sites = make_sites(cores=[2], speed=[10.0], memory=[1e6], bw_in=[1e12], bw_out=[1e12])
    res = simulate(jobs, sites, get_policy("fastest_site"), jax.random.PRNGKey(0))
    starts = np.asarray(res.jobs.t_start)[:n_jobs]
    # arrival order == start order (ties broken by id which follows arrival)
    assert (np.diff(starts) >= -1e-5).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), frac=st.floats(0.05, 0.95))
def test_determinism_same_key(seed, frac):
    r1 = build(30, 3, seed, frac, "panda_dispatch")
    r2 = build(30, 3, seed, frac, "panda_dispatch")
    np.testing.assert_array_equal(np.asarray(r1.jobs.t_start), np.asarray(r2.jobs.t_start))
    assert float(r1.makespan) == float(r2.makespan)
