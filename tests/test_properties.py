"""Hypothesis property tests on simulator invariants.

The conservation-law harness at the bottom is the engine-invariant contract
(ISSUE 2): for random workloads and scenarios — with and without availability
calendars and data policies — every valid job terminates, site resources
return to their initial values, storage stays within capacity, and the
per-site counters exactly account for every attempt.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the 'dev' extra")
from hypothesis import given, settings, strategies as st

from repro.core import (
    CANCELLED,
    DONE,
    FAILED,
    catalog_invariants,
    get_data_policy,
    get_policy,
    make_availability,
    make_jobs,
    make_replicas,
    make_sites,
    make_transfers,
    make_workflow,
    simulate,
    uniform_network,
    zipf_dataset_sizes,
)
from repro.core.events import transition_rows
from repro.core.monitor import link_occupancy_timeline

POLICIES = ["random", "round_robin", "least_loaded", "shortest_wait", "panda_dispatch"]


def build(n_jobs, n_sites, seed, multicore_frac, policy):
    rng = np.random.default_rng(seed)
    cores = np.where(rng.random(n_jobs) < multicore_frac, 8, 1)
    jobs = make_jobs(
        job_id=np.arange(n_jobs),
        arrival=np.sort(rng.uniform(0, 100.0, n_jobs)),
        work=rng.lognormal(np.log(500.0), 1.0, n_jobs),
        cores=cores,
        memory=np.where(cores > 1, 16.0, 2.0),
        bytes_in=rng.lognormal(np.log(1e8), 1.0, n_jobs),
        bytes_out=rng.lognormal(np.log(1e7), 1.0, n_jobs),
    )
    sites = make_sites(
        cores=rng.integers(8, 64, n_sites),
        speed=rng.uniform(1.0, 30.0, n_sites),
        memory=rng.uniform(64.0, 512.0, n_sites),
        bw_in=rng.uniform(1e8, 1e10, n_sites),
        bw_out=rng.uniform(1e8, 1e10, n_sites),
    )
    return simulate(jobs, sites, get_policy(policy), jax.random.PRNGKey(seed))


@settings(max_examples=12, deadline=None)
@given(
    n_jobs=st.integers(5, 80),
    n_sites=st.integers(1, 8),
    seed=st.integers(0, 2**16),
    multicore_frac=st.floats(0.0, 1.0),
    policy=st.sampled_from(POLICIES),
)
def test_conservation_and_timestamps(n_jobs, n_sites, seed, multicore_frac, policy):
    res = build(n_jobs, n_sites, seed, multicore_frac, policy)
    jobs = res.jobs
    valid = np.asarray(jobs.valid)
    state = np.asarray(jobs.state)[valid]
    # conservation: every valid job terminates (sites are always feasible here)
    assert np.isin(state, [DONE, FAILED]).all()
    # timestamp ordering: arrival <= assign <= start <= finish
    a = np.asarray(jobs.arrival)[valid]
    g = np.asarray(jobs.t_assign)[valid]
    s = np.asarray(jobs.t_start)[valid]
    f = np.asarray(jobs.t_finish)[valid]
    assert (a <= g + 1e-5).all()
    assert (g <= s + 1e-5).all()
    assert (s < f).all()


@settings(max_examples=8, deadline=None)
@given(
    n_jobs=st.integers(10, 60),
    n_sites=st.integers(1, 6),
    seed=st.integers(0, 2**16),
    policy=st.sampled_from(POLICIES),
)
def test_capacity_never_exceeded(n_jobs, n_sites, seed, policy):
    res = build(n_jobs, n_sites, seed, 0.5, policy)
    # replaying the transition stream keeps available cores non-negative
    rows = transition_rows(res)
    assert min((r["avail_cores"] for r in rows), default=0) >= 0


@settings(max_examples=8, deadline=None)
@given(n_jobs=st.integers(5, 40), seed=st.integers(0, 2**16))
def test_single_core_fifo_order(n_jobs, seed):
    """Equal-priority single-core jobs on one site start in arrival order."""
    rng = np.random.default_rng(seed)
    jobs = make_jobs(
        job_id=np.arange(n_jobs),
        arrival=np.sort(rng.uniform(0, 10.0, n_jobs)),
        work=rng.uniform(10.0, 100.0, n_jobs),
        cores=np.ones(n_jobs),
        memory=np.ones(n_jobs),
        bytes_in=np.zeros(n_jobs),
        bytes_out=np.zeros(n_jobs),
    )
    sites = make_sites(cores=[2], speed=[10.0], memory=[1e6], bw_in=[1e12], bw_out=[1e12])
    res = simulate(jobs, sites, get_policy("fastest_site"), jax.random.PRNGKey(0))
    starts = np.asarray(res.jobs.t_start)[:n_jobs]
    # arrival order == start order (ties broken by id which follows arrival)
    assert (np.diff(starts) >= -1e-5).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), frac=st.floats(0.05, 0.95))
def test_determinism_same_key(seed, frac):
    r1 = build(30, 3, seed, frac, "panda_dispatch")
    r2 = build(30, 3, seed, frac, "panda_dispatch")
    np.testing.assert_array_equal(np.asarray(r1.jobs.t_start), np.asarray(r2.jobs.t_start))
    assert float(r1.makespan) == float(r2.makespan)


# --------------------------------------------------------------------------
# engine-invariant conservation laws (ISSUE 2 harness)
# --------------------------------------------------------------------------

N_SITES = 4  # fixed shape: hypothesis varies values, not compile shapes


def build_scenario(n_jobs, seed, policy, *, fail_rate, with_avail, with_data,
                   with_transfers=False, max_active=2, **sim_kw):
    """Random-but-terminating scenario: sites always feasible, every outage
    window finite, so each valid job must end DONE or FAILED."""
    rng = np.random.default_rng(seed)
    cores = np.where(rng.random(n_jobs) < 0.4, 8, 1)
    jobs = make_jobs(
        job_id=np.arange(n_jobs),
        arrival=np.sort(rng.uniform(0, 100.0, n_jobs)),
        work=rng.lognormal(np.log(400.0), 1.0, n_jobs),
        cores=cores,
        memory=np.where(cores > 1, 16.0, 2.0),
        bytes_in=rng.lognormal(np.log(1e8), 1.0, n_jobs),
        bytes_out=rng.lognormal(np.log(1e7), 1.0, n_jobs),
        dataset=rng.integers(0, 8, n_jobs) if with_data else None,
        capacity=n_jobs + 3,  # padding rows must stay inert
    )
    sites = make_sites(
        cores=rng.integers(8, 48, N_SITES),
        speed=rng.uniform(2.0, 20.0, N_SITES),
        memory=rng.uniform(64.0, 256.0, N_SITES),
        bw_in=rng.uniform(1e8, 1e10, N_SITES),
        bw_out=rng.uniform(1e8, 1e10, N_SITES),
        fail_rate=np.full(N_SITES, fail_rate),
    )
    kw = {}
    if with_avail:
        windows = []
        for s in range(N_SITES - 1):  # keep one site clean so work always drains
            for _ in range(int(rng.integers(0, 3))):
                t0 = float(rng.uniform(0.0, 400.0))
                windows.append(
                    dict(
                        site=s,
                        start=t0,
                        end=t0 + float(rng.uniform(20.0, 300.0)),
                        factor=float(rng.choice([0.0, 0.0, 0.5])),
                        preempt=bool(rng.random() < 0.7),
                    )
                )
        kw["availability"] = make_availability(N_SITES, windows)
    if with_data:
        kw["data_policy"] = get_data_policy("cache_on_read")
        kw["network"] = uniform_network(N_SITES, bw=1e9, latency=0.01)
        # site 0 is the data lake holding every origin; the rest run tight
        # caches (~2 datasets) so insertion/eviction churns under load
        kw["replicas"] = make_replicas(
            zipf_dataset_sizes(8, seed=seed % 1000, mean_bytes=1e9),
            disk_capacity=np.array([1e12] + [2.5e9] * (N_SITES - 1)),
            origin=np.zeros(8, np.int32),
        )
    if with_transfers:
        kw["transfers"] = make_transfers(N_SITES, n_jobs + 3, max_active=max_active)
    res = simulate(jobs, sites, get_policy(policy), jax.random.PRNGKey(seed), **kw, **sim_kw)
    return res, jobs, sites, kw


def assert_conservation_laws(res, jobs0, sites0):
    valid = np.asarray(res.jobs.valid)
    state = np.asarray(res.jobs.state)[valid]
    # 1. termination: every valid job ends DONE or FAILED
    assert np.isin(state, [DONE, FAILED]).all()
    # padding rows never move
    assert (np.asarray(res.jobs.state)[~valid] == DONE).all()
    assert not np.isfinite(np.asarray(res.jobs.t_start)[~valid]).any()
    # 2. resources return to initial values
    np.testing.assert_array_equal(
        np.asarray(res.sites.free_cores), np.asarray(sites0.cores)
    )
    np.testing.assert_allclose(
        np.asarray(res.sites.free_memory), np.asarray(sites0.memory), rtol=1e-4, atol=1e-2
    )
    # 3. per-site counters account for every attempt exactly:
    #    finishes == DONE jobs; every unsuccessful attempt is a machine
    #    failure or a preemption; each one is a resubmission or terminal
    n_done = int((state == DONE).sum())
    n_term_failed = int((state == FAILED).sum())
    retries = int(np.asarray(res.jobs.retries)[valid].sum())
    n_pre = int(np.asarray(res.avail.n_preempted).sum()) if res.avail is not None else 0
    assert int(np.asarray(res.sites.n_finished).sum()) == n_done
    assert int(np.asarray(res.sites.n_failed).sum()) + n_pre == retries + n_term_failed
    if res.avail is not None:
        assert n_pre == int(np.asarray(res.jobs.preempted)[valid].sum())
    # 4. storage never exceeds capacity
    if res.replicas is not None:
        inv = catalog_invariants(res.replicas)
        assert inv["capacity_ok"] and inv["accounting_ok"] and inv["origins_ok"]
    # 5. timestamps stay ordered for every terminal job
    a = np.asarray(res.jobs.arrival)[valid]
    s = np.asarray(res.jobs.t_start)[valid]
    f = np.asarray(res.jobs.t_finish)[valid]
    assert (a <= s + 1e-5).all() and (s < f).all()


@settings(max_examples=8, deadline=None)
@given(
    n_jobs=st.integers(10, 60),
    seed=st.integers(0, 2**16),
    fail_rate=st.sampled_from([0.0, 0.3]),
    policy=st.sampled_from(POLICIES),
)
def test_conservation_laws_plain(n_jobs, seed, fail_rate, policy):
    res, jobs0, sites0, _ = build_scenario(
        n_jobs, seed, policy, fail_rate=fail_rate, with_avail=False, with_data=False
    )
    assert_conservation_laws(res, jobs0, sites0)


@settings(max_examples=8, deadline=None)
@given(
    n_jobs=st.integers(10, 60),
    seed=st.integers(0, 2**16),
    fail_rate=st.sampled_from([0.0, 0.2]),
    policy=st.sampled_from(["round_robin", "least_loaded", "panda_dispatch"]),
)
def test_conservation_laws_with_availability(n_jobs, seed, fail_rate, policy):
    res, jobs0, sites0, _ = build_scenario(
        n_jobs, seed, policy, fail_rate=fail_rate, with_avail=True, with_data=False
    )
    assert_conservation_laws(res, jobs0, sites0)


@settings(max_examples=6, deadline=None)
@given(
    n_jobs=st.integers(10, 48),
    seed=st.integers(0, 2**16),
    with_avail=st.booleans(),
)
def test_conservation_laws_with_data_policy(n_jobs, seed, with_avail):
    res, jobs0, sites0, _ = build_scenario(
        n_jobs, seed, "round_robin", fail_rate=0.1, with_avail=with_avail, with_data=True
    )
    assert_conservation_laws(res, jobs0, sites0)


_XFER_LOG_ROWS = 4096  # plenty: rounds ~ O(jobs * retries), far below this


@settings(max_examples=6, deadline=None)
@given(
    n_jobs=st.integers(10, 48),
    seed=st.integers(0, 2**16),
    cap=st.integers(1, 4),
    fail_rate=st.sampled_from([0.0, 0.2]),
    with_avail=st.booleans(),
)
def test_transfer_conservation_laws(n_jobs, seed, cap, fail_rate, with_avail):
    """Transfer-queue invariants (ISSUE 8): every enqueue is accounted as a
    completion or a cancellation (in flows and in bytes), the overflow valve
    never fires at default ring sizing, queues fully drain by termination,
    and per-link occupancy never exceeds the cap at any logged round."""
    res, jobs0, sites0, kw = build_scenario(
        n_jobs, seed, "least_loaded", fail_rate=fail_rate,
        with_avail=with_avail, with_data=True, with_transfers=True,
        max_active=cap, log_rows=_XFER_LOG_ROWS,
    )
    assert_conservation_laws(res, jobs0, sites0)

    ts = res.ext["transfers"]
    n_enq = int(ts.n_enq)
    n_done = int(ts.n_done)
    n_cancel = int(ts.n_cancel)
    # flow accounting: enqueues == completions + cancellations, no overflow
    assert n_enq == n_done + n_cancel
    assert int(ts.n_overflow) == 0
    np.testing.assert_allclose(
        float(ts.bytes_enq), float(ts.bytes_done) + float(ts.bytes_cancel), rtol=1e-4
    )
    # without failures or outages nothing ever interrupts a staging job
    if fail_rate == 0.0 and not with_avail:
        assert n_cancel == 0
    # queues drain: no transfer left queued or active, all slots released
    assert (np.asarray(ts.stat) == 0).all()
    assert (np.asarray(ts.active) == 0).all()
    assert (np.asarray(ts.qlen) == 0).all()
    # per-link occupancy respects the cap at every logged round; the log
    # ring did not wrap, so this covers the whole run
    assert int(np.asarray(res.log.cursor)) <= _XFER_LOG_ROWS
    occ = link_occupancy_timeline(res)
    caps = np.asarray(ts.cap, dtype=np.float64).reshape(N_SITES, N_SITES)
    assert (occ <= caps[None, :, :] + 1e-9).all()
    # DONE jobs that actually moved bytes carry a finite, non-negative wait
    valid = np.asarray(res.jobs.valid)
    moved = valid & (np.asarray(res.jobs.state) == DONE) & (
        np.asarray(res.jobs.xfer_bytes) > 0
    )
    waits = np.asarray(res.jobs.xfer_wait)[moved]
    assert np.isfinite(waits).all() and (waits >= 0.0).all()


# --------------------------------------------------------------------------
# fault-injection conservation laws (ISSUE 10): the attempt ledger extended
# by walltime kills, the transfer ledger extended by injected failures, and
# every backed-off job still terminating
# --------------------------------------------------------------------------
from repro.core import make_faults  # noqa: E402


def assert_fault_laws(res, jobs0, sites0):
    """The ISSUE-2 laws restated for runs with the faults subsystem on:
    walltime kills join preemptions on the unsuccessful-attempt side, and
    injected transfer failures join the FTS ledger."""
    valid = np.asarray(res.jobs.valid)
    state = np.asarray(res.jobs.state)[valid]
    fs = res.ext["faults"]
    # 1. termination — backed-off and killed jobs still drain
    assert np.isin(state, [DONE, FAILED]).all()
    assert (np.asarray(res.jobs.state)[~valid] == DONE).all()
    # 2. resources restored
    np.testing.assert_array_equal(
        np.asarray(res.sites.free_cores), np.asarray(sites0.cores)
    )
    np.testing.assert_allclose(
        np.asarray(res.sites.free_memory), np.asarray(sites0.memory), rtol=1e-4, atol=1e-2
    )
    # 3. attempt ledger: every unsuccessful attempt is a machine failure, an
    #    outage preemption, or a walltime kill — each a resubmission or
    #    terminal; kills and preemptions share the per-job preempted counter
    n_term_failed = int((state == FAILED).sum())
    retries = int(np.asarray(res.jobs.retries)[valid].sum())
    n_pre = int(np.asarray(res.avail.n_preempted).sum()) if res.avail is not None else 0
    n_kills = int(fs.n_kills)
    assert int((state == DONE).sum()) == int(np.asarray(res.sites.n_finished).sum())
    assert (
        int(np.asarray(res.sites.n_failed).sum()) + n_pre + n_kills
        == retries + n_term_failed
    )
    assert n_pre + n_kills == int(np.asarray(res.jobs.preempted)[valid].sum())
    # 4. transfer ledger extended by injected failures; queues drained and no
    #    backoff retry left pending
    ts = (res.ext or {}).get("transfers")
    if ts is not None:
        assert int(ts.n_enq) == int(ts.n_done) + int(ts.n_cancel) + int(fs.n_xfer_fail)
        assert (np.asarray(ts.stat) == 0).all()
        assert (np.asarray(ts.active) == 0).all()
    assert not np.isfinite(np.asarray(fs.retry_at)).any()
    # 5. loss calendar applied up to the horizon (events after the last
    #    finish never fire — the engine stops with the work); catalog exact
    lt = np.asarray(fs.loss_t)
    assert np.asarray(fs.loss_done)[np.isfinite(lt) & (lt < float(res.makespan))].all()
    if res.replicas is not None:
        inv = catalog_invariants(res.replicas)
        assert inv["capacity_ok"] and inv["accounting_ok"] and inv["origins_ok"]
    # 6. timestamps ordered against the (possibly backoff-pushed) arrival
    a = np.asarray(res.jobs.arrival)[valid]
    s = np.asarray(res.jobs.t_start)[valid]
    f = np.asarray(res.jobs.t_finish)[valid]
    assert (a <= s + 1e-5).all() and (s < f).all()


@settings(max_examples=6, deadline=None)
@given(
    n_jobs=st.integers(10, 40),
    seed=st.integers(0, 2**16),
    link_p=st.sampled_from([0.0, 0.3]),
    job_backoff=st.sampled_from([0.0, 50.0]),
    walltime=st.sampled_from([np.inf, 1500.0]),
    with_blacklist=st.booleans(),
)
def test_fault_conservation_laws(n_jobs, seed, link_p, job_backoff, walltime,
                                 with_blacklist):
    """All five subsystems on (availability + workflow via the DAG-free
    degenerate case is covered elsewhere; here: avail + data + transfers +
    faults) with every fault channel randomly armed."""
    fl = make_faults(
        N_SITES, n_jobs + 3,
        link_fail_p=link_p, xfer_backoff=40.0, max_xfer_attempts=3,
        job_backoff=job_backoff, walltime=float(walltime),
        replica_loss=[(200.0, 1, 1), (600.0, 3, 2)],
        blacklist_threshold=0.7 if with_blacklist else None,
        blacklist_alpha=0.5, blacklist_cooldown=300.0,
    )
    res, jobs0, sites0, _ = build_scenario(
        n_jobs, seed, "least_loaded", fail_rate=0.15,
        with_avail=True, with_data=True, with_transfers=True,
        faults=fl,
    )
    assert_fault_laws(res, jobs0, sites0)


# --------------------------------------------------------------------------
# subsystem-API equivalence (ISSUE 4): the legacy kwargs surface and an
# explicit subsystems=(...) tuple are the same engine, bit for bit
# --------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    n_jobs=st.integers(10, 48),
    seed=st.integers(0, 2**16),
    with_avail=st.booleans(),
    with_data=st.booleans(),
)
def test_kwargs_and_subsystems_tuple_identical(n_jobs, seed, with_avail, with_data):
    """Running the same seed through ``availability=``/``data_policy=`` kwargs
    and through an explicit ``subsystems=((Subsystem, state0), ...)`` tuple
    must produce identical ``SimResult`` pytrees — same leaves, same
    treedef — so the kwargs surface is provably sugar over the protocol."""
    from repro.core import availability_subsystem, data_subsystem

    res1, jobs, sites, kw = build_scenario(
        n_jobs, seed, "panda_dispatch", fail_rate=0.1,
        with_avail=with_avail, with_data=with_data,
    )
    # attach the exact same state objects explicitly, in canonical order
    pairs = []
    if with_avail:
        pairs.append((availability_subsystem(), kw["availability"]))
    if with_data:
        pairs.append(
            (data_subsystem(kw["data_policy"]), (kw["network"], kw["replicas"]))
        )
    res2 = simulate(
        jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(seed),
        subsystems=tuple(pairs),
    )
    leaves1, tree1 = jax.tree.flatten(res1)
    leaves2, tree2 = jax.tree.flatten(res2)
    assert tree1 == tree2
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=8, deadline=None)
@given(
    n_jobs=st.integers(10, 48),
    seed=st.integers(0, 2**16),
    fail_rate=st.floats(0.0, 0.3),
    with_avail=st.booleans(),
    with_data=st.booleans(),
    policy=st.sampled_from(POLICIES),
)
def test_phase_skip_guard_identical(n_jobs, seed, fail_rate, with_avail, with_data, policy):
    """The phase-skip guard (ISSUE 5) must be invisible: running with the
    guard force-disabled (``phase_skip=False``, the always-execute pipeline)
    and enabled produces identical ``SimResult`` pytrees — the skipped
    assignment/start phases were provably no-ops on the skipped rounds."""
    res1, jobs, sites, kw = build_scenario(
        n_jobs, seed, policy, fail_rate=fail_rate,
        with_avail=with_avail, with_data=with_data,
    )
    res2 = simulate(
        jobs, sites, get_policy(policy), jax.random.PRNGKey(seed),
        phase_skip=False, **kw,
    )
    leaves1, tree1 = jax.tree.flatten(res1)
    leaves2, tree2 = jax.tree.flatten(res2)
    assert tree1 == tree2
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# workflow DAG conservation laws (ISSUE 3): dependency gating, cascade-cancel
# partition, termination
# --------------------------------------------------------------------------


def random_dag_edges(n_jobs, rng, *, p_edge=0.35, max_parents=3):
    """Random DAG over [0, n_jobs): edges only point forward (acyclic by
    construction), bounded in-degree so the parent matrix stays small."""
    edges = []
    n_par = np.zeros(n_jobs, np.int64)
    for c in range(1, n_jobs):
        for p in rng.choice(c, size=min(c, max_parents), replace=False):
            if n_par[c] < max_parents and rng.random() < p_edge:
                edges.append((int(p), int(c)))
                n_par[c] += 1
    return edges


@settings(max_examples=8, deadline=None)
@given(
    n_jobs=st.integers(8, 40),
    seed=st.integers(0, 2**16),
    fail_rate=st.sampled_from([0.0, 0.4]),
    policy=st.sampled_from(["round_robin", "panda_dispatch", "critical_path_first"]),
)
def test_conservation_laws_with_workflow(n_jobs, seed, fail_rate, policy):
    """DAG invariants: no child starts before its last parent finishes,
    DONE + FAILED + CANCELLED partitions every DAG, cancellation happens iff
    a parent died, and the run terminates with resources restored."""
    rng = np.random.default_rng(seed)
    jobs = make_jobs(
        job_id=np.arange(n_jobs),
        arrival=np.sort(rng.uniform(0, 50.0, n_jobs)),
        work=rng.lognormal(np.log(300.0), 1.0, n_jobs),
        cores=np.where(rng.random(n_jobs) < 0.3, 8, 1),
        memory=np.full(n_jobs, 2.0),
        bytes_in=rng.lognormal(np.log(1e7), 1.0, n_jobs),
        bytes_out=rng.lognormal(np.log(1e6), 1.0, n_jobs),
        capacity=n_jobs + 2,  # padding rows must stay inert
    )
    jobs, wf = make_workflow(jobs, random_dag_edges(n_jobs, rng))
    sites = make_sites(
        cores=rng.integers(8, 32, N_SITES),
        speed=rng.uniform(2.0, 20.0, N_SITES),
        memory=rng.uniform(64.0, 256.0, N_SITES),
        bw_in=rng.uniform(1e8, 1e10, N_SITES),
        bw_out=rng.uniform(1e8, 1e10, N_SITES),
        fail_rate=np.full(N_SITES, fail_rate),
    )
    res = simulate(jobs, sites, get_policy(policy), jax.random.PRNGKey(seed),
                   workflow=wf, max_retries=2)

    valid = np.asarray(res.jobs.valid)
    state = np.asarray(res.jobs.state)[valid]
    # termination + partition: every valid job ends DONE, FAILED or CANCELLED
    assert np.isin(state, [DONE, FAILED, CANCELLED]).all()
    assert (np.asarray(res.jobs.state)[~valid] == DONE).all()
    # resources restored
    np.testing.assert_array_equal(np.asarray(res.sites.free_cores), np.asarray(sites.cores))
    np.testing.assert_allclose(
        np.asarray(res.sites.free_memory), np.asarray(sites.memory), rtol=1e-4, atol=1e-2
    )
    # dependency gate: no child starts before its last parent finishes; a
    # child ran at all only if every parent is DONE
    ts = np.asarray(res.jobs.t_start)
    tf = np.asarray(res.jobs.t_finish)
    full_state = np.asarray(res.jobs.state)
    par = np.asarray(wf.parents)
    for j in np.flatnonzero(valid):
        ps = par[j][par[j] >= 0]
        if np.isfinite(ts[j]):
            assert (full_state[ps] == DONE).all()
            if ps.size:
                assert ts[j] >= tf[ps].max() - 1e-4
    # cascade exactness: cancelled iff some parent is FAILED or CANCELLED
    for j in np.flatnonzero(valid):
        ps = par[j][par[j] >= 0]
        parent_dead = ps.size and np.isin(full_state[ps], [FAILED, CANCELLED]).any()
        if full_state[j] == CANCELLED:
            assert parent_dead
        if parent_dead:
            assert full_state[j] == CANCELLED
    # counter: the WorkflowState tally matches the state partition
    assert int(res.wf.n_cancelled) == int((state == CANCELLED).sum())
    # finished/failed site counters still account exactly (no double count
    # from the workflow layer)
    n_done = int((state == DONE).sum())
    retries = int(np.asarray(res.jobs.retries)[valid].sum())
    assert int(np.asarray(res.sites.n_finished).sum()) == n_done
    assert int(np.asarray(res.sites.n_failed).sum()) == retries + int((state == FAILED).sum())


# --------------------------------------------------------------------------
# ISSUE 7: platform-calibration properties — objective geometry, seed
# determinism, and the bounds guarantee of calibrate_platform
# --------------------------------------------------------------------------
from repro.core.calibration import (  # noqa: E402
    PARAM_FIELDS,
    apply_platform_params,
    calibrate_platform,
    default_bounds,
    make_synthetic_platform_problem,
    platform_objective,
    platform_params,
)


def _perturb(params, sigma, seed):
    """Multiplicative lognormal kick on every included knob family."""
    ks = jax.random.split(jax.random.PRNGKey(seed), len(PARAM_FIELDS))
    kicked = {}
    for k, f in zip(ks, PARAM_FIELDS):
        x = getattr(params, f)
        kicked[f] = None if x is None else x * jnp.exp(
            sigma * jax.random.normal(k, x.shape)
        )
    return params._replace(**kicked)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), include_bw=st.booleans())
def test_objective_zero_at_truth_worse_when_perturbed(seed, include_bw):
    """The closed-form objective is ~0 at the hidden truth and strictly
    worse under a large multiplicative perturbation of the true knobs."""
    include = ("speed", "bw", "overhead") if include_bw else ("speed", "overhead")
    problem, truth = make_synthetic_platform_problem(
        n_jobs=32, n_sites=3, seed=seed % 1000, include=include,
        trace="closed_form", wan_frac=0.5 if include_bw else 0.0,
    )
    at_truth = float(platform_objective(problem, truth))
    assert at_truth < 1e-5
    kicked = _perturb(truth, 1.0, seed)
    assert float(platform_objective(problem, kicked)) > at_truth + 0.05


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16), method=st.sampled_from(["spsa", "grad"]))
def test_calibrate_platform_seed_deterministic(seed, method):
    """Same seed -> bitwise-identical result pytree."""
    problem, _ = make_synthetic_platform_problem(
        n_jobs=24, n_sites=3, seed=seed % 1000, include=("speed",),
        trace="closed_form",
    )
    kw = dict(method=method, objective="closed_form", include=("speed",),
              n_iters=8, seed=seed % 97)
    r1 = calibrate_platform(problem, **kw)
    r2 = calibrate_platform(problem, **kw)
    for a, b in zip(jax.tree.leaves(r1), jax.tree.leaves(r2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16), factor=st.floats(1.05, 1.5))
def test_calibrate_platform_respects_bounds(seed, factor):
    """Results never leave the declared box — even when the box is so tight
    that the optimizer slams into the walls."""
    problem, _ = make_synthetic_platform_problem(
        n_jobs=24, n_sites=3, seed=seed % 1000, include=("speed", "overhead"),
        trace="closed_form", misconfig_sigma=0.8,
    )
    p0 = platform_params(problem, ("speed", "overhead"))
    bounds = default_bounds(p0, factor=factor)
    res = calibrate_platform(
        problem, method="spsa", objective="closed_form",
        include=("speed", "overhead"), bounds=bounds, n_iters=12,
        seed=seed % 89, a0=0.5,
    )
    for f in ("speed", "overhead"):
        x = np.asarray(getattr(res.params, f))
        lo = np.asarray(getattr(bounds.lo, f))
        hi = np.asarray(getattr(bounds.hi, f))
        assert (x >= lo - 1e-6 * lo).all() and (x <= hi + 1e-6 * hi).all()


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    k=st.integers(1, 6),
    policy=st.sampled_from(["data_locality", "fastest_site", "least_loaded"]),
)
def test_sparse_candidates_contain_dense_argmax(seed, k, policy):
    """Sparse top-k membership guarantee (DESIGN.md §12): the candidate index
    always contains the dense pre-rank argmax site whenever any site is
    feasible — the property the k<S approximation gate rests on."""
    from test_sparse_topk import check_candidates_contain_dense_argmax

    check_candidates_contain_dense_argmax(seed, k, policy)
