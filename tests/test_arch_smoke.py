"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape + finiteness asserts; serving path consistency (prefill+decode ==
teacher-forced forward) for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, get_skips, get_smoke, runnable_cells
from repro.models import build_model, param_count


def make_batch(cfg, B=2, S=48, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[1], (B, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, cfg.n_patches, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = m.forward(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    (loss, metrics), grads = jax.value_and_grad(m.loss, has_aux=True)(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert flat and all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # one SGD step must change the loss
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
    loss2, _ = m.loss(params2, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_matches_forward(arch):
    """prefill(prompt) + decode_step == teacher-forced forward, per family.

    For MoE, the equivalence only holds when no token is dropped: capacity
    admission in a full batch is a *different population* than a single
    decoded token (that asymmetry is inherent to capacity routing, not a
    bug), so the check uses an ample capacity_factor.
    """
    cfg = get_smoke(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=32.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = make_batch(cfg, B=B, S=S, seed=3)
    tokens = batch["tokens"]

    full_logits, _ = m.forward(params, batch)

    P = S - 4
    cache = m.init_cache(B, S + 8)
    prompt_batch = dict(batch, tokens=tokens[:, :P])
    logits_p, cache = m.prefill(params, prompt_batch, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1]), np.asarray(full_logits[:, P - 1]), rtol=2e-2, atol=2e-2
    )
    for i in range(P, S):
        logits_d, cache = m.decode(params, tokens[:, i : i + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]),
            np.asarray(full_logits[:, i]),
            rtol=3e-2,
            atol=3e-2,
            err_msg=f"{arch} step {i}",
        )


def test_exact_configs_match_brief():
    """The full (not smoke) configs carry the exact public hyperparameters."""
    c = get_config("llama3-405b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        126, 16384, 128, 8, 53248, 128256,
    )
    c = get_config("kimi-k2-1t-a32b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k, c.vocab_size) == (
        61, 7168, 384, 8, 163840,
    )
    assert 1.0e12 < c.param_count() < 1.1e12           # ~1T total
    assert 30e9 < c.active_param_count() < 34e9        # ~32B active
    c = get_config("nemotron-4-340b")
    assert c.mlp_act == "relu2" and c.d_ff == 73728
    c = get_config("recurrentgemma-2b")
    assert c.block_pattern == ("rec", "rec", "att") and c.window == 2048
    c = get_config("qwen2.5-32b")
    assert c.qkv_bias and c.n_kv_heads == 8
    c = get_config("mamba2-130m")
    assert c.family == "ssm" and c.ssm_state == 128 and c.n_heads == 0
    c = get_config("whisper-small")
    assert c.n_enc_layers == 12 and c.n_dec_layers == 12


def test_cell_matrix_covers_brief():
    cells = runnable_cells()
    assert len(cells) == 32  # 40 minus 8 documented long_500k skips
    skipped = [(a, s) for a in ARCHS for s in get_skips(a)]
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    # SSM/hybrid archs run the long-context cell
    assert ("mamba2-130m", "long_500k") in cells
    assert ("recurrentgemma-2b", "long_500k") in cells
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
