"""Golden-trace regression: a fixed-seed scenario's exact outcome snapshot.

Engine refactors that silently change semantics — a reordered round step, a
different tie-break, an extra RNG draw — shift these numbers and fail tier-1
immediately, instead of surfacing months later as a calibration drift.

The snapshot lives in ``tests/data/golden_trace.json`` and is compared for
*exact* equality (float32 values round-trip exactly through ``float``/JSON).
After an intentional semantics change, regenerate with

    REGEN_GOLDEN=1 pytest tests/test_golden_trace.py

and commit the diff alongside the change that caused it.
"""
import itertools
import json
import os
import pathlib

import jax
import numpy as np
import pytest

from repro.core import (
    atlas_like_platform,
    get_data_policy,
    get_policy,
    make_availability,
    make_faults,
    make_replicas,
    make_transfers,
    make_workflow,
    simulate,
    synthetic_panda_jobs,
    uniform_network,
    zipf_dataset_sizes,
)

GOLDEN = pathlib.Path(__file__).parent / "data" / "golden_trace.json"
GOLDEN_MATRIX = pathlib.Path(__file__).parent / "data" / "golden_matrix.json"


def _snapshot_one(res) -> dict:
    valid = np.asarray(res.jobs.valid)
    state = np.asarray(res.jobs.state)[valid]
    return dict(
        makespan=float(res.makespan),
        rounds=int(res.rounds),
        state_counts={str(s): int((state == s).sum()) for s in range(6)},
        site_n_assigned=np.asarray(res.sites.n_assigned).tolist(),
        site_n_finished=np.asarray(res.sites.n_finished).tolist(),
        site_n_failed=np.asarray(res.sites.n_failed).tolist(),
        sum_retries=int(np.asarray(res.jobs.retries)[valid].sum()),
        # exact per-job timestamps for a probe subset (full arrays would bloat
        # the snapshot without adding sensitivity)
        t_start_head=[float(t) for t in np.asarray(res.jobs.t_start)[:8]],
        t_finish_head=[float(t) for t in np.asarray(res.jobs.t_finish)[:8]],
        n_preempted=(
            np.asarray(res.avail.n_preempted).tolist() if res.avail is not None else None
        ),
    )


def compute_snapshot() -> dict:
    jobs = synthetic_panda_jobs(60, seed=11, duration=900.0)
    sites = atlas_like_platform(4, seed=12, fail_rate=0.05)
    pol = get_policy("panda_dispatch")
    key = jax.random.PRNGKey(0)
    base = simulate(jobs, sites, pol, key)
    # site 3 carries the whole workload under this seed: hit it mid-run
    av = make_availability(
        4,
        [
            dict(site=3, start=2000.0, end=20000.0, preempt=True),
            dict(site=2, start=500.0, end=5000.0, factor=0.5),
        ],
    )
    outage = simulate(jobs, sites, pol, key, availability=av)
    return dict(baseline=_snapshot_one(base), outage=_snapshot_one(outage))


def test_golden_trace_exact():
    snap = compute_snapshot()
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(snap, indent=2) + "\n")
        pytest.skip(f"regenerated {GOLDEN}")
    expected = json.loads(GOLDEN.read_text())
    assert snap == expected


# --------------------------------------------------------------------------
# subsystem on/off matrix (ISSUE 4): every combination of the data-movement,
# availability, and workflow subsystems must stay bit-for-bit stable
# --------------------------------------------------------------------------

N_DS = 12


def _snapshot_combo(res) -> dict:
    """Per-combo snapshot: the base engine probe plus each subsystem's own
    counters, so a regression in any one layer shifts its combo rows."""
    snap = _snapshot_one(res)
    snap["state_counts"]["6"] = int(
        (np.asarray(res.jobs.state)[np.asarray(res.jobs.valid)] == 6).sum()
    )
    rep = res.replicas
    snap["data"] = (
        dict(
            n_hits=int(rep.n_hits),
            n_transfers=int(rep.n_transfers),
            bytes_moved=float(rep.bytes_moved),
            disk_used=np.asarray(rep.disk_used).tolist(),
        )
        if rep is not None
        else None
    )
    wf = res.wf
    snap["workflow"] = (
        dict(n_cancelled=int(wf.n_cancelled), n_produced=int(wf.n_produced))
        if wf is not None
        else None
    )
    # transfer-queue counters only appear when the subsystem ran, so the
    # pre-transfers combo rows keep their exact committed shape
    ts = (getattr(res, "ext", None) or {}).get("transfers")
    if ts is not None:
        snap["transfers"] = dict(
            n_enq=int(ts.n_enq),
            n_done=int(ts.n_done),
            n_cancel=int(ts.n_cancel),
            bytes_done=float(ts.bytes_done),
        )
    # fault counters likewise only appear when the faults subsystem ran
    fs = (getattr(res, "ext", None) or {}).get("faults")
    if fs is not None:
        snap["faults"] = dict(
            n_xfer_fail=int(fs.n_xfer_fail),
            n_xfer_retry=int(fs.n_xfer_retry),
            n_xfer_exhaust=int(fs.n_xfer_exhaust),
            n_kills=int(fs.n_kills),
            n_lost_replicas=int(fs.n_lost_replicas),
            n_bl_trips=int(fs.n_bl_trips),
            n_probes=int(fs.n_probes),
            time_lost=float(fs.time_lost),
        )
    return snap


def matrix_scenario():
    """One deterministic scenario feeding all 8 subsystem combinations.

    Every catalogued dataset is materialized at t=0 (origins at site 0's data
    lake), so the data subsystem is valid with or without the workflow gate;
    the DAG chains half the jobs pairwise so cancellation, gating, and (with
    data on) output materialization all fire.
    """
    jobs = synthetic_panda_jobs(60, seed=11, duration=900.0, n_datasets=N_DS)
    sites = atlas_like_platform(4, seed=12, fail_rate=0.05)
    availability = make_availability(
        4,
        [
            dict(site=3, start=2000.0, end=20000.0, preempt=True),
            dict(site=2, start=500.0, end=5000.0, factor=0.5),
            dict(site=1, start=8000.0, end=12000.0, factor=0.0, preempt=False),
        ],
    )
    network = uniform_network(4, bw=5e8, latency=0.05)
    replicas = make_replicas(
        zipf_dataset_sizes(N_DS, seed=3, mean_bytes=2e9),
        disk_capacity=np.array([1e13, 6e9, 6e9, 6e9]),
        origin=np.zeros(N_DS, np.int32),
    )
    data_policy = get_data_policy("cache_on_read")
    # pairwise chains over consecutive jobs; even rows materialize an output
    # the odd child job consumes through the catalog when data is on
    edges = [(j - 1, j) for j in range(1, 60, 2)]
    out_dataset = np.where(np.arange(60) % 2 == 0, np.arange(60) % N_DS, -1)
    jobs_wf, workflow = make_workflow(jobs, edges, out_dataset=out_dataset)
    return dict(
        jobs=jobs,
        jobs_wf=jobs_wf,
        sites=sites,
        availability=availability,
        network=network,
        replicas=replicas,
        data_policy=data_policy,
        workflow=workflow,
    )


def combo_kwargs(scn: dict, data: bool, avail: bool, wf: bool):
    jobs = scn["jobs_wf"] if wf else scn["jobs"]
    kw = {}
    if data:
        kw.update(
            data_policy=scn["data_policy"],
            network=scn["network"],
            replicas=scn["replicas"],
        )
    if avail:
        kw["availability"] = scn["availability"]
    if wf:
        kw["workflow"] = scn["workflow"]
    return jobs, kw


def compute_matrix_snapshot() -> dict:
    scn = matrix_scenario()
    pol = get_policy("panda_dispatch")
    key = jax.random.PRNGKey(0)
    out = {}
    for data, avail, wf in itertools.product((False, True), repeat=3):
        name = "+".join(
            n for n, on in (("data", data), ("avail", avail), ("wf", wf)) if on
        ) or "plain"
        jobs, kw = combo_kwargs(scn, data, avail, wf)
        out[name] = _snapshot_combo(simulate(jobs, scn["sites"], pol, key, **kw))
    # transfer-queue combos (ISSUE 8): the queued WAN model rides on the data
    # subsystem, so only the data-on half of the matrix composes with it
    for avail, wf in itertools.product((False, True), repeat=2):
        name = "+".join(
            n for n, on in (("data", True), ("tr", True), ("avail", avail), ("wf", wf))
            if on
        )
        jobs, kw = combo_kwargs(scn, True, avail, wf)
        kw["transfers"] = make_transfers(4, jobs.capacity, max_active=2)
        out[name] = _snapshot_combo(simulate(jobs, scn["sites"], pol, key, **kw))
    # fault-injection combos (ISSUE 10): all four channels armed at once —
    # flaky WAN links, resubmission backoff, walltime kills, replica loss
    # targeting cached (non-origin) copies, and the circuit breaker
    def faults_state(jobs):
        return make_faults(
            4, jobs.capacity,
            link_fail_p=0.3, xfer_backoff=120.0, max_xfer_attempts=3,
            job_backoff=60.0, walltime=4000.0,
            replica_loss=[(3000.0, 1, 1), (3000.0, 1, 2), (6000.0, 2, 3)],
            blacklist_threshold=0.5, blacklist_alpha=0.5,
            blacklist_cooldown=1800.0,
        )
    for combo in ((False, False, False), (False, True, False),
                  (True, False, False), (True, True, True)):
        data, avail, wf = combo
        name = "+".join(
            n for n, on in (("data", data), ("tr", data), ("avail", avail),
                            ("wf", wf)) if on
        )
        name = f"{name}+faults" if name else "faults"
        jobs, kw = combo_kwargs(scn, data, avail, wf)
        if data:
            kw["transfers"] = make_transfers(4, jobs.capacity, max_active=2)
        kw["faults"] = faults_state(jobs)
        out[name] = _snapshot_combo(simulate(jobs, scn["sites"], pol, key, **kw))
    return out


def test_golden_matrix_exact():
    """Bit-for-bit parity for all 8 subsystem on/off combinations."""
    snap = compute_matrix_snapshot()
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN_MATRIX.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN_MATRIX.write_text(json.dumps(snap, indent=2) + "\n")
        pytest.skip(f"regenerated {GOLDEN_MATRIX}")
    expected = json.loads(GOLDEN_MATRIX.read_text())
    assert snap == expected


def test_golden_matrix_is_sensitive():
    """Each subsystem must leave a visible fingerprint in its combo rows."""
    expected = json.loads(GOLDEN_MATRIX.read_text())
    assert set(expected) == {
        "plain", "data", "avail", "wf", "data+avail", "data+wf", "avail+wf",
        "data+avail+wf", "data+tr", "data+tr+avail", "data+tr+wf",
        "data+tr+avail+wf", "faults", "avail+faults", "data+tr+faults",
        "data+tr+avail+wf+faults",
    }
    # availability preempts; data moves bytes; the coupled combo materializes
    assert sum(expected["avail"]["n_preempted"]) > 0
    assert expected["data"]["data"]["n_transfers"] > 0
    assert expected["data+avail+wf"]["workflow"]["n_produced"] > 0
    # the transfer queue actually carried flows, and accounts for all of them
    for name in ("data+tr", "data+tr+avail", "data+tr+wf", "data+tr+avail+wf"):
        ts = expected[name]["transfers"]
        assert ts["n_enq"] > 0
        assert ts["n_enq"] == ts["n_done"] + ts["n_cancel"]
    # transfers-off rows never grow the counter block
    assert "transfers" not in expected["data"]
    # fault channels leave fingerprints: backoff shifts retries into waits,
    # flaky links fail transfers, and the extended ledger still balances
    assert "faults" not in expected["plain"]
    assert expected["faults"]["faults"]["time_lost"] > 0
    for name in ("data+tr+faults", "data+tr+avail+wf+faults"):
        ts, fs = expected[name]["transfers"], expected[name]["faults"]
        assert fs["n_xfer_fail"] > 0
        assert ts["n_enq"] == ts["n_done"] + ts["n_cancel"] + fs["n_xfer_fail"]
    # subsystems genuinely interact: no two combos collapse to the same run
    spans = {k: (v["makespan"], v["rounds"]) for k, v in expected.items()}
    assert len(set(spans.values())) == len(spans)


def test_golden_scenario_is_sensitive():
    """The committed scenario must actually exercise the dynamics it guards:
    the outage run preempts jobs and takes longer than the baseline."""
    expected = json.loads(GOLDEN.read_text())
    assert sum(expected["outage"]["n_preempted"]) > 0
    assert expected["outage"]["makespan"] > expected["baseline"]["makespan"]
    assert expected["baseline"]["n_preempted"] is None
