"""Golden-trace regression: a fixed-seed scenario's exact outcome snapshot.

Engine refactors that silently change semantics — a reordered round step, a
different tie-break, an extra RNG draw — shift these numbers and fail tier-1
immediately, instead of surfacing months later as a calibration drift.

The snapshot lives in ``tests/data/golden_trace.json`` and is compared for
*exact* equality (float32 values round-trip exactly through ``float``/JSON).
After an intentional semantics change, regenerate with

    REGEN_GOLDEN=1 pytest tests/test_golden_trace.py

and commit the diff alongside the change that caused it.
"""
import json
import os
import pathlib

import jax
import numpy as np
import pytest

from repro.core import (
    atlas_like_platform,
    get_policy,
    make_availability,
    simulate,
    synthetic_panda_jobs,
)

GOLDEN = pathlib.Path(__file__).parent / "data" / "golden_trace.json"


def _snapshot_one(res) -> dict:
    valid = np.asarray(res.jobs.valid)
    state = np.asarray(res.jobs.state)[valid]
    return dict(
        makespan=float(res.makespan),
        rounds=int(res.rounds),
        state_counts={str(s): int((state == s).sum()) for s in range(6)},
        site_n_assigned=np.asarray(res.sites.n_assigned).tolist(),
        site_n_finished=np.asarray(res.sites.n_finished).tolist(),
        site_n_failed=np.asarray(res.sites.n_failed).tolist(),
        sum_retries=int(np.asarray(res.jobs.retries)[valid].sum()),
        # exact per-job timestamps for a probe subset (full arrays would bloat
        # the snapshot without adding sensitivity)
        t_start_head=[float(t) for t in np.asarray(res.jobs.t_start)[:8]],
        t_finish_head=[float(t) for t in np.asarray(res.jobs.t_finish)[:8]],
        n_preempted=(
            np.asarray(res.avail.n_preempted).tolist() if res.avail is not None else None
        ),
    )


def compute_snapshot() -> dict:
    jobs = synthetic_panda_jobs(60, seed=11, duration=900.0)
    sites = atlas_like_platform(4, seed=12, fail_rate=0.05)
    pol = get_policy("panda_dispatch")
    key = jax.random.PRNGKey(0)
    base = simulate(jobs, sites, pol, key)
    # site 3 carries the whole workload under this seed: hit it mid-run
    av = make_availability(
        4,
        [
            dict(site=3, start=2000.0, end=20000.0, preempt=True),
            dict(site=2, start=500.0, end=5000.0, factor=0.5),
        ],
    )
    outage = simulate(jobs, sites, pol, key, availability=av)
    return dict(baseline=_snapshot_one(base), outage=_snapshot_one(outage))


def test_golden_trace_exact():
    snap = compute_snapshot()
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(snap, indent=2) + "\n")
        pytest.skip(f"regenerated {GOLDEN}")
    expected = json.loads(GOLDEN.read_text())
    assert snap == expected


def test_golden_scenario_is_sensitive():
    """The committed scenario must actually exercise the dynamics it guards:
    the outage run preempts jobs and takes longer than the baseline."""
    expected = json.loads(GOLDEN.read_text())
    assert sum(expected["outage"]["n_preempted"]) > 0
    assert expected["outage"]["makespan"] > expected["baseline"]["makespan"]
    assert expected["baseline"]["n_preempted"] is None
