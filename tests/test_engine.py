"""Engine behaviour: event-round semantics, FIFO-with-capacity, failures."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DONE,
    FAILED,
    atlas_like_platform,
    compute_metrics,
    get_policy,
    make_jobs,
    make_sites,
    simulate,
    synthetic_panda_jobs,
)


def mini_jobs(n=16, cores=1, arrival=None, work=None, **kw):
    return make_jobs(
        job_id=np.arange(n),
        arrival=arrival if arrival is not None else np.zeros(n),
        work=work if work is not None else np.full(n, 100.0),
        cores=np.full(n, cores),
        memory=np.full(n, 1.0),
        bytes_in=np.zeros(n),
        bytes_out=np.zeros(n),
        **kw,
    )


def one_site(cores=4, speed=10.0):
    return make_sites(cores=[cores], speed=[speed], memory=[1e9], bw_in=[1e12], bw_out=[1e12])


def test_all_jobs_finish():
    jobs = synthetic_panda_jobs(200, seed=0, duration=600.0)
    sites = atlas_like_platform(5, seed=1)
    res = simulate(jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0))
    state = np.asarray(res.jobs.state)[np.asarray(res.jobs.valid)]
    assert (state == DONE).all()
    assert float(res.makespan) > 0
    assert np.isfinite(np.asarray(res.jobs.t_finish)[np.asarray(res.jobs.valid)]).all()


def test_serial_execution_on_one_core():
    # 4 jobs, 1 core, work 100 @ speed 10 => 10s each, strictly serialized
    jobs = mini_jobs(4)
    sites = one_site(cores=1)
    res = simulate(jobs, sites, get_policy("fastest_site"), jax.random.PRNGKey(0))
    starts = np.sort(np.asarray(res.jobs.t_start)[:4])
    np.testing.assert_allclose(starts, [0.0, 10.0, 20.0, 30.0], atol=1e-4)
    assert float(res.makespan) == pytest.approx(40.0, abs=1e-3)


def test_parallel_execution_within_capacity():
    jobs = mini_jobs(4)
    sites = one_site(cores=4)
    res = simulate(jobs, sites, get_policy("fastest_site"), jax.random.PRNGKey(0))
    assert float(res.makespan) == pytest.approx(10.0, abs=1e-3)
    np.testing.assert_allclose(np.asarray(res.jobs.t_start)[:4], 0.0, atol=1e-5)


def test_fifo_blocking_head_of_line():
    # head job needs 4 cores (all), next needs 1: strict FIFO means the small
    # one must NOT overtake the big one once the big one is at queue head.
    jobs = make_jobs(
        job_id=[0, 1],
        arrival=[0.0, 0.1],
        work=[400.0, 10.0],
        cores=[4, 1],
        memory=[1.0, 1.0],
        bytes_in=[0.0, 0.0],
        bytes_out=[0.0, 0.0],
    )
    sites = one_site(cores=4)
    res = simulate(jobs, sites, get_policy("fastest_site"), jax.random.PRNGKey(0))
    t = np.asarray(res.jobs.t_start)
    assert t[0] == pytest.approx(0.0, abs=1e-5)
    # big job runs 400/(10*speedup(4)) with gamma=0 => 10s; small starts after
    assert t[1] == pytest.approx(10.0, abs=1e-3)


def test_priority_order_within_site():
    jobs = mini_jobs(3, arrival=np.zeros(3))
    jobs = jobs._replace(priority=jnp.array([0.0, 5.0, 1.0]))
    sites = one_site(cores=1)
    res = simulate(jobs, sites, get_policy("fastest_site"), jax.random.PRNGKey(0))
    t = np.asarray(res.jobs.t_start)[:3]
    assert t[1] < t[2] < t[0]


def test_multicore_amdahl_slowdown():
    jobs = mini_jobs(1, cores=8, work=np.full(1, 800.0))
    fast = make_sites(cores=[8], speed=[10.0], memory=[64.0], bw_in=[1e12], bw_out=[1e12])
    contended = fast._replace(par_gamma=jnp.array([0.1]))
    r1 = simulate(jobs, fast, get_policy("fastest_site"), jax.random.PRNGKey(0))
    r2 = simulate(jobs, contended, get_policy("fastest_site"), jax.random.PRNGKey(0))
    w1 = float(r1.jobs.t_finish[0] - r1.jobs.t_start[0])
    w2 = float(r2.jobs.t_finish[0] - r2.jobs.t_start[0])
    assert w1 == pytest.approx(10.0, abs=1e-3)          # 800/(10*8)
    assert w2 == pytest.approx(17.0, abs=1e-2)          # speedup 8/1.7


def test_failures_resubmit_and_exhaust():
    jobs = mini_jobs(32)
    sites = one_site(cores=32)._replace(fail_rate=jnp.array([1.0]))  # always fail
    res = simulate(jobs, sites, get_policy("fastest_site"), jax.random.PRNGKey(0), max_retries=2)
    state = np.asarray(res.jobs.state)[:32]
    assert (state == FAILED).all()
    assert (np.asarray(res.jobs.retries)[:32] == 2).all()
    assert int(res.sites.n_failed[0]) == 32 * 3  # every attempt failed


def test_zero_failure_rate_never_fails():
    jobs = synthetic_panda_jobs(100, seed=3, duration=100.0)
    sites = atlas_like_platform(4, seed=4, fail_rate=0.0)
    res = simulate(jobs, sites, get_policy("least_loaded"), jax.random.PRNGKey(0))
    assert int(compute_metrics(res).n_failed) == 0


def test_infeasible_job_halts_cleanly():
    # job needs 64 cores but max site has 4: engine must halt, not spin
    jobs = mini_jobs(1, cores=64)
    sites = one_site(cores=4)
    res = simulate(jobs, sites, get_policy("fastest_site"), jax.random.PRNGKey(0))
    assert int(res.jobs.state[0]) not in (DONE, FAILED)
    assert int(res.rounds) < 10


def test_horizon_cuts_simulation():
    jobs = mini_jobs(16, arrival=np.linspace(0, 1000.0, 16))
    sites = one_site(cores=1)
    res = simulate(jobs, sites, get_policy("fastest_site"), jax.random.PRNGKey(0), horizon=50.0)
    # engine may process one event past the horizon before the cond fires
    assert float(res.makespan) <= 70.0
    state = np.asarray(res.jobs.state)[:16]
    assert (state == DONE).sum() < 16  # plenty of jobs were cut off


def test_rounds_bounded_by_two_per_job():
    jobs = synthetic_panda_jobs(300, seed=5, duration=3600.0)
    sites = atlas_like_platform(8, seed=6)
    res = simulate(jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0))
    assert int(res.rounds) <= 2 * 300 + 2


def test_stage_in_adds_time():
    big_in = make_jobs(
        job_id=[0], arrival=[0.0], work=[100.0], cores=[1], memory=[1.0],
        bytes_in=[1e9], bytes_out=[0.0],
    )
    sites = make_sites(cores=[4], speed=[10.0], memory=[64.0], bw_in=[1e8], bw_out=[1e8])
    res = simulate(big_in, sites, get_policy("fastest_site"), jax.random.PRNGKey(0))
    wall = float(res.jobs.t_finish[0] - res.jobs.t_start[0])
    assert wall == pytest.approx(10.0 + 10.0, abs=1e-2)  # compute + stage-in
