"""Fused candidate-set assignment kernel (kernels/assign/fused.py).

Three layers of parity, all exact:
  1. kernel (interpret mode on CPU) ≡ jnp oracle on random candidate sets,
  2. with candidates = all sites, fused ≡ the dense k=1 assignment oracle
     (same pick, same FIFO admission),
  3. end-to-end through the engine: ``simulate(topk=S)`` with the fused
     assigner ≡ dense ``with_capacity_assign`` bit-for-bit, oracle and
     interpret-mode kernel alike.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    atlas_like_platform,
    get_policy,
    simulate,
    synthetic_panda_jobs,
    with_capacity_assign,
    with_fused_assign,
)
from repro.kernels.assign.fused import fused_assign_pallas, fused_assign_ref
from repro.kernels.assign.ops import make_capacity_assign, make_fused_capacity_assign
from repro.kernels.assign.ref import assign_ref


def _random_case(seed, N=97, E=7, K=4):
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=(N, K)).astype(np.float32)
    # candidate rows: sorted-ascending distinct site ids with sentinel E pads
    cand = np.full((N, K), E, np.int32)
    for i in range(N):
        n = rng.integers(0, K + 1)
        cand[i, :n] = np.sort(rng.choice(E, size=n, replace=False))
    sizes = rng.integers(1, 4, size=N).astype(np.float32)
    caps = rng.integers(0, 40, size=E).astype(np.float32)
    return jnp.asarray(scores), jnp.asarray(cand), jnp.asarray(sizes), jnp.asarray(caps)


def test_fused_kernel_matches_oracle_random():
    for seed in range(5):
        scores, cand, sizes, caps = _random_case(seed)
        s_ref, a_ref = fused_assign_ref(scores, cand, sizes, caps, block_n=32)
        s_ker, a_ker = fused_assign_pallas(
            scores, cand, sizes, caps, block_n=32, interpret=True
        )
        assert (np.asarray(s_ref) == np.asarray(s_ker)).all()
        assert (np.asarray(a_ref) == np.asarray(a_ker)).all()


def test_fused_empty_rows_never_admit():
    scores, cand, sizes, caps = _random_case(0)
    cand = jnp.full_like(cand, caps.shape[0])  # all-sentinel rows
    site, admit = fused_assign_ref(scores, cand, sizes, caps)
    assert (np.asarray(site) == -1).all() and not np.asarray(admit).any()


def test_fused_full_candidates_match_dense_assign():
    """cand = all sites ascending -> fused pick + admission == the dense k=1
    oracle on the equivalent masked [N, E] score matrix."""
    rng = np.random.default_rng(42)
    N, E = 64, 5
    dense = jnp.asarray(rng.normal(size=(N, E)).astype(np.float32))
    feas = jnp.asarray(rng.random((N, E)) < 0.7)
    sizes = jnp.ones((N,), jnp.float32)
    caps = jnp.asarray(rng.integers(2, 12, size=E).astype(np.float32))
    NEG = jnp.float32(-1e30)

    cand = jnp.where(feas, jnp.arange(E)[None, :], E).astype(jnp.int32)
    cand = jnp.sort(cand, axis=-1)
    scores_k = jnp.where(cand < E, jnp.take_along_axis(
        dense, jnp.clip(cand, 0, E - 1), axis=-1), NEG)
    s_f, a_f = fused_assign_ref(scores_k, cand, sizes, caps)

    idx, gate, admit, pos = assign_ref(jnp.where(feas, dense, NEG), sizes, caps, k=1)
    ok_dense = np.asarray(feas).any(-1)
    assert (np.asarray(a_f) == (np.asarray(admit)[:, 0] & ok_dense)).all()
    assert (np.asarray(s_f)[ok_dense] == np.asarray(idx)[ok_dense, 0]).all()
    assert (np.asarray(s_f)[~ok_dense] == -1).all()


def _trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        if not np.array_equal(x, y, equal_nan=np.issubdtype(x.dtype, np.floating)):
            return False
    return True


def test_engine_fused_topk_full_equals_dense_capacity_assign():
    jobs = synthetic_panda_jobs(60, seed=11, duration=900.0)
    sites = atlas_like_platform(4, seed=12, fail_rate=0.05)
    key = jax.random.PRNGKey(0)
    base = get_policy("panda_dispatch")
    dense_pol = with_capacity_assign(
        base, make_capacity_assign(jobs_cores=jobs.cores, use_kernel=False)
    )
    res_dense = simulate(jobs, sites, dense_pol, key)
    for use_kernel in (False, True):  # jnp oracle, interpret-mode kernel
        fused_pol = with_fused_assign(
            base, make_fused_capacity_assign(jobs_cores=jobs.cores, use_kernel=use_kernel)
        )
        res_fused = simulate(jobs, sites, fused_pol, key, topk=sites.capacity)
        assert _trees_equal(res_dense, res_fused), f"use_kernel={use_kernel}"


def test_engine_fused_small_k_runs_and_completes():
    """k < S through the fused assigner: approximation, but every job still
    terminates and capacity accounting stays consistent."""
    jobs = synthetic_panda_jobs(60, seed=11, duration=900.0)
    sites = atlas_like_platform(4, seed=12)
    pol = with_fused_assign(
        get_policy("panda_dispatch"),
        make_fused_capacity_assign(jobs_cores=jobs.cores, use_kernel=False),
    )
    res = simulate(jobs, sites, pol, jax.random.PRNGKey(0), topk=2)
    state = np.asarray(res.jobs.state)[np.asarray(res.jobs.valid)]
    assert (state >= 4).all()  # DONE or FAILED, nothing stuck
    assert int(np.asarray(res.sites.n_assigned).sum()) >= 60
