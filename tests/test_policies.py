"""Policy plugin system: registry, built-ins, custom plugins, hooks."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DONE,
    AllocationPlugin,
    atlas_like_platform,
    get_policy,
    make_policy,
    register,
    simulate,
    synthetic_panda_jobs,
)
from repro.core.policies import REGISTRY


def run(policy, n_jobs=120, n_sites=6, seed=0):
    jobs = synthetic_panda_jobs(n_jobs, seed=seed, duration=1200.0)
    sites = atlas_like_platform(n_sites, seed=seed + 1)
    return simulate(jobs, sites, policy, jax.random.PRNGKey(seed))


def test_all_builtin_policies_complete():
    for name in sorted(REGISTRY):
        res = run(get_policy(name))
        state = np.asarray(res.jobs.state)[np.asarray(res.jobs.valid)]
        assert (state == DONE).all(), name


def test_round_robin_spreads_load():
    res = run(get_policy("round_robin"), n_jobs=240)
    sites = np.asarray(res.jobs.site)[np.asarray(res.jobs.valid)]
    counts = np.bincount(sites, minlength=6)
    assert counts.min() > 0
    assert counts.max() - counts.min() <= counts.mean()  # roughly even


def test_data_locality_prefers_fat_links():
    res = run(get_policy("data_locality"), n_jobs=200)
    bw = np.asarray(res.sites.bw_in)
    sites = np.asarray(res.jobs.site)[np.asarray(res.jobs.valid)]
    # most jobs should land on the widest active links
    top = np.argsort(-bw)[:2]
    assert np.isin(sites, top).mean() > 0.5


def test_shortest_wait_beats_random_on_makespan():
    r_rand = run(get_policy("random"), n_jobs=400)
    r_sw = run(get_policy("shortest_wait"), n_jobs=400)
    assert float(r_sw.makespan) <= float(r_rand.makespan) * 1.05


def test_custom_plugin_class():
    class OnlySiteZero(AllocationPlugin):
        name = "only_site_zero"

        def assign_job(self, jobs, sites, state, clock, rng):
            S = sites.capacity
            return jnp.where(jnp.arange(S)[None, :] == 0, 1.0, -1.0).repeat(
                jobs.capacity, axis=0
            )

    res = run(OnlySiteZero().build(), n_jobs=50)
    sites = np.asarray(res.jobs.site)[np.asarray(res.jobs.valid)]
    assert (sites == 0).all()


def test_registry_registration():
    @register("always_fastest_test")
    def _factory():
        def score(jobs, sites, state, clock, rng):
            return jnp.broadcast_to(sites.speed[None, :], (jobs.capacity, sites.capacity))

        return make_policy("always_fastest_test", score)

    assert "always_fastest_test" in REGISTRY
    res = run(get_policy("always_fastest_test"), n_jobs=30)
    state = np.asarray(res.jobs.state)[np.asarray(res.jobs.valid)]
    assert (state == DONE).all()


def test_on_step_hook_accumulates():
    # count completions through the hook; must equal number of jobs
    def score(jobs, sites, state, clock, rng):
        return jnp.broadcast_to(sites.speed[None, :], (jobs.capacity, sites.capacity))

    def init(jobs, sites):
        return jnp.int32(0)

    def on_step(state, jobs, sites, completed, started, clock):
        return state + completed.sum().astype(jnp.int32)

    pol = make_policy("counting", score, init=init, on_step=on_step)
    res = run(pol, n_jobs=64)
    assert int(res.policy_state) == 64
