"""Shared test configuration: hypothesis profiles.

CI runs with ``HYPOTHESIS_PROFILE=ci`` (see .github/workflows/ci.yml): fewer,
derandomized examples with no deadline, so property tests are reproducible
and never flake on shared-runner jitter or jit compile time.  Local runs get
the ``dev`` profile (deadline off — every new shape recompiles the engine).
"""
import os

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci",
        deadline=None,
        max_examples=8,
        derandomize=True,
        suppress_health_check=[HealthCheck.too_slow],
        print_blob=True,
    )
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:  # property tests importorskip hypothesis themselves
    pass
