"""Calibration: objective consistency, all four optimizers, paper's claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import atlas_like_platform, make_jobs, synthetic_panda_jobs
from repro.core.calibration import (
    calibrate,
    closed_form_objective,
    closed_form_walltimes,
    engine_objective,
    geomean_error,
    make_synthetic_problem,
    per_site_rel_mae,
)


@pytest.fixture(scope="module")
def problem():
    jobs = synthetic_panda_jobs(1500, seed=0, duration=24 * 3600.0)
    sites = atlas_like_platform(50, seed=1)
    return make_synthetic_problem(jobs, sites, seed=2)


def test_initial_error_matches_paper_regime(problem):
    _, _, e0 = closed_form_objective(problem, problem.sites0.speed)
    # paper's uncalibrated regime is ~76%; our misconfiguration lands in the
    # same several-tens-of-percent band
    assert 0.3 < float(e0) < 1.5


def test_random_search_hits_paper_band(problem):
    r = calibrate(problem, "random", seed=3)
    # paper: 76% -> 17%. Residual noise floor here is the injected 15%
    # lognormal measurement noise, so ~<=0.17 is the right target.
    assert float(r.err) < 0.17
    assert float(r.err) < float(r.err0) / 3


def test_all_methods_improve(problem):
    errs = {}
    for m in ["grid", "random", "cma_es", "gp_bo"]:
        r = calibrate(problem, m, seed=4)
        errs[m] = float(r.err)
        assert float(r.err) < float(r.err0), m
    # the paper's headline ordering: random search is the best performer
    assert errs["random"] <= min(errs.values()) + 1e-6


def test_history_monotone(problem):
    r = calibrate(problem, "random", seed=5)
    h = np.asarray(r.history)
    assert (np.diff(h) <= 1e-6).all()


def test_closed_form_matches_engine_walltimes():
    """The fast-path objective and the full engine agree when queueing and
    bandwidth sharing are off (bytes=0, ample cores)."""
    n = 40
    jobs = make_jobs(
        job_id=np.arange(n),
        arrival=np.linspace(0, 1000, n),
        work=np.random.default_rng(0).lognormal(np.log(1000), 0.5, n),
        cores=np.where(np.arange(n) % 2 == 0, 1, 8),
        memory=np.full(n, 1.0),
        bytes_in=np.zeros(n),
        bytes_out=np.zeros(n),
    )
    sites = atlas_like_platform(4, seed=7, cores_range=(4000, 8000))
    prob = make_synthetic_problem(jobs, sites, seed=8, noise_sigma=0.0)
    mae_c, has_c, ge_c = closed_form_objective(prob, prob.sites0.speed)
    mae_e, has_e, ge_e = engine_objective(prob, prob.sites0.speed)
    np.testing.assert_allclose(np.asarray(ge_c), np.asarray(ge_e), rtol=1e-3)
    np.testing.assert_allclose(
        np.asarray(mae_c)[np.asarray(has_c)], np.asarray(mae_e)[np.asarray(has_e)], rtol=1e-3
    )


def test_per_site_rel_mae_shapes():
    n = 10
    jobs = make_jobs(
        job_id=np.arange(n), arrival=np.zeros(n), work=np.ones(n),
        cores=np.ones(n), memory=np.ones(n), bytes_in=np.zeros(n), bytes_out=np.zeros(n),
    )
    site = jnp.zeros(n, jnp.int32)
    wall = jnp.ones(n)
    mae, has = per_site_rel_mae(jobs, site, wall, wall * 1.5, 3)
    assert mae.shape == (3, 2) and has.shape == (3, 2)
    assert float(mae[0, 0]) == pytest.approx(0.5)
    assert not bool(has[1, 0])  # empty site excluded


def test_geomean_ignores_empty_cells():
    mae = jnp.array([[0.1, 0.0], [0.4, 0.0]])
    has = jnp.array([[True, False], [True, False]])
    assert float(geomean_error(mae, has)) == pytest.approx(0.2, rel=1e-3)


def test_perfect_speeds_give_noise_floor():
    jobs = synthetic_panda_jobs(400, seed=9, duration=3600.0)
    sites = atlas_like_platform(10, seed=10)
    prob = make_synthetic_problem(jobs, sites, seed=11, noise_sigma=0.0, misconfig_sigma=0.0)
    _, _, e = closed_form_objective(prob, sites.speed)
    assert float(e) < 1e-5
