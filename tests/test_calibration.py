"""Calibration: objective consistency, all four optimizers, paper's claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import atlas_like_platform, make_jobs, synthetic_panda_jobs
from repro.core.calibration import (
    calibrate,
    closed_form_objective,
    closed_form_walltimes,
    engine_objective,
    geomean_error,
    make_synthetic_problem,
    per_site_rel_mae,
)


@pytest.fixture(scope="module")
def problem():
    jobs = synthetic_panda_jobs(1500, seed=0, duration=24 * 3600.0)
    sites = atlas_like_platform(50, seed=1)
    return make_synthetic_problem(jobs, sites, seed=2)


def test_initial_error_matches_paper_regime(problem):
    _, _, e0 = closed_form_objective(problem, problem.sites0.speed)
    # paper's uncalibrated regime is ~76%; our misconfiguration lands in the
    # same several-tens-of-percent band
    assert 0.3 < float(e0) < 1.5


def test_random_search_hits_paper_band(problem):
    r = calibrate(problem, "random", seed=3)
    # paper: 76% -> 17%. Residual noise floor here is the injected 15%
    # lognormal measurement noise, so ~<=0.17 is the right target.
    assert float(r.err) < 0.17
    assert float(r.err) < float(r.err0) / 3


def test_all_methods_improve(problem):
    errs = {}
    for m in ["grid", "random", "cma_es", "gp_bo"]:
        r = calibrate(problem, m, seed=4)
        errs[m] = float(r.err)
        assert float(r.err) < float(r.err0), m
    # the paper's headline ordering: random search is the best performer
    assert errs["random"] <= min(errs.values()) + 1e-6


def test_history_monotone(problem):
    r = calibrate(problem, "random", seed=5)
    h = np.asarray(r.history)
    assert (np.diff(h) <= 1e-6).all()


def test_closed_form_matches_engine_walltimes():
    """The fast-path objective and the full engine agree when queueing and
    bandwidth sharing are off (bytes=0, ample cores)."""
    n = 40
    jobs = make_jobs(
        job_id=np.arange(n),
        arrival=np.linspace(0, 1000, n),
        work=np.random.default_rng(0).lognormal(np.log(1000), 0.5, n),
        cores=np.where(np.arange(n) % 2 == 0, 1, 8),
        memory=np.full(n, 1.0),
        bytes_in=np.zeros(n),
        bytes_out=np.zeros(n),
    )
    sites = atlas_like_platform(4, seed=7, cores_range=(4000, 8000))
    prob = make_synthetic_problem(jobs, sites, seed=8, noise_sigma=0.0)
    mae_c, has_c, ge_c = closed_form_objective(prob, prob.sites0.speed)
    mae_e, has_e, ge_e = engine_objective(prob, prob.sites0.speed)
    np.testing.assert_allclose(np.asarray(ge_c), np.asarray(ge_e), rtol=1e-3)
    np.testing.assert_allclose(
        np.asarray(mae_c)[np.asarray(has_c)], np.asarray(mae_e)[np.asarray(has_e)], rtol=1e-3
    )


def test_per_site_rel_mae_shapes():
    n = 10
    jobs = make_jobs(
        job_id=np.arange(n), arrival=np.zeros(n), work=np.ones(n),
        cores=np.ones(n), memory=np.ones(n), bytes_in=np.zeros(n), bytes_out=np.zeros(n),
    )
    site = jnp.zeros(n, jnp.int32)
    wall = jnp.ones(n)
    mae, has = per_site_rel_mae(jobs, site, wall, wall * 1.5, 3)
    assert mae.shape == (3, 2) and has.shape == (3, 2)
    assert float(mae[0, 0]) == pytest.approx(0.5)
    assert not bool(has[1, 0])  # empty site excluded


def test_geomean_ignores_empty_cells():
    mae = jnp.array([[0.1, 0.0], [0.4, 0.0]])
    has = jnp.array([[True, False], [True, False]])
    assert float(geomean_error(mae, has)) == pytest.approx(0.2, rel=1e-3)


def test_perfect_speeds_give_noise_floor():
    jobs = synthetic_panda_jobs(400, seed=9, duration=3600.0)
    sites = atlas_like_platform(10, seed=10)
    prob = make_synthetic_problem(jobs, sites, seed=11, noise_sigma=0.0, misconfig_sigma=0.0)
    _, _, e = closed_form_objective(prob, sites.speed)
    assert float(e) < 1e-5


# --------------------------------------------------------------------------
# ISSUE 7: platform calibration — parameter recovery regressions
# --------------------------------------------------------------------------
from repro.core.calibration import (  # noqa: E402
    PlatformBounds,
    calibrate_platform,
    default_bounds,
    make_synthetic_platform_problem,
    platform_params,
    platform_problem_from_trace,
    recovery_error,
)
from repro.core.events import recorded_trace  # noqa: E402


def test_spsa_recovers_hidden_speeds_and_bandwidths():
    """Acceptance gate: hidden per-site speeds AND per-link WAN bandwidths,
    engine-replay objective, SPSA over lane-batched populations — final
    geomean rel-MAE over exercised knobs <= 0.05 and >= 5x better than the
    misconfigured start."""
    problem, truth = make_synthetic_platform_problem(
        n_jobs=48, n_sites=3, seed=3, include=("speed", "bw"),
        trace="engine", wan_frac=0.5, misconfig_sigma=0.7,
    )
    e0 = recovery_error(problem, platform_params(problem, ("speed", "bw")), truth)
    assert e0 > 0.15  # the misconfiguration is material
    res = calibrate_platform(
        problem, method="spsa", objective="engine", include=("speed", "bw"),
        n_iters=100, spsa_dirs=6, a0=0.25, c0=0.1, seed=0, max_rounds=6000,
    )
    e1 = recovery_error(problem, res.params, truth)
    assert e1 <= 0.05
    assert e1 <= e0 / 5.0
    assert float(res.err) < float(res.err0)


def test_grad_recovers_closed_form_truth():
    """The differentiable path: jax.grad through the generalized closed form
    recovers hidden speeds + bandwidths from a closed-form trace."""
    problem, truth = make_synthetic_platform_problem(
        n_jobs=96, n_sites=3, seed=5, include=("speed", "bw"),
        trace="closed_form", wan_frac=0.5, misconfig_sigma=0.7,
    )
    e0 = recovery_error(problem, platform_params(problem, ("speed", "bw")), truth)
    res = calibrate_platform(
        problem, method="grad", objective="closed_form",
        include=("speed", "bw"), n_iters=300, lr=0.1, seed=0,
    )
    e1 = recovery_error(problem, res.params, truth)
    assert e1 <= 0.05
    assert e1 <= e0 / 5.0


def test_calibrate_platform_manifest_sidecar(tmp_path):
    """manifest_out writes a RunManifest sidecar carrying the calibration
    provenance: scenario hash, initial/final params, loss curve."""
    import json

    problem, _ = make_synthetic_platform_problem(
        n_jobs=24, n_sites=3, seed=0, include=("speed",), trace="closed_form"
    )
    out = tmp_path / "calib.json"
    res = calibrate_platform(
        problem, method="grad", objective="closed_form", include=("speed",),
        n_iters=20, seed=0, manifest_out=out,
    )
    side = tmp_path / "calib.json.manifest.json"
    assert side.exists()
    m = json.loads(side.read_text())
    cal = m["extra"]["calibration"]
    assert cal["method"] == "grad" and cal["include"] == ["speed"]
    assert len(cal["scenario_hash"]) == 16
    assert cal["err"] == pytest.approx(float(res.err))
    assert len(cal["loss_curve"]) == 20
    assert cal["params0"]["speed"] is not None
    assert cal["bounds"]["lo"]["speed"] is not None


def test_trace_roundtrip_builds_problem():
    """recorded_trace(engine run) -> platform_problem_from_trace reproduces
    the synthetic problem's histogram columns (job-id aligned)."""
    problem, _ = make_synthetic_platform_problem(
        n_jobs=32, n_sites=3, seed=7, trace="engine", wan_frac=0.5
    )
    from repro.core import simulate
    from repro.core.calibration import pinned_policy

    res = simulate(
        problem.jobs, problem.sites0, pinned_policy(problem.hist_site),
        jax.random.PRNGKey(0), data_policy=problem.data_policy,
        network=problem.network0, replicas=problem.replicas,
        max_rounds=6000,
    )
    rec = recorded_trace(res)
    rebuilt = platform_problem_from_trace(
        problem.jobs, problem.sites0, rec, network0=problem.network0,
        data_policy=problem.data_policy, replicas=problem.replicas,
    )
    assert rebuilt.hist_site.shape == problem.hist_site.shape
    covered = np.asarray(rebuilt.hist_wall) > 0
    assert covered.sum() == rec["job_id"].shape[0]
    np.testing.assert_array_equal(
        np.asarray(rebuilt.hist_site)[covered],
        np.asarray(problem.hist_site)[covered],
    )


@pytest.mark.slow
def test_spsa_recovery_full():
    """Fuller recovery run: all three knob families, larger platform."""
    problem, truth = make_synthetic_platform_problem(
        n_jobs=96, n_sites=4, seed=11, trace="engine", wan_frac=0.5,
        misconfig_sigma=0.6,
    )
    e0 = recovery_error(problem, platform_params(problem), truth)
    res = calibrate_platform(
        problem, method="spsa", objective="engine",
        n_iters=200, spsa_dirs=6, a0=0.25, c0=0.1, seed=0, max_rounds=10_000,
    )
    e1 = recovery_error(problem, res.params, truth)
    assert e1 < e0 / 3.0
    assert float(res.err) < float(res.err0) / 5.0
