"""Workflow DAG subsystem (DESIGN.md §6): gating, cascade-cancel, output
materialization through the replica catalog, workflow-aware policies, and the
ISSUE 3 acceptance demos."""
import jax
import numpy as np
import pytest

from repro.core import (
    CANCELLED,
    DONE,
    FAILED,
    atlas_mc_workflows,
    catalog_invariants,
    chain_workflows,
    get_data_policy,
    get_policy,
    make_jobs,
    make_replicas,
    make_sites,
    make_workflow,
    map_reduce_workflows,
    materialize_outputs,
    scenario_replicas,
    simulate,
    simulate_ensemble,
    uniform_network,
)
from repro.core.events import job_rows, ml_dataset, transfer_rows, workflow_rows
from repro.core.monitor import workflow_timeline
from repro.core.workflows import parent_status


def flat_sites(n=4, cores=16, speed=10.0, fail_rate=0.0):
    return make_sites(
        cores=[cores] * n,
        speed=[speed] * n,
        memory=[256.0] * n,
        bw_in=[1e9] * n,
        bw_out=[1e9] * n,
        fail_rate=[fail_rate] * n,
    )


def diamond_jobs():
    """4-job diamond: 0 -> {1, 2} -> 3."""
    jobs = make_jobs(
        job_id=np.arange(4),
        arrival=np.zeros(4),
        work=np.array([100.0, 200.0, 300.0, 50.0]),
        cores=np.ones(4),
        memory=np.ones(4),
        bytes_in=np.zeros(4),
        bytes_out=np.zeros(4),
    )
    return make_workflow(jobs, [(0, 1), (0, 2), (1, 3), (2, 3)], out_dataset=np.arange(4))


# --------------------------------------------------------------------------
# DAG construction
# --------------------------------------------------------------------------


def test_make_workflow_depth_crit_parents():
    jobs, wf = diamond_jobs()
    np.testing.assert_array_equal(np.asarray(jobs.dag_depth), [0, 1, 1, 2])
    np.testing.assert_array_equal(np.asarray(jobs.n_parents), [0, 1, 1, 2])
    # upward rank: crit[3]=50, crit[1]=250, crit[2]=350, crit[0]=100+350
    np.testing.assert_allclose(np.asarray(jobs.wf_crit), [450.0, 250.0, 350.0, 50.0])
    assert wf.max_parents == 2
    np.testing.assert_array_equal(np.asarray(wf.parents)[3], [1, 2])
    np.testing.assert_array_equal(np.asarray(jobs.wf_id), [0, 0, 0, 0])


def test_make_workflow_rejects_cycles_and_bad_edges():
    jobs = make_jobs(
        job_id=np.arange(3), arrival=np.zeros(3), work=np.ones(3), cores=np.ones(3),
        memory=np.ones(3), bytes_in=np.zeros(3), bytes_out=np.zeros(3),
    )
    with pytest.raises(ValueError, match="cycle"):
        make_workflow(jobs, [(0, 1), (1, 2), (2, 0)])
    with pytest.raises(ValueError, match="self-edge"):
        make_workflow(jobs, [(1, 1)])
    with pytest.raises(ValueError, match="outside"):
        make_workflow(jobs, [(0, 7)])


def test_parent_status_masks():
    jobs, wf = diamond_jobs()
    state = np.array([DONE, DONE, 3, 0], np.int32)  # 2 running, 3 pending
    ready, dead = parent_status(wf.parents, np.asarray(state))
    np.testing.assert_array_equal(np.asarray(ready), [True, True, True, False])
    assert not np.asarray(dead).any()
    state = np.array([DONE, FAILED, DONE, 0], np.int32)
    ready, dead = parent_status(wf.parents, np.asarray(state))
    np.testing.assert_array_equal(np.asarray(dead), [False, False, False, True])


# --------------------------------------------------------------------------
# engine: gating, cascade, makespan structure
# --------------------------------------------------------------------------


def test_children_never_start_before_parents_finish():
    scn = chain_workflows(4, 4, seed=3, work_sigma=0.6)
    res = simulate(scn.jobs, flat_sites(), get_policy("panda_dispatch"), jax.random.PRNGKey(0),
                   workflow=scn.workflow)
    ts = np.asarray(res.jobs.t_start)
    tf = np.asarray(res.jobs.t_finish)
    par = np.asarray(scn.workflow.parents)
    valid = np.asarray(res.jobs.valid)
    assert (np.asarray(res.jobs.state)[valid] == DONE).all()
    for j in np.flatnonzero(valid):
        for p in par[j]:
            if p >= 0:
                assert ts[j] >= tf[p] - 1e-4


def test_chain_makespan_is_at_least_serial_critical_path():
    # one chain on one fast site: makespan >= sum of stage compute times
    scn = chain_workflows(1, 5, seed=0, work_sigma=0.0, base_work=1000.0, input_bytes=0.0)
    sites = flat_sites(1, cores=64, speed=10.0)
    res = simulate(scn.jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0),
                   workflow=scn.workflow)
    assert float(res.makespan) >= 5 * 1000.0 / 10.0 - 1e-3


def test_cascade_cancel_counts_and_partition():
    # all sites always fail -> roots exhaust retries, descendants cancelled
    scn = chain_workflows(3, 4, seed=1)
    res = simulate(scn.jobs, flat_sites(fail_rate=1.0), get_policy("round_robin"),
                   jax.random.PRNGKey(0), workflow=scn.workflow, max_retries=1)
    state = np.asarray(res.jobs.state)[np.asarray(res.jobs.valid)]
    assert (state == FAILED).sum() == 3      # the three roots
    assert (state == CANCELLED).sum() == 9   # all nine descendants
    assert int(res.wf.n_cancelled) == 9
    # partition: every valid job is DONE, FAILED or CANCELLED
    assert np.isin(state, [DONE, FAILED, CANCELLED]).all()
    # cancelled jobs never ran
    cancelled = np.asarray(res.jobs.state) == CANCELLED
    assert not np.isfinite(np.asarray(res.jobs.t_start)[cancelled]).any()
    # resources fully restored
    np.testing.assert_array_equal(np.asarray(res.sites.free_cores), np.asarray(res.sites.cores))


def test_mixed_failure_only_affected_workflow_cancelled():
    # site 0 always fails; chains pinned there die, others finish
    scn = chain_workflows(2, 3, seed=2)
    sites = flat_sites(2, fail_rate=0.0)
    sites = sites._replace(fail_rate=np.array([1.0, 0.0], np.float32))

    # force chain 0 to site 0 and chain 1 to site 1 via a pinning policy
    from repro.core import make_policy

    def score(jobs, sites_, state, clock, rng):
        want = jobs.wf_id[:, None] == np.arange(2)[None, :]
        return want.astype(np.float32)

    res = simulate(scn.jobs, sites, make_policy("pin", score), jax.random.PRNGKey(0),
                   workflow=scn.workflow, max_retries=0)
    state = np.asarray(res.jobs.state)
    wf_id = np.asarray(res.jobs.wf_id)
    assert (state[wf_id == 1] == DONE).all()
    assert (state[wf_id == 0][0] == FAILED) and (state[wf_id == 0][1:] == CANCELLED).all()


# --------------------------------------------------------------------------
# output materialization through the replica catalog (ISSUE acceptance)
# --------------------------------------------------------------------------


def test_fan_in_children_stage_from_parent_site_via_catalog():
    """ISSUE 3 acceptance: a fan-in DAG's children stage in parent outputs
    from the parent's execution site via the replica catalog — the transfer
    stream references the produced datasets."""
    scn = map_reduce_workflows(2, 4, seed=0, root_out_bytes=8e9, map_out_bytes=1e9)
    sites = flat_sites(4)
    net = uniform_network(4, bw=1e8, latency=0.02)
    rep = scenario_replicas(scn, disk_capacity=np.full(4, 1e12))
    res = simulate(
        scn.jobs, sites, get_policy("round_robin"), jax.random.PRNGKey(0),
        workflow=scn.workflow, data_policy=get_data_policy("always_remote"),
        network=net, replicas=rep,
    )
    valid = np.asarray(res.jobs.valid)
    assert (np.asarray(res.jobs.state)[valid] == DONE).all()
    assert int(res.wf.n_produced) == int((np.asarray(scn.jobs.out_dataset)[valid] >= 0).sum())

    rows = transfer_rows(res)
    assert rows, "expected stage-in transfers through the catalog"
    produced = np.asarray(scn.jobs.out_dataset)
    site = np.asarray(res.jobs.site)
    tf = np.asarray(res.jobs.t_finish)
    ts = np.asarray(res.jobs.t_start)
    checked_remote = 0
    for r in rows:
        d = r["dataset"]
        # every staged dataset is one some job produced (dataset id == row)
        assert produced[d] == d
        # the source is the producing parent's execution site, and the read
        # happens only after the parent finished there
        assert r["src"] == f"site{site[d]}"
        assert ts[r["job_id"]] >= tf[d] - 1e-4
        if not r["cache_hit"]:
            checked_remote += 1
    assert checked_remote > 0
    # catalog stays consistent (origin = producer site, pinned)
    inv = catalog_invariants(res.replicas)
    assert inv["accounting_ok"] and inv["origins_ok"]
    org = np.asarray(res.replicas.origin)
    for d in np.flatnonzero(produced >= 0):
        assert org[d] == site[d]


def test_unproduced_outputs_stay_unmaterialized():
    scn = chain_workflows(1, 3, seed=0)
    net = uniform_network(4)
    rep = scenario_replicas(scn, disk_capacity=np.full(4, 1e12))
    res = simulate(
        scn.jobs, flat_sites(fail_rate=1.0), get_policy("round_robin"),
        jax.random.PRNGKey(0), workflow=scn.workflow, max_retries=0,
        data_policy=get_data_policy("cache_on_read"), network=net, replicas=rep,
    )
    # root failed -> nothing produced, descendants cancelled, catalog empty
    assert int(res.wf.n_produced) == 0
    assert not np.asarray(res.replicas.present).any()
    assert (np.asarray(res.replicas.origin) == -1).all()
    assert catalog_invariants(res.replicas)["origins_ok"]


def test_validate_workflow_data_rejects_ungated_readers():
    """A job reading an unmaterialized dataset that no DAG ancestor produces
    is a configuration error: the gate cannot guarantee the data exists."""
    from repro.core import validate_workflow_data

    scn = chain_workflows(1, 3, seed=0)
    scenario_replicas(scn, disk_capacity=np.full(4, 1e12))  # builders pass

    # a reader with no DAG edge to the producer of its input dataset
    bad = make_jobs(
        job_id=np.arange(2), arrival=np.zeros(2), work=np.ones(2), cores=np.ones(2),
        memory=np.ones(2), bytes_in=np.zeros(2), bytes_out=np.zeros(2),
        dataset=np.array([-1, 0]), out_dataset=np.array([0, -1]),
    )
    bad, wf = make_workflow(bad, [], out_dataset=np.array([0, -1]))  # no edges
    rep2 = make_replicas(np.array([1e9], np.float32), np.full(2, 1e12),
                         origin=np.array([-1]), materialized=np.zeros(1, bool))
    with pytest.raises(ValueError, match="no DAG ancestor"):
        validate_workflow_data(bad, wf, rep2)
    # and with no producer at all
    none = bad._replace(out_dataset=np.full(2, -1, np.int32))
    with pytest.raises(ValueError, match="no job produces"):
        validate_workflow_data(none, wf, rep2)


def test_materialize_outputs_pins_origin():
    rep = make_replicas(np.array([5.0, 7.0], np.float32), np.array([100.0, 100.0]),
                        origin=np.array([-1, -1]), materialized=np.zeros(2, bool))
    rep = materialize_outputs(rep, np.array([0, 1]), np.array([1, 0]),
                              np.array([True, False]), 3.0)
    assert bool(rep.present[0, 1]) and not np.asarray(rep.present)[1].any()
    assert int(rep.origin[0]) == 1 and int(rep.origin[1]) == -1
    np.testing.assert_allclose(np.asarray(rep.disk_used), [0.0, 5.0])


# --------------------------------------------------------------------------
# workflow-aware policies
# --------------------------------------------------------------------------


def test_critical_path_first_beats_fifo_on_contended_chain():
    """One deep chain + many fillers on a small site: ranking by upward rank
    pulls each chain stage to the queue head, FIFO strands it behind the
    backlog each stage."""
    n_fill, n_stages = 48, 6
    work = np.concatenate([np.full(n_fill, 1000.0), np.full(n_stages, 1000.0)])
    jobs = make_jobs(
        job_id=np.arange(n_fill + n_stages),
        arrival=np.concatenate([np.zeros(n_fill), np.full(n_stages, 1.0)]),
        work=work,
        cores=np.ones(n_fill + n_stages),
        memory=np.ones(n_fill + n_stages),
        bytes_in=np.zeros(n_fill + n_stages),
        bytes_out=np.zeros(n_fill + n_stages),
    )
    edges = [(n_fill + k, n_fill + k + 1) for k in range(n_stages - 1)]
    jobs, wf = make_workflow(jobs, edges)
    sites = flat_sites(1, cores=8)
    key = jax.random.PRNGKey(0)
    fifo = simulate(jobs, sites, get_policy("panda_dispatch"), key, workflow=wf)
    crit = simulate(jobs, sites, get_policy("critical_path_first"), key, workflow=wf)
    assert (np.asarray(fifo.jobs.state)[: n_fill + n_stages] == DONE).all()
    assert float(crit.makespan) < float(fifo.makespan) * 0.75


def test_rank_is_secondary_to_user_priority():
    """jobs.priority dominates the start order; wf_crit only breaks ties —
    a high-priority standalone job starts before a low-priority chain head
    even under critical_path_first."""
    n = 6
    jobs = make_jobs(
        job_id=np.arange(n),
        arrival=np.zeros(n),
        work=np.full(n, 100.0),
        cores=np.ones(n),
        memory=np.ones(n),
        bytes_in=np.zeros(n),
        bytes_out=np.zeros(n),
        # rows 0-1: a chain with low priority; rows 2-5: standalone, higher
        priority=np.array([0.2, 0.2, 0.9, 0.9, 0.9, 0.9]),
    )
    jobs, wf = make_workflow(jobs, [(0, 1)])
    sites = flat_sites(1, cores=1)  # strictly serial: start order is visible
    res = simulate(jobs, sites, get_policy("critical_path_first"), jax.random.PRNGKey(0),
                   workflow=wf)
    ts = np.asarray(res.jobs.t_start)
    assert ts[2:].max() < ts[0]  # every priority-0.9 job starts before the chain


def test_workflow_locality_places_children_with_parents():
    scn = chain_workflows(4, 3, seed=5)
    pol = get_policy("workflow_locality", workflow=scn.workflow, base="round_robin")
    res = simulate(scn.jobs, flat_sites(4), pol, jax.random.PRNGKey(0),
                   workflow=scn.workflow)
    site = np.asarray(res.jobs.site)
    par = np.asarray(scn.workflow.parents)
    valid = np.asarray(res.jobs.valid)
    for j in np.flatnonzero(valid):
        p = par[j, 0]
        if p >= 0:
            assert site[j] == site[p]


# --------------------------------------------------------------------------
# no-op guarantee, vmap, exports
# --------------------------------------------------------------------------


def test_workflow_none_is_bit_for_bit_noop():
    from repro.core import atlas_like_platform, synthetic_panda_jobs

    jobs = synthetic_panda_jobs(100, seed=4, duration=600.0)
    sites = atlas_like_platform(4, seed=5, fail_rate=0.05)
    pol = get_policy("panda_dispatch")
    r0 = simulate(jobs, sites, pol, jax.random.PRNGKey(0), log_rows=64)
    r1 = simulate(jobs, sites, pol, jax.random.PRNGKey(0), log_rows=64, workflow=None)
    for k in r0.jobs._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(r0.jobs, k)), np.asarray(getattr(r1.jobs, k)), err_msg=f"jobs.{k}"
        )
    for k in r0.log._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(r0.log, k)), np.asarray(getattr(r1.log, k)), err_msg=f"log.{k}"
        )
    assert float(r0.makespan) == float(r1.makespan)
    assert int(r0.rounds) == int(r1.rounds)
    assert r1.wf is None


def test_workflow_under_ensemble_vmap():
    scn = chain_workflows(2, 3, seed=0)
    sites = flat_sites(2)
    cands = np.stack([np.asarray(sites.speed), np.asarray(sites.speed) * 2.0])
    res = simulate_ensemble(
        scn.jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0),
        speed_candidates=np.asarray(cands, np.float32), workflow=scn.workflow,
    )
    assert res.makespan.shape == (2,)
    assert float(res.makespan[1]) < float(res.makespan[0])
    state = np.asarray(res.jobs.state)[:, np.asarray(scn.jobs.valid)]
    assert (state == DONE).all()


def test_atlas_mc_size_profile():
    scn = atlas_mc_workflows(2, seed=0, evnt_bytes=2e8)
    sz = scn.ds_sizes.reshape(2, 4)
    # evgen -> simul inflates 20x; recon and deriv reduce
    np.testing.assert_allclose(sz[0], [2e8, 4e9, 5e8, 5e7])
    cores = np.asarray(scn.jobs.cores)[: 8].reshape(2, 4)
    np.testing.assert_array_equal(cores[0], [1, 8, 8, 1])


# --------------------------------------------------------------------------
# exports: stable ML schema, workflow rows, timeline
# --------------------------------------------------------------------------


def test_ml_schema_stable_across_plain_and_dag_runs():
    from repro.core import atlas_like_platform, synthetic_panda_jobs

    plain = simulate(
        synthetic_panda_jobs(30, seed=0, duration=300.0),
        atlas_like_platform(2, seed=1),
        get_policy("panda_dispatch"), jax.random.PRNGKey(0),
    )
    scn = chain_workflows(3, 3, seed=0)
    dag = simulate(scn.jobs, flat_sites(2), get_policy("panda_dispatch"),
                   jax.random.PRNGKey(0), workflow=scn.workflow)
    ds_p, ds_d = ml_dataset(plain), ml_dataset(dag)
    assert list(ds_p["feature_names"]) == list(ds_d["feature_names"])
    for nm in ("n_parents", "dag_depth", "wf_id"):
        assert nm in list(ds_p["feature_names"])
    i = list(ds_p["feature_names"]).index("wf_id")
    assert (ds_p["features"][:, i] == -1).all()       # constant -1 without a DAG
    assert (ds_d["features"][:, i] >= 0).all()
    j = list(ds_p["feature_names"]).index("dag_depth")
    assert (ds_p["features"][:, j] == 0).all()

    rows_p, rows_d = job_rows(plain), job_rows(dag)
    assert set(rows_p[0]) == set(rows_d[0])
    assert all(r["wf_id"] == -1 and r["n_parents"] == 0 for r in rows_p)
    assert workflow_rows(plain) == []
    wrows = workflow_rows(dag)
    assert len(wrows) == 3 and all(r["completed"] for r in wrows)
    assert all(r["makespan"] is not None and r["makespan"] > 0 for r in wrows)


def test_example_workflow_chain_acceptance():
    """ISSUE 3 acceptance: in examples/workflow_chain.py, locality-aware
    beats remote-always and critical-path-first beats FIFO on makespan."""
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "examples"))
    try:
        import workflow_chain
    finally:
        sys.path.pop(0)
    assert workflow_chain.locality_vs_remote() > 1.0
    assert workflow_chain.critical_path_vs_fifo() > 1.0


def test_workflow_timeline_monotone_in_depth():
    scn = chain_workflows(2, 4, seed=0)
    res = simulate(scn.jobs, flat_sites(2), get_policy("panda_dispatch"),
                   jax.random.PRNGKey(0), workflow=scn.workflow)
    ids, td = workflow_timeline(res)
    assert ids.shape == (2,) and td.shape == (2, 4)
    assert np.isfinite(td).all()
    assert (np.diff(td, axis=1) > 0).all()  # later stages finish later
