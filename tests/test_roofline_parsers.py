"""Roofline HLO-text parsers: synthetic-HLO unit tests (no compilation)."""
import jax.numpy as jnp
import numpy as np

from repro.launch.measure import _collective_bytes_corrected, _fusion_adjusted_bytes
from repro.launch.roofline import _shape_bytes, collective_bytes
from repro.train.optimizer import _dq8_block, _q8_block

HLO = """
HloModule jit_fn

%fused_computation.1 (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128] parameter(0)
  %big_internal = f32[4096,4096] broadcast(%p0), dimensions={0,1}
  ROOT %r = f32[128,128] add(%p0, %p0)
}

ENTRY %main (a: bf16[256,512], w: bf16[512,512]) -> bf16[256,512] {
  %a = bf16[256,512] parameter(0)
  %w = bf16[512,512] parameter(1)
  %ag = bf16[512,512] all-gather(%w), replica_groups={}, dimensions={0}
  %d = bf16[256,512] dot(%a, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[256,512] all-reduce(%d), to_apply=%add_comp
  %f = f32[128,128] fusion(%ar), kind=kLoop, calls=%fused_computation.1
  %rs = bf16[128,512] reduce-scatter(%d), dimensions={0}
  %cp = bf16[256,512] collective-permute(%d), source_target_pairs={{0,1}}
  ROOT %out = bf16[256,512] copy(%d)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[256,512]") == 256 * 512 * 2
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert _shape_bytes("token[]") == 0


def test_collective_bytes_kinds():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 512 * 512 * 2
    assert out["all-reduce"] == 256 * 512 * 4
    assert out["reduce-scatter"] == 128 * 512 * 2
    assert out["collective-permute"] == 256 * 512 * 2


def test_collective_bytes_corrected_halves_f32():
    total, breakdown = _collective_bytes_corrected(HLO, bf16_correct=True)
    # all-reduce result f32 counted at 2 B/elem, cost factor 2
    assert breakdown["all-reduce"] == 2 * (256 * 512 * 2)
    # bf16 untouched
    assert breakdown["all-gather"] == 512 * 512 * 2
    total_raw, _ = _collective_bytes_corrected(HLO, bf16_correct=False)
    assert total_raw > total


def test_fusion_adjusted_bytes_skips_fused_internals():
    b = _fusion_adjusted_bytes(HLO, bf16_correct=False)
    # the 4096x4096 broadcast inside the fused computation must NOT count
    assert b < 4096 * 4096 * 4
    # but the dot + collectives + fusion boundary do
    assert b > 256 * 512 * 2


def test_q8_roundtrip_multiblock():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 1000)).astype(np.float32))
    q, s = _q8_block(x)
    assert q.shape == x.shape and q.dtype == jnp.int8
    assert s.shape == (4, 4)  # ceil(1000/256) blocks
    rel = np.abs(np.asarray(_dq8_block(q, s)) - np.asarray(x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 0.02
