"""Flight recorder (ISSUE 6): spans/sinks, manifests, watch(), lane tracing."""
import io
import json

import jax
import numpy as np
import pytest

from repro.core import (
    Scenario,
    atlas_like_platform,
    get_policy,
    simulate,
    stack_scenarios,
    synthetic_panda_jobs,
)
from repro.core.monitor import follow_stream, watch
from repro.core.telemetry import (
    CallbackSink,
    MemorySink,
    NDJSONSink,
    NullRecorder,
    TraceRecorder,
    iter_ndjson,
    lane_occupancy,
    manifest_drift,
    manifest_path,
    read_manifest,
    run_manifest,
    scenario_hash,
    write_manifest,
)


def tiny_scenario(n=60, seed=0):
    jobs = synthetic_panda_jobs(n, seed=seed, duration=900.0)
    sites = atlas_like_platform(4, seed=1)
    return jobs, sites, get_policy("panda_dispatch"), jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# recorder + sinks
# --------------------------------------------------------------------------


def test_recorder_span_counter_roundtrip():
    sink = MemorySink()
    rec = TraceRecorder(sink=sink)
    with rec.span("a"):
        pass
    with rec.span("a"):
        pass
    rec.count("hits")
    rec.count("hits", 2)
    rec.gauge("lanes", 16)
    rec.note("mode", "scan")
    s = rec.summary()
    assert s["spans"]["a"]["count"] == 2
    assert s["spans"]["a"]["total_s"] >= 0
    assert s["counters"] == {"hits": 3, "lanes": 16}
    assert s["notes"] == {"mode": "scan"}
    # every closed span streamed to the sink
    assert [r["type"] for r in sink.records] == ["span", "span"]
    assert rec.total("a") >= 0 and rec.total("missing") == 0.0


def test_null_recorder_is_inert():
    rec = NullRecorder()
    with rec.span("x"):
        pass
    rec.count("c")
    rec.gauge("g", 1)
    assert rec.summary() == dict(spans={}, counters={}, notes={})


def test_callback_and_ndjson_sinks(tmp_path):
    seen = []
    cb = CallbackSink(seen.append)
    cb.emit({"a": 1})
    assert seen == [{"a": 1}]

    path = tmp_path / "run.ndjson"
    with NDJSONSink(path) as sink:
        sink.emit({"type": "frame", "i": 0})
        sink.emit({"type": "end"})
    recs = list(iter_ndjson(path))
    assert [r["type"] for r in recs] == ["frame", "end"]
    # stops at the end record even with trailing garbage lines
    with open(path, "a") as f:
        f.write(json.dumps({"type": "frame", "i": 99}) + "\n")
    assert len(list(iter_ndjson(path))) == 2


# --------------------------------------------------------------------------
# manifests
# --------------------------------------------------------------------------


def test_scenario_hash_stable_and_sensitive():
    jobs, sites, *_ = tiny_scenario()
    h1 = scenario_hash(jobs, sites)
    assert h1 == scenario_hash(jobs, sites)  # deterministic
    jobs2, *_ = tiny_scenario(seed=7)
    assert h1 != scenario_hash(jobs2, sites)  # content-sensitive
    assert h1 != scenario_hash(jobs, sites, None)  # structure-sensitive


def test_manifest_roundtrip_and_drift(tmp_path):
    jobs, sites, pol, key = tiny_scenario()
    rec = TraceRecorder()
    simulate(jobs, sites, pol, key, recorder=rec)
    man = run_manifest(jobs=jobs, sites=sites, recorder=rec, extra={"k": 1})
    assert man["schema"] == "cgsim.run_manifest/v1"
    assert man["jax"]["backend"] == jax.default_backend()
    assert man["scenario"]["n_jobs"] == 60
    assert man["scenario"]["hash"] == scenario_hash(jobs, sites, None)
    assert "execute" in man["telemetry"]["spans"]

    artifact = tmp_path / "run.ndjson"
    artifact.write_text("")
    side = write_manifest(artifact, man)
    assert side == manifest_path(artifact)
    assert side.name == "run.ndjson.manifest.json"
    man2 = read_manifest(artifact)
    assert manifest_drift(man2, man) == []
    stale = json.loads(json.dumps(man))
    stale["jax"]["device_count"] = 512
    diffs = manifest_drift(man, stale)
    assert [d["key"] for d in diffs] == ["jax.device_count"]


# --------------------------------------------------------------------------
# engine instrumentation
# --------------------------------------------------------------------------


def test_simulate_with_recorder_matches_and_records():
    jobs, sites, pol, key = tiny_scenario()
    base = simulate(jobs, sites, pol, key)
    rec = TraceRecorder()
    res = simulate(jobs, sites, pol, key, recorder=rec)
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s = rec.summary()
    # one call = either a fresh compile or a cache hit, never both
    assert ("trace_compile" in s["spans"]) != ("dispatch" in s["spans"])
    assert "execute" in s["spans"]
    assert s["counters"]["rounds_executed"] == int(base.rounds)
    assert s["counters"]["early_exit_rounds"] == (
        s["counters"]["round_budget"] - int(base.rounds)
    )
    assert s["counters"]["n_jobs"] == 60
    # warm second call must be a dispatch, not a recompile
    rec2 = TraceRecorder()
    simulate(jobs, sites, pol, key, recorder=rec2)
    assert "dispatch" in rec2.summary()["spans"]


# --------------------------------------------------------------------------
# watch(): the segmented driver
# --------------------------------------------------------------------------


def test_watch_is_bitwise_identical_to_simulate():
    jobs, sites, pol, key = tiny_scenario()
    base = simulate(jobs, sites, pol, key, log_rows=32)
    sink = MemorySink()
    res = watch(jobs, sites, pol, key, frames=6, render=False, sink=sink, log_rows=32)
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    types = [r["type"] for r in sink.records]
    assert types[0] == "run_meta" and types[-1] == "end"
    frames = [r for r in sink.records if r["type"] == "frame"]
    assert frames, "watch emitted no frames"
    need = {"round", "time", "counts", "site_free", "site_queued", "site_running"}
    assert need <= set(frames[0])
    assert sink.records[-1]["rounds"] == int(base.rounds)


def test_watch_respects_horizon():
    jobs, sites, pol, key = tiny_scenario()
    hz = 5000.0
    base = simulate(jobs, sites, pol, key, horizon=hz)
    res = watch(jobs, sites, pol, key, frames=4, horizon=hz, render=False)
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_watch_ndjson_stream_renders_via_follow(tmp_path):
    jobs, sites, pol, key = tiny_scenario()
    path = tmp_path / "run.ndjson"
    rec = TraceRecorder()
    with NDJSONSink(path) as sink:
        res = watch(jobs, sites, pol, key, frames=5, render=False, sink=sink,
                    recorder=rec)
    write_manifest(path, run_manifest(jobs=jobs, sites=sites, recorder=rec))
    # a separate consumer renders the stream from the file alone
    out = io.StringIO()
    shown = follow_stream(path, clear=False, out=out)
    assert shown > 0
    text = out.getvalue()
    assert "cores" in text and "end:" in text
    assert f"rounds={int(res.rounds)}" in text
    assert rec.summary()["counters"]["watch_segments"] > 0
    assert read_manifest(path)["scenario"]["n_jobs"] == 60


def test_watch_renders_frames_to_out():
    jobs, sites, pol, key = tiny_scenario(n=20)
    out = io.StringIO()
    watch(jobs, sites, pol, key, frames=3, out=out)
    assert "t=" in out.getvalue()


# --------------------------------------------------------------------------
# lane occupancy + padding stats
# --------------------------------------------------------------------------


def _lane_pair():
    """Two-lane ensemble where lane 0 is deliberately near-idle: 5 jobs vs
    60, stacked (so lane 0 is also mostly padding)."""
    sites = atlas_like_platform(3, seed=1)
    idle = Scenario(synthetic_panda_jobs(5, seed=2, duration=200.0), sites)
    busy = Scenario(synthetic_panda_jobs(60, seed=3, duration=2000.0), sites)
    return [idle, busy]


def test_lane_occupancy_idle_lane():
    from repro.core.distributed import simulate_many_sharded

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    stacked = stack_scenarios(_lane_pair())
    rec = TraceRecorder()
    res = simulate_many_sharded(
        stacked, get_policy("panda_dispatch"), jax.random.PRNGKey(0), mesh,
        lane_mode="scan", recorder=rec, log_rows=64,
    )
    occ = lane_occupancy(res)
    lanes = occ["lanes"]
    assert lanes[1]["active_frac"] == 1.0
    # the idle lane retires in a fraction of the busy lane's rounds
    assert lanes[0]["active_frac"] < 0.5
    assert lanes[0]["rounds"] < lanes[1]["rounds"]
    assert lanes[0]["padding_frac"] > 0.8  # 5 valid rows padded to 60
    # frame log present -> phase-skip work-round rate per lane
    assert 0.0 <= lanes[0]["work_round_frac"] <= 1.0
    assert lanes[0]["skip_frac"] == pytest.approx(1.0 - lanes[0]["work_round_frac"])
    s = occ["summary"]
    assert s["n_lanes"] == 2
    assert 0.0 < s["lockstep_waste_frac"] < 1.0
    # the sharded-run recorder saw the same lanes
    c = rec.summary()["counters"]
    assert c["lanes"] == 2
    assert c["lane_rounds_max"] == lanes[1]["rounds"]
    assert "ensemble_run" in rec.summary()["spans"]


def test_padding_stats_bucketed_beats_flat():
    sites = atlas_like_platform(3, seed=1)
    scenarios = [
        Scenario(synthetic_panda_jobs(n, seed=i, duration=500.0), sites)
        for i, n in enumerate((8, 10, 48, 50))
    ]
    buckets = stack_scenarios(scenarios, buckets=2)
    stats = buckets.padding_stats()
    assert [r["lanes"] for r in stats["buckets"]] == [2, 2]
    s = stats["summary"]
    assert s["n_scenarios"] == 4
    assert s["used_rows"] == 8 + 10 + 48 + 50
    # bucketing strictly reduces dense rows on this ragged ensemble
    assert s["saved_rows"] > 0
    assert s["waste_frac"] < s["flat_waste_frac"]
    for r in stats["buckets"]:
        assert 0.0 <= r["waste_frac"] < 1.0
