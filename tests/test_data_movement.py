"""Data movement & replica management subsystem (DESIGN.md §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DONE,
    atlas_like_network,
    atlas_like_platform,
    catalog_invariants,
    get_data_policy,
    get_policy,
    make_jobs,
    make_replicas,
    make_sites,
    matrix_network,
    network_from_sites,
    shared_transfer_times,
    simulate,
    star_network,
    synthetic_panda_jobs,
    tiered_network,
    uniform_network,
    zipf_dataset_sizes,
)
from repro.core.engine import simulate_ensemble
from repro.core.events import to_csv, to_json, transfer_rows
from repro.core.replicas import insert_mask, insert_replicas, nearest_source


def data_jobs(n=24, n_datasets=6, *, seed=0, work=50.0, ds_bytes=None, arrival=None):
    rng = np.random.default_rng(seed)
    jobs = make_jobs(
        job_id=np.arange(n),
        arrival=arrival if arrival is not None else np.zeros(n),
        work=np.full(n, work),
        cores=np.ones(n, np.int32),
        memory=np.full(n, 1.0),
        bytes_in=np.zeros(n),
        bytes_out=np.zeros(n),
        dataset=rng.integers(0, n_datasets, n),
    )
    return jobs


def grid(n_sites=3, cores=32):
    return make_sites(
        cores=np.full(n_sites, cores),
        speed=np.full(n_sites, 10.0),
        memory=np.full(n_sites, 1e9),
        bw_in=np.full(n_sites, 1e12),
        bw_out=np.full(n_sites, 1e12),
    )


# --------------------------------------------------------------------------
# topology builders
# --------------------------------------------------------------------------


def test_topology_builders_shapes_and_diagonal():
    for net in (
        uniform_network(4, bw=1e9, latency=0.01),
        star_network(np.full(4, 1e9), latency=np.full(4, 0.02)),
        tiered_network([0, 1, 2, 2], [4e10, 1e10, 1e9]),
        matrix_network(np.full((4, 4), 1e9), np.full((4, 4), 0.01)),
        network_from_sites(grid(4)),
        atlas_like_network(4, seed=0),
    ):
        assert net.bw.shape == (4, 4) and net.latency.shape == (4, 4)
        # intra-site reads are effectively free
        assert float(jnp.diag(net.bw).min()) >= 1e14
        assert float(jnp.diag(net.latency).max()) == 0.0
        assert float(net.bw.min()) > 0


def test_star_network_bottleneck():
    net = star_network(np.array([1e9, 4e9, 2e9]), latency=np.array([0.01, 0.02, 0.03]))
    assert float(net.bw[0, 1]) == pytest.approx(1e9)  # min(up[0], down[1])
    assert float(net.bw[1, 2]) == pytest.approx(2e9)
    assert float(net.latency[0, 2]) == pytest.approx(0.04)


def test_tiered_network_bottlenecks_on_thinner_tier():
    net = tiered_network([0, 2], [1e11, 1e10, 1e9])
    assert float(net.bw[0, 1]) == pytest.approx(1e9)
    assert float(net.bw[1, 0]) == pytest.approx(1e9)


# --------------------------------------------------------------------------
# bandwidth sharing
# --------------------------------------------------------------------------


def test_link_sharing_conserves_bandwidth():
    net = uniform_network(3, bw=1e9, latency=0.0)
    # 4 concurrent transfers on link 0->1, 2 on 2->1, one inactive row
    src = jnp.array([0, 0, 0, 0, 2, 2, 0], jnp.int32)
    dst = jnp.array([1, 1, 1, 1, 1, 1, 2], jnp.int32)
    nbytes = jnp.full((7,), 1e9)
    active = jnp.array([True] * 6 + [False])
    t, bw_eff = shared_transfer_times(net, src, dst, nbytes, active)
    bw_eff = np.asarray(bw_eff)
    assert bw_eff[:4].sum() == pytest.approx(1e9, rel=1e-6)  # link 0->1 saturated
    assert bw_eff[4:6].sum() == pytest.approx(1e9, rel=1e-6)
    assert bw_eff[6] == 0.0 and float(t[6]) == 0.0
    # each of the 4 flows on 0->1 takes 4x the solo time
    assert float(t[0]) == pytest.approx(4.0, rel=1e-5)


def test_transfer_time_includes_latency():
    net = uniform_network(2, bw=1e9, latency=0.5)
    t, _ = shared_transfer_times(
        net, jnp.array([0]), jnp.array([1]), jnp.array([1e9]), jnp.array([True])
    )
    assert float(t[0]) == pytest.approx(1.5, rel=1e-5)


# --------------------------------------------------------------------------
# replica catalog
# --------------------------------------------------------------------------


def test_make_replicas_origin_pinned_and_accounted():
    sizes = np.array([10.0, 20.0, 30.0])
    rep = make_replicas(sizes, disk_capacity=np.array([100.0, 100.0]), origin=[0, 1, 0])
    inv = catalog_invariants(rep)
    assert inv["capacity_ok"] and inv["accounting_ok"] and inv["origins_ok"]
    assert float(rep.disk_used[0]) == pytest.approx(40.0)
    assert float(rep.disk_used[1]) == pytest.approx(20.0)


def test_insert_respects_capacity_with_lru_eviction():
    # site 1 cap 55: holds ds2 (origin, 30). Insert ds0 (10) -> fits (40).
    # Insert ds1 (20): needs 5 -> evicts LRU ds0 (non-origin), lands at 50.
    sizes = np.array([10.0, 20.0, 30.0])
    rep = make_replicas(sizes, disk_capacity=np.array([100.0, 55.0]), origin=[0, 0, 1])
    rep = insert_replicas(rep, jnp.array([0]), jnp.array([1]), jnp.array([True]), 1.0)
    assert bool(rep.present[0, 1])
    rep = insert_replicas(rep, jnp.array([1]), jnp.array([1]), jnp.array([True]), 2.0)
    assert not bool(rep.present[0, 1])  # evicted
    assert bool(rep.present[1, 1])
    assert bool(rep.present[2, 1])  # origin never evicted
    inv = catalog_invariants(rep)
    assert inv["capacity_ok"] and inv["accounting_ok"] and inv["origins_ok"]


def test_insert_skipped_when_it_can_never_fit():
    sizes = np.array([10.0, 200.0])
    rep = make_replicas(sizes, disk_capacity=np.array([300.0, 50.0]), origin=[0, 0])
    rep = insert_replicas(rep, jnp.array([1]), jnp.array([1]), jnp.array([True]), 1.0)
    assert not bool(rep.present[1, 1])  # 200 > cap 50: skipped, not crammed
    assert catalog_invariants(rep)["capacity_ok"]


def test_nearest_source_prefers_fat_link_and_local():
    sizes = np.array([1e9])
    rep = make_replicas(sizes, disk_capacity=np.full(3, 1e10), origin=[0])
    rep = insert_mask(rep, jnp.array([[True, True, False]]), 0.0)
    bw = np.full((3, 3), 1e8)
    bw[1, 2] = 1e10  # site 1 has the fat link to site 2
    net = matrix_network(bw, np.zeros((3, 3)))
    src = nearest_source(rep, net, jnp.array([0, 0]), jnp.array([2, 1]))
    assert int(src[0]) == 1  # remote read picks the fat link
    assert int(src[1]) == 1  # local replica wins outright


# --------------------------------------------------------------------------
# engine integration
# --------------------------------------------------------------------------


def run_with(policy_name, jobs, sites, net, rep, **kw):
    return simulate(
        jobs,
        sites,
        get_policy("round_robin"),
        jax.random.PRNGKey(0),
        data_policy=get_data_policy(policy_name),
        network=net,
        replicas=rep,
        **kw,
    )


def test_cache_hit_means_zero_transfer_bytes():
    # two jobs, same dataset, same (single) extra site, serialized on 1 core:
    # first read stages over the WAN, second is a local cache hit.
    jobs = make_jobs(
        job_id=[0, 1], arrival=[0.0, 0.1], work=[50.0, 50.0], cores=[1, 1],
        memory=[1.0, 1.0], bytes_in=[0.0, 0.0], bytes_out=[0.0, 0.0], dataset=[0, 0],
    )
    sites = grid(2, cores=1)._replace(active=jnp.array([False, True]))
    net = uniform_network(2, bw=1e8, latency=0.0)
    rep = make_replicas(np.array([1e9]), disk_capacity=np.full(2, 1e10), origin=[0])
    res = run_with("cache_on_read", jobs, sites, net, rep)
    xb = np.asarray(res.jobs.xfer_bytes)[:2]
    assert xb[0] == pytest.approx(1e9)
    assert xb[1] == 0.0  # cache hit
    assert np.asarray(res.jobs.xfer_time)[1] == 0.0
    assert int(res.replicas.n_hits) == 1 and int(res.replicas.n_transfers) == 1
    # the hit saves the 10s transfer: walltimes differ by exactly that
    wall = np.asarray(res.jobs.t_finish - res.jobs.t_start)[:2]
    assert wall[0] - wall[1] == pytest.approx(10.0, rel=1e-4)


def test_capacity_invariant_holds_under_pressure():
    jobs = data_jobs(64, n_datasets=16, seed=1)
    sites = grid(4)
    net = uniform_network(4, bw=1e9, latency=0.001)
    # site 0 is the data lake holding all origins; the other sites run tiny
    # caches with room for ~2 datasets -> constant eviction churn
    rep = make_replicas(
        zipf_dataset_sizes(16, seed=2, mean_bytes=1e9),
        disk_capacity=np.array([1e12, 2.5e9, 2.5e9, 2.5e9]),
        origin=np.zeros(16, np.int32),
    )
    assert catalog_invariants(rep)["capacity_ok"], "test setup must start valid"
    res = run_with("cache_on_read", jobs, sites, net, rep)
    inv = catalog_invariants(res.replicas)
    assert inv["capacity_ok"] and inv["accounting_ok"] and inv["origins_ok"]
    state = np.asarray(res.jobs.state)[np.asarray(res.jobs.valid)]
    assert (state == DONE).all()


def test_cache_on_read_beats_always_remote():
    """Acceptance demo: on a Zipf workload with transfer-dominated jobs,
    caching measurably cuts both WAN bytes and makespan."""
    jobs = synthetic_panda_jobs(
        96, seed=0, duration=60.0, multicore_frac=0.0, mean_walltime_hours=0.005,
        n_datasets=12, zipf_alpha=1.3,
    )
    # few cores per site -> jobs run in waves, so hot datasets are re-read;
    # thin WAN -> staging dominates the critical path
    sites = grid(4, cores=8)
    net = uniform_network(4, bw=2e8, latency=0.01)
    rep = make_replicas(
        zipf_dataset_sizes(12, seed=2, mean_bytes=50e9),
        disk_capacity=np.full(4, 1e12),
        seed=3,
    )
    remote = run_with("always_remote", jobs, sites, net, rep)
    cached = run_with("cache_on_read", jobs, sites, net, rep)
    assert float(cached.replicas.bytes_moved) < 0.7 * float(remote.replicas.bytes_moved)
    assert float(cached.makespan) < 0.9 * float(remote.makespan)
    for res in (remote, cached):
        state = np.asarray(res.jobs.state)[np.asarray(res.jobs.valid)]
        assert (state == DONE).all()


def test_pre_place_hot_reduces_transfers():
    jobs = data_jobs(64, n_datasets=8, seed=4)
    sites = grid(4)
    net = uniform_network(4, bw=1e9, latency=0.01)
    rep = make_replicas(
        zipf_dataset_sizes(8, seed=5, mean_bytes=5e9), disk_capacity=np.full(4, 1e12), seed=6
    )
    base = run_with("always_remote", jobs, sites, net, rep)
    pre = simulate(
        jobs, sites, get_policy("round_robin"), jax.random.PRNGKey(0),
        data_policy=get_data_policy("pre_place_hot", hot_frac=0.5, n_copies=4),
        network=net, replicas=rep,
    )
    assert float(pre.replicas.bytes_moved) < float(base.replicas.bytes_moved)


def test_datasetless_jobs_keep_flat_link_model():
    """dataset = -1 rows take the flat per-site path even under a DataPolicy."""
    jobs = synthetic_panda_jobs(48, seed=2, duration=300.0)  # no datasets
    sites = atlas_like_platform(3, seed=3)
    net = atlas_like_network(3, seed=4)
    rep = make_replicas(np.array([1e9]), disk_capacity=np.full(3, 1e12), origin=[0])
    r_plain = simulate(jobs, sites, get_policy("round_robin"), jax.random.PRNGKey(0))
    r_data = run_with("cache_on_read", jobs, sites, net, rep)
    # different policy objects force a retrace, but dynamics must agree
    np.testing.assert_allclose(
        np.asarray(r_plain.jobs.t_finish), np.asarray(r_data.jobs.t_finish), rtol=1e-5
    )
    assert float(r_data.replicas.bytes_moved) == 0.0


def test_engine_with_data_policy_vmaps_in_ensemble():
    jobs = data_jobs(32, n_datasets=6, seed=7)
    sites = grid(3)
    net = uniform_network(3, bw=1e9, latency=0.01)
    rep = make_replicas(
        zipf_dataset_sizes(6, seed=8, mean_bytes=2e9), disk_capacity=np.full(3, 1e11), seed=9
    )
    cands = sites.speed[None, :] * jnp.array([[0.5], [1.0], [2.0]])
    res = simulate_ensemble(
        jobs, sites, get_policy("round_robin"), jax.random.PRNGKey(1),
        speed_candidates=cands,
        data_policy=get_data_policy("cache_on_read"), network=net, replicas=rep,
    )
    assert res.makespan.shape == (3,)
    assert np.isfinite(np.asarray(res.makespan)).all()
    assert res.replicas.present.shape == (3, 6, 3)
    # faster sites don't change how many bytes must move on first reads
    assert (np.asarray(res.replicas.bytes_moved) > 0).all()


def test_transfer_rows_export_roundtrip():
    jobs = data_jobs(32, n_datasets=5, seed=10, arrival=np.linspace(0, 10, 32))
    sites = grid(3)
    net = uniform_network(3, bw=1e9, latency=0.01)
    rep = make_replicas(
        zipf_dataset_sizes(5, seed=11, mean_bytes=2e9), disk_capacity=np.full(3, 1e12), seed=12
    )
    res = run_with("cache_on_read", jobs, sites, net, rep)
    rows = transfer_rows(res)
    assert len(rows) == 32  # one stage-in per dataset-carrying job
    assert {"time", "job_id", "dataset", "src", "dst", "bytes", "duration", "cache_hit",
            "queue_wait", "queue_depth"} == set(rows[0])
    # transfers-off runs carry the inert defaults in the new columns
    assert all(r["queue_wait"] == 0.0 and r["queue_depth"] == -1 for r in rows)
    times = [r["time"] for r in rows]
    assert times == sorted(times)
    moved = sum(r["bytes"] for r in rows)
    assert moved == pytest.approx(float(res.replicas.bytes_moved), rel=1e-5)
    hits = sum(r["cache_hit"] for r in rows)
    assert hits == int(res.replicas.n_hits)
    assert all((r["bytes"] == 0.0) == r["cache_hit"] for r in rows)
    # serialization round-trips
    csv_text = to_csv(rows)
    assert len(csv_text.splitlines()) == len(rows) + 1
    import json

    assert json.loads(to_json(rows))[0]["dataset"] == rows[0]["dataset"]


def test_transfer_rows_empty_without_data_policy():
    # dataset ids alone don't fabricate a transfer log: without a DataPolicy
    # nothing staged through the subsystem, so no rows
    jobs = data_jobs(16, n_datasets=4, seed=20)
    res = simulate(jobs, grid(2), get_policy("round_robin"), jax.random.PRNGKey(0))
    assert transfer_rows(res) == []


def test_flat_jobs_dont_share_ingress_with_dataset_jobs():
    # one flat-link job and one (locally-replicated) dataset job start in the
    # same round: the flat job's stage-in must use the full ingress link, not
    # a 2-way share with the WAN-staged job
    jobs = make_jobs(
        job_id=[0, 1], arrival=[0.0, 0.0], work=[100.0, 100.0], cores=[1, 1],
        memory=[1.0, 1.0], bytes_in=[1e9, 0.0], bytes_out=[0.0, 0.0], dataset=[-1, 0],
    )
    sites = make_sites(cores=[2], speed=[10.0], memory=[64.0], bw_in=[1e8], bw_out=[1e12])
    net = uniform_network(1, bw=1e9, latency=0.0)
    rep = make_replicas(np.array([1e9]), disk_capacity=np.array([1e12]), origin=[0])
    res = run_with("always_remote", jobs, sites, net, rep)
    wall = np.asarray(res.jobs.t_finish - res.jobs.t_start)
    assert wall[0] == pytest.approx(10.0 + 10.0, abs=1e-2)  # full 1e8 link: 10s stage + 10s compute
    assert wall[1] == pytest.approx(10.0, abs=1e-2)          # local replica: compute only


def test_network_timeline_conserves_bytes_with_sparse_monitoring():
    jobs = data_jobs(48, n_datasets=8, seed=21)
    sites = grid(3, cores=8)
    net = uniform_network(3, bw=1e9, latency=0.01)
    rep = make_replicas(
        zipf_dataset_sizes(8, seed=22, mean_bytes=2e9), disk_capacity=np.full(3, 1e12), seed=23
    )
    from repro.core.monitor import network_timeline

    res = run_with("cache_on_read", jobs, sites, net, rep, log_rows=512, monitor_every=3)
    nt = network_timeline(res)
    # bytes moved between writes accumulate into the next logged frame
    assert nt.sum() == pytest.approx(float(res.replicas.bytes_moved), rel=1e-4)


def test_monitor_storage_and_network_columns():
    from repro.core.monitor import network_timeline, render_frame, storage_timeline
    from repro.core.events import log_frames

    jobs = data_jobs(48, n_datasets=8, seed=13, arrival=np.linspace(0, 60, 48))
    sites = grid(3)
    net = uniform_network(3, bw=1e9, latency=0.01)
    rep = make_replicas(
        zipf_dataset_sizes(8, seed=14, mean_bytes=2e9), disk_capacity=np.full(3, 1e11), seed=15
    )
    res = run_with("cache_on_read", jobs, sites, net, rep, log_rows=128)
    frames = log_frames(res)
    assert frames and "site_disk" in frames[0] and "site_net_in" in frames[0]
    st = storage_timeline(res)
    nt = network_timeline(res)
    assert st.shape == nt.shape and st.shape[1] == sites.capacity
    assert st.max() > 0  # caches filled
    assert nt.sum() == pytest.approx(float(res.replicas.bytes_moved), rel=1e-4)
    txt = render_frame(frames[-1], np.asarray(res.sites.cores), disk_cap=np.asarray(rep.disk_cap))
    assert "disk|" in txt and "net_in=" in txt


# --------------------------------------------------------------------------
# nearest_source sentinel handling + pinned-origin invariant (ISSUE 9)
# --------------------------------------------------------------------------


def test_nearest_source_masks_unreachable_links():
    """Sources behind zero-bandwidth or non-finite-latency links must be
    masked out *before* the argmin — an unreachable holder never wins, and a
    dataset whose every holder is unreachable falls back to the origin."""
    sizes = np.array([1e9, 1e9])
    rep = make_replicas(sizes, disk_capacity=np.full(3, 1e10), origin=[0, 0])
    # dataset 0 also lives at site 1; dataset 1 only at its origin
    rep = insert_mask(rep, jnp.array([[True, True, False], [True, False, False]]), 0.0)
    bw = np.full((3, 3), 1e8)
    bw[1, 2] = 0.0  # site 1 -> 2: dead link (zero bandwidth sentinel)
    lat = np.zeros((3, 3))
    lat[0, 2] = np.inf  # site 0 -> 2: dead link (inf latency sentinel)
    net = matrix_network(bw, lat)
    src = nearest_source(rep, net, jnp.array([0]), jnp.array([1]))
    assert int(src[0]) == 1  # local replica at dst wins (diagonal free link)
    # dst=2: dataset 0's holders are sites 0 (inf latency) and 1 (zero bw) —
    # all unreachable -> pinned-origin fallback, not an argmin over NaN/inf
    src = nearest_source(rep, net, jnp.array([0, 1]), jnp.array([2, 2]))
    assert int(src[0]) == 0  # fallback = origin
    assert int(src[1]) == 0  # single unreachable holder -> origin fallback


def test_nearest_source_is_nan_free_under_debug_nans():
    """The masked-operand formulation never divides by a sentinel, so the
    whole selection runs clean under jax.debug_nans."""
    sizes = np.array([1e9])
    rep = make_replicas(sizes, disk_capacity=np.full(3, 1e10), origin=[0])
    bw = np.full((3, 3), 1e8)
    bw[0, 2] = 0.0
    lat = np.zeros((3, 3))
    lat[0, 1] = np.inf
    net = matrix_network(bw, lat)
    with jax.debug_nans(True):
        src = jax.jit(nearest_source)(rep, net, jnp.array([0, 0]), jnp.array([1, 2]))
        jax.block_until_ready(src)
    assert (np.asarray(src) == 0).all()  # origin fallback on both dead paths


def test_origin_pinned_survives_eviction_pressure():
    """catalog_invariants' origin_pinned_ok: the authoritative copy survives
    sustained LRU churn (tiny caches, many datasets) through a full run."""
    jobs = data_jobs(64, n_datasets=16, seed=7)
    sites = grid(4)
    net = uniform_network(4, bw=1e9, latency=0.001)
    rep = make_replicas(
        zipf_dataset_sizes(16, seed=8, mean_bytes=1e9),
        disk_capacity=np.array([1e12, 2.2e9, 2.2e9, 2.2e9]),
        origin=np.zeros(16, np.int32),
    )
    res = run_with("cache_on_read", jobs, sites, net, rep)
    inv = catalog_invariants(res.replicas)
    assert inv["origin_pinned_ok"] and inv["origins_ok"] and inv["capacity_ok"]
