"""CGSim-JAX core: the paper's contribution as a vectorized JAX system.

A SimGrid-class grid simulator whose whole state is dense arrays: an
event-round engine (``engine.simulate``), a plugin policy system
(``policies``), CGSim's JSON input layer (``platform``), PanDA-shaped
workloads (``workload``), calibration optimizers (``calibration``), the
event-level ML dataset (``events``) and monitoring (``monitor``).
"""
from .types import (  # noqa: F401
    ASSIGNED,
    CANCELLED,
    DONE,
    FAILED,
    PENDING,
    QUEUED,
    RUNNING,
    STATE_NAMES,
    EngineState,
    EventLog,
    JobsState,
    SimResult,
    SiteState,
    make_jobs,
    make_log,
    make_sites,
    pad_jobs_capacity,
)
from .engine import (  # noqa: F401
    Scenario,
    ScenarioBuckets,
    SimHandle,
    advance_sim,
    compute_time,
    finish_sim,
    init_sim,
    queue_times,
    service_time,
    sim_active,
    simulate,
    simulate_ensemble,
    simulate_many,
    stack_scenarios,
    walltimes,
)
from .telemetry import (  # noqa: F401
    CallbackSink,
    MemorySink,
    NDJSONSink,
    NullRecorder,
    NullSink,
    Sink,
    TraceRecorder,
    iter_ndjson,
    jsonable,
    lane_occupancy,
    manifest_drift,
    read_manifest,
    run_manifest,
    scenario_hash,
    write_manifest,
)
from .subsystems import (  # noqa: F401
    RoundCtx,
    Subsystem,
    make_subsystem,
    pad_ext_jobs,
    resolve_subsystems,
)
from .availability import (  # noqa: F401
    AvailabilityState,
    availability_factor,
    availability_subsystem,
    downtime_fraction,
    make_availability,
    next_window_edge,
    sample_correlated_outages,
)
from .network import (  # noqa: F401
    NetworkState,
    atlas_like_network,
    link_caps,
    link_index,
    matrix_network,
    network_from_sites,
    shared_transfer_times,
    star_network,
    tiered_network,
    uniform_network,
    with_bandwidth,
)
from .replicas import (  # noqa: F401
    ReplicaState,
    catalog_invariants,
    insert_replicas,
    make_replicas,
    materialize_outputs,
    nearest_source,
    zipf_dataset_sizes,
)
from .workflows import (  # noqa: F401
    WorkflowScenario,
    WorkflowState,
    atlas_mc_workflows,
    chain_workflows,
    make_workflow,
    map_reduce_workflows,
    parent_status,
    scenario_replicas,
    validate_workflow_data,
    workflow_locality,
    workflow_subsystem,
)
from .datapolicies import (  # noqa: F401
    DataExt,
    DataPlugin,
    DataPolicy,
    data_subsystem,
    get_data_policy,
    make_data_policy,
    register_data,
)
from .transfers import (  # noqa: F401
    TransferState,
    make_transfers,
    transfers_subsystem,
)
from .faults import (  # noqa: F401
    BL_CLOSED,
    BL_HALF_OPEN,
    BL_TRIPPED,
    FaultState,
    FaultsConfig,
    faults_subsystem,
    make_faults,
)
from .platform import (  # noqa: F401
    ExecutionParams,
    apply_site_params,
    atlas_like_platform,
    deactivate_sites,
    dump_platform,
    load_availability,
    load_faults,
    load_platform,
)
from .policies import (  # noqa: F401
    AllocationPlugin,
    Policy,
    critical_path_first,
    get_policy,
    make_policy,
    register,
    with_capacity_assign,
    with_fused_assign,
)
from .workload import (  # noqa: F401
    flaky_grid,
    flaky_sites,
    from_records,
    lm_job_records,
    lossy_links,
    maintenance_calendar,
    replica_loss_calendar,
    rolling_brownout,
    synthetic_panda_jobs,
)
from .sparse import build_candidates, bytes_per_round, static_feasibility  # noqa: F401
from .metrics import Metrics, compute_metrics, summary_str  # noqa: F401
from .events import read_ml_trace, recorded_trace, stream_rows, write_ml_dataset  # noqa: F401
from .calibration import (  # noqa: F401
    CalibProblem,
    CalibResult,
    PlatformBounds,
    PlatformCalibResult,
    PlatformParams,
    PlatformProblem,
    calibrate,
    calibrate_platform,
    default_bounds,
    make_population_objective,
    make_synthetic_platform_problem,
    platform_objective,
    platform_params,
    platform_problem_from_trace,
    recovery_error,
)
from .monitor import watch  # noqa: F401
