"""Workload-allocation policies — the CGSim plugin mechanism, JAX-native.

CGSim plugins are C++ shared libraries implementing an abstract class
(Fig. 2 of the paper): ``getResourceInformation`` / ``assignJob`` /
``onJobEnd`` / ``onSimulationEnd``.  Here a plugin is a ``Policy`` pytree of
pure functions with the same four extension points (plus the assignment
combinator), so user policies compile into the simulator without touching the
core — and remain ``vmap``-able for calibration ensembles.

    paper hook               | Policy field
    -------------------------+----------------------------------------
    getResourceInformation   | init(jobs, sites) -> policy_state
    assignJob                | score(jobs, sites, state, clock, rng) -> f32[J, S]
                             | assign(scores, queued, feasible, sites) -> (site, mask)
    onJobEnd                 | on_step(state, jobs, sites, completed, started, clock)
    onSimulationEnd          | on_end(state, jobs, sites, clock)

The optional ``rank`` hook (DESIGN.md §6) orders *starts within a site
queue*: ``rank(jobs, sites, state, clock) -> f32[J]`` is a secondary key in
the engine's FIFO-with-capacity sort — after ``jobs.priority``, before
arrival time, higher first — so user priorities always dominate.
``rank=None`` (the default) keeps the exact pre-workflow start order.

Sparse top-k scoring (DESIGN.md §12): with ``simulate(..., topk=K)`` the
engine evaluates scores only at a per-job candidate-site index ``i32[J, K]``
instead of the dense ``[J, S]`` matrix.  Three optional hooks serve that
mode, all ``None``-defaulting so existing policies keep working:

- ``score_cand(jobs, sites, state, clock, rng, cand) -> f32[J, K]`` scores
  each job at its candidate sites (``cand`` is clamped to valid site ids).
  Must be float-identical to gathering ``score(...)`` at ``cand`` — every
  built-in below satisfies this, so ``topk=S`` stays bit-for-bit equal to
  the dense path.  ``None`` falls back to a dense score + gather (exact,
  but without the memory win).
- ``pre_rank(jobs, sites, state, clock, rng) -> f32[J, S]`` is the dense
  pre-ranking the engine uses when *building* the candidate index (init
  time / every ``topk_refresh`` rounds — off the per-round hot path).
  ``None`` reuses ``score``.
- ``assign_cand(scores_k, queued, feas_k, cand, sites) -> (site, mask)``
  picks a site per job from candidate-set scores (the sparse analogue of
  ``assign``).  ``None`` uses ``engine.default_assign_cand``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .engine import _site_sum, default_assign
from .types import ASSIGNED, QUEUED, RUNNING, JobsState, SiteState

NEG = jnp.float32(-1e30)


class Policy(NamedTuple):
    name: str
    init: Callable
    score: Callable
    assign: Callable
    on_step: Callable
    on_end: Callable
    rank: Callable | None = None  # start-order key within site queues (None = jobs.priority)
    score_cand: Callable | None = None  # candidate-set score form (None = dense gather)
    pre_rank: Callable | None = None    # dense pre-rank for candidate building (None = score)
    assign_cand: Callable | None = None  # candidate-set assigner (None = default_assign_cand)


def _no_state(jobs, sites):
    return ()


def _keep_state(state, *_):
    return state


def make_policy(
    name: str, score: Callable, *, init=None, assign=None, on_step=None, on_end=None, rank=None,
    score_cand=None, pre_rank=None, assign_cand=None,
) -> Policy:
    return Policy(
        name=name,
        init=init or _no_state,
        score=score,
        assign=assign or default_assign,
        on_step=on_step or _keep_state,
        on_end=on_end or _keep_state,
        rank=rank,
        score_cand=score_cand,
        pre_rank=pre_rank,
        assign_cand=assign_cand,
    )


# --------------------------------------------------------------------------
# site-load helpers shared by several policies
# --------------------------------------------------------------------------

def site_backlog(jobs: JobsState, sites: SiteState):
    """Per-site queued core-demand and outstanding work (running + queued)."""
    S = sites.capacity
    q_site = jnp.where(jobs.state == ASSIGNED, jobs.site, S)
    r_site = jnp.where((jobs.state == RUNNING) | (jobs.state == ASSIGNED), jobs.site, S)
    q_cores = _site_sum(jobs.cores, q_site, S)  # int: one-hot fast path
    out_work = jax.ops.segment_sum(jobs.work, r_site, num_segments=S + 1)[:S]
    return q_cores.astype(jnp.float32), out_work


# --------------------------------------------------------------------------
# built-in policies (the paper ships a simple example; we ship a family)
# --------------------------------------------------------------------------

def random_policy(seed_salt: int = 0) -> Policy:
    def score(jobs, sites, state, clock, rng):
        J, S = jobs.capacity, sites.capacity
        return jax.random.uniform(jax.random.fold_in(rng, seed_salt), (J, S))

    return make_policy("random", score)


def round_robin() -> Policy:
    """Deterministic round-robin by job id (stateless, vmap-safe)."""

    def _want(jobs, sites):
        return jnp.mod(
            jnp.maximum(jobs.job_id, 0), jnp.maximum(sites.active.sum(), 1)
        )[:, None]

    def score(jobs, sites, state, clock, rng):
        S = sites.capacity
        idx = jnp.arange(S)[None, :]
        return -jnp.mod(idx - _want(jobs, sites), S).astype(jnp.float32)

    def score_cand(jobs, sites, state, clock, rng, cand):
        # integer mod is exact, so gather-then-compute ≡ compute-then-gather
        return -jnp.mod(cand - _want(jobs, sites), sites.capacity).astype(jnp.float32)

    return make_policy("round_robin", score, score_cand=score_cand)


def fastest_site() -> Policy:
    def score(jobs, sites, state, clock, rng):
        return jnp.broadcast_to(sites.speed[None, :], (jobs.capacity, sites.capacity))

    def score_cand(jobs, sites, state, clock, rng, cand):
        return sites.speed[cand]

    return make_policy("fastest_site", score, score_cand=score_cand)


def least_loaded() -> Policy:
    """Prefer the site with the most free-core headroom after its queue drains."""

    def _head(jobs, sites):
        q_cores, _ = site_backlog(jobs, sites)
        return (sites.free_cores.astype(jnp.float32) - q_cores) / jnp.maximum(
            sites.cores.astype(jnp.float32), 1.0
        )

    def score(jobs, sites, state, clock, rng):
        return jnp.broadcast_to(
            _head(jobs, sites)[None, :], (jobs.capacity, sites.capacity)
        )

    def score_cand(jobs, sites, state, clock, rng, cand):
        return _head(jobs, sites)[cand]

    return make_policy("least_loaded", score, score_cand=score_cand)


def data_locality() -> Policy:
    """Minimize stage-in cost (CGSim data-movement policy hook)."""

    def score(jobs, sites, state, clock, rng):
        t_in = sites.latency[None, :] + jobs.bytes_in[:, None] / sites.bw_in[None, :]
        return -t_in

    def score_cand(jobs, sites, state, clock, rng, cand):
        return -(sites.latency[cand] + jobs.bytes_in[:, None] / sites.bw_in[cand])

    return make_policy("data_locality", score, score_cand=score_cand)


def shortest_wait() -> Policy:
    """Greedy expected-completion-time (backlog drain + own service estimate)."""

    def _drain(jobs, sites):
        _, out_work = site_backlog(jobs, sites)
        cap_rate = sites.speed * jnp.maximum(sites.cores.astype(jnp.float32), 1.0)
        return out_work / jnp.maximum(cap_rate, 1e-9)

    def score(jobs, sites, state, clock, rng):
        mine = jobs.work[:, None] / jnp.maximum(
            sites.speed[None, :] * jobs.cores[:, None].astype(jnp.float32), 1e-9
        )
        stage = sites.latency[None, :] + jobs.bytes_in[:, None] / sites.bw_in[None, :]
        return -(_drain(jobs, sites)[None, :] + mine + stage)

    def score_cand(jobs, sites, state, clock, rng, cand):
        mine = jobs.work[:, None] / jnp.maximum(
            sites.speed[cand] * jobs.cores[:, None].astype(jnp.float32), 1e-9
        )
        stage = sites.latency[cand] + jobs.bytes_in[:, None] / sites.bw_in[cand]
        return -(_drain(jobs, sites)[cand] + mine + stage)

    return make_policy("shortest_wait", score, score_cand=score_cand)


def panda_site_score(jobs, sites, w_speed=1.0, w_free=1.0, w_queue=2.0, w_fail=4.0):
    """The PanDA brokerage score as a per-site vector ``f32[S]`` — shared by
    the dense broadcast, the candidate gather, and the fused assignment
    kernel's site-score input."""
    q_cores, _ = site_backlog(jobs, sites)
    cores_f = jnp.maximum(sites.cores.astype(jnp.float32), 1.0)
    norm_speed = sites.speed / jnp.maximum(sites.speed.max(), 1e-9)
    free_frac = sites.free_cores.astype(jnp.float32) / cores_f
    queue_frac = q_cores / cores_f
    return (
        w_speed * norm_speed
        + w_free * free_frac
        - w_queue * queue_frac
        - w_fail * sites.fail_rate
    )


def panda_dispatch(w_speed=1.0, w_free=1.0, w_queue=2.0, w_fail=4.0) -> Policy:
    """PanDA-flavoured weighted dispatch (brokerage mixes capability, load,
    reliability) — the default policy for the ATLAS case study."""

    def score(jobs, sites, state, clock, rng):
        s = panda_site_score(jobs, sites, w_speed, w_free, w_queue, w_fail)
        return jnp.broadcast_to(s[None, :], (jobs.capacity, sites.capacity))

    def score_cand(jobs, sites, state, clock, rng, cand):
        return panda_site_score(jobs, sites, w_speed, w_free, w_queue, w_fail)[cand]

    return make_policy("panda_dispatch", score, score_cand=score_cand)


def crit_rank_fn(jobs, sites, state, clock):
    """Start-order rank: critical-path weight — among equal-priority jobs,
    the one whose downstream chain is heaviest starts first (the engine
    keeps ``jobs.priority`` as the primary key)."""
    return jobs.wf_crit


def critical_path_first(base: str = "panda_dispatch", **params) -> Policy:
    """Workflow-aware scheduling (DESIGN.md §6): site choice follows the
    ``base`` policy, but within each site queue jobs start in decreasing
    critical-path weight (``jobs.wf_crit``, the upward rank computed by
    ``workflows.make_workflow``) instead of FIFO.  On DAG-free workloads
    ``wf_crit`` is 0 everywhere, so this degrades to the base policy."""
    pol = get_policy(base, **params)
    return pol._replace(name=f"critical_path_first[{pol.name}]", rank=crit_rank_fn)


def with_capacity_assign(policy: Policy, assign_fn) -> Policy:
    """Swap in a capacity-constrained assigner (e.g. ``repro.kernels.assign``):
    jobs beyond a site's free cores stay QUEUED at the main server instead of
    piling into site queues."""

    def assign(scores, queued, feasible, sites):
        return assign_fn(scores, queued, feasible, sites)

    return policy._replace(name=policy.name + "+capacity", assign=assign)


def with_fused_assign(policy: Policy, assign_cand_fn) -> Policy:
    """Swap in a fused candidate-set assigner for sparse top-k mode
    (``repro.kernels.assign.make_fused_capacity_assign``): rank + capacity
    pick run in one kernel over ``[J, K]`` candidates instead of the dense
    ``[J, S]`` matrix.  Only consulted when the engine runs with ``topk=``;
    pair with :func:`with_capacity_assign` for the dense fallback."""

    def assign_cand(scores_k, queued, feas_k, cand, sites):
        return assign_cand_fn(scores_k, queued, feas_k, cand, sites)

    return policy._replace(name=policy.name + "+fused", assign_cand=assign_cand)


REGISTRY: dict[str, Callable[..., Policy]] = {
    "random": random_policy,
    "round_robin": round_robin,
    "fastest_site": fastest_site,
    "least_loaded": least_loaded,
    "data_locality": data_locality,
    "shortest_wait": shortest_wait,
    "panda_dispatch": panda_dispatch,
    "critical_path_first": critical_path_first,
}


def get_policy(name: str, **params) -> Policy:
    if name not in REGISTRY:
        raise KeyError(f"unknown policy {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name](**params)


def register(name: str):
    """Decorator: plug a user policy factory into the registry (paper §3.3)."""

    def deco(fn):
        REGISTRY[name] = fn
        return fn

    return deco


# --------------------------------------------------------------------------
# Abstract-class adapter mirroring the paper's Fig. 2 C++ API, for users who
# prefer subclassing over composing functions.
# --------------------------------------------------------------------------

class AllocationPlugin:
    """Subclass and override, then call ``.build()`` to get a Policy.

    Mirrors CGSim's abstract plugin class: ``get_resource_information`` is
    called once with the platform; ``assign_job`` must produce per-site scores
    for every queued job; ``on_job_end``/``on_simulation_end`` are optional.
    """

    name = "custom"

    def get_resource_information(self, jobs: JobsState, sites: SiteState):
        return ()

    def assign_job(self, jobs, sites, state, clock, rng):  # -> f32[J, S]
        raise NotImplementedError

    def on_job_end(self, state, jobs, sites, completed, started, clock):
        return state

    def on_simulation_end(self, state, jobs, sites, clock):
        return state

    def build(self) -> Policy:
        return Policy(
            name=self.name,
            init=self.get_resource_information,
            score=self.assign_job,
            assign=default_assign,
            on_step=self.on_job_end,
            on_end=self.on_simulation_end,
        )
