"""Site availability dynamics — downtime, preemption, degradation (DESIGN.md §5).

CGSim evaluates infrastructures under realistic operating conditions; real
grids are never fully up.  Sites take scheduled maintenance, suffer outages,
and run degraded ("brown-outs") when power or cooling is constrained —
Horzela et al. (arXiv:2403.14903) show unmodeled infrastructure dynamics
dominate HEP-grid calibration error.  This module models all of that as a
fixed-shape calendar of per-site windows so the engine stays jit/vmap-safe:

- ``AvailabilityState`` holds ``f32[S, W]`` window start/end times padded
  with ``inf``, a per-window ``factor`` (0 = full outage, (0,1) = brown-out),
  and a per-window ``preempt`` flag (outage kills running jobs vs. drains).
- ``availability_factor`` reduces the windows covering a time ``t`` to one
  per-site multiplier (most severe window wins).
- ``next_window_edge`` makes window boundaries an *event source*: the engine
  clock min-reduction includes the next edge, so rounds land exactly on
  window starts/ends and no boundary is skipped.

Everything here is masked dense algebra over ``[S, W]``; window count is a
static shape, not a loop bound.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .types import ASSIGNED, FAILED, QUEUED, RUNNING

INF = jnp.float32(jnp.inf)


class AvailabilityState(NamedTuple):
    """Fixed-capacity per-site downtime/degradation calendar.

    Unused window slots have ``win_start = win_end = inf`` and never match.
    ``win_preempt`` only matters for full outages (``win_factor == 0``):
    True kills the site's running jobs at window entry (they return to
    QUEUED with a retry, PanDA-style), False drains them to completion.
    """

    win_start: jax.Array    # f32[S, W] window start times (inf = unused slot)
    win_end: jax.Array      # f32[S, W] window end times (exclusive)
    win_factor: jax.Array   # f32[S, W] capacity/speed multiplier inside the window
    win_preempt: jax.Array  # bool[S, W] outage preempts running jobs (vs drain)
    n_preempted: jax.Array  # i32[S] cumulative attempts preempted per site

    @property
    def n_sites(self) -> int:
        return self.win_start.shape[-2]

    @property
    def max_windows(self) -> int:
        return self.win_start.shape[-1]


def make_availability(
    n_sites: int, windows=(), *, max_windows: int | None = None
) -> AvailabilityState:
    """Build an AvailabilityState from window specs.

    ``windows``: iterable of dicts (``site``, ``start``, ``end``,
    ``factor`` = 0.0, ``preempt`` = False) or tuples in that order.  Windows
    are grouped per site and padded to ``max_windows`` slots (default: the
    max per-site count, at least 1).
    """
    per_site: list[list[tuple]] = [[] for _ in range(n_sites)]
    for w in windows:
        if isinstance(w, dict):
            site = int(w["site"])
            row = (float(w["start"]), float(w["end"]),
                   float(w.get("factor", 0.0)), bool(w.get("preempt", False)))
        else:
            site = int(w[0])
            row = (float(w[1]), float(w[2]),
                   float(w[3]) if len(w) > 3 else 0.0,
                   bool(w[4]) if len(w) > 4 else False)
        if not 0 <= site < n_sites:
            raise ValueError(f"window site {site} out of range [0, {n_sites})")
        if not row[1] > row[0]:
            raise ValueError(f"window end {row[1]} must be > start {row[0]}")
        if not 0.0 <= row[2] <= 1.0:
            raise ValueError(f"window factor {row[2]} must be in [0, 1]")
        per_site[site].append(row)

    W = max_windows or max(1, max((len(p) for p in per_site), default=1))
    if any(len(p) > W for p in per_site):
        raise ValueError(f"a site has more than max_windows={W} windows")
    start = np.full((n_sites, W), np.inf, np.float32)
    end = np.full((n_sites, W), np.inf, np.float32)
    factor = np.ones((n_sites, W), np.float32)
    preempt = np.zeros((n_sites, W), bool)
    for s, rows in enumerate(per_site):
        for i, (t0, t1, f, p) in enumerate(sorted(rows)):
            start[s, i], end[s, i], factor[s, i], preempt[s, i] = t0, t1, f, p
    return AvailabilityState(
        win_start=jnp.asarray(start),
        win_end=jnp.asarray(end),
        win_factor=jnp.asarray(factor),
        win_preempt=jnp.asarray(preempt),
        n_preempted=jnp.zeros((n_sites,), jnp.int32),
    )


def active_windows(avail: AvailabilityState, t: jax.Array) -> jax.Array:
    """bool[S, W]: windows covering time ``t`` (half-open ``[start, end)``)."""
    return (avail.win_start <= t) & (t < avail.win_end)


def availability_factor(avail: AvailabilityState, t: jax.Array) -> jax.Array:
    """f32[S]: per-site capacity multiplier at time ``t``.

    1.0 outside any window; overlapping windows reduce to the most severe
    (minimum) factor — an outage inside a brown-out is still an outage.
    """
    f = jnp.where(active_windows(avail, t), avail.win_factor, 1.0)
    return f.min(axis=-1)


def preempting_sites(avail: AvailabilityState, t0: jax.Array, t1: jax.Array) -> jax.Array:
    """bool[S]: sites with a ``preempt`` full-outage window overlapping
    ``(t0, t1]``.

    Interval (not instant) semantics so ``quantum > 0`` rounds, whose clock
    can jump past a short window entirely, still preempt the jobs that were
    running through it — mirroring how job events inside a quantum are
    retired late but never dropped.  With ``t0 == previous round clock`` and
    ``t1 == current clock`` this reduces to "active at t1" whenever rounds
    land on every edge (the quantum == 0 case).
    """
    hit = (avail.win_start <= t1) & (avail.win_end > t0)
    return jnp.any(hit & avail.win_preempt & (avail.win_factor <= 0.0), axis=-1)


def next_window_edge(avail: AvailabilityState, t: jax.Array) -> jax.Array:
    """f32[]: the earliest window start/end strictly after ``t`` (inf if none).

    Feeding this into the engine's clock min-reduction makes availability
    transitions exact event rounds even when no job event is nearby.
    """
    edges = jnp.concatenate([avail.win_start.ravel(), avail.win_end.ravel()])
    return jnp.where(edges > t, edges, INF).min()


def downtime_fraction(avail: AvailabilityState, horizon) -> np.ndarray:
    """f64[S]: fraction of ``[0, horizon]`` each site spends fully down.

    Numpy post-processing helper (ML features / reports).  Overlapping outage
    windows on one site (e.g. two correlated incidents) are merged, so the
    result is the exact measure of the per-site downtime union.
    """
    horizon = float(horizon)
    S = int(avail.n_sites)
    if horizon <= 0:
        return np.zeros(S)
    start = np.clip(np.asarray(avail.win_start, np.float64), 0.0, horizon)
    end = np.clip(np.asarray(avail.win_end, np.float64), 0.0, horizon)
    down = (np.asarray(avail.win_factor) <= 0.0) & (end > start)
    out = np.zeros(S)
    for s in range(S):
        covered, edge = 0.0, -np.inf
        for a, b in sorted(zip(start[s][down[s]], end[s][down[s]])):
            covered += max(b - max(a, edge), 0.0)
            edge = max(edge, b)
        out[s] = covered / horizon
    return np.clip(out, 0.0, 1.0)


# --------------------------------------------------------------------------
# the availability Subsystem (DESIGN.md §7): the engine wiring above,
# re-expressed as hooks on the composable round-loop protocol
# --------------------------------------------------------------------------


def _av_validate(sub, av: AvailabilityState, jobs, sites) -> None:
    S = sites.capacity
    if av.win_start.shape[-2] != S:
        raise ValueError(
            f"availability has {av.win_start.shape[-2]} sites, platform has {S}"
        )


def _av_event_times(sub, ctx):
    # window starts/ends are event sources: rounds land exactly on edges
    return next_window_edge(ctx.ext["availability"], ctx.clock_prev)


def _av_completion_filter(sub, ctx, comp):
    # a preempting outage opening before the job's finish kills it first;
    # only reachable when quantum > 0 jumps the clock past both the window
    # start and t_finish in one round (at quantum=0 rounds land on every
    # edge, so this mask is identically False).  The survivor stays RUNNING
    # and the on_completions hook preempts it.
    av = ctx.ext["availability"]
    jobs = ctx.jobs
    ksite = jnp.clip(jobs.site, 0, ctx.S - 1)
    ws = av.win_start[ksite]                                   # [J, W]
    wkill = av.win_preempt[ksite] & (av.win_factor[ksite] <= 0.0)
    killed_first = jnp.any(
        wkill & (ws > ctx.clock_prev) & (ws < jobs.t_finish[:, None]), axis=-1
    )
    return comp & ~killed_first


def _av_on_completions(sub, ctx):
    """Outage preemption & brown-out scaling (engine step 2b, DESIGN.md §5)."""
    from .engine import _site_sum

    av = ctx.ext["availability"]
    jobs, sites, S = ctx.jobs, ctx.sites, ctx.S
    factor = availability_factor(av, ctx.clock)     # f32[S]
    # brown-out: a factor-f window caps usable cores at floor(f*cores); a
    # site whose cap floors to 0 is a de facto outage, so the dispatcher
    # routes around it just like a factor-0 window
    eff_cap = jnp.floor(sites.cores.astype(jnp.float32) * factor).astype(jnp.int32)
    ctx.scratch["availability"] = dict(factor=factor, eff_cap=eff_cap, avail_up=eff_cap > 0)
    # preempt: running jobs on a site whose preempting outage overlaps
    # (prev clock, clock] lose this attempt now (completions already retired
    # jobs whose t_finish <= clock, so a job finishing at the edge still
    # finishes; interval overlap keeps windows shorter than a quantum from
    # being skipped)
    site_c0 = jnp.clip(jobs.site, 0, S - 1)
    preempting = preempting_sites(av, ctx.clock_prev, ctx.clock)[site_c0]
    pre = (jobs.state == RUNNING) & preempting
    pre_resub = pre & (jobs.retries < ctx.max_retries)
    pre_fail = pre & ~pre_resub
    pre_site = jnp.where(pre, jobs.site, S)
    # jobs still waiting in the dead site's queue bounce back to the server —
    # no attempt was lost, so no retry — instead of sitting stranded behind
    # an outage while other sites idle (drain windows leave the site queue
    # paused, as announced maintenance does)
    bounce = (jobs.state == ASSIGNED) & preempting
    ctx.jobs = jobs._replace(
        state=jnp.where(
            pre_resub | bounce, QUEUED, jnp.where(pre_fail, FAILED, jobs.state)
        ),
        retries=jobs.retries + pre_resub.astype(jnp.int32),
        site=jnp.where(pre_resub | bounce, -1, jobs.site),
        t_finish=jnp.where(pre_resub, INF, jnp.where(pre_fail, ctx.clock, jobs.t_finish)),
        preempted=jobs.preempted + pre.astype(jnp.int32),
    )
    ctx.sites = sites._replace(
        free_cores=sites.free_cores + _site_sum(jnp.where(pre, jobs.cores, 0), pre_site, S),
        free_memory=sites.free_memory
        + _site_sum(jnp.where(pre, jobs.memory, 0.0), pre_site, S),
    )
    ctx.ext["availability"] = av._replace(
        n_preempted=av.n_preempted + _site_sum(pre.astype(jnp.int32), pre_site, S)
    )
    # a preemption round changed state: give the dispatcher one more round
    # to re-route the requeued jobs before halt detection
    ctx.progressed = jnp.logical_or(ctx.progressed, jnp.any(pre))


def _av_pre_assign(sub, ctx):
    sc = ctx.scratch["availability"]
    # the dispatcher routes around sites currently in a full outage
    ctx.feasible = ctx.feasible & sc["avail_up"][None, :]
    # starts only claim cores up to the brown-out cap net of busy ones, at
    # speed scaled by the window factor; a full outage admits no starts
    sites = ctx.sites
    busy = sites.cores - sites.free_cores
    ctx.start_cores = jnp.clip(sc["eff_cap"] - busy, 0, sites.free_cores)
    ctx.sites_serv = ctx.sites_serv._replace(
        speed=jnp.maximum(ctx.sites_serv.speed * sc["factor"], 1e-9)
    )


def _av_log_spec(sub, av, jobs, sites):
    return {"site_avail": jnp.ones((sites.capacity,), jnp.float32)}


def _av_log_columns(sub, ctx, write):
    return {"site_avail": ctx.scratch["availability"]["factor"]}


def _av_finalize(sub, av, jobs, sites, clock):
    return av, {"avail": av}


def availability_subsystem() -> "Subsystem":
    """Availability dynamics as a composable engine subsystem; its ext slot
    carries the ``AvailabilityState`` calendar + preemption counters."""
    from .subsystems import Subsystem

    return Subsystem(
        name="availability",
        validate=_av_validate,
        event_times=_av_event_times,
        completion_filter=_av_completion_filter,
        on_completions=_av_on_completions,
        pre_assign=_av_pre_assign,
        log_spec=_av_log_spec,
        log_columns=_av_log_columns,
        finalize=_av_finalize,
    )


def sample_correlated_outages(
    n_sites: int,
    tier,
    *,
    horizon: float,
    events_per_tier: float = 2.0,
    mean_duration: float = 4 * 3600.0,
    p_follow: float = 0.7,
    factor: float = 0.0,
    preempt: bool = True,
    jitter: float = 0.0,
    seed: int = 0,
    max_windows: int | None = None,
) -> AvailabilityState:
    """Tier-correlated outage calendar (shared storage, power, or WAN cuts).

    Real grid outages cluster: a Tier-1 storage incident takes down the T2s
    behind it.  For each tier we draw a Poisson number of *tier events*
    (mean ``events_per_tier``) uniform over ``[0, horizon]``; each event hits
    every site of that tier independently with probability ``p_follow``,
    with log-normal duration around ``mean_duration`` and per-site start
    jitter of up to ``jitter`` seconds.
    """
    tier = np.asarray(tier, np.int64)
    if tier.shape != (n_sites,):
        raise ValueError(f"tier must be shape ({n_sites},), got {tier.shape}")
    rng = np.random.default_rng(seed)
    windows = []
    for t_id in np.unique(tier):
        members = np.flatnonzero(tier == t_id)
        for _ in range(rng.poisson(events_per_tier)):
            t0 = rng.uniform(0.0, horizon)
            hit = members[rng.random(members.size) < p_follow]
            for s in hit:
                start = t0 + rng.uniform(0.0, jitter) if jitter > 0 else t0
                dur = rng.lognormal(np.log(mean_duration), 0.5)
                windows.append(dict(site=int(s), start=start, end=start + dur,
                                    factor=factor, preempt=preempt))
    return make_availability(n_sites, windows, max_windows=max_windows)
