"""Event-level dataset generation (paper §4.3.2, Table 1).

CGSim records every job state transition alongside concurrent site metrics so
the runs double as ML training data.  Inside jit we only keep the per-job
timestamps (they fully determine the transition stream); this module expands
them into Table-1-style rows and ML feature matrices in numpy post-processing
— the paper's "output layer" (SQLite/CSV) becomes CSV/JSON/columnar exports.
"""
from __future__ import annotations

import csv
import io
import json

import numpy as np

from .types import CANCELLED, DONE, FAILED, STATE_NAMES, SimResult

# transition kinds, in tie-break order at equal timestamps: completions free
# cores before same-instant assigns/starts consume them (engine round order)
K_FINISH, K_ASSIGN, K_START = 0, 1, 2
KIND_NAMES = {K_ASSIGN: "assigned", K_START: "running", K_FINISH: "finished"}


def iter_transitions(result: SimResult, site_names=None):
    """Yield job state-transition rows one at a time (Table 1 stream).

    The generator form of ``transition_rows``: the sort still needs one
    ``(time, kind, job, site)`` tuple per transition (3 per job), but rows —
    an order of magnitude wider — are materialized one at a time, so a
    sink-fed export never holds the whole table.
    """
    jobs = jax_to_np(result.jobs)
    sites = jax_to_np(result.sites)
    S = len(sites["cores"])
    name = lambda s: (site_names[s] if site_names else f"site{s}")

    evs = []
    J = len(jobs["arrival"])
    for j in range(J):
        if not jobs["valid"][j]:
            continue
        sid = int(jobs["site"][j])
        if np.isfinite(jobs["t_assign"][j]):
            evs.append((float(jobs["t_assign"][j]), K_ASSIGN, j, sid))
        if np.isfinite(jobs["t_start"][j]):
            evs.append((float(jobs["t_start"][j]), K_START, j, sid))
        if np.isfinite(jobs["t_finish"][j]):
            evs.append((float(jobs["t_finish"][j]), K_FINISH, j, sid))
    evs.sort(key=lambda e: (e[0], e[1], e[2]))

    free = sites["cores"].astype(np.int64).copy()
    queued = np.zeros(S, np.int64)   # in site queue, not yet running
    running = np.zeros(S, np.int64)
    finished = np.zeros(S, np.int64)
    for eid, (t, kind, j, sid) in enumerate(evs):
        if sid < 0:
            continue
        if kind == K_ASSIGN:
            queued[sid] += 1
        elif kind == K_START:
            queued[sid] -= 1
            running[sid] += 1
            free[sid] -= int(jobs["cores"][j])
        else:
            running[sid] -= 1
            free[sid] += int(jobs["cores"][j])
            finished[sid] += 1
        state = KIND_NAMES[kind]
        if kind == K_FINISH and jobs["state"][j] == FAILED:
            state = "failed"
        yield dict(
            event_id=eid,
            time=round(t, 3),
            job_id=int(jobs["job_id"][j]),
            state=state,
            site=name(sid),
            avail_cores=int(free[sid]),
            pending_jobs=int(queued[sid]),
            assigned_jobs=int(running[sid]),
            finished_jobs=int(finished[sid]),
        )


def transition_rows(result: SimResult, site_names=None) -> list[dict]:
    """Expand a SimResult into one row per job state transition (Table 1).

    Each row: event_id, time, job_id, state, site, site available cores,
    site pending (queued) jobs, site assigned (running) jobs, site finished.

    Note: for resubmitted jobs only the final attempt's timestamps survive in
    ``JobsState``, so the stream contains one assign/start/finish triplet per
    job (failed intermediate attempts are visible in ``sites.n_failed``).
    ``iter_transitions`` is the streaming (generator) form.
    """
    return list(iter_transitions(result, site_names))


def transfer_rows(result: SimResult, site_names=None) -> list[dict]:
    """One row per stage-in data movement (DESIGN.md §3): src/dst storage
    elements, bytes over the WAN (0 for a local cache hit), and duration.

    Only jobs that actually staged through the data subsystem produce rows
    (``xfer_src >= 0`` — a run without a DataPolicy records none); as with
    ``transition_rows``, resubmitted jobs keep their final attempt only.
    """
    jobs = jax_to_np(result.jobs)
    name = lambda s: (site_names[s] if site_names else f"site{s}")
    rows = []
    order = np.argsort(jobs["t_start"], kind="stable")
    for j in order:
        if not jobs["valid"][j] or jobs["dataset"][j] < 0 or jobs["xfer_src"][j] < 0:
            continue
        if not np.isfinite(jobs["t_start"][j]) or jobs["site"][j] < 0:
            continue
        nbytes = float(jobs["xfer_bytes"][j])
        rows.append(
            dict(
                time=round(float(jobs["t_start"][j]), 3),
                job_id=int(jobs["job_id"][j]),
                dataset=int(jobs["dataset"][j]),
                src=name(int(jobs["xfer_src"][j])),
                dst=name(int(jobs["site"][j])),
                bytes=round(nbytes, 1),
                duration=round(float(jobs["xfer_time"][j]), 3),
                cache_hit=nbytes == 0.0,
                # transfer-queue columns (DESIGN.md §11): 0.0/-1 when the
                # subsystem is off, so schemas concatenate across runs
                queue_wait=round(float(jobs["xfer_wait"][j]), 3),
                queue_depth=int(jobs["xfer_qdepth"][j]),
            )
        )
    return rows


def job_rows(result: SimResult, site_names=None) -> list[dict]:
    """One row per valid job with a *stable* schema across engine features.

    The workflow columns (``n_parents``/``dag_depth``/``wf_id``) are emitted
    for every run — constant ``0``/``0``/``-1`` without a DAG — so exported
    datasets from plain and workflow runs concatenate cleanly (DESIGN.md §6).
    Non-finite timestamps export as ``None`` (JSON-safe).
    """
    jobs = jax_to_np(result.jobs)
    name = lambda s: (site_names[s] if site_names else f"site{s}") if s >= 0 else None
    t = lambda x: round(float(x), 3) if np.isfinite(x) else None
    rows = []
    for j in range(len(jobs["arrival"])):
        if not jobs["valid"][j]:
            continue
        rows.append(
            dict(
                job_id=int(jobs["job_id"][j]),
                state=STATE_NAMES[int(jobs["state"][j])],
                site=name(int(jobs["site"][j])),
                arrival=t(jobs["arrival"][j]),
                t_start=t(jobs["t_start"][j]),
                t_finish=t(jobs["t_finish"][j]),
                cores=int(jobs["cores"][j]),
                work=float(jobs["work"][j]),
                retries=int(jobs["retries"][j]),
                dataset=int(jobs["dataset"][j]),
                n_parents=int(jobs["n_parents"][j]),
                dag_depth=int(jobs["dag_depth"][j]),
                wf_id=int(jobs["wf_id"][j]),
            )
        )
    return rows


def workflow_rows(result: SimResult) -> list[dict]:
    """One row per workflow (``wf_id`` group): job counts by outcome, DAG
    depth, submit time, and makespan — the per-workflow companion to the
    per-job stream (DESIGN.md §6).  Runs without a DAG produce no rows."""
    jobs = jax_to_np(result.jobs)
    sel = jobs["valid"] & (jobs["wf_id"] >= 0)
    rows = []
    for w in np.unique(jobs["wf_id"][sel]):
        m = sel & (jobs["wf_id"] == w)
        state = jobs["state"][m]
        fin = jobs["t_finish"][m]
        fin = fin[np.isfinite(fin)]
        t0 = float(jobs["arrival"][m].min())
        done = bool((state == DONE).all())
        rows.append(
            dict(
                wf_id=int(w),
                n_jobs=int(m.sum()),
                n_done=int((state == DONE).sum()),
                n_failed=int((state == FAILED).sum()),
                n_cancelled=int((state == CANCELLED).sum()),
                dag_depth=int(jobs["dag_depth"][m].max()),
                t_submit=round(t0, 3),
                t_end=round(float(fin.max()), 3) if fin.size else None,
                makespan=round(float(fin.max()) - t0, 3) if (done and fin.size) else None,
                completed=done,
            )
        )
    return rows


def availability_rows(result: SimResult, site_names=None) -> list[dict]:
    """One row per availability window (DESIGN.md §5): the outage/brown-out
    calendar alongside how many running attempts each site's outages killed.

    Rows are time-ordered by window start.  ``n_preempted`` is the site's
    *cumulative* preemption counter (repeated on each of its rows); a run
    without an ``AvailabilityState`` produces no rows.
    """
    avail = getattr(result, "avail", None)
    if avail is None:
        return []
    start = np.asarray(avail.win_start)
    end = np.asarray(avail.win_end)
    factor = np.asarray(avail.win_factor)
    preempt = np.asarray(avail.win_preempt)
    n_pre = np.asarray(avail.n_preempted)
    name = lambda s: (site_names[s] if site_names else f"site{s}")
    rows = []
    for s, w in sorted(zip(*np.nonzero(np.isfinite(start))), key=lambda i: start[i]):
        f = float(factor[s, w])
        rows.append(
            dict(
                time=round(float(start[s, w]), 3),
                site=name(int(s)),
                kind="outage" if f <= 0.0 else "brownout",
                start=round(float(start[s, w]), 3),
                end=round(float(end[s, w]), 3) if np.isfinite(end[s, w]) else float("inf"),
                factor=f,
                preempt=bool(preempt[s, w]),
                n_preempted=int(n_pre[s]),
            )
        )
    return rows


_BL_NAMES = {0: "closed", 1: "tripped", 2: "half-open"}


def fault_rows(result: SimResult, site_names=None) -> list[dict]:
    """One row per site from the faults subsystem (DESIGN.md §13): the final
    EWMA failure score, circuit-breaker state, and how many replica-loss
    events hit the site — plus the run-level fault counters repeated on each
    row (like ``availability_rows``' cumulative ``n_preempted``).  A run
    without ``faults=`` produces no rows.
    """
    fs = (getattr(result, "ext", None) or {}).get("faults")
    if fs is None:
        return []
    score = np.asarray(fs.score)
    bl = np.asarray(fs.bl_state)
    loss_s = np.asarray(fs.loss_s)
    loss_done = np.asarray(fs.loss_done)
    name = lambda s: (site_names[s] if site_names else f"site{s}")
    rows = []
    for s in range(score.shape[-1]):
        rows.append(
            dict(
                site=name(s),
                fault_score=round(float(score[s]), 4),
                blacklist=_BL_NAMES.get(int(bl[s]), "?"),
                loss_events=int(((loss_s == s) & loss_done).sum()),
                n_kills=int(fs.n_kills),
                n_xfer_fail=int(fs.n_xfer_fail),
                n_bl_trips=int(fs.n_bl_trips),
                time_lost=round(float(fs.time_lost), 3),
            )
        )
    return rows


def to_csv(rows: list[dict]) -> str:
    if not rows:
        return ""
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=list(rows[0]))
    w.writeheader()
    w.writerows(rows)
    return buf.getvalue()


def to_json(rows: list[dict]) -> str:
    return json.dumps(rows)


def _ml_context(result: SimResult) -> dict:
    """Everything ``_ml_block`` needs that is *per-run*, not per-job-slice:
    the host-side column arrays, the per-site availability columns, and the
    feature-name schema.  Computed once so chunked export pays it once."""
    jobs = jax_to_np(result.jobs)
    sites = jax_to_np(result.sites)
    names = [
        "log_work", "cores", "memory_gb", "log_bytes_in", "log_bytes_out",
        "priority", "site_speed", "site_cores", "site_log_bw", "site_gamma",
        "site_fail_rate", "log_xfer_bytes", "xfer_time", "has_dataset",
        "n_parents", "dag_depth", "wf_id",
    ]
    ctx = dict(jobs=jobs, sites=sites, down_frac=None, site_pre=None, net_bw=None)
    avail = getattr(result, "avail", None)
    if avail is not None:
        from .availability import downtime_fraction

        ctx["down_frac"] = downtime_fraction(avail, float(result.makespan))
        ctx["site_pre"] = np.asarray(avail.n_preempted, np.float64)
        names = names + ["n_preempted", "site_downtime_frac", "site_log_preempted"]
    ext = getattr(result, "ext", None) or {}
    if "transfers" in ext and "data" in ext:
        # transfer-queue features (DESIGN.md §11); appended only when the
        # subsystem ran, preserving byte-identity of existing exports
        ctx["net_bw"] = np.asarray(ext["data"].network.bw, np.float64)
        names = names + ["xfer_queue_wait", "xfer_queue_depth", "src_link_log_bw"]
    ctx["faults_bw"] = None
    if "faults" in ext:
        # fault features (DESIGN.md §13): the job's cumulative backoff wait
        # and retry count, and its final site's EWMA failure score — what a
        # surrogate needs to learn failure-shaped walltime tails
        ctx["faults_bw"] = np.asarray(ext["faults"].backoff_wait, np.float64)
        ctx["fault_score"] = np.asarray(ext["faults"].score, np.float64)
        names = names + ["fault_backoff_wait", "fault_retries", "site_fault_score"]
    ctx["names"] = names
    return ctx


def _ml_block(ctx: dict, sl: slice = slice(None)) -> dict[str, np.ndarray]:
    """Features/labels for one job-axis slice.

    Every per-job column is elementwise (transforms and site gathers), so a
    slice computes values identical to the same rows of the full matrix —
    the invariant that makes ``write_ml_dataset`` byte-identical to
    ``ml_dataset`` at any segment size (tested)."""
    jobs = {k: v[sl] for k, v in ctx["jobs"].items()}
    sites = ctx["sites"]
    done = np.isin(jobs["state"], [DONE, FAILED]) & jobs["valid"]
    sid = np.clip(jobs["site"], 0, len(sites["cores"]) - 1)

    feats = np.stack(
        [
            np.log1p(jobs["work"]),
            jobs["cores"].astype(np.float64),
            jobs["memory"],
            np.log1p(jobs["bytes_in"]),
            np.log1p(jobs["bytes_out"]),
            jobs["priority"],
            sites["speed"][sid],
            sites["cores"][sid].astype(np.float64),
            np.log1p(sites["bw_in"][sid]),
            sites["par_gamma"][sid],
            sites["fail_rate"][sid],
            np.log1p(jobs["xfer_bytes"]),
            jobs["xfer_time"],
            (jobs["dataset"] >= 0).astype(np.float64),
            # workflow DAG features — constant 0/0/-1 without a workflow, so
            # the export schema is stable across plain and DAG runs
            jobs["n_parents"].astype(np.float64),
            jobs["dag_depth"].astype(np.float64),
            jobs["wf_id"].astype(np.float64),
        ],
        axis=-1,
    )[done]
    if ctx["down_frac"] is not None:
        extra = np.stack(
            [
                jobs["preempted"].astype(np.float64),
                ctx["down_frac"][sid],
                np.log1p(ctx["site_pre"][sid]),
            ],
            axis=-1,
        )[done]
        feats = np.concatenate([feats, extra], axis=-1)
    if ctx["net_bw"] is not None:
        src = jobs["xfer_src"]
        src_c = np.clip(src, 0, ctx["net_bw"].shape[0] - 1)
        extra = np.stack(
            [
                jobs["xfer_wait"],
                jobs["xfer_qdepth"].astype(np.float64),
                np.where(src >= 0, np.log1p(ctx["net_bw"][src_c, sid]), 0.0),
            ],
            axis=-1,
        )[done]
        feats = np.concatenate([feats, extra], axis=-1)
    if ctx["faults_bw"] is not None:
        extra = np.stack(
            [
                ctx["faults_bw"][sl],
                jobs["retries"].astype(np.float64),
                ctx["fault_score"][sid],
            ],
            axis=-1,
        )[done]
        feats = np.concatenate([feats, extra], axis=-1)
    wall = (jobs["t_finish"] - jobs["t_start"])[done]
    queue = (jobs["t_start"] - jobs["arrival"])[done]
    failed = (jobs["state"] == FAILED)[done]
    return dict(
        features=feats.astype(np.float32),
        walltime=wall.astype(np.float32),
        queue_time=queue.astype(np.float32),
        failed=failed,
        # identity labels (not features): which job ran where — what lets a
        # calibration trace join rows back to workload entries
        job_id=jobs["job_id"][done].astype(np.int32),
        site=sid[done].astype(np.int32),
    )


def ml_dataset(result: SimResult) -> dict[str, np.ndarray]:
    """Feature/label matrices for surrogate training (paper §1: "datasets
    suitable for modern machine learning approaches").

    Features (per finished/failed job): work, cores, memory, bytes_in/out,
    priority, site one-hot stats (speed, cores, bw, queue pressure at assign),
    plus data-movement columns (WAN bytes staged, stage-in duration, dataset
    presence) so surrogates can learn transfer-dominated walltimes.  Runs with
    an ``AvailabilityState`` append availability columns — the job's preempted
    attempts, its final site's downtime fraction and cumulative preemptions —
    so surrogates can learn outage-shaped walltime tails.  Workflow DAG
    columns (``n_parents``/``dag_depth``/``wf_id``) are always present
    (0/0/-1 without a DAG) so the schema is stable across run kinds.
    Labels: walltime, queue_time, failed.

    ``write_ml_dataset`` streams the same dataset to NDJSON in bounded-memory
    segments, row/byte-identical to this in-memory form.
    """
    ctx = _ml_context(result)
    block = _ml_block(ctx)
    block["feature_names"] = np.array(ctx["names"])
    return block


def write_ml_dataset(result: SimResult, target, *, segment: int = 0) -> int:
    """Stream the ``ml_dataset`` rows to NDJSON with bounded peak memory.

    ``target`` is a path or text file object.  ``segment`` is the number of
    *jobs* whose feature block is materialized at a time (0 = all at once);
    peak export memory is O(segment × n_features), not O(jobs), so WLCG-scale
    runs export without assembling the full matrix.  The emitted bytes are
    identical for every segment size: one ``ml_header`` line (schema +
    feature names), then one ``ml_row`` line per finished/failed job in job
    order.  Returns the number of data rows written.
    """
    ctx = _ml_context(result)
    J = len(ctx["jobs"]["arrival"])
    step = J if segment <= 0 else segment
    own = not hasattr(target, "write")
    f = open(target, "w") if own else target
    n = 0
    try:
        f.write(
            json.dumps(
                {"type": "ml_header", "feature_names": ctx["names"]},
                separators=(",", ":"),
            )
            + "\n"
        )
        for lo in range(0, J, step):
            block = _ml_block(ctx, slice(lo, min(lo + step, J)))
            for i in range(len(block["walltime"])):
                rec = {
                    "type": "ml_row",
                    "job_id": int(block["job_id"][i]),
                    "site": int(block["site"][i]),
                    "features": [float(x) for x in block["features"][i]],
                    "walltime": float(block["walltime"][i]),
                    "queue_time": float(block["queue_time"][i]),
                    "failed": bool(block["failed"][i]),
                }
                f.write(json.dumps(rec, separators=(",", ":")) + "\n")
                n += 1
    finally:
        if own:
            f.close()
    return n


def recorded_trace(result: SimResult) -> dict[str, np.ndarray]:
    """Extract the calibration ground-truth columns from one finished run.

    Per finished/failed job (in job order): ``job_id``, the ``site`` it ran
    at, its ``walltime``/``queue_time``, and the WAN stage-in it performed —
    replica source ``xfer_src`` (−1 = flat-link stage-in) and ``xfer_bytes``
    moved.  This is the row schema ``calibration.platform_problem_from_trace``
    consumes; ``ml_dataset`` rows carry the same ``job_id``/``site``/
    ``walltime`` labels, so an exported NDJSON dataset (``read_ml_trace``)
    works as a trace too.
    """
    jobs = jax_to_np(result.jobs)
    done = np.isin(jobs["state"], [DONE, FAILED]) & jobs["valid"]
    S = len(np.asarray(result.sites.cores))
    return dict(
        job_id=jobs["job_id"][done].astype(np.int32),
        site=np.clip(jobs["site"], 0, S - 1)[done].astype(np.int32),
        walltime=(jobs["t_finish"] - jobs["t_start"])[done].astype(np.float32),
        queue_time=(jobs["t_start"] - jobs["arrival"])[done].astype(np.float32),
        xfer_src=jobs["xfer_src"][done].astype(np.int32),
        xfer_bytes=jobs["xfer_bytes"][done].astype(np.float32),
    )


def read_ml_trace(source) -> dict[str, np.ndarray]:
    """Load a ``write_ml_dataset`` NDJSON export back into trace arrays.

    Returns ``job_id``/``site``/``walltime``/``queue_time``/``failed``
    columns plus the feature matrix and names — the round trip that lets a
    recorded production trace on disk drive ``platform_problem_from_trace``.
    """
    own = not hasattr(source, "read")
    f = open(source) if own else source
    try:
        head = json.loads(f.readline())
        if head.get("type") != "ml_header":
            raise ValueError("not an ml NDJSON export (missing ml_header)")
        rows = [json.loads(line) for line in f if line.strip()]
    finally:
        if own:
            f.close()
    rows = [r for r in rows if r.get("type") == "ml_row"]
    return dict(
        feature_names=np.array(head["feature_names"]),
        features=np.array([r["features"] for r in rows], np.float32),
        job_id=np.array([r["job_id"] for r in rows], np.int32),
        site=np.array([r["site"] for r in rows], np.int32),
        walltime=np.array([r["walltime"] for r in rows], np.float32),
        queue_time=np.array([r["queue_time"] for r in rows], np.float32),
        failed=np.array([r["failed"] for r in rows], bool),
    )


def iter_frames(result: SimResult):
    """Yield per-round monitoring snapshots one at a time (generator form of
    ``log_frames`` — the rounds×sites table never materializes at once)."""
    log = jax_to_np(result.log)
    extra = {k: np.asarray(v) for k, v in result.log.extra.items()}
    n = int(log["cursor"])
    rows = min(n, len(log["time"]))
    for i in range(rows):
        if log["round_idx"][i] < 0:
            continue
        yield dict(
            round=int(log["round_idx"][i]),
            time=float(log["time"][i]),
            counts={k: int(v) for k, v in zip(STATE_NAMES, log["counts"][i])},
            started=int(log["n_started"][i]),
            completed=int(log["n_completed"][i]),
            site_free=log["site_free"][i].tolist(),
            site_queued=log["site_queued"][i].tolist(),
            site_running=log["site_running"][i].tolist(),
            **{k: v[i].tolist() for k, v in extra.items()},
        )


def log_frames(result: SimResult) -> list[dict]:
    """Per-round monitoring snapshots captured in-sim (EventLog ring buffer).

    Core pressure columns are always present; subsystem-declared columns
    (``EventLog.extra``, DESIGN.md §7 — e.g. ``site_disk``/``site_net_in``
    from the data subsystem, ``site_avail`` from availability) appear under
    their declared names whenever the subsystem ran, so the export schema
    assembles itself from whatever was attached.  ``iter_frames`` is the
    streaming (generator) form."""
    return list(iter_frames(result))


# streaming row sources by record type: (generator, takes site_names?)
_STREAMS = {
    "transition": (iter_transitions, True),
    "frame": (iter_frames, False),
    "job": (job_rows, True),
    "transfer": (transfer_rows, True),
    "workflow": (workflow_rows, False),
    "availability": (availability_rows, True),
    "fault": (fault_rows, True),
}


def stream_rows(result: SimResult, sink, *, kinds=("transition",), site_names=None) -> int:
    """Push event rows to a ``telemetry.Sink``, one record at a time.

    Each record is the corresponding ``*_rows`` dict plus a ``"type"`` tag
    (``transition``/``frame``/``job``/``transfer``/``workflow``/
    ``availability``) so heterogeneous kinds multiplex into one NDJSON
    stream — the chunked path named in ROADMAP's WLCG-scale item: export
    memory is per-row, not rounds×sites.  Returns the row count emitted.
    """
    n = 0
    for kind in kinds:
        if kind not in _STREAMS:
            raise ValueError(f"unknown stream kind {kind!r} (have {sorted(_STREAMS)})")
        gen, named = _STREAMS[kind]
        rows = gen(result, site_names) if named else gen(result)
        for row in rows:
            sink.emit({"type": kind, **row})
            n += 1
    return n


def jax_to_np(tree) -> dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in tree._asdict().items() if not isinstance(v, dict)}
