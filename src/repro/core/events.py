"""Event-level dataset generation (paper §4.3.2, Table 1).

CGSim records every job state transition alongside concurrent site metrics so
the runs double as ML training data.  Inside jit we only keep the per-job
timestamps (they fully determine the transition stream); this module expands
them into Table-1-style rows and ML feature matrices in numpy post-processing
— the paper's "output layer" (SQLite/CSV) becomes CSV/JSON/columnar exports.
"""
from __future__ import annotations

import csv
import io
import json

import numpy as np

from .types import CANCELLED, DONE, FAILED, STATE_NAMES, SimResult

# transition kinds, in tie-break order at equal timestamps: completions free
# cores before same-instant assigns/starts consume them (engine round order)
K_FINISH, K_ASSIGN, K_START = 0, 1, 2
KIND_NAMES = {K_ASSIGN: "assigned", K_START: "running", K_FINISH: "finished"}


def transition_rows(result: SimResult, site_names=None) -> list[dict]:
    """Expand a SimResult into one row per job state transition (Table 1).

    Each row: event_id, time, job_id, state, site, site available cores,
    site pending (queued) jobs, site assigned (running) jobs, site finished.

    Note: for resubmitted jobs only the final attempt's timestamps survive in
    ``JobsState``, so the stream contains one assign/start/finish triplet per
    job (failed intermediate attempts are visible in ``sites.n_failed``).
    """
    jobs = jax_to_np(result.jobs)
    sites = jax_to_np(result.sites)
    S = len(sites["cores"])
    name = lambda s: (site_names[s] if site_names else f"site{s}")

    evs = []
    J = len(jobs["arrival"])
    for j in range(J):
        if not jobs["valid"][j]:
            continue
        sid = int(jobs["site"][j])
        if np.isfinite(jobs["t_assign"][j]):
            evs.append((float(jobs["t_assign"][j]), K_ASSIGN, j, sid))
        if np.isfinite(jobs["t_start"][j]):
            evs.append((float(jobs["t_start"][j]), K_START, j, sid))
        if np.isfinite(jobs["t_finish"][j]):
            evs.append((float(jobs["t_finish"][j]), K_FINISH, j, sid))
    evs.sort(key=lambda e: (e[0], e[1], e[2]))

    free = sites["cores"].astype(np.int64).copy()
    queued = np.zeros(S, np.int64)   # in site queue, not yet running
    running = np.zeros(S, np.int64)
    finished = np.zeros(S, np.int64)
    rows = []
    for eid, (t, kind, j, sid) in enumerate(evs):
        if sid < 0:
            continue
        if kind == K_ASSIGN:
            queued[sid] += 1
        elif kind == K_START:
            queued[sid] -= 1
            running[sid] += 1
            free[sid] -= int(jobs["cores"][j])
        else:
            running[sid] -= 1
            free[sid] += int(jobs["cores"][j])
            finished[sid] += 1
        state = KIND_NAMES[kind]
        if kind == K_FINISH and jobs["state"][j] == FAILED:
            state = "failed"
        rows.append(
            dict(
                event_id=eid,
                time=round(t, 3),
                job_id=int(jobs["job_id"][j]),
                state=state,
                site=name(sid),
                avail_cores=int(free[sid]),
                pending_jobs=int(queued[sid]),
                assigned_jobs=int(running[sid]),
                finished_jobs=int(finished[sid]),
            )
        )
    return rows


def transfer_rows(result: SimResult, site_names=None) -> list[dict]:
    """One row per stage-in data movement (DESIGN.md §3): src/dst storage
    elements, bytes over the WAN (0 for a local cache hit), and duration.

    Only jobs that actually staged through the data subsystem produce rows
    (``xfer_src >= 0`` — a run without a DataPolicy records none); as with
    ``transition_rows``, resubmitted jobs keep their final attempt only.
    """
    jobs = jax_to_np(result.jobs)
    name = lambda s: (site_names[s] if site_names else f"site{s}")
    rows = []
    order = np.argsort(jobs["t_start"], kind="stable")
    for j in order:
        if not jobs["valid"][j] or jobs["dataset"][j] < 0 or jobs["xfer_src"][j] < 0:
            continue
        if not np.isfinite(jobs["t_start"][j]) or jobs["site"][j] < 0:
            continue
        nbytes = float(jobs["xfer_bytes"][j])
        rows.append(
            dict(
                time=round(float(jobs["t_start"][j]), 3),
                job_id=int(jobs["job_id"][j]),
                dataset=int(jobs["dataset"][j]),
                src=name(int(jobs["xfer_src"][j])),
                dst=name(int(jobs["site"][j])),
                bytes=round(nbytes, 1),
                duration=round(float(jobs["xfer_time"][j]), 3),
                cache_hit=nbytes == 0.0,
            )
        )
    return rows


def job_rows(result: SimResult, site_names=None) -> list[dict]:
    """One row per valid job with a *stable* schema across engine features.

    The workflow columns (``n_parents``/``dag_depth``/``wf_id``) are emitted
    for every run — constant ``0``/``0``/``-1`` without a DAG — so exported
    datasets from plain and workflow runs concatenate cleanly (DESIGN.md §6).
    Non-finite timestamps export as ``None`` (JSON-safe).
    """
    jobs = jax_to_np(result.jobs)
    name = lambda s: (site_names[s] if site_names else f"site{s}") if s >= 0 else None
    t = lambda x: round(float(x), 3) if np.isfinite(x) else None
    rows = []
    for j in range(len(jobs["arrival"])):
        if not jobs["valid"][j]:
            continue
        rows.append(
            dict(
                job_id=int(jobs["job_id"][j]),
                state=STATE_NAMES[int(jobs["state"][j])],
                site=name(int(jobs["site"][j])),
                arrival=t(jobs["arrival"][j]),
                t_start=t(jobs["t_start"][j]),
                t_finish=t(jobs["t_finish"][j]),
                cores=int(jobs["cores"][j]),
                work=float(jobs["work"][j]),
                retries=int(jobs["retries"][j]),
                dataset=int(jobs["dataset"][j]),
                n_parents=int(jobs["n_parents"][j]),
                dag_depth=int(jobs["dag_depth"][j]),
                wf_id=int(jobs["wf_id"][j]),
            )
        )
    return rows


def workflow_rows(result: SimResult) -> list[dict]:
    """One row per workflow (``wf_id`` group): job counts by outcome, DAG
    depth, submit time, and makespan — the per-workflow companion to the
    per-job stream (DESIGN.md §6).  Runs without a DAG produce no rows."""
    jobs = jax_to_np(result.jobs)
    sel = jobs["valid"] & (jobs["wf_id"] >= 0)
    rows = []
    for w in np.unique(jobs["wf_id"][sel]):
        m = sel & (jobs["wf_id"] == w)
        state = jobs["state"][m]
        fin = jobs["t_finish"][m]
        fin = fin[np.isfinite(fin)]
        t0 = float(jobs["arrival"][m].min())
        done = bool((state == DONE).all())
        rows.append(
            dict(
                wf_id=int(w),
                n_jobs=int(m.sum()),
                n_done=int((state == DONE).sum()),
                n_failed=int((state == FAILED).sum()),
                n_cancelled=int((state == CANCELLED).sum()),
                dag_depth=int(jobs["dag_depth"][m].max()),
                t_submit=round(t0, 3),
                t_end=round(float(fin.max()), 3) if fin.size else None,
                makespan=round(float(fin.max()) - t0, 3) if (done and fin.size) else None,
                completed=done,
            )
        )
    return rows


def availability_rows(result: SimResult, site_names=None) -> list[dict]:
    """One row per availability window (DESIGN.md §5): the outage/brown-out
    calendar alongside how many running attempts each site's outages killed.

    Rows are time-ordered by window start.  ``n_preempted`` is the site's
    *cumulative* preemption counter (repeated on each of its rows); a run
    without an ``AvailabilityState`` produces no rows.
    """
    avail = getattr(result, "avail", None)
    if avail is None:
        return []
    start = np.asarray(avail.win_start)
    end = np.asarray(avail.win_end)
    factor = np.asarray(avail.win_factor)
    preempt = np.asarray(avail.win_preempt)
    n_pre = np.asarray(avail.n_preempted)
    name = lambda s: (site_names[s] if site_names else f"site{s}")
    rows = []
    for s, w in sorted(zip(*np.nonzero(np.isfinite(start))), key=lambda i: start[i]):
        f = float(factor[s, w])
        rows.append(
            dict(
                time=round(float(start[s, w]), 3),
                site=name(int(s)),
                kind="outage" if f <= 0.0 else "brownout",
                start=round(float(start[s, w]), 3),
                end=round(float(end[s, w]), 3) if np.isfinite(end[s, w]) else float("inf"),
                factor=f,
                preempt=bool(preempt[s, w]),
                n_preempted=int(n_pre[s]),
            )
        )
    return rows


def to_csv(rows: list[dict]) -> str:
    if not rows:
        return ""
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=list(rows[0]))
    w.writeheader()
    w.writerows(rows)
    return buf.getvalue()


def to_json(rows: list[dict]) -> str:
    return json.dumps(rows)


def ml_dataset(result: SimResult) -> dict[str, np.ndarray]:
    """Feature/label matrices for surrogate training (paper §1: "datasets
    suitable for modern machine learning approaches").

    Features (per finished/failed job): work, cores, memory, bytes_in/out,
    priority, site one-hot stats (speed, cores, bw, queue pressure at assign),
    plus data-movement columns (WAN bytes staged, stage-in duration, dataset
    presence) so surrogates can learn transfer-dominated walltimes.  Runs with
    an ``AvailabilityState`` append availability columns — the job's preempted
    attempts, its final site's downtime fraction and cumulative preemptions —
    so surrogates can learn outage-shaped walltime tails.  Workflow DAG
    columns (``n_parents``/``dag_depth``/``wf_id``) are always present
    (0/0/-1 without a DAG) so the schema is stable across run kinds.
    Labels: walltime, queue_time, failed.
    """
    jobs = jax_to_np(result.jobs)
    sites = jax_to_np(result.sites)
    done = np.isin(jobs["state"], [DONE, FAILED]) & jobs["valid"]
    sid = np.clip(jobs["site"], 0, len(sites["cores"]) - 1)

    feats = np.stack(
        [
            np.log1p(jobs["work"]),
            jobs["cores"].astype(np.float64),
            jobs["memory"],
            np.log1p(jobs["bytes_in"]),
            np.log1p(jobs["bytes_out"]),
            jobs["priority"],
            sites["speed"][sid],
            sites["cores"][sid].astype(np.float64),
            np.log1p(sites["bw_in"][sid]),
            sites["par_gamma"][sid],
            sites["fail_rate"][sid],
            np.log1p(jobs["xfer_bytes"]),
            jobs["xfer_time"],
            (jobs["dataset"] >= 0).astype(np.float64),
            # workflow DAG features — constant 0/0/-1 without a workflow, so
            # the export schema is stable across plain and DAG runs
            jobs["n_parents"].astype(np.float64),
            jobs["dag_depth"].astype(np.float64),
            jobs["wf_id"].astype(np.float64),
        ],
        axis=-1,
    )[done]
    names = [
        "log_work", "cores", "memory_gb", "log_bytes_in", "log_bytes_out",
        "priority", "site_speed", "site_cores", "site_log_bw", "site_gamma",
        "site_fail_rate", "log_xfer_bytes", "xfer_time", "has_dataset",
        "n_parents", "dag_depth", "wf_id",
    ]
    avail = getattr(result, "avail", None)
    if avail is not None:
        from .availability import downtime_fraction

        down_frac = downtime_fraction(avail, float(result.makespan))
        site_pre = np.asarray(avail.n_preempted, np.float64)
        extra = np.stack(
            [
                jobs["preempted"].astype(np.float64),
                down_frac[sid],
                np.log1p(site_pre[sid]),
            ],
            axis=-1,
        )[done]
        feats = np.concatenate([feats, extra], axis=-1)
        names += ["n_preempted", "site_downtime_frac", "site_log_preempted"]
    wall = (jobs["t_finish"] - jobs["t_start"])[done]
    queue = (jobs["t_start"] - jobs["arrival"])[done]
    failed = (jobs["state"] == FAILED)[done]
    return dict(
        features=feats.astype(np.float32),
        walltime=wall.astype(np.float32),
        queue_time=queue.astype(np.float32),
        failed=failed,
        feature_names=np.array(names),
    )


def log_frames(result: SimResult) -> list[dict]:
    """Per-round monitoring snapshots captured in-sim (EventLog ring buffer).

    Core pressure columns are always present; subsystem-declared columns
    (``EventLog.extra``, DESIGN.md §7 — e.g. ``site_disk``/``site_net_in``
    from the data subsystem, ``site_avail`` from availability) appear under
    their declared names whenever the subsystem ran, so the export schema
    assembles itself from whatever was attached."""
    log = jax_to_np(result.log)
    extra = {k: np.asarray(v) for k, v in result.log.extra.items()}
    n = int(log["cursor"])
    rows = min(n, len(log["time"]))
    out = []
    for i in range(rows):
        if log["round_idx"][i] < 0:
            continue
        out.append(
            dict(
                round=int(log["round_idx"][i]),
                time=float(log["time"][i]),
                counts={k: int(v) for k, v in zip(STATE_NAMES, log["counts"][i])},
                started=int(log["n_started"][i]),
                completed=int(log["n_completed"][i]),
                site_free=log["site_free"][i].tolist(),
                site_queued=log["site_queued"][i].tolist(),
                site_running=log["site_running"][i].tolist(),
                **{k: v[i].tolist() for k, v in extra.items()},
            )
        )
    return out


def jax_to_np(tree) -> dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in tree._asdict().items() if not isinstance(v, dict)}
