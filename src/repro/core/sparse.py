"""Sparse top-k candidate scoring — the WLCG-scale perf path (DESIGN.md §12).

At paper scale (S=300 sites, J=100k jobs) the dense per-round score matrix is
the engine's memory wall: every event round materializes ``f32[J, S]`` scores
plus a ``bool[J, S]`` feasibility mask (~150MB/round, several passes).  The
sparse mode replaces both with a static-``k`` per-job *candidate-site index*
``i32[J, K]`` built here — once at init (the default) or every
``topk_refresh`` rounds — from three signals:

  1. static feasibility (active, core/memory fit — constant over a run),
  2. the policy's dense pre-rank (``Policy.pre_rank``, falling back to
     ``Policy.score``),
  3. data locality: sites holding a replica of the job's dataset, plus the
     ``replicas.nearest_source`` pick for the pre-rank-best destination,
     rank above equally-scored non-holders.

Per round the engine then evaluates ``Policy.score_cand`` (or a dense-score
gather) over ``[J, K]`` only.  Exactness contract: candidate rows are sorted
ascending by site id with sentinel ``S`` padding, so at ``k >= S`` the index
enumerates *all* statically feasible sites and the sparse argmax reproduces
the dense first-max tie-break bit-for-bit; at ``k < S`` assignment is a
documented approximation (gated by a ≤1% makespan-drift acceptance test).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# salt for the non-consuming candidate-build RNG stream: folding the round
# carry key keeps the engine's own split(rng, 4) bitstream untouched, so a
# sparse run draws identical failure/policy randomness to its dense twin
CAND_SALT = 0x7093


def static_feasibility(jobs, sites) -> jax.Array:
    """``bool[J, S]`` — can this job *ever* fit this site (active, total
    cores, total memory).  Time-invariant, so it can be baked into the
    candidate index; dynamic per-round masks (availability windows, free
    capacity) are re-applied at gather time by the engine."""
    return (
        sites.active[None, :]
        & (jobs.cores[:, None] <= sites.cores[None, :])
        & (jobs.memory[:, None] <= sites.memory[None, :])
    )


def build_candidates(jobs, sites, policy, pstate, clock, key, ext, k: int) -> jax.Array:
    """Build the ``i32[J, K]`` candidate-site index (sentinel ``S`` = empty).

    O(J*S) work — paid only at init / every ``topk_refresh`` rounds, never on
    the per-round hot path.  Rows come out sorted ascending by site id with
    the dense pre-rank argmax force-included, so (a) ``k >= S`` degenerates
    to "all feasible sites in dense scan order" (bit-for-bit dense parity)
    and (b) the candidate set provably contains the dense argmax site
    whenever any site is feasible.
    """
    S = sites.capacity
    k = min(int(k), S)
    feas = static_feasibility(jobs, sites)
    neg = jnp.float32(-jnp.inf)
    pre_fn = getattr(policy, "pre_rank", None) or policy.score
    masked = jnp.where(feas, pre_fn(jobs, sites, pstate, clock, key), neg)
    best = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    best_val = jnp.max(masked, axis=-1)

    sel = masked
    if "data" in ext:
        # data-locality bonus: replica holders of the job's dataset, plus the
        # nearest WAN source toward the pre-rank-best destination, outrank
        # equally-scored non-holders.  The bonus exceeds the row's finite
        # score range, so it reorders *between* the groups, never within.
        from .replicas import nearest_source

        dext = ext["data"]
        rep, net = dext.replicas, dext.network
        D = rep.present.shape[-2]
        has_ds = jobs.dataset >= 0
        d_c = jnp.clip(jobs.dataset, 0, D - 1)
        holders = rep.present[d_c]  # [J, S]
        src = nearest_source(rep, net, jobs.dataset, best)  # [J]
        local = holders | (jnp.arange(S)[None, :] == src[:, None])
        row_max = jnp.max(jnp.where(feas, masked, neg), axis=-1)
        row_min = jnp.min(jnp.where(feas, masked, jnp.float32(jnp.inf)), axis=-1)
        span = jnp.where(
            jnp.isfinite(row_max) & jnp.isfinite(row_min), row_max - row_min, 0.0
        )
        bonus = (span + 1.0)[:, None]
        sel = jnp.where(feas & local & has_ds[:, None], masked + bonus, masked)

    _, idx = jax.lax.top_k(sel, k)
    idx = idx.astype(jnp.int32)
    # force-include the dense pre-rank argmax: locality bonuses may push it
    # past slot k, but the membership guarantee is what the k<S approximation
    # is gated on (hypothesis-tested)
    missing = jnp.isfinite(best_val) & ~jnp.any(idx == best[:, None], axis=-1)
    idx = idx.at[..., -1].set(jnp.where(missing, best, idx[..., -1]))
    # sentinel-out infeasible slots, then sort ascending by site id (sentinels
    # sort last) — the dense-argmax tie-break order
    vals = jnp.take_along_axis(masked, idx, axis=-1)
    cand = jnp.where(jnp.isfinite(vals), idx, jnp.int32(S))
    return jnp.sort(cand, axis=-1)


def bytes_per_round(J: int, S: int, k: int | None) -> dict:
    """The §12 memory model: per-round score-path bytes, dense vs sparse.

    Dense rounds materialize the f32 score matrix, the bool feasibility mask,
    and the masked-score intermediate; sparse rounds carry the i32 candidate
    index plus f32 score/bool mask gathers over [J, K].
    """
    dense = J * S * (4 + 1 + 4)
    sparse = None if k is None else J * min(k, S) * (4 + 4 + 1) + S
    return dict(dense=dense, sparse=sparse,
                ratio=None if sparse is None else dense / sparse)
