"""Distributed simulation — beyond the paper's single-process scaling.

CGSim runs on one laptop core; its multi-site scaling is wall-time-linear in
sites.  Because our engine state is dense arrays, the *simulator itself*
shards: jobs over the ``data`` mesh axis (and calibration replicas over the
whole mesh).  We deliberately use pjit/SPMD rather than hand-rolled actors:
the engine body's min-reductions become ``all-reduce(min)``, the per-site
``segment_sum`` updates become scatter+``psum``, inserted by XLA.  The
collective schedule is inspected by the dry-run (EXPERIMENTS.md §Dry-run).

Sharding map:
  jobs.* [J]      -> P(axis)       one shard of jobs per device
  sites.* [S]     -> replicated    every device sees the whole grid
  scalars, rng    -> replicated

Ensemble (calibration) map:
  candidates [K,S] -> P(axis, None)  independent sims per device (no comms)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .engine import (
    Scenario,
    ScenarioBuckets,
    _run_buckets,
    _simulate,
    simulate,
    simulate_many,
    stack_scenarios,
)
from .types import JobsState, SimResult, SiteState


def use_mesh(mesh: Mesh):
    """Mesh-context compat: ``jax.set_mesh`` (new API) or the Mesh object
    itself, which is a context manager on older jax (<= 0.4.x)."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def job_shardings(mesh: Mesh, axis: str, jobs: JobsState, sites: SiteState):
    """NamedShardings for (jobs, sites, rng) under job-parallel simulation."""
    jsh = jax.tree.map(lambda _: NamedSharding(mesh, P(axis)), jobs)
    ssh = jax.tree.map(lambda _: NamedSharding(mesh, P()), sites)
    return jsh, ssh, NamedSharding(mesh, P())


def shard_jobs(jobs: JobsState, sites: SiteState, mesh: Mesh, axis: str = "data"):
    """Place a workload on the mesh for job-parallel simulation.

    Pads the job capacity to a multiple of the axis size (padding rows are
    DONE/invalid so they never participate)."""
    n_dev = mesh.shape[axis]
    J = jobs.capacity
    pad = (-J) % n_dev
    if pad:
        from .types import pad_jobs_capacity

        jobs = pad_jobs_capacity(jobs, J + pad)
    jsh, ssh, _ = job_shardings(mesh, axis, jobs, sites)
    return jax.device_put(jobs, jsh), jax.device_put(sites, ssh)


def _prepare_subsystems(kw: dict, jobs, sites, mesh: Mesh, old_capacity: int) -> dict:
    """Normalize the subsystem kwargs into explicit ``(Subsystem, state)``
    pairs with state padded to the (possibly grown) job capacity and fully
    replicated on the mesh, mirroring ``sites``.  Subsystem state is
    read-only or all-reduced inside the round loop, so replication costs one
    copy — and the engine never sees a mesh-specific code path.

    Entirely generic: capacity padding goes through each subsystem's
    ``pad_jobs`` hook and replication is one ``tree.map`` over the whole ext
    mapping, so new subsystems distribute with zero code here."""
    from .subsystems import pad_ext_jobs, resolve_subsystems

    kw = dict(kw)
    subs, ext = resolve_subsystems(
        data_policy=kw.pop("data_policy", None),
        network=kw.pop("network", None),
        replicas=kw.pop("replicas", None),
        availability=kw.pop("availability", None),
        workflow=kw.pop("workflow", None),
        transfers=kw.pop("transfers", None),
        faults=kw.pop("faults", None),
        subsystems=kw.pop("subsystems", ()),
        jobs=jobs,
        sites=sites,
        validate=False,  # validated by simulate() against the padded shapes
    )
    ext = pad_ext_jobs(subs, ext, old_capacity, jobs.capacity)
    rep = NamedSharding(mesh, P())
    ext = jax.tree.map(lambda x: jax.device_put(jnp.asarray(x), rep), ext)
    kw["subsystems"] = tuple((sub, ext[sub.name]) for sub in subs)
    return kw


def simulate_distributed(
    jobs: JobsState,
    sites: SiteState,
    policy,
    rng: jax.Array,
    mesh: Mesh,
    *,
    axis: str = "data",
    **kw,
) -> SimResult:
    """Job-parallel simulation: identical semantics to ``engine.simulate``
    (same event rounds, same FIFO), with XLA SPMD distributing each round."""
    jobs_d, sites_d = shard_jobs(jobs, sites, mesh, axis)
    kw = _prepare_subsystems(kw, jobs_d, sites_d, mesh, jobs.capacity)
    with use_mesh(mesh):
        return simulate(jobs_d, sites_d, policy, rng, **kw)


def lower_distributed(
    jobs: JobsState,
    sites: SiteState,
    policy,
    mesh: Mesh,
    *,
    axis: str = "data",
    **kw,
):
    """Lower+compile the engine for a mesh from ShapeDtypeStructs only —
    the simulator's own multi-pod dry-run (no allocation)."""
    jsh, ssh, rsh = job_shardings(mesh, axis, jobs, sites)
    jobs_s = jax.tree.map(lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s), jobs, jsh)
    sites_s = jax.tree.map(lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s), sites, ssh)
    rng_s = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rsh)

    def fn(j, s, r):
        return simulate(j, s, policy, r, **kw)

    with use_mesh(mesh):
        lowered = jax.jit(fn).lower(jobs_s, sites_s, rng_s)
        return lowered, lowered.compile()


def simulate_ensemble_distributed(
    jobs: JobsState,
    sites: SiteState,
    policy,
    rng: jax.Array,
    speed_candidates: jax.Array,  # [K, S]
    mesh: Mesh,
    *,
    axis: str = "data",
    **kw,
) -> SimResult:
    """K independent sims (calibration ensemble), candidates sharded over the
    mesh axis — embarrassingly parallel, zero collectives in steady state."""
    K = speed_candidates.shape[0]
    n_dev = mesh.shape[axis]
    if K % n_dev:
        raise ValueError(f"candidates {K} must divide over {n_dev} devices")
    cand = jax.device_put(speed_candidates, NamedSharding(mesh, P(axis, None)))
    keys = jax.device_put(jax.random.split(rng, K), NamedSharding(mesh, P(axis, None)))
    kw = _prepare_subsystems(kw, jobs, sites, mesh, jobs.capacity)

    def one(speed, key):
        return simulate(jobs, sites._replace(speed=speed), policy, key, **kw)

    with use_mesh(mesh):
        return jax.vmap(one)(cand, keys)


# --------------------------------------------------------------------------
# sharded scenario ensembles: lock-step-free simulate_many (DESIGN.md §8)
# --------------------------------------------------------------------------


def _shard_map_compat(fn, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions (experimental on <= 0.4.x)."""
    try:
        from jax import shard_map as _sm  # jax >= 0.5-ish

        return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    except (ImportError, TypeError):
        from jax.experimental.shard_map import shard_map as _sm

        return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


@functools.lru_cache(maxsize=None)
def _sharded_ensemble_fn(policy, subsystems, mesh, axis, donate, lane_mode, kw_items):
    """Build (and cache) the jitted shard_map program for one ensemble
    configuration.  Caching on the static configuration keeps repeat calls on
    the jit fast path instead of retracing a fresh closure every time."""
    kw = dict(kw_items)

    def block(jobs, sites, ext, keys):
        # one device's lane block, free of *global* lock-step either way:
        #
        # - "scan": lanes run one after another, each in its own solo
        #   while_loop — zero lock-step even inside the block, and the
        #   phase-skip guard fires per lane.  The right mode when lanes
        #   don't vectorize (CPU hosts: a batched round costs ~K solo
        #   rounds, so retiring lanes independently strictly wins).
        # - "vmap": lanes batch SIMD-style; the block's while_loop halts
        #   when the *local* lanes drain and the phase-skip batch-any
        #   reduces over the block alone.  The right mode on accelerators,
        #   where a batched round is far cheaper than K solo rounds.
        def one(j, s, e, k):
            return _simulate(j, s, policy, k, e, subsystems=subsystems, **kw)

        if lane_mode == "scan":
            def step(carry, x):
                return carry, one(*x)

            _, res = jax.lax.scan(step, None, (jobs, sites, ext, keys))
            return res
        return jax.vmap(one)(jobs, sites, ext, keys)

    fn = _shard_map_compat(
        block, mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis),
    )
    # the stacked lane buffers are device_put copies owned by the caller
    # below, so they are donated into the program: XLA aliases them straight
    # into the while-loop carry instead of defensively copying K-lane state
    return jax.jit(fn, donate_argnums=(0, 1, 2, 3) if donate else ())


def _sharded_stacked(
    scenarios: Scenario,
    keys: jax.Array,
    policy,
    mesh: Mesh,
    axis: str,
    subsystems: tuple,
    donate: bool | None,
    lane_mode: str,
    kw: dict,
) -> SimResult:
    from .engine import _check_ensemble

    if lane_mode == "auto":
        # scan lanes where batching doesn't pay (CPU), vectorize where it
        # does (accelerators) — both are bit-for-bit identical per lane
        lane_mode = "scan" if jax.default_backend() == "cpu" else "vmap"
    if lane_mode not in ("scan", "vmap"):
        raise ValueError(f"lane_mode must be auto|scan|vmap, got {lane_mode!r}")
    ext = _check_ensemble(scenarios, subsystems)
    scenarios = Scenario(scenarios.jobs, scenarios.sites, ext)
    K = scenarios.jobs.arrival.shape[0]
    n_dev = mesh.shape[axis]
    pad = (-K) % n_dev
    if pad:
        # round the lane count up to the mesh axis: repeat the last scenario
        # into throwaway lanes (their results are sliced off below)
        pad_ix = jnp.concatenate(
            [jnp.arange(K), jnp.full((pad,), K - 1, jnp.int32)]
        )
        scenarios = jax.tree.map(lambda x: x[pad_ix], scenarios)
        keys = keys[pad_ix]
    if donate is None:
        # on a 1-device mesh the device_put below can alias the caller's
        # arrays instead of resharding, so donation is only safe (and only
        # useful) when the lanes actually spread over the mesh
        donate = mesh.devices.size > 1
    sh = NamedSharding(mesh, P(axis))
    if donate:
        # inputs already laid out on the mesh pass through device_put
        # untouched — donating would hand the *caller's* buffers to XLA and
        # invalidate them for the next call, so fall back to non-donating
        leaves = jax.tree.leaves((scenarios, keys))
        if any(getattr(x, "sharding", None) == sh for x in leaves):
            donate = False
    args = jax.tree.map(
        lambda x: jax.device_put(jnp.asarray(x), sh),
        (scenarios.jobs, scenarios.sites, scenarios.ext, keys),
    )
    fn = _sharded_ensemble_fn(
        policy, tuple(subsystems), mesh, axis, donate, lane_mode,
        tuple(sorted(kw.items())),
    )
    with use_mesh(mesh):
        res = fn(*args)
    if pad:
        res = jax.tree.map(lambda x: x[:K], res)
    return res


def simulate_many_sharded(
    scenarios,
    policy,
    rng: jax.Array,
    mesh: Mesh,
    *,
    axis: str = "data",
    subsystems: tuple = (),
    donate: bool | None = None,
    lane_mode: str = "auto",
    recorder=None,
    **kw,
) -> SimResult:
    """Lock-step-free ensemble execution: the stacked scenario axis K is
    partitioned over ``mesh[axis]`` with ``shard_map``, and every device runs
    its *own* ``lax.while_loop`` over its lane block.

    This attacks the ensemble lock-step tax at the shard level (DESIGN.md
    §8): under plain ``simulate_many`` all K lanes spin until the slowest
    scenario terminates, paying full round work per lane per round; here a
    shard whose scenarios drain early simply stops.  There are no cross-
    device collectives — each lane's state is fully local to its device — so
    scaling is near-linear in devices (``benchmarks/bench_engine_rounds
    --devices``).  Lane results are bit-for-bit identical to plain
    ``simulate_many`` and to solo ``simulate`` runs: sharding only changes
    *which* device retires a lane's rounds, never the rounds themselves.

    ``scenarios`` is a list of ``Scenario``s, a stacked ``Scenario``, or a
    ``ScenarioBuckets`` (each bucket is sharded separately and results merge
    in original order).  Lane counts that do not divide the mesh axis are
    padded with throwaway repeats of the last lane.  ``donate`` controls
    donating the on-mesh lane buffers into the program (default: on for
    multi-device meshes).  ``lane_mode`` picks how a device walks its lane
    block: ``"scan"`` (sequential solo loops — zero lock-step, the CPU
    default) or ``"vmap"`` (SIMD batching — the accelerator default);
    ``"auto"`` resolves by backend.

    Pass a ``telemetry.TraceRecorder`` as ``recorder`` to instrument the run:
    stack/run wall-clock spans, lane and mesh gauges, per-lane round spread,
    and (for bucketed input) the measured padding-waste breakdown from
    ``ScenarioBuckets.padding_stats`` — the numbers behind the PR 5 win.
    """
    runner = lambda scen, keys: _sharded_stacked(  # noqa: E731
        scen, keys, policy, mesh, axis, subsystems, donate, lane_mode, kw
    )
    if recorder is None:
        if isinstance(scenarios, ScenarioBuckets):
            return _run_buckets(scenarios, rng, runner, subsystems)
        if not isinstance(scenarios, Scenario):
            scenarios = stack_scenarios(scenarios, subsystems=subsystems)
        K = scenarios.jobs.arrival.shape[0]
        return runner(scenarios, jax.random.split(rng, K))

    buckets = scenarios if isinstance(scenarios, ScenarioBuckets) else None
    if buckets is None and not isinstance(scenarios, Scenario):
        with recorder.span("ensemble_stack"):
            scenarios = stack_scenarios(scenarios, subsystems=subsystems)
            if isinstance(scenarios, ScenarioBuckets):  # pragma: no cover
                buckets = scenarios
    n_dev = mesh.shape[axis]
    if buckets is not None:
        lanes = [s.jobs.arrival.shape[0] for s in buckets.buckets]
        K = sum(lanes)
        lane_pad = sum((-k) % n_dev for k in lanes)
        recorder.note("bucket_padding", buckets.padding_stats())
        with recorder.span("ensemble_run"):
            res = _run_buckets(buckets, rng, runner, subsystems)
            jax.block_until_ready(res)
    else:
        K = scenarios.jobs.arrival.shape[0]
        lane_pad = (-K) % n_dev
        with recorder.span("ensemble_run"):
            res = runner(scenarios, jax.random.split(rng, K))
            jax.block_until_ready(res)
    import numpy as np

    rounds = np.asarray(res.rounds)
    recorder.gauge("lanes", K)
    recorder.gauge("mesh_devices", int(mesh.devices.size))
    recorder.gauge("lane_pad_total", lane_pad)
    recorder.gauge("lane_rounds_min", int(rounds.min()))
    recorder.gauge("lane_rounds_max", int(rounds.max()))
    recorder.gauge("lane_rounds_mean", float(rounds.mean()))
    recorder.note("lane_mode", lane_mode)
    return res


def simulate_population(
    scenarios,
    policy,
    rng: jax.Array,
    *,
    mesh: Mesh | None = None,
    axis: str = "data",
    subsystems: tuple = (),
    **kw,
) -> SimResult:
    """One entry point for candidate-population ensembles (calibration lanes).

    A calibration step evaluates a whole candidate population as ensemble
    lanes; whether those lanes run on one device (``simulate_many``) or
    spread over a mesh (``simulate_many_sharded``) is a deployment detail the
    optimizer should not care about.  ``mesh=None`` takes the single-device
    vmapped path; a mesh takes the lock-step-free sharded path (lane counts
    that do not divide the mesh are padded with repeats, results unpadded).
    Lane ``i`` draws ``split(rng, K)[i]`` on both paths, so results are
    bit-for-bit identical across deployments and to solo ``simulate`` runs.
    """
    if mesh is None:
        return simulate_many(scenarios, policy, rng, subsystems=subsystems, **kw)
    return simulate_many_sharded(
        scenarios, policy, rng, mesh, axis=axis, subsystems=subsystems, **kw
    )
