"""Distributed simulation — beyond the paper's single-process scaling.

CGSim runs on one laptop core; its multi-site scaling is wall-time-linear in
sites.  Because our engine state is dense arrays, the *simulator itself*
shards: jobs over the ``data`` mesh axis (and calibration replicas over the
whole mesh).  We deliberately use pjit/SPMD rather than hand-rolled actors:
the engine body's min-reductions become ``all-reduce(min)``, the per-site
``segment_sum`` updates become scatter+``psum``, inserted by XLA.  The
collective schedule is inspected by the dry-run (EXPERIMENTS.md §Dry-run).

Sharding map:
  jobs.* [J]      -> P(axis)       one shard of jobs per device
  sites.* [S]     -> replicated    every device sees the whole grid
  scalars, rng    -> replicated

Ensemble (calibration) map:
  candidates [K,S] -> P(axis, None)  independent sims per device (no comms)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .engine import simulate
from .types import JobsState, SimResult, SiteState


def use_mesh(mesh: Mesh):
    """Mesh-context compat: ``jax.set_mesh`` (new API) or the Mesh object
    itself, which is a context manager on older jax (<= 0.4.x)."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def job_shardings(mesh: Mesh, axis: str, jobs: JobsState, sites: SiteState):
    """NamedShardings for (jobs, sites, rng) under job-parallel simulation."""
    jsh = jax.tree.map(lambda _: NamedSharding(mesh, P(axis)), jobs)
    ssh = jax.tree.map(lambda _: NamedSharding(mesh, P()), sites)
    return jsh, ssh, NamedSharding(mesh, P())


def shard_jobs(jobs: JobsState, sites: SiteState, mesh: Mesh, axis: str = "data"):
    """Place a workload on the mesh for job-parallel simulation.

    Pads the job capacity to a multiple of the axis size (padding rows are
    DONE/invalid so they never participate)."""
    n_dev = mesh.shape[axis]
    J = jobs.capacity
    pad = (-J) % n_dev
    if pad:
        from .types import pad_jobs_capacity

        jobs = pad_jobs_capacity(jobs, J + pad)
    jsh, ssh, _ = job_shardings(mesh, axis, jobs, sites)
    return jax.device_put(jobs, jsh), jax.device_put(sites, ssh)


def _prepare_subsystems(kw: dict, jobs, sites, mesh: Mesh, old_capacity: int) -> dict:
    """Normalize the subsystem kwargs into explicit ``(Subsystem, state)``
    pairs with state padded to the (possibly grown) job capacity and fully
    replicated on the mesh, mirroring ``sites``.  Subsystem state is
    read-only or all-reduced inside the round loop, so replication costs one
    copy — and the engine never sees a mesh-specific code path.

    Entirely generic: capacity padding goes through each subsystem's
    ``pad_jobs`` hook and replication is one ``tree.map`` over the whole ext
    mapping, so new subsystems distribute with zero code here."""
    from .subsystems import pad_ext_jobs, resolve_subsystems

    kw = dict(kw)
    subs, ext = resolve_subsystems(
        data_policy=kw.pop("data_policy", None),
        network=kw.pop("network", None),
        replicas=kw.pop("replicas", None),
        availability=kw.pop("availability", None),
        workflow=kw.pop("workflow", None),
        subsystems=kw.pop("subsystems", ()),
        jobs=jobs,
        sites=sites,
        validate=False,  # validated by simulate() against the padded shapes
    )
    ext = pad_ext_jobs(subs, ext, old_capacity, jobs.capacity)
    rep = NamedSharding(mesh, P())
    ext = jax.tree.map(lambda x: jax.device_put(jnp.asarray(x), rep), ext)
    kw["subsystems"] = tuple((sub, ext[sub.name]) for sub in subs)
    return kw


def simulate_distributed(
    jobs: JobsState,
    sites: SiteState,
    policy,
    rng: jax.Array,
    mesh: Mesh,
    *,
    axis: str = "data",
    **kw,
) -> SimResult:
    """Job-parallel simulation: identical semantics to ``engine.simulate``
    (same event rounds, same FIFO), with XLA SPMD distributing each round."""
    jobs_d, sites_d = shard_jobs(jobs, sites, mesh, axis)
    kw = _prepare_subsystems(kw, jobs_d, sites_d, mesh, jobs.capacity)
    with use_mesh(mesh):
        return simulate(jobs_d, sites_d, policy, rng, **kw)


def lower_distributed(
    jobs: JobsState,
    sites: SiteState,
    policy,
    mesh: Mesh,
    *,
    axis: str = "data",
    **kw,
):
    """Lower+compile the engine for a mesh from ShapeDtypeStructs only —
    the simulator's own multi-pod dry-run (no allocation)."""
    jsh, ssh, rsh = job_shardings(mesh, axis, jobs, sites)
    jobs_s = jax.tree.map(lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s), jobs, jsh)
    sites_s = jax.tree.map(lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s), sites, ssh)
    rng_s = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rsh)

    def fn(j, s, r):
        return simulate(j, s, policy, r, **kw)

    with use_mesh(mesh):
        lowered = jax.jit(fn).lower(jobs_s, sites_s, rng_s)
        return lowered, lowered.compile()


def simulate_ensemble_distributed(
    jobs: JobsState,
    sites: SiteState,
    policy,
    rng: jax.Array,
    speed_candidates: jax.Array,  # [K, S]
    mesh: Mesh,
    *,
    axis: str = "data",
    **kw,
) -> SimResult:
    """K independent sims (calibration ensemble), candidates sharded over the
    mesh axis — embarrassingly parallel, zero collectives in steady state."""
    K = speed_candidates.shape[0]
    n_dev = mesh.shape[axis]
    if K % n_dev:
        raise ValueError(f"candidates {K} must divide over {n_dev} devices")
    cand = jax.device_put(speed_candidates, NamedSharding(mesh, P(axis, None)))
    keys = jax.device_put(jax.random.split(rng, K), NamedSharding(mesh, P(axis, None)))
    kw = _prepare_subsystems(kw, jobs, sites, mesh, jobs.capacity)

    def one(speed, key):
        return simulate(jobs, sites._replace(speed=speed), policy, key, **kw)

    with use_mesh(mesh):
        return jax.vmap(one)(cand, keys)
