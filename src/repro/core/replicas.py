"""Storage elements and the replica catalog (DESIGN.md §3).

Grid jobs read *datasets* that live on storage elements at specific sites;
where the replicas are dominates stage-in time (Begy et al., Horzela et al.).
Dense representation over D datasets x S sites:

  present[D, S]      replica catalog (bool)
  size[D]            dataset bytes
  origin[D]          pinned home site — the tape/origin copy, never evicted
  disk_used[S]/cap   storage-element occupancy
  last_access[D, S]  LRU clock for capacity eviction

All operations (source selection, cache-on-read insertion, masked LRU
eviction) are fixed-shape masked algebra, so an engine carrying a
``ReplicaState`` still jits and vmaps for calibration ensembles.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.float32(jnp.inf)


class ReplicaState(NamedTuple):
    present: jax.Array      # bool[D, S] replica catalog
    size: jax.Array         # f32[D] dataset bytes
    origin: jax.Array       # i32[D] home site (pinned copy)
    disk_used: jax.Array    # f32[S] bytes resident per storage element
    disk_cap: jax.Array     # f32[S] storage-element capacity
    last_access: jax.Array  # f32[D, S] last read/insert time (LRU)
    n_hits: jax.Array       # i32[] cumulative local cache hits
    n_transfers: jax.Array  # i32[] cumulative WAN transfers
    bytes_moved: jax.Array  # f32[] cumulative WAN bytes

    @property
    def n_datasets(self) -> int:
        return self.present.shape[-2]

    @property
    def n_sites(self) -> int:
        return self.present.shape[-1]


def make_replicas(
    sizes,
    disk_capacity,
    *,
    origin=None,
    placement=None,
    materialized=None,
    seed: int = 0,
) -> ReplicaState:
    """Build a catalog: one pinned origin replica per dataset plus optional
    extra ``placement`` (bool[D, S]).  Default origins are drawn by capacity
    weight (big storage elements hold more data), like PanDA's data lakes.

    ``materialized`` (bool[D], default all True) marks datasets that exist at
    t=0; False rows start with no replica anywhere and ``origin = -1`` —
    intermediate workflow outputs that some job will materialize mid-run via
    ``materialize_outputs`` (DESIGN.md §6).
    """
    size = jnp.asarray(sizes, jnp.float32)
    cap = jnp.asarray(disk_capacity, jnp.float32)
    D, S = size.shape[0], cap.shape[0]
    mat = (
        np.ones(D, bool) if materialized is None else np.asarray(materialized, bool)
    )
    if origin is None:
        rng = np.random.default_rng(seed)
        w = np.maximum(np.asarray(cap, np.float64), 0.0)
        w = w / max(w.sum(), 1e-9)
        origin = np.where(mat, rng.choice(S, size=D, p=w), -1)
    origin = jnp.asarray(origin, jnp.int32)
    seeded = jnp.asarray(mat) & (origin >= 0)
    present = (
        jnp.zeros((D, S), bool).at[jnp.arange(D), jnp.clip(origin, 0, S - 1)].set(seeded)
    )
    if placement is not None:
        present = present | jnp.asarray(placement, bool)
    disk_used = (present * size[:, None]).sum(0)
    return ReplicaState(
        present=present,
        size=size,
        origin=origin,
        disk_used=disk_used,
        disk_cap=cap,
        last_access=jnp.where(present, 0.0, -INF),
        n_hits=jnp.zeros((), jnp.int32),
        n_transfers=jnp.zeros((), jnp.int32),
        bytes_moved=jnp.zeros((), jnp.float32),
    )


def materialize_outputs(
    rep: ReplicaState, dataset: jax.Array, site: jax.Array, mask: jax.Array, clock
) -> ReplicaState:
    """Row-wise output production (DESIGN.md §6): where ``mask[j]``, dataset
    ``dataset[j]`` comes into existence at ``site[j]`` — the site the
    producing job actually ran on — and that copy becomes the dataset's
    pinned origin (the authoritative replica children stage in from; never
    LRU-evicted).

    Like ``make_replicas``' initial origin copies, the authoritative copy
    bypasses the capacity check — size origin storage elements for the data
    they must hold; only policy-managed caches are capacity-bound.
    """
    D, S = rep.present.shape
    d = jnp.clip(dataset, 0, D - 1)
    s = jnp.clip(site, 0, S - 1).astype(jnp.int32)
    dd = jnp.where(mask, d, D)  # out-of-range rows drop out of the scatters
    origin = rep.origin.at[dd].set(s, mode="drop")
    add = jnp.zeros((D, S), bool).at[dd, s].set(True, mode="drop")
    new = add & ~rep.present
    return rep._replace(
        present=rep.present | add,
        origin=origin,
        disk_used=rep.disk_used + (new * rep.size[:, None]).sum(0),
        last_access=jnp.where(add, jnp.float32(clock), rep.last_access),
    )


def zipf_dataset_sizes(n_datasets: int, *, seed: int = 0, mean_bytes: float = 20e9, sigma: float = 1.0):
    """Log-normal dataset sizes (HEP AOD/DAOD-flavoured heavy tail)."""
    rng = np.random.default_rng(seed)
    return rng.lognormal(np.log(mean_bytes), sigma, n_datasets).astype(np.float32)


# --------------------------------------------------------------------------
# source selection
# --------------------------------------------------------------------------


def nearest_source(rep: ReplicaState, net, dataset: jax.Array, dst: jax.Array) -> jax.Array:
    """Best replica site for each job: minimize unshared transfer time
    ``latency[src, dst] + size / bw[src, dst]`` over sites holding a replica.

    Local replicas win automatically (the diagonal link is ~free).  Rows whose
    dataset has no *reachable* replica fall back to the pinned origin (which
    by construction always holds one).

    Unreachable sources — no-link sentinels like zero/NaN bandwidth or
    non-finite latency — are masked out of both the cost *operands* and the
    argmin, so the division never touches a sentinel and the whole selection
    is NaN-free under ``jax.debug_nans`` regardless of link encoding.
    """
    D, S = rep.present.shape
    d = jnp.clip(dataset, 0, D - 1)
    lat = net.latency[:, :].T[dst]              # [J, S] latency[src, dst_j]
    bw = net.bw[:, :].T[dst]                    # [J, S]
    reach = rep.present[d] & (bw > 0) & jnp.isfinite(lat)
    # sentinel-proof operands: unreachable cells compute 0 + 0/1, never
    # inf/inf or nan arithmetic; reachable cells see the exact original values
    lat_s = jnp.where(reach, lat, 0.0)
    bw_s = jnp.where(reach, jnp.maximum(bw, 1e-9), 1.0)
    cost = jnp.where(reach, lat_s + rep.size[d][:, None] / bw_s, INF)
    src = jnp.argmin(cost, axis=-1).astype(jnp.int32)
    return jnp.where(jnp.any(reach, axis=-1), src, rep.origin[d])


# --------------------------------------------------------------------------
# cache insertion with masked LRU eviction
# --------------------------------------------------------------------------


def insert_mask(rep: ReplicaState, want: jax.Array, clock) -> ReplicaState:
    """Insert replicas for every True cell of ``want[D, S]``, evicting LRU
    non-origin replicas per site to make room.  Sites that cannot fit a new
    replica even after evicting everything evictable skip the insertion, so
    ``disk_used <= disk_cap`` is an invariant (given a valid initial state).

    The LRU machinery (a [D, S] argsort) only runs when some site is actually
    over capacity: pressure-free rounds — the common case, and the only case
    on adequately-provisioned WLCG catalogs — take a scalar-guarded fast path
    that is value-identical (with ``need == 0`` the eviction mask below is
    provably all-False and every insertion fits).
    """
    D, S = rep.present.shape
    size_col = rep.size[:, None]                       # [D, 1]
    new = want & ~rep.present
    incoming = (new * size_col).sum(0)                 # f32[S]
    need = jnp.maximum(rep.disk_used + incoming - rep.disk_cap, 0.0)

    def _fast(rep: ReplicaState) -> ReplicaState:
        return rep._replace(
            present=rep.present | new,
            disk_used=rep.disk_used + incoming,
            last_access=jnp.where(new, jnp.float32(clock), rep.last_access),
        )

    def _evict(rep: ReplicaState) -> ReplicaState:
        return _insert_mask_evicting(rep, want, new, incoming, need, clock)

    from .engine import _ensemble_any  # lazy: avoid import cycle at module load

    return jax.lax.cond(_ensemble_any(jnp.any(need > 0.0)), _evict, _fast, rep)


def _insert_mask_evicting(
    rep: ReplicaState, want, new, incoming, need, clock
) -> ReplicaState:
    """The full LRU-eviction path of ``insert_mask`` (see its docstring)."""
    D, S = rep.present.shape
    size_col = rep.size[:, None]
    is_origin = (
        jnp.arange(S)[None, :] == jnp.clip(rep.origin, 0, S - 1)[:, None]
    )                                                  # [D, S]

    # LRU eviction candidates: resident, not the pinned origin, not being
    # read/inserted this round.
    evictable = rep.present & ~is_origin & ~want
    order = jnp.argsort(jnp.where(evictable, rep.last_access, INF), axis=0)  # [D, S]
    ev_sorted = jnp.take_along_axis(evictable, order, axis=0)
    sz_sorted = jnp.take_along_axis(jnp.broadcast_to(size_col, (D, S)), order, axis=0)
    sz_sorted = jnp.where(ev_sorted, sz_sorted, 0.0)
    cum_excl = jnp.cumsum(sz_sorted, axis=0) - sz_sorted
    evict_sorted = ev_sorted & (cum_excl < need[None, :])
    evict = jnp.zeros((D, S), bool).at[order, jnp.arange(S)[None, :]].set(evict_sorted)
    freed = (evict * size_col).sum(0)

    # drop insertions at sites that still don't fit after max eviction
    fits = rep.disk_used - freed + incoming <= rep.disk_cap + 1e-3
    do_insert = new & fits[None, :]
    kept_in = (do_insert * size_col).sum(0)
    # a site only evicts if its insertions actually land
    evict = evict & fits[None, :]
    freed = jnp.where(fits, freed, 0.0)

    present = (rep.present & ~evict) | do_insert
    return rep._replace(
        present=present,
        disk_used=rep.disk_used - freed + kept_in,
        last_access=jnp.where(
            do_insert, jnp.float32(clock), jnp.where(evict, -INF, rep.last_access)
        ),
    )


def insert_replicas(
    rep: ReplicaState, dataset: jax.Array, site: jax.Array, mask: jax.Array, clock
) -> ReplicaState:
    """Row-wise insertion: cache dataset[j] at site[j] where mask[j]."""
    D, S = rep.present.shape
    d = jnp.clip(dataset, 0, D - 1)
    s = jnp.clip(site, 0, S - 1)
    want = jnp.zeros((D, S), bool).at[d, s].max(mask)
    return insert_mask(rep, want, clock)


def touch(rep: ReplicaState, dataset: jax.Array, site: jax.Array, mask: jax.Array, clock) -> ReplicaState:
    """Refresh the LRU clock of replicas read this round (where present).

    Blocked access path (DESIGN.md §12): a row-wise scatter over the J
    (dataset, site) pairs actually referenced this round — O(J) work — in
    place of building a dense ``bool[D, S]`` touch mask.  Value-identical:
    every touched cell receives the same clock, so scatter duplicates and
    the old dense ``where`` agree bit-for-bit.
    """
    D, S = rep.present.shape
    d = jnp.clip(dataset, 0, D - 1)
    s = jnp.clip(site, 0, S - 1)
    on = mask & rep.present[d, s]
    dd = jnp.where(on, d, D)  # rows that miss (or are masked) drop out
    return rep._replace(
        last_access=rep.last_access.at[dd, s].set(jnp.float32(clock), mode="drop")
    )


def catalog_invariants(rep: ReplicaState) -> dict:
    """Numpy invariant checks for tests: capacity respected, accounting exact,
    origins pinned."""
    present = np.asarray(rep.present)
    size = np.asarray(rep.size)
    used = np.asarray(rep.disk_used)
    cap = np.asarray(rep.disk_cap)
    origin_raw = np.asarray(rep.origin)
    origin = np.clip(origin_raw, 0, present.shape[1] - 1)
    recomputed = (present * size[:, None]).sum(0)
    # origin < 0 = declared-but-never-materialized dataset (e.g. the producer
    # was cascade-cancelled): exempt from the pinned-copy check
    has_origin = origin_raw >= 0
    # pinned-origin rows must survive eviction: the authoritative copy is
    # present AND was never swept by the LRU (-inf last_access is the
    # eviction sentinel — a pinned copy must never carry it)
    rows = np.arange(present.shape[0])
    last = np.asarray(rep.last_access)
    origin_pinned_ok = bool(
        (present[rows, origin][has_origin] & np.isfinite(last[rows, origin][has_origin])).all()
    )
    return dict(
        capacity_ok=bool((used <= cap + 1e-2).all()),
        accounting_ok=bool(np.allclose(used, recomputed, rtol=1e-5, atol=1.0)),
        origins_ok=bool(present[np.arange(present.shape[0]), origin][has_origin].all()),
        origin_pinned_ok=origin_pinned_ok,
    )
