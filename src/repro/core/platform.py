"""Platform construction — CGSim's input layer.

The paper configures a simulation from three JSON files (infrastructure,
network topology, execution parameters).  We keep that contract:
``load_platform`` accepts the same three dict/JSON payloads and produces a
``SiteState`` plus an ``ExecutionParams``; ``atlas_like_platform`` generates
the WLCG-flavoured topology used by the case study (sites of 100-2000 cores,
heterogeneous HS23-like speeds and WAN links).
"""
from __future__ import annotations

import json
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .types import SiteState, make_sites


class ExecutionParams(NamedTuple):
    max_rounds: int = 200_000
    horizon: float = float("inf")
    max_retries: int = 3
    log_rows: int = 0
    monitor_every: int = 1
    policy: str = "panda_dispatch"
    seed: int = 0


def load_platform(infrastructure: dict | str, network: dict | str | None = None,
                  execution: dict | str | None = None, capacity: int | None = None):
    """Build (SiteState, ExecutionParams) from CGSim-style JSON payloads.

    infrastructure: {"sites": [{"name", "cores", "speed", "memory_gb",
                                "fail_rate"?, "par_gamma"?}, ...]}
    network:        {"links": [{"site", "bw_in_gbps", "bw_out_gbps",
                                "latency_ms"}, ...]}  (defaults if omitted)
    execution:      {"max_rounds"?, "horizon"?, "max_retries"?, "policy"?, ...}
    """
    if isinstance(infrastructure, str):
        infrastructure = json.loads(infrastructure)
    if isinstance(network, str):
        network = json.loads(network)
    if isinstance(execution, str):
        execution = json.loads(execution)

    sites_cfg = infrastructure["sites"]
    n = len(sites_cfg)
    names = [s.get("name", f"site{i}") for i, s in enumerate(sites_cfg)]
    link_by_site = {}
    for link in (network or {}).get("links", []):
        link_by_site[link["site"]] = link

    def get_link(name, key, default):
        return link_by_site.get(name, {}).get(key, default)

    gb = 1e9 / 8  # Gbps -> bytes/s
    sites = make_sites(
        cores=[s["cores"] for s in sites_cfg],
        speed=[s.get("speed", 10.0) for s in sites_cfg],
        memory=[s.get("memory_gb", 2.0 * s["cores"]) for s in sites_cfg],
        bw_in=[get_link(nm, "bw_in_gbps", 10.0) * gb for nm in names],
        bw_out=[get_link(nm, "bw_out_gbps", 10.0) * gb for nm in names],
        latency=[get_link(nm, "latency_ms", 10.0) / 1e3 for nm in names],
        par_gamma=[s.get("par_gamma", 0.02) for s in sites_cfg],
        fail_rate=[s.get("fail_rate", 0.0) for s in sites_cfg],
        capacity=capacity,
    )
    ep = ExecutionParams(**(execution or {}))
    return sites, names, ep


def dump_platform(sites: SiteState, names=None) -> str:
    """Round-trip a SiteState back to the CGSim infrastructure JSON."""
    active = np.asarray(sites.active)
    rows = []
    for i in range(int(active.sum())):
        rows.append(
            dict(
                name=(names[i] if names else f"site{i}"),
                cores=int(sites.cores[i]),
                speed=float(sites.speed[i]),
                memory_gb=float(sites.memory[i]),
                par_gamma=float(sites.par_gamma[i]),
                fail_rate=float(sites.fail_rate[i]),
            )
        )
    return json.dumps({"sites": rows}, indent=2)


def atlas_like_platform(
    n_sites: int = 50,
    *,
    seed: int = 0,
    capacity: int | None = None,
    fail_rate: float = 0.0,
    speed_range=(5.0, 25.0),
    cores_range=(100, 2000),
) -> SiteState:
    """WLCG-flavoured heterogeneous platform (paper §4.1/§4.3: 100-2000 cores
    per site, HEPScore23-like per-core speeds, 1-100 Gbps WAN links)."""
    rng = np.random.default_rng(seed)
    cores = rng.integers(cores_range[0], cores_range[1] + 1, size=n_sites)
    # a few Tier-1-scale sites
    tier1 = rng.choice(n_sites, size=max(1, n_sites // 10), replace=False)
    cores[tier1] = rng.integers(cores_range[1], 4 * cores_range[1], size=tier1.size)
    speed = rng.uniform(*speed_range, size=n_sites)
    gb = 1e9 / 8
    bw = rng.choice([1.0, 10.0, 40.0, 100.0], size=n_sites, p=[0.15, 0.45, 0.25, 0.15]) * gb
    return make_sites(
        cores=cores,
        speed=speed,
        memory=2.0 * cores,  # 2 GB/core, the ATLAS rule of thumb
        bw_in=bw,
        bw_out=bw,
        latency=rng.uniform(0.005, 0.12, size=n_sites),
        par_gamma=rng.uniform(0.0, 0.05, size=n_sites),
        fail_rate=np.full(n_sites, fail_rate),
        capacity=capacity,
    )


def apply_site_params(sites: SiteState, *, speed=None, latency=None) -> SiteState:
    """Overlay continuous per-site knobs on a platform (calibration hot path).

    ``None`` leaves a knob untouched, so the same call site works for any
    subset of the ``PlatformParams`` fields; values broadcast against the
    site axis (a vmapped candidate population passes batched arrays).
    """
    repl = {}
    if speed is not None:
        repl["speed"] = jnp.asarray(speed, jnp.float32)
    if latency is not None:
        repl["latency"] = jnp.asarray(latency, jnp.float32)
    return sites._replace(**repl) if repl else sites


def load_availability(spec: dict | str, names=None, *, n_sites: int | None = None):
    """Build an ``AvailabilityState`` from a CGSim-style JSON payload.

    spec: {"windows": [{"site": <name or index>, "start": s, "end": s,
                        "factor"?: 0.0, "preempt"?: false}, ...]}
    Site names resolve through ``names`` (the ``load_platform`` name list);
    ``n_sites`` defaults to ``len(names)``.
    """
    from .availability import make_availability

    if isinstance(spec, str):
        spec = json.loads(spec)
    if n_sites is None:
        if names is None:
            raise ValueError("load_availability needs names= or n_sites=")
        n_sites = len(names)
    index = {nm: i for i, nm in enumerate(names or [])}
    windows = []
    for w in spec.get("windows", []):
        site = w["site"]
        if isinstance(site, str):
            if site not in index:
                raise ValueError(f"unknown site name {site!r}")
            site = index[site]
        windows.append(
            dict(site=site, start=w["start"], end=w["end"],
                 factor=w.get("factor", 0.0), preempt=w.get("preempt", False))
        )
    return make_availability(n_sites, windows)


def load_faults(spec: dict | str, names=None, *, n_sites: int | None = None,
                job_capacity: int | None = None):
    """Build a ``FaultState`` from a CGSim-style JSON payload.

    spec: {"link_fail_p"?: {"default": p, "links": [{"src": <name or idx>,
                                                     "dst": ..., "p": p}]},
           "xfer_backoff"?: s, "max_xfer_attempts"?: n,
           "job_backoff"?: s, "walltime"?: s,
           "replica_loss"?: [{"t": s, "dataset": d, "site": <name or idx>}],
           "blacklist"?: {"threshold": x, "alpha"?: a, "cooldown"?: s}}

    Site names resolve through ``names`` (the ``load_platform`` name list);
    ``n_sites`` defaults to ``len(names)``.  ``job_capacity`` must match the
    run's ``JobsState`` (also accepts the state itself).
    """
    from .faults import make_faults

    if isinstance(spec, str):
        spec = json.loads(spec)
    if n_sites is None:
        if names is None:
            raise ValueError("load_faults needs names= or n_sites=")
        n_sites = len(names)
    if job_capacity is None:
        raise ValueError("load_faults needs job_capacity= (int or JobsState)")
    index = {nm: i for i, nm in enumerate(names or [])}

    def site_idx(site):
        if isinstance(site, str):
            if site not in index:
                raise ValueError(f"unknown site name {site!r}")
            return index[site]
        return int(site)

    kw = {}
    lf = spec.get("link_fail_p")
    if lf is not None:
        if isinstance(lf, dict):
            mat = np.full((n_sites, n_sites), float(lf.get("default", 0.0)), np.float32)
            for link in lf.get("links", []):
                mat[site_idx(link["src"]), site_idx(link["dst"])] = float(link["p"])
            kw["link_fail_p"] = mat
        else:
            kw["link_fail_p"] = float(lf)
    for key in ("xfer_backoff", "max_xfer_attempts", "job_backoff", "walltime"):
        if key in spec:
            kw[key] = spec[key]
    if "replica_loss" in spec:
        kw["replica_loss"] = [
            (float(ev["t"]), int(ev["dataset"]), site_idx(ev["site"]))
            for ev in spec["replica_loss"]
        ]
    bl = spec.get("blacklist")
    if bl is not None:
        kw["blacklist_threshold"] = float(bl["threshold"])
        if "alpha" in bl:
            kw["blacklist_alpha"] = float(bl["alpha"])
        if "cooldown" in bl:
            kw["blacklist_cooldown"] = float(bl["cooldown"])
    return make_faults(n_sites, job_capacity, **kw)


def deactivate_sites(sites: SiteState, down: jax.Array) -> SiteState:
    """Fault injection: mark sites inactive (jobs there keep running; nothing
    new is assigned — the dispatcher's feasibility mask reads ``active``)."""
    down = jnp.asarray(down)
    return sites._replace(active=sites.active & ~down)
