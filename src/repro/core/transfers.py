"""FTS-style transfer queues — queued, rate-limited WAN flows (DESIGN.md §11).

PR 1's data subsystem prices every WAN stage-in instantaneously: the round
that starts a dataset job folds ``shared_transfer_times`` into its service
time, with bandwidth split among the transfers that happen to start in the
same round.  Real grids funnel third-party copies through FTS channels with
per-link *active-transfer limits*; queue-wait and link contention — not raw
bandwidth — dominate data-access latency at scale (arxiv 2403.14903,
1902.10069).

This module models that as a pure additive :class:`~.subsystems.Subsystem`:

- Each directed link ``src -> dst`` (flattened id ``src * S + dst``) owns a
  fixed-shape FIFO ring of job ids (``i32[L, Q]``), an ``active`` counter,
  and a ``cap`` (``max_active``).
- When a dataset job starts on a WAN read, the data subsystem *defers* the
  transfer here instead of pricing it: the job enters a **staging gate** —
  it is RUNNING with ``t_finish = inf`` so it is excluded from the engine's
  finish-time min-reduction, exactly like gated workflow children.  Its wake
  event is the transfer completion, contributed through ``event_times``.
- Link bandwidth splits equal-share among the *active* transfers on that
  link only; everything past ``cap`` waits in FIFO order.  Because the
  active set is constant between rounds, each flow's completion time is a
  closed form and byte progress integrates exactly.
- On completion the remaining compute (+ stage-out + WAN latency) is priced
  into ``t_finish``, cache-on-read replicas materialize at the destination,
  and the freed slot admits the next queued transfer.

Fixed shapes and masked algebra throughout: the subsystem jit/vmaps under
``simulate_many`` / ``simulate_many_sharded``, and ``transfers=None`` is a
bit-for-bit no-op (static specialization removes every trace).

Preempted staging jobs (availability outages) are handled with stamped
tickets: cancelling a queued transfer leaves a tombstone in the ring that is
garbage-collected for free when it reaches the head, and a ticket mismatch
keeps a re-enqueued retry of the same job from being confused with its stale
entry.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .network import link_caps
from .types import RUNNING

INF = jnp.float32(jnp.inf)

# per-transfer status (one slot per job row: a job has at most one in-flight
# transfer — its current stage-in attempt)
T_IDLE, T_QUEUED, T_ACTIVE = 0, 1, 2


class TransferState(NamedTuple):
    """The transfer subsystem's ``EngineState.ext["transfers"]`` slot.

    Link axis ``L = S * S`` over flattened directed links; ring axis ``Q``
    (queue slots per link); transfer axis = the job capacity ``J``.
    """

    # per-link FIFO rings
    queue: jax.Array    # i32[L, Q] job ids (-1 = empty slot)
    tickets: jax.Array  # i32[L, Q] enqueue ticket stamped into each slot
    head: jax.Array     # i32[L] ring read position
    qlen: jax.Array     # i32[L] occupied slots from head (incl. tombstones)
    active: jax.Array   # i32[L] transfers currently moving bytes
    cap: jax.Array      # i32[L] max_active per link (FTS channel limit)
    # per-transfer rows (indexed by job id)
    stat: jax.Array     # i32[J] T_IDLE / T_QUEUED / T_ACTIVE
    link: jax.Array     # i32[J] flattened link id (-1 = none)
    rem: jax.Array      # f32[J] remaining bytes
    t_done: jax.Array   # f32[J] completion time under the current share (inf
    #                     unless active) — the subsystem's event_times source
    resid: jax.Array    # f32[J] post-staging service remainder (compute +
    #                     stage-out + WAN latency), priced into t_finish at release
    enq_t: jax.Array    # f32[J] enqueue clock
    act_t: jax.Array    # f32[J] activation clock
    ticket: jax.Array   # i32[J] current enqueue ticket (-1 = none)
    cache: jax.Array    # bool[J] materialize a replica at the dst on landing
    # conservation counters (every enqueue terminates as done or cancelled)
    n_enq: jax.Array       # i32 total transfers enqueued (also ticket counter)
    n_done: jax.Array      # i32 transfers completed
    n_cancel: jax.Array    # i32 transfers cancelled (staging job preempted)
    n_overflow: jax.Array  # i32 ring-full enqueues admitted past the cap
    bytes_enq: jax.Array     # f32 bytes enqueued
    bytes_done: jax.Array    # f32 bytes of completed transfers (full size)
    bytes_cancel: jax.Array  # f32 bytes of cancelled transfers (full size)


def make_transfers(
    n_sites: int,
    job_capacity: int,
    *,
    max_active: int = 4,
    caps=None,
    queue_slots: int | None = None,
) -> TransferState:
    """Build an empty transfer-queue state.

    ``n_sites`` also accepts a ``NetworkState``/``SiteState``; ``job_capacity``
    also accepts a ``JobsState``.  ``max_active`` is the default per-link
    concurrency cap, refined by ``caps`` (a ``{(src, dst): cap}`` mapping or a
    full ``[S, S]`` matrix — see :func:`~.network.link_caps`).  ``queue_slots``
    defaults to the job capacity, which can never overflow since each job
    holds at most one in-flight transfer.
    """
    S = getattr(n_sites, "n_sites", None) or getattr(n_sites, "capacity", None) or int(n_sites)
    J = getattr(job_capacity, "capacity", None) or int(job_capacity)
    L = S * S
    Q = int(queue_slots) if queue_slots is not None else J
    Q = max(Q, 1)
    return TransferState(
        queue=jnp.full((L, Q), -1, jnp.int32),
        tickets=jnp.full((L, Q), -1, jnp.int32),
        head=jnp.zeros((L,), jnp.int32),
        qlen=jnp.zeros((L,), jnp.int32),
        active=jnp.zeros((L,), jnp.int32),
        cap=link_caps(S, max_active, caps),
        stat=jnp.zeros((J,), jnp.int32),
        link=jnp.full((J,), -1, jnp.int32),
        rem=jnp.zeros((J,), jnp.float32),
        t_done=jnp.full((J,), jnp.inf, jnp.float32),
        resid=jnp.zeros((J,), jnp.float32),
        enq_t=jnp.zeros((J,), jnp.float32),
        act_t=jnp.zeros((J,), jnp.float32),
        ticket=jnp.full((J,), -1, jnp.int32),
        cache=jnp.zeros((J,), bool),
        n_enq=jnp.int32(0),
        n_done=jnp.int32(0),
        n_cancel=jnp.int32(0),
        n_overflow=jnp.int32(0),
        bytes_enq=jnp.float32(0.0),
        bytes_done=jnp.float32(0.0),
        bytes_cancel=jnp.float32(0.0),
    )


# --------------------------------------------------------------------------
# queue mechanics (all fixed-shape [L, Q] / [J] masked algebra)
# --------------------------------------------------------------------------


def _link_count(mask, link, L):
    """Per-link count of True rows (mask[J], link[J]) -> i32[L]."""
    from .engine import _segment_sum_small

    seg = jnp.where(mask, link, L)
    return _segment_sum_small(mask.astype(jnp.int32), seg, L + 1)[:L]


def _enqueue(ts: TransferState, want, link, nbytes, resid, cache, clock):
    """Append the ``want`` rows to their links' FIFO rings.

    Same-round enqueuers on one link are ordered by job id — they start at
    the same instant, and the id tiebreak matches the engine's start-order
    sort.  Returns ``(ts, depth)`` where ``depth[J]`` is the number of ring
    entries ahead of each enqueued row (its queue position at entry).

    Ring-full safety valve: if a link's ring has no room (only possible when
    ``queue_slots`` was shrunk below the job capacity), the transfer
    activates immediately, bypassing the cap, and ``n_overflow`` counts it.
    """
    from .engine import _segment_exclusive_base

    L, Q = ts.queue.shape[-2], ts.queue.shape[-1]
    J = want.shape[-1]
    idx = jnp.arange(J, dtype=jnp.int32)
    lc = jnp.clip(link, 0, L - 1)
    seg = jnp.where(want, lc, L)
    order = jnp.argsort(seg, stable=True)
    incl = _segment_exclusive_base(want[order].astype(jnp.int32), seg[order], L + 1)
    rank = jnp.zeros((J,), jnp.int32).at[order].set(incl - want[order].astype(jnp.int32))
    depth = ts.qlen[lc] + rank                   # entries ahead at enqueue time
    room = want & (depth < Q)
    slot = (ts.head[lc] + depth) % Q
    # unique ticket per enqueue event: running counter + within-round rank
    grank = jnp.cumsum(want.astype(jnp.int32)) - want.astype(jnp.int32)
    tkt = ts.n_enq + grank
    tgt = jnp.where(room, lc * Q + slot, L * Q)  # OOB rows dropped by the scatter
    queue = ts.queue.reshape(L * Q).at[tgt].set(idx, mode="drop").reshape(L, Q)
    tickets = ts.tickets.reshape(L * Q).at[tgt].set(tkt, mode="drop").reshape(L, Q)
    ovf = want & ~room
    return ts._replace(
        queue=queue,
        tickets=tickets,
        qlen=ts.qlen + _link_count(room, lc, L),
        active=ts.active + _link_count(ovf, lc, L),
        stat=jnp.where(room, T_QUEUED, jnp.where(ovf, T_ACTIVE, ts.stat)),
        link=jnp.where(want, lc, ts.link),
        rem=jnp.where(want, nbytes, ts.rem),
        resid=jnp.where(want, resid, ts.resid),
        enq_t=jnp.where(want, clock, ts.enq_t),
        act_t=jnp.where(want, clock, ts.act_t),  # re-stamped on admission
        ticket=jnp.where(want, tkt, ts.ticket),
        cache=jnp.where(want, cache, ts.cache),
        n_enq=ts.n_enq + want.sum().astype(jnp.int32),
        n_overflow=ts.n_overflow + ovf.sum().astype(jnp.int32),
        bytes_enq=ts.bytes_enq + jnp.where(want, nbytes, 0.0).sum(),
    ), depth


def _admit(ts: TransferState, clock):
    """Pop each link's FIFO into the free ``cap - active`` slots.

    A ring entry is *live* iff the job it names is still T_QUEUED under the
    same ticket; stale entries (cancelled by preemption, then possibly
    re-enqueued under a new ticket) are tombstones and pop for free — even
    at zero budget — so they can never wedge a queue.
    """
    L, Q = ts.queue.shape[-2], ts.queue.shape[-1]
    J = ts.stat.shape[-1]
    off = jnp.arange(Q, dtype=jnp.int32)[None, :]
    pos = (ts.head[:, None] + off) % Q
    ent = jnp.take_along_axis(ts.queue, pos, axis=-1)
    tkt = jnp.take_along_axis(ts.tickets, pos, axis=-1)
    in_q = off < ts.qlen[:, None]
    ec = jnp.clip(ent, 0, J - 1)
    live = in_q & (ent >= 0) & (ts.stat[ec] == T_QUEUED) & (ts.ticket[ec] == tkt)
    vcum = jnp.cumsum(live.astype(jnp.int32), axis=-1)
    budget = jnp.maximum(ts.cap - ts.active, 0)[:, None]
    popped = in_q & (vcum <= budget)  # contiguous head prefix: tombstones ride along
    admit = popped & live
    ids = jnp.where(admit, ec, J).reshape(-1)
    go = jnp.zeros((J + 1,), bool).at[ids].set(True)[:J]
    n_pop = popped.sum(-1).astype(jnp.int32)
    return ts._replace(
        head=(ts.head + n_pop) % Q,
        qlen=ts.qlen - n_pop,
        active=ts.active + admit.sum(-1).astype(jnp.int32),
        stat=jnp.where(go, T_ACTIVE, ts.stat),
        act_t=jnp.where(go, clock, ts.act_t),
    )


def _reprice(ts: TransferState, bw_flat, clock):
    """Materialize each active flow's completion time under the current
    equal-share split.  The active sets only change at rounds, so this is
    exact — and it is the invariant ``event_times`` reads."""
    L = bw_flat.shape[-1]
    lc = jnp.clip(ts.link, 0, L - 1)
    act = ts.stat == T_ACTIVE
    rate = bw_flat[lc] / jnp.maximum(ts.active[lc], 1).astype(jnp.float32)
    t_done = clock + ts.rem / jnp.maximum(rate, 1e-9)
    return ts._replace(t_done=jnp.where(act, t_done, INF))


# --------------------------------------------------------------------------
# Subsystem hooks
# --------------------------------------------------------------------------


def _tr_init(sub, state0, jobs, sites):
    if jobs is not None and state0.stat.shape[-1] != jobs.capacity:
        raise ValueError(
            f"TransferState sized for {state0.stat.shape[-1]} jobs, "
            f"got capacity {jobs.capacity}; build with make_transfers(S, jobs)"
        )
    if sites is not None and state0.cap.shape[-1] != sites.capacity**2:
        raise ValueError(
            f"TransferState has {state0.cap.shape[-1]} links, "
            f"expected S*S = {sites.capacity**2}"
        )
    return state0


def _tr_event_times(sub, ctx):
    """Transfer completions join the round clock: the staging gate's wake."""
    return ctx.ext["transfers"].t_done.min()


def _tr_on_completions(sub, ctx):
    """Engine step 2b: integrate byte progress over the elapsed interval,
    release jobs whose transfer landed (pricing the post-staging remainder
    into ``t_finish``), cancel transfers whose staging job was preempted,
    then admit queued flows into the freed slots."""
    from .datapolicies import land_deferred

    ts: TransferState = ctx.ext["transfers"]
    dext = ctx.ext.get("data")
    if dext is None:
        return
    jobs, S, J = ctx.jobs, ctx.S, ctx.J
    L = S * S
    bw_flat = dext.network.bw.reshape(L)
    lc = jnp.clip(ts.link, 0, L - 1)
    act = ts.stat == T_ACTIVE

    # byte progress: the active set (and so each flow's share) was constant
    # over [clock_prev, clock]
    dt = jnp.maximum(ctx.clock - ctx.clock_prev, 0.0)
    rate = bw_flat[lc] / jnp.maximum(ts.active[lc], 1).astype(jnp.float32)
    rem = jnp.where(act, jnp.maximum(ts.rem - rate * dt, 0.0), ts.rem)

    # a preempted staging job (availability outage moved it out of RUNNING
    # in this same hook phase — availability runs first) abandons its
    # transfer; its ring entry becomes a tombstone
    staging = jobs.state == RUNNING
    fin = act & (ts.t_done <= ctx.clock) & staging
    cancel = (ts.stat > T_IDLE) & ~staging

    # fault injection (static specialization, like the data subsystem's
    # defer branch): would-complete flows may fail with per-link probability
    # before release — failed rows clear like cancels but land on the fault
    # ledger (n_enq == n_done + n_cancel + faults.n_xfer_fail)
    xfail = jnp.zeros((J,), bool)
    if "faults" in ctx.ext:
        from .faults import inject_transfer_failures

        fin, xfail, jobs = inject_transfer_failures(ctx, ts, fin, jobs)

    # release: price the post-staging remainder into t_finish so the job
    # rejoins the round clock.  The engine's partial-failure fraction was
    # consumed by the staging gate's inf, so failing attempts re-draw it
    # from the subsystem's own RNG stream.
    frac = jax.random.uniform(ctx.subkey("transfers"), (J,), minval=0.05, maxval=1.0)
    t_rest = jnp.where(jobs.will_fail, ts.resid * frac, ts.resid)
    ctx.jobs = jobs._replace(
        t_finish=jnp.where(fin, ctx.clock + t_rest, jobs.t_finish),
        xfer_time=jnp.where(fin, ctx.clock - ts.act_t, jobs.xfer_time),
        xfer_wait=jnp.where(fin, ts.act_t - ts.enq_t, jobs.xfer_wait),
    )
    # deferred landing: replica materialization + WAN counters at the dst
    ctx.ext["data"] = land_deferred(dext, ctx.jobs, fin, ts.cache, ctx.clock, S)

    clear = fin | cancel | xfail
    ts = ts._replace(
        stat=jnp.where(clear, T_IDLE, ts.stat),
        rem=jnp.where(clear, 0.0, rem),
        t_done=jnp.where(clear, INF, ts.t_done),
        active=ts.active - _link_count(fin | xfail | (cancel & act), lc, L),
        n_done=ts.n_done + fin.sum().astype(jnp.int32),
        n_cancel=ts.n_cancel + cancel.sum().astype(jnp.int32),
        bytes_done=ts.bytes_done + jnp.where(fin, jobs.xfer_bytes, 0.0).sum(),
        bytes_cancel=ts.bytes_cancel + jnp.where(cancel, jobs.xfer_bytes, 0.0).sum(),
    )
    ts = _admit(ts, ctx.clock)
    ctx.ext["transfers"] = _reprice(ts, bw_flat, ctx.clock)
    ctx.progressed = ctx.progressed | fin.any() | cancel.any()


def _tr_on_start(sub, ctx):
    """Engine step 5b, after the data subsystem: divert this round's WAN
    reads (staged in ``ctx.scratch['transfers']``) into the link queues and
    hold the jobs in the staging gate (``t_serv = inf``)."""
    ts: TransferState = ctx.ext["transfers"]
    dext = ctx.ext.get("data")
    if dext is None:
        return
    L = ctx.S * ctx.S
    sc = ctx.scratch.get("transfers")
    if sc is not None:
        xfer = sc["xfer"]
        # staging gate: inf service time keeps t_finish = inf, excluding the
        # job from the clock min-reduction until its transfer lands
        ctx.t_serv = jnp.where(xfer, INF, ctx.t_serv)
        ts, depth = _enqueue(ts, xfer, sc["link"], sc["bytes"], sc["resid"], sc["cache"], ctx.clock)
        ctx.jobs = ctx.jobs._replace(
            xfer_qdepth=jnp.where(xfer, depth, ctx.jobs.xfer_qdepth),
            xfer_wait=jnp.where(xfer, 0.0, ctx.jobs.xfer_wait),
        )
    # newly enqueued flows activate now if their link has free slots —
    # required for liveness: an uncontended transfer must create its own
    # wake event this same round
    ts = _admit(ts, ctx.clock)
    ctx.ext["transfers"] = _reprice(ts, dext.network.bw.reshape(L), ctx.clock)


def _tr_log_spec(sub, ts: TransferState, jobs, sites):
    L = ts.cap.shape[-1]
    zeros = jnp.zeros((L,), jnp.int32)
    return {"link_active": zeros, "link_queued": zeros}


def _tr_log_columns(sub, ctx, write):
    ts: TransferState = ctx.ext["transfers"]
    L = ts.cap.shape[-1]
    queued = _link_count(ts.stat == T_QUEUED, jnp.clip(ts.link, 0, L - 1), L)
    return {"link_active": ts.active, "link_queued": queued}


def _tr_pad_jobs(sub, ts: TransferState, old_cap: int, new_cap: int):
    n = new_cap - old_cap
    fills = {
        "stat": T_IDLE, "link": -1, "rem": 0.0, "t_done": jnp.inf, "resid": 0.0,
        "enq_t": 0.0, "act_t": 0.0, "ticket": -1, "cache": False,
    }

    def pad(name, x):
        if name not in fills:
            return x
        widths = [(0, 0)] * (x.ndim - 1) + [(0, n)]
        return jnp.pad(x, widths, constant_values=fills[name])

    out = ts._replace(**{k: pad(k, getattr(ts, k)) for k in fills})
    # default-sized rings (Q == job capacity) grow with it, keeping the
    # no-overflow guarantee and a stackable shape across ragged lanes;
    # explicit queue_slots are left alone (pre-run rings are empty, so
    # widening never disturbs ring arithmetic)
    if ts.queue.shape[-1] == old_cap:
        widths = [(0, 0)] * (ts.queue.ndim - 1) + [(0, n)]
        out = out._replace(
            queue=jnp.pad(ts.queue, widths, constant_values=-1),
            tickets=jnp.pad(ts.tickets, widths, constant_values=-1),
        )
    return out


def transfers_subsystem() -> "Subsystem":
    """The transfer-queue engine plugin.  Initial state is a
    :class:`TransferState` from :func:`make_transfers`; requires the data
    subsystem (it owns the network matrices and the replica catalog)."""
    from .subsystems import Subsystem

    return Subsystem(
        name="transfers",
        config=None,
        init=_tr_init,
        event_times=_tr_event_times,
        on_completions=_tr_on_completions,
        on_start=_tr_on_start,
        log_spec=_tr_log_spec,
        log_columns=_tr_log_columns,
        pad_jobs=_tr_pad_jobs,
    )
