"""Core state types for the vectorized grid simulator.

CGSim models a computing grid as sites (SimGrid netzones) of hosts plus a
central main server that dispatches jobs.  Here the whole simulation state is
a fixed-capacity struct-of-arrays pytree so every simulator advance is dense,
masked algebra (see DESIGN.md §2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)

# --- job lifecycle states (CGSim: pending/assigned/running/finished/failed) ---
PENDING = 0   # not yet arrived at the main server
QUEUED = 1    # at the main server, awaiting a site assignment ("pending list")
ASSIGNED = 2  # placed in a site queue, awaiting free cores
RUNNING = 3   # executing on site cores
DONE = 4
FAILED = 5    # terminally failed (retries exhausted)
CANCELLED = 6  # cascade-cancelled: an ancestor in its workflow DAG failed
N_STATES = 7

STATE_NAMES = ("pending", "queued", "assigned", "running", "finished", "failed", "cancelled")


class JobsState(NamedTuple):
    """Struct-of-arrays over a fixed job capacity J (padded with inactive rows)."""

    job_id: jax.Array     # i32[J] external id (e.g. PanDA job id)
    arrival: jax.Array    # f32[J] seconds
    work: jax.Array       # f32[J] compute demand (HS23-normalised core-seconds)
    cores: jax.Array      # i32[J] cores required (1 or 8 for ATLAS single/multicore)
    memory: jax.Array     # f32[J] GB resident
    bytes_in: jax.Array   # f32[J] stage-in volume
    bytes_out: jax.Array  # f32[J] stage-out volume
    priority: jax.Array   # f32[J] higher starts first within a site queue
    state: jax.Array      # i32[J] lifecycle state
    site: jax.Array       # i32[J] assigned site, -1 if none
    t_assign: jax.Array   # f32[J] time assigned to a site (inf until set)
    t_start: jax.Array    # f32[J] time execution started
    t_finish: jax.Array   # f32[J] time execution finished/failed
    retries: jax.Array    # i32[J] resubmission count
    will_fail: jax.Array  # bool[J] sampled at start: this attempt fails
    valid: jax.Array      # bool[J] row is a real job (padding rows are False)
    dataset: jax.Array    # i32[J] input dataset id, -1 = no catalogued dataset
    xfer_src: jax.Array   # i32[J] replica site the last stage-in read from (-1 none)
    xfer_bytes: jax.Array  # f32[J] WAN bytes moved by the last stage-in (0 = cache hit)
    xfer_time: jax.Array  # f32[J] stage-in duration of the last attempt
    xfer_wait: jax.Array  # f32[J] transfer queue-wait of the last attempt (0 = never queued)
    xfer_qdepth: jax.Array  # i32[J] link-queue depth seen at enqueue (-1 = never enqueued)
    preempted: jax.Array  # i32[J] attempts cut short by site outages (DESIGN.md §5)
    wf_id: jax.Array      # i32[J] workflow the job belongs to, -1 = standalone
    n_parents: jax.Array  # i32[J] number of DAG parents (0 = root / standalone)
    dag_depth: jax.Array  # i32[J] longest root->job path length (0 for roots)
    wf_crit: jax.Array    # f32[J] critical-path weight: own work + heaviest descendant chain
    out_dataset: jax.Array  # i32[J] dataset this job materializes on completion, -1 = none

    @property
    def capacity(self) -> int:
        return self.arrival.shape[-1]


class SiteState(NamedTuple):
    """Struct-of-arrays over a fixed site capacity S."""

    cores: jax.Array        # i32[S] total cores
    speed: jax.Array        # f32[S] per-core work units / second  (CALIBRATION TARGET)
    memory: jax.Array       # f32[S] GB
    bw_in: jax.Array        # f32[S] ingress bandwidth bytes/s (shared by staging jobs)
    bw_out: jax.Array       # f32[S] egress bandwidth bytes/s
    latency: jax.Array      # f32[S] per-transfer latency seconds
    par_gamma: jax.Array    # f32[S] Amdahl contention: speedup = c / (1 + gamma*(c-1))
    fail_rate: jax.Array    # f32[S] per-attempt failure probability
    active: jax.Array       # bool[S] site exists / is up (elasticity + padding)
    free_cores: jax.Array   # i32[S]
    free_memory: jax.Array  # f32[S]
    n_assigned: jax.Array   # i32[S] cumulative jobs assigned
    n_finished: jax.Array   # i32[S] cumulative finished
    n_failed: jax.Array     # i32[S] cumulative failed attempts

    @property
    def capacity(self) -> int:
        return self.cores.shape[-1]


class EventLog(NamedTuple):
    """Fixed-shape ring buffer of per-round snapshots (CGSim Table 1 / dashboard feed).

    ``site_free``/``site_running``/``site_queued`` are per-site columns so the
    monitor can render node pressure; ``counts`` are global per-state tallies.
    ``extra`` holds subsystem-declared columns (DESIGN.md §7) keyed by name —
    e.g. ``site_disk``/``site_net_in`` from the data subsystem, ``site_avail``
    from availability — so new subsystems export dashboard feeds without
    touching this type.
    """

    time: jax.Array          # f32[R]
    round_idx: jax.Array     # i32[R]
    counts: jax.Array        # i32[R, N_STATES]
    n_started: jax.Array     # i32[R] jobs started this round
    n_completed: jax.Array   # i32[R]
    site_free: jax.Array     # i32[R, S]
    site_queued: jax.Array   # i32[R, S] jobs sitting in each site queue
    site_running: jax.Array  # i32[R, S]
    extra: dict              # {name: [R, ...]} subsystem-declared columns
    cursor: jax.Array        # i32[] next write slot (wraps)

    @property
    def rows(self) -> int:
        return self.time.shape[-1]


class EngineState(NamedTuple):
    """The while-loop carry: core engine state plus the generic subsystem
    extension mapping ``ext`` (a dict pytree, one slot per Subsystem name —
    DESIGN.md §7).  Subsystem-specific fields never appear here."""

    clock: jax.Array        # f32[]
    round: jax.Array        # i32[]
    jobs: JobsState
    sites: SiteState
    rng: jax.Array          # PRNGKey
    policy_state: object    # policy-defined pytree
    log: EventLog
    halted: jax.Array       # bool[] no further progress possible
    ext: dict               # {subsystem name: subsystem-defined state pytree};
                            # "~"-prefixed keys are engine-internal carries
                            # (e.g. "~cand", "~srank") stripped at finalize


class SimResult(NamedTuple):
    makespan: jax.Array     # f32[] clock at termination
    rounds: jax.Array       # i32[]
    jobs: JobsState
    sites: SiteState
    log: EventLog
    policy_state: object
    replicas: object = None     # final ReplicaState (None without a DataPolicy)
    data_state: object = ()
    avail: object = None        # final AvailabilityState (None without availability)
    wf: object = None           # final WorkflowState (None without a workflow DAG)
    ext: object = None          # {name: final state} for every attached subsystem


def make_jobs(
    *,
    job_id,
    arrival,
    work,
    cores,
    memory,
    bytes_in,
    bytes_out,
    priority=None,
    dataset=None,
    wf_id=None,
    n_parents=None,
    dag_depth=None,
    wf_crit=None,
    out_dataset=None,
    capacity: int | None = None,
) -> JobsState:
    """Build a JobsState from per-job vectors, padding to ``capacity`` rows."""
    arrival = jnp.asarray(arrival, jnp.float32)
    n = arrival.shape[0]
    cap = capacity or n
    if cap < n:
        raise ValueError(f"capacity {cap} < number of jobs {n}")

    def pad_f(x, fill=0.0):
        x = jnp.asarray(x, jnp.float32)
        return jnp.pad(x, (0, cap - n), constant_values=fill)

    def pad_i(x, fill=0):
        x = jnp.asarray(x, jnp.int32)
        return jnp.pad(x, (0, cap - n), constant_values=fill)

    if priority is None:
        priority = jnp.zeros((n,), jnp.float32)
    if dataset is None:
        dataset = jnp.full((n,), -1, jnp.int32)
    if wf_id is None:
        wf_id = jnp.full((n,), -1, jnp.int32)
    if n_parents is None:
        n_parents = jnp.zeros((n,), jnp.int32)
    if dag_depth is None:
        dag_depth = jnp.zeros((n,), jnp.int32)
    if wf_crit is None:
        wf_crit = jnp.zeros((n,), jnp.float32)
    if out_dataset is None:
        out_dataset = jnp.full((n,), -1, jnp.int32)
    valid = jnp.arange(cap) < n
    return JobsState(
        job_id=pad_i(job_id, -1),
        arrival=pad_f(arrival, jnp.inf),
        work=pad_f(work),
        cores=pad_i(cores, 1),
        memory=pad_f(memory),
        bytes_in=pad_f(bytes_in),
        bytes_out=pad_f(bytes_out),
        priority=pad_f(priority),
        state=jnp.where(valid, PENDING, DONE).astype(jnp.int32),
        site=jnp.full((cap,), -1, jnp.int32),
        t_assign=jnp.full((cap,), jnp.inf, jnp.float32),
        t_start=jnp.full((cap,), jnp.inf, jnp.float32),
        t_finish=jnp.full((cap,), jnp.inf, jnp.float32),
        retries=jnp.zeros((cap,), jnp.int32),
        will_fail=jnp.zeros((cap,), bool),
        valid=valid,
        dataset=pad_i(dataset, -1),
        xfer_src=jnp.full((cap,), -1, jnp.int32),
        xfer_bytes=jnp.zeros((cap,), jnp.float32),
        xfer_time=jnp.zeros((cap,), jnp.float32),
        xfer_wait=jnp.zeros((cap,), jnp.float32),
        xfer_qdepth=jnp.full((cap,), -1, jnp.int32),
        preempted=jnp.zeros((cap,), jnp.int32),
        wf_id=pad_i(wf_id, -1),
        n_parents=pad_i(n_parents),
        dag_depth=pad_i(dag_depth),
        wf_crit=pad_f(wf_crit),
        out_dataset=pad_i(out_dataset, -1),
    )


# Per-column fill values for inert job padding rows (DONE/invalid, never
# arriving).  A padding row built from these is a fixed point of the engine:
# it passes through every round untouched, which is what makes padded and
# unpadded runs bit-for-bit comparable (and lets bucketed ensemble results be
# re-padded to a common capacity after the fact).
JOB_PAD_FILLS = dict(
    job_id=-1, arrival=float("inf"), state=DONE, site=-1, t_assign=float("inf"),
    t_start=float("inf"), t_finish=float("inf"), valid=False, dataset=-1,
    xfer_src=-1, xfer_qdepth=-1, wf_id=-1, out_dataset=-1, cores=1,
)


def pad_jobs_capacity(jobs: JobsState, capacity: int) -> JobsState:
    """Grow a JobsState to ``capacity`` rows of inert padding (DONE/invalid,
    never arriving) — the shape canonicalization used by ragged scenario
    ensembles (``stack_scenarios``) and mesh sharding (``shard_jobs``)."""
    J = jobs.capacity
    if capacity == J:
        return jobs
    if capacity < J:
        raise ValueError(f"capacity {capacity} < current job capacity {J}")
    n = capacity - J

    def pad(name, x):
        fill = JOB_PAD_FILLS.get(name, 0)
        return jnp.pad(x, [(0, n)] + [(0, 0)] * (x.ndim - 1), constant_values=fill)

    return JobsState(**{k: pad(k, v) for k, v in jobs._asdict().items()})


def make_sites(
    *,
    cores,
    speed,
    memory,
    bw_in,
    bw_out,
    latency=None,
    par_gamma=None,
    fail_rate=None,
    capacity: int | None = None,
) -> SiteState:
    cores = jnp.asarray(cores, jnp.int32)
    n = cores.shape[0]
    cap = capacity or n

    def pad_f(x, fill=0.0):
        x = jnp.broadcast_to(jnp.asarray(x, jnp.float32), (n,))
        return jnp.pad(x, (0, cap - n), constant_values=fill)

    def pad_i(x, fill=0):
        x = jnp.broadcast_to(jnp.asarray(x, jnp.int32), (n,))
        return jnp.pad(x, (0, cap - n), constant_values=fill)

    if latency is None:
        latency = jnp.zeros((n,), jnp.float32)
    if par_gamma is None:
        par_gamma = jnp.zeros((n,), jnp.float32)
    if fail_rate is None:
        fail_rate = jnp.zeros((n,), jnp.float32)
    active = jnp.arange(cap) < n
    cores_p = pad_i(cores)
    mem_p = pad_f(memory)
    return SiteState(
        cores=cores_p,
        speed=pad_f(speed, 1.0),
        memory=mem_p,
        bw_in=pad_f(bw_in, 1.0),
        bw_out=pad_f(bw_out, 1.0),
        latency=pad_f(latency),
        par_gamma=pad_f(par_gamma),
        fail_rate=pad_f(fail_rate),
        active=active,
        free_cores=cores_p,
        free_memory=mem_p,
        n_assigned=jnp.zeros((cap,), jnp.int32),
        n_finished=jnp.zeros((cap,), jnp.int32),
        n_failed=jnp.zeros((cap,), jnp.int32),
    )


def make_log(rows: int, n_sites: int, extra: dict | None = None) -> EventLog:
    """Allocate the ring buffer.  ``extra`` maps subsystem column names to
    their time-zero row values; unwritten rows keep that initial value."""
    r = max(rows, 1)
    return EventLog(
        time=jnp.full((r,), jnp.nan, jnp.float32),
        round_idx=jnp.full((r,), -1, jnp.int32),
        counts=jnp.zeros((r, N_STATES), jnp.int32),
        n_started=jnp.zeros((r,), jnp.int32),
        n_completed=jnp.zeros((r,), jnp.int32),
        site_free=jnp.zeros((r, n_sites), jnp.int32),
        site_queued=jnp.zeros((r, n_sites), jnp.int32),
        site_running=jnp.zeros((r, n_sites), jnp.int32),
        extra={
            k: jnp.broadcast_to(jnp.asarray(v)[None], (r,) + jnp.asarray(v).shape)
            for k, v in (extra or {}).items()
        },
        cursor=jnp.zeros((), jnp.int32),
    )
