"""Data-movement policies — the second CGSim plugin family (DESIGN.md §3).

The paper promises a "modular plugin mechanism for testing custom workflow
scheduling *and data movement policies*"; ``policies.Policy`` covers the
scheduling half, this module covers data.  A ``DataPolicy`` is a pytree of
pure functions with the same extension-point shape as ``Policy``:

    paper hook               | DataPolicy field
    -------------------------+-------------------------------------------------
    getResourceInformation   | init(jobs, sites, network, replicas)
                             |   -> (replicas, data_state)   (pre-placement)
    assignJob (data half)    | select_source(jobs, sites, network, replicas,
                             |   state, dst, clock) -> i32[J] replica site
                             | should_cache(jobs, sites, network, replicas,
                             |   state, dst, clock) -> bool[J] cache-on-read
    onJobEnd                 | on_step(state, jobs, replicas, started, xfer,
                             |   clock) -> state
    onSimulationEnd          | on_end(state, jobs, replicas, clock) -> state

All fields are jit-traceable, so ``engine.simulate`` with a DataPolicy keeps
vmapping under ``simulate_ensemble``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .replicas import ReplicaState, insert_mask, nearest_source


class DataPolicy(NamedTuple):
    name: str
    init: Callable
    select_source: Callable
    should_cache: Callable
    on_step: Callable
    on_end: Callable


def _default_init(jobs, sites, network, replicas):
    return replicas, ()


def _default_select(jobs, sites, network, replicas, state, dst, clock):
    return nearest_source(replicas, network, jobs.dataset, dst)


def _never_cache(jobs, sites, network, replicas, state, dst, clock):
    return jnp.zeros((jobs.capacity,), bool)


def _always_cache(jobs, sites, network, replicas, state, dst, clock):
    return jnp.ones((jobs.capacity,), bool)


def _keep_state(state, *_):
    return state


def make_data_policy(
    name: str,
    *,
    init=None,
    select_source=None,
    should_cache=None,
    on_step=None,
    on_end=None,
) -> DataPolicy:
    return DataPolicy(
        name=name,
        init=init or _default_init,
        select_source=select_source or _default_select,
        should_cache=should_cache or _never_cache,
        on_step=on_step or _keep_state,
        on_end=on_end or _keep_state,
    )


# --------------------------------------------------------------------------
# the data Subsystem (DESIGN.md §7): replica-aware stage-in as hooks on the
# composable round-loop protocol.  The DataPolicy rides in ``sub.config``;
# the ext slot carries (network, catalog, policy state, WAN-ingress accum).
# --------------------------------------------------------------------------


class DataExt(NamedTuple):
    """The data subsystem's ``EngineState.ext["data"]`` slot."""

    network: object      # NetworkState link matrices (read-only in the loop)
    replicas: ReplicaState
    state: object        # DataPolicy-defined pytree
    net_acc: jax.Array   # f32[S] WAN bytes staged since the last log write


def _data_init(sub, state0, jobs, sites):
    network, replicas = state0
    replicas, dstate = sub.config.init(jobs, sites, network, replicas)
    return DataExt(
        network=network,
        replicas=replicas,
        state=dstate,
        net_acc=jnp.zeros((sites.capacity,), jnp.float32),
    )


def _data_on_start(sub, ctx):
    """Replica-aware stage-in (engine step 5b, DESIGN.md §3): dataset jobs
    swap the flat latency+stage-in terms for a WAN transfer from the
    policy-selected replica, with catalog bookkeeping (LRU touch,
    cache-on-read insertion, hit/transfer counters)."""
    from .engine import _site_sum, service_time, stage_in_time
    from .network import shared_transfer_times
    from .replicas import insert_replicas, touch

    policy = sub.config
    dext = ctx.ext["data"]
    network, rep, dstate = dext.network, dext.replicas, dext.state
    jobs, sites, S = ctx.jobs, ctx.sites, ctx.S
    started, site_c, share, start_site = ctx.started, ctx.site_c, ctx.share, ctx.start_site
    clock = ctx.clock

    has_ds = jobs.dataset >= 0
    # only flat-link stage-ins contend for the site ingress link; dataset
    # jobs stage over the WAN matrix instead
    n_flat_start = _site_sum((started & ~has_ds).astype(jnp.int32), start_site, S)
    share_in = n_flat_start[site_c].astype(jnp.float32)
    t_serv = service_time(jobs, ctx.sites_serv, site_c, share_in, share)
    D = rep.present.shape[0]
    d_c = jnp.clip(jobs.dataset, 0, D - 1)
    ds_bytes = rep.size[d_c]
    local = rep.present[d_c, site_c]
    read = started & has_ds
    src = policy.select_source(jobs, sites, network, rep, dstate, site_c, clock)
    src_c = jnp.clip(src, 0, S - 1)
    xfer = read & ~local
    # swap the flat latency+stage-in terms for the WAN transfer
    in_flat = stage_in_time(jobs, ctx.sites_serv, site_c, share_in)
    # static specialization: with the transfer-queue subsystem registered, WAN
    # reads are deferred to its link queues (DESIGN.md §11) instead of being
    # priced instantly — the staging gate and landing happen in transfers.py
    defer = "transfers" in ctx.ext
    if defer:
        ctx.t_serv = jnp.where(has_ds, t_serv - in_flat, t_serv)
    else:
        t_net, _ = shared_transfer_times(network, src_c, site_c, ds_bytes, xfer)
        ctx.t_serv = jnp.where(has_ds, t_serv - in_flat + t_net, t_serv)
    # catalog bookkeeping: touch LRU clocks, cache-on-read insertion
    rep = touch(rep, jobs.dataset, src_c, xfer, clock)
    rep = touch(rep, jobs.dataset, site_c, read & local, clock)
    want_cache = policy.should_cache(jobs, sites, network, rep, dstate, site_c, clock) & xfer
    moved = jnp.where(xfer, ds_bytes, 0.0)
    rep = rep._replace(n_hits=rep.n_hits + (read & local).sum().astype(jnp.int32))
    net_in_now = dext.net_acc
    if defer:
        # hand this round's WAN reads to the transfer queues; replica
        # insertion and WAN counters land at transfer completion
        ctx.scratch["transfers"] = {
            "xfer": xfer,
            "link": src_c * S + site_c,
            "bytes": moved,
            "resid": jnp.maximum(t_serv - in_flat, 0.0) + network.latency[src_c, site_c],
            "cache": want_cache,
        }
        t_net_col = jnp.zeros((jobs.capacity,), jnp.float32)
    else:
        rep = insert_replicas(rep, jobs.dataset, site_c, want_cache, clock)
        rep = rep._replace(
            n_transfers=rep.n_transfers + xfer.sum().astype(jnp.int32),
            bytes_moved=rep.bytes_moved + moved.sum(),
        )
        net_in_now = net_in_now + _site_sum(moved, jnp.where(xfer, jobs.site, S), S)
        t_net_col = t_net
    ctx.jobs = jobs._replace(
        xfer_src=jnp.where(read, src_c, jobs.xfer_src),
        xfer_bytes=jnp.where(read, moved, jobs.xfer_bytes),
        xfer_time=jnp.where(read, t_net_col, jobs.xfer_time),
    )
    dstate = policy.on_step(dstate, ctx.jobs, rep, started, xfer, clock)
    ctx.ext["data"] = DataExt(
        network=network, replicas=rep, state=dstate, net_acc=net_in_now
    )


def land_deferred(dext: DataExt, jobs, done, cache, clock, S):
    """Deferred landing for queue-managed transfers (DESIGN.md §11): the
    catalog/WAN bookkeeping that ``_data_on_start`` skips in defer mode,
    applied by the transfer subsystem on the ``done`` rows at completion —
    replica materialization at the destination, transfer/byte counters, and
    per-site WAN-ingress accumulation for the event log."""
    from .engine import _site_sum
    from .replicas import insert_replicas

    rep = insert_replicas(dext.replicas, jobs.dataset, jnp.clip(jobs.site, 0, S - 1), done & cache, clock)
    moved = jnp.where(done, jobs.xfer_bytes, 0.0)
    rep = rep._replace(
        n_transfers=rep.n_transfers + done.sum().astype(jnp.int32),
        bytes_moved=rep.bytes_moved + moved.sum(),
    )
    net_in = _site_sum(moved, jnp.where(done, jobs.site, S), S)
    return dext._replace(replicas=rep, net_acc=dext.net_acc + net_in)


def _data_log_spec(sub, dext: DataExt, jobs, sites):
    return {"site_disk": dext.replicas.disk_used, "site_net_in": dext.net_acc}


def _data_log_columns(sub, ctx, write):
    dext = ctx.ext["data"]
    cols = {"site_disk": dext.replicas.disk_used, "site_net_in": dext.net_acc}
    # WAN ingress accumulates between log writes so monitor_every > 1 still
    # conserves bytes in the exported timeline; reset on write
    ctx.ext["data"] = dext._replace(net_acc=jnp.where(write, 0.0, dext.net_acc))
    return cols


def _data_finalize(sub, dext: DataExt, jobs, sites, clock):
    dstate = sub.config.on_end(dext.state, jobs, dext.replicas, clock)
    dext = dext._replace(state=dstate)
    return dext, {"replicas": dext.replicas, "data_state": dstate}


def data_subsystem(policy: DataPolicy) -> "Subsystem":
    """Data movement as a composable engine subsystem.  Initial state is the
    ``(NetworkState, ReplicaState)`` pair; the DataPolicy (static functions)
    rides in ``config`` so identically-configured subsystems share jit cache
    entries."""
    from .subsystems import Subsystem

    return Subsystem(
        name="data",
        config=policy,
        init=_data_init,
        on_start=_data_on_start,
        log_spec=_data_log_spec,
        log_columns=_data_log_columns,
        finalize=_data_finalize,
    )


# --------------------------------------------------------------------------
# built-in data policies
# --------------------------------------------------------------------------


def always_remote() -> DataPolicy:
    """Read from the nearest replica, never cache: every job whose dataset is
    not already local pays a WAN transfer (the Begy et al. 'remote access'
    baseline)."""
    return make_data_policy("always_remote")


def cache_on_read() -> DataPolicy:
    """Nearest-replica reads, and every remote read inserts a replica at the
    compute site (LRU-evicting under storage pressure) — the Rucio-style
    volatile cache."""
    return make_data_policy("cache_on_read", should_cache=_always_cache)


def pre_place_hot(hot_frac: float = 0.1, n_copies: int = 3, cache: bool = False) -> DataPolicy:
    """Replicate the hottest ``hot_frac`` of datasets (by job count in the
    submitted workload) to the ``n_copies`` largest storage elements before
    the run — PanDA PD2P-flavoured pre-placement."""

    def init(jobs, sites, network, replicas: ReplicaState):
        D, S = replicas.present.shape
        d = jnp.clip(jobs.dataset, 0, D - 1)
        has = jobs.valid & (jobs.dataset >= 0)
        counts = jax.ops.segment_sum(has.astype(jnp.int32), jnp.where(has, d, D), num_segments=D + 1)[:D]
        k = max(int(round(hot_frac * D)), 1)
        rank = jnp.argsort(-counts)
        hot = jnp.zeros((D,), bool).at[rank[:k]].set(True)
        targets = jnp.argsort(-replicas.disk_cap)[:n_copies]
        target_mask = jnp.zeros((S,), bool).at[targets].set(True)
        want = hot[:, None] & target_mask[None, :]
        return insert_mask(replicas, want, 0.0), ()

    return make_data_policy(
        f"pre_place_hot({hot_frac},{n_copies})",
        init=init,
        should_cache=_always_cache if cache else _never_cache,
    )


DATA_REGISTRY: dict[str, Callable[..., DataPolicy]] = {
    "always_remote": always_remote,
    "cache_on_read": cache_on_read,
    "pre_place_hot": pre_place_hot,
}


def get_data_policy(name: str, **params) -> DataPolicy:
    if name not in DATA_REGISTRY:
        raise KeyError(f"unknown data policy {name!r}; have {sorted(DATA_REGISTRY)}")
    return DATA_REGISTRY[name](**params)


def register_data(name: str):
    """Decorator: plug a user data-policy factory into the registry."""

    def deco(fn):
        DATA_REGISTRY[name] = fn
        return fn

    return deco


# --------------------------------------------------------------------------
# Abstract-class adapter mirroring ``policies.AllocationPlugin``.
# --------------------------------------------------------------------------


class DataPlugin:
    """Subclass and override, then call ``.build()`` to get a DataPolicy."""

    name = "custom_data"

    def get_resource_information(self, jobs, sites, network, replicas):
        return replicas, ()

    def select_source(self, jobs, sites, network, replicas, state, dst, clock):
        return nearest_source(replicas, network, jobs.dataset, dst)

    def should_cache(self, jobs, sites, network, replicas, state, dst, clock):
        return jnp.zeros((jobs.capacity,), bool)

    def on_transfer(self, state, jobs, replicas, started, xfer, clock):
        return state

    def on_simulation_end(self, state, jobs, replicas, clock):
        return state

    def build(self) -> DataPolicy:
        return DataPolicy(
            name=self.name,
            init=self.get_resource_information,
            select_source=self.select_source,
            should_cache=self.should_cache,
            on_step=self.on_transfer,
            on_end=self.on_simulation_end,
        )
