"""Data-movement policies — the second CGSim plugin family (DESIGN.md §3).

The paper promises a "modular plugin mechanism for testing custom workflow
scheduling *and data movement policies*"; ``policies.Policy`` covers the
scheduling half, this module covers data.  A ``DataPolicy`` is a pytree of
pure functions with the same extension-point shape as ``Policy``:

    paper hook               | DataPolicy field
    -------------------------+-------------------------------------------------
    getResourceInformation   | init(jobs, sites, network, replicas)
                             |   -> (replicas, data_state)   (pre-placement)
    assignJob (data half)    | select_source(jobs, sites, network, replicas,
                             |   state, dst, clock) -> i32[J] replica site
                             | should_cache(jobs, sites, network, replicas,
                             |   state, dst, clock) -> bool[J] cache-on-read
    onJobEnd                 | on_step(state, jobs, replicas, started, xfer,
                             |   clock) -> state
    onSimulationEnd          | on_end(state, jobs, replicas, clock) -> state

All fields are jit-traceable, so ``engine.simulate`` with a DataPolicy keeps
vmapping under ``simulate_ensemble``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .replicas import ReplicaState, insert_mask, nearest_source


class DataPolicy(NamedTuple):
    name: str
    init: Callable
    select_source: Callable
    should_cache: Callable
    on_step: Callable
    on_end: Callable


def _default_init(jobs, sites, network, replicas):
    return replicas, ()


def _default_select(jobs, sites, network, replicas, state, dst, clock):
    return nearest_source(replicas, network, jobs.dataset, dst)


def _never_cache(jobs, sites, network, replicas, state, dst, clock):
    return jnp.zeros((jobs.capacity,), bool)


def _always_cache(jobs, sites, network, replicas, state, dst, clock):
    return jnp.ones((jobs.capacity,), bool)


def _keep_state(state, *_):
    return state


def make_data_policy(
    name: str,
    *,
    init=None,
    select_source=None,
    should_cache=None,
    on_step=None,
    on_end=None,
) -> DataPolicy:
    return DataPolicy(
        name=name,
        init=init or _default_init,
        select_source=select_source or _default_select,
        should_cache=should_cache or _never_cache,
        on_step=on_step or _keep_state,
        on_end=on_end or _keep_state,
    )


# --------------------------------------------------------------------------
# built-in data policies
# --------------------------------------------------------------------------


def always_remote() -> DataPolicy:
    """Read from the nearest replica, never cache: every job whose dataset is
    not already local pays a WAN transfer (the Begy et al. 'remote access'
    baseline)."""
    return make_data_policy("always_remote")


def cache_on_read() -> DataPolicy:
    """Nearest-replica reads, and every remote read inserts a replica at the
    compute site (LRU-evicting under storage pressure) — the Rucio-style
    volatile cache."""
    return make_data_policy("cache_on_read", should_cache=_always_cache)


def pre_place_hot(hot_frac: float = 0.1, n_copies: int = 3, cache: bool = False) -> DataPolicy:
    """Replicate the hottest ``hot_frac`` of datasets (by job count in the
    submitted workload) to the ``n_copies`` largest storage elements before
    the run — PanDA PD2P-flavoured pre-placement."""

    def init(jobs, sites, network, replicas: ReplicaState):
        D, S = replicas.present.shape
        d = jnp.clip(jobs.dataset, 0, D - 1)
        has = jobs.valid & (jobs.dataset >= 0)
        counts = jax.ops.segment_sum(has.astype(jnp.int32), jnp.where(has, d, D), num_segments=D + 1)[:D]
        k = max(int(round(hot_frac * D)), 1)
        rank = jnp.argsort(-counts)
        hot = jnp.zeros((D,), bool).at[rank[:k]].set(True)
        targets = jnp.argsort(-replicas.disk_cap)[:n_copies]
        target_mask = jnp.zeros((S,), bool).at[targets].set(True)
        want = hot[:, None] & target_mask[None, :]
        return insert_mask(replicas, want, 0.0), ()

    return make_data_policy(
        f"pre_place_hot({hot_frac},{n_copies})",
        init=init,
        should_cache=_always_cache if cache else _never_cache,
    )


DATA_REGISTRY: dict[str, Callable[..., DataPolicy]] = {
    "always_remote": always_remote,
    "cache_on_read": cache_on_read,
    "pre_place_hot": pre_place_hot,
}


def get_data_policy(name: str, **params) -> DataPolicy:
    if name not in DATA_REGISTRY:
        raise KeyError(f"unknown data policy {name!r}; have {sorted(DATA_REGISTRY)}")
    return DATA_REGISTRY[name](**params)


def register_data(name: str):
    """Decorator: plug a user data-policy factory into the registry."""

    def deco(fn):
        DATA_REGISTRY[name] = fn
        return fn

    return deco


# --------------------------------------------------------------------------
# Abstract-class adapter mirroring ``policies.AllocationPlugin``.
# --------------------------------------------------------------------------


class DataPlugin:
    """Subclass and override, then call ``.build()`` to get a DataPolicy."""

    name = "custom_data"

    def get_resource_information(self, jobs, sites, network, replicas):
        return replicas, ()

    def select_source(self, jobs, sites, network, replicas, state, dst, clock):
        return nearest_source(replicas, network, jobs.dataset, dst)

    def should_cache(self, jobs, sites, network, replicas, state, dst, clock):
        return jnp.zeros((jobs.capacity,), bool)

    def on_transfer(self, state, jobs, replicas, started, xfer, clock):
        return state

    def on_simulation_end(self, state, jobs, replicas, clock):
        return state

    def build(self) -> DataPolicy:
        return DataPolicy(
            name=self.name,
            init=self.get_resource_information,
            select_source=self.select_source,
            should_cache=self.should_cache,
            on_step=self.on_transfer,
            on_end=self.on_simulation_end,
        )
