"""Composable engine subsystems — the round loop as an ordered phase pipeline.

Three PRs of growth (data movement, availability, workflow DAGs) each wove
``if <flag>:`` blocks through ``engine.simulate`` plus lockstep edits to
``EngineState``, ``distributed``, ``events`` and ``monitor`` — exactly the
"hardwired algorithms" failure mode CGSim exists to fix.  This module turns
each capability into a ``Subsystem``: a static bundle of hook functions the
engine calls at fixed points of every event round, with all of the
subsystem's dynamic state living in one slot of the generic
``EngineState.ext`` mapping (a dict pytree keyed by subsystem name).

Static specialization (DESIGN.md §7): the subsystem tuple is a *static* jit
argument, so a run without a subsystem traces no trace of it — no ``lax.cond``
at runtime, no extra ops or RNG draws, bit-for-bit identical to an engine
that never knew the subsystem existed (the golden-trace matrix test pins all
8 on/off combinations of the built-in trio).

Hook protocol — every hook is optional (``None`` = not interested), takes the
subsystem itself first (so hooks can be module-level functions and the
``Subsystem`` stays hashable for jit caching), and reads/writes the mutable
trace-time ``RoundCtx``:

  phase (engine round)       | hook
  ---------------------------+------------------------------------------------
  0. pre-run (host)          | validate(sub, state0, jobs, sites)   may raise
  0. pre-run (traced)        | init(sub, state0, jobs, sites) -> ext
  1. clock min-reduction     | event_times(sub, ctx) -> f32[] next event time
     arrivability            | arrival_gate(sub, ctx) -> bool[J]  (also step 3)
  2. completions             | completion_filter(sub, ctx, comp) -> bool[J]
  2b/2c. post-completion     | on_completions(sub, ctx)      state transitions
  4. assignment              | pre_assign(sub, ctx)   feasibility/speed mods
  5b. starts                 | on_start(sub, ctx)     service-time adjustments
  6. event log               | log_columns(sub, ctx, write) -> {name: [S] col}
     (declaration)           | log_spec(sub, ext, jobs, sites) -> {name: [S]}
  end of run                 | finalize(sub, ext, jobs, sites, clock)
                             |   -> (ext, {SimResult field: value})
  capacity padding (host)    | pad_jobs(sub, state0, old_J, new_J) -> state0

Hooks fire in subsystem-tuple order within each phase; the canonical order
for the built-ins is (availability, workflow, data, transfers, faults), which
reproduces the hand-written engine exactly: outage preemption before
cascade-cancel, output materialization before replica-source selection,
stage-in pricing before transfer-queue diversion, and fault recovery last so
it observes every other subsystem's transitions (DESIGN.md §11, §13).
"""
from __future__ import annotations

import zlib
from typing import Any, Callable, NamedTuple

INF = float("inf")

# fold_in salt separating the subsystem key tree from the engine's own
# split(key, 4) stream (see RoundCtx.subkey)
_SUBKEY_SALT = 0x5B5D5


class Subsystem(NamedTuple):
    """Static hook bundle for one engine extension (see module docstring).

    ``config`` carries compile-time constants (e.g. the ``DataPolicy``); all
    run-time state lives in ``EngineState.ext[name]``.  Keep hooks
    module-level functions so two identically-configured subsystems compare
    equal and hit the same jit cache entry.
    """

    name: str
    config: Any = None
    init: Callable | None = None
    validate: Callable | None = None
    event_times: Callable | None = None
    arrival_gate: Callable | None = None
    completion_filter: Callable | None = None
    on_completions: Callable | None = None
    pre_assign: Callable | None = None
    on_start: Callable | None = None
    log_spec: Callable | None = None
    log_columns: Callable | None = None
    finalize: Callable | None = None
    pad_jobs: Callable | None = None


def make_subsystem(name: str, **hooks) -> Subsystem:
    """Convenience constructor: ``make_subsystem("scratch", on_start=f, ...)``."""
    return Subsystem(name=name, **hooks)


class RoundCtx:
    """Mutable trace-time context threaded through one engine round.

    This is *staging state*, not carried state: the engine rebuilds it every
    round from the ``EngineState`` pytree, hooks mutate it in place while the
    round body is traced, and the engine collects the mutated fields back
    into the next ``EngineState``.  Fields a hook may read/write:

      jobs, sites        current JobsState / SiteState (replace to transition)
      ext                dict name -> subsystem state (replace your slot)
      clock_prev, clock  round entry time / this round's event time
      comp, done_now, failed_now   completion masks (set by the engine, step 2)
      arrived            this round's arrival mask (engine, step 3)
      feasible           bool[J, S] assignment feasibility (AND your mask in);
                         sparse top-k mode (``simulate(topk=)``) carries a
                         broadcastable bool[1, S] site-level mask instead —
                         per-job feasibility lives in the candidate index
                         (DESIGN.md §12)
      start_cores        i32[S] cores the start phase may claim this round
      sites_serv         SiteState used for service-time pricing (speed mods)
      started, site_c, share, start_site   start-phase masks (engine, step 5)
      t_serv             f32[J] service time of starting jobs (override/adjust)
      progressed         OR in a bool[] if your transitions made progress
      scratch            per-round dict for passing values between your hooks
      max_retries, S, J  static knobs

    Stochastic subsystems draw randomness through ``subkey(name)`` — a
    per-round, per-subsystem PRNG stream folded off the engine's carry key
    *without consuming it*, so adding draws never perturbs the engine's own
    bitstream (failure sampling, policy keys) and existing runs stay
    bit-for-bit reproducible (ROADMAP: subsystem-level RNG streams).
    """

    def __init__(self, *, jobs, sites, ext, clock_prev, max_retries, rng=None):
        self.jobs = jobs
        self.sites = sites
        self.ext = ext
        self.clock_prev = clock_prev
        self.clock = clock_prev
        self.max_retries = max_retries
        self.rng = rng
        self.S = sites.capacity
        self.J = jobs.capacity
        self.comp = None
        self.done_now = None
        self.failed_now = None
        self.arrived = None
        self.feasible = None
        self.start_cores = None
        self.sites_serv = None
        self.started = None
        self.site_c = None
        self.share = None
        self.start_site = None
        self.t_serv = None
        self.progressed = False
        self.scratch = {}

    def subkey(self, name: str, salt: int = 0):
        """This round's PRNG key for subsystem ``name`` (salt for extra
        streams).  Derived by ``fold_in`` from the round's carry key — the
        engine splits that key separately, so drawing here adds no ops to and
        removes no draws from the engine's own stream: a subsystem that
        starts (or stops) consuming randomness leaves every other consumer's
        bitstream untouched.  Deterministic across runs: the stream depends
        only on (run key, round, subsystem name, salt)."""
        import jax

        if self.rng is None:
            raise ValueError("RoundCtx.subkey needs the engine round key (rng=)")
        key = jax.random.fold_in(self.rng, _SUBKEY_SALT)
        key = jax.random.fold_in(key, zlib.crc32(name.encode()) & 0x7FFFFFFF)
        return jax.random.fold_in(key, salt) if salt else key


SubsystemPair = tuple  # (Subsystem, initial state pytree)


def resolve_subsystems(
    *,
    data_policy=None,
    network=None,
    replicas=None,
    availability=None,
    workflow=None,
    transfers=None,
    faults=None,
    subsystems=(),
    jobs=None,
    sites=None,
    validate=True,
):
    """Normalize the engine's keyword API into ``(static tuple, ext0 dict)``.

    The legacy kwargs (``availability=``, ``workflow=``, ``data_policy=`` +
    ``network=``/``replicas=``, ``transfers=``, ``faults=``) map onto the
    built-in subsystems in canonical order — availability, workflow, data,
    transfers, faults — followed by any explicit
    ``subsystems=((Subsystem, state0), ...)`` pairs in caller order.
    Host-side ``validate`` hooks run here, before anything is traced.
    """
    pairs: list[tuple[Subsystem, Any]] = []
    if availability is not None:
        from .availability import availability_subsystem

        pairs.append((availability_subsystem(), availability))
    if workflow is not None:
        from .workflows import workflow_subsystem

        pairs.append((workflow_subsystem(), workflow))
    if data_policy is not None:
        if network is None or replicas is None:
            raise ValueError("data_policy requires both network= and replicas=")
        from .datapolicies import data_subsystem

        pairs.append((data_subsystem(data_policy), (network, replicas)))
    if transfers is not None:
        if data_policy is None:
            raise ValueError(
                "transfers= requires the data subsystem (data_policy= with "
                "network=/replicas=) — it owns the WAN matrices and catalog"
            )
        from .transfers import transfers_subsystem

        pairs.append((transfers_subsystem(), transfers))
    if faults is not None:
        from .faults import faults_subsystem

        # the static channel flags are derived host-side from the concrete
        # state here, before anything is traced (FaultsConfig docstring)
        pairs.append((faults_subsystem(faults), faults))
    for entry in subsystems:
        if isinstance(entry, Subsystem):
            raise TypeError(
                f"subsystems entries are (Subsystem, state0) pairs; got bare "
                f"Subsystem {entry.name!r} — pass ({entry.name}, state0)"
            )
        sub, state0 = entry
        pairs.append((sub, state0))

    names = [sub.name for sub, _ in pairs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate subsystem names: {sorted(names)}")
    if validate:
        for sub, state0 in pairs:
            if sub.validate is not None:
                sub.validate(sub, state0, jobs, sites)
    return tuple(sub for sub, _ in pairs), {sub.name: state0 for sub, state0 in pairs}


def pad_ext_jobs(subsystems, ext: dict, old_capacity: int, new_capacity: int) -> dict:
    """Grow job-capacity-shaped subsystem state (host-side, for distributed
    padding) via each subsystem's ``pad_jobs`` hook — no per-subsystem code in
    the caller."""
    if new_capacity == old_capacity:
        return ext
    out = dict(ext)
    for sub in subsystems:
        if sub.pad_jobs is not None and sub.name in out:
            out[sub.name] = sub.pad_jobs(sub, out[sub.name], old_capacity, new_capacity)
    return out
