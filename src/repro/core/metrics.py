"""Operational metrics (paper §1: queue time, CPU efficiency, failure rate,
throughput) computed from a finished ``SimResult``."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .types import DONE, FAILED, SimResult


class Metrics(NamedTuple):
    makespan: jax.Array
    n_done: jax.Array
    n_failed: jax.Array
    failure_rate: jax.Array
    mean_walltime: jax.Array
    mean_queue_time: jax.Array
    p95_queue_time: jax.Array
    throughput: jax.Array        # finished jobs / simulated second
    core_utilization: jax.Array  # busy core-seconds / (total cores x makespan)
    cpu_efficiency: jax.Array    # compute seconds / walltime seconds (I/O overhead)
    # distribution tails (the dashboard / telemetry quotables)
    p50_queue_time: jax.Array
    p99_queue_time: jax.Array
    p50_walltime: jax.Array
    p95_walltime: jax.Array
    p99_walltime: jax.Array
    # transfer-queue tails (DESIGN.md §11) — 0 when no WAN transfers happened
    p50_xfer_wait: jax.Array   # queue-wait of completed jobs' last stage-in
    p95_xfer_wait: jax.Array
    p99_xfer_wait: jax.Array
    p50_xfer_time: jax.Array   # transfer duration of the last stage-in
    p95_xfer_time: jax.Array
    p99_xfer_time: jax.Array
    # fault-channel tails (DESIGN.md §13) — 0 when faults are off
    time_lost_failures: jax.Array  # core-attempt seconds burned by failures/kills
    p50_retries: jax.Array         # retry counts over terminated jobs
    p95_retries: jax.Array
    p99_retries: jax.Array
    p50_backoff_wait: jax.Array    # cumulative resubmission backoff per job
    p95_backoff_wait: jax.Array
    p99_backoff_wait: jax.Array


def _masked_percentile(values: jax.Array, mask: jax.Array, n: jax.Array, q: float):
    """Percentile of ``values[mask]`` without dynamic shapes: masked-out rows
    sort to the front as ``-inf``, so the q-th valid element sits at a fixed
    offset from the tail.  Matches the engine's original p95 formula exactly
    (same truncation, same clamp) so historical numbers are unchanged."""
    cap = values.shape[-1]
    sorted_ = jnp.sort(jnp.where(mask, values, -jnp.inf))
    idx = jnp.clip((cap - n) + (q * n).astype(jnp.int32), 0, cap - 1)
    return jnp.maximum(sorted_[idx], 0.0)


def compute_metrics(result: SimResult) -> Metrics:
    jobs, sites = result.jobs, result.sites
    done = (jobs.state == DONE) & jobs.valid
    failed = (jobs.state == FAILED) & jobs.valid
    n_done = done.sum()
    n_failed = failed.sum()

    wall = jnp.where(done, jobs.t_finish - jobs.t_start, 0.0)
    queue = jnp.where(done, jobs.t_start - jobs.arrival, 0.0)
    mean_wall = wall.sum() / jnp.maximum(n_done, 1)
    mean_queue = queue.sum() / jnp.maximum(n_done, 1)
    q_raw = jobs.t_start - jobs.arrival
    w_raw = jobs.t_finish - jobs.t_start
    p95_queue = _masked_percentile(q_raw, done, n_done, 0.95)

    busy = jnp.where(done | failed, (jobs.t_finish - jobs.t_start) * jobs.cores, 0.0).sum()
    total_cores = jnp.where(sites.active, sites.cores, 0).sum().astype(jnp.float32)
    makespan = jnp.maximum(result.makespan, 1e-9)
    util = busy / jnp.maximum(total_cores * makespan, 1e-9)

    # share of walltime spent computing (vs staging) under the service model
    compute_t = jnp.where(done, jobs.work / jnp.maximum(
        result.sites.speed[jnp.clip(jobs.site, 0, sites.capacity - 1)]
        * jobs.cores.astype(jnp.float32), 1e-9), 0.0)
    eff = compute_t.sum() / jnp.maximum(wall.sum(), 1e-9)

    # transfer tails over completed jobs whose last stage-in moved WAN bytes
    moved = done & (jobs.xfer_bytes > 0)
    n_moved = moved.sum()

    # fault tails: retry counts always exist; backoff waits / time lost come
    # from the faults subsystem state when it ran (static python branch, so
    # faults-off runs trace identically to before).
    term = done | failed
    n_term = term.sum()
    retries_f = jobs.retries.astype(jnp.float32)
    fs = (getattr(result, "ext", None) or {}).get("faults")
    if fs is not None:
        time_lost = fs.time_lost
        bwait = fs.backoff_wait
        waited = term & (bwait > 0)
        n_waited = waited.sum()
        p50_bw = _masked_percentile(bwait, waited, n_waited, 0.50)
        p95_bw = _masked_percentile(bwait, waited, n_waited, 0.95)
        p99_bw = _masked_percentile(bwait, waited, n_waited, 0.99)
    else:
        time_lost = jnp.float32(0.0)
        p50_bw = p95_bw = p99_bw = jnp.float32(0.0)

    return Metrics(
        makespan=result.makespan,
        n_done=n_done,
        n_failed=n_failed,
        failure_rate=n_failed / jnp.maximum(n_done + n_failed, 1),
        mean_walltime=mean_wall,
        mean_queue_time=mean_queue,
        p95_queue_time=p95_queue,
        throughput=n_done / makespan,
        core_utilization=util,
        cpu_efficiency=jnp.minimum(eff, 1.0),
        p50_queue_time=_masked_percentile(q_raw, done, n_done, 0.50),
        p99_queue_time=_masked_percentile(q_raw, done, n_done, 0.99),
        p50_walltime=_masked_percentile(w_raw, done, n_done, 0.50),
        p95_walltime=_masked_percentile(w_raw, done, n_done, 0.95),
        p99_walltime=_masked_percentile(w_raw, done, n_done, 0.99),
        p50_xfer_wait=_masked_percentile(jobs.xfer_wait, moved, n_moved, 0.50),
        p95_xfer_wait=_masked_percentile(jobs.xfer_wait, moved, n_moved, 0.95),
        p99_xfer_wait=_masked_percentile(jobs.xfer_wait, moved, n_moved, 0.99),
        p50_xfer_time=_masked_percentile(jobs.xfer_time, moved, n_moved, 0.50),
        p95_xfer_time=_masked_percentile(jobs.xfer_time, moved, n_moved, 0.95),
        p99_xfer_time=_masked_percentile(jobs.xfer_time, moved, n_moved, 0.99),
        time_lost_failures=time_lost,
        p50_retries=_masked_percentile(retries_f, term, n_term, 0.50),
        p95_retries=_masked_percentile(retries_f, term, n_term, 0.95),
        p99_retries=_masked_percentile(retries_f, term, n_term, 0.99),
        p50_backoff_wait=p50_bw,
        p95_backoff_wait=p95_bw,
        p99_backoff_wait=p99_bw,
    )


def summary_str(m: Metrics) -> str:
    return (
        f"makespan={float(m.makespan):.1f}s done={int(m.n_done)} failed={int(m.n_failed)} "
        f"fail_rate={float(m.failure_rate):.3f} mean_wall={float(m.mean_walltime):.1f}s "
        f"mean_queue={float(m.mean_queue_time):.1f}s "
        f"queue_p50/95/99={float(m.p50_queue_time):.1f}/{float(m.p95_queue_time):.1f}/"
        f"{float(m.p99_queue_time):.1f}s "
        f"wall_p50/95/99={float(m.p50_walltime):.1f}/{float(m.p95_walltime):.1f}/"
        f"{float(m.p99_walltime):.1f}s "
        f"throughput={float(m.throughput) * 3600.0:.1f} jobs/h "
        f"util={float(m.core_utilization):.3f} cpu_eff={float(m.cpu_efficiency):.3f} "
        f"xfer_wait_p50/95/99={float(m.p50_xfer_wait):.1f}/{float(m.p95_xfer_wait):.1f}/"
        f"{float(m.p99_xfer_wait):.1f}s "
        f"xfer_time_p50/95/99={float(m.p50_xfer_time):.1f}/{float(m.p95_xfer_time):.1f}/"
        f"{float(m.p99_xfer_time):.1f}s "
        f"time_lost={float(m.time_lost_failures):.1f}s "
        f"retries_p50/95/99={float(m.p50_retries):.0f}/{float(m.p95_retries):.0f}/"
        f"{float(m.p99_retries):.0f} "
        f"backoff_p50/95/99={float(m.p50_backoff_wait):.1f}/{float(m.p95_backoff_wait):.1f}/"
        f"{float(m.p99_backoff_wait):.1f}s"
    )
