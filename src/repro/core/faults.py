"""Fault injection & recovery — chaos channels for the event engine (DESIGN.md §13).

CGSim's pitch is evaluating grid resilience *policies* before deploying them,
but through PR 9 the only failure mode is a per-site coin flip with instant,
free resubmission: FTS flows never fail, replicas never vanish, overrunning
jobs never get killed, and nothing reacts to a site that fails every job it
touches.  This module adds the retry/failure dynamics real WLCG middleware
exhibits (arxiv 1902.10069, 2403.14903) as a fifth built-in
:class:`~.subsystems.Subsystem` with four channels in one fixed-shape
:class:`FaultState` pytree:

1. **Transfer-failure injection** — each in-flight FTS flow (PR 8) fails at
   its would-complete event with a per-link probability, drawn from the
   subsystem's own RNG stream (``ctx.subkey("faults")``).  Failed flows
   re-enqueue after an exponential-backoff delay (``base * 2^attempt``);
   past ``max_xfer_attempts`` the staging job fails its attempt and takes
   the engine's normal retry path.
2. **Resubmission backoff** — jobs resubmitted after a failed attempt are
   pushed back to PENDING with ``arrival = clock + base * 2^(retries-1)``
   instead of rejoining QUEUED in the same round.  Backoff base 0 (the
   default) keeps the current bitstream: the channel is then statically
   compiled out (it would invalidate the packed start-order fast path,
   which keys on run-constant arrivals — see ``FaultsConfig.mutates_arrival``).
3. **Replica-loss calendar** — timed loss events drop non-pinned replicas
   from the PR 1 catalog mid-run, so later readers re-source from the origin
   over the WAN.  The pinned-origin invariant is preserved by construction
   (origin copies are never dropped) and the catalog stays exact
   (``disk_used`` decremented, ``last_access`` reset to the -inf sentinel).
4. **Adaptive site blacklisting** — a per-site EWMA failure score trips a
   circuit breaker: the site leaves assignment feasibility (and gets zero
   start budget) for a cooldown window, then reopens *half-open* — exactly
   one probe job is admitted; success closes the breaker and resets the
   score, failure re-trips it.

Walltime kills ride along as a fifth behavior: RUNNING jobs whose
``t_start + walltime`` deadline passes are preempted (resources freed,
transfer cancelled, attempt retried or failed), mirroring batch-system
walltime limits.

Every channel contributes its next edge (backoff wake-ups, loss events,
cooldown expiries, kill deadlines) to the engine's event-time min-reduction,
so fault dynamics land on exact event rounds — no polling quantum needed.
``faults=None`` is bit-for-bit inert via static specialization, and a
default-constructed ``make_faults`` state (probability 0, backoff 0, no
events, infinite walltime, blacklisting off) reproduces the faults-off
engine bitstream: all masks are provably False and the subsystem only
draws from its own fold_in stream.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .types import ASSIGNED, FAILED, PENDING, QUEUED, RUNNING

INF = jnp.float32(jnp.inf)

# circuit-breaker states (per site)
BL_CLOSED, BL_TRIPPED, BL_HALF_OPEN = 0, 1, 2


class FaultsConfig(NamedTuple):
    """Static (hashable) compile-time flags for the faults subsystem.

    Both are derived host-side from the concrete initial state by
    :func:`faults_subsystem` so that disabled channels trace no ops:

    - ``job_backoff``: channel 2 mutates ``jobs.arrival``, which invalidates
      the engine's packed start-order fast path (``~srank`` assumes
      run-constant arrivals) — the engine checks ``mutates_arrival`` and
      falls back to the general ranking when set.
    - ``blacklist``: the circuit breaker expands the sparse top-k site-level
      feasibility mask to a full ``[J, S]`` probe gate; compiled out when
      the trip threshold is infinite.
    """

    job_backoff: bool = False
    blacklist: bool = False

    @property
    def mutates_arrival(self) -> bool:
        return self.job_backoff


class FaultState(NamedTuple):
    """The faults subsystem's ``EngineState.ext["faults"]`` slot.

    Link axis ``L = S * S`` (flattened directed links, matching
    :class:`~.transfers.TransferState`); job axis ``J``; site axis ``S``;
    loss-calendar axis ``E`` (fixed, inf-padded).
    """

    # channel 1: transfer-failure injection + exponential backoff re-enqueue
    link_fail_p: jax.Array  # f32[L] per-link failure probability
    xfer_backoff: jax.Array  # f32[] backoff base (s); delay = base * 2^attempt
    max_xfer_attempts: jax.Array  # i32[] failures before the job attempt fails
    attempt: jax.Array  # i32[J] failures of the current stage-in
    retry_at: jax.Array  # f32[J] backoff wake time (inf = no retry pending)
    # channel 2: resubmission backoff (enabled iff base > 0 — static flag)
    job_backoff: jax.Array  # f32[] backoff base (s); delay = base * 2^(retries-1)
    backoff_wait: jax.Array  # f32[J] cumulative scheduled backoff delay per job
    # walltime kills
    walltime: jax.Array  # f32[J] per-job walltime limit (inf = none)
    # channel 3: replica-loss calendar (host-built, sorted by time)
    loss_t: jax.Array  # f32[E] event times (inf = padding)
    loss_d: jax.Array  # i32[E] dataset ids
    loss_s: jax.Array  # i32[E] site ids
    loss_done: jax.Array  # bool[E] already applied
    # channel 4: adaptive site blacklisting (circuit breaker per site)
    bl_threshold: jax.Array  # f32[] EWMA score trip level (inf = disabled)
    bl_alpha: jax.Array  # f32[] EWMA smoothing factor
    bl_cooldown: jax.Array  # f32[] tripped -> half-open delay (s)
    score: jax.Array  # f32[S] EWMA failure fraction
    bl_state: jax.Array  # i32[S] BL_CLOSED / BL_TRIPPED / BL_HALF_OPEN
    bl_until: jax.Array  # f32[S] cooldown expiry (inf unless tripped)
    probe_job: jax.Array  # i32[S] in-flight half-open probe job id (-1 = none)
    seen_failed: jax.Array  # i32[S] sites.n_failed at last scoring pass
    seen_done: jax.Array  # i32[S] sites.n_finished at last scoring pass
    # counters (conservation: transfers.n_enq == n_done + n_cancel + n_xfer_fail)
    n_xfer_fail: jax.Array  # i32 injected transfer failures
    n_xfer_retry: jax.Array  # i32 backoff re-enqueues that fired
    n_xfer_exhaust: jax.Array  # i32 stage-ins that ran out of attempts
    n_kills: jax.Array  # i32 walltime kills
    n_lost_replicas: jax.Array  # i32 replicas dropped by loss events
    n_bl_trips: jax.Array  # i32 circuit-breaker trips (incl. probe re-trips)
    n_probes: jax.Array  # i32 half-open probe jobs admitted
    time_lost: jax.Array  # f32 wall-seconds of failed/killed attempts


def make_faults(
    n_sites,
    job_capacity,
    *,
    link_fail_p=0.0,
    xfer_backoff: float = 60.0,
    max_xfer_attempts: int = 3,
    job_backoff: float = 0.0,
    walltime=None,
    replica_loss=(),
    blacklist_threshold: float | None = None,
    blacklist_alpha: float = 0.25,
    blacklist_cooldown: float = 3600.0,
) -> FaultState:
    """Build a fault-injection state (all channels off by default — the
    default state is bitstream-identical to ``faults=None``).

    ``n_sites`` also accepts a ``SiteState``/``NetworkState``;
    ``job_capacity`` also accepts a ``JobsState``.

    - ``link_fail_p``: scalar, full ``[S, S]`` matrix, or ``{(src, dst): p}``
      mapping of per-link transfer failure probabilities.
    - ``xfer_backoff`` / ``max_xfer_attempts``: transfer retry schedule
      (delay ``base * 2^attempt``; past the cap the job attempt fails).
    - ``job_backoff``: resubmission backoff base in seconds (0 = resubmit in
      the same round, the engine's historical behavior).
    - ``walltime``: scalar seconds or per-job ``f32[J]`` (None = no limit).
    - ``replica_loss``: iterable of ``(t, dataset, site)`` tuples (or dicts
      with those keys) — see :func:`~.workload.replica_loss_calendar`.
    - ``blacklist_threshold``: EWMA failure-score trip level in ``(0, 1]``;
      None disables the circuit breaker entirely (statically compiled out).
    """
    S = getattr(n_sites, "n_sites", None) or getattr(n_sites, "capacity", None) or int(n_sites)
    J = getattr(job_capacity, "capacity", None) or int(job_capacity)
    L = S * S

    if isinstance(link_fail_p, dict):
        mat = np.zeros((S, S), np.float32)
        for (src, dst), p in link_fail_p.items():
            mat[int(src), int(dst)] = float(p)
        p_flat = mat.reshape(L)
    else:
        arr = np.asarray(link_fail_p, np.float32)
        if arr.ndim == 0:
            p_flat = np.full((L,), float(arr), np.float32)
        elif arr.shape == (S, S):
            p_flat = arr.reshape(L)
        else:
            raise ValueError(f"link_fail_p matrix must be [S, S] = [{S}, {S}], got {arr.shape}")
    if np.any((p_flat < 0) | (p_flat > 1)):
        raise ValueError("link_fail_p probabilities must lie in [0, 1]")

    if walltime is None:
        wt = np.full((J,), np.inf, np.float32)
    else:
        arr = np.asarray(walltime, np.float32)
        wt = np.full((J,), float(arr), np.float32) if arr.ndim == 0 else arr
        if wt.shape != (J,):
            raise ValueError(f"walltime must be scalar or shape ({J},), got {arr.shape}")

    events = []
    for ev in replica_loss:
        if isinstance(ev, dict):
            events.append((float(ev["t"]), int(ev["dataset"]), int(ev["site"])))
        else:
            t, d, s = ev
            events.append((float(t), int(d), int(s)))
    events.sort()
    E = max(len(events), 1)
    loss_t = np.full((E,), np.inf, np.float32)
    loss_d = np.full((E,), -1, np.int32)
    loss_s = np.full((E,), -1, np.int32)
    for i, (t, d, s) in enumerate(events):
        if not 0 <= s < S:
            raise ValueError(f"replica_loss site {s} out of range [0, {S})")
        loss_t[i], loss_d[i], loss_s[i] = t, d, s

    thresh = np.inf if blacklist_threshold is None else float(blacklist_threshold)
    return FaultState(
        link_fail_p=jnp.asarray(p_flat),
        xfer_backoff=jnp.float32(xfer_backoff),
        max_xfer_attempts=jnp.int32(max_xfer_attempts),
        attempt=jnp.zeros((J,), jnp.int32),
        retry_at=jnp.full((J,), jnp.inf, jnp.float32),
        job_backoff=jnp.float32(job_backoff),
        backoff_wait=jnp.zeros((J,), jnp.float32),
        walltime=jnp.asarray(wt),
        loss_t=jnp.asarray(loss_t),
        loss_d=jnp.asarray(loss_d),
        loss_s=jnp.asarray(loss_s),
        loss_done=jnp.zeros((E,), bool),
        bl_threshold=jnp.float32(thresh),
        bl_alpha=jnp.float32(blacklist_alpha),
        bl_cooldown=jnp.float32(blacklist_cooldown),
        score=jnp.zeros((S,), jnp.float32),
        bl_state=jnp.zeros((S,), jnp.int32),
        bl_until=jnp.full((S,), jnp.inf, jnp.float32),
        probe_job=jnp.full((S,), -1, jnp.int32),
        seen_failed=jnp.zeros((S,), jnp.int32),
        seen_done=jnp.zeros((S,), jnp.int32),
        n_xfer_fail=jnp.int32(0),
        n_xfer_retry=jnp.int32(0),
        n_xfer_exhaust=jnp.int32(0),
        n_kills=jnp.int32(0),
        n_lost_replicas=jnp.int32(0),
        n_bl_trips=jnp.int32(0),
        n_probes=jnp.int32(0),
        time_lost=jnp.float32(0.0),
    )


# --------------------------------------------------------------------------
# channel 1 helper, called from transfers._tr_on_completions (static branch)
# --------------------------------------------------------------------------


def inject_transfer_failures(ctx, ts, fin, jobs):
    """Fail would-complete flows with per-link probability; schedule backoff
    retries (or, past the attempt cap, fail the staging job's attempt).

    Called by the transfer subsystem *before* releasing ``fin`` rows, so a
    failed flow never prices ``t_finish``, never lands a replica, and never
    counts as done.  Returns ``(fin', xfail, jobs')``: the surviving release
    mask, the injected-failure mask (the caller clears those rows and frees
    their link slots — each counts against ``n_xfer_fail`` in the ledger),
    and jobs with exhausted attempts routed onto the engine's retry path.
    """
    fs: FaultState = ctx.ext["faults"]
    J, L = ctx.J, ctx.S * ctx.S
    u = jax.random.uniform(ctx.subkey("faults"), (J,))
    xfail = fin & (u < fs.link_fail_p[jnp.clip(ts.link, 0, L - 1)])
    nxt = fs.attempt + 1
    exhaust = xfail & (nxt >= fs.max_xfer_attempts)
    retry = xfail & ~exhaust
    delay = fs.xfer_backoff * jnp.exp2(fs.attempt.astype(jnp.float32))
    ctx.ext["faults"] = fs._replace(
        attempt=jnp.where(exhaust, 0, jnp.where(retry, nxt, fs.attempt)),
        retry_at=jnp.where(retry, ctx.clock + delay, jnp.where(exhaust, INF, fs.retry_at)),
        backoff_wait=fs.backoff_wait + jnp.where(retry, delay, 0.0),
        n_xfer_fail=fs.n_xfer_fail + xfail.sum().astype(jnp.int32),
        n_xfer_exhaust=fs.n_xfer_exhaust + exhaust.sum().astype(jnp.int32),
    )
    # out of attempts: leave the staging gate as a failing attempt — next
    # round's completion step retires it through the normal resubmit path
    jobs = jobs._replace(
        will_fail=jobs.will_fail | exhaust,
        t_finish=jnp.where(exhaust, ctx.clock, jobs.t_finish),
    )
    ctx.progressed = ctx.progressed | xfail.any()
    return fin & ~xfail, xfail, jobs


# --------------------------------------------------------------------------
# Subsystem hooks
# --------------------------------------------------------------------------


def _fl_init(sub, state0, jobs, sites):
    if jobs is not None and state0.attempt.shape[-1] != jobs.capacity:
        raise ValueError(
            f"FaultState sized for {state0.attempt.shape[-1]} jobs, got "
            f"capacity {jobs.capacity}; build with make_faults(S, jobs)"
        )
    if sites is not None and state0.score.shape[-1] != sites.capacity:
        raise ValueError(
            f"FaultState sized for {state0.score.shape[-1]} sites, "
            f"got capacity {sites.capacity}"
        )
    return state0


def _fl_validate(sub, state0, jobs, sites):
    if sites is not None:
        S = sites.capacity
        if state0.link_fail_p.shape[-1] != S * S:
            raise ValueError(
                f"FaultState has {state0.link_fail_p.shape[-1]} links, "
                f"expected S*S = {S * S}"
            )
    if jobs is not None and state0.walltime.shape[-1] != jobs.capacity:
        raise ValueError(
            f"FaultState.walltime sized for {state0.walltime.shape[-1]} jobs, "
            f"got capacity {jobs.capacity}"
        )


def _fl_event_times(sub, ctx):
    """Backoff wake-ups, loss-event edges, cooldown expiries, and walltime
    deadlines all join the round clock — fault dynamics are exact events."""
    fs: FaultState = ctx.ext["faults"]
    t = jnp.minimum(fs.retry_at.min(), fs.bl_until.min())
    t = jnp.minimum(t, jnp.where(fs.loss_done, INF, fs.loss_t).min())
    kill = jnp.where(ctx.jobs.state == RUNNING, ctx.jobs.t_start + fs.walltime, INF)
    return jnp.minimum(t, kill.min())


def _fl_on_completions(sub, ctx):
    """Engine step 2b (last in canonical order): walltime kills, resubmission
    backoff, transfer-retry wake-ups, blacklist scoring/transitions, and
    replica-loss events."""
    from .engine import _site_sum

    fs: FaultState = ctx.ext["faults"]
    cfg: FaultsConfig = sub.config or FaultsConfig()
    jobs, sites, S, J = ctx.jobs, ctx.sites, ctx.S, ctx.J
    clock = ctx.clock

    # ---- time lost to failed attempts (engine completions this round) ----
    lost = jnp.where(ctx.failed_now, jnp.maximum(clock - jobs.t_start, 0.0), 0.0).sum()

    # ---- channel 2: resubmission backoff -------------------------------
    # rows the engine just requeued (failed_now & QUEUED — availability
    # preemptions are not in failed_now) go back to PENDING with a pushed
    # arrival; the engine's arrival min-reduction provides the wake event
    if cfg.job_backoff:
        resub = ctx.failed_now & (jobs.state == QUEUED)
        delay = fs.job_backoff * jnp.exp2(
            jnp.maximum(jobs.retries - 1, 0).astype(jnp.float32)
        )
        jobs = jobs._replace(
            state=jnp.where(resub, PENDING, jobs.state),
            arrival=jnp.where(resub, clock + delay, jobs.arrival),
        )
        fs = fs._replace(backoff_wait=fs.backoff_wait + jnp.where(resub, delay, 0.0))

    # ---- walltime kills -------------------------------------------------
    # completions already retired t_finish <= clock, so a job finishing at
    # its deadline still finishes; staging-gate jobs (t_finish = inf) are
    # killable like any other RUNNING job
    killed = (jobs.state == RUNNING) & (jobs.t_start + fs.walltime <= clock)
    kill_resub = killed & (jobs.retries < ctx.max_retries)
    kill_fail = killed & ~kill_resub
    kill_site = jnp.where(killed, jobs.site, S)
    if cfg.job_backoff:
        kdelay = fs.job_backoff * jnp.exp2(jobs.retries.astype(jnp.float32))
        new_state = jnp.where(kill_resub, PENDING, jnp.where(kill_fail, FAILED, jobs.state))
        new_arrival = jnp.where(kill_resub, clock + kdelay, jobs.arrival)
        fs = fs._replace(backoff_wait=fs.backoff_wait + jnp.where(kill_resub, kdelay, 0.0))
    else:
        new_state = jnp.where(kill_resub, QUEUED, jnp.where(kill_fail, FAILED, jobs.state))
        new_arrival = jobs.arrival
    jobs = jobs._replace(
        state=new_state,
        arrival=new_arrival,
        retries=jobs.retries + kill_resub.astype(jnp.int32),
        site=jnp.where(kill_resub, -1, jobs.site),
        t_finish=jnp.where(kill_resub, INF, jnp.where(kill_fail, clock, jobs.t_finish)),
        preempted=jobs.preempted + killed.astype(jnp.int32),
    )
    sites = sites._replace(
        free_cores=sites.free_cores + _site_sum(jnp.where(killed, jobs.cores, 0), kill_site, S),
        free_memory=sites.free_memory
        + _site_sum(jnp.where(killed, jobs.memory, 0.0), kill_site, S),
    )
    lost = lost + jnp.where(killed, jnp.maximum(clock - jobs.t_start, 0.0), 0.0).sum()
    fs = fs._replace(
        n_kills=fs.n_kills + killed.sum().astype(jnp.int32),
        time_lost=fs.time_lost + lost,
    )
    ctx.progressed = ctx.progressed | killed.any()

    # ---- channel 1: transfer retries & kill-side cancels ----------------
    if "transfers" in ctx.ext:
        from .transfers import T_ACTIVE, T_IDLE, _admit, _enqueue, _link_count, _reprice

        ts = ctx.ext["transfers"]
        dext = ctx.ext.get("data")
        L = S * S
        # a killed staging job abandons its flow now (the transfer
        # subsystem's own cancel sweep runs before this hook, so without
        # this the slot would stay occupied until the next event round)
        tr = killed & (ts.stat > T_IDLE)
        ts = ts._replace(
            stat=jnp.where(tr, T_IDLE, ts.stat),
            rem=jnp.where(tr, 0.0, ts.rem),
            t_done=jnp.where(tr, INF, ts.t_done),
            active=ts.active
            - _link_count(tr & (ts.stat == T_ACTIVE), jnp.clip(ts.link, 0, L - 1), L),
            n_cancel=ts.n_cancel + tr.sum().astype(jnp.int32),
            bytes_cancel=ts.bytes_cancel + jnp.where(tr, jobs.xfer_bytes, 0.0).sum(),
        )
        # a pending backoff retry whose job left the staging gate (killed,
        # preempted, cancelled, or exhausted) is dropped — its failure is
        # already on the ledger, so conservation holds without a re-enqueue
        orphan = jnp.isfinite(fs.retry_at) & (jobs.state != RUNNING)
        due = (fs.retry_at <= clock) & (jobs.state == RUNNING)
        # backoff expired: the full transfer restarts as a fresh ledger
        # attempt on the same link (resid/cache/link survive in the
        # transfer rows; rem resets to the full size)
        ts, _ = _enqueue(ts, due, ts.link, jobs.xfer_bytes, ts.resid, ts.cache, clock)
        fs = fs._replace(
            retry_at=jnp.where(due | orphan, INF, fs.retry_at),
            attempt=jnp.where(orphan, 0, fs.attempt),
            n_xfer_retry=fs.n_xfer_retry + due.sum().astype(jnp.int32),
        )
        if dext is not None:
            ts = _admit(ts, clock)
            ts = _reprice(ts, dext.network.bw.reshape(L), clock)
        ctx.ext["transfers"] = ts
        ctx.progressed = ctx.progressed | due.any() | tr.any()

    # ---- channel 4: blacklist scoring + circuit transitions -------------
    if cfg.blacklist:
        idx = jnp.arange(J, dtype=jnp.int32)
        kills_per_site = _site_sum(killed.astype(jnp.int32), kill_site, S)
        d_fail = (sites.n_failed - fs.seen_failed) + kills_per_site
        d_done = sites.n_finished - fs.seen_done
        n_ev = d_fail + d_done
        frac = d_fail.astype(jnp.float32) / jnp.maximum(n_ev, 1).astype(jnp.float32)
        score = jnp.where(
            n_ev > 0, fs.score + fs.bl_alpha * (frac - fs.score), fs.score
        )
        closed = fs.bl_state == BL_CLOSED
        tripped = fs.bl_state == BL_TRIPPED
        half = fs.bl_state == BL_HALF_OPEN
        trip = closed & (score >= fs.bl_threshold)
        expire = tripped & (fs.bl_until <= clock)
        # half-open probe resolution (states are disjoint, so the masks are)
        pj = jnp.clip(fs.probe_job, 0, J - 1)
        has = half & (fs.probe_job >= 0)
        p_succ = has & ctx.done_now[pj]
        p_fail = has & (ctx.failed_now[pj] | killed[pj])
        p_gone = has & ~p_succ & ~p_fail & (jobs.site[pj] != jnp.arange(S))
        retrip = trip | p_fail
        fs = fs._replace(
            score=jnp.where(p_succ, 0.0, score),
            bl_state=jnp.where(
                retrip,
                BL_TRIPPED,
                jnp.where(expire, BL_HALF_OPEN, jnp.where(p_succ, BL_CLOSED, fs.bl_state)),
            ),
            bl_until=jnp.where(retrip, clock + fs.bl_cooldown, jnp.where(expire | p_succ, INF, fs.bl_until)),
            probe_job=jnp.where(expire | p_succ | p_fail | p_gone, -1, fs.probe_job),
            seen_failed=sites.n_failed,
            seen_done=sites.n_finished,
            n_bl_trips=fs.n_bl_trips + retrip.sum().astype(jnp.int32),
        )
        # jobs queued at a newly tripped site bounce back to the server (no
        # attempt lost, no retry) so the half-open window admits exactly the
        # probe, not a backlog — mirrors the availability drain bounce
        bounce = (jobs.state == ASSIGNED) & trip[jnp.clip(jobs.site, 0, S - 1)]
        jobs = jobs._replace(
            state=jnp.where(bounce, QUEUED, jobs.state),
            site=jnp.where(bounce, -1, jobs.site),
        )
        ctx.progressed = (
            ctx.progressed | retrip.any() | expire.any() | p_succ.any() | bounce.any()
        )

    # ---- channel 3: replica-loss calendar -------------------------------
    due_loss = ~fs.loss_done & (fs.loss_t <= clock)
    dext = ctx.ext.get("data")
    if dext is not None:
        rep = dext.replicas
        D = rep.size.shape[-1]
        dd = jnp.where(due_loss, jnp.clip(fs.loss_d, 0, D - 1), D)
        ss = jnp.clip(fs.loss_s, 0, S - 1)
        hit = jnp.zeros((D, S), bool).at[dd, ss].set(True, mode="drop")
        org = jnp.clip(rep.origin, 0, S - 1)
        is_origin = (jnp.arange(S)[None, :] == org[:, None]) & (rep.origin >= 0)[:, None]
        dropped = hit & rep.present & ~is_origin  # pinned origins never drop
        ctx.ext["data"] = dext._replace(
            replicas=rep._replace(
                present=rep.present & ~dropped,
                disk_used=rep.disk_used - (dropped * rep.size[:, None]).sum(-2),
                last_access=jnp.where(dropped, -INF, rep.last_access),
            )
        )
        fs = fs._replace(
            n_lost_replicas=fs.n_lost_replicas + dropped.sum().astype(jnp.int32)
        )
        ctx.progressed = ctx.progressed | due_loss.any()
    fs = fs._replace(loss_done=fs.loss_done | due_loss)

    ctx.jobs = jobs
    ctx.sites = sites
    ctx.ext["faults"] = fs


def _fl_pre_assign(sub, ctx):
    """Remove tripped sites from feasibility (and zero their start budget);
    gate half-open sites down to a single probe candidate."""
    cfg: FaultsConfig = sub.config or FaultsConfig()
    if not cfg.blacklist:
        return
    fs: FaultState = ctx.ext["faults"]
    J = ctx.J
    tripped = fs.bl_state == BL_TRIPPED
    probe_ok = (fs.bl_state == BL_HALF_OPEN) & (fs.probe_job < 0)
    # probe candidate: the lowest queued job id — matches the engine's
    # start-order id tiebreak, so the probe is deterministic
    idx = jnp.arange(J, dtype=jnp.int32)
    queued = ctx.jobs.state == QUEUED
    cand = jnp.where(queued, idx, J).min()
    # note: the [J, S] probe gate expands a sparse top-k [1, S] site mask to
    # per-job feasibility — the assignment gather dispatches on the leading
    # dim, so this is correct (if heavier) under topk
    gate = (fs.bl_state == BL_CLOSED)[None, :] | (
        probe_ok[None, :] & (idx[:, None] == cand)
    )
    ctx.feasible = ctx.feasible & gate
    ctx.start_cores = jnp.where(tripped, 0, ctx.start_cores)


def _fl_on_start(sub, ctx):
    """Register half-open probes; reset transfer-attempt counters for jobs
    entering a fresh stage-in."""
    fs: FaultState = ctx.ext["faults"]
    cfg: FaultsConfig = sub.config or FaultsConfig()
    if cfg.blacklist:
        half_free = (fs.bl_state == BL_HALF_OPEN) & (fs.probe_job < 0)
        ps = ctx.started & half_free[ctx.site_c]
        tgt = jnp.where(ps, ctx.site_c, ctx.S)
        fs = fs._replace(
            probe_job=fs.probe_job.at[tgt].set(
                jnp.arange(ctx.J, dtype=jnp.int32), mode="drop"
            ),
            n_probes=fs.n_probes + ps.sum().astype(jnp.int32),
        )
    sc = ctx.scratch.get("transfers")
    if sc is not None:
        xfer = sc["xfer"]
        fs = fs._replace(
            attempt=jnp.where(xfer, 0, fs.attempt),
            retry_at=jnp.where(xfer, INF, fs.retry_at),
        )
    ctx.ext["faults"] = fs


def _fl_log_spec(sub, fs: FaultState, jobs, sites):
    S = fs.score.shape[-1]
    return {
        "site_fault_score": jnp.zeros((S,), jnp.float32),
        "site_blacklist": jnp.zeros((S,), jnp.int32),
    }


def _fl_log_columns(sub, ctx, write):
    fs: FaultState = ctx.ext["faults"]
    return {"site_fault_score": fs.score, "site_blacklist": fs.bl_state}


def _fl_pad_jobs(sub, fs: FaultState, old_cap: int, new_cap: int):
    n = new_cap - old_cap
    fills = {"attempt": 0, "retry_at": jnp.inf, "backoff_wait": 0.0, "walltime": jnp.inf}

    def pad(name, x):
        widths = [(0, 0)] * (x.ndim - 1) + [(0, n)]
        return jnp.pad(x, widths, constant_values=fills[name])

    return fs._replace(**{k: pad(k, getattr(fs, k)) for k in fills})


def faults_subsystem(state0: FaultState | None = None, *, job_backoff=None, blacklist=None):
    """The fault-injection engine plugin.  Initial state is a
    :class:`FaultState` from :func:`make_faults`.

    The static channel flags (``job_backoff``, ``blacklist`` — see
    :class:`FaultsConfig`) are derived host-side from ``state0`` when not
    given explicitly; pass them explicitly when building the subsystem for
    traced/stacked states (e.g. the explicit ``subsystems=`` ensemble path
    with per-lane fault configs).
    """
    from .subsystems import Subsystem

    if state0 is not None:
        if job_backoff is None:
            job_backoff = bool((np.asarray(jax.device_get(state0.job_backoff)) > 0).any())
        if blacklist is None:
            blacklist = bool(
                np.isfinite(np.asarray(jax.device_get(state0.bl_threshold))).any()
            )
    cfg = FaultsConfig(job_backoff=bool(job_backoff), blacklist=bool(blacklist))
    return Subsystem(
        name="faults",
        config=cfg,
        init=_fl_init,
        validate=_fl_validate,
        event_times=_fl_event_times,
        on_completions=_fl_on_completions,
        pre_assign=_fl_pre_assign,
        on_start=_fl_on_start,
        log_spec=_fl_log_spec,
        log_columns=_fl_log_columns,
        pad_jobs=_fl_pad_jobs,
    )
