"""Inter-site network topology — the data-movement half of CGSim's input layer.

The paper configures a network topology JSON next to the infrastructure JSON;
the seed reduced it to a flat per-site ingress/egress link.  This module
models the WAN properly: dense ``f32[S, S]`` bandwidth/latency matrices
(src -> dst), built from simple topology specs (star hub, tiered/fat-tree-ish,
or an explicit matrix), plus per-round equal-share bandwidth allocation among
concurrent transfers on the same directed link (DESIGN.md §3).

Everything is dense masked algebra so the engine stays jit/vmap-safe: a round
that starts T transfers computes every transfer's effective bandwidth in one
segment-sum over flattened link ids.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

LOCAL_BW = 1e15  # bytes/s stand-in for "no WAN hop" (same-site read)


class NetworkState(NamedTuple):
    """Directed inter-site link matrices over the site capacity S.

    ``bw[src, dst]`` is the bottleneck bandwidth of the src->dst path in
    bytes/s; the diagonal is the intra-site (LAN) path and should be fast
    enough to make local reads effectively free.
    """

    bw: jax.Array       # f32[S, S] bytes/s
    latency: jax.Array  # f32[S, S] seconds

    @property
    def n_sites(self) -> int:
        return self.bw.shape[-1]


def _finalize(bw, latency, local_bw, local_latency):
    S = bw.shape[0]
    eye = jnp.eye(S, dtype=bool)
    bw = jnp.where(eye, jnp.float32(local_bw), bw.astype(jnp.float32))
    latency = jnp.where(eye, jnp.float32(local_latency), latency.astype(jnp.float32))
    return NetworkState(bw=bw, latency=latency)


def matrix_network(bw, latency, *, local_bw: float = LOCAL_BW, local_latency: float = 0.0) -> NetworkState:
    """Explicit-topology spec: full [S, S] matrices (CGSim network JSON)."""
    bw = jnp.asarray(bw, jnp.float32)
    latency = jnp.asarray(latency, jnp.float32)
    if bw.shape != latency.shape or bw.ndim != 2 or bw.shape[0] != bw.shape[1]:
        raise ValueError(f"need square [S,S] matrices, got {bw.shape} / {latency.shape}")
    return _finalize(bw, latency, local_bw, local_latency)


def uniform_network(n_sites: int, *, bw: float = 1.25e9, latency: float = 0.02) -> NetworkState:
    """Every site pair connected at the same bandwidth/latency."""
    S = n_sites
    return _finalize(
        jnp.full((S, S), bw, jnp.float32), jnp.full((S, S), latency, jnp.float32), LOCAL_BW, 0.0
    )


def star_network(
    bw_up, bw_down=None, latency=None, *, hub_latency: float = 0.0
) -> NetworkState:
    """Star topology: every transfer crosses a central hub (LHCONE-style).

    src->dst bandwidth is the bottleneck ``min(bw_up[src], bw_down[dst])``;
    latency adds both access legs plus the hub."""
    bw_up = jnp.asarray(bw_up, jnp.float32)
    bw_down = bw_up if bw_down is None else jnp.asarray(bw_down, jnp.float32)
    S = bw_up.shape[0]
    lat = jnp.zeros((S,), jnp.float32) if latency is None else jnp.asarray(latency, jnp.float32)
    bw = jnp.minimum(bw_up[:, None], bw_down[None, :])
    lat2 = lat[:, None] + lat[None, :] + jnp.float32(hub_latency)
    return _finalize(bw, lat2, LOCAL_BW, 0.0)


def tiered_network(
    tier, tier_bw, *, tier_latency: float = 0.01
) -> NetworkState:
    """Fat-tree-ish tiers (WLCG T0/T1/T2): a transfer between sites of tiers
    (a, b) bottlenecks on the thinner tier's uplink ``tier_bw[max(a, b)]`` and
    pays one latency hop per tier level crossed up to the common root."""
    tier = jnp.asarray(tier, jnp.int32)
    tier_bw = jnp.asarray(tier_bw, jnp.float32)
    hi = jnp.maximum(tier[:, None], tier[None, :])
    bw = tier_bw[jnp.clip(hi, 0, tier_bw.shape[0] - 1)]
    hops = (tier[:, None] + tier[None, :] + 2).astype(jnp.float32)
    return _finalize(bw, hops * jnp.float32(tier_latency), LOCAL_BW, 0.0)


def network_from_sites(sites) -> NetworkState:
    """Derive a star WAN from a ``SiteState``'s flat per-site links — the
    drop-in upgrade path for existing platforms (egress bottleneck at the
    source, ingress at the destination)."""
    return star_network(sites.bw_out, sites.bw_in, sites.latency)


def with_bandwidth(net: NetworkState, bw) -> NetworkState:
    """Replace the WAN (off-diagonal) bandwidths of ``net`` with ``bw``.

    The intra-site diagonal is preserved from ``net`` — calibration treats
    the ``f32[S, S]`` bandwidth matrix as a free parameter, but the LAN path
    must stay effectively infinite regardless of the candidate values.
    """
    bw = jnp.asarray(bw, jnp.float32)
    if bw.shape != net.bw.shape:
        raise ValueError(f"bandwidth shape {bw.shape} != {net.bw.shape}")
    eye = jnp.eye(net.bw.shape[-1], dtype=bool)
    return net._replace(bw=jnp.where(eye, net.bw, bw))


def atlas_like_network(n_sites: int, *, seed: int = 0, capacity: int | None = None) -> NetworkState:
    """WLCG-flavoured random topology matching ``atlas_like_platform``:
    ~10% Tier-1 sites on fat links, the rest on 1-10 Gbps access links."""
    rng = np.random.default_rng(seed)
    cap = capacity or n_sites
    gb = 1e9 / 8
    tier = np.full(cap, 2, np.int32)
    tier[rng.choice(n_sites, size=max(1, n_sites // 10), replace=False)] = 1
    tier_bw = np.array([400.0, 100.0, 10.0]) * gb
    net = tiered_network(tier, tier_bw, tier_latency=0.015)
    jitter = rng.lognormal(0.0, 0.25, size=(cap, cap)).astype(np.float32)
    bw = np.asarray(net.bw) * jitter
    np.fill_diagonal(bw, LOCAL_BW)
    return NetworkState(bw=jnp.asarray(bw), latency=net.latency)


# --------------------------------------------------------------------------
# flattened directed-link helpers (the transfer-queue subsystem's index space)
# --------------------------------------------------------------------------


def link_index(src, dst, n_sites: int):
    """Flattened directed-link id ``src * S + dst`` — the index space shared
    by ``link_shares`` and the transfer-queue subsystem's per-link state."""
    return jnp.asarray(src, jnp.int32) * n_sites + jnp.asarray(dst, jnp.int32)


def link_caps(n_sites: int, default: int, overrides=None) -> jax.Array:
    """Per-link concurrent-transfer caps as a flat ``i32[S*S]`` vector.

    ``default`` applies to every directed link; ``overrides`` is either a
    full ``[S, S]`` matrix replacing it outright or a ``{(src, dst): cap}``
    mapping patching individual links (FTS-style per-channel limits).
    """
    S = n_sites
    if overrides is not None and not isinstance(overrides, dict):
        caps = np.asarray(overrides, np.int32)
        if caps.shape != (S, S):
            raise ValueError(f"link cap matrix must be [{S},{S}], got {caps.shape}")
        return jnp.asarray(caps.reshape(-1))
    caps = np.full((S, S), int(default), np.int32)
    for (src, dst), c in (overrides or {}).items():
        caps[src, dst] = int(c)
    return jnp.asarray(caps.reshape(-1))


# --------------------------------------------------------------------------
# per-round bandwidth sharing
# --------------------------------------------------------------------------


def link_shares(net: NetworkState, src: jax.Array, dst: jax.Array, active: jax.Array) -> jax.Array:
    """Number of concurrent ``active`` transfers on each transfer's directed
    link (>= 1 for active rows) — the equal-share divisor."""
    S = net.n_sites
    link = jnp.where(active, src * S + dst, S * S)
    counts = jax.ops.segment_sum(
        active.astype(jnp.int32), link, num_segments=S * S + 1
    )[: S * S]
    return jnp.maximum(counts[jnp.clip(link, 0, S * S - 1)], 1).astype(jnp.float32)


def shared_transfer_times(
    net: NetworkState, src: jax.Array, dst: jax.Array, nbytes: jax.Array, active: jax.Array
):
    """Transfer duration for each row under equal-share link allocation.

    Returns ``(t, bw_eff)``: duration (0 for inactive rows) and the per-flow
    effective bandwidth.  Conservation: the bw_eff of the flows on one
    directed link sums to exactly that link's capacity.
    """
    share = link_shares(net, src, dst, active)
    bw_eff = net.bw[src, dst] / share
    t = net.latency[src, dst] + nbytes / jnp.maximum(bw_eff, 1e-9)
    return jnp.where(active, t, 0.0), jnp.where(active, bw_eff, 0.0)
