"""Calibration framework (paper §4.2, Fig. 1c, Fig. 3).

The paper replays historical PanDA jobs with their *real* site assignments and
tunes per-site CPU speed (the dominant sensitivity) to minimize
``Δexe_time = Sim_exe_time − His_exe_time``.  Four optimizers are compared:
brute force, random sampling, Bayesian optimization, CMA-ES; random search
wins on their landscape.  All four are implemented here, pure JAX.

Two objective paths, which agree exactly in pinned-replay mode (tested):

* ``closed_form_walltimes`` — service-time model evaluated directly (fast path
  for walltime-only calibration, what the paper's Fig. 3 measures);
* ``engine_objective`` — full simulation via ``engine.simulate`` with a
  pinned-assignment policy (needed once queue-time modelling is included).

Beyond the paper: per-site error decomposition lets random/grid search select
the best candidate *per site* from one vmapped candidate batch — turning a
K-candidate x S-site search into an embarrassingly parallel single pass.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .engine import simulate
from .policies import make_policy
from .types import DONE, JobsState, SiteState

# --------------------------------------------------------------------------
# ground truth + objective
# --------------------------------------------------------------------------


def closed_form_walltimes(jobs: JobsState, sites: SiteState, site: jax.Array) -> jax.Array:
    """Walltime of each job if executed at ``site`` (no queueing, unit bw share).

    Matches ``engine.service_time`` with share=1, which is what a pinned
    replay converges to for walltime (queue time is separate, as in the paper).
    """
    s = jnp.clip(site, 0, sites.capacity - 1)
    c = jobs.cores.astype(jnp.float32)
    gamma = sites.par_gamma[s]
    speedup = c / (1.0 + gamma * jnp.maximum(c - 1.0, 0.0))
    return (
        sites.latency[s]
        + jobs.bytes_in / sites.bw_in[s]
        + jobs.work / (sites.speed[s] * jnp.maximum(speedup, 1e-9))
        + jobs.bytes_out / sites.bw_out[s]
    )


def per_site_rel_mae(
    jobs: JobsState,
    hist_site: jax.Array,
    hist_wall: jax.Array,
    sim_wall: jax.Array,
    n_sites: int,
) -> jax.Array:
    """Relative MAE per (site, job-class) — Fig. 3's metric.

    Returns f32[n_sites, 2]: column 0 single-core, column 1 multicore.
    Sites with no jobs of a class get 0 (excluded from geomeans by mask).
    """
    rel = jnp.abs(sim_wall - hist_wall) / jnp.maximum(hist_wall, 1e-9)
    multi = jobs.cores > 1
    seg = jnp.where(jobs.valid, hist_site, n_sites)

    def cls_mae(mask):
        num = jax.ops.segment_sum(jnp.where(mask, rel, 0.0), seg, num_segments=n_sites + 1)[:n_sites]
        den = jax.ops.segment_sum(mask.astype(jnp.float32), seg, num_segments=n_sites + 1)[:n_sites]
        return num / jnp.maximum(den, 1.0), den > 0

    mae_s, has_s = cls_mae(jobs.valid & ~multi)
    mae_m, has_m = cls_mae(jobs.valid & multi)
    return jnp.stack([mae_s, mae_m], axis=-1), jnp.stack([has_s, has_m], axis=-1)


def geomean_error(mae: jax.Array, has: jax.Array) -> jax.Array:
    """Geometric mean of per-(site, class) relative MAE over populated cells."""
    logs = jnp.where(has, jnp.log(jnp.maximum(mae, 1e-9)), 0.0)
    n = jnp.maximum(has.sum(), 1)
    return jnp.exp(logs.sum() / n)


class CalibProblem(NamedTuple):
    jobs: JobsState
    sites0: SiteState       # platform with the *misconfigured* initial speeds
    hist_site: jax.Array    # i32[J] historical assignment (PanDA replay)
    hist_wall: jax.Array    # f32[J] ground-truth walltime
    n_sites: int


def make_synthetic_problem(
    jobs: JobsState,
    sites: SiteState,
    *,
    seed: int = 0,
    misconfig_sigma: float = 0.75,
    noise_sigma: float = 0.15,
) -> CalibProblem:
    """Build a Fig.-3-style problem: hidden true speeds produce "historical"
    walltimes (log-normal measurement noise); the platform is then
    misconfigured by ``misconfig_sigma`` in log-space (≈76% initial error)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    S = sites.capacity
    active = sites.active
    # historical assignment: PanDA-ish weighted by capacity
    w = jnp.where(active, sites.cores.astype(jnp.float32), 0.0)
    hist_site = jax.random.categorical(
        k1, jnp.log(jnp.maximum(w, 1e-9))[None, :].repeat(jobs.capacity, 0)
    ).astype(jnp.int32)
    true_sites = sites
    wall = closed_form_walltimes(jobs, true_sites, hist_site)
    wall = wall * jnp.exp(noise_sigma * jax.random.normal(k2, wall.shape))
    bad_speed = sites.speed * jnp.exp(misconfig_sigma * jax.random.normal(k3, (S,)))
    return CalibProblem(
        jobs=jobs,
        sites0=sites._replace(speed=bad_speed),
        hist_site=hist_site,
        hist_wall=wall,
        n_sites=S,
    )


def closed_form_objective(problem: CalibProblem, speeds: jax.Array):
    """err[S,2], has[S,2], geomean for one speed vector (fast path)."""
    sites = problem.sites0._replace(speed=speeds)
    sim_wall = closed_form_walltimes(problem.jobs, sites, problem.hist_site)
    mae, has = per_site_rel_mae(
        problem.jobs, problem.hist_site, problem.hist_wall, sim_wall, problem.sites0.capacity
    )
    return mae, has, geomean_error(mae, has)


def pinned_policy(hist_site: jax.Array):
    """Replay policy: every job scores +1 only at its historical site."""

    def score(jobs, sites, state, clock, rng):
        S = sites.capacity
        return (jnp.arange(S)[None, :] == hist_site[:, None]).astype(jnp.float32)

    return make_policy("pinned_replay", score)


def engine_objective(problem: CalibProblem, speeds: jax.Array, *, max_rounds: int = 60_000):
    """Full-engine objective (includes queueing): geomean rel-MAE of walltime."""
    sites = problem.sites0._replace(speed=speeds)
    res = simulate(
        problem.jobs, sites, pinned_policy(problem.hist_site), jax.random.PRNGKey(0),
        max_rounds=max_rounds,
    )
    sim_wall = jnp.where(res.jobs.state == DONE, res.jobs.t_finish - res.jobs.t_start, 0.0)
    mae, has = per_site_rel_mae(
        problem.jobs, problem.hist_site, problem.hist_wall, sim_wall, problem.sites0.capacity
    )
    return mae, has, geomean_error(mae, has)


# --------------------------------------------------------------------------
# optimizer 1/2: brute-force grid + random search (paper's winner)
# --------------------------------------------------------------------------


class CalibResult(NamedTuple):
    speeds: jax.Array        # f32[S] calibrated speeds
    err0: jax.Array          # geomean error before
    err: jax.Array           # geomean error after
    history: jax.Array       # f32[iters] best-so-far geomean per iteration


@functools.partial(jax.jit, static_argnames=("n_points", "log_range"))
def grid_search(problem: CalibProblem, *, n_points: int = 64, log_range: float = 2.0) -> CalibResult:
    """Brute force (paper: "theoretically optimal but infeasible" jointly).

    Feasible here because the walltime objective decomposes per site: sweep a
    per-site 1-D grid in log-space and take each site's argmin independently.
    """
    _, _, err0 = closed_form_objective(problem, problem.sites0.speed)
    grid = jnp.exp(jnp.linspace(-log_range, log_range, n_points))  # multiplicative

    def eval_one(mult):
        mae, has, _ = closed_form_objective(problem, problem.sites0.speed * mult)
        return jnp.where(has, mae, jnp.inf).mean(-1)  # [S] mean over classes

    errs = jax.vmap(eval_one)(grid)  # [n_points, S]
    best = jnp.argmin(errs, axis=0)
    speeds = problem.sites0.speed * grid[best]
    _, _, err = closed_form_objective(problem, speeds)
    hist = jax.lax.cummin(jnp.min(errs, axis=1))
    return CalibResult(speeds=speeds, err0=err0, err=err, history=hist)


@functools.partial(jax.jit, static_argnames=("n_iters", "pop", "per_site"))
def random_search(
    problem: CalibProblem,
    rng: jax.Array,
    *,
    n_iters: int = 30,
    pop: int = 32,
    sigma0: float = 0.8,
    shrink: float = 0.88,
    per_site: bool = True,
) -> CalibResult:
    """Log-normal random search around the incumbent with shrinking step size.

    ``per_site=True`` is the beyond-paper accelerator: each site independently
    adopts the candidate that minimizes *its own* error, valid because the
    walltime objective is separable across sites.
    """
    _, _, err0 = closed_form_objective(problem, problem.sites0.speed)

    def step(carry, key):
        speeds, sigma = carry
        noise = jax.random.normal(key, (pop, speeds.shape[0]))
        cands = speeds[None, :] * jnp.exp(sigma * noise)
        cands = jnp.concatenate([speeds[None, :], cands], 0)

        def eval_one(sp):
            mae, has, ge = closed_form_objective(problem, sp)
            site_err = jnp.where(has, mae, 0.0).sum(-1) / jnp.maximum(has.sum(-1), 1)
            site_err = jnp.where(has.any(-1), site_err, jnp.inf)
            return site_err, ge

        site_errs, ges = jax.vmap(eval_one)(cands)  # [pop+1, S], [pop+1]
        if per_site:
            pick = jnp.argmin(site_errs, axis=0)  # [S]
            new = cands[pick, jnp.arange(speeds.shape[0])]
        else:
            new = cands[jnp.argmin(ges)]
        _, _, ge_new = closed_form_objective(problem, new)
        return (new, sigma * shrink), ge_new

    keys = jax.random.split(rng, n_iters)
    (speeds, _), hist = jax.lax.scan(step, (problem.sites0.speed, jnp.float32(sigma0)), keys)
    _, _, err = closed_form_objective(problem, speeds)
    return CalibResult(speeds=speeds, err0=err0, err=err, history=jax.lax.cummin(hist))


# --------------------------------------------------------------------------
# optimizer 3: CMA-ES (Hansen 2016), in log-speed space
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_iters", "pop"))
def cma_es(
    problem: CalibProblem,
    rng: jax.Array,
    *,
    n_iters: int = 60,
    pop: int = 0,
    sigma0: float = 0.5,
) -> CalibResult:
    import math

    S = problem.sites0.speed.shape[0]
    n = S
    lam = pop or int(4 + 3 * math.log(n))
    lam = max(lam, 8)
    mu = lam // 2
    w = jnp.log(mu + 0.5) - jnp.log(jnp.arange(1, mu + 1))
    w = w / w.sum()
    mueff = 1.0 / (w**2).sum()
    cc = (4 + mueff / n) / (n + 4 + 2 * mueff / n)
    cs = (mueff + 2) / (n + mueff + 5)
    c1 = 2 / ((n + 1.3) ** 2 + mueff)
    cmu = jnp.minimum(1 - c1, 2 * (mueff - 2 + 1 / mueff) / ((n + 2) ** 2 + mueff))
    damps = 1 + 2 * jnp.maximum(0.0, jnp.sqrt((mueff - 1) / (n + 1)) - 1) + cs
    chiN = jnp.sqrt(n) * (1 - 1 / (4 * n) + 1 / (21 * n * n))

    _, _, err0 = closed_form_objective(problem, problem.sites0.speed)
    m0 = jnp.log(problem.sites0.speed)

    def f(logsp):
        _, _, ge = closed_form_objective(problem, jnp.exp(logsp))
        return ge

    def step(carry, key):
        m, sigma, C, pc, ps = carry
        # sample
        evals, evecs = jnp.linalg.eigh(C + 1e-10 * jnp.eye(n))
        D = jnp.sqrt(jnp.maximum(evals, 1e-12))
        z = jax.random.normal(key, (lam, n))
        y = (z * D[None, :]) @ evecs.T
        x = m[None, :] + sigma * y
        fx = jax.vmap(f)(x)
        idx = jnp.argsort(fx)[:mu]
        y_sel = y[idx]
        y_w = (w[:, None] * y_sel).sum(0)
        m_new = m + sigma * y_w
        # step-size path
        C_inv_sqrt = evecs @ jnp.diag(1.0 / D) @ evecs.T
        ps_new = (1 - cs) * ps + jnp.sqrt(cs * (2 - cs) * mueff) * (C_inv_sqrt @ y_w)
        hsig = (jnp.linalg.norm(ps_new) / jnp.sqrt(1 - (1 - cs) ** 2) / chiN) < (1.4 + 2 / (n + 1))
        pc_new = (1 - cc) * pc + hsig * jnp.sqrt(cc * (2 - cc) * mueff) * y_w
        C_new = (
            (1 - c1 - cmu) * C
            + c1 * (jnp.outer(pc_new, pc_new) + (1 - hsig) * cc * (2 - cc) * C)
            + cmu * (w[:, None, None] * (y_sel[:, :, None] * y_sel[:, None, :])).sum(0)
        )
        sigma_new = sigma * jnp.exp((cs / damps) * (jnp.linalg.norm(ps_new) / chiN - 1))
        return (m_new, sigma_new, C_new, pc_new, ps_new), fx.min()

    keys = jax.random.split(rng, n_iters)
    init = (m0, jnp.float32(sigma0), jnp.eye(n), jnp.zeros(n), jnp.zeros(n))
    (m, *_), hist = jax.lax.scan(step, init, keys)
    speeds = jnp.exp(m)
    _, _, err = closed_form_objective(problem, speeds)
    return CalibResult(speeds=speeds, err0=err0, err=err, history=jax.lax.cummin(hist))


# --------------------------------------------------------------------------
# optimizer 4: GP-UCB Bayesian optimization (lightweight, exact-GP)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_iters", "n_init", "n_cand"))
def gp_bo(
    problem: CalibProblem,
    rng: jax.Array,
    *,
    n_iters: int = 48,
    n_init: int = 16,
    n_cand: int = 256,
    lengthscale: float = 1.0,
    beta: float = 2.0,
) -> CalibResult:
    """GP-UCB over log-speeds.  Exact GP (Cholesky) on a fixed-size buffer —
    the paper's BO baseline at the scale its experiments used (≤ a few hundred
    evaluations over 50 sites)."""
    S = problem.sites0.speed.shape[0]
    T = n_init + n_iters
    m0 = jnp.log(problem.sites0.speed)
    _, _, err0 = closed_form_objective(problem, problem.sites0.speed)

    def f(logsp):
        _, _, ge = closed_form_objective(problem, jnp.exp(logsp))
        return ge

    k_init, k_loop = jax.random.split(rng)
    X0 = m0[None, :] + 0.6 * jax.random.normal(k_init, (n_init, S))
    y0 = jax.vmap(f)(X0)
    X = jnp.zeros((T, S)).at[:n_init].set(X0)
    y = jnp.full((T,), 1e6).at[:n_init].set(y0)

    def kern(a, b):
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        return jnp.exp(-0.5 * d2 / lengthscale**2)

    def step(carry, key):
        X, y, t = carry
        mask = jnp.arange(T) < t
        ymu = jnp.where(mask, y, 0.0).sum() / jnp.maximum(mask.sum(), 1)
        yc = jnp.where(mask, y - ymu, 0.0)
        K = kern(X, X) * (mask[:, None] & mask[None, :]) + jnp.eye(T) * (
            1e-4 + (~mask) * 1e6
        )
        L = jnp.linalg.cholesky(K)
        alpha = jax.scipy.linalg.cho_solve((L, True), yc)
        # candidates around the incumbent
        best_idx = jnp.argmin(jnp.where(mask, y, jnp.inf))
        kc, ks = jax.random.split(key)
        scale = jax.random.uniform(ks, (n_cand, 1), minval=0.05, maxval=0.8)
        cand = X[best_idx][None, :] + scale * jax.random.normal(kc, (n_cand, S))
        Kc = kern(cand, X) * mask[None, :]
        mu = Kc @ alpha + ymu
        v = jax.scipy.linalg.solve_triangular(L, Kc.T, lower=True)
        var = jnp.maximum(1.0 - (v**2).sum(0), 1e-9)
        ucb = mu - beta * jnp.sqrt(var)  # minimize ⇒ lower confidence bound
        x_new = cand[jnp.argmin(ucb)]
        y_new = f(x_new)
        X = X.at[t].set(x_new)
        y = y.at[t].set(y_new)
        return (X, y, t + 1), jnp.minimum(y_new, jnp.where(mask, y, jnp.inf).min())

    keys = jax.random.split(k_loop, n_iters)
    (X, y, _), hist = jax.lax.scan(step, (X, y, jnp.int32(n_init)), keys)
    best = jnp.argmin(y)
    speeds = jnp.exp(X[best])
    _, _, err = closed_form_objective(problem, speeds)
    return CalibResult(speeds=speeds, err0=err0, err=err, history=jax.lax.cummin(hist))


OPTIMIZERS: dict[str, Callable] = {
    "grid": grid_search,
    "random": random_search,
    "cma_es": cma_es,
    "gp_bo": gp_bo,
}


def calibrate(problem: CalibProblem, method: str = "random", seed: int = 0, **kw) -> CalibResult:
    if method == "grid":
        return grid_search(problem, **kw)
    return OPTIMIZERS[method](problem, jax.random.PRNGKey(seed), **kw)


# ==========================================================================
# ensemble-scale platform calibration (ISSUE 7 / ROADMAP "differentiable
# calibration at ensemble scale"): the full continuous knob set — per-site
# speeds, the WAN bandwidth matrix, per-site startup overheads — as one flat
# params pytree, scored against a recorded trace with the whole candidate
# population packed into ensemble lanes of a single compiled program.
# ==========================================================================


PARAM_FIELDS = ("speed", "bw", "overhead")
_EPS = 1e-12


class PlatformParams(NamedTuple):
    """Continuous platform knobs as one flat pytree.

    ``None`` fields are excluded from the search — ``ravel_pytree`` drops
    them and restores them on unravel, so every fitter works on any knob
    subset with no special-casing.  The ``bw`` diagonal (intra-site LAN) is
    inert: ``apply_platform_params`` preserves the platform's own diagonal.
    """

    speed: jax.Array | None = None     # f32[S]   per-site CPU speed
    bw: jax.Array | None = None        # f32[S,S] WAN bandwidth, bytes/s
    overhead: jax.Array | None = None  # f32[S]   per-site startup overhead, s


class PlatformBounds(NamedTuple):
    """Box bounds (same treedef as the params) for the log-space search."""

    lo: PlatformParams
    hi: PlatformParams


def default_bounds(params: PlatformParams, *, factor: float = 30.0) -> PlatformBounds:
    """Multiplicative box around the starting point: [p/factor, p*factor]."""
    return PlatformBounds(
        lo=jax.tree.map(lambda x: x / factor, params),
        hi=jax.tree.map(lambda x: x * factor, params),
    )


def encode_params(params: PlatformParams, bounds: PlatformBounds) -> PlatformParams:
    """Params -> unconstrained-ish log space (clipped into the box first)."""
    return jax.tree.map(
        lambda p, lo, hi: jnp.log(
            jnp.clip(p, jnp.maximum(lo, _EPS), jnp.maximum(hi, _EPS))
        ),
        params, bounds.lo, bounds.hi,
    )


def decode_params(z: PlatformParams, bounds: PlatformBounds) -> PlatformParams:
    """Log space -> params.  The clip *guarantees* every decoded candidate —
    hence every ``calibrate_platform`` result — lies inside the declared
    bounds, no matter what the optimizer proposes (property-tested)."""
    return jax.tree.map(
        lambda z_, lo, hi: jnp.clip(jnp.exp(z_), lo, hi), z, bounds.lo, bounds.hi
    )


class PlatformProblem(NamedTuple):
    """Trace-matching problem over the full platform knob set.

    Generalizes ``CalibProblem`` (speed-only) with the WAN matrix and
    startup overheads, plus the per-job transfer columns a recorded trace
    pins down: ``hist_src[j]`` is the replica source of job ``j``'s stage-in
    (−1 = flat-link stage-in, no WAN hop) and ``hist_bytes[j]`` the bytes it
    moved (0 for local replica reads).  ``hist_wall[j] <= 0`` marks jobs the
    trace did not cover; they drop out of the mape/quantile losses.

    ``data_policy``/``replicas``/``availability`` describe the scenario for
    the exact-engine objective; the closed form ignores them.
    """

    jobs: JobsState
    sites0: SiteState             # platform at the *misconfigured* start
    network0: object = None       # NetworkState | None
    hist_site: jax.Array = None   # i32[J]
    hist_wall: jax.Array = None   # f32[J]
    hist_src: jax.Array = None    # i32[J] | None
    hist_bytes: jax.Array = None  # f32[J] | None
    data_policy: object = None
    replicas: object = None
    availability: object = None

    @property
    def n_sites(self) -> int:
        return self.sites0.capacity


def platform_params(
    problem: PlatformProblem, include=PARAM_FIELDS
) -> PlatformParams:
    """The problem's starting point as a params pytree (``None`` = excluded)."""
    return PlatformParams(
        speed=problem.sites0.speed if "speed" in include else None,
        bw=(
            problem.network0.bw
            if "bw" in include and problem.network0 is not None
            else None
        ),
        overhead=problem.sites0.latency if "overhead" in include else None,
    )


def apply_platform_params(problem: PlatformProblem, params: PlatformParams):
    """Materialize one candidate as ``(SiteState, NetworkState | None)``."""
    from .network import with_bandwidth
    from .platform import apply_site_params

    sites = apply_site_params(
        problem.sites0, speed=params.speed, latency=params.overhead
    )
    net = problem.network0
    if params.bw is not None:
        if net is None:
            raise ValueError("bw params need a problem.network0 topology")
        net = with_bandwidth(net, params.bw)
    return sites, net


def platform_walltimes(problem: PlatformProblem, params: PlatformParams) -> jax.Array:
    """Differentiable closed-form walltime under one candidate.

    Mirrors the engine's data pricing (``datapolicies._data_on_start``) at
    unit link share: jobs with a WAN stage-in (``hist_src >= 0``) swap the
    flat latency + stage-in terms for the recorded transfer — latency plus
    bytes over the candidate's ``bw[src, dst]`` link, and nothing at all for
    local replica reads (``hist_bytes == 0`` or ``src == dst``).
    """
    sites, net = apply_platform_params(problem, params)
    wall = closed_form_walltimes(problem.jobs, sites, problem.hist_site)
    if net is None or problem.hist_src is None:
        return wall
    S = problem.sites0.capacity
    s = jnp.clip(problem.hist_site, 0, S - 1)
    src = jnp.clip(problem.hist_src, 0, S - 1)
    has_ds = problem.hist_src >= 0
    nbytes = (
        problem.hist_bytes if problem.hist_bytes is not None else problem.jobs.bytes_in
    )
    in_flat = sites.latency[s] + problem.jobs.bytes_in / sites.bw_in[s]
    xfer = has_ds & (nbytes > 0) & (src != s)
    t_net = jnp.where(
        xfer, net.latency[src, s] + nbytes / jnp.maximum(net.bw[src, s], _EPS), 0.0
    )
    return jnp.where(has_ds, wall - in_flat + t_net, wall)


# --------------------------------------------------------------------------
# trace losses
# --------------------------------------------------------------------------

_QUANTILES = jnp.linspace(0.1, 0.9, 9)
TRACE_LOSSES = ("mape", "quantile", "geomean")


def trace_loss(sim_wall, hist_wall, mask, *, loss: str = "mape") -> jax.Array:
    """Scalar distance between simulated and recorded walltimes.

    ``mape``: mean |sim − hist| / hist over covered jobs (Fig. 3's Δexe_time
    flavour).  ``quantile``: mean relative gap between the walltime deciles —
    distribution matching that tolerates per-job noise.
    """
    if loss == "mape":
        rel = jnp.abs(sim_wall - hist_wall) / jnp.maximum(hist_wall, 1e-9)
        return jnp.where(mask, rel, 0.0).sum() / jnp.maximum(mask.sum(), 1)
    if loss == "quantile":
        q_sim = jnp.nanquantile(jnp.where(mask, sim_wall, jnp.nan), _QUANTILES)
        q_his = jnp.nanquantile(jnp.where(mask, hist_wall, jnp.nan), _QUANTILES)
        return jnp.mean(jnp.abs(q_sim - q_his) / jnp.maximum(q_his, 1e-9))
    raise ValueError(f"unknown loss {loss!r}; have {TRACE_LOSSES}")


def _score_walltimes(problem: PlatformProblem, sim_wall, loss: str) -> jax.Array:
    if loss == "geomean":
        mae, has = per_site_rel_mae(
            problem.jobs, problem.hist_site, problem.hist_wall, sim_wall,
            problem.sites0.capacity,
        )
        return geomean_error(mae, has)
    mask = problem.jobs.valid & (problem.hist_wall > 0)
    return trace_loss(sim_wall, problem.hist_wall, mask, loss=loss)


def platform_objective(
    problem: PlatformProblem, params: PlatformParams, *, loss: str = "mape"
) -> jax.Array:
    """Closed-form scalar loss for one candidate — differentiable in every
    ``PlatformParams`` field, the ``jax.grad`` path of ``calibrate_platform``."""
    return _score_walltimes(problem, platform_walltimes(problem, params), loss)


def _engine_score(problem: PlatformProblem, jobs, loss: str) -> jax.Array:
    """Loss of one finished engine lane + a penalty for work it never ran
    (a candidate so slow the round budget ran out must not look 'accurate'
    because its unfinished jobs fell out of the metric)."""
    done = jobs.state == DONE
    sim_wall = jnp.where(done, jobs.t_finish - jobs.t_start, 0.0)
    base = _score_walltimes(problem, sim_wall, loss)
    undone = (problem.jobs.valid & ~done).sum().astype(jnp.float32)
    penalty = 10.0 * undone / jnp.maximum(problem.jobs.valid.sum(), 1)
    return base + penalty


def _problem_sim_kwargs(problem: PlatformProblem, net) -> dict:
    kw = {}
    if problem.data_policy is not None:
        kw.update(
            data_policy=problem.data_policy, network=net, replicas=problem.replicas
        )
    if problem.availability is not None:
        kw["availability"] = problem.availability
    return kw


def engine_platform_objective(
    problem: PlatformProblem,
    params: PlatformParams,
    rng: jax.Array | None = None,
    *,
    loss: str = "mape",
    max_rounds: int = 20_000,
    policy=None,
) -> jax.Array:
    """Exact-engine scalar loss for one candidate (queueing, WAN sharing,
    subsystems).  Reference implementation the lane-batched population
    objective is equivalence-tested against; pass a pre-built ``policy`` to
    reuse one jit cache entry across a loop of solo calls.
    """
    sites, net = apply_platform_params(problem, params)
    rng = jax.random.PRNGKey(0) if rng is None else rng
    policy = pinned_policy(problem.hist_site) if policy is None else policy
    res = simulate(
        problem.jobs, sites, policy, rng, max_rounds=max_rounds,
        **_problem_sim_kwargs(problem, net),
    )
    return _engine_score(problem, res.jobs, loss)


def ravel_params(params: PlatformParams):
    """Flatten a params pytree to ``(f32[D], unravel)`` — ``None`` knobs are
    dropped and restored by ``unravel``, so D adapts to the knob subset."""
    from jax.flatten_util import ravel_pytree

    return ravel_pytree(params)


# --------------------------------------------------------------------------
# lane-batched population objective: the whole candidate population as
# ensemble lanes of ONE compiled program (DESIGN.md §8 machinery)
# --------------------------------------------------------------------------


def make_population_objective(
    problem: PlatformProblem,
    *,
    objective: str = "engine",
    loss: str = "mape",
    include=PARAM_FIELDS,
    bounds: PlatformBounds | None = None,
    mesh=None,
    axis: str = "data",
    max_rounds: int = 20_000,
):
    """Build ``batch_eval(z_pop, rng) -> f32[K]`` for a candidate population.

    ``z_pop`` is a ``[K, D]`` block of raveled log-space candidates; each row
    becomes one ensemble lane (per-lane sites/network, shared workload) and
    the whole population runs as a single ``simulate_many`` /
    ``simulate_many_sharded`` program — one compile per population size K,
    never per candidate.  Two things make that hold and are deliberately
    hoisted out of the returned closure: the pinned replay ``policy`` and the
    resolved ``Subsystem`` tuple are built ONCE here, because policy closures
    are jit static keys (``engine_objective`` rebuilds its policy per call
    and retraces — the anti-pattern this factory exists to fix).

    ``objective='closed_form'`` evaluates the differentiable walltime model
    instead (vmapped, same signature).  The returned function exposes
    ``trace_count()`` — how many times the candidate-dependent program was
    (re)traced — plus ``z0``/``unravel``/``bounds`` for the fitters.
    """
    p0 = platform_params(problem, include)
    bounds = default_bounds(p0) if bounds is None else bounds
    z0, unravel = ravel_params(encode_params(p0, bounds))
    traces: list = []

    if objective == "closed_form":

        def _impl(z_pop, rng):
            traces.append(None)

            def one(z):
                return platform_objective(
                    problem, decode_params(unravel(z), bounds), loss=loss
                )

            return jax.vmap(one)(z_pop)

        jitted = jax.jit(_impl)

        def batch_eval(z_pop, rng=None):
            rng = jax.random.PRNGKey(0) if rng is None else rng
            return jitted(z_pop, rng)

    elif objective == "engine":
        from .distributed import simulate_population
        from .engine import Scenario, simulate_many
        from .subsystems import resolve_subsystems

        policy = pinned_policy(problem.hist_site)
        subs, ext0 = resolve_subsystems(
            data_policy=problem.data_policy,
            network=problem.network0,
            replicas=problem.replicas,
            availability=problem.availability,
            jobs=problem.jobs,
            sites=problem.sites0,
        )

        def _build(z_pop) -> Scenario:
            traces.append(None)
            K = z_pop.shape[0]
            params_pop = jax.vmap(lambda z: decode_params(unravel(z), bounds))(z_pop)
            sites_pop, net_pop = jax.vmap(
                lambda p: apply_platform_params(problem, p)
            )(params_pop)
            jobs_pop = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (K,) + x.shape), problem.jobs
            )
            ext_pop = jax.tree.map(
                lambda x: jnp.broadcast_to(jnp.asarray(x), (K,) + jnp.shape(x)), ext0
            )
            if "data" in ext_pop:
                # lanes stage over their candidate's WAN matrix, not the start's
                _, replicas_pop = ext_pop["data"]
                ext_pop["data"] = (net_pop, replicas_pop)
            return Scenario(jobs=jobs_pop, sites=sites_pop, ext=ext_pop or None)

        def _score_lanes(jobs_k):
            return jax.vmap(lambda jl: _engine_score(problem, jl, loss))(jobs_k)

        if mesh is None:
            # one fused program: decode + lane build + K engine lanes + loss
            def _impl(z_pop, rng):
                scn = _build(z_pop)
                res = simulate_many(
                    scn, policy, rng, subsystems=subs, max_rounds=max_rounds
                )
                return _score_lanes(res.jobs)

            jitted = jax.jit(_impl)

            def batch_eval(z_pop, rng=None):
                rng = jax.random.PRNGKey(0) if rng is None else rng
                return jitted(z_pop, rng)

        else:
            build = jax.jit(_build)
            score = jax.jit(_score_lanes)

            def batch_eval(z_pop, rng=None):
                rng = jax.random.PRNGKey(0) if rng is None else rng
                scn = build(z_pop)
                res = simulate_population(
                    scn, policy, rng, mesh=mesh, axis=axis,
                    subsystems=subs, max_rounds=max_rounds,
                )
                return score(res.jobs)

    else:
        raise ValueError(
            f"unknown objective {objective!r}; have ('closed_form', 'engine')"
        )

    batch_eval.trace_count = lambda: len(traces)
    batch_eval.z0 = z0
    batch_eval.unravel = unravel
    batch_eval.bounds = bounds
    return batch_eval


# --------------------------------------------------------------------------
# fitters over the raveled log-space vector
# --------------------------------------------------------------------------


def spsa(
    batch_eval,
    z0: jax.Array,
    rng: jax.Array,
    *,
    n_iters: int = 100,
    n_dirs: int = 4,
    a0: float = 0.15,
    c0: float = 0.1,
    alpha: float = 0.602,
    gamma: float = 0.101,
    A: float | None = None,
    z_lo=None,
    z_hi=None,
):
    """Simultaneous-perturbation stochastic approximation, lane-batched.

    Each iteration packs the incumbent plus ``n_dirs`` antithetic Rademacher
    perturbation pairs into ONE population call of fixed size
    ``2*n_dirs + 1`` — a single compiled program services the entire fit.
    Classic Spall decay schedules (alpha/gamma); returns
    ``(best_z, best_f, history)`` with history the best-so-far loss per
    iteration (monotone).
    """
    z = jnp.asarray(z0, jnp.float32)
    D = z.shape[0]
    A = 0.1 * n_iters if A is None else A
    clip = (lambda v: v) if z_lo is None else (lambda v: jnp.clip(v, z_lo, z_hi))
    best_z, best_f = z, float("inf")
    hist = []
    for k in range(n_iters):
        rng, k_d, k_e = jax.random.split(rng, 3)
        ck = c0 / (k + 1) ** gamma
        ak = a0 / (k + 1 + A) ** alpha
        delta = jax.random.rademacher(k_d, (n_dirs, D), dtype=jnp.float32)
        cand = jnp.concatenate(
            [z[None], clip(z[None] + ck * delta), clip(z[None] - ck * delta)], 0
        )
        f = batch_eval(cand, k_e)
        fp, fm = f[1 : 1 + n_dirs], f[1 + n_dirs :]
        ghat = ((fp - fm)[:, None] * delta).mean(0) / (2.0 * ck)
        z = clip(z - ak * ghat)
        i = int(jnp.argmin(f))
        fi = float(f[i])
        if fi < best_f:
            best_z, best_f = cand[i], fi
        hist.append(best_f)
    return best_z, jnp.float32(best_f), jnp.asarray(hist, jnp.float32)


def fit_gradient(
    obj,
    z0: jax.Array,
    *,
    n_iters: int = 200,
    lr: float = 0.05,
    z_lo=None,
    z_hi=None,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
):
    """Adam on ``jax.grad obj`` — the whole fit is one scanned program.

    Only valid for the closed-form objective: the exact engine's discrete
    dispatch (argmax assignment, sorted start order) has no useful gradient.
    Returns ``(best_z, best_f, history)``.
    """
    clip = (lambda v: v) if z_lo is None else (lambda v: jnp.clip(v, z_lo, z_hi))
    vg = jax.value_and_grad(obj)

    def step(carry, t):
        z, m, v, best_z, best_f = carry
        f, g = vg(z)
        better = f < best_f
        best_z = jnp.where(better, z, best_z)
        best_f = jnp.minimum(f, best_f)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** (t + 1.0))
        vh = v / (1 - b2 ** (t + 1.0))
        z = clip(z - lr * mh / (jnp.sqrt(vh) + eps))
        return (z, m, v, best_z, best_f), best_f

    z0 = jnp.asarray(z0, jnp.float32)
    init = (z0, jnp.zeros_like(z0), jnp.zeros_like(z0), z0, jnp.float32(jnp.inf))
    (z, _, _, best_z, best_f), hist = jax.lax.scan(
        step, init, jnp.arange(n_iters, dtype=jnp.float32)
    )
    f_last = obj(z)
    best_z = jnp.where(f_last < best_f, z, best_z)
    best_f = jnp.minimum(f_last, best_f)
    return best_z, best_f, hist


def fit_cma(
    batch_eval,
    z0: jax.Array,
    rng: jax.Array,
    *,
    n_iters: int = 60,
    pop: int = 0,
    sigma0: float = 0.4,
    z_lo=None,
    z_hi=None,
):
    """Generic CMA-ES (Hansen 2016) over the raveled z vector with
    lane-batched ranking — the evolution path for the exact engine, same
    update equations as the speed-only ``cma_es`` above but agnostic to what
    the coordinates mean.  Population size is fixed, so every generation is
    one population call of the same compiled program.
    """
    import math

    z0 = jnp.asarray(z0, jnp.float32)
    D = int(z0.shape[0])
    lam = pop or max(8, int(4 + 3 * math.log(max(D, 2))))
    mu = lam // 2
    w = jnp.log(mu + 0.5) - jnp.log(jnp.arange(1, mu + 1))
    w = w / w.sum()
    mueff = 1.0 / (w**2).sum()
    cc = (4 + mueff / D) / (D + 4 + 2 * mueff / D)
    cs = (mueff + 2) / (D + mueff + 5)
    c1 = 2 / ((D + 1.3) ** 2 + mueff)
    cmu = jnp.minimum(1 - c1, 2 * (mueff - 2 + 1 / mueff) / ((D + 2) ** 2 + mueff))
    damps = 1 + 2 * jnp.maximum(0.0, jnp.sqrt((mueff - 1) / (D + 1)) - 1) + cs
    chiN = jnp.sqrt(D) * (1 - 1 / (4 * D) + 1 / (21 * D * D))
    clip = (lambda v: v) if z_lo is None else (lambda v: jnp.clip(v, z_lo, z_hi))

    m, sigma = z0, jnp.float32(sigma0)
    C, pc, ps = jnp.eye(D), jnp.zeros(D), jnp.zeros(D)
    best_z, best_f = z0, float("inf")
    hist = []
    for _ in range(n_iters):
        rng, k_s, k_e = jax.random.split(rng, 3)
        evals, evecs = jnp.linalg.eigh(C + 1e-10 * jnp.eye(D))
        Dd = jnp.sqrt(jnp.maximum(evals, 1e-12))
        zn = jax.random.normal(k_s, (lam, D))
        x = clip(m[None, :] + sigma * ((zn * Dd[None, :]) @ evecs.T))
        y = (x - m[None, :]) / sigma  # post-clip displacement keeps paths honest
        f = batch_eval(x, k_e)
        idx = jnp.argsort(f)[:mu]
        y_sel = y[idx]
        y_w = (w[:, None] * y_sel).sum(0)
        m = m + sigma * y_w
        C_inv_sqrt = evecs @ jnp.diag(1.0 / Dd) @ evecs.T
        ps = (1 - cs) * ps + jnp.sqrt(cs * (2 - cs) * mueff) * (C_inv_sqrt @ y_w)
        hsig = (jnp.linalg.norm(ps) / jnp.sqrt(1 - (1 - cs) ** 2) / chiN) < (
            1.4 + 2 / (D + 1)
        )
        pc = (1 - cc) * pc + hsig * jnp.sqrt(cc * (2 - cc) * mueff) * y_w
        C = (
            (1 - c1 - cmu) * C
            + c1 * (jnp.outer(pc, pc) + (1 - hsig) * cc * (2 - cc) * C)
            + cmu * (w[:, None, None] * (y_sel[:, :, None] * y_sel[:, None, :])).sum(0)
        )
        sigma = sigma * jnp.exp((cs / damps) * (jnp.linalg.norm(ps) / chiN - 1))
        i = int(jnp.argmin(f))
        fi = float(f[i])
        if fi < best_f:
            best_z, best_f = x[i], fi
        hist.append(best_f)
    return best_z, jnp.float32(best_f), jnp.asarray(hist, jnp.float32)


# --------------------------------------------------------------------------
# calibrate_platform(): the tentpole API
# --------------------------------------------------------------------------


class PlatformCalibResult(NamedTuple):
    params0: PlatformParams  # starting point (clipped into bounds)
    params: PlatformParams   # best candidate found (always inside bounds)
    err0: jax.Array          # loss at the start
    err: jax.Array           # loss at the result (<= err0)
    history: jax.Array       # f32[n_iters] best-so-far loss per iteration


PLATFORM_METHODS = ("spsa", "grad", "cma_es")


def calibrate_platform(
    problem: PlatformProblem,
    *,
    method: str = "spsa",
    objective: str = "closed_form",
    loss: str = "mape",
    include=PARAM_FIELDS,
    bounds: PlatformBounds | None = None,
    n_iters: int = 100,
    seed: int = 0,
    mesh=None,
    max_rounds: int = 20_000,
    manifest_out=None,
    spsa_dirs: int = 4,
    pop: int = 0,
    a0: float = 0.15,
    c0: float = 0.1,
    lr: float = 0.05,
) -> PlatformCalibResult:
    """Fit continuous platform knobs to a recorded trace at ensemble speed.

    The search space is the ``PlatformParams`` pytree selected by
    ``include`` — per-site speeds, the WAN bandwidth matrix, per-site startup
    overheads — searched in log space inside ``bounds`` (default: x30 box
    around the start; results are *guaranteed* inside the box by the
    decoder).  ``objective`` picks the evaluator: ``'closed_form'`` is the
    differentiable walltime model (supports ``method='grad'``),
    ``'engine'`` replays the trace through the exact engine with every
    candidate of an iteration packed into ensemble lanes of one compiled
    program (``mesh=`` spreads the lanes via ``simulate_many_sharded``).
    ``method`` is ``'spsa'`` (default — works on both objectives),
    ``'cma_es'``, or ``'grad'`` (closed form only: the engine's discrete
    dispatch blocks gradients).

    Same seed -> bitwise-identical result pytree (property-tested).  When
    ``manifest_out`` is given, a PR 6 RunManifest sidecar
    (``<manifest_out>.manifest.json``) records the scenario hash, initial and
    final params, and the loss curve — the Tracekit-style provenance trail
    for every calibration artifact.
    """
    if method not in PLATFORM_METHODS:
        raise ValueError(f"unknown method {method!r}; have {PLATFORM_METHODS}")
    if method == "grad" and objective != "closed_form":
        raise ValueError(
            "method='grad' needs objective='closed_form' — the exact engine's "
            "discrete dispatch blocks gradients; use 'spsa' or 'cma_es'"
        )
    p0 = platform_params(problem, include)
    bounds = default_bounds(p0) if bounds is None else bounds
    z0, unravel = ravel_params(encode_params(p0, bounds))
    z_lo, _ = ravel_params(encode_params(bounds.lo, bounds))
    z_hi, _ = ravel_params(encode_params(bounds.hi, bounds))
    batch_eval = make_population_objective(
        problem, objective=objective, loss=loss, include=include,
        bounds=bounds, mesh=mesh, max_rounds=max_rounds,
    )
    rng = jax.random.PRNGKey(seed)
    rng, k_init = jax.random.split(rng)
    err0 = batch_eval(z0[None], k_init)[0]
    if method == "spsa":
        best_z, best_f, hist = spsa(
            batch_eval, z0, rng, n_iters=n_iters, n_dirs=spsa_dirs,
            a0=a0, c0=c0, z_lo=z_lo, z_hi=z_hi,
        )
    elif method == "cma_es":
        best_z, best_f, hist = fit_cma(
            batch_eval, z0, rng, n_iters=n_iters, pop=pop, z_lo=z_lo, z_hi=z_hi
        )
    else:  # grad
        def obj(z):
            return platform_objective(
                problem, decode_params(unravel(z), bounds), loss=loss
            )

        best_z, best_f, hist = fit_gradient(
            obj, z0, n_iters=n_iters, lr=lr, z_lo=z_lo, z_hi=z_hi
        )
    # never return something worse than the starting point
    best_z = jnp.where(best_f <= err0, best_z, z0)
    err = jnp.minimum(best_f, err0)
    result = PlatformCalibResult(
        params0=decode_params(unravel(z0), bounds),
        params=decode_params(unravel(best_z), bounds),
        err0=err0,
        err=err,
        history=jnp.minimum(jnp.asarray(hist, jnp.float32), err0),
    )
    if manifest_out is not None:
        from .telemetry import jsonable, run_manifest, scenario_hash, write_manifest

        manifest = run_manifest(
            jobs=problem.jobs,
            sites=problem.sites0,
            extra=dict(
                calibration=dict(
                    method=method,
                    objective=objective,
                    loss=loss,
                    include=list(include),
                    n_iters=n_iters,
                    seed=seed,
                    scenario_hash=scenario_hash(
                        problem.jobs, problem.sites0, problem.network0
                    ),
                    err0=float(err0),
                    err=float(err),
                    loss_curve=[float(x) for x in result.history],
                    params0=jsonable(result.params0),
                    params=jsonable(result.params),
                    bounds=dict(lo=jsonable(bounds.lo), hi=jsonable(bounds.hi)),
                )
            ),
        )
        write_manifest(manifest_out, manifest)
    return result


# --------------------------------------------------------------------------
# recovery harness: synthetic hidden-truth problems + trace ingestion
# --------------------------------------------------------------------------


def make_synthetic_platform_problem(
    n_jobs: int = 96,
    n_sites: int = 4,
    *,
    seed: int = 0,
    include=PARAM_FIELDS,
    misconfig_sigma: float = 0.6,
    noise_sigma: float = 0.0,
    wan_frac: float = 0.5,
    trace: str = "closed_form",
    max_rounds: int = 20_000,
):
    """Hidden-truth platform problem + the true params (recovery harness).

    A heterogeneous platform and a jittered WAN topology are the hidden
    truth; the "recorded trace" is produced at the truth (``trace=`` picks
    the closed form or the exact engine), then every knob in ``include`` is
    misconfigured by ``misconfig_sigma`` in log space.  Cores are plentiful
    so the trace has no queueing and every walltime is pure service time —
    the regime where speeds, links, and overheads are all identifiable.
    WAN jobs each read their own single-replica dataset from a source site
    distinct from their compute site, so exactly the traced links carry
    signal.  Returns ``(problem, true_params)``.
    """
    import numpy as np

    from .datapolicies import get_data_policy
    from .network import uniform_network, with_bandwidth
    from .platform import atlas_like_platform
    from .replicas import make_replicas
    from .workload import synthetic_panda_jobs

    rng_np = np.random.default_rng(seed)
    sites_true = atlas_like_platform(
        n_sites, seed=seed, fail_rate=0.0, cores_range=(4000, 8000)
    )
    jobs = synthetic_panda_jobs(n_jobs, seed=seed + 1, duration=6 * 3600.0)
    net0 = uniform_network(n_sites, bw=1.25e9, latency=0.02)
    jitter = rng_np.lognormal(0.0, 0.5, size=(n_sites, n_sites)).astype(np.float32)
    net_true = with_bandwidth(net0, np.asarray(net0.bw) * jitter)

    w = jnp.log(jnp.maximum(sites_true.cores.astype(jnp.float32), 1.0))
    hist_site = jax.random.categorical(
        jax.random.PRNGKey(seed + 2), w[None, :].repeat(jobs.capacity, 0)
    ).astype(jnp.int32)

    J = jobs.capacity
    n_wan = int(round(wan_frac * J))
    data_policy = replicas = None
    hist_src = jnp.full((J,), -1, jnp.int32)
    hist_bytes = jnp.zeros((J,), jnp.float32)
    if n_wan > 0:
        wan_rows = np.sort(rng_np.choice(J, size=n_wan, replace=False))
        dataset = np.full(J, -1, np.int32)
        dataset[wan_rows] = np.arange(n_wan)
        hs = np.asarray(hist_site)
        origin = (
            hs[wan_rows] + 1 + rng_np.integers(0, n_sites - 1, size=n_wan)
        ).astype(np.int32) % n_sites
        sizes = rng_np.lognormal(np.log(2e9), 0.6, size=n_wan).astype(np.float32)
        replicas = make_replicas(
            sizes, np.full(n_sites, 1e18, np.float32), origin=origin
        )
        data_policy = get_data_policy("always_remote")
        jobs = jobs._replace(dataset=jnp.asarray(dataset))
        hist_src = hist_src.at[jnp.asarray(wan_rows)].set(jnp.asarray(origin))
        hist_bytes = hist_bytes.at[jnp.asarray(wan_rows)].set(jnp.asarray(sizes))

    true_params = PlatformParams(
        speed=sites_true.speed if "speed" in include else None,
        bw=net_true.bw if "bw" in include else None,
        overhead=sites_true.latency if "overhead" in include else None,
    )
    problem_true = PlatformProblem(
        jobs=jobs, sites0=sites_true, network0=net_true,
        hist_site=hist_site, hist_wall=jnp.zeros((J,), jnp.float32),
        hist_src=hist_src, hist_bytes=hist_bytes,
        data_policy=data_policy, replicas=replicas,
    )
    if trace == "engine":
        hist_wall = jnp.asarray(
            engine_platform_walltimes(problem_true, max_rounds=max_rounds)
        )
    elif trace == "closed_form":
        hist_wall = platform_walltimes(problem_true, PlatformParams())
    else:
        raise ValueError(f"unknown trace {trace!r}; have ('closed_form', 'engine')")
    if noise_sigma > 0:
        hist_wall = hist_wall * jnp.exp(
            noise_sigma
            * jax.random.normal(jax.random.PRNGKey(seed + 4), hist_wall.shape)
        )

    def bad(x, salt):
        key = jax.random.PRNGKey(seed + 100 + salt)
        return x * jnp.exp(misconfig_sigma * jax.random.normal(key, x.shape))

    sites0 = sites_true._replace(
        speed=bad(sites_true.speed, 0) if "speed" in include else sites_true.speed,
        latency=(
            bad(sites_true.latency, 1) if "overhead" in include else sites_true.latency
        ),
    )
    network0 = (
        with_bandwidth(net_true, bad(net_true.bw, 2)) if "bw" in include else net_true
    )
    problem = problem_true._replace(
        sites0=sites0, network0=network0, hist_wall=hist_wall
    )
    return problem, true_params


def engine_platform_walltimes(
    problem: PlatformProblem, *, max_rounds: int = 20_000, rng=None
) -> jax.Array:
    """Ground-truth walltimes from one exact-engine replay of ``problem`` at
    its own platform (used to record synthetic traces; 0 = job never ran)."""
    sites, net = apply_platform_params(problem, PlatformParams())
    res = simulate(
        problem.jobs, sites, pinned_policy(problem.hist_site),
        jax.random.PRNGKey(0) if rng is None else rng,
        max_rounds=max_rounds, **_problem_sim_kwargs(problem, net),
    )
    return jnp.where(res.jobs.state == DONE, res.jobs.t_finish - res.jobs.t_start, 0.0)


def platform_problem_from_trace(
    jobs: JobsState,
    sites0: SiteState,
    trace: dict,
    *,
    network0=None,
    data_policy=None,
    replicas=None,
    availability=None,
) -> PlatformProblem:
    """Build a ``PlatformProblem`` from recorded trace rows.

    ``trace`` is ``events.recorded_trace(result)``, an ``events.ml_dataset``
    dict, or ``events.read_ml_trace(path)`` — anything with ``job_id`` /
    ``site`` / ``walltime`` columns (``xfer_src``/``xfer_bytes`` optional).
    Rows align to workload entries by ``job_id``; jobs the trace does not
    cover get ``hist_wall = 0`` and drop out of the mape/quantile losses.
    """
    import numpy as np

    J = jobs.capacity
    pos = {int(j): i for i, j in enumerate(np.asarray(jobs.job_id))}
    site = np.zeros(J, np.int32)
    wall = np.zeros(J, np.float32)
    src = np.full(J, -1, np.int32)
    nbytes = np.zeros(J, np.float32)
    t_src = trace.get("xfer_src")
    t_bytes = trace.get("xfer_bytes")
    for r, jid in enumerate(np.asarray(trace["job_id"])):
        i = pos.get(int(jid))
        if i is None:
            raise ValueError(f"trace job_id {int(jid)} not in the workload")
        site[i] = trace["site"][r]
        wall[i] = trace["walltime"][r]
        if t_src is not None:
            src[i] = t_src[r]
            nbytes[i] = t_bytes[r] if t_bytes is not None else 0.0
    return PlatformProblem(
        jobs=jobs, sites0=sites0, network0=network0,
        hist_site=jnp.asarray(site), hist_wall=jnp.asarray(wall),
        hist_src=jnp.asarray(src) if t_src is not None else None,
        hist_bytes=jnp.asarray(nbytes) if t_src is not None else None,
        data_policy=data_policy, replicas=replicas, availability=availability,
    )


def recovery_error(
    problem: PlatformProblem,
    params: PlatformParams,
    true_params: PlatformParams,
) -> float:
    """Geomean across knob families of the mean relative error vs the hidden
    truth — measured only over *identifiable* entries: sites the trace ran
    jobs at, WAN links it actually transferred bytes over.  This is the
    recovery acceptance metric (geomean rel-MAE)."""
    import numpy as np

    valid = np.asarray(problem.jobs.valid)
    hs = np.asarray(problem.hist_site)[valid]
    S = problem.sites0.capacity
    used_site = np.zeros(S, bool)
    used_site[np.unique(np.clip(hs, 0, S - 1))] = True

    def rel(a, b):
        b = np.maximum(np.abs(np.asarray(b, np.float64)), 1e-30)
        return np.abs(np.asarray(a, np.float64) / b - 1.0)

    maes = []
    if params.speed is not None and true_params.speed is not None:
        maes.append(rel(params.speed, true_params.speed)[used_site].mean())
    if params.overhead is not None and true_params.overhead is not None:
        maes.append(rel(params.overhead, true_params.overhead)[used_site].mean())
    if (
        params.bw is not None
        and true_params.bw is not None
        and problem.hist_src is not None
    ):
        src = np.asarray(problem.hist_src)[valid]
        byt = (
            np.asarray(problem.hist_bytes)[valid]
            if problem.hist_bytes is not None
            else np.ones_like(src, np.float32)
        )
        m = (src >= 0) & (src != hs) & (byt > 0)
        used = np.zeros((S, S), bool)
        used[src[m], hs[m]] = True
        if used.any():
            maes.append(rel(params.bw, true_params.bw)[used].mean())
    if not maes:
        return float("nan")
    return float(np.exp(np.mean(np.log(np.maximum(np.asarray(maes), 1e-12)))))
