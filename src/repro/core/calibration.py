"""Calibration framework (paper §4.2, Fig. 1c, Fig. 3).

The paper replays historical PanDA jobs with their *real* site assignments and
tunes per-site CPU speed (the dominant sensitivity) to minimize
``Δexe_time = Sim_exe_time − His_exe_time``.  Four optimizers are compared:
brute force, random sampling, Bayesian optimization, CMA-ES; random search
wins on their landscape.  All four are implemented here, pure JAX.

Two objective paths, which agree exactly in pinned-replay mode (tested):

* ``closed_form_walltimes`` — service-time model evaluated directly (fast path
  for walltime-only calibration, what the paper's Fig. 3 measures);
* ``engine_objective`` — full simulation via ``engine.simulate`` with a
  pinned-assignment policy (needed once queue-time modelling is included).

Beyond the paper: per-site error decomposition lets random/grid search select
the best candidate *per site* from one vmapped candidate batch — turning a
K-candidate x S-site search into an embarrassingly parallel single pass.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .engine import simulate
from .policies import make_policy
from .types import DONE, JobsState, SiteState

# --------------------------------------------------------------------------
# ground truth + objective
# --------------------------------------------------------------------------


def closed_form_walltimes(jobs: JobsState, sites: SiteState, site: jax.Array) -> jax.Array:
    """Walltime of each job if executed at ``site`` (no queueing, unit bw share).

    Matches ``engine.service_time`` with share=1, which is what a pinned
    replay converges to for walltime (queue time is separate, as in the paper).
    """
    s = jnp.clip(site, 0, sites.capacity - 1)
    c = jobs.cores.astype(jnp.float32)
    gamma = sites.par_gamma[s]
    speedup = c / (1.0 + gamma * jnp.maximum(c - 1.0, 0.0))
    return (
        sites.latency[s]
        + jobs.bytes_in / sites.bw_in[s]
        + jobs.work / (sites.speed[s] * jnp.maximum(speedup, 1e-9))
        + jobs.bytes_out / sites.bw_out[s]
    )


def per_site_rel_mae(
    jobs: JobsState,
    hist_site: jax.Array,
    hist_wall: jax.Array,
    sim_wall: jax.Array,
    n_sites: int,
) -> jax.Array:
    """Relative MAE per (site, job-class) — Fig. 3's metric.

    Returns f32[n_sites, 2]: column 0 single-core, column 1 multicore.
    Sites with no jobs of a class get 0 (excluded from geomeans by mask).
    """
    rel = jnp.abs(sim_wall - hist_wall) / jnp.maximum(hist_wall, 1e-9)
    multi = jobs.cores > 1
    seg = jnp.where(jobs.valid, hist_site, n_sites)

    def cls_mae(mask):
        num = jax.ops.segment_sum(jnp.where(mask, rel, 0.0), seg, num_segments=n_sites + 1)[:n_sites]
        den = jax.ops.segment_sum(mask.astype(jnp.float32), seg, num_segments=n_sites + 1)[:n_sites]
        return num / jnp.maximum(den, 1.0), den > 0

    mae_s, has_s = cls_mae(jobs.valid & ~multi)
    mae_m, has_m = cls_mae(jobs.valid & multi)
    return jnp.stack([mae_s, mae_m], axis=-1), jnp.stack([has_s, has_m], axis=-1)


def geomean_error(mae: jax.Array, has: jax.Array) -> jax.Array:
    """Geometric mean of per-(site, class) relative MAE over populated cells."""
    logs = jnp.where(has, jnp.log(jnp.maximum(mae, 1e-9)), 0.0)
    n = jnp.maximum(has.sum(), 1)
    return jnp.exp(logs.sum() / n)


class CalibProblem(NamedTuple):
    jobs: JobsState
    sites0: SiteState       # platform with the *misconfigured* initial speeds
    hist_site: jax.Array    # i32[J] historical assignment (PanDA replay)
    hist_wall: jax.Array    # f32[J] ground-truth walltime
    n_sites: int


def make_synthetic_problem(
    jobs: JobsState,
    sites: SiteState,
    *,
    seed: int = 0,
    misconfig_sigma: float = 0.75,
    noise_sigma: float = 0.15,
) -> CalibProblem:
    """Build a Fig.-3-style problem: hidden true speeds produce "historical"
    walltimes (log-normal measurement noise); the platform is then
    misconfigured by ``misconfig_sigma`` in log-space (≈76% initial error)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    S = sites.capacity
    active = sites.active
    # historical assignment: PanDA-ish weighted by capacity
    w = jnp.where(active, sites.cores.astype(jnp.float32), 0.0)
    hist_site = jax.random.categorical(
        k1, jnp.log(jnp.maximum(w, 1e-9))[None, :].repeat(jobs.capacity, 0)
    ).astype(jnp.int32)
    true_sites = sites
    wall = closed_form_walltimes(jobs, true_sites, hist_site)
    wall = wall * jnp.exp(noise_sigma * jax.random.normal(k2, wall.shape))
    bad_speed = sites.speed * jnp.exp(misconfig_sigma * jax.random.normal(k3, (S,)))
    return CalibProblem(
        jobs=jobs,
        sites0=sites._replace(speed=bad_speed),
        hist_site=hist_site,
        hist_wall=wall,
        n_sites=S,
    )


def closed_form_objective(problem: CalibProblem, speeds: jax.Array):
    """err[S,2], has[S,2], geomean for one speed vector (fast path)."""
    sites = problem.sites0._replace(speed=speeds)
    sim_wall = closed_form_walltimes(problem.jobs, sites, problem.hist_site)
    mae, has = per_site_rel_mae(
        problem.jobs, problem.hist_site, problem.hist_wall, sim_wall, problem.sites0.capacity
    )
    return mae, has, geomean_error(mae, has)


def pinned_policy(hist_site: jax.Array):
    """Replay policy: every job scores +1 only at its historical site."""

    def score(jobs, sites, state, clock, rng):
        S = sites.capacity
        return (jnp.arange(S)[None, :] == hist_site[:, None]).astype(jnp.float32)

    return make_policy("pinned_replay", score)


def engine_objective(problem: CalibProblem, speeds: jax.Array, *, max_rounds: int = 60_000):
    """Full-engine objective (includes queueing): geomean rel-MAE of walltime."""
    sites = problem.sites0._replace(speed=speeds)
    res = simulate(
        problem.jobs, sites, pinned_policy(problem.hist_site), jax.random.PRNGKey(0),
        max_rounds=max_rounds,
    )
    sim_wall = jnp.where(res.jobs.state == DONE, res.jobs.t_finish - res.jobs.t_start, 0.0)
    mae, has = per_site_rel_mae(
        problem.jobs, problem.hist_site, problem.hist_wall, sim_wall, problem.sites0.capacity
    )
    return mae, has, geomean_error(mae, has)


# --------------------------------------------------------------------------
# optimizer 1/2: brute-force grid + random search (paper's winner)
# --------------------------------------------------------------------------


class CalibResult(NamedTuple):
    speeds: jax.Array        # f32[S] calibrated speeds
    err0: jax.Array          # geomean error before
    err: jax.Array           # geomean error after
    history: jax.Array       # f32[iters] best-so-far geomean per iteration


@functools.partial(jax.jit, static_argnames=("n_points", "log_range"))
def grid_search(problem: CalibProblem, *, n_points: int = 64, log_range: float = 2.0) -> CalibResult:
    """Brute force (paper: "theoretically optimal but infeasible" jointly).

    Feasible here because the walltime objective decomposes per site: sweep a
    per-site 1-D grid in log-space and take each site's argmin independently.
    """
    _, _, err0 = closed_form_objective(problem, problem.sites0.speed)
    grid = jnp.exp(jnp.linspace(-log_range, log_range, n_points))  # multiplicative

    def eval_one(mult):
        mae, has, _ = closed_form_objective(problem, problem.sites0.speed * mult)
        return jnp.where(has, mae, jnp.inf).mean(-1)  # [S] mean over classes

    errs = jax.vmap(eval_one)(grid)  # [n_points, S]
    best = jnp.argmin(errs, axis=0)
    speeds = problem.sites0.speed * grid[best]
    _, _, err = closed_form_objective(problem, speeds)
    hist = jax.lax.cummin(jnp.min(errs, axis=1))
    return CalibResult(speeds=speeds, err0=err0, err=err, history=hist)


@functools.partial(jax.jit, static_argnames=("n_iters", "pop", "per_site"))
def random_search(
    problem: CalibProblem,
    rng: jax.Array,
    *,
    n_iters: int = 30,
    pop: int = 32,
    sigma0: float = 0.8,
    shrink: float = 0.88,
    per_site: bool = True,
) -> CalibResult:
    """Log-normal random search around the incumbent with shrinking step size.

    ``per_site=True`` is the beyond-paper accelerator: each site independently
    adopts the candidate that minimizes *its own* error, valid because the
    walltime objective is separable across sites.
    """
    _, _, err0 = closed_form_objective(problem, problem.sites0.speed)

    def step(carry, key):
        speeds, sigma = carry
        noise = jax.random.normal(key, (pop, speeds.shape[0]))
        cands = speeds[None, :] * jnp.exp(sigma * noise)
        cands = jnp.concatenate([speeds[None, :], cands], 0)

        def eval_one(sp):
            mae, has, ge = closed_form_objective(problem, sp)
            site_err = jnp.where(has, mae, 0.0).sum(-1) / jnp.maximum(has.sum(-1), 1)
            site_err = jnp.where(has.any(-1), site_err, jnp.inf)
            return site_err, ge

        site_errs, ges = jax.vmap(eval_one)(cands)  # [pop+1, S], [pop+1]
        if per_site:
            pick = jnp.argmin(site_errs, axis=0)  # [S]
            new = cands[pick, jnp.arange(speeds.shape[0])]
        else:
            new = cands[jnp.argmin(ges)]
        _, _, ge_new = closed_form_objective(problem, new)
        return (new, sigma * shrink), ge_new

    keys = jax.random.split(rng, n_iters)
    (speeds, _), hist = jax.lax.scan(step, (problem.sites0.speed, jnp.float32(sigma0)), keys)
    _, _, err = closed_form_objective(problem, speeds)
    return CalibResult(speeds=speeds, err0=err0, err=err, history=jax.lax.cummin(hist))


# --------------------------------------------------------------------------
# optimizer 3: CMA-ES (Hansen 2016), in log-speed space
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_iters", "pop"))
def cma_es(
    problem: CalibProblem,
    rng: jax.Array,
    *,
    n_iters: int = 60,
    pop: int = 0,
    sigma0: float = 0.5,
) -> CalibResult:
    import math

    S = problem.sites0.speed.shape[0]
    n = S
    lam = pop or int(4 + 3 * math.log(n))
    lam = max(lam, 8)
    mu = lam // 2
    w = jnp.log(mu + 0.5) - jnp.log(jnp.arange(1, mu + 1))
    w = w / w.sum()
    mueff = 1.0 / (w**2).sum()
    cc = (4 + mueff / n) / (n + 4 + 2 * mueff / n)
    cs = (mueff + 2) / (n + mueff + 5)
    c1 = 2 / ((n + 1.3) ** 2 + mueff)
    cmu = jnp.minimum(1 - c1, 2 * (mueff - 2 + 1 / mueff) / ((n + 2) ** 2 + mueff))
    damps = 1 + 2 * jnp.maximum(0.0, jnp.sqrt((mueff - 1) / (n + 1)) - 1) + cs
    chiN = jnp.sqrt(n) * (1 - 1 / (4 * n) + 1 / (21 * n * n))

    _, _, err0 = closed_form_objective(problem, problem.sites0.speed)
    m0 = jnp.log(problem.sites0.speed)

    def f(logsp):
        _, _, ge = closed_form_objective(problem, jnp.exp(logsp))
        return ge

    def step(carry, key):
        m, sigma, C, pc, ps = carry
        # sample
        evals, evecs = jnp.linalg.eigh(C + 1e-10 * jnp.eye(n))
        D = jnp.sqrt(jnp.maximum(evals, 1e-12))
        z = jax.random.normal(key, (lam, n))
        y = (z * D[None, :]) @ evecs.T
        x = m[None, :] + sigma * y
        fx = jax.vmap(f)(x)
        idx = jnp.argsort(fx)[:mu]
        y_sel = y[idx]
        y_w = (w[:, None] * y_sel).sum(0)
        m_new = m + sigma * y_w
        # step-size path
        C_inv_sqrt = evecs @ jnp.diag(1.0 / D) @ evecs.T
        ps_new = (1 - cs) * ps + jnp.sqrt(cs * (2 - cs) * mueff) * (C_inv_sqrt @ y_w)
        hsig = (jnp.linalg.norm(ps_new) / jnp.sqrt(1 - (1 - cs) ** 2) / chiN) < (1.4 + 2 / (n + 1))
        pc_new = (1 - cc) * pc + hsig * jnp.sqrt(cc * (2 - cc) * mueff) * y_w
        C_new = (
            (1 - c1 - cmu) * C
            + c1 * (jnp.outer(pc_new, pc_new) + (1 - hsig) * cc * (2 - cc) * C)
            + cmu * (w[:, None, None] * (y_sel[:, :, None] * y_sel[:, None, :])).sum(0)
        )
        sigma_new = sigma * jnp.exp((cs / damps) * (jnp.linalg.norm(ps_new) / chiN - 1))
        return (m_new, sigma_new, C_new, pc_new, ps_new), fx.min()

    keys = jax.random.split(rng, n_iters)
    init = (m0, jnp.float32(sigma0), jnp.eye(n), jnp.zeros(n), jnp.zeros(n))
    (m, *_), hist = jax.lax.scan(step, init, keys)
    speeds = jnp.exp(m)
    _, _, err = closed_form_objective(problem, speeds)
    return CalibResult(speeds=speeds, err0=err0, err=err, history=jax.lax.cummin(hist))


# --------------------------------------------------------------------------
# optimizer 4: GP-UCB Bayesian optimization (lightweight, exact-GP)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_iters", "n_init", "n_cand"))
def gp_bo(
    problem: CalibProblem,
    rng: jax.Array,
    *,
    n_iters: int = 48,
    n_init: int = 16,
    n_cand: int = 256,
    lengthscale: float = 1.0,
    beta: float = 2.0,
) -> CalibResult:
    """GP-UCB over log-speeds.  Exact GP (Cholesky) on a fixed-size buffer —
    the paper's BO baseline at the scale its experiments used (≤ a few hundred
    evaluations over 50 sites)."""
    S = problem.sites0.speed.shape[0]
    T = n_init + n_iters
    m0 = jnp.log(problem.sites0.speed)
    _, _, err0 = closed_form_objective(problem, problem.sites0.speed)

    def f(logsp):
        _, _, ge = closed_form_objective(problem, jnp.exp(logsp))
        return ge

    k_init, k_loop = jax.random.split(rng)
    X0 = m0[None, :] + 0.6 * jax.random.normal(k_init, (n_init, S))
    y0 = jax.vmap(f)(X0)
    X = jnp.zeros((T, S)).at[:n_init].set(X0)
    y = jnp.full((T,), 1e6).at[:n_init].set(y0)

    def kern(a, b):
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        return jnp.exp(-0.5 * d2 / lengthscale**2)

    def step(carry, key):
        X, y, t = carry
        mask = jnp.arange(T) < t
        ymu = jnp.where(mask, y, 0.0).sum() / jnp.maximum(mask.sum(), 1)
        yc = jnp.where(mask, y - ymu, 0.0)
        K = kern(X, X) * (mask[:, None] & mask[None, :]) + jnp.eye(T) * (
            1e-4 + (~mask) * 1e6
        )
        L = jnp.linalg.cholesky(K)
        alpha = jax.scipy.linalg.cho_solve((L, True), yc)
        # candidates around the incumbent
        best_idx = jnp.argmin(jnp.where(mask, y, jnp.inf))
        kc, ks = jax.random.split(key)
        scale = jax.random.uniform(ks, (n_cand, 1), minval=0.05, maxval=0.8)
        cand = X[best_idx][None, :] + scale * jax.random.normal(kc, (n_cand, S))
        Kc = kern(cand, X) * mask[None, :]
        mu = Kc @ alpha + ymu
        v = jax.scipy.linalg.solve_triangular(L, Kc.T, lower=True)
        var = jnp.maximum(1.0 - (v**2).sum(0), 1e-9)
        ucb = mu - beta * jnp.sqrt(var)  # minimize ⇒ lower confidence bound
        x_new = cand[jnp.argmin(ucb)]
        y_new = f(x_new)
        X = X.at[t].set(x_new)
        y = y.at[t].set(y_new)
        return (X, y, t + 1), jnp.minimum(y_new, jnp.where(mask, y, jnp.inf).min())

    keys = jax.random.split(k_loop, n_iters)
    (X, y, _), hist = jax.lax.scan(step, (X, y, jnp.int32(n_init)), keys)
    best = jnp.argmin(y)
    speeds = jnp.exp(X[best])
    _, _, err = closed_form_objective(problem, speeds)
    return CalibResult(speeds=speeds, err0=err0, err=err, history=jax.lax.cummin(hist))


OPTIMIZERS: dict[str, Callable] = {
    "grid": grid_search,
    "random": random_search,
    "cma_es": cma_es,
    "gp_bo": gp_bo,
}


def calibrate(problem: CalibProblem, method: str = "random", seed: int = 0, **kw) -> CalibResult:
    if method == "grid":
        return grid_search(problem, **kw)
    return OPTIMIZERS[method](problem, jax.random.PRNGKey(seed), **kw)
