"""Real-time monitoring (paper §4.3.3, Fig. 5) — terminal edition.

CGSim ships a web dashboard showing per-site node pressure with job-level
hover details.  Headless here, so the same observables render as (a) ANSI
terminal frames during a run and (b) JSON frame streams any dashboard can
consume.  ``watch()`` wraps the engine: it splits the horizon into segments
and re-enters the jitted simulator between frames (``engine.init_sim`` /
``advance_sim``), so monitoring costs nothing inside the hot loop and the
result stays bit-for-bit identical to a plain ``simulate``.  Frames stream
to any ``telemetry.Sink``; ``python -m repro.monitor --follow run.ndjson``
tails such a stream live from a separate process (the paper's real-time
dashboard, decoupled).
"""
from __future__ import annotations

import json
import sys

import numpy as np

from .events import log_frames
from .types import ASSIGNED, RUNNING, SimResult, STATE_NAMES

BAR = " ▁▂▃▄▅▆▇█"


def pressure_bar(used: int, total: int, width: int = 20) -> str:
    if total <= 0:
        return " " * width
    frac = min(max(used / total, 0.0), 1.0)
    full = int(frac * width)
    return "█" * full + "·" * (width - full)


def render_frame(
    frame: dict, sites_cores, site_names=None, max_sites: int = 24, disk_cap=None
) -> str:
    """One dashboard frame: global counts + per-site node pressure, plus
    storage-element and WAN-ingress pressure when the data subsystem is on."""
    c = frame["counts"]
    lines = [
        f"t={frame['time']:>12.1f}s  round={frame['round']:>7d}  "
        + "  ".join(f"{k}={c[k]}" for k in STATE_NAMES),
    ]
    free = np.asarray(frame["site_free"])
    queued = np.asarray(frame["site_queued"])
    running = np.asarray(frame["site_running"])
    total = np.asarray(sites_cores)
    disk = np.asarray(frame.get("site_disk", np.zeros_like(total, dtype=float)))
    net_in = np.asarray(frame.get("site_net_in", np.zeros_like(total, dtype=float)))
    avail = np.asarray(frame.get("site_avail", np.ones_like(total, dtype=float)))
    show_data = disk.any() or net_in.any() or disk_cap is not None
    order = np.argsort(-(total - free))[:max_sites]
    for s in order:
        if total[s] <= 0:
            continue
        name = site_names[s] if site_names else f"site{s:03d}"
        used = int(total[s] - free[s])
        line = (
            f"  {name:>12s} |{pressure_bar(used, int(total[s]))}| "
            f"{used:>6d}/{int(total[s]):<6d} cores  run={int(running[s]):>5d} queue={int(queued[s]):>5d}"
        )
        if avail[s] <= 0.0:
            line += "  DOWN"
        elif avail[s] < 1.0:
            line += f"  avail=x{avail[s]:.2f}"
        if show_data:
            cap = float(np.asarray(disk_cap)[s]) if disk_cap is not None else 0.0
            bar = pressure_bar(int(disk[s]), int(cap), width=8) if cap > 0 else " " * 8
            line += f"  disk|{bar}| {disk[s] / 1e12:>6.2f}TB  net_in={net_in[s] / 1e9:>7.2f}GB"
        lines.append(line)
    return "\n".join(lines)


def state_frame(handle) -> dict:
    """Host-side dashboard frame snapshotted from a paused ``SimHandle`` —
    same shape ``render_frame`` consumes, computed between jit segments
    (never inside the round loop)."""
    st = handle.state
    state = np.asarray(st.jobs.state)
    valid = np.asarray(st.jobs.valid)
    site = np.asarray(st.jobs.site)
    S = st.sites.capacity
    counts = {name: int(((state == s) & valid).sum()) for s, name in enumerate(STATE_NAMES)}

    def per_site(kind):
        m = (state == kind) & valid & (site >= 0)
        return np.bincount(site[m], minlength=S)[:S].tolist()

    return dict(
        round=int(st.round),
        time=float(st.clock),
        counts=counts,
        site_free=np.asarray(st.sites.free_cores).tolist(),
        site_queued=per_site(ASSIGNED),
        site_running=per_site(RUNNING),
    )


def watch(
    jobs0,
    sites0,
    policy,
    rng,
    *,
    frames: int = 24,
    horizon: float | None = None,
    segment: float | None = None,
    sink=None,
    site_names=None,
    render: bool = True,
    out=sys.stdout,
    recorder=None,
    max_segments: int = 10_000,
    **kw,
) -> SimResult:
    """Run a simulation while watching it: the long-promised segmented driver.

    Splits the run into time segments (``segment`` seconds each, or
    ``horizon / frames``; without a horizon the segment width is estimated
    from the arrival span) and re-enters the jitted round loop between them.
    Because the loop's horizon is a *dynamic* argument checked before each
    round, every segment continues the exact round sequence one ``simulate``
    call would execute — the returned ``SimResult`` is bit-for-bit identical
    (tested), and all segments share a single compile.

    After each segment a host-side frame snapshot goes to ``sink`` (any
    ``telemetry.Sink``; an ``NDJSONSink`` makes the run tailable live with
    ``python -m repro.monitor --follow run.ndjson``) and/or renders to
    ``out``.  The stream carries a ``run_meta`` record first (site cores and
    names — what a renderer needs) and an ``end`` record last.  Pass a
    ``telemetry.TraceRecorder`` to time the segments; remaining ``**kw``
    (``log_rows``, subsystems, ...) forward to the engine.
    """
    from .engine import advance_sim, finish_sim, init_sim, sim_active
    from .telemetry import maybe

    rec = maybe(recorder)
    with rec.span("watch_init"):
        handle = init_sim(jobs0, sites0, policy, rng, **kw)
    hz = None if horizon is None or not np.isfinite(horizon) else float(horizon)
    if segment is not None:
        dt = float(segment)
    elif hz is not None:
        dt = hz / max(frames, 1)
    else:
        arr = np.asarray(jobs0.arrival, np.float64)
        fin = arr[np.isfinite(arr) & np.asarray(jobs0.valid)]
        est = 2.0 * float(fin.max()) if fin.size and fin.max() > 0 else float(frames)
        dt = est / max(frames, 1)
    dt = max(dt, 1e-9)

    if sink is not None:
        sink.emit(
            dict(
                type="run_meta",
                n_sites=sites0.capacity,
                sites_cores=np.asarray(sites0.cores).tolist(),
                site_names=list(site_names) if site_names else None,
                horizon=hz,
            )
        )
    cores = np.asarray(sites0.cores)
    n_seg = 0
    t_edge = 0.0
    while sim_active(handle) and n_seg < max_segments:
        t_edge += dt
        at_end = hz is not None and t_edge >= hz
        with rec.span("watch_segment"):
            handle = advance_sim(handle, hz if at_end else t_edge)
        frame = state_frame(handle)
        if sink is not None:
            sink.emit({"type": "frame", **frame})
        if render:
            out.write(render_frame(frame, cores, site_names) + "\n\n")
        n_seg += 1
        if at_end:
            break
    if hz is None and sim_active(handle):
        # segment budget exhausted on an open-horizon run: drain to the end
        with rec.span("watch_segment"):
            handle = advance_sim(handle)
    with rec.span("watch_finalize"):
        res = finish_sim(handle)
    rec.gauge("watch_segments", n_seg)
    rec.gauge("rounds_executed", int(res.rounds))
    if sink is not None:
        sink.emit(
            dict(
                type="end",
                rounds=int(res.rounds),
                makespan=float(res.makespan),
                segments=n_seg,
            )
        )
    return res


def follow_stream(
    source,
    *,
    follow: bool = False,
    every: int = 1,
    clear: bool = True,
    out=sys.stdout,
    poll_s: float = 0.2,
    timeout_s: float | None = None,
) -> int:
    """Render a frame NDJSON stream (as written by ``watch``) to a terminal.

    ``follow=True`` tails a file another process is still writing — the
    decoupled live dashboard.  Returns the number of frames rendered."""
    from .telemetry import iter_ndjson

    cores = None
    names = None
    shown = i = 0
    for rec in iter_ndjson(source, follow=follow, poll_s=poll_s, timeout_s=timeout_s):
        t = rec.get("type")
        if t == "run_meta":
            cores = np.asarray(rec["sites_cores"])
            names = rec.get("site_names")
        elif t == "frame":
            if i % every == 0 and cores is not None:
                if clear:
                    out.write("\x1b[2J\x1b[H")
                out.write(render_frame(rec, cores, names) + "\n\n")
                shown += 1
            i += 1
        elif t == "end":
            out.write(
                f"end: rounds={rec.get('rounds')} makespan={rec.get('makespan')}\n"
            )
            break
    return shown


def render_run(result: SimResult, site_names=None, every: int = 1, out=sys.stdout) -> None:
    frames = log_frames(result)
    cores = np.asarray(result.sites.cores)
    for i, frame in enumerate(frames):
        if i % every:
            continue
        out.write(render_frame(frame, cores, site_names) + "\n\n")


def frames_json(result: SimResult) -> str:
    """JSON frame stream for an external dashboard (the web-UI contract)."""
    return json.dumps(log_frames(result))


def utilization_timeline(result: SimResult) -> np.ndarray:
    """[T, S] core-utilization per logged frame — sparkline/heatmap feed."""
    frames = log_frames(result)
    cores = np.maximum(np.asarray(result.sites.cores, dtype=np.float64), 1.0)
    rows = [(cores - np.asarray(f["site_free"], dtype=np.float64)) / cores for f in frames]
    return np.stack(rows) if rows else np.zeros((0, cores.size))


def extra_timeline(result: SimResult, column: str, default: float = 0.0) -> np.ndarray:
    """[T, S] per-frame values of a subsystem-declared log column
    (``EventLog.extra``, DESIGN.md §7); ``default`` fills frames from runs
    where the owning subsystem was not attached."""
    frames = log_frames(result)
    S = result.sites.capacity
    fallback = np.full((S,), default)
    rows = [np.asarray(f.get(column, fallback), dtype=np.float64) for f in frames]
    return np.stack(rows) if rows else np.zeros((0, S))


def storage_timeline(result: SimResult) -> np.ndarray:
    """[T, S] storage-element occupancy (bytes) per logged frame."""
    return extra_timeline(result, "site_disk")


def network_timeline(result: SimResult) -> np.ndarray:
    """[T, S] WAN bytes staged into each site per logged frame."""
    return extra_timeline(result, "site_net_in")


def _link_timeline(result: SimResult, column: str) -> np.ndarray:
    """[T, S, S] per-frame values of a transfer-queue link column — the
    flattened ``[S*S]`` log rows folded back onto the (src, dst) matrix.
    Frames from runs without the subsystem come back as zeros."""
    frames = log_frames(result)
    S = result.sites.capacity
    fallback = np.zeros((S * S,))
    rows = [np.asarray(f.get(column, fallback), dtype=np.float64) for f in frames]
    out = np.stack(rows) if rows else np.zeros((0, S * S))
    return out.reshape(-1, S, S)


def link_occupancy_timeline(result: SimResult) -> np.ndarray:
    """[T, S, S] active transfers per directed link per logged frame — the
    DESIGN.md §11 dashboard feed for FTS channel saturation (compare against
    the per-link caps)."""
    return _link_timeline(result, "link_active")


def transfer_queue_timeline(result: SimResult) -> np.ndarray:
    """[T, S, S] queued (waiting) transfers per directed link per logged
    frame — queue-depth build-up and drain on hot links."""
    return _link_timeline(result, "link_queued")


def availability_timeline(result: SimResult) -> np.ndarray:
    """[T, S] availability factor per logged frame (1 up, (0,1) degraded,
    0 down) — the DESIGN.md §5 dashboard feed for outage/brown-out studies."""
    return extra_timeline(result, "site_avail", default=1.0)


def fault_score_timeline(result: SimResult) -> np.ndarray:
    """[T, S] EWMA fault score per logged frame (DESIGN.md §13) — watch a
    flaky site's score climb toward the blacklist threshold."""
    return extra_timeline(result, "site_fault_score")


def blacklist_timeline(result: SimResult) -> np.ndarray:
    """[T, S] circuit-breaker state per logged frame (0 closed, 1 tripped,
    2 half-open) — the trip/cooldown/probe cycle as a step chart."""
    return extra_timeline(result, "site_blacklist")


def workflow_timeline(result: SimResult) -> tuple[np.ndarray, np.ndarray]:
    """Per-workflow stage-completion matrix (DESIGN.md §6 dashboard feed).

    Returns ``(wf_ids[W], t_done[W, Dmax+1])``: for each workflow and DAG
    depth level, the time the *last* job at that depth finished (``nan``
    where the level never fully finished — failed/cancelled levels stay
    nan).  Runs without a DAG return empty arrays.
    """
    from .types import DONE

    jobs = np.asarray(result.jobs.wf_id)
    valid = np.asarray(result.jobs.valid)
    sel = valid & (jobs >= 0)
    if not sel.any():
        return np.zeros((0,), np.int64), np.zeros((0, 0))
    depth = np.asarray(result.jobs.dag_depth)
    state = np.asarray(result.jobs.state)
    fin = np.asarray(result.jobs.t_finish, np.float64)
    wf_ids = np.unique(jobs[sel])
    dmax = int(depth[sel].max())
    out = np.full((wf_ids.size, dmax + 1), np.nan)
    for i, w in enumerate(wf_ids):
        for d in range(dmax + 1):
            m = sel & (jobs == w) & (depth == d)
            if m.any() and (state[m] == DONE).all():
                out[i, d] = fin[m].max()
    return wf_ids, out


def render_workflows(result: SimResult, max_rows: int = 16, width: int = 48) -> str:
    """ASCII per-workflow gantt: one bar per workflow spanning submit ->
    last finish, with stage-completion ticks at each DAG depth."""
    wf_ids, t_done = workflow_timeline(result)
    if wf_ids.size == 0:
        return "(no workflows)"
    jobs = np.asarray(result.jobs.wf_id)
    valid = np.asarray(result.jobs.valid)
    arr = np.asarray(result.jobs.arrival, np.float64)
    span = float(np.nanmax(t_done)) if np.isfinite(t_done).any() else 1.0
    span = max(span, 1e-9)
    lines = []
    for i, w in enumerate(wf_ids[:max_rows]):
        t0 = float(arr[valid & (jobs == w)].min())
        cells = [" "] * width
        a, b = int(t0 / span * (width - 1)), 0
        ends = t_done[i][np.isfinite(t_done[i])]
        if ends.size:
            b = int(ends.max() / span * (width - 1))
            for x in range(a, b + 1):
                cells[x] = "─"
            for td in ends:
                cells[int(td / span * (width - 1))] = "┃"
        done = np.isfinite(t_done[i]).all()
        lines.append(
            f"  wf{int(w):>4d} |{''.join(cells)}| "
            + (f"done @ {ends.max():>10.1f}s" if done and ends.size else "incomplete")
        )
    return "\n".join(lines)


def sparkline(values: np.ndarray, width: int = 60) -> str:
    if values.size == 0:
        return ""
    idx = np.linspace(0, values.size - 1, width).astype(int)
    v = values[idx]
    lo, hi = float(v.min()), float(v.max())
    span = (hi - lo) or 1.0
    chars = [BAR[int((x - lo) / span * (len(BAR) - 1))] for x in v]
    return "".join(chars) + f"  [{lo:.2f}..{hi:.2f}]"
