"""Vectorized discrete-event engine.

SimGrid runs one event at a time through coroutine actors.  On an accelerator
we instead run *event rounds*: a ``lax.while_loop`` whose body advances the
clock to the next event time (an O(J) min-reduction) and applies every
transition that fires at that instant as masked dense updates:

  round(t*):
    1. completions   — running jobs with t_finish <= t*  → DONE/FAILED/resubmit
    2. subsystems    — post-completion transitions (outage preemption,
                       DAG cascade-cancel, ...) via ``on_completions`` hooks
    3. arrivals      — pending jobs with arrival  <= t*  → QUEUED at the server
    4. assignment    — the policy plugin scores QUEUED jobs against sites;
                       feasible best-site rows become ASSIGNED (site queue)
    5. starts        — per-site FIFO-with-capacity: sort ASSIGNED rows by
                       (site, -priority, arrival), start the per-site prefix
                       whose cumulative core/memory demand fits free resources
    6. bookkeeping   — service times, failure sampling, counters, event log

The round body is an ordered phase pipeline over a *static* tuple of
``Subsystem`` hook bundles (DESIGN.md §7): each subsystem contributes clock
event sources, arrival gates, completion filters, post-completion
transitions, feasibility/speed modifiers, service-time adjustments, and event
log columns, and owns one slot of the generic ``EngineState.ext`` mapping.
Specialization happens at trace time — a run without a subsystem compiles to
the exact program the hand-written engine produced, with no ``lax.cond``
overhead (the golden-trace matrix pins all 8 on/off combinations).

FIFO-with-capacity ≡ sort + segmented prefix-sum + mask is the central
de-actorification trick (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .subsystems import RoundCtx, resolve_subsystems
from .types import (
    ASSIGNED,
    DONE,
    FAILED,
    N_STATES,
    PENDING,
    QUEUED,
    RUNNING,
    EngineState,
    EventLog,
    JobsState,
    SimResult,
    SiteState,
    make_log,
)

INF = jnp.float32(jnp.inf)


def compute_time(jobs: JobsState, sites: SiteState, site: jax.Array) -> jax.Array:
    """Amdahl-style compute term: ``work / (speed * c / (1 + gamma (c-1)))``
    so ``par_gamma`` can be calibrated per site."""
    c = jobs.cores.astype(jnp.float32)
    gamma = sites.par_gamma[site]
    speedup = c / (1.0 + gamma * jnp.maximum(c - 1.0, 0.0))
    return jobs.work / (sites.speed[site] * jnp.maximum(speedup, 1e-9))


def stage_in_time(
    jobs: JobsState, sites: SiteState, site: jax.Array, share_in: jax.Array
) -> jax.Array:
    """Flat-link stage-in: site latency + ``bytes_in`` over the ingress link
    shared equally among the ``share_in`` jobs staging concurrently."""
    bw_in = sites.bw_in[site] / jnp.maximum(share_in, 1.0)
    return sites.latency[site] + jobs.bytes_in / bw_in


def service_time(
    jobs: JobsState, sites: SiteState, site: jax.Array, share_in: jax.Array, share_out: jax.Array
) -> jax.Array:
    """Deterministic-at-start service time model (DESIGN.md §2 network note).

    t = latency + stage_in + compute + stage_out, where stage bandwidth is the
    site link shared among the ``share`` jobs staging concurrently.  This is
    the flat-link model; jobs with a catalogued dataset replace the latency +
    stage-in terms with a replica-aware WAN transfer (DESIGN.md §3).
    """
    bw_out = sites.bw_out[site] / jnp.maximum(share_out, 1.0)
    return (
        stage_in_time(jobs, sites, site, share_in)
        + compute_time(jobs, sites, site)
        + jobs.bytes_out / bw_out
    )


@functools.lru_cache(maxsize=None)
def _int_segment_sum(num_segments: int):
    """Integer ``segment_sum`` that picks its lowering by batching context.

    Solo runs use the native scatter-add — O(J) work and O(J) memory, which
    matters at WLCG scale where a one-hot ``[J, S+1]`` intermediate is ~100MB+
    per call.  Under ``vmap`` (ensembles) the ``def_vmap`` rule switches to a
    one-hot contraction: on CPU a *batched* scatter is the single most
    expensive op in an ensemble round (~6x a one-hot matmul at K=16, J=320 —
    DESIGN.md §8).  Integer sums are exact in any reduction order, so the two
    lowerings are bit-for-bit identical in every context.
    """

    @jax.custom_batching.custom_vmap
    def seg_sum(values: jax.Array, seg: jax.Array) -> jax.Array:
        return jax.ops.segment_sum(values, seg, num_segments=num_segments)

    @seg_sum.def_vmap
    def _seg_sum_batched(axis_size, in_batched, values, seg):
        vb, sb = in_batched
        if not vb:
            values = jnp.broadcast_to(values, (axis_size,) + values.shape)
        if not sb:
            seg = jnp.broadcast_to(seg, (axis_size,) + seg.shape)
        onehot = (seg[..., None] == jnp.arange(num_segments, dtype=seg.dtype)).astype(
            values.dtype
        )
        return jnp.einsum("...j,...js->...s", values, onehot), True

    return seg_sum


def _segment_sum_small(values: jax.Array, seg: jax.Array, num_segments: int) -> jax.Array:
    """``segment_sum`` specialized for the engine's few-segment reductions.

    Integer (and bool) values dispatch through ``_int_segment_sum`` — a
    scatter-add solo and a one-hot contraction under ``vmap`` (both exact for
    ints, so bit-for-bit identical).  Float values keep ``segment_sum``'s
    sequential accumulation order — reordering float adds would shift low
    bits and break the golden traces.
    """
    if jnp.issubdtype(values.dtype, jnp.integer) or values.dtype == jnp.bool_:
        # bool saturates under einsum (logical OR), so count in int32
        values = values.astype(jnp.int32) if values.dtype == jnp.bool_ else values
        return _int_segment_sum(num_segments)(values, seg)
    return jax.ops.segment_sum(values, seg, num_segments=num_segments)


def _site_sum(values: jax.Array, site: jax.Array, num_sites: int) -> jax.Array:
    """Scatter per-job values onto their site: ``segment_sum`` with one extra
    padding segment (site == ``num_sites``) for non-participating rows.

    The ubiquitous engine scatter — completions, preemption, starts, and log
    pressure columns all reduce job rows to per-site totals this way.
    """
    return _segment_sum_small(values, site, num_sites + 1)[:num_sites]


@functools.lru_cache(maxsize=None)
def _int_segment_sum_stacked(num_segments: int):
    """``_int_segment_sum`` for feature-stacked int values ``[J, F] -> [seg, F]``.

    One scatter pass over J for F columns sharing segment ids, instead of F
    separate passes — the completion and start phases each fold their integer
    per-site reductions through this (integer adds are order-exact, so the
    stacking is bit-for-bit identical to the separate calls it replaces).
    """

    @jax.custom_batching.custom_vmap
    def seg_sum(values: jax.Array, seg: jax.Array) -> jax.Array:
        return jax.ops.segment_sum(values, seg, num_segments=num_segments)

    @seg_sum.def_vmap
    def _seg_sum_batched(axis_size, in_batched, values, seg):
        vb, sb = in_batched
        if not vb:
            values = jnp.broadcast_to(values, (axis_size,) + values.shape)
        if not sb:
            seg = jnp.broadcast_to(seg, (axis_size,) + seg.shape)
        onehot = (seg[..., None] == jnp.arange(num_segments, dtype=seg.dtype)).astype(
            values.dtype
        )
        return jnp.einsum("...jf,...js->...sf", values, onehot), True

    return seg_sum


def _site_sum_stacked(values: jax.Array, site: jax.Array, num_sites: int) -> jax.Array:
    """``_site_sum`` over int features stacked in the trailing axis ``[J, F]``."""
    return _int_segment_sum_stacked(num_sites + 1)(values, site)[:num_sites]


# Below this job capacity a *solo* run computes the start order by pairwise
# ranking instead of ``jnp.lexsort`` (the O(J^2) comparison matrix wins for
# small J on CPU).  Ensembles never hit either per-lane path: ``_start_order``
# carries a ``custom_vmap`` rule that flattens the whole batch into ONE
# lane-major lexsort — under vmap a 16-way ensemble used to pay ~18x one sort
# per round through batched ``lax.sort`` (the DESIGN.md §7 note), now it pays
# a single O(KJ log KJ) sort.  All paths produce the *same* permutation — the
# job-index tiebreak makes the order strict, so the rank is unique — and the
# downstream cumulative sums fold in the identical sequence, keeping results
# bit-for-bit equal.
_PAIRWISE_ORDER_MAX_J = 512


@jax.custom_batching.custom_vmap
def _start_order(
    sort_site: jax.Array, priority: jax.Array, rank_val: jax.Array, arrival: jax.Array
) -> jax.Array:
    """Start-order permutation by (site, -priority, -rank, arrival, index)."""
    J = sort_site.shape[-1]
    idx = jnp.arange(J)
    if J > _PAIRWISE_ORDER_MAX_J:
        return jnp.lexsort((idx, arrival, -rank_val, -priority, sort_site))

    def asc(k):  # strictly-before / tie masks on one [J, J] key level
        return k[:, None] < k[None, :], k[:, None] == k[None, :]

    def desc(k):
        return k[:, None] > k[None, :], k[:, None] == k[None, :]

    s_lt, s_eq = asc(sort_site)
    p_lt, p_eq = desc(priority)
    r_lt, r_eq = desc(rank_val)
    a_lt, a_eq = asc(arrival)
    before = s_lt | (
        s_eq & (p_lt | (p_eq & (r_lt | (r_eq & (a_lt | (a_eq & (idx[:, None] < idx[None, :])))))))
    )
    rank = jnp.sum(before, axis=0, dtype=jnp.int32)   # unique in [0, J)
    return jnp.zeros((J,), jnp.int32).at[rank].set(idx)


@_start_order.def_vmap
def _start_order_batched(axis_size, in_batched, sort_site, priority, rank_val, arrival):
    """Batched start order as ONE lane-major flattened lexsort (DESIGN.md §8).

    The lane id is the most-significant sort key, so rows of the flat
    permutation group by lane and each lane's block is exactly the
    permutation its solo run computes (the key tuple is a strict total order
    thanks to the index tiebreak, so *any* correct sort yields the identical
    permutation — bit-for-bit lane equivalence is preserved).
    """
    K = axis_size
    site_b, prio_b, rank_b, arr_b = (
        x if b else jnp.broadcast_to(x, (K,) + x.shape)
        for x, b in zip((sort_site, priority, rank_val, arrival), in_batched)
    )
    J = site_b.shape[-1]
    lane = jnp.broadcast_to(jnp.arange(K, dtype=jnp.int32)[:, None], (K, J)).reshape(-1)
    idx = jnp.broadcast_to(jnp.arange(J, dtype=jnp.int32)[None, :], (K, J)).reshape(-1)
    perm = jnp.lexsort(
        (idx, arr_b.reshape(-1), -rank_b.reshape(-1), -prio_b.reshape(-1),
         site_b.reshape(-1), lane)
    )
    order = perm.reshape(K, J).astype(jnp.int32) - (jnp.arange(K, dtype=jnp.int32) * J)[:, None]
    return order, True


@jax.custom_batching.custom_vmap
def _start_order_packed(packed: jax.Array) -> jax.Array:
    """Start-order permutation from a single strict-total-order i32 key.

    The packed key is ``sort_site * J + srank`` where ``srank`` is the
    (init-time) rank of each job under ``(-priority, arrival, index)`` — a
    bijection onto ``[0, J)``, so the packed keys are all distinct and *any*
    sort yields the identical permutation ``_start_order`` computes with its
    5-level lexsort.  One single-key argsort per round instead of a 5-key
    lexsort is the difference between the sort dominating and vanishing from
    the per-round profile at J=100k (DESIGN.md §12).  Only valid while
    priority/arrival are run-constant (nothing in the engine or the stock
    subsystems mutates them) and the policy has no dynamic ``rank`` fn.
    ``stable=False`` is safe for the same reason any sort is: distinct keys
    admit exactly one sorted permutation.
    """
    return jnp.argsort(packed, stable=False).astype(jnp.int32)


@_start_order_packed.def_vmap
def _start_order_packed_batched(axis_size, in_batched, packed):
    """Batched packed order: ONE lane-major flattened 2-key lexsort, same
    construction as ``_start_order_batched`` (lane id most significant)."""
    K = axis_size
    p = packed if in_batched[0] else jnp.broadcast_to(packed, (K,) + packed.shape)
    J = p.shape[-1]
    lane = jnp.broadcast_to(jnp.arange(K, dtype=jnp.int32)[:, None], (K, J)).reshape(-1)
    perm = jnp.lexsort((p.reshape(-1), lane))
    order = perm.reshape(K, J).astype(jnp.int32) - (jnp.arange(K, dtype=jnp.int32) * J)[:, None]
    return order, True


def _static_start_rank(jobs) -> jax.Array:
    """``i32[J]``: rank of each job under ``(-priority, arrival, index)`` —
    the run-constant suffix of the start-order key (see ``_start_order_packed``)."""
    J = jobs.capacity
    perm = jnp.lexsort((jnp.arange(J), jobs.arrival, -jobs.priority))
    return jnp.zeros((J,), jnp.int32).at[perm].set(jnp.arange(J, dtype=jnp.int32))


def _packed_order_ok(policy, J: int, S: int) -> bool:
    """Static predicate: can this run use the packed single-key start order?
    Needs a rank-less policy (dynamic ranks change the key mid-run) and the
    packed key ``site * J + srank`` to fit int32 (site spans [0, S])."""
    return getattr(policy, "rank", None) is None and (S + 1) * J <= 2**31 - 1


@jax.custom_batching.custom_vmap
def _ensemble_any(pred: jax.Array) -> jax.Array:
    """Identity on a scalar bool — except under ``vmap``, where it reduces to
    a single *unbatched* ``any`` over the whole batch.

    This is what keeps the phase-skip guard a real scalar ``lax.cond`` inside
    a vmapped ensemble: the round body branches on "does ANY lane have
    dispatchable work", and lanes without work execute the taken branch as an
    exact no-op (DESIGN.md §8).  A lane is therefore always bit-for-bit equal
    to its solo run, while a fully drained batch (or mesh shard) skips the
    assignment/start phases outright.
    """
    return pred


@_ensemble_any.def_vmap
def _ensemble_any_batched(axis_size, in_batched, pred):
    return jnp.any(pred, axis=0) if in_batched[0] else pred, False


def _segment_exclusive_base(values: jax.Array, seg_ids: jax.Array, num_segments: int):
    """For values sorted by seg_ids: per-element cumulative sum *within* its segment."""
    total_cum = jnp.cumsum(values)
    seg_totals = _segment_sum_small(values, seg_ids, num_segments)
    seg_base = jnp.concatenate([jnp.zeros((1,), values.dtype), jnp.cumsum(seg_totals)[:-1]])
    return total_cum - seg_base[seg_ids]


def default_assign(scores: jax.Array, queued: jax.Array, feasible: jax.Array, sites=None):
    """Reference assignment: best feasible site per queued job (site-queue mode).

    Returns (site[J] int32 with -1 for unassigned, assigned_mask[J]).
    Capacity-constrained assignment is provided by ``repro.kernels.assign``.
    """
    neg = jnp.float32(-jnp.inf)
    masked = jnp.where(feasible, scores, neg)
    best = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    best_val = jnp.max(masked, axis=-1)
    ok = queued & jnp.isfinite(best_val)
    return jnp.where(ok, best, -1), ok


def default_assign_cand(scores_k, queued, feas_k, cand, sites=None):
    """Candidate-set analogue of ``default_assign`` (DESIGN.md §12).

    ``scores_k``/``feas_k`` are ``[J, K]`` over the candidate index ``cand``
    (clamped site ids, ascending per row).  Because candidates are sorted
    ascending, the slot argmax picks the lowest site id among score ties —
    the same tie-break ``jnp.argmax`` applies over the dense ``[J, S]`` row,
    so ``topk=S`` matches the dense path bit-for-bit.
    """
    neg = jnp.float32(-jnp.inf)
    masked = jnp.where(feas_k, scores_k, neg)
    best_c = jnp.argmax(masked, axis=-1)
    best_val = jnp.max(masked, axis=-1)
    site = jnp.take_along_axis(cand, best_c[:, None], axis=-1)[..., 0].astype(jnp.int32)
    ok = queued & jnp.isfinite(best_val)
    return jnp.where(ok, site, -1), ok


def _init_state(
    jobs0: JobsState,
    sites0: SiteState,
    policy,
    rng: jax.Array,
    ext0: dict,
    subsystems: tuple,
    log_rows: int,
    topk: int | None = None,
) -> EngineState:
    """Build the round-loop carry: run policy/subsystem init hooks, allocate
    the frame ring buffer, seat the extension states."""
    policy_state0 = policy.init(jobs0, sites0)
    ext0 = dict(ext0)
    for sub in subsystems:
        if sub.init is not None:
            ext0[sub.name] = sub.init(sub, ext0[sub.name], jobs0, sites0)
    if topk is not None:
        # sparse-mode candidate index (DESIGN.md §12): engine-internal carry
        # keys start with "~" and are dropped from SimResult.ext in _finalize
        from .sparse import CAND_SALT, build_candidates

        ext0["~cand"] = build_candidates(
            jobs0, sites0, policy, policy_state0, jnp.float32(0.0),
            jax.random.fold_in(rng, CAND_SALT), ext0, topk,
        )
    # the packed key assumes run-constant arrivals — a subsystem that pushes
    # arrivals (faults resubmission backoff) disables the fast path statically
    mutates_arrival = any(
        getattr(sub.config, "mutates_arrival", False) for sub in subsystems
    )
    if not mutates_arrival and _packed_order_ok(policy, jobs0.capacity, sites0.capacity):
        # run-constant start-order key suffix (see _start_order_packed)
        ext0["~srank"] = _static_start_rank(jobs0)
    log_extra0 = {}
    for sub in subsystems:
        if sub.log_spec is not None:
            log_extra0.update(sub.log_spec(sub, ext0[sub.name], jobs0, sites0))
    log0 = make_log(log_rows, sites0.capacity, extra=log_extra0)
    return EngineState(
        clock=jnp.float32(0.0),
        round=jnp.int32(0),
        jobs=jobs0,
        sites=sites0,
        rng=rng,
        policy_state=policy_state0,
        log=log0,
        halted=jnp.array(False),
        ext=ext0,
    )


def _round_fns(
    policy,
    subsystems: tuple,
    *,
    max_rounds: int,
    log_rows: int,
    max_retries: int,
    monitor_every: int,
    quantum: float,
    phase_skip: bool,
    topk: int | None = None,
    topk_refresh: int = 0,
):
    """Build the engine while-loop's ``(cond, body)`` pair for one static
    configuration.  ``cond`` takes the horizon as a second (traced) argument
    so segmented drivers (``advance_sim``/``monitor.watch``) re-enter the
    *same* compiled loop with a different stopping time per segment — the
    round sequence of a run is identical whether it executes in one
    ``while_loop`` or paused-and-resumed across many."""

    def cond(st: EngineState, horizon):
        active = (
            (st.jobs.state == PENDING)
            | (st.jobs.state == QUEUED)
            | (st.jobs.state == ASSIGNED)
            | (st.jobs.state == RUNNING)
        )
        return (
            (~st.halted)
            & jnp.any(active & st.jobs.valid)
            & (st.round < max_rounds)
            & (st.clock <= horizon)
        )

    def body(st: EngineState) -> EngineState:
        S = st.sites.capacity
        J = st.jobs.capacity
        jobs, sites = st.jobs, st.sites
        rng, k_fail, k_frac, k_policy = jax.random.split(st.rng, 4)
        ctx = RoundCtx(
            jobs=jobs, sites=sites, ext=dict(st.ext),
            clock_prev=st.clock, max_retries=max_retries,
            # per-subsystem RNG streams fold off the round's carry key (see
            # RoundCtx.subkey); the split above is untouched, so subsystem
            # draws never shift the engine's own bitstream
            rng=st.rng,
        )

        # ---- 1. advance the clock to the next event ------------------------
        arrivable = (jobs.state == PENDING) & jobs.valid
        for sub in subsystems:
            if sub.arrival_gate is not None:
                # gated jobs are not an event source: their wake-up event is
                # whatever un-gates them (e.g. a DAG parent's completion)
                arrivable = arrivable & sub.arrival_gate(sub, ctx)
        arr_t = jnp.where(arrivable, jobs.arrival, INF)
        fin_t = jnp.where(jobs.state == RUNNING, jobs.t_finish, INF)
        t_next = jnp.minimum(arr_t.min(), fin_t.min())
        for sub in subsystems:
            if sub.event_times is not None:
                # subsystem event sources (e.g. outage window edges) join the
                # min-reduction so rounds land exactly on their boundaries
                t_next = jnp.minimum(t_next, sub.event_times(sub, ctx))
        if quantum > 0.0:
            t_next = t_next + quantum
        clock = jnp.where(jnp.isfinite(t_next), jnp.maximum(st.clock, t_next), st.clock)
        ctx.clock = clock

        # ---- 2. completions -------------------------------------------------
        comp = (jobs.state == RUNNING) & (jobs.t_finish <= clock)
        for sub in subsystems:
            if sub.completion_filter is not None:
                comp = sub.completion_filter(sub, ctx, comp)
        comp_site = jnp.where(comp, jobs.site, S)  # padded segment for non-events
        freed_mem = _site_sum(jnp.where(comp, jobs.memory, 0.0), comp_site, S)
        failed_now = comp & jobs.will_fail
        resubmit = failed_now & (jobs.retries < max_retries)
        perm_fail = failed_now & ~resubmit
        done_now = comp & ~jobs.will_fail
        # one stacked scatter for the three int per-site completion reductions
        comp_sums = _site_sum_stacked(
            jnp.stack(
                [
                    jnp.where(comp, jobs.cores, 0),
                    done_now.astype(jnp.int32),
                    failed_now.astype(jnp.int32),
                ],
                axis=-1,
            ),
            comp_site,
            S,
        )
        freed_cores = comp_sums[..., 0]

        new_state = jobs.state
        new_state = jnp.where(done_now, DONE, new_state)
        new_state = jnp.where(perm_fail, FAILED, new_state)
        new_state = jnp.where(resubmit, QUEUED, new_state)  # PanDA-style resubmission
        jobs = jobs._replace(
            state=new_state,
            retries=jobs.retries + resubmit.astype(jnp.int32),
            site=jnp.where(resubmit, -1, jobs.site),
            t_finish=jnp.where(resubmit, INF, jobs.t_finish),
        )
        sites = sites._replace(
            free_cores=sites.free_cores + freed_cores,
            free_memory=sites.free_memory + freed_mem,
            n_finished=sites.n_finished + comp_sums[..., 1],
            n_failed=sites.n_failed + comp_sums[..., 2],
        )
        ctx.jobs, ctx.sites = jobs, sites
        ctx.comp, ctx.done_now, ctx.failed_now = comp, done_now, failed_now

        # ---- 2b. subsystem post-completion transitions -----------------------
        # (availability preemption/brown-out, workflow cascade-cancel, ...)
        for sub in subsystems:
            if sub.on_completions is not None:
                sub.on_completions(sub, ctx)
        jobs, sites = ctx.jobs, ctx.sites

        # ---- 3. arrivals -----------------------------------------------------
        arrived = (jobs.state == PENDING) & (jobs.arrival <= clock) & jobs.valid
        for sub in subsystems:
            if sub.arrival_gate is not None:
                # re-gate against post-completion states so a job un-gated
                # *this round* arrives (and can start) this round
                arrived = arrived & sub.arrival_gate(sub, ctx)
        jobs = jobs._replace(state=jnp.where(arrived, QUEUED, jobs.state))
        ctx.jobs, ctx.arrived = jobs, arrived

        # ---- 4+5. assignment & starts -----------------------------------------
        queued = jobs.state == QUEUED
        if topk is not None and topk_refresh > 0:
            # periodic candidate rebuild (DESIGN.md §12): O(J*S) behind a
            # scalar cond so non-refresh rounds never touch dense shapes.
            # ``_ensemble_any`` keeps the cond scalar under vmap — lanes of
            # an ensemble therefore refresh on shared rounds (exact only at
            # k >= S, where rebuilds are idempotent).
            from .sparse import CAND_SALT, build_candidates

            do_refresh = _ensemble_any(jnp.mod(st.round, topk_refresh) == 0)
            ctx.ext["~cand"] = jax.lax.cond(
                do_refresh,
                lambda ops: build_candidates(
                    ops[0], ops[1], policy, st.policy_state, clock,
                    jax.random.fold_in(st.rng, CAND_SALT), ctx.ext, topk,
                ),
                lambda ops: ctx.ext["~cand"],
                (jobs, sites),
            )
        if topk is None:
            # static feasibility: job can ever fit the site
            ctx.feasible = (
                sites.active[None, :]
                & (jobs.cores[:, None] <= sites.cores[None, :])
                & (jobs.memory[:, None] <= sites.memory[None, :])
            )
        else:
            # sparse mode: the static core/memory fit lives in the candidate
            # index; per-round feasibility starts as a per-site [1, S] mask
            # that pre_assign hooks compose with [None, :]-broadcast masks
            # (availability does).  A hook may still write a full [J, S] —
            # the gather below dispatches on the leading dim.
            ctx.feasible = sites.active[None, :]
        ctx.start_cores = sites.free_cores
        ctx.sites_serv = sites
        for sub in subsystems:
            if sub.pre_assign is not None:
                sub.pre_assign(sub, ctx)
        pstate = st.policy_state
        rank_fn = getattr(policy, "rank", None)
        feasible, start_cores = ctx.feasible, ctx.start_cores

        def _assign_and_start(ops):
            """Phases 4 (policy assignment, the plugin hot spot) and 5
            (per-site FIFO-with-capacity starts), exactly as the unguarded
            engine ran them.  With no QUEUED or ASSIGNED rows every update in
            here is a masked no-op, which is what makes the phase-skip guard
            below bit-for-bit safe."""
            jobs, sites = ops
            if topk is None:
                scores = policy.score(jobs, sites, pstate, clock, k_policy)  # [J, S]
                site_pick, assigned_now = policy.assign(scores, queued, feasible, sites)
            else:
                cand = ctx.ext["~cand"]                     # i32[J, K]
                cand_c = jnp.minimum(cand, S - 1)
                # re-check everything the dense mask carries, gathered at the
                # candidates: validity, per-round dynamic feasibility, and the
                # static core/memory fit (exact at k=S, where ``cand``
                # enumerates every statically feasible site)
                f_at = (
                    feasible[0][cand_c]
                    if feasible.shape[0] == 1
                    else jnp.take_along_axis(feasible, cand_c, axis=-1)
                )
                feas_k = (
                    (cand < S)
                    & f_at
                    & (jobs.cores[:, None] <= sites.cores[cand_c])
                    & (jobs.memory[:, None] <= sites.memory[cand_c])
                )
                score_c = getattr(policy, "score_cand", None)
                if score_c is not None:
                    scores_k = score_c(jobs, sites, pstate, clock, k_policy, cand_c)
                else:
                    # exact fallback: dense score + gather (no memory win)
                    scores_k = jnp.take_along_axis(
                        policy.score(jobs, sites, pstate, clock, k_policy), cand_c, axis=-1
                    )
                assign_c = getattr(policy, "assign_cand", None) or default_assign_cand
                site_pick, assigned_now = assign_c(scores_k, queued, feas_k, cand_c, sites)
            assigned_now = assigned_now & queued
            jobs = jobs._replace(
                state=jnp.where(assigned_now, ASSIGNED, jobs.state),
                site=jnp.where(assigned_now, site_pick, jobs.site),
                t_assign=jnp.where(assigned_now, clock, jobs.t_assign),
            )
            asg_site = jnp.where(assigned_now, site_pick, S)
            sites = sites._replace(
                n_assigned=sites.n_assigned
                + _site_sum(assigned_now.astype(jnp.int32), asg_site, S)
            )

            cand = jobs.state == ASSIGNED
            sort_site = jnp.where(cand, jobs.site, S).astype(jnp.int32)
            if "~srank" in st.ext:
                # packed fast path: one single-key sort, provably the same
                # permutation as the 5-key lexsort (see _start_order_packed)
                order = _start_order_packed(sort_site * J + ctx.ext["~srank"])
            else:
                # policy rank is a secondary start-order key: priority still
                # dominates, rank breaks ties before arrival time (a rank-less
                # policy contributes a constant key, which the stable lexsort
                # ignores)
                rank_val = (
                    jnp.zeros((J,), jnp.float32) if rank_fn is None
                    else rank_fn(jobs, sites, pstate, clock)
                )
                order = _start_order(sort_site, jobs.priority, rank_val, jobs.arrival)
            site_s = sort_site[order]
            cand_s = cand[order]
            cores_s = jnp.where(cand_s, jobs.cores[order], 0).astype(jnp.int32)
            mem_s = jnp.where(cand_s, jobs.memory[order], 0.0)
            cum_cores = _segment_exclusive_base(cores_s, site_s, S + 1)
            cum_mem = _segment_exclusive_base(mem_s, site_s, S + 1)
            fits = (
                cand_s
                & (cum_cores <= start_cores[jnp.minimum(site_s, S - 1)])
                & (cum_mem <= sites.free_memory[jnp.minimum(site_s, S - 1)] + 1e-6)
                & (site_s < S)
            )
            started = jnp.zeros((J,), bool).at[order].set(fits)
            return jobs, sites, started

        if phase_skip:
            # phase-skip guard (DESIGN.md §8): completion-only rounds — the
            # rounds that dominate a draining ensemble lane — skip the score
            # matrix, the start-order sort, and the segmented prefix sums
            # entirely.  ``_ensemble_any`` reduces the predicate over the
            # whole vmap batch, so the cond stays scalar (a real branch, not
            # a select) inside ensembles and mesh shards alike.
            has_work = _ensemble_any(jnp.any(queued | (jobs.state == ASSIGNED)))
            jobs, sites, started = jax.lax.cond(
                has_work,
                _assign_and_start,
                lambda ops: (ops[0], ops[1], jnp.zeros((J,), bool)),
                (jobs, sites),
            )
        else:
            jobs, sites, started = _assign_and_start((jobs, sites))
        ctx.jobs, ctx.sites = jobs, sites

        start_site = jnp.where(started, jobs.site, S)
        start_sums = _site_sum_stacked(
            jnp.stack(
                [jnp.where(started, jobs.cores, 0), started.astype(jnp.int32)], axis=-1
            ),
            start_site,
            S,
        )
        used_cores = start_sums[..., 0]
        used_mem = _site_sum(jnp.where(started, jobs.memory, 0.0), start_site, S)
        n_start_per_site = start_sums[..., 1]
        site_c = jnp.minimum(jobs.site, S - 1)
        share = n_start_per_site[site_c].astype(jnp.float32)

        # ---- 5b. service times + subsystem adjustments -----------------------
        ctx.started, ctx.site_c = started, site_c
        ctx.share, ctx.start_site = share, start_site
        ctx.t_serv = service_time(jobs, ctx.sites_serv, site_c, share, share)
        for sub in subsystems:
            if sub.on_start is not None:
                # e.g. workflow output materialization, then replica-aware
                # stage-in repricing (DESIGN.md §3/§6) — tuple order matters
                sub.on_start(sub, ctx)
        jobs = ctx.jobs
        t_serv = ctx.t_serv

        u_fail = jax.random.uniform(k_fail, (J,))
        # clip (not minimum): unassigned rows carry site == -1, and minimum
        # would map them to the *last* site's fail rate — masked by `started`
        # today, but an OOB/NaN-hygiene hazard under refactors
        will_fail = started & (u_fail < sites.fail_rate[jnp.clip(jobs.site, 0, S - 1)])
        # a failing attempt dies partway through its service time
        frac = jax.random.uniform(k_frac, (J,), minval=0.05, maxval=1.0)
        t_fin = clock + jnp.where(will_fail, t_serv * frac, t_serv)

        jobs = jobs._replace(
            state=jnp.where(started, RUNNING, jobs.state),
            t_start=jnp.where(started, clock, jobs.t_start),
            t_finish=jnp.where(started, t_fin, jobs.t_finish),
            will_fail=jnp.where(started, will_fail, jobs.will_fail),
        )
        sites = sites._replace(
            free_cores=sites.free_cores - used_cores,
            free_memory=sites.free_memory - used_mem,
        )
        ctx.jobs, ctx.sites = jobs, sites

        pstate = policy.on_step(pstate, jobs, sites, comp, started, clock)

        # ---- 6. halt detection & event log -----------------------------------
        n_started = started.sum()
        n_completed = comp.sum()
        # subsystem transitions (preemption, cascade rounds) count as progress
        # so halt detection gives the dispatcher a round to react to them
        progressed = (n_started > 0) | (n_completed > 0) | jnp.any(arrived) | ctx.progressed
        halted = (~jnp.isfinite(t_next)) & ~progressed

        log = st.log
        if log_rows > 0:
            slot = jnp.mod(log.cursor, log_rows)
            write = jnp.mod(st.round, monitor_every) == 0

            def _log_write(operand):
                log, ext = operand
                # branch-local ext: subsystem log hooks may update engine
                # state (e.g. the data subsystem's between-writes WAN
                # accumulator), so ext rides the cond carry
                ctx.ext = dict(ext)
                counts = jax.vmap(
                    lambda s: jnp.sum((jobs.state == s) & jobs.valid).astype(jnp.int32)
                )(jnp.arange(N_STATES))
                q_site = jnp.where(jobs.state == ASSIGNED, jobs.site, S)
                r_site = jnp.where(jobs.state == RUNNING, jobs.site, S)
                site_queued = _site_sum(jnp.ones((J,), jnp.int32), q_site, S)
                site_running = _site_sum(jnp.ones((J,), jnp.int32), r_site, S)

                def wr(buf, val):
                    return jnp.where(write, buf.at[slot].set(val), buf)

                extra = dict(log.extra)
                for sub in subsystems:
                    if sub.log_columns is not None:
                        for k, v in sub.log_columns(sub, ctx, write).items():
                            extra[k] = wr(extra[k], v)
                return EventLog(
                    time=wr(log.time, clock),
                    round_idx=wr(log.round_idx, st.round),
                    counts=wr(log.counts, counts),
                    n_started=wr(log.n_started, n_started.astype(jnp.int32)),
                    n_completed=wr(log.n_completed, n_completed.astype(jnp.int32)),
                    site_free=wr(log.site_free, sites.free_cores),
                    site_queued=wr(log.site_queued, site_queued),
                    site_running=wr(log.site_running, site_running),
                    extra=extra,
                    cursor=log.cursor + write.astype(jnp.int32),
                ), ctx.ext

            # the log reductions (two segment sums + a per-state count sweep)
            # are real per-round work at WLCG scale; behind a scalar cond,
            # rounds between monitor samples skip them entirely (``wr`` still
            # selects per lane, so a mixed-write ensemble batch stays exact)
            log, ctx.ext = jax.lax.cond(
                _ensemble_any(write), _log_write, lambda op: op, (log, dict(ctx.ext))
            )

        return EngineState(
            clock=clock,
            round=st.round + 1,
            jobs=jobs,
            sites=sites,
            rng=rng,
            policy_state=pstate,
            log=log,
            halted=halted,
            ext=ctx.ext,
        )

    return cond, body


def _finalize(st: EngineState, policy, subsystems: tuple) -> SimResult:
    """End-of-run hooks (policy ``on_end``, subsystem ``finalize``) plus
    SimResult assembly — shared by the one-shot jit and the segmented API."""
    pstate = policy.on_end(st.policy_state, st.jobs, st.sites, st.clock)
    # "~"-prefixed keys are engine-internal carry (e.g. the sparse candidate
    # index): dropped here so sparse results keep the dense pytree structure
    ext = {k: v for k, v in st.ext.items() if not k.startswith("~")}
    result_fields = {}
    for sub in subsystems:
        if sub.finalize is not None:
            ext[sub.name], fields = sub.finalize(sub, ext[sub.name], st.jobs, st.sites, st.clock)
            result_fields.update(fields)
    return SimResult(
        makespan=st.clock,
        rounds=st.round,
        jobs=st.jobs,
        sites=st.sites,
        log=st.log,
        policy_state=pstate,
        ext=ext,
        **result_fields,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy",
        "subsystems",
        "max_rounds",
        "log_rows",
        "max_retries",
        "monitor_every",
        "quantum",
        "phase_skip",
        "topk",
        "topk_refresh",
    ),
)
def _simulate(
    jobs0: JobsState,
    sites0: SiteState,
    policy,
    rng: jax.Array,
    ext0: dict,
    *,
    subsystems: tuple = (),
    max_rounds: int = 100_000,
    horizon: float = float("inf"),
    log_rows: int = 0,
    max_retries: int = 3,
    monitor_every: int = 1,
    quantum: float = 0.0,
    phase_skip: bool = True,
    topk: int | None = None,
    topk_refresh: int = 0,
) -> SimResult:
    """The jitted phase pipeline; ``subsystems`` is a static Subsystem tuple,
    ``ext0`` the matching name -> state pytree mapping (see subsystems.py)."""
    if topk is not None:
        topk = min(int(topk), sites0.capacity)  # k >= S is exactly dense
    st0 = _init_state(jobs0, sites0, policy, rng, ext0, subsystems, log_rows, topk)
    cond, body = _round_fns(
        policy,
        subsystems,
        max_rounds=max_rounds,
        log_rows=log_rows,
        max_retries=max_retries,
        monitor_every=monitor_every,
        quantum=quantum,
        phase_skip=phase_skip,
        topk=topk,
        topk_refresh=topk_refresh,
    )
    st = jax.lax.while_loop(lambda s: cond(s, horizon), body, st0)
    return _finalize(st, policy, subsystems)


def simulate(
    jobs0: JobsState,
    sites0: SiteState,
    policy,
    rng: jax.Array,
    *,
    data_policy=None,
    network=None,
    replicas=None,
    availability=None,
    workflow=None,
    transfers=None,
    faults=None,
    subsystems=(),
    max_rounds: int = 100_000,
    horizon: float = float("inf"),
    log_rows: int = 0,
    max_retries: int = 3,
    monitor_every: int = 1,
    quantum: float = 0.0,
    phase_skip: bool = True,
    topk: int | None = None,
    topk_refresh: int = 0,
    recorder=None,
) -> SimResult:
    """Run the grid simulation to completion (or ``max_rounds``/``horizon``).

    ``recorder`` (a ``telemetry.TraceRecorder``) makes the run observable at
    the jit boundary: the call is split into a ``trace_compile`` (cache miss)
    or ``dispatch`` (cache hit) span plus an ``execute`` span
    (``block_until_ready``), and rounds-executed / round-budget / early-exit
    counters are recorded.  ``None`` (the default) adds no host syncs and no
    overhead — results are bit-for-bit identical either way.

    ``phase_skip`` (default on) guards the assignment + start phases behind a
    scalar ``lax.cond`` on "any QUEUED/ASSIGNED rows": completion-only rounds
    skip the score matrix, start-order sort, and segmented prefix sums
    entirely, with bit-for-bit identical results (DESIGN.md §8).  ``False``
    forces the unguarded pipeline (the equivalence is property-tested).

    ``topk`` switches assignment to the sparse candidate-set path
    (DESIGN.md §12): scores are evaluated over a static ``i32[J, topk]``
    candidate-site index instead of the dense ``[J, S]`` matrix — the
    WLCG-scale lever (S=300, J=100k).  ``topk >= S`` is bit-for-bit equal to
    the dense path; smaller k is a documented approximation.  The index is
    built once at init from static feasibility, data locality, and the
    policy pre-rank; ``topk_refresh=N`` rebuilds it every N rounds (0 =
    never) so load/locality-sensitive pre-ranks stay current.

    ``quantum`` > 0 batches all events inside [t*, t* + quantum] into one
    round (SimGrid-style time-precision knob): timestamps quantize to the
    window but each round retires many events — the lever that turns
    O(events) rounds into O(horizon/quantum) for dense workloads (paper
    Fig. 4 scaling regime).

    Engine extensions are ``Subsystem`` hook bundles (DESIGN.md §7) composed
    into the round loop at trace time.  The built-in trio keeps its keyword
    API — each maps onto a subsystem in canonical order:

    - ``data_policy=`` (with ``network=`` and ``replicas=``) switches stage-in
      for dataset-carrying jobs to the replica-aware WAN model: each starting
      job reads its dataset from the policy-selected replica over the shared
      link matrix (zero-cost local cache hits), and the policy may
      cache-on-read into the site's storage element (DESIGN.md §3).  Jobs with
      ``dataset == -1`` — and every run without a data policy — keep the flat
      per-site link model.

    - ``availability=`` (an ``AvailabilityState`` downtime calendar) turns on
      availability dynamics (DESIGN.md §5): window edges become event rounds,
      full outages block assignment/starts and either preempt running jobs
      (back to QUEUED with a retry) or drain them, and brown-out windows scale
      a site's effective speed and usable cores by the window factor.

    - ``workflow=`` (a ``WorkflowState`` DAG, DESIGN.md §6) gates the
      dispatcher on dependencies: a job stays PENDING until every parent is
      DONE, a terminally failed parent cascade-cancels its descendants, and —
      when the data subsystem is on — each completing parent materializes its
      ``jobs.out_dataset`` into the replica catalog at the site it ran on.

    - ``faults=`` (a ``FaultState`` from ``make_faults``, DESIGN.md §13) adds
      fault injection and recovery: per-link transfer failures with
      exponential-backoff re-enqueue, resubmission backoff, walltime kills, a
      replica-loss calendar, and adaptive site blacklisting with a half-open
      circuit breaker.  The default-constructed state is inert.

    ``subsystems=((Subsystem, state0), ...)`` appends custom subsystems after
    the built-ins (see ``examples/custom_subsystem.py``).  Every ``None``/
    absent subsystem costs nothing: specialization is static, so such runs
    stay bit-for-bit identical to an engine compiled without the subsystem.
    """
    subs, ext0 = resolve_subsystems(
        data_policy=data_policy,
        network=network,
        replicas=replicas,
        availability=availability,
        workflow=workflow,
        transfers=transfers,
        faults=faults,
        subsystems=subsystems,
        jobs=jobs0,
        sites=sites0,
    )
    kw = dict(
        subsystems=subs,
        max_rounds=max_rounds,
        horizon=horizon,
        log_rows=log_rows,
        max_retries=max_retries,
        monitor_every=monitor_every,
        quantum=quantum,
        phase_skip=phase_skip,
        topk=topk,
        topk_refresh=topk_refresh,
    )
    if recorder is None:
        return _simulate(jobs0, sites0, policy, rng, ext0, **kw)

    # flight-recorder path: split the jit call into compile-vs-execute spans
    # (tracing+compilation is synchronous in the call, execution is async
    # until block_until_ready) and count rounds against the budget
    import time as _time

    cache_size = getattr(_simulate, "_cache_size", None)
    before = cache_size() if cache_size is not None else -1
    t0 = _time.perf_counter()
    res = _simulate(jobs0, sites0, policy, rng, ext0, **kw)
    t_call = _time.perf_counter() - t0
    compiled = cache_size is not None and cache_size() > before
    recorder.record("trace_compile" if compiled else "dispatch", t_call)
    with recorder.span("execute"):
        jax.block_until_ready(res)
    rounds = int(res.rounds)
    recorder.gauge("rounds_executed", rounds)
    recorder.gauge("round_budget", max_rounds)
    recorder.gauge("early_exit_rounds", max(max_rounds - rounds, 0))
    recorder.gauge("n_jobs", int(np.asarray(jobs0.valid).sum()))
    recorder.gauge("n_sites", sites0.capacity)
    recorder.note("jit_cache_hit", not compiled)
    recorder.note("subsystems", [s.name for s in subs])
    return res


# --------------------------------------------------------------------------
# segmented execution: pause/resume the round loop between frames
# --------------------------------------------------------------------------


class SimHandle(NamedTuple):
    """A paused simulation: the while-loop carry plus everything needed to
    resume it.  Produced by ``init_sim``, advanced by ``advance_sim``,
    finished by ``finish_sim`` — the substrate of ``monitor.watch`` and of
    any streaming driver that wants frames *between* jit re-entries rather
    than inside the hot loop."""

    state: EngineState
    policy: object
    subsystems: tuple
    statics: tuple  # (max_rounds, log_rows, max_retries, monitor_every, quantum,
    #                  phase_skip, topk, topk_refresh)

    @property
    def max_rounds(self) -> int:
        return self.statics[0]


def init_sim(
    jobs0: JobsState,
    sites0: SiteState,
    policy,
    rng: jax.Array,
    *,
    data_policy=None,
    network=None,
    replicas=None,
    availability=None,
    workflow=None,
    transfers=None,
    faults=None,
    subsystems=(),
    max_rounds: int = 100_000,
    log_rows: int = 0,
    max_retries: int = 3,
    monitor_every: int = 1,
    quantum: float = 0.0,
    phase_skip: bool = True,
    topk: int | None = None,
    topk_refresh: int = 0,
) -> SimHandle:
    """Initialize a resumable simulation (same kwargs as ``simulate`` minus
    ``horizon``, which ``advance_sim`` takes per segment)."""
    from .subsystems import resolve_subsystems as _resolve

    subs, ext0 = _resolve(
        data_policy=data_policy,
        network=network,
        replicas=replicas,
        availability=availability,
        workflow=workflow,
        transfers=transfers,
        faults=faults,
        subsystems=subsystems,
        jobs=jobs0,
        sites=sites0,
    )
    if topk is not None:
        topk = min(int(topk), sites0.capacity)
    st0 = _init_state(jobs0, sites0, policy, rng, ext0, subs, log_rows, topk)
    statics = (max_rounds, log_rows, max_retries, monitor_every, quantum, phase_skip,
               topk, topk_refresh)
    return SimHandle(state=st0, policy=policy, subsystems=subs, statics=statics)


@functools.lru_cache(maxsize=None)
def _segment_fn(policy, subsystems: tuple, statics: tuple):
    """The cached jitted segment runner: the exact engine while loop with the
    horizon as a *dynamic* argument, so every segment of every run with the
    same static configuration shares one compile."""
    (max_rounds, log_rows, max_retries, monitor_every, quantum, phase_skip,
     topk, topk_refresh) = statics
    cond, body = _round_fns(
        policy,
        subsystems,
        max_rounds=max_rounds,
        log_rows=log_rows,
        max_retries=max_retries,
        monitor_every=monitor_every,
        quantum=quantum,
        phase_skip=phase_skip,
        topk=topk,
        topk_refresh=topk_refresh,
    )

    def run(st: EngineState, horizon):
        return jax.lax.while_loop(lambda s: cond(s, horizon), body, st)

    return jax.jit(run)


def advance_sim(handle: SimHandle, horizon: float = float("inf")) -> SimHandle:
    """Run rounds until the clock passes ``horizon`` (or the run drains).

    Because ``cond`` checks the clock *before* each round, resuming with a
    larger horizon continues the identical round sequence a single
    ``simulate`` call would have executed — segmentation changes where the
    loop pauses, never what it computes (property-tested bit-for-bit)."""
    run = _segment_fn(handle.policy, tuple(handle.subsystems), handle.statics)
    return handle._replace(state=run(handle.state, jnp.float32(horizon)))


def sim_active(handle: SimHandle) -> bool:
    """Host-side: would the round loop still run, given an open horizon?"""
    st = handle.state
    if bool(st.halted) or int(st.round) >= handle.max_rounds:
        return False
    state = np.asarray(st.jobs.state)
    valid = np.asarray(st.jobs.valid)
    active = (
        (state == PENDING) | (state == QUEUED) | (state == ASSIGNED) | (state == RUNNING)
    )
    return bool((active & valid).any())


def finish_sim(handle: SimHandle) -> SimResult:
    """Run end-of-run hooks on a (drained or abandoned) handle."""
    return _finalize(handle.state, handle.policy, tuple(handle.subsystems))


# --------------------------------------------------------------------------
# scenario ensembles: one compile, many simulations
# --------------------------------------------------------------------------


class Scenario(NamedTuple):
    """One point of a scenario ensemble: a workload + platform + per-scenario
    subsystem states (calendars, catalogs, DAGs) keyed by subsystem name.

    Feed a list of these (identical shapes/treedefs) to ``simulate_many`` —
    or pre-stack them with ``stack_scenarios`` — to batch the whole ensemble
    through one vmapped compile.
    """

    jobs: JobsState
    sites: SiteState
    ext: dict | None = None


class ScenarioBuckets(NamedTuple):
    """A ragged ensemble grouped into a few padded shape buckets.

    ``buckets[b]`` is a stacked ``Scenario`` whose jobs are padded only to
    that bucket's largest capacity — instead of every scenario paying dense
    rows up to the *global* max J (the padding tax of one-bucket stacking).
    ``index[b]`` holds each lane's position in the original scenario list, so
    results reassemble in caller order (and lane ``i`` draws the same RNG key
    it would in a single-bucket stack).
    """

    buckets: tuple  # tuple[Scenario], each stacked with leading K_b
    index: tuple    # tuple[tuple[int, ...]] original scenario positions

    @property
    def n_scenarios(self) -> int:
        return sum(len(ix) for ix in self.index)

    def padding_stats(self) -> dict:
        """Measure the padding tax this bucketing actually pays.

        Returns per-bucket rows (capacity, lanes, used vs padded job rows,
        waste fraction) plus a summary comparing against the one-bucket
        alternative (every lane dense to the global max capacity) — the
        saved-row count that justifies the extra compiles."""
        rows = []
        total_rows = total_used = 0
        for b, (scn, ix) in enumerate(zip(self.buckets, self.index)):
            cap = scn.jobs.capacity
            lanes = len(ix)
            used = int(np.asarray(scn.jobs.valid).sum())
            dense = lanes * cap
            rows.append(
                dict(
                    bucket=b,
                    capacity=cap,
                    lanes=lanes,
                    used_rows=used,
                    padded_rows=dense - used,
                    waste_frac=float((dense - used) / dense) if dense else 0.0,
                )
            )
            total_rows += dense
            total_used += used
        cap_max = max(r["capacity"] for r in rows)
        flat_rows = self.n_scenarios * cap_max
        return dict(
            buckets=rows,
            summary=dict(
                n_buckets=len(rows),
                n_scenarios=self.n_scenarios,
                total_rows=total_rows,
                used_rows=total_used,
                waste_frac=(
                    float((total_rows - total_used) / total_rows) if total_rows else 0.0
                ),
                flat_rows=flat_rows,
                flat_waste_frac=(
                    float((flat_rows - total_used) / flat_rows) if flat_rows else 0.0
                ),
                saved_rows=flat_rows - total_rows,
            ),
        )


def stack_scenarios(scenarios, *, subsystems: tuple = (), buckets: int = 1):
    """Stack a list of Scenarios into one leading-K pytree.

    Ragged workloads (different job counts per scenario) are canonicalized by
    padding every ``jobs`` to the largest capacity with inert rows — the
    static-shape normalization that lets the whole ensemble share a single
    compile where a ``simulate`` loop would retrace per size.  Job-shaped
    subsystem state (e.g. a workflow parent matrix) pads alongside through
    each subsystem's ``pad_jobs`` hook when ``subsystems`` is given
    (``simulate_many`` passes its own).  Sites and non-job-shaped subsystem
    state must already share shapes (pad calendars/catalogs with their
    builders' ``max_windows=``/``capacity=`` knobs).

    ``buckets > 1`` returns a ``ScenarioBuckets`` instead: scenarios are
    ordered by job capacity and split into up to ``buckets`` similar-size
    groups, each padded only to its own max — a few compiles instead of one,
    but far fewer wasted dense rows on very ragged ensembles (DESIGN.md §8).
    ``simulate_many`` and ``simulate_many_sharded`` dispatch per bucket and
    return results in the original scenario order.
    """
    from .subsystems import pad_ext_jobs
    from .types import pad_jobs_capacity

    scenarios = list(scenarios)
    if not scenarios:
        raise ValueError("need at least one scenario")
    if buckets > 1:
        order = sorted(range(len(scenarios)), key=lambda i: scenarios[i].jobs.capacity)
        groups = [g for g in np.array_split(order, min(buckets, len(scenarios))) if len(g)]
        return ScenarioBuckets(
            buckets=tuple(
                stack_scenarios([scenarios[i] for i in g], subsystems=subsystems)
                for g in groups
            ),
            index=tuple(tuple(int(i) for i in g) for g in groups),
        )
    cap = max(s.jobs.capacity for s in scenarios)
    norm = [
        Scenario(
            pad_jobs_capacity(s.jobs, cap),
            s.sites,
            pad_ext_jobs(subsystems, s.ext or {}, s.jobs.capacity, cap),
        )
        for s in scenarios
    ]
    return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *norm)


def _check_ensemble(scenarios: Scenario, subsystems: tuple) -> dict:
    """Validate a stacked ensemble against its subsystem tuple; returns ext."""
    ext = scenarios.ext or {}
    known = {sub.name for sub in subsystems}
    if set(ext) != known:
        raise ValueError(
            f"scenario ext keys {sorted(ext)} must match the attached "
            f"subsystems {sorted(known)} one-to-one"
        )
    for sub in subsystems:
        if sub.validate is not None:
            # shape checks use negative axes, so the leading K is transparent
            sub.validate(sub, ext[sub.name], scenarios.jobs, scenarios.sites)
    return ext


def _simulate_many_stacked(
    scenarios: Scenario, policy, keys: jax.Array, *, subsystems: tuple = (), **kw
) -> SimResult:
    """The vmapped ensemble core: one compile, per-lane RNG keys supplied."""
    ext = _check_ensemble(scenarios, subsystems)

    def one(jobs, sites, ext_k, key):
        return _simulate(jobs, sites, policy, key, ext_k, subsystems=subsystems, **kw)

    return jax.vmap(one)(scenarios.jobs, scenarios.sites, ext, keys)


def _pad_result_jobs(jobs: JobsState, capacity: int) -> JobsState:
    """Pad the trailing job axis of a leading-K ``JobsState`` with inert rows
    (the ``types.JOB_PAD_FILLS`` fixed point) — how bucketed results rejoin a
    common shape."""
    from .types import JOB_PAD_FILLS

    J = jobs.capacity
    if capacity == J:
        return jobs
    n = capacity - J

    def pad(name, x):
        fill = JOB_PAD_FILLS.get(name, 0)
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, n)], constant_values=fill)

    return JobsState(**{k: pad(k, v) for k, v in jobs._asdict().items()})


# legacy SimResult accessors that alias a subsystem's ext slot; after a
# bucketed merge re-pads ext, the aliases must point at the padded state
_EXT_ALIASES = {"workflow": ("wf",), "availability": ("avail",)}


def _pad_result_to(res: SimResult, subsystems: tuple, capacity: int) -> SimResult:
    """Grow one bucket's SimResult to the ensemble-wide job capacity."""
    J_b = res.jobs.capacity
    repl = {"jobs": _pad_result_jobs(res.jobs, capacity)}
    if J_b != capacity and res.ext:
        ext = dict(res.ext)
        for sub in subsystems:
            if sub.pad_jobs is not None and sub.name in ext:
                padded = jax.vmap(lambda s: sub.pad_jobs(sub, s, J_b, capacity))(
                    ext[sub.name]
                )
                ext[sub.name] = padded
                for field in _EXT_ALIASES.get(sub.name, ()):
                    if getattr(res, field) is not None:
                        repl[field] = padded
        repl["ext"] = ext
    return res._replace(**repl)


@functools.lru_cache(maxsize=None)
def _bucket_merger(subsystems: tuple, cap: int, inv: tuple):
    """Jitted bucket-result reassembly (pad to the common capacity, concat,
    un-permute): one program instead of hundreds of eager per-leaf dispatches
    — the merge is on the hot path of every bucketed ensemble call."""
    inv_a = jnp.asarray(inv)

    def merge(*results):
        padded = [_pad_result_to(r, subsystems, cap) for r in results]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0)[inv_a], *padded)

    return jax.jit(merge)


def _run_buckets(sb: ScenarioBuckets, rng: jax.Array, runner, subsystems):
    """Dispatch a bucketed ensemble through ``runner(stacked, keys)`` per
    bucket, then reassemble one SimResult in the original scenario order.

    Lane ``i`` draws ``split(rng, K)[i]`` exactly as it would in a
    single-bucket stack, so bucketing is invisible to the results (the merge
    re-pads each bucket's jobs/ext to the global max capacity with inert
    rows — the same rows single-bucket stacking would have carried through
    the whole run).
    """
    keys = jax.random.split(rng, sb.n_scenarios)
    cap = max(s.jobs.capacity for s in sb.buckets)
    results = [
        runner(scen, keys[np.asarray(ix)]) for scen, ix in zip(sb.buckets, sb.index)
    ]
    inv = np.argsort(np.concatenate([np.asarray(ix) for ix in sb.index]))
    merge = _bucket_merger(tuple(subsystems), cap, tuple(int(i) for i in inv))
    return merge(*results)


def simulate_many(
    scenarios,
    policy,
    rng: jax.Array,
    *,
    subsystems: tuple = (),
    **kw,
) -> SimResult:
    """Batched ensemble execution: K scenarios, one compile, one device program.

    ``scenarios`` is a list of ``Scenario``s (stacked here), an already
    stacked ``Scenario`` whose leaves carry a leading K axis, or a
    ``ScenarioBuckets`` from ``stack_scenarios(..., buckets=n)`` (dispatched
    per bucket, one compile per distinct shape) — stacked workloads,
    platforms (speeds), and subsystem states (outage calendars, replica
    catalogs, workflow DAGs) all vary per scenario.  ``subsystems`` is a
    tuple of the static ``Subsystem`` bundles matching the keys of
    ``Scenario.ext`` (empty for plain runs).  Each scenario gets its own RNG
    stream; the returned ``SimResult`` has a leading K axis on every leaf, in
    the original scenario order.

    This is the surrogate-dataset / design-space lever (ROADMAP): the paper
    runs scenarios one process at a time, a vmapped ensemble retires them in
    lockstep rounds at device throughput (``benchmarks/bench_engine_rounds``).
    To spread the ensemble over a device mesh — and break the global
    lock-step — see ``distributed.simulate_many_sharded``.
    """
    if isinstance(scenarios, ScenarioBuckets):
        runner = lambda scen, keys: _simulate_many_stacked(  # noqa: E731
            scen, policy, keys, subsystems=subsystems, **kw
        )
        return _run_buckets(scenarios, rng, runner, subsystems)
    if not isinstance(scenarios, Scenario):
        scenarios = stack_scenarios(scenarios, subsystems=subsystems)
    K = scenarios.jobs.arrival.shape[0]
    return _simulate_many_stacked(
        scenarios, policy, jax.random.split(rng, K), subsystems=subsystems, **kw
    )


def simulate_ensemble(
    jobs0: JobsState,
    sites0: SiteState,
    policy,
    rng: jax.Array,
    *,
    speed_candidates: jax.Array,  # f32[K, S] per-site speeds to evaluate
    **kw,
) -> SimResult:
    """vmap the full simulation over K per-site speed vectors (calibration inner loop)."""

    def one(speed, key):
        sites = sites0._replace(speed=speed)
        return simulate(jobs0, sites, policy, key, **kw)

    keys = jax.random.split(rng, speed_candidates.shape[0])
    return jax.vmap(one)(speed_candidates, keys)


def walltimes(result: SimResult) -> jax.Array:
    """Per-job walltime (t_finish - t_start); inf for jobs that never ran."""
    return result.jobs.t_finish - result.jobs.t_start


def queue_times(result: SimResult) -> jax.Array:
    return result.jobs.t_start - result.jobs.arrival


AssignFn = Callable[[jax.Array, jax.Array, jax.Array, SiteState], tuple]
