"""Vectorized discrete-event engine.

SimGrid runs one event at a time through coroutine actors.  On an accelerator
we instead run *event rounds*: a ``lax.while_loop`` whose body advances the
clock to the next event time (an O(J) min-reduction) and applies every
transition that fires at that instant as masked dense updates:

  round(t*):
    1. completions   — running jobs with t_finish <= t*  → DONE/FAILED/resubmit
    2. availability  — sites whose outage window covers t* preempt running
                       jobs (→ QUEUED with a retry) or drain; brown-outs scale
                       effective speed/cores (DESIGN.md §5)
    2c. workflow     — DAG gate: terminally-failed parents cascade-cancel
                       descendants; children unlock when all parents are DONE
                       (DESIGN.md §6)
    3. arrivals      — pending jobs with arrival  <= t*  → QUEUED at the server
    4. assignment    — the policy plugin scores QUEUED jobs against sites;
                       feasible best-site rows become ASSIGNED (site queue)
    5. starts        — per-site FIFO-with-capacity: sort ASSIGNED rows by
                       (site, -priority, arrival), start the per-site prefix
                       whose cumulative core/memory demand fits free resources
    6. bookkeeping   — service times, failure sampling, counters, event log

With an ``AvailabilityState`` the clock min-reduction also includes the next
window start/end, so availability transitions are exact event rounds.

FIFO-with-capacity ≡ sort + segmented prefix-sum + mask is the central
de-actorification trick (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .types import (
    ASSIGNED,
    DONE,
    FAILED,
    N_STATES,
    PENDING,
    QUEUED,
    RUNNING,
    EngineState,
    EventLog,
    JobsState,
    SimResult,
    SiteState,
    make_log,
)

INF = jnp.float32(jnp.inf)


def compute_time(jobs: JobsState, sites: SiteState, site: jax.Array) -> jax.Array:
    """Amdahl-style compute term: ``work / (speed * c / (1 + gamma (c-1)))``
    so ``par_gamma`` can be calibrated per site."""
    c = jobs.cores.astype(jnp.float32)
    gamma = sites.par_gamma[site]
    speedup = c / (1.0 + gamma * jnp.maximum(c - 1.0, 0.0))
    return jobs.work / (sites.speed[site] * jnp.maximum(speedup, 1e-9))


def stage_in_time(
    jobs: JobsState, sites: SiteState, site: jax.Array, share_in: jax.Array
) -> jax.Array:
    """Flat-link stage-in: site latency + ``bytes_in`` over the ingress link
    shared equally among the ``share_in`` jobs staging concurrently."""
    bw_in = sites.bw_in[site] / jnp.maximum(share_in, 1.0)
    return sites.latency[site] + jobs.bytes_in / bw_in


def service_time(
    jobs: JobsState, sites: SiteState, site: jax.Array, share_in: jax.Array, share_out: jax.Array
) -> jax.Array:
    """Deterministic-at-start service time model (DESIGN.md §2 network note).

    t = latency + stage_in + compute + stage_out, where stage bandwidth is the
    site link shared among the ``share`` jobs staging concurrently.  This is
    the flat-link model; jobs with a catalogued dataset replace the latency +
    stage-in terms with a replica-aware WAN transfer (DESIGN.md §3).
    """
    bw_out = sites.bw_out[site] / jnp.maximum(share_out, 1.0)
    return (
        stage_in_time(jobs, sites, site, share_in)
        + compute_time(jobs, sites, site)
        + jobs.bytes_out / bw_out
    )


def _segment_exclusive_base(values: jax.Array, seg_ids: jax.Array, num_segments: int):
    """For values sorted by seg_ids: per-element cumulative sum *within* its segment."""
    total_cum = jnp.cumsum(values)
    seg_totals = jax.ops.segment_sum(values, seg_ids, num_segments=num_segments)
    seg_base = jnp.concatenate([jnp.zeros((1,), values.dtype), jnp.cumsum(seg_totals)[:-1]])
    return total_cum - seg_base[seg_ids]


def default_assign(scores: jax.Array, queued: jax.Array, feasible: jax.Array, sites=None):
    """Reference assignment: best feasible site per queued job (site-queue mode).

    Returns (site[J] int32 with -1 for unassigned, assigned_mask[J]).
    Capacity-constrained assignment is provided by ``repro.kernels.assign``.
    """
    neg = jnp.float32(-jnp.inf)
    masked = jnp.where(feasible, scores, neg)
    best = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    best_val = jnp.max(masked, axis=-1)
    ok = queued & jnp.isfinite(best_val)
    return jnp.where(ok, best, -1), ok


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy",
        "data_policy",
        "max_rounds",
        "log_rows",
        "max_retries",
        "monitor_every",
        "quantum",
    ),
)
def simulate(
    jobs0: JobsState,
    sites0: SiteState,
    policy,
    rng: jax.Array,
    *,
    data_policy=None,
    network=None,
    replicas=None,
    availability=None,
    workflow=None,
    max_rounds: int = 100_000,
    horizon: float = float("inf"),
    log_rows: int = 0,
    max_retries: int = 3,
    monitor_every: int = 1,
    quantum: float = 0.0,
) -> SimResult:
    """Run the grid simulation to completion (or ``max_rounds``/``horizon``).

    ``quantum`` > 0 batches all events inside [t*, t* + quantum] into one
    round (SimGrid-style time-precision knob): timestamps quantize to the
    window but each round retires many events — the lever that turns
    O(events) rounds into O(horizon/quantum) for dense workloads (paper
    Fig. 4 scaling regime).

    Passing a ``data_policy`` (with a ``NetworkState`` and a ``ReplicaState``)
    switches stage-in for dataset-carrying jobs to the replica-aware WAN
    model: each starting job reads its dataset from the policy-selected
    replica over the shared link matrix (zero-cost local cache hits), and the
    policy may cache-on-read into the site's storage element (DESIGN.md §3).
    Jobs with ``dataset == -1`` — and every run without a data policy — keep
    the flat per-site link model, so existing callers are unchanged.

    Passing an ``availability`` (an ``AvailabilityState`` downtime calendar)
    turns on availability dynamics (DESIGN.md §5): window edges become event
    rounds, full outages block assignment/starts and either preempt running
    jobs (back to QUEUED with a retry; progress is lost) or drain them, and
    brown-out windows scale a site's effective speed and usable cores by the
    window factor.  Runs with ``availability=None`` take a code path with no
    extra ops or RNG draws, so they stay bit-for-bit identical to the
    pre-availability engine.

    Passing a ``workflow`` (a ``WorkflowState`` DAG, DESIGN.md §6) gates the
    dispatcher on dependencies: a job stays PENDING until every parent is
    DONE, a terminally failed parent cascade-cancels its descendants (one
    DAG level per round, counted in ``wf.n_cancelled``), and — when the data
    subsystem is on — each completing parent materializes its
    ``jobs.out_dataset`` into the replica catalog at the site it ran on, so
    children's stage-in is priced from where the parent actually executed.
    ``workflow=None`` adds no ops or RNG draws: bit-for-bit identical to the
    workflow-free engine.
    """
    S = sites0.capacity
    J = jobs0.capacity
    policy_state0 = policy.init(jobs0, sites0)
    log0 = make_log(log_rows, S)
    data_on = data_policy is not None
    if data_on:
        if network is None or replicas is None:
            raise ValueError("data_policy requires both network= and replicas=")
        from .network import shared_transfer_times
        from .replicas import insert_replicas, touch

        replicas0, data_state0 = data_policy.init(jobs0, sites0, network, replicas)
    else:
        replicas0, data_state0 = None, ()
    avail_on = availability is not None
    if avail_on:
        from .availability import availability_factor, next_window_edge, preempting_sites

        if availability.win_start.shape[-2] != S:
            raise ValueError(
                f"availability has {availability.win_start.shape[-2]} sites, platform has {S}"
            )
    wf_on = workflow is not None
    if wf_on:
        from .types import CANCELLED
        from .workflows import parent_status

        if workflow.parents.shape[-2] != J:
            raise ValueError(
                f"workflow has {workflow.parents.shape[-2]} job rows, workload has {J}"
            )
        if data_on:
            from .replicas import materialize_outputs

    def cond(st: EngineState):
        active = (
            (st.jobs.state == PENDING)
            | (st.jobs.state == QUEUED)
            | (st.jobs.state == ASSIGNED)
            | (st.jobs.state == RUNNING)
        )
        return (
            (~st.halted)
            & jnp.any(active & st.jobs.valid)
            & (st.round < max_rounds)
            & (st.clock <= horizon)
        )

    def body(st: EngineState) -> EngineState:
        jobs, sites = st.jobs, st.sites
        rng, k_fail, k_frac, k_policy = jax.random.split(st.rng, 4)

        # ---- 1. advance the clock to the next event ------------------------
        arrivable = (jobs.state == PENDING) & jobs.valid
        if wf_on:
            # gated jobs are not an event source: their wake-up event is the
            # last parent's completion, which fin_t already carries
            ready0, _ = parent_status(st.wf.parents, jobs.state)
            arrivable = arrivable & ready0
        arr_t = jnp.where(arrivable, jobs.arrival, INF)
        fin_t = jnp.where(jobs.state == RUNNING, jobs.t_finish, INF)
        t_next = jnp.minimum(arr_t.min(), fin_t.min())
        if avail_on:
            # window starts/ends are event sources: rounds land exactly on edges
            t_next = jnp.minimum(t_next, next_window_edge(st.avail, st.clock))
        if quantum > 0.0:
            t_next = t_next + quantum
        clock = jnp.where(jnp.isfinite(t_next), jnp.maximum(st.clock, t_next), st.clock)

        # ---- 2. completions -------------------------------------------------
        comp = (jobs.state == RUNNING) & (jobs.t_finish <= clock)
        if avail_on:
            # a preempting outage opening before the job's finish kills it
            # first; only reachable when quantum > 0 jumps the clock past
            # both the window start and t_finish in one round (at quantum=0
            # rounds land on every edge, so this mask is identically False).
            # The survivor stays RUNNING and step 2b preempts it.
            ksite = jnp.clip(jobs.site, 0, S - 1)
            ws = st.avail.win_start[ksite]                             # [J, W]
            wkill = st.avail.win_preempt[ksite] & (st.avail.win_factor[ksite] <= 0.0)
            killed_first = jnp.any(
                wkill & (ws > st.clock) & (ws < jobs.t_finish[:, None]), axis=-1
            )
            comp = comp & ~killed_first
        comp_site = jnp.where(comp, jobs.site, S)  # padded segment for non-events
        freed_cores = jax.ops.segment_sum(
            jnp.where(comp, jobs.cores, 0), comp_site, num_segments=S + 1
        )[:S]
        freed_mem = jax.ops.segment_sum(
            jnp.where(comp, jobs.memory, 0.0), comp_site, num_segments=S + 1
        )[:S]
        failed_now = comp & jobs.will_fail
        resubmit = failed_now & (jobs.retries < max_retries)
        perm_fail = failed_now & ~resubmit
        done_now = comp & ~jobs.will_fail

        new_state = jobs.state
        new_state = jnp.where(done_now, DONE, new_state)
        new_state = jnp.where(perm_fail, FAILED, new_state)
        new_state = jnp.where(resubmit, QUEUED, new_state)  # PanDA-style resubmission
        jobs = jobs._replace(
            state=new_state,
            retries=jobs.retries + resubmit.astype(jnp.int32),
            site=jnp.where(resubmit, -1, jobs.site),
            t_finish=jnp.where(resubmit, INF, jobs.t_finish),
        )
        sites = sites._replace(
            free_cores=sites.free_cores + freed_cores,
            free_memory=sites.free_memory + freed_mem,
            n_finished=sites.n_finished
            + jax.ops.segment_sum(done_now.astype(jnp.int32), comp_site, num_segments=S + 1)[:S],
            n_failed=sites.n_failed
            + jax.ops.segment_sum(failed_now.astype(jnp.int32), comp_site, num_segments=S + 1)[:S],
        )

        # ---- 2b. availability: outage preemption & brown-out scaling ---------
        avail = st.avail
        pre = jnp.zeros((J,), bool)
        if avail_on:
            factor = availability_factor(avail, clock)     # f32[S]
            # brown-out: a factor-f window caps usable cores at floor(f*cores);
            # a site whose cap floors to 0 is a de facto outage, so the
            # dispatcher routes around it just like a factor-0 window
            eff_cap = jnp.floor(sites.cores.astype(jnp.float32) * factor).astype(jnp.int32)
            avail_up = eff_cap > 0
            # preempt: running jobs on a site whose preempting outage overlaps
            # (prev clock, clock] lose this attempt now (completions above
            # already retired jobs whose t_finish <= clock, so a job finishing
            # at the edge still finishes; interval overlap keeps windows
            # shorter than a quantum from being skipped)
            site_c0 = jnp.clip(jobs.site, 0, S - 1)
            preempting = preempting_sites(avail, st.clock, clock)[site_c0]
            pre = (jobs.state == RUNNING) & preempting
            pre_resub = pre & (jobs.retries < max_retries)
            pre_fail = pre & ~pre_resub
            pre_site = jnp.where(pre, jobs.site, S)
            # jobs still waiting in the dead site's queue bounce back to the
            # server — no attempt was lost, so no retry — instead of sitting
            # stranded behind an outage while other sites idle (drain windows
            # leave the site queue paused, as announced maintenance does)
            bounce = (jobs.state == ASSIGNED) & preempting
            jobs = jobs._replace(
                state=jnp.where(
                    pre_resub | bounce, QUEUED, jnp.where(pre_fail, FAILED, jobs.state)
                ),
                retries=jobs.retries + pre_resub.astype(jnp.int32),
                site=jnp.where(pre_resub | bounce, -1, jobs.site),
                t_finish=jnp.where(pre_resub, INF, jnp.where(pre_fail, clock, jobs.t_finish)),
                preempted=jobs.preempted + pre.astype(jnp.int32),
            )
            sites = sites._replace(
                free_cores=sites.free_cores
                + jax.ops.segment_sum(
                    jnp.where(pre, jobs.cores, 0), pre_site, num_segments=S + 1
                )[:S],
                free_memory=sites.free_memory
                + jax.ops.segment_sum(
                    jnp.where(pre, jobs.memory, 0.0), pre_site, num_segments=S + 1
                )[:S],
            )
            avail = avail._replace(
                n_preempted=avail.n_preempted
                + jax.ops.segment_sum(pre.astype(jnp.int32), pre_site, num_segments=S + 1)[:S]
            )
        else:
            factor = jnp.ones((S,), jnp.float32)

        # ---- 2c. workflow DAG: cascade-cancel + dependency gate --------------
        wf = st.wf
        cancel_now = ()
        if wf_on:
            # recompute against post-completion states so a child whose last
            # parent finished *this round* arrives (and can start) this round
            ready, dead = parent_status(wf.parents, jobs.state)
            # a dead ancestor can only be seen from PENDING: children never
            # leave PENDING before all parents are DONE, and DONE is terminal
            cancel_now = (jobs.state == PENDING) & jobs.valid & dead
            jobs = jobs._replace(state=jnp.where(cancel_now, CANCELLED, jobs.state))
            wf = wf._replace(n_cancelled=wf.n_cancelled + cancel_now.sum().astype(jnp.int32))

        # ---- 3. arrivals -----------------------------------------------------
        arrived = (jobs.state == PENDING) & (jobs.arrival <= clock) & jobs.valid
        if wf_on:
            arrived = arrived & ready
        jobs = jobs._replace(state=jnp.where(arrived, QUEUED, jobs.state))

        # ---- 4. policy assignment (the plugin hot spot) ----------------------
        queued = jobs.state == QUEUED
        # static feasibility: job can ever fit the site
        feasible = (
            sites.active[None, :]
            & (jobs.cores[:, None] <= sites.cores[None, :])
            & (jobs.memory[:, None] <= sites.memory[None, :])
        )
        if avail_on:
            # the dispatcher routes around sites currently in a full outage
            feasible = feasible & avail_up[None, :]
        pstate = st.policy_state
        scores = policy.score(jobs, sites, pstate, clock, k_policy)  # [J, S]
        site_pick, assigned_now = policy.assign(scores, queued, feasible, sites)
        assigned_now = assigned_now & queued
        jobs = jobs._replace(
            state=jnp.where(assigned_now, ASSIGNED, jobs.state),
            site=jnp.where(assigned_now, site_pick, jobs.site),
            t_assign=jnp.where(assigned_now, clock, jobs.t_assign),
        )
        asg_site = jnp.where(assigned_now, site_pick, S)
        sites = sites._replace(
            n_assigned=sites.n_assigned
            + jax.ops.segment_sum(assigned_now.astype(jnp.int32), asg_site, num_segments=S + 1)[:S]
        )

        # ---- 5. starts: per-site FIFO with capacity --------------------------
        if avail_on:
            # starts only claim cores up to the brown-out cap net of busy
            # ones, at speed scaled by the window factor; a full outage
            # (eff_cap = 0) admits no starts at all
            busy = sites.cores - sites.free_cores
            start_cores = jnp.clip(eff_cap - busy, 0, sites.free_cores)
            sites_serv = sites._replace(speed=jnp.maximum(sites.speed * factor, 1e-9))
        else:
            start_cores = sites.free_cores
            sites_serv = sites
        cand = jobs.state == ASSIGNED
        sort_site = jnp.where(cand, jobs.site, S).astype(jnp.int32)
        rank_fn = getattr(policy, "rank", None)
        if rank_fn is None:
            order = jnp.lexsort(
                (jnp.arange(J), jobs.arrival, -jobs.priority, sort_site)
            )
        else:
            # policy rank is a secondary start-order key: priority still
            # dominates, rank breaks ties before arrival time
            rank_val = rank_fn(jobs, sites, pstate, clock)
            order = jnp.lexsort(
                (jnp.arange(J), jobs.arrival, -rank_val, -jobs.priority, sort_site)
            )
        site_s = sort_site[order]
        cand_s = cand[order]
        cores_s = jnp.where(cand_s, jobs.cores[order], 0).astype(jnp.int32)
        mem_s = jnp.where(cand_s, jobs.memory[order], 0.0)
        cum_cores = _segment_exclusive_base(cores_s, site_s, S + 1)
        cum_mem = _segment_exclusive_base(mem_s, site_s, S + 1)
        fits = (
            cand_s
            & (cum_cores <= start_cores[jnp.minimum(site_s, S - 1)])
            & (cum_mem <= sites.free_memory[jnp.minimum(site_s, S - 1)] + 1e-6)
            & (site_s < S)
        )
        started = jnp.zeros((J,), bool).at[order].set(fits)

        start_site = jnp.where(started, jobs.site, S)
        used_cores = jax.ops.segment_sum(
            jnp.where(started, jobs.cores, 0), start_site, num_segments=S + 1
        )[:S]
        used_mem = jax.ops.segment_sum(
            jnp.where(started, jobs.memory, 0.0), start_site, num_segments=S + 1
        )[:S]
        n_start_per_site = jax.ops.segment_sum(
            started.astype(jnp.int32), start_site, num_segments=S + 1
        )[:S]
        site_c = jnp.minimum(jobs.site, S - 1)
        share = n_start_per_site[site_c].astype(jnp.float32)

        # ---- 5b. data movement: replica-aware stage-in (DESIGN.md §3) --------
        rep, dstate = st.replicas, st.data_state
        net_in_now = jnp.zeros((S,), jnp.float32)
        if data_on:
            if wf_on:
                # workflow output production (DESIGN.md §6): completing
                # parents materialize their output dataset at the site they
                # ran on — before source selection, so a child starting this
                # same round already stages in from the parent's site
                produced = done_now & (jobs.out_dataset >= 0)
                rep = materialize_outputs(
                    rep, jobs.out_dataset, jnp.clip(jobs.site, 0, S - 1), produced, clock
                )
                wf = wf._replace(
                    n_produced=wf.n_produced + produced.sum().astype(jnp.int32)
                )
            has_ds = jobs.dataset >= 0
            # only flat-link stage-ins contend for the site ingress link;
            # dataset jobs stage over the WAN matrix instead
            n_flat_start = jax.ops.segment_sum(
                (started & ~has_ds).astype(jnp.int32), start_site, num_segments=S + 1
            )[:S]
            share_in = n_flat_start[site_c].astype(jnp.float32)
            t_serv = service_time(jobs, sites_serv, site_c, share_in, share)
            D = rep.present.shape[0]
            d_c = jnp.clip(jobs.dataset, 0, D - 1)
            ds_bytes = rep.size[d_c]
            local = rep.present[d_c, site_c]
            read = started & has_ds
            src = data_policy.select_source(jobs, sites, network, rep, dstate, site_c, clock)
            src_c = jnp.clip(src, 0, S - 1)
            xfer = read & ~local
            t_net, _ = shared_transfer_times(network, src_c, site_c, ds_bytes, xfer)
            # swap the flat latency+stage-in terms for the WAN transfer
            in_flat = stage_in_time(jobs, sites_serv, site_c, share_in)
            t_serv = jnp.where(has_ds, t_serv - in_flat + t_net, t_serv)
            # catalog bookkeeping: touch LRU clocks, cache-on-read insertion
            rep = touch(rep, jobs.dataset, src_c, xfer, clock)
            rep = touch(rep, jobs.dataset, site_c, read & local, clock)
            want_cache = (
                data_policy.should_cache(jobs, sites, network, rep, dstate, site_c, clock) & xfer
            )
            rep = insert_replicas(rep, jobs.dataset, site_c, want_cache, clock)
            moved = jnp.where(xfer, ds_bytes, 0.0)
            rep = rep._replace(
                n_hits=rep.n_hits + (read & local).sum().astype(jnp.int32),
                n_transfers=rep.n_transfers + xfer.sum().astype(jnp.int32),
                bytes_moved=rep.bytes_moved + moved.sum(),
            )
            net_in_now = jax.ops.segment_sum(
                moved, jnp.where(xfer, jobs.site, S), num_segments=S + 1
            )[:S]
            jobs = jobs._replace(
                xfer_src=jnp.where(read, src_c, jobs.xfer_src),
                xfer_bytes=jnp.where(read, moved, jobs.xfer_bytes),
                xfer_time=jnp.where(read, t_net, jobs.xfer_time),
            )
            dstate = data_policy.on_step(dstate, jobs, rep, started, xfer, clock)
        else:
            t_serv = service_time(jobs, sites_serv, site_c, share, share)

        u_fail = jax.random.uniform(k_fail, (J,))
        will_fail = started & (u_fail < sites.fail_rate[jnp.minimum(jobs.site, S - 1)])
        # a failing attempt dies partway through its service time
        frac = jax.random.uniform(k_frac, (J,), minval=0.05, maxval=1.0)
        t_fin = clock + jnp.where(will_fail, t_serv * frac, t_serv)

        jobs = jobs._replace(
            state=jnp.where(started, RUNNING, jobs.state),
            t_start=jnp.where(started, clock, jobs.t_start),
            t_finish=jnp.where(started, t_fin, jobs.t_finish),
            will_fail=jnp.where(started, will_fail, jobs.will_fail),
        )
        sites = sites._replace(
            free_cores=sites.free_cores - used_cores,
            free_memory=sites.free_memory - used_mem,
        )

        pstate = policy.on_step(pstate, jobs, sites, comp, started, clock)
        disk_now = rep.disk_used if data_on else jnp.zeros((S,), jnp.float32)
        # accumulate WAN ingress between log writes so monitor_every > 1
        # still conserves bytes in the exported timeline
        net_acc = st.net_acc + net_in_now

        # ---- 6. halt detection & event log -----------------------------------
        n_started = started.sum()
        n_completed = comp.sum()
        progressed = (n_started > 0) | (n_completed > 0) | jnp.any(arrived)
        if avail_on:
            # a preemption round changed state: give the dispatcher one more
            # round to re-route the requeued jobs before halt detection
            progressed = progressed | jnp.any(pre)
        if wf_on:
            # a cancel round changed state: the cascade needs one round per
            # DAG level even when no timed event remains
            progressed = progressed | jnp.any(cancel_now)
        halted = (~jnp.isfinite(t_next)) & ~progressed

        log = st.log
        if log_rows > 0:
            slot = jnp.mod(log.cursor, log_rows)
            write = jnp.mod(st.round, monitor_every) == 0
            counts = jax.vmap(
                lambda s: jnp.sum((jobs.state == s) & jobs.valid).astype(jnp.int32)
            )(jnp.arange(N_STATES))
            q_site = jnp.where(jobs.state == ASSIGNED, jobs.site, S)
            r_site = jnp.where(jobs.state == RUNNING, jobs.site, S)
            site_queued = jax.ops.segment_sum(
                jnp.ones((J,), jnp.int32), q_site, num_segments=S + 1
            )[:S]
            site_running = jax.ops.segment_sum(
                jnp.ones((J,), jnp.int32), r_site, num_segments=S + 1
            )[:S]

            def wr(buf, val):
                return jnp.where(write, buf.at[slot].set(val), buf)

            log = EventLog(
                time=wr(log.time, clock),
                round_idx=wr(log.round_idx, st.round),
                counts=wr(log.counts, counts),
                n_started=wr(log.n_started, n_started.astype(jnp.int32)),
                n_completed=wr(log.n_completed, n_completed.astype(jnp.int32)),
                site_free=wr(log.site_free, sites.free_cores),
                site_queued=wr(log.site_queued, site_queued),
                site_running=wr(log.site_running, site_running),
                site_disk=wr(log.site_disk, disk_now),
                site_net_in=wr(log.site_net_in, net_acc),
                site_avail=wr(log.site_avail, factor),
                cursor=log.cursor + write.astype(jnp.int32),
            )
            net_acc = jnp.where(write, 0.0, net_acc)

        return EngineState(
            clock=clock,
            round=st.round + 1,
            jobs=jobs,
            sites=sites,
            rng=rng,
            policy_state=pstate,
            log=log,
            halted=halted,
            replicas=rep,
            data_state=dstate,
            net_acc=net_acc,
            avail=avail,
            wf=wf,
        )

    st0 = EngineState(
        clock=jnp.float32(0.0),
        round=jnp.int32(0),
        jobs=jobs0,
        sites=sites0,
        rng=rng,
        policy_state=policy_state0,
        log=log0,
        halted=jnp.array(False),
        replicas=replicas0,
        data_state=data_state0,
        net_acc=jnp.zeros((S,), jnp.float32),
        avail=availability if avail_on else (),
        wf=workflow if wf_on else (),
    )
    st = jax.lax.while_loop(cond, body, st0)
    pstate = policy.on_end(st.policy_state, st.jobs, st.sites, st.clock)
    dstate = (
        data_policy.on_end(st.data_state, st.jobs, st.replicas, st.clock) if data_on else ()
    )
    return SimResult(
        makespan=st.clock,
        rounds=st.round,
        jobs=st.jobs,
        sites=st.sites,
        log=st.log,
        policy_state=pstate,
        replicas=st.replicas,
        data_state=dstate,
        avail=st.avail if avail_on else None,
        wf=st.wf if wf_on else None,
    )


def simulate_ensemble(
    jobs0: JobsState,
    sites0: SiteState,
    policy,
    rng: jax.Array,
    *,
    speed_candidates: jax.Array,  # f32[K, S] per-site speeds to evaluate
    **kw,
) -> SimResult:
    """vmap the full simulation over K per-site speed vectors (calibration inner loop)."""

    def one(speed, key):
        sites = sites0._replace(speed=speed)
        return simulate(jobs0, sites, policy, key, **kw)

    keys = jax.random.split(rng, speed_candidates.shape[0])
    return jax.vmap(one)(speed_candidates, keys)


def walltimes(result: SimResult) -> jax.Array:
    """Per-job walltime (t_finish - t_start); inf for jobs that never ran."""
    return result.jobs.t_finish - result.jobs.t_start


def queue_times(result: SimResult) -> jax.Array:
    return result.jobs.t_start - result.jobs.arrival


AssignFn = Callable[[jax.Array, jax.Array, jax.Array, SiteState], tuple]
