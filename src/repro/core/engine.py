"""Vectorized discrete-event engine.

SimGrid runs one event at a time through coroutine actors.  On an accelerator
we instead run *event rounds*: a ``lax.while_loop`` whose body advances the
clock to the next event time (an O(J) min-reduction) and applies every
transition that fires at that instant as masked dense updates:

  round(t*):
    1. completions   — running jobs with t_finish <= t*  → DONE/FAILED/resubmit
    2. arrivals      — pending jobs with arrival  <= t*  → QUEUED at the server
    3. assignment    — the policy plugin scores QUEUED jobs against sites;
                       feasible best-site rows become ASSIGNED (site queue)
    4. starts        — per-site FIFO-with-capacity: sort ASSIGNED rows by
                       (site, -priority, arrival), start the per-site prefix
                       whose cumulative core/memory demand fits free resources
    5. bookkeeping   — service times, failure sampling, counters, event log

FIFO-with-capacity ≡ sort + segmented prefix-sum + mask is the central
de-actorification trick (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .types import (
    ASSIGNED,
    DONE,
    FAILED,
    N_STATES,
    PENDING,
    QUEUED,
    RUNNING,
    EngineState,
    EventLog,
    JobsState,
    SimResult,
    SiteState,
    make_log,
)

INF = jnp.float32(jnp.inf)


def service_time(
    jobs: JobsState, sites: SiteState, site: jax.Array, share_in: jax.Array, share_out: jax.Array
) -> jax.Array:
    """Deterministic-at-start service time model (DESIGN.md §2 network note).

    t = latency + stage_in + compute + stage_out, where stage bandwidth is the
    site link shared among the ``share`` jobs staging concurrently, and the
    compute term uses an Amdahl-style multicore speedup
    ``c / (1 + gamma (c - 1))`` so ``par_gamma`` can be calibrated per site.
    """
    lat = sites.latency[site]
    bw_in = sites.bw_in[site] / jnp.maximum(share_in, 1.0)
    bw_out = sites.bw_out[site] / jnp.maximum(share_out, 1.0)
    c = jobs.cores.astype(jnp.float32)
    gamma = sites.par_gamma[site]
    speedup = c / (1.0 + gamma * jnp.maximum(c - 1.0, 0.0))
    compute = jobs.work / (sites.speed[site] * jnp.maximum(speedup, 1e-9))
    return lat + jobs.bytes_in / bw_in + compute + jobs.bytes_out / bw_out


def _segment_exclusive_base(values: jax.Array, seg_ids: jax.Array, num_segments: int):
    """For values sorted by seg_ids: per-element cumulative sum *within* its segment."""
    total_cum = jnp.cumsum(values)
    seg_totals = jax.ops.segment_sum(values, seg_ids, num_segments=num_segments)
    seg_base = jnp.concatenate([jnp.zeros((1,), values.dtype), jnp.cumsum(seg_totals)[:-1]])
    return total_cum - seg_base[seg_ids]


def default_assign(scores: jax.Array, queued: jax.Array, feasible: jax.Array, sites=None):
    """Reference assignment: best feasible site per queued job (site-queue mode).

    Returns (site[J] int32 with -1 for unassigned, assigned_mask[J]).
    Capacity-constrained assignment is provided by ``repro.kernels.assign``.
    """
    neg = jnp.float32(-jnp.inf)
    masked = jnp.where(feasible, scores, neg)
    best = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    best_val = jnp.max(masked, axis=-1)
    ok = queued & jnp.isfinite(best_val)
    return jnp.where(ok, best, -1), ok


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy",
        "max_rounds",
        "log_rows",
        "max_retries",
        "monitor_every",
        "quantum",
    ),
)
def simulate(
    jobs0: JobsState,
    sites0: SiteState,
    policy,
    rng: jax.Array,
    *,
    max_rounds: int = 100_000,
    horizon: float = float("inf"),
    log_rows: int = 0,
    max_retries: int = 3,
    monitor_every: int = 1,
    quantum: float = 0.0,
) -> SimResult:
    """Run the grid simulation to completion (or ``max_rounds``/``horizon``).

    ``quantum`` > 0 batches all events inside [t*, t* + quantum] into one
    round (SimGrid-style time-precision knob): timestamps quantize to the
    window but each round retires many events — the lever that turns
    O(events) rounds into O(horizon/quantum) for dense workloads (paper
    Fig. 4 scaling regime).
    """
    S = sites0.capacity
    J = jobs0.capacity
    policy_state0 = policy.init(jobs0, sites0)
    log0 = make_log(log_rows, S)

    def cond(st: EngineState):
        active = (
            (st.jobs.state == PENDING)
            | (st.jobs.state == QUEUED)
            | (st.jobs.state == ASSIGNED)
            | (st.jobs.state == RUNNING)
        )
        return (
            (~st.halted)
            & jnp.any(active & st.jobs.valid)
            & (st.round < max_rounds)
            & (st.clock <= horizon)
        )

    def body(st: EngineState) -> EngineState:
        jobs, sites = st.jobs, st.sites
        rng, k_fail, k_frac, k_policy = jax.random.split(st.rng, 4)

        # ---- 1. advance the clock to the next event ------------------------
        arr_t = jnp.where((jobs.state == PENDING) & jobs.valid, jobs.arrival, INF)
        fin_t = jnp.where(jobs.state == RUNNING, jobs.t_finish, INF)
        t_next = jnp.minimum(arr_t.min(), fin_t.min())
        if quantum > 0.0:
            t_next = t_next + quantum
        clock = jnp.where(jnp.isfinite(t_next), jnp.maximum(st.clock, t_next), st.clock)

        # ---- 2. completions -------------------------------------------------
        comp = (jobs.state == RUNNING) & (jobs.t_finish <= clock)
        comp_site = jnp.where(comp, jobs.site, S)  # padded segment for non-events
        freed_cores = jax.ops.segment_sum(
            jnp.where(comp, jobs.cores, 0), comp_site, num_segments=S + 1
        )[:S]
        freed_mem = jax.ops.segment_sum(
            jnp.where(comp, jobs.memory, 0.0), comp_site, num_segments=S + 1
        )[:S]
        failed_now = comp & jobs.will_fail
        resubmit = failed_now & (jobs.retries < max_retries)
        perm_fail = failed_now & ~resubmit
        done_now = comp & ~jobs.will_fail

        new_state = jobs.state
        new_state = jnp.where(done_now, DONE, new_state)
        new_state = jnp.where(perm_fail, FAILED, new_state)
        new_state = jnp.where(resubmit, QUEUED, new_state)  # PanDA-style resubmission
        jobs = jobs._replace(
            state=new_state,
            retries=jobs.retries + resubmit.astype(jnp.int32),
            site=jnp.where(resubmit, -1, jobs.site),
            t_finish=jnp.where(resubmit, INF, jobs.t_finish),
        )
        sites = sites._replace(
            free_cores=sites.free_cores + freed_cores,
            free_memory=sites.free_memory + freed_mem,
            n_finished=sites.n_finished
            + jax.ops.segment_sum(done_now.astype(jnp.int32), comp_site, num_segments=S + 1)[:S],
            n_failed=sites.n_failed
            + jax.ops.segment_sum(failed_now.astype(jnp.int32), comp_site, num_segments=S + 1)[:S],
        )

        # ---- 3. arrivals -----------------------------------------------------
        arrived = (jobs.state == PENDING) & (jobs.arrival <= clock) & jobs.valid
        jobs = jobs._replace(state=jnp.where(arrived, QUEUED, jobs.state))

        # ---- 4. policy assignment (the plugin hot spot) ----------------------
        queued = jobs.state == QUEUED
        # static feasibility: job can ever fit the site
        feasible = (
            sites.active[None, :]
            & (jobs.cores[:, None] <= sites.cores[None, :])
            & (jobs.memory[:, None] <= sites.memory[None, :])
        )
        pstate = st.policy_state
        scores = policy.score(jobs, sites, pstate, clock, k_policy)  # [J, S]
        site_pick, assigned_now = policy.assign(scores, queued, feasible, sites)
        assigned_now = assigned_now & queued
        jobs = jobs._replace(
            state=jnp.where(assigned_now, ASSIGNED, jobs.state),
            site=jnp.where(assigned_now, site_pick, jobs.site),
            t_assign=jnp.where(assigned_now, clock, jobs.t_assign),
        )
        asg_site = jnp.where(assigned_now, site_pick, S)
        sites = sites._replace(
            n_assigned=sites.n_assigned
            + jax.ops.segment_sum(assigned_now.astype(jnp.int32), asg_site, num_segments=S + 1)[:S]
        )

        # ---- 5. starts: per-site FIFO with capacity --------------------------
        cand = jobs.state == ASSIGNED
        sort_site = jnp.where(cand, jobs.site, S).astype(jnp.int32)
        order = jnp.lexsort(
            (jnp.arange(J), jobs.arrival, -jobs.priority, sort_site)
        )
        site_s = sort_site[order]
        cand_s = cand[order]
        cores_s = jnp.where(cand_s, jobs.cores[order], 0).astype(jnp.int32)
        mem_s = jnp.where(cand_s, jobs.memory[order], 0.0)
        cum_cores = _segment_exclusive_base(cores_s, site_s, S + 1)
        cum_mem = _segment_exclusive_base(mem_s, site_s, S + 1)
        fits = (
            cand_s
            & (cum_cores <= sites.free_cores[jnp.minimum(site_s, S - 1)])
            & (cum_mem <= sites.free_memory[jnp.minimum(site_s, S - 1)] + 1e-6)
            & (site_s < S)
        )
        started = jnp.zeros((J,), bool).at[order].set(fits)

        start_site = jnp.where(started, jobs.site, S)
        used_cores = jax.ops.segment_sum(
            jnp.where(started, jobs.cores, 0), start_site, num_segments=S + 1
        )[:S]
        used_mem = jax.ops.segment_sum(
            jnp.where(started, jobs.memory, 0.0), start_site, num_segments=S + 1
        )[:S]
        n_start_per_site = jax.ops.segment_sum(
            started.astype(jnp.int32), start_site, num_segments=S + 1
        )[:S]
        share = n_start_per_site[jnp.minimum(jobs.site, S - 1)].astype(jnp.float32)
        t_serv = service_time(jobs, sites, jnp.minimum(jobs.site, S - 1), share, share)

        u_fail = jax.random.uniform(k_fail, (J,))
        will_fail = started & (u_fail < sites.fail_rate[jnp.minimum(jobs.site, S - 1)])
        # a failing attempt dies partway through its service time
        frac = jax.random.uniform(k_frac, (J,), minval=0.05, maxval=1.0)
        t_fin = clock + jnp.where(will_fail, t_serv * frac, t_serv)

        jobs = jobs._replace(
            state=jnp.where(started, RUNNING, jobs.state),
            t_start=jnp.where(started, clock, jobs.t_start),
            t_finish=jnp.where(started, t_fin, jobs.t_finish),
            will_fail=jnp.where(started, will_fail, jobs.will_fail),
        )
        sites = sites._replace(
            free_cores=sites.free_cores - used_cores,
            free_memory=sites.free_memory - used_mem,
        )

        pstate = policy.on_step(pstate, jobs, sites, comp, started, clock)

        # ---- 6. halt detection & event log -----------------------------------
        n_started = started.sum()
        n_completed = comp.sum()
        progressed = (n_started > 0) | (n_completed > 0) | jnp.any(arrived)
        halted = (~jnp.isfinite(t_next)) & ~progressed

        log = st.log
        if log_rows > 0:
            slot = jnp.mod(log.cursor, log_rows)
            write = jnp.mod(st.round, monitor_every) == 0
            counts = jax.vmap(
                lambda s: jnp.sum((jobs.state == s) & jobs.valid).astype(jnp.int32)
            )(jnp.arange(N_STATES))
            q_site = jnp.where(jobs.state == ASSIGNED, jobs.site, S)
            r_site = jnp.where(jobs.state == RUNNING, jobs.site, S)
            site_queued = jax.ops.segment_sum(
                jnp.ones((J,), jnp.int32), q_site, num_segments=S + 1
            )[:S]
            site_running = jax.ops.segment_sum(
                jnp.ones((J,), jnp.int32), r_site, num_segments=S + 1
            )[:S]

            def wr(buf, val):
                return jnp.where(write, buf.at[slot].set(val), buf)

            log = EventLog(
                time=wr(log.time, clock),
                round_idx=wr(log.round_idx, st.round),
                counts=wr(log.counts, counts),
                n_started=wr(log.n_started, n_started.astype(jnp.int32)),
                n_completed=wr(log.n_completed, n_completed.astype(jnp.int32)),
                site_free=wr(log.site_free, sites.free_cores),
                site_queued=wr(log.site_queued, site_queued),
                site_running=wr(log.site_running, site_running),
                cursor=log.cursor + write.astype(jnp.int32),
            )

        return EngineState(
            clock=clock,
            round=st.round + 1,
            jobs=jobs,
            sites=sites,
            rng=rng,
            policy_state=pstate,
            log=log,
            halted=halted,
        )

    st0 = EngineState(
        clock=jnp.float32(0.0),
        round=jnp.int32(0),
        jobs=jobs0,
        sites=sites0,
        rng=rng,
        policy_state=policy_state0,
        log=log0,
        halted=jnp.array(False),
    )
    st = jax.lax.while_loop(cond, body, st0)
    pstate = policy.on_end(st.policy_state, st.jobs, st.sites, st.clock)
    return SimResult(
        makespan=st.clock,
        rounds=st.round,
        jobs=st.jobs,
        sites=st.sites,
        log=st.log,
        policy_state=pstate,
    )


def simulate_ensemble(
    jobs0: JobsState,
    sites0: SiteState,
    policy,
    rng: jax.Array,
    *,
    speed_candidates: jax.Array,  # f32[K, S] per-site speeds to evaluate
    **kw,
) -> SimResult:
    """vmap the full simulation over K per-site speed vectors (calibration inner loop)."""

    def one(speed, key):
        sites = sites0._replace(speed=speed)
        return simulate(jobs0, sites, policy, key, **kw)

    keys = jax.random.split(rng, speed_candidates.shape[0])
    return jax.vmap(one)(speed_candidates, keys)


def walltimes(result: SimResult) -> jax.Array:
    """Per-job walltime (t_finish - t_start); inf for jobs that never ran."""
    return result.jobs.t_finish - result.jobs.t_start


def queue_times(result: SimResult) -> jax.Array:
    return result.jobs.t_start - result.jobs.arrival


AssignFn = Callable[[jax.Array, jax.Array, jax.Array, SiteState], tuple]
