"""Workload generation and trace ingestion.

The paper replays 6 months of PanDA job records (Jan-Jun 2024).  Those records
are not public, so the synthetic generator reproduces their documented shape:
single-core and 8-core (multicore) production jobs, log-normal compute demand,
heavy-tailed stage-in/out volumes, bursty Poisson arrivals.  ``from_records``
ingests real traces (CSV/JSON/columnar dicts) when available.

Availability scenarios (DESIGN.md §5) live here too: ``maintenance_calendar``,
``flaky_sites`` and ``rolling_brownout`` build the downtime calendars that
turn a clean-grid replay into a realistic operating-conditions study; the
workflow scenario builders (DESIGN.md §6: ``chain_workflows``,
``map_reduce_workflows``, ``atlas_mc_workflows``) are re-exported from
``workflows`` so workload construction stays a one-module import.
"""
from __future__ import annotations

import csv
import io
import json

import numpy as np

from .availability import AvailabilityState, make_availability
from .types import JobsState, make_jobs
from .workflows import (  # noqa: F401  (workload-construction re-exports)
    WorkflowScenario,
    atlas_mc_workflows,
    chain_workflows,
    make_workflow,
    map_reduce_workflows,
    scenario_replicas,
)


def synthetic_panda_jobs(
    n_jobs: int,
    *,
    seed: int = 0,
    duration: float = 24 * 3600.0,
    multicore_frac: float = 0.5,
    mean_walltime_hours: float = 4.0,
    burstiness: float = 0.3,
    n_datasets: int | None = None,
    zipf_alpha: float = 1.2,
    capacity: int | None = None,
) -> JobsState:
    """ATLAS-production-shaped synthetic workload.

    work is calibrated so that on a speed-10 site a single-core job averages
    ``mean_walltime_hours``; multicore (8-core) jobs carry ~8x the work, as in
    ATLAS reconstruction/simulation task splits.

    ``n_datasets`` assigns each job an input dataset with Zipf(``zipf_alpha``)
    popularity — a few hot datasets dominate reads, the regime where replica
    caching pays off (DESIGN.md §3).  Default None leaves ``dataset = -1``
    (flat-link stage-in).
    """
    rng = np.random.default_rng(seed)
    dataset = None
    if n_datasets is not None:
        p = 1.0 / np.arange(1, n_datasets + 1) ** zipf_alpha
        dataset = rng.choice(n_datasets, size=n_jobs, p=p / p.sum()).astype(np.int32)
    multicore = rng.random(n_jobs) < multicore_frac
    cores = np.where(multicore, 8, 1).astype(np.int32)

    base_work = 10.0 * mean_walltime_hours * 3600.0  # work units at speed 10
    work = rng.lognormal(mean=np.log(base_work), sigma=0.8, size=n_jobs)
    work = work * np.where(multicore, 8.0, 1.0)

    # bursty arrivals: a Poisson process with a slow sinusoidal rate modulation
    gaps = rng.exponential(duration / max(n_jobs, 1), size=n_jobs)
    arrival = np.cumsum(gaps)
    arrival *= duration / max(arrival[-1], 1e-9)
    arrival += burstiness * duration / 20.0 * np.sin(arrival / duration * 12 * np.pi)
    arrival = np.clip(arrival, 0.0, None)
    arrival.sort()

    memory = np.where(multicore, 16.0, 2.0) * rng.uniform(0.8, 1.2, n_jobs)
    bytes_in = rng.lognormal(np.log(2e9), 1.0, n_jobs)   # ~GBs of input
    bytes_out = rng.lognormal(np.log(5e8), 1.0, n_jobs)
    priority = rng.choice([0.0, 1.0, 2.0], size=n_jobs, p=[0.7, 0.2, 0.1])

    return make_jobs(
        job_id=np.arange(n_jobs, dtype=np.int32),
        arrival=arrival,
        work=work,
        cores=cores,
        memory=memory,
        bytes_in=bytes_in,
        bytes_out=bytes_out,
        priority=priority,
        dataset=dataset,
        capacity=capacity,
    )


def maintenance_calendar(
    n_sites: int,
    *,
    horizon: float,
    period: float = 7 * 86400.0,
    duration: float = 4 * 3600.0,
    first: float | None = None,
    stagger: bool = True,
    sites=None,
    preempt: bool = False,
) -> AvailabilityState:
    """Scheduled-maintenance scenario: periodic full-outage windows per site.

    Each selected site goes down for ``duration`` every ``period`` seconds,
    starting at ``first`` (default one period in).  ``stagger`` offsets sites
    evenly across the period — the WLCG norm of rolling maintenance so the
    grid never loses every site at once.  Drain semantics by default
    (maintenance is announced; queues pause, running jobs finish).
    """
    chosen = range(n_sites) if sites is None else sites
    base = period if first is None else first
    windows = []
    for s in chosen:
        offset = (period * (s / max(n_sites, 1))) if stagger else 0.0
        t0 = base + offset
        while t0 < horizon:
            windows.append(dict(site=int(s), start=t0, end=t0 + duration, preempt=preempt))
            t0 += period
    return make_availability(n_sites, windows)


def flaky_sites(
    n_sites: int,
    flaky,
    *,
    horizon: float,
    mtbf: float = 12 * 3600.0,
    mean_down: float = 1800.0,
    seed: int = 0,
    preempt: bool = True,
    max_windows: int | None = None,
) -> AvailabilityState:
    """Flaky-T2 scenario: unannounced short outages that kill running jobs.

    Sites flagged in ``flaky`` (bool mask or index list) fail as a Poisson
    process with mean time between failures ``mtbf`` and log-normal repair
    time around ``mean_down``; jobs caught running are preempted and
    resubmitted (a retry), reshaping failure/retry statistics the way Begy
    et al. (arXiv:1902.10069) observe in real data-access profiles.
    """
    mask = np.zeros(n_sites, bool)
    flaky = np.asarray(flaky)
    mask[flaky.astype(np.int64) if flaky.dtype != np.bool_ else flaky] = True
    rng = np.random.default_rng(seed)
    windows = []
    for s in np.flatnonzero(mask):
        t = float(rng.exponential(mtbf))
        while t < horizon:
            down = float(rng.lognormal(np.log(mean_down), 0.5))
            windows.append(dict(site=int(s), start=t, end=t + down, preempt=preempt))
            t += down + float(rng.exponential(mtbf))
    return make_availability(n_sites, windows, max_windows=max_windows)


def rolling_brownout(
    n_sites: int,
    *,
    horizon: float,
    factor: float = 0.5,
    duration: float | None = None,
    start: float = 0.0,
    sites=None,
) -> AvailabilityState:
    """Rolling brown-out: a degradation wave crosses the grid site by site.

    Models pledge reductions / power capping: each site in turn runs at
    ``factor`` of its speed and cores for one slot; slots tile ``[start,
    horizon]`` back-to-back (``duration`` overrides the slot length).
    """
    chosen = list(range(n_sites) if sites is None else sites)
    if not chosen:
        return make_availability(n_sites)
    slot = duration if duration is not None else (horizon - start) / len(chosen)
    windows = [
        dict(site=int(s), start=start + i * slot, end=start + (i + 1) * slot, factor=factor)
        for i, s in enumerate(chosen)
    ]
    return make_availability(n_sites, windows)


# --------------------------------------------------------------------------
# fault-injection scenario builders (DESIGN.md §13)
# --------------------------------------------------------------------------


def lossy_links(
    n_sites: int,
    *,
    p: float = 0.05,
    hot=None,
    hot_p: float = 0.3,
    seed: int = 0,
) -> np.ndarray:
    """Per-link transfer-failure probabilities for ``make_faults(link_fail_p=)``.

    Every WAN link (``src != dst``) fails with probability ``p``; links
    touching a ``hot`` site (index list, or an int count of sites sampled by
    ``seed``) fail with ``hot_p`` — the degraded-storage-endpoint scenario
    where one SE times out most third-party copies.  Local links never fail.
    """
    mat = np.full((n_sites, n_sites), float(p), np.float32)
    if hot is not None:
        if np.ndim(hot) == 0:
            rng = np.random.default_rng(seed)
            hot = rng.choice(n_sites, size=int(hot), replace=False)
        for s in np.asarray(hot, np.int64).ravel():
            mat[s, :] = hot_p
            mat[:, s] = hot_p
    np.fill_diagonal(mat, 0.0)
    return mat


def replica_loss_calendar(
    n_datasets: int,
    n_sites: int,
    *,
    horizon: float,
    rate: float = 1.0 / (24 * 3600.0),
    seed: int = 0,
    sites=None,
) -> list[tuple[float, int, int]]:
    """Sampled ``(t, dataset, site)`` loss events for ``make_faults(replica_loss=)``.

    Each candidate site loses a uniformly-chosen dataset replica as a Poisson
    process with ``rate`` events/second — disk crashes and SE corruptions that
    force readers back to the origin over the WAN.  ``n_datasets`` also
    accepts a ``ReplicaState``.  Origin-pinned copies are immune at
    application time, so sampling the origin site is harmless.
    """
    sz = getattr(n_datasets, "size", None)
    D = sz.shape[-1] if getattr(sz, "ndim", 0) else int(n_datasets)
    rng = np.random.default_rng(seed)
    chosen = range(n_sites) if sites is None else sites
    events = []
    for s in chosen:
        t = float(rng.exponential(1.0 / rate))
        while t < horizon:
            events.append((t, int(rng.integers(0, D)), int(s)))
            t += float(rng.exponential(1.0 / rate))
    events.sort()
    return events


def flaky_grid(
    n_sites: int,
    *,
    n_flaky: int = 1,
    flaky_fail_rate: float = 0.9,
    base_fail_rate: float = 0.02,
    seed: int = 0,
    **platform_kw,
):
    """Flaky-grid platform: an ``atlas_like_platform`` where ``n_flaky``
    sites fail almost every job they run (``flaky_fail_rate``) while the
    rest stay healthy — the scenario where adaptive blacklisting
    (``make_faults(blacklist_threshold=)``) pays off (see
    ``examples/chaos_day.py``).  Returns ``(sites, flaky_idx)``.
    """
    from .platform import atlas_like_platform

    sites = atlas_like_platform(n_sites, seed=seed, fail_rate=base_fail_rate, **platform_kw)
    rng = np.random.default_rng(seed + 1)
    flaky_idx = np.sort(rng.choice(n_sites, size=int(n_flaky), replace=False))
    fr = np.asarray(sites.fail_rate).copy()
    fr[flaky_idx] = flaky_fail_rate
    import jax.numpy as jnp

    return sites._replace(fail_rate=jnp.asarray(fr, jnp.float32)), flaky_idx


_FIELDS = ("job_id", "arrival", "work", "cores", "memory", "bytes_in", "bytes_out", "priority")


def from_records(records, *, capacity: int | None = None) -> JobsState:
    """Ingest job records: list[dict], dict-of-columns, CSV text, or JSON text."""
    if isinstance(records, str):
        s = records.lstrip()
        if s.startswith("[") or s.startswith("{"):
            records = json.loads(records)
        else:
            records = list(csv.DictReader(io.StringIO(records)))
    if isinstance(records, dict):  # dict of columns
        cols = {k: np.asarray(v) for k, v in records.items()}
    else:  # list of dicts
        cols = {k: np.array([float(r.get(k, 0) or 0) for r in records]) for k in _FIELDS}
    n = len(cols["arrival"])
    return make_jobs(
        job_id=cols.get("job_id", np.arange(n)).astype(np.int32),
        arrival=cols["arrival"],
        work=cols["work"],
        cores=cols.get("cores", np.ones(n)).astype(np.int32),
        memory=cols.get("memory", np.full(n, 2.0)),
        bytes_in=cols.get("bytes_in", np.zeros(n)),
        bytes_out=cols.get("bytes_out", np.zeros(n)),
        priority=cols.get("priority", np.zeros(n)),
        dataset=np.asarray(cols.get("dataset", np.full(n, -1))).astype(np.int32),
        capacity=capacity,
    )


def lm_job_records(cells: list[dict], *, jobs_per_cell: int = 8, seed: int = 0) -> dict:
    """Turn roofline-derived (arch x shape) cells into a grid workload
    (DESIGN.md §4: the LM workload layer feeds the simulator).

    Each cell dict carries ``flops``, ``bytes``, ``collective_bytes`` per step
    and ``steps``; a job's work is its step FLOPs x steps scaled into
    HS23-like work units, its stage-in is the checkpoint+data volume.
    """
    rng = np.random.default_rng(seed)
    rows = {k: [] for k in _FIELDS}
    jid = 0
    t = 0.0
    for cell in cells:
        for _ in range(jobs_per_cell):
            steps = cell.get("steps", 100)
            flops = cell["flops"] * steps
            rows["job_id"].append(jid)
            rows["arrival"].append(t)
            # 1 work unit == 1e12 flop; a speed-10 site does 10 TFLOP/s-core
            rows["work"].append(flops / 1e12)
            rows["cores"].append(int(cell.get("cores", 8)))
            rows["memory"].append(float(cell.get("memory_gb", 16.0)))
            rows["bytes_in"].append(float(cell.get("bytes_in", cell.get("bytes", 0.0))))
            rows["bytes_out"].append(float(cell.get("bytes_out", 1e9)))
            rows["priority"].append(1.0)
            jid += 1
            t += float(rng.exponential(60.0))
    return {k: np.asarray(v) for k, v in rows.items()}
