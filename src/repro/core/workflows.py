"""Workflow DAG subsystem — dependency-gated jobs, intermediate-dataset
production, and workflow-aware scheduling (DESIGN.md §6).

CGSim's headline feature is a plugin mechanism for *workflow* scheduling and
data-movement policies over production PanDA workloads; multi-stage chains
(evgen -> simul -> recon -> deriv) are the dominant ATLAS production shape.
This module adds job dependencies to the engine without leaving the
fixed-shape, jit/vmap-safe regime:

- ``WorkflowState`` carries a padded parent matrix ``int32[J, P]`` (-1 in
  unused slots).  Dependency logic is one ``[J, P]`` gather per round
  (``parent_status``): a job stays PENDING until *all* its parents are DONE
  (the dispatcher gate), and a terminally FAILED or CANCELLED parent
  cascade-cancels every descendant (one DAG level per round), counted in
  ``n_cancelled`` separately from machine failures.
- Parents *materialize output datasets* at the site where they actually ran:
  on completion the engine inserts ``jobs.out_dataset`` into the replica
  catalog (``replicas.materialize_outputs``), so a child's stage-in is priced
  over the WAN from the parent's execution site through the DESIGN.md §3
  machinery — workflow structure and data movement couple.
- Per-job DAG metadata (``wf_id`` / ``n_parents`` / ``dag_depth`` /
  ``wf_crit``) lives in ``JobsState`` columns, so scheduling policies can be
  workflow-aware without new plumbing: ``critical_path_first`` ranks site
  queues by critical-path weight, ``workflow_locality`` steers children to
  the sites holding their parents' outputs.
- Scenario builders (``chain_workflows``, ``map_reduce_workflows``,
  ``atlas_mc_workflows``) generate chains, fan-out/fan-in map-reduce, and the
  ATLAS-like 4-stage MC production with per-stage output inflation/reduction.

``engine.simulate(workflow=None)`` takes a code path with no extra ops or RNG
draws — bit-for-bit identical to the workflow-free engine (golden trace).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .replicas import ReplicaState, make_replicas, materialize_outputs
from .types import CANCELLED, DONE, FAILED, PENDING, JobsState, make_jobs
from . import policies as _policies


class WorkflowState(NamedTuple):
    """Fixed-shape DAG state carried through the engine round loop.

    ``parents[j]`` holds the job-row indices job ``j`` depends on, padded
    with -1; static per-job metadata (depth, critical-path weight, workflow
    id, output dataset) lives in ``JobsState`` columns so policies see it.
    """

    parents: jax.Array      # i32[J, P] parent job rows, -1 = unused slot
    n_cancelled: jax.Array  # i32[] jobs cascade-cancelled so far
    n_produced: jax.Array   # i32[] output datasets materialized so far

    @property
    def capacity(self) -> int:
        return self.parents.shape[-2]

    @property
    def max_parents(self) -> int:
        return self.parents.shape[-1]


def parent_status(parents: jax.Array, job_state: jax.Array):
    """The per-round dependency gate: ``(ready, dead)`` bool[J] masks.

    ``ready[j]``: every parent of ``j`` is DONE (vacuously true for roots) —
    the job may leave PENDING.  ``dead[j]``: some parent is terminally FAILED
    or already CANCELLED — the job (and, transitively, its descendants, one
    DAG level per engine round) must be cascade-cancelled.  A parent that
    merely failed an *attempt* and was resubmitted is neither, so the child
    just stays gated.
    """
    J = job_state.shape[-1]
    ps = job_state[jnp.clip(parents, 0, J - 1)]          # [J, P]
    has = parents >= 0
    ready = jnp.all(~has | (ps == DONE), axis=-1)
    dead = jnp.any(has & ((ps == FAILED) | (ps == CANCELLED)), axis=-1)
    return ready, dead


# --------------------------------------------------------------------------
# the workflow Subsystem (DESIGN.md §7): dependency gate, cascade-cancel, and
# output materialization as hooks on the composable round-loop protocol
# --------------------------------------------------------------------------


def _wf_validate(sub, wf: WorkflowState, jobs, sites) -> None:
    J = jobs.capacity
    if wf.parents.shape[-2] != J:
        raise ValueError(
            f"workflow has {wf.parents.shape[-2]} job rows, workload has {J}"
        )


def _wf_arrival_gate(sub, ctx):
    # gated jobs wait for their last parent's completion; called once for the
    # clock min-reduction (pre-completion states) and once for arrivals
    # (post-completion states, so a child un-gated this round arrives now)
    ready, _ = parent_status(ctx.ext["workflow"].parents, ctx.jobs.state)
    return ready


def _wf_on_completions(sub, ctx):
    """Cascade-cancel (engine step 2c): a terminally dead parent cancels its
    PENDING descendants, one DAG level per round."""
    wf = ctx.ext["workflow"]
    jobs = ctx.jobs
    # a dead ancestor can only be seen from PENDING: children never leave
    # PENDING before all parents are DONE, and DONE is terminal
    _, dead = parent_status(wf.parents, jobs.state)
    cancel_now = (jobs.state == PENDING) & jobs.valid & dead
    ctx.jobs = jobs._replace(state=jnp.where(cancel_now, CANCELLED, jobs.state))
    ctx.ext["workflow"] = wf._replace(
        n_cancelled=wf.n_cancelled + cancel_now.sum().astype(jnp.int32)
    )
    # a cancel round changed state: the cascade needs one round per DAG
    # level even when no timed event remains
    ctx.progressed = jnp.logical_or(ctx.progressed, jnp.any(cancel_now))


def _wf_on_start(sub, ctx):
    """Output production (DESIGN.md §6): completing parents materialize their
    output dataset at the site they ran on — before the data subsystem's
    source selection (it runs later in the tuple), so a child starting this
    same round already stages in from the parent's site.  A no-op unless the
    data subsystem is attached: without a catalog there is nowhere to
    materialize into."""
    dext = ctx.ext.get("data")
    if dext is None:
        return
    jobs = ctx.jobs
    produced = ctx.done_now & (jobs.out_dataset >= 0)
    rep = materialize_outputs(
        dext.replicas, jobs.out_dataset, jnp.clip(jobs.site, 0, ctx.S - 1), produced, ctx.clock
    )
    ctx.ext["data"] = dext._replace(replicas=rep)
    wf = ctx.ext["workflow"]
    ctx.ext["workflow"] = wf._replace(
        n_produced=wf.n_produced + produced.sum().astype(jnp.int32)
    )


def _wf_pad_jobs(sub, wf: WorkflowState, old_capacity: int, new_capacity: int):
    """Grow the parent matrix to a padded job capacity (padding rows are
    parentless, so they stay inert like the padded jobs themselves)."""
    pad = new_capacity - wf.parents.shape[-2]
    return wf._replace(parents=jnp.pad(wf.parents, ((0, pad), (0, 0)), constant_values=-1))


def _wf_finalize(sub, wf, jobs, sites, clock):
    return wf, {"wf": wf}


def workflow_subsystem() -> "Subsystem":
    """The workflow DAG as a composable engine subsystem; its ext slot
    carries the ``WorkflowState`` (parent matrix + counters)."""
    from .subsystems import Subsystem

    return Subsystem(
        name="workflow",
        validate=_wf_validate,
        arrival_gate=_wf_arrival_gate,
        on_completions=_wf_on_completions,
        on_start=_wf_on_start,
        pad_jobs=_wf_pad_jobs,
        finalize=_wf_finalize,
    )


# --------------------------------------------------------------------------
# DAG construction
# --------------------------------------------------------------------------


def make_workflow(
    jobs: JobsState,
    edges,
    *,
    wf_id=None,
    out_dataset=None,
    max_parents: int | None = None,
) -> tuple[JobsState, WorkflowState]:
    """Attach a DAG to a workload: returns ``(jobs', WorkflowState)``.

    ``edges``: iterable of ``(parent_row, child_row)`` job-row index pairs
    (rows, not external job ids).  Host-side numpy computes the padded parent
    matrix, per-job depth (longest root path), and critical-path weight
    ``wf_crit[j] = work[j] + max(wf_crit[child])`` — the classic upward rank.
    ``wf_id`` defaults to weakly-connected-component labels (standalone jobs
    get their own id); ``out_dataset`` marks the dataset each job produces
    (-1 = none).  Raises on cycles, self-edges, and out-of-range rows.
    """
    J = jobs.capacity
    valid = np.asarray(jobs.valid)
    n = int(valid.sum())
    edges = [(int(p), int(c)) for p, c in edges]
    for p, c in edges:
        if not (0 <= p < n and 0 <= c < n):
            raise ValueError(f"edge ({p}, {c}) outside the {n} valid job rows")
        if p == c:
            raise ValueError(f"self-edge on job row {p}")

    par: list[list[int]] = [[] for _ in range(n)]
    chl: list[list[int]] = [[] for _ in range(n)]
    for p, c in edges:
        if p not in par[c]:
            par[c].append(p)
            chl[p].append(c)

    # Kahn toposort: depth + cycle check
    depth = np.zeros(J, np.int32)
    indeg = np.array([len(ps) for ps in par])
    frontier = [j for j in range(n) if indeg[j] == 0]
    topo = []
    while frontier:
        j = frontier.pop()
        topo.append(j)
        for c in chl[j]:
            depth[c] = max(depth[c], depth[j] + 1)
            indeg[c] -= 1
            if indeg[c] == 0:
                frontier.append(c)
    if len(topo) != n:
        raise ValueError("workflow edges contain a cycle")

    # critical-path (upward-rank) weight in work units, reverse-topological
    work = np.asarray(jobs.work, np.float64)
    crit = work[:J].copy()
    crit[~valid] = 0.0
    for j in reversed(topo):
        if chl[j]:
            crit[j] = work[j] + max(crit[c] for c in chl[j])

    if wf_id is None:
        # weakly-connected components over the DAG; standalone jobs included
        label = np.arange(n, dtype=np.int32)

        def find(a):
            while label[a] != a:
                label[a] = label[label[a]]
                a = label[a]
            return a

        for p, c in edges:
            ra, rb = find(p), find(c)
            if ra != rb:
                label[max(ra, rb)] = min(ra, rb)
        roots = np.array([find(j) for j in range(n)])
        _, wf_id = np.unique(roots, return_inverse=True)

    P = max_parents or max(1, max((len(ps) for ps in par), default=1))
    if any(len(ps) > P for ps in par):
        raise ValueError(f"a job has more than max_parents={P} parents")
    parents = np.full((J, P), -1, np.int32)
    for j, ps in enumerate(par):
        parents[j, : len(ps)] = sorted(ps)

    def pad_i(x, fill):
        x = np.asarray(x, np.int32)
        return np.pad(x, (0, J - x.shape[0]), constant_values=fill)

    jobs = jobs._replace(
        wf_id=jnp.asarray(pad_i(wf_id, -1)),
        n_parents=jnp.asarray(pad_i([len(ps) for ps in par], 0)),
        dag_depth=jnp.asarray(depth),
        wf_crit=jnp.asarray(crit, jnp.float32),
        out_dataset=(
            jobs.out_dataset if out_dataset is None else jnp.asarray(pad_i(out_dataset, -1))
        ),
    )
    wf = WorkflowState(
        parents=jnp.asarray(parents),
        n_cancelled=jnp.zeros((), jnp.int32),
        n_produced=jnp.zeros((), jnp.int32),
    )
    return jobs, wf


# --------------------------------------------------------------------------
# scenario builders (chains, map-reduce, ATLAS 4-stage MC production)
# --------------------------------------------------------------------------

# ATLAS-like 4-stage MC production: per-stage (work multiplier, cores,
# memory GB, output bytes as a multiple of the previous stage's output).
# evgen writes small EVNT files, simul inflates them into HITS (~20x), recon
# reduces HITS to AOD (~1/8), deriv skims AOD to DAOD (~1/10).
ATLAS_STAGES = ("evgen", "simul", "recon", "deriv")
ATLAS_WORK = (1.0, 8.0, 4.0, 1.0)
ATLAS_CORES = (1, 8, 8, 1)
ATLAS_MEMORY = (2.0, 16.0, 16.0, 4.0)
ATLAS_INFLATION = (1.0, 20.0, 0.125, 0.1)


class WorkflowScenario(NamedTuple):
    """A workload + DAG + the dataset universe its jobs will produce.

    ``ds_sizes[d]`` is the byte size of dataset ``d``; ``ds_origin``/
    ``ds_materialized`` describe the initial catalog (-1/False = the dataset
    does not exist yet — some job materializes it mid-run).  Feed these to
    ``scenario_replicas`` to build the matching ``ReplicaState``.
    """

    jobs: JobsState
    workflow: WorkflowState
    ds_sizes: np.ndarray        # f32[D]
    ds_origin: np.ndarray       # i32[D]
    ds_materialized: np.ndarray  # bool[D]


def scenario_replicas(scn: WorkflowScenario, disk_capacity, *, seed: int = 0) -> ReplicaState:
    """Replica catalog for a workflow scenario: intermediate datasets start
    absent and appear at their producer's site mid-run."""
    rep = make_replicas(
        scn.ds_sizes,
        disk_capacity,
        origin=scn.ds_origin,
        materialized=scn.ds_materialized,
        seed=seed,
    )
    validate_workflow_data(scn.jobs, scn.workflow, rep)
    return rep


def validate_workflow_data(jobs: JobsState, workflow, replicas: ReplicaState) -> None:
    """Host-side sanity check for hand-built configurations: every catalogued
    input that starts *unmaterialized* (no replica anywhere, ``origin = -1``)
    must be produced by a DAG ancestor of the job that reads it — otherwise
    the dependency gate cannot guarantee the data exists when the job starts,
    and ``nearest_source``'s origin fallback would silently price the read
    from a clipped bogus site.  Raises ``ValueError`` on violations; the
    built-in scenario builders are safe by construction.
    """
    present = np.asarray(replicas.present)
    origin = np.asarray(replicas.origin)
    unmat = ~present.any(axis=1) & (origin < 0)       # not readable at t=0
    dataset = np.asarray(jobs.dataset)
    out_ds = np.asarray(jobs.out_dataset)
    valid = np.asarray(jobs.valid)
    parents = None if workflow is None else np.asarray(workflow.parents)
    D = present.shape[0]
    for j in np.flatnonzero(valid & (dataset >= 0)):
        d = dataset[j]
        if d >= D:
            raise ValueError(f"job row {j} reads dataset {d} outside the {D}-row catalog")
        if not unmat[d]:
            continue
        producers = set(np.flatnonzero((out_ds == d) & valid))
        if parents is None or not producers:
            raise ValueError(
                f"job row {j} reads unmaterialized dataset {d} that no job produces"
            )
        ancestors, stack = set(), [int(j)]
        while stack:
            for p in parents[stack.pop()]:
                if p >= 0 and p not in ancestors:
                    ancestors.add(int(p))
                    stack.append(int(p))
        if not (producers & ancestors):
            raise ValueError(
                f"job row {j} reads unmaterialized dataset {d}, but no DAG ancestor "
                f"produces it (producers: {sorted(producers)}) — the dependency gate "
                "cannot guarantee the data exists before the job starts"
            )


def _stage_tuple(x, n_stages, default):
    if x is None:
        x = default
    x = list(x)
    if len(x) < n_stages:  # cycle the trailing value
        x = x + [x[-1]] * (n_stages - len(x))
    return x[:n_stages]


def chain_workflows(
    n_chains: int,
    n_stages: int = 4,
    *,
    seed: int = 0,
    arrival_span: float = 0.0,
    base_work: float = 3600.0,
    stage_work=None,
    stage_cores=None,
    stage_memory=None,
    stage_out_bytes=None,
    input_bytes: float = 2e9,
    work_sigma: float = 0.3,
    priority=None,
    capacity: int | None = None,
) -> WorkflowScenario:
    """Linear production chains: ``n_chains`` independent chains of
    ``n_stages`` dependent jobs each.

    Stage 0 stages its external input over the flat site link (``bytes_in``,
    no catalogued dataset); every stage materializes an output dataset
    (dataset id == producing job row) that the next stage declares as its
    ``jobs.dataset`` — so with a data policy, stage k+1's stage-in is priced
    from wherever stage k actually ran.  ``stage_*`` are per-stage lists
    (work multiplier on ``base_work``, cores, memory GB, output bytes).
    """
    rng = np.random.default_rng(seed)
    w_mult = _stage_tuple(stage_work, n_stages, (1.0,))
    cores = _stage_tuple(stage_cores, n_stages, (1,))
    mem = _stage_tuple(stage_memory, n_stages, (2.0,))
    out_b = _stage_tuple(stage_out_bytes, n_stages, (1e9,))

    n = n_chains * n_stages
    stage = np.tile(np.arange(n_stages), n_chains)
    chain = np.repeat(np.arange(n_chains), n_stages)
    submit = np.sort(rng.uniform(0.0, max(arrival_span, 0.0), n_chains)) if arrival_span else np.zeros(n_chains)
    work = base_work * np.asarray(w_mult)[stage] * rng.lognormal(0.0, work_sigma, n)
    rows = np.arange(n)
    parent = rows - 1  # previous stage in the same chain (stage 0 has none)
    edges = [(int(parent[j]), int(j)) for j in rows if stage[j] > 0]

    jobs = make_jobs(
        job_id=rows,
        arrival=submit[chain],
        work=work,
        cores=np.asarray(cores)[stage],
        memory=np.asarray(mem)[stage],
        bytes_in=np.where(stage == 0, input_bytes, 1e6),
        bytes_out=np.asarray(out_b)[stage],
        priority=priority,
        dataset=np.where(stage > 0, parent, -1),
        capacity=capacity,
    )
    jobs, wf = make_workflow(jobs, edges, wf_id=chain, out_dataset=rows)
    return WorkflowScenario(
        jobs=jobs,
        workflow=wf,
        ds_sizes=np.asarray(out_b, np.float32)[stage],
        ds_origin=np.full(n, -1, np.int32),
        ds_materialized=np.zeros(n, bool),
    )


def atlas_mc_workflows(
    n_tasks: int,
    *,
    seed: int = 0,
    arrival_span: float = 0.0,
    base_work: float = 3600.0,
    evnt_bytes: float = 2e8,
    inflation=ATLAS_INFLATION,
    capacity: int | None = None,
) -> WorkflowScenario:
    """ATLAS-like 4-stage MC production (evgen -> simul -> recon -> deriv).

    Per-stage output sizes follow ``inflation`` multiplicatively from the
    evgen EVNT size: simul inflates ~20x into HITS, recon cuts to AOD,
    deriv skims to DAOD — the size profile that makes stage placement matter
    (Begy et al., arXiv:1902.10069).
    """
    out_bytes, b = [], evnt_bytes
    for f in _stage_tuple(list(inflation), 4, (1.0,)):
        b = b * f
        out_bytes.append(b)
    return chain_workflows(
        n_tasks,
        4,
        seed=seed,
        arrival_span=arrival_span,
        base_work=base_work,
        stage_work=ATLAS_WORK,
        stage_cores=ATLAS_CORES,
        stage_memory=ATLAS_MEMORY,
        stage_out_bytes=out_bytes,
        capacity=capacity,
    )


def map_reduce_workflows(
    n_workflows: int,
    n_maps: int,
    *,
    seed: int = 0,
    arrival_span: float = 0.0,
    root_work: float = 1800.0,
    map_work: float = 3600.0,
    reduce_work: float = 900.0,
    root_out_bytes: float = 5e9,
    map_out_bytes: float = 5e8,
    work_sigma: float = 0.3,
    capacity: int | None = None,
) -> WorkflowScenario:
    """Fan-out/fan-in map-reduce: root -> ``n_maps`` mappers -> reducer.

    Every mapper declares the root's output as its input dataset (fan-out
    reads of one produced dataset); the reducer is gated on *all* mappers
    (fan-in) and stages the first mapper's partial as its catalogued input —
    ``JobsState.dataset`` is scalar, so the remaining partials ride in the
    reducer's flat ``bytes_in``.
    """
    rng = np.random.default_rng(seed)
    per = n_maps + 2
    n = n_workflows * per
    rows = np.arange(n)
    local = rows % per              # 0 = root, 1..n_maps = maps, n_maps+1 = reduce
    wf = rows // per
    is_root = local == 0
    is_red = local == per - 1
    root_row = wf * per
    submit = np.sort(rng.uniform(0.0, max(arrival_span, 0.0), n_workflows)) if arrival_span else np.zeros(n_workflows)

    edges = []
    for w in range(n_workflows):
        r0 = w * per
        for m in range(1, n_maps + 1):
            edges.append((r0, r0 + m))
            edges.append((r0 + m, r0 + per - 1))

    work = np.where(is_root, root_work, np.where(is_red, reduce_work, map_work))
    work = work * rng.lognormal(0.0, work_sigma, n)
    jobs = make_jobs(
        job_id=rows,
        arrival=submit[wf],
        work=work,
        cores=np.ones(n, np.int32),
        memory=np.full(n, 2.0),
        bytes_in=np.where(is_root, root_out_bytes / 4, np.where(is_red, (n_maps - 1) * map_out_bytes, 1e6)),
        bytes_out=np.where(is_root, root_out_bytes, map_out_bytes),
        dataset=np.where(is_root, -1, np.where(is_red, root_row + 1, root_row)).astype(np.int32),
        capacity=capacity,
    )
    jobs, wfs = make_workflow(jobs, edges, wf_id=wf, out_dataset=np.where(is_red, -1, rows))
    return WorkflowScenario(
        jobs=jobs,
        workflow=wfs,
        ds_sizes=np.where(is_root, root_out_bytes, map_out_bytes).astype(np.float32),
        ds_origin=np.full(n, -1, np.int32),
        ds_materialized=np.zeros(n, bool),
    )


# --------------------------------------------------------------------------
# workflow-aware scheduling policies (registered beside the built-in family)
# --------------------------------------------------------------------------


@_policies.register("workflow_locality")
def workflow_locality(
    workflow: WorkflowState | None = None,
    *,
    base: str = "panda_dispatch",
    w_local: float = 1e6,
    crit_rank: bool = True,
    **params,
) -> _policies.Policy:
    """Data-locality gating for DAG children: strongly prefer the sites where
    a job's parents actually ran — exactly where their output datasets were
    materialized, so stage-in is a local cache hit instead of a WAN read.

    Wraps ``base``'s site scores with a ``w_local`` bonus per resident
    parent; with ``crit_rank`` the site-queue start order follows
    critical-path weight too.  Pass the run's ``WorkflowState`` (the parent
    matrix is closed over as a compile-time constant); without one there is
    nothing to be local to, so the policy degrades to the base policy.
    """
    pol = _policies.get_policy(base, **params)
    rank = _policies.crit_rank_fn if crit_rank else pol.rank
    if workflow is None:
        return pol._replace(name=f"workflow_locality[{pol.name}]", rank=rank)
    parents = workflow.parents
    base_score = pol.score

    def score(jobs, sites, state, clock, rng):
        s = base_score(jobs, sites, state, clock, rng)
        J, S = jobs.capacity, sites.capacity
        p = parents
        if p.shape[0] < J:  # distributed padding grew the job capacity
            p = jnp.pad(p, ((0, J - p.shape[0]), (0, 0)), constant_values=-1)
        pc = jnp.clip(p, 0, J - 1)
        psite = jnp.where(p >= 0, jobs.site[pc], -1)                  # [J, P]
        n_here = (psite[:, :, None] == jnp.arange(S)[None, None, :]).sum(1)
        return s + w_local * n_here.astype(jnp.float32)

    return pol._replace(name=f"workflow_locality[{pol.name}]", score=score, rank=rank)
