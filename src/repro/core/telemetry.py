"""Flight recorder: run telemetry, manifests, sinks, and lane tracing.

The paper ships "interactive real-time visualization dashboards" and
event-level run datasets (CGSim §4.3.3, Table 1); what it never records is
why a run was fast or slow.  This module is the observability substrate for
the whole harness (DESIGN.md §9):

- ``TraceRecorder`` — a host-side span/counter recorder wrapped around the
  jit boundary (``with rec.span("execute"): ...``).  Spans cost two
  ``perf_counter`` calls and a dict update; every instrumentation site in the
  engine is guarded by ``recorder is not None``, so a recorder-less run pays
  nothing.
- ``Sink`` — a tiny streaming-record protocol (``emit(dict)``/``close()``)
  with NDJSON-file, in-memory, and callback implementations.  Monitor frames,
  telemetry spans, and event rows all stream through sinks, so export memory
  is bounded per record, not per run (``events.stream_rows``).
- ``RunManifest`` — a Tracekit-style self-describing sidecar JSON
  (``<artifact>.manifest.json``) recording the environment (jax version /
  backend / device count, package versions), the scenario content hash, the
  subsystem set, and the recorder's wall-clock breakdown.  ``manifest_drift``
  diffs two manifests' environment blocks — env drift explains perf drift
  (``benchmarks/summarize_results --check-bench``).
- ``lane_occupancy`` — per-lane ensemble tracing: active-round fraction per
  lane, per-bucket padding waste, and the phase-skip work-round rate, so the
  DESIGN.md §8 lock-step-tax win is a measured quantity on every sharded run.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import time
from typing import Any, Callable, Protocol, runtime_checkable

import numpy as np

MANIFEST_SCHEMA = "cgsim.run_manifest/v1"
MANIFEST_SUFFIX = ".manifest.json"


# --------------------------------------------------------------------------
# sinks: streaming record consumers
# --------------------------------------------------------------------------


@runtime_checkable
class Sink(Protocol):
    """Anything that accepts a stream of JSON-able record dicts."""

    def emit(self, record: dict) -> None: ...

    def close(self) -> None: ...


class NullSink:
    """Drops every record (the default when observability is off)."""

    def emit(self, record: dict) -> None:
        pass

    def close(self) -> None:
        pass


class MemorySink:
    """Collects records in a list — tests, notebooks, small runs."""

    def __init__(self):
        self.records: list[dict] = []

    def emit(self, record: dict) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass

    def __len__(self) -> int:
        return len(self.records)


class CallbackSink:
    """Forwards each record to a callable (dashboard push, queue producer)."""

    def __init__(self, fn: Callable[[dict], None]):
        self.fn = fn

    def emit(self, record: dict) -> None:
        self.fn(record)

    def close(self) -> None:
        pass


class NDJSONSink:
    """Streams records as newline-delimited JSON, one object per line.

    Accepts a path (opened/owned here) or any ``.write()``-able.  Each record
    is flushed on emit so a separate process can tail the file live
    (``python -m repro.monitor --follow run.ndjson``).
    """

    def __init__(self, target, *, flush_every: int = 1):
        if hasattr(target, "write"):
            self._f, self._owns = target, False
        else:
            self.path = pathlib.Path(target)
            self._f, self._owns = open(self.path, "w"), True
        self._flush_every = max(int(flush_every), 1)
        self._n = 0

    def emit(self, record: dict) -> None:
        self._f.write(json.dumps(record, separators=(",", ":")) + "\n")
        self._n += 1
        if self._n % self._flush_every == 0:
            self._f.flush()

    def close(self) -> None:
        self._f.flush()
        if self._owns:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def iter_ndjson(source, *, follow: bool = False, poll_s: float = 0.2,
                timeout_s: float | None = None):
    """Yield records from an NDJSON file (or file-like), optionally tailing.

    With ``follow=True`` the generator keeps polling for appended lines —
    the decoupled-dashboard half of ``monitor.watch``: the simulator writes
    through an ``NDJSONSink`` while a separate ``python -m repro.monitor
    --follow`` process renders.  Stops at a ``{"type": "end"}`` record, at
    ``timeout_s`` without new data, or (follow off) at EOF.
    """
    f = source if hasattr(source, "readline") else open(source)
    owns = f is not source
    waited = 0.0
    try:
        buf = ""
        while True:
            line = f.readline()
            if not line:
                if not follow:
                    return
                if timeout_s is not None and waited >= timeout_s:
                    return
                time.sleep(poll_s)
                waited += poll_s
                continue
            buf += line
            if not buf.endswith("\n"):
                continue  # partial line from a concurrent writer: wait for the rest
            waited = 0.0
            rec = json.loads(buf)
            buf = ""
            yield rec
            if rec.get("type") == "end":
                return
    finally:
        if owns:
            f.close()


# --------------------------------------------------------------------------
# TraceRecorder: spans + counters around the jit boundary
# --------------------------------------------------------------------------


class _Span:
    __slots__ = ("_rec", "_name", "_t0")

    def __init__(self, rec: "TraceRecorder", name: str):
        self._rec = rec
        self._name = name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._rec.record(self._name, time.perf_counter() - self._t0)
        return False


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class TraceRecorder:
    """Host-side flight recorder: named wall-clock spans, counters, notes.

    Spans accumulate (total seconds, call count) per name; counters are
    either monotonic (``count``) or last-write-wins gauges (``gauge``).  An
    optional sink receives every span as a record the moment it closes, so a
    long run's telemetry streams out live alongside its monitor frames.
    """

    def __init__(self, sink: Sink | None = None):
        self.spans: dict[str, list] = {}  # name -> [total_s, count]
        self.counters: dict[str, float] = {}
        self.notes: dict[str, Any] = {}
        self._sink = sink

    def span(self, name: str) -> _Span:
        return _Span(self, name)

    def record(self, name: str, seconds: float) -> None:
        e = self.spans.get(name)
        if e is None:
            self.spans[name] = [seconds, 1]
        else:
            e[0] += seconds
            e[1] += 1
        if self._sink is not None:
            self._sink.emit({"type": "span", "name": name, "s": round(seconds, 6)})

    def count(self, name: str, inc: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        self.counters[name] = value

    def note(self, name: str, value: Any) -> None:
        self.notes[name] = value

    def total(self, name: str) -> float:
        e = self.spans.get(name)
        return e[0] if e else 0.0

    def summary(self) -> dict:
        return dict(
            spans={
                n: dict(total_s=round(t, 6), count=c)
                for n, (t, c) in self.spans.items()
            },
            counters={n: (v if isinstance(v, (int, bool)) else float(v))
                      for n, v in self.counters.items()},
            notes=dict(self.notes),
        )


class NullRecorder:
    """API-compatible no-op recorder; ``span`` returns a shared no-op
    context manager, so instrumentation sites cost an attribute lookup."""

    spans: dict = {}
    counters: dict = {}
    notes: dict = {}

    def span(self, name: str):
        return _NULL_SPAN

    def record(self, name: str, seconds: float) -> None:
        pass

    def count(self, name: str, inc: float = 1) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def note(self, name: str, value: Any) -> None:
        pass

    def total(self, name: str) -> float:
        return 0.0

    def summary(self) -> dict:
        return dict(spans={}, counters={}, notes={})


NULL_RECORDER = NullRecorder()


def maybe(recorder) -> TraceRecorder | NullRecorder:
    """Normalize an optional recorder: ``None`` becomes the shared no-op."""
    return NULL_RECORDER if recorder is None else recorder


# --------------------------------------------------------------------------
# RunManifest: self-describing sidecar JSON
# --------------------------------------------------------------------------


def scenario_hash(*trees) -> str:
    """Deterministic content hash over pytrees (workload, platform, ext).

    Hashes tree structure, leaf shapes/dtypes, and leaf bytes, so two runs
    share a hash iff they simulate the same scenario — the key manifests are
    compared by.  ``None`` trees hash to a fixed token (subsystem off)."""
    import jax

    h = hashlib.sha256()
    for tree in trees:
        if tree is None:
            h.update(b"<none>")
            continue
        leaves, treedef = jax.tree.flatten(tree)
        h.update(repr(treedef).encode())
        for x in leaves:
            a = np.asarray(x)
            h.update(f"{a.shape}{a.dtype}".encode())
            h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()[:16]


def jsonable(tree):
    """Pytree -> plain JSON-serializable Python (dicts / lists / scalars).

    NamedTuples become dicts keyed by field, arrays become (nested) lists,
    ``None`` passes through — how calibration results and parameter pytrees
    land inside a RunManifest sidecar without a custom encoder.
    """
    if tree is None:
        return None
    if hasattr(tree, "_asdict"):
        return {k: jsonable(v) for k, v in tree._asdict().items()}
    if isinstance(tree, dict):
        return {str(k): jsonable(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [jsonable(v) for v in tree]
    if isinstance(tree, (str, bool, int, float)):
        return tree
    a = np.asarray(tree)
    return a.item() if a.ndim == 0 else a.tolist()


def run_manifest(
    *,
    jobs=None,
    sites=None,
    ext=None,
    subsystems: tuple = (),
    recorder=None,
    extra: dict | None = None,
) -> dict:
    """Build a RunManifest dict: environment + scenario identity + telemetry.

    Everything a perf regression hunt asks first: which jax/backend/device
    count produced this artifact, what scenario hash it simulated, which
    subsystems were attached, and where the wall-clock went.  Written next to
    any exported artifact by ``write_manifest`` (Tracekit-style sidecars)."""
    import platform as _platform
    import sys

    import jax

    devices = jax.devices()
    m: dict[str, Any] = {
        "schema": MANIFEST_SCHEMA,
        "created_unix": round(time.time(), 3),
        "jax": {
            "version": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "device_kinds": sorted({d.device_kind for d in devices}),
        },
        "versions": {
            "python": _platform.python_version(),
            "numpy": np.__version__,
            "jax": jax.__version__,
        },
        "platform": _platform.platform(),
        "argv": list(sys.argv),
    }
    if jobs is not None or sites is not None or ext is not None:
        names = [s.name for s in subsystems] if subsystems else sorted(ext or {})
        m["scenario"] = {
            "hash": scenario_hash(jobs, sites, ext),
            "n_jobs": int(np.asarray(jobs.valid).sum()) if jobs is not None else None,
            "job_capacity": jobs.capacity if jobs is not None else None,
            "n_sites": sites.capacity if sites is not None else None,
            "subsystems": names,
        }
    if recorder is not None:
        m["telemetry"] = recorder.summary()
    if extra:
        m["extra"] = extra
    return m


def manifest_path(artifact_path) -> pathlib.Path:
    """Sidecar path convention: ``run.ndjson`` -> ``run.ndjson.manifest.json``."""
    p = pathlib.Path(artifact_path)
    if p.name.endswith(MANIFEST_SUFFIX):
        return p
    return p.with_name(p.name + MANIFEST_SUFFIX)


def write_manifest(artifact_path, manifest: dict) -> pathlib.Path:
    """Write ``manifest`` as the sidecar of ``artifact_path``; returns the
    sidecar path.  Never touches the artifact itself."""
    path = manifest_path(artifact_path)
    path.write_text(json.dumps(manifest, indent=2, sort_keys=True) + "\n")
    return path


def read_manifest(artifact_path) -> dict:
    return json.loads(manifest_path(artifact_path).read_text())


# environment keys whose drift between two manifests explains perf drift
_DRIFT_KEYS = (
    ("jax", "version"),
    ("jax", "backend"),
    ("jax", "device_count"),
    ("jax", "device_kinds"),
    ("versions", "python"),
    ("versions", "numpy"),
)


def manifest_drift(fresh: dict, baseline: dict) -> list[dict]:
    """Environment diffs between two manifests (empty = same environment).

    Only compares the perf-relevant environment block — scenario hashes and
    telemetry are expected to differ run-to-run."""
    diffs = []
    for section, key in _DRIFT_KEYS:
        a = (fresh.get(section) or {}).get(key)
        b = (baseline.get(section) or {}).get(key)
        if a != b:
            diffs.append({"key": f"{section}.{key}", "fresh": a, "baseline": b})
    return diffs


# --------------------------------------------------------------------------
# lane-occupancy tracing for scenario ensembles (DESIGN.md §8/§9)
# --------------------------------------------------------------------------


def lane_occupancy(result, buckets=None) -> dict:
    """Per-lane occupancy metrics for an ensemble ``SimResult`` (leading K).

    Reports, per lane: rounds executed, ``active_frac`` (this lane's rounds
    over the slowest lane's — the lock-step tax a *vmapped* ensemble pays for
    the lane, and the work a sharded lane avoids), valid-job count and
    padding fraction.  When the run logged frames (``log_rows > 0``), each
    lane also reports ``work_round_frac`` — the fraction of its logged rounds
    with QUEUED/ASSIGNED rows outstanding, i.e. rounds the phase-skip guard
    could *not* skip (``skip_frac`` is its complement, the guard's hit-rate).

    ``buckets`` (a ``ScenarioBuckets``) adds the per-bucket padding-waste
    breakdown from ``ScenarioBuckets.padding_stats``.
    """
    from .types import ASSIGNED, QUEUED

    rounds = np.atleast_1d(np.asarray(result.rounds)).reshape(-1)
    K = rounds.size
    valid = np.asarray(result.jobs.valid).reshape(K, -1)
    cap = valid.shape[-1]
    n_valid = valid.sum(-1)
    max_r = max(int(rounds.max()), 1)

    # per-lane work-round rate from the in-sim frame log, when captured
    work_frac = [None] * K
    log = getattr(result, "log", None)
    if log is not None and np.asarray(log.time).ndim >= 1:
        counts = np.asarray(log.counts).reshape(K, -1, np.asarray(log.counts).shape[-1])
        ridx = np.asarray(log.round_idx).reshape(K, -1)
        for i in range(K):
            m = ridx[i] >= 0
            if m.any():
                work = (counts[i, m, QUEUED] + counts[i, m, ASSIGNED]) > 0
                work_frac[i] = float(work.mean())

    lanes = []
    for i in range(K):
        lane = dict(
            lane=i,
            rounds=int(rounds[i]),
            active_frac=round(float(rounds[i]) / max_r, 4),
            n_jobs=int(n_valid[i]),
            padded_rows=int(cap - n_valid[i]),
            padding_frac=round(1.0 - float(n_valid[i]) / max(cap, 1), 4),
        )
        if work_frac[i] is not None:
            lane["work_round_frac"] = round(work_frac[i], 4)
            lane["skip_frac"] = round(1.0 - work_frac[i], 4)
        lanes.append(lane)

    wf = [w for w in work_frac if w is not None]
    out = dict(
        lanes=lanes,
        summary=dict(
            n_lanes=K,
            rounds_max=int(rounds.max()),
            rounds_total=int(rounds.sum()),
            # lock-step tax: rounds a vmapped ensemble executes per lane vs
            # the rounds the lanes actually need
            active_frac_mean=round(float(rounds.mean()) / max_r, 4),
            lockstep_waste_frac=round(1.0 - float(rounds.sum()) / (K * max_r), 4),
            padding_frac_mean=round(1.0 - float(n_valid.mean()) / max(cap, 1), 4),
            **({"work_round_frac_mean": round(float(np.mean(wf)), 4),
                "skip_frac_mean": round(1.0 - float(np.mean(wf)), 4)} if wf else {}),
        ),
    )
    if buckets is not None:
        out["buckets"] = buckets.padding_stats()
    return out
