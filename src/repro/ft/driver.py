"""Fault-tolerant training driver.

Checkpoint/restart loop for the whole run: any step may raise (node loss,
preemption — injectable for tests); the driver restores the latest checkpoint
and replays from there.  The data pipeline is a pure function of the step, so
recovery is bit-deterministic.  Straggler mitigation at this layer is
step-time watchdogging (log + optional abort->restart); in the simulator
layer it is PanDA-style resubmission (engine retries).
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from ..checkpoint.checkpoint import AsyncCheckpointer, latest_step, restore
from ..data.pipeline import TokenPipeline
from ..train.train_step import TrainState, init_train_state, make_train_step

log = logging.getLogger("repro.ft")


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministically fail at given steps (tests) or with probability p."""

    at_steps: tuple = ()
    prob: float = 0.0
    seed: int = 0
    _failed_once: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.at_steps and step not in self._failed_once:
            self._failed_once.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")
        if self.prob > 0:
            if np.random.default_rng((self.seed, step)).random() < self.prob:
                if step not in self._failed_once:
                    self._failed_once.add(step)
                    raise InjectedFailure(f"injected stochastic failure at step {step}")


@dataclass
class RunReport:
    steps_done: int = 0
    restarts: int = 0
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    slow_steps: int = 0


def train_with_restarts(
    model,
    pipeline: TokenPipeline,
    *,
    total_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 20,
    opt_cfg=None,
    microbatches: int = 1,
    compress: bool = False,
    injector: FailureInjector | None = None,
    max_restarts: int = 10,
    straggler_factor: float = 3.0,
    rng_seed: int = 0,
) -> RunReport:
    """Run to ``total_steps`` surviving failures via checkpoint/restart."""
    from ..train.optimizer import AdamWConfig

    opt_cfg = opt_cfg or AdamWConfig(total_steps=total_steps)
    step_fn = jax.jit(
        make_train_step(model, opt_cfg, microbatches=microbatches, compress=compress)
    )
    ckpt = AsyncCheckpointer(ckpt_dir)
    report = RunReport()

    restarts = 0
    while True:
        # ---- (re)initialize or restore --------------------------------------
        state = init_train_state(model, jax.random.PRNGKey(rng_seed), compress=compress)
        start = 0
        if latest_step(ckpt_dir) is not None:
            state, start = restore(ckpt_dir, state)
            log.info("restored checkpoint at step %d", start)
        try:
            step_ema = None
            for step in range(start, total_steps):
                if injector is not None:
                    injector.maybe_fail(step)
                t0 = time.time()
                batch = pipeline.batch_at(step)
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                report.losses.append(loss)
                report.step_times.append(dt)
                report.steps_done = step + 1
                # straggler watchdog
                if step_ema is not None and dt > straggler_factor * step_ema:
                    report.slow_steps += 1
                    log.warning("straggler step %d: %.2fs vs ema %.2fs", step, dt, step_ema)
                step_ema = dt if step_ema is None else 0.9 * step_ema + 0.1 * dt
                if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
                    ckpt.save(step + 1, state)
            ckpt.wait()
            report.restarts = restarts
            return report
        except InjectedFailure as e:
            restarts += 1
            log.warning("%s — restarting (%d/%d)", e, restarts, max_restarts)
            ckpt.wait()
            if restarts > max_restarts:
                raise
