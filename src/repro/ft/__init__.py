from .driver import FailureInjector, InjectedFailure, RunReport, train_with_restarts  # noqa: F401
