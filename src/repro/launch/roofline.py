"""Roofline-term extraction from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

``compiled.cost_analysis()`` reports the per-partition (per-chip) module, so
terms divide by single-chip constants.  collective_bytes comes from parsing
the post-SPMD per-device HLO: we sum the byte cost of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute with the
standard ring-cost factors (all-reduce counts twice: reduce-scatter +
all-gather phases).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-kind byte totals from a post-optimization per-device HLO dump."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result-shape tokens appear before ' <op>(' — match op use, not name
        for kind in _COLLECTIVES:
            if f" {kind}(" in stripped or f" {kind}-start(" in stripped:
                lhs = stripped.split("=", 1)
                if len(lhs) != 2:
                    continue
                # result shape(s) sit at the start of the RHS
                rhs = lhs[1].strip()
                shape_end = rhs.find(kind)
                out[kind] += _shape_bytes(rhs[:shape_end])
                break
    return out


# ring-cost multipliers: bytes actually moved per device per op result-byte
_COST_FACTOR = {
    "all-gather": 1.0,          # (n-1)/n ~ 1 of the gathered result
    "all-reduce": 2.0,          # reduce-scatter + all-gather phases
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    coll_bytes: float           # per device (cost-weighted)
    coll_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float          # 6*N_active*D (train) / 2*N_active*D (serve)
    useful_ratio: float         # model_flops_per_device / hlo_flops
    peak_bytes_per_device: float
    step_s: float               # max of the three terms
    roofline_frac: float        # model-flops-time / step_s (perf score)

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    compiled,
    model_flops_total: float,
    peak_bytes: float | None = None,
) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))
    breakdown = collective_bytes(compiled.as_text())
    coll = sum(_COST_FACTOR[k] * v for k, v in breakdown.items())

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_ / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    model_flops_dev = model_flops_total / n_devices
    step_s = max(terms.values())
    ideal_s = model_flops_dev / PEAK_FLOPS_BF16
    if peak_bytes is None:
        try:
            ma = compiled.memory_analysis()
            peak_bytes = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)
            )
        except Exception:
            peak_bytes = -1.0
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_devices=n_devices,
        hlo_flops=flops,
        hlo_bytes=bytes_,
        coll_bytes=coll,
        coll_breakdown=breakdown,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops_total,
        useful_ratio=(model_flops_dev / flops) if flops else 0.0,
        peak_bytes_per_device=peak_bytes,
        step_s=step_s,
        roofline_frac=(ideal_s / step_s) if step_s else 0.0,
    )


def model_flops_for_cell(cfg, shape_spec, kind: str) -> float:
    """6*N_active*tokens for train; 2*N_active*tokens for serving steps."""
    if kind == "train":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return cfg.model_flops_per_token(backward=True) * tokens
    if kind == "prefill":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return cfg.model_flops_per_token(backward=False) * tokens
    # decode: one token per sequence; attention reads the cache (memory-bound,
    # not counted in 2N) — 2*N_active per new token
    tokens = shape_spec.global_batch
    return cfg.model_flops_per_token(backward=False) * tokens
