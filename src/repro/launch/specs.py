"""input_specs(): ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
zero allocation) for every (arch x shape) dry-run cell, plus the step
function that cell lowers."""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config, get_plan
from ..models import build_model
from ..parallel.sharding import batch_axes, cache_shardings, params_shardings
from ..serve.serve_step import make_decode_step, make_prefill_step
from ..train.optimizer import AdamWConfig
from ..train.train_step import init_train_state, make_train_step


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


class Cell(NamedTuple):
    arch: str
    shape: str
    cfg: object
    plan: object
    kind: str
    microbatches: int


def build_cell(arch: str, shape: str, mesh: Mesh) -> Cell:
    cfg = get_config(arch)
    plan = get_plan(arch, shape)
    spec = SHAPES[shape]
    # Megatron-style vocab padding so [V, d] tables shard over 'model'
    # (documented fidelity note: pad ids are never targets)
    model_par = mesh.shape.get("model", 1)
    cfg = cfg.replace(vocab_size=round_up(cfg.vocab_size, max(16, model_par)))
    if plan.seq_shard and spec.kind == "train":
        cfg = cfg.replace(seq_shard=True)
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    mb = plan.microbatches
    if spec.kind == "train" and dp < 32:
        mb = min(mb * (32 // dp), spec.global_batch)  # keep per-shard footprint
    return Cell(arch, shape, cfg, plan, spec.kind, mb)


def _struct(sharding):
    return lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sharding) if not isinstance(
        x, jax.ShapeDtypeStruct
    ) else jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sharding)


def _to_structs(abstract, shardings):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), abstract, shardings
    )


def _batch_spec_for(B: int, mesh: Mesh):
    axes = batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return axes if (axes and B % total == 0) else None


def input_specs(cell: Cell, mesh: Mesh):
    """Returns (fn, specs_tuple, donate) for jax.jit(...).lower(*specs)."""
    cfg, spec = cell.cfg, SHAPES[cell.shape]
    model = build_model(cfg)
    B, S = spec.global_batch, spec.seq_len
    Baxes = _batch_spec_for(B, mesh)

    def tok_struct(b, s):
        return jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=NamedSharding(mesh, P(Baxes, None)))

    def emb_struct(b, t):
        return jax.ShapeDtypeStruct(
            (b, t, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(Baxes, None, None)),
        )

    def batch_structs(b, s):
        batch = {"tokens": tok_struct(b, s)}
        if cfg.family == "encdec":
            batch["frames"] = emb_struct(b, cfg.n_frames)
        if cfg.family == "vlm":
            batch["patch_embeds"] = emb_struct(b, cfg.n_patches)
        return batch

    if cell.kind == "train":
        opt_8bit = getattr(cell.plan, "opt_8bit", False)
        abs_state = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0), opt_8bit=opt_8bit)
        )
        state_structs = _to_structs(abs_state, params_shardings(abs_state, mesh))
        step = make_train_step(model, AdamWConfig(), microbatches=cell.microbatches,
                               opt_8bit=opt_8bit)
        fn = lambda state, batch: step(state, batch)
        return fn, (state_structs, batch_structs(B, S)), (0,)

    abs_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    psh = params_shardings(abs_params, mesh)
    params_structs = _to_structs(abs_params, psh)

    if cell.kind == "prefill":
        cache_len = S
        abs_cache = jax.eval_shape(lambda: model.init_cache(B, cache_len))
        csh = cache_shardings(abs_cache, mesh, shard_len=cell.plan.shard_cache_len, batch=Baxes)
        cache_structs = _to_structs(abs_cache, csh)
        step = make_prefill_step(model)
        fn = lambda params, batch, cache: step(params, batch, cache)
        return fn, (params_structs, batch_structs(B, S), cache_structs), (2,)

    # decode: one new token against a cache of seq_len (or the plan override)
    cache_len = cell.plan.decode_cache_len or S
    abs_cache = jax.eval_shape(lambda: model.init_cache(B, cache_len))
    # pretend the cache is full: len scalar is part of the pytree
    csh = cache_shardings(abs_cache, mesh, shard_len=cell.plan.shard_cache_len, batch=Baxes)
    cache_structs = _to_structs(abs_cache, csh)
    decode = make_decode_step(model)
    fn = lambda params, tok, cache: decode(params, tok, cache)
    return fn, (params_structs, tok_struct(B, 1), cache_structs), (2,)
