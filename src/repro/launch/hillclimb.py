"""§Perf hillclimbing driver: measure a cell under optimization variants and
log hypothesis -> change -> before -> after.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen2.5-32b:prefill_32k \
        --variant 'qblock:attention_impl=qblock' --variant 'bigchunk:attn_chunk=2048'

Variants are ``name:key=val,key=val`` (ints/floats/bools/strs auto-coerced;
``mb=N`` sets microbatches).  Results append to results/perf/<cell>.jsonl.
"""
from __future__ import annotations

import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import json

from .measure import measure_cell


def _coerce(v: str):
    if v in ("True", "true"):
        return True
    if v in ("False", "false"):
        return False
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def parse_variant(spec: str):
    name, _, kvs = spec.partition(":")
    overrides, plan_overrides, mb = {}, {}, None
    if kvs:
        for kv in kvs.split(","):
            k, _, v = kv.partition("=")
            if k == "mb":
                mb = int(v)
            elif k.startswith("plan."):
                plan_overrides[k[5:]] = _coerce(v)
            else:
                overrides[k] = _coerce(v)
    return name, overrides, plan_overrides, mb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", action="append", default=[],
                    help="name:key=val,... ('baseline' runs plan defaults)")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    arch, _, shape = args.cell.partition(":")
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{arch}__{shape}.jsonl")

    variants = [("baseline", {}, {}, None)] if not args.variant else [
        parse_variant(v) for v in args.variant
    ]
    for name, overrides, plan_overrides, mb in variants:
        try:
            rec = measure_cell(arch, shape, overrides=overrides, microbatches=mb,
                               plan_overrides=plan_overrides)
            rec["variant"] = name
            rec["overrides"] = {**overrides, **{f"plan.{k}": v for k, v in plan_overrides.items()}}
            if mb is not None:
                rec["microbatches"] = mb
        except Exception as e:  # noqa: BLE001
            rec = dict(arch=arch, shape=shape, variant=name, overrides=overrides,
                       ok=False, error=f"{type(e).__name__}: {e}")
            print("FAIL", name, rec["error"])
        with open(path, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
