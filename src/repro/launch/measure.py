"""Roofline measurement pass: exact per-device cost terms per cell.

Why not read the full dry-run module?  Two artifacts corrupt its counts:
  1. XLA cost_analysis counts while/scan bodies ONCE (verified: a 10-step
     scanned matmul reports 1 matmul of flops), so scan-over-layers and
     grad-accumulation undercount by L x MB.
  2. The CPU backend has no native bf16 dots: FloatNormalization upcasts to
     f32 *before* weight all-gathers, inflating byte counts 2x vs the TPU
     target.

Method (per cell, single-pod mesh):
  * compile the cell's program UNROLLED (scan_layers=False: layer loop,
    attention KV loop, SSD chunk loop all unrolled) at two reduced depths
    L1 < L2.  Per-layer cost is depth-uniform, so
        cost(L) = fixed + (L / pattern) * group
    is exact linear extrapolation to the full depth.
  * for train cells the measured program is value_and_grad(loss) on ONE
    microbatch; totals compose as MB x micro + optimizer (the optimizer
    update is elementwise — compiled separately, counted exactly).
  * bytes and collective bytes are dtype-corrected: f32 tensors in a bf16
    model are CPU upcasts, counted at 2 bytes (the optimizer program is
    genuinely f32 and is not corrected).

Outputs one JSON per cell under results/roofline/.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # before jax locks the device count
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_skips, runnable_cells
from ..models import build_model
from ..models.transformer import plan_segments
from ..parallel.sharding import cache_shardings, params_shardings
from ..train.optimizer import AdamWConfig, adamw_update, init_opt_state
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from .roofline import _COST_FACTOR, collective_bytes, model_flops_for_cell
from .specs import Cell, _batch_spec_for, _to_structs, build_cell


def _collective_bytes_corrected(hlo_text: str, bf16_correct: bool) -> tuple[float, dict]:
    """Cost-weighted collective bytes; f32 results halved when the model is
    bf16 (CPU FloatNormalization upcast)."""
    import re

    total = 0.0
    breakdown = {}
    pat = re.compile(r"=\s*(\(?[^=]*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        shapes_text, kind = m.groups()
        from .roofline import _shape_bytes, _SHAPE_RE

        b = 0.0
        for dt, dims in _SHAPE_RE.findall(shapes_text):
            from .roofline import _DTYPE_BYTES

            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            size = n * _DTYPE_BYTES[dt]
            if bf16_correct and dt == "f32":
                size *= 0.5
            b += size
        total += _COST_FACTOR[kind] * b
        breakdown[kind] = breakdown.get(kind, 0.0) + _COST_FACTOR[kind] * b
    return total, breakdown


# ops whose operands/results actually move through HBM on the TPU target.
# Pure elementwise ops fuse on TPU; the CPU backend leaves them unfused, so
# raw "bytes accessed" overcounts HBM traffic by ~2 orders of magnitude
# (measured 15 TB/step on deepseek train_4k).  We count dots, convolutions,
# fusions (their boundary operands), data movement and collectives.
_MATERIAL_OPS = {
    "dot", "convolution", "fusion", "scatter", "gather", "dynamic-slice",
    "dynamic-update-slice", "reduce", "reduce-window", "sort", "copy",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "select-and-scatter", "pad", "concatenate",
    "iota", "rng-bit-generator",
}

_LINE_RE = None


def _fusion_adjusted_bytes(hlo_text: str, bf16_correct: bool) -> float:
    """Sum result+operand bytes over materialization-worthy ops, with f32
    halved for bf16 models (CPU upcast correction)."""
    import re

    from .roofline import _DTYPE_BYTES

    def_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    op_re = re.compile(r"([\w-]+)\(")
    arg_re = re.compile(r"%([\w.\-]+)")

    sizes: dict[str, float] = {}
    total = 0.0
    in_fused = False  # ops inside %fused_computation bodies are paid at the
    # fusion call site; counting them again would double-bill
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "fused_computation" in stripped:
            in_fused = True
            continue
        if in_fused:
            if stripped == "}" or stripped.startswith("}"):
                in_fused = False
            continue
        m = def_re.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # result bytes (first shape tokens before the opcode)
        om = op_re.search(rhs)
        opcode = om.group(1) if om else ""
        shape_end = rhs.find(opcode + "(") if opcode else len(rhs)
        rbytes = 0.0
        for dt, dims in shape_re.findall(rhs[: shape_end if shape_end > 0 else len(rhs)]):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            b = n * _DTYPE_BYTES[dt]
            if bf16_correct and dt == "f32":
                b *= 0.5
            rbytes += b
        sizes[name] = rbytes
        if opcode in _MATERIAL_OPS:
            ob = sum(sizes.get(a, 0.0) for a in arg_re.findall(rhs[shape_end:]))
            total += rbytes + ob
    return total


def _measure_program(fn, arg_structs, mesh, *, bf16_correct: bool):
    with jax.set_mesh(mesh):
        compiled = jax.jit(fn).lower(*arg_structs).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    txt = compiled.as_text()
    bytes_ = _fusion_adjusted_bytes(txt, bf16_correct)
    coll, breakdown = _collective_bytes_corrected(txt, bf16_correct)
    return {"flops": flops, "bytes": bytes_, "coll": coll, "breakdown": breakdown}


def _depths(cfg) -> tuple[int, int, float]:
    """(L1, L2, groups_at_full_depth) in layer units matched to the pattern."""
    if cfg.family == "encdec":
        return 2, 4, cfg.n_layers  # n_enc = n_dec = L in reduced cfgs
    pat = len(cfg.block_pattern) if cfg.family == "hybrid" else 1
    return pat, 2 * pat, cfg.n_layers / pat


def _reduced(cfg, L: int):
    kw = dict(n_layers=L, scan_layers=False)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=L, n_dec_layers=L)
    return cfg.replace(**kw)


def _program_structs(cell: Cell, cfg_L, mesh):
    """Input structs for the measured (single-microbatch / serve) program."""
    spec = SHAPES[cell.shape]
    model = build_model(cfg_L)
    B, S = spec.global_batch, spec.seq_len
    Baxes = _batch_spec_for(B, mesh)

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=NamedSharding(mesh, P(Baxes, None)))

    def emb(b, t):
        return jax.ShapeDtypeStruct(
            (b, t, cfg_L.d_model), jnp.dtype(cfg_L.dtype),
            sharding=NamedSharding(mesh, P(Baxes, None, None)),
        )

    def batch_structs(b, s):
        batch = {"tokens": tok(b, s)}
        if cfg_L.family == "encdec":
            batch["frames"] = emb(b, cfg_L.n_frames)
        if cfg_L.family == "vlm":
            batch["patch_embeds"] = emb(b, cfg_L.n_patches)
        return batch

    abs_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pstructs = _to_structs(abs_params, params_shardings(abs_params, mesh))

    if cell.kind == "train":
        b_micro = max(B // cell.microbatches, 1)

        def fn(params, batch):
            (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
            return loss, grads

        return fn, (pstructs, batch_structs(b_micro, S))
    if cell.kind == "prefill":
        abs_cache = jax.eval_shape(lambda: model.init_cache(B, S))
        cstructs = _to_structs(
            abs_cache,
            cache_shardings(abs_cache, mesh, shard_len=cell.plan.shard_cache_len, batch=Baxes),
        )
        return (lambda p, b, c: model.prefill(p, b, c)), (pstructs, batch_structs(B, S), cstructs)
    cache_len = cell.plan.decode_cache_len or S
    abs_cache = jax.eval_shape(lambda: model.init_cache(B, cache_len))
    cstructs = _to_structs(
        abs_cache,
        cache_shardings(abs_cache, mesh, shard_len=cell.plan.shard_cache_len, batch=Baxes),
    )
    return (lambda p, t, c: model.decode(p, t, c)), (pstructs, tok(B, 1), cstructs)


def measure_cell(arch: str, shape: str, *, verbose: bool = True,
                 overrides: dict | None = None, microbatches: int | None = None,
                 plan_overrides: dict | None = None) -> dict:
    """``overrides``: ModelConfig.replace kwargs applied on top of the cell
    plan (the §Perf hillclimb hook); ``microbatches`` overrides the plan's;
    ``plan_overrides``: CellPlan.replace kwargs (e.g. opt_8bit=True)."""
    import dataclasses

    mesh = make_production_mesh(multi_pod=False)
    cell = build_cell(arch, shape, mesh)
    if overrides:
        cell = cell._replace(cfg=cell.cfg.replace(**overrides))
    if plan_overrides:
        cell = cell._replace(plan=dataclasses.replace(cell.plan, **plan_overrides))
    if microbatches is not None:
        cell = cell._replace(microbatches=microbatches)
    cfg = cell.cfg
    bf16 = jnp.dtype(cfg.dtype) == jnp.bfloat16
    L1, L2, n_groups = _depths(cfg)

    t0 = time.time()
    meas = {}
    for L in (L1, L2):
        cfg_L = _reduced(cfg, L)
        cell_L = cell._replace(cfg=cfg_L)
        fn, structs = _program_structs(cell_L, cfg_L, mesh)
        meas[L] = _measure_program(fn, structs, mesh, bf16_correct=bf16)

    # linear extrapolation: cost(L) = fixed + (L/pat) * group
    pat = L2 - L1
    out = {}
    for key in ("flops", "bytes", "coll"):
        group = (meas[L2][key] - meas[L1][key]) / (L2 / L1 - 1)  # per L1-sized group
        fixed = meas[L1][key] - group
        per_unit = group  # cost of L1 layers
        total_units = cfg.n_layers / L1 if cfg.family != "encdec" else cfg.n_layers / L1
        out[key] = fixed + per_unit * total_units

    # optimizer program (train only): exact, no dtype correction
    opt = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    if cell.kind == "train":
        from ..train.optimizer import adamw_update_8bit, init_opt_state_8bit

        opt_8bit = getattr(cell.plan, "opt_8bit", False)
        model = build_model(cfg)
        abs_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        psh = params_shardings(abs_params, mesh)
        pstructs = _to_structs(abs_params, psh)
        init_fn = init_opt_state_8bit if opt_8bit else init_opt_state
        abs_opt = jax.eval_shape(lambda: init_fn(abs_params))
        ostructs = _to_structs(abs_opt, params_shardings(abs_opt, mesh))
        gstructs = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, jnp.float32, sharding=s), abs_params, psh
        )
        update = adamw_update_8bit if opt_8bit else adamw_update

        def opt_fn(params, grads, state):
            return update(AdamWConfig(), params, grads, state)

        opt = _measure_program(opt_fn, (pstructs, gstructs, ostructs), mesh, bf16_correct=False)
        for key in ("flops", "bytes", "coll"):
            out[key] = out[key] * cell.microbatches + opt[key]

    spec = SHAPES[shape]
    n_dev = mesh.size
    model_flops_total = model_flops_for_cell(cfg, spec, cell.kind)
    compute_s = out["flops"] / PEAK_FLOPS_BF16
    memory_s = out["bytes"] / HBM_BW
    collective_s = out["coll"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    ideal_s = model_flops_total / n_dev / PEAK_FLOPS_BF16
    rec = dict(
        arch=arch,
        shape=shape,
        mesh="16x16",
        n_devices=n_dev,
        kind=cell.kind,
        microbatches=cell.microbatches,
        seq_shard=cfg.seq_shard,
        hlo_flops=out["flops"],
        hlo_bytes=out["bytes"],
        coll_bytes=out["coll"],
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops_total,
        useful_ratio=(model_flops_total / n_dev / out["flops"]) if out["flops"] else 0.0,
        step_s=step_s,
        roofline_frac=(ideal_s / step_s) if step_s else 0.0,
        opt_terms=opt,
        measure_depths=[L1, L2],
        measure_s=time.time() - t0,
        ok=True,
    )
    if verbose:
        print(
            f"[roofline] {arch} x {shape}: compute={compute_s:.4f}s memory={memory_s:.4f}s "
            f"collective={collective_s:.4f}s -> {bottleneck}-bound frac={rec['roofline_frac']:.3f} "
            f"useful={rec['useful_ratio']:.2f} ({rec['measure_s']:.0f}s)"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    cells = runnable_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        path = os.path.join(args.out, f"{arch}__{shape}.json".replace("/", "_"))
        if args.skip_existing and os.path.exists(path):
            print("skip", arch, shape)
            continue
        try:
            rec = measure_cell(arch, shape)
        except Exception as e:  # noqa: BLE001
            rec = dict(arch=arch, shape=shape, ok=False,
                       error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-4000:])
            print("FAIL", arch, shape, rec["error"])
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
