"""Multi-pod dry-run: ``.lower().compile()`` every (architecture x input
shape x mesh) cell on placeholder devices; record memory_analysis,
cost_analysis and the collective schedule for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun

The XLA_FLAGS lines below MUST run before any other import touches jax.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback

import jax

from ..configs import ARCHS, SHAPES, get_skips, runnable_cells
from .mesh import make_production_mesh
from .roofline import analyze, model_flops_for_cell
from .specs import build_cell, input_specs


def run_cell(arch: str, shape: str, *, multi_pod: bool, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_dev = mesh.size
    cell = build_cell(arch, shape, mesh)
    fn, specs, donate = input_specs(cell, mesh)

    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        if verbose:
            print(f"[{mesh_name}] {arch} x {shape}: lower {t_lower:.1f}s compile {t_compile:.1f}s")
            print("  memory_analysis:", mem)
        spec = SHAPES[shape]
        rf = analyze(
            arch=arch,
            shape=shape,
            mesh_name=mesh_name,
            n_devices=n_dev,
            compiled=compiled,
            model_flops_total=model_flops_for_cell(cell.cfg, spec, cell.kind),
        )
        if verbose:
            print("  cost_analysis: flops/dev=%.3e bytes/dev=%.3e coll/dev=%.3e" % (
                rf.hlo_flops, rf.hlo_bytes, rf.coll_bytes))
            print("  terms: compute=%.4fs memory=%.4fs collective=%.4fs -> %s-bound, "
                  "roofline_frac=%.3f" % (
                      rf.compute_s, rf.memory_s, rf.collective_s, rf.bottleneck,
                      rf.roofline_frac))
    out = json.loads(rf.to_json())
    out.update(
        lower_s=t_lower,
        compile_s=t_compile,
        memory_analysis=str(mem),
        microbatches=cell.microbatches,
        seq_shard=cell.cfg.seq_shard,
        kind=cell.kind,
        ok=True,
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="every runnable cell")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = runnable_cells()
    else:
        archs = [args.arch] if args.arch else list(ARCHS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [
            (a, s) for a in archs for s in shapes if s not in get_skips(a)
        ]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{'2x16x16' if multi else '16x16'}__{arch}__{shape}".replace("/", "_")
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print("skip", tag)
                continue
            try:
                rec = run_cell(arch, shape, multi_pod=multi)
            except Exception as e:  # noqa: BLE001 - record and continue
                rec = dict(arch=arch, shape=shape, mesh="2x16x16" if multi else "16x16",
                           ok=False, error=f"{type(e).__name__}: {e}",
                           traceback=traceback.format_exc()[-4000:])
                failures.append(tag)
                print("FAIL", tag, rec["error"])
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    skipped = [(a, s, r) for a in ARCHS for s, r in get_skips(a).items()]
    with open(os.path.join(args.out, "skips.json"), "w") as f:
        json.dump([{"arch": a, "shape": s, "reason": r} for a, s, r in skipped], f, indent=1)
    print(f"done; {len(failures)} failures", failures if failures else "")


if __name__ == "__main__":
    main()
