"""Production mesh definitions.

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(model: int = 1):
    """Whatever this host has (tests / examples): (n_dev/model, model)."""
    n = len(jax.devices())
    data = max(n // model, 1)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


# TPU v5e hardware constants (roofline targets; this container is CPU-only)
PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
