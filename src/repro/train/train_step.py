"""Distributed train step: grad-accumulation scan + AdamW (+ optional int8
error-feedback gradient compression).

The global batch [GB, S] is split into ``microbatches`` chunks scanned
sequentially (activation footprint / microbatch, the memory lever for the
400B-class cells); gradients accumulate in f32.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from ..parallel.sharding import constrain_batch
from .compress import compress_grads, init_error_state
from .optimizer import (AdamWConfig, adamw_update, adamw_update_8bit,
                        init_opt_state, init_opt_state_8bit)


class TrainState(NamedTuple):
    params: dict
    opt: dict
    err: dict | None  # error-feedback state (grad compression) or None


def init_train_state(model: Model, rng, *, compress: bool = False,
                     opt_8bit: bool = False) -> TrainState:
    params = model.init(rng)
    return TrainState(
        params=params,
        opt=init_opt_state_8bit(params) if opt_8bit else init_opt_state(params),
        err=init_error_state(params) if compress else None,
    )


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    microbatches: int = 1,
    compress: bool = False,
    opt_8bit: bool = False,
):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: tokens [GB, S] (+ frames/patch_embeds with matching leading GB).
    """

    def loss_of(params, mb):
        return model.loss(params, mb)

    def train_step(state: TrainState, batch):
        batch = {k: constrain_batch(v) for k, v in batch.items()}
        params = state.params
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = {
                k: v.reshape(microbatches, v.shape[0] // microbatches, *v.shape[1:])
                for k, v in batch.items()
            }
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                (l, met), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return acc, (l, met)

            grads, (losses, metss) = jax.lax.scan(body, zero, micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metss)

        err = state.err
        if compress and err is not None:
            grads, err = compress_grads(grads, err)

        update = adamw_update_8bit if opt_8bit else adamw_update
        new_params, new_opt, opt_metrics = update(opt_cfg, params, grads, state.opt)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(new_params, new_opt, err), metrics

    return train_step


def make_eval_step(model: Model):
    @jax.jit
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return dict(metrics, loss=loss)

    return eval_step
