"""Int8 gradient compression with error feedback (1-bit-Adam family numerics).

Deployed at scale this sits on the cross-pod all-reduce: each pod reduces in
bf16 in-pod, quantizes to int8 (per-tensor absmax scale), all-reduces int8
across the DCI, dequantizes, and carries the quantization residual into the
next step (error feedback keeps the bias bounded).  Under pjit the reduction
itself is XLA-inserted, so this module implements the *numerics* transform
(quantize -> dequantize + residual carry) that the compressed collective
produces; EXPERIMENTS.md §Perf accounts the 4x cross-pod byte saving on the
collective roofline term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, err_state):
    """grads + carried error -> (int8-roundtripped grads, new error)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _q8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq, gf - deq

    out = jax.tree.map(one, grads, err_state)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, err


def compression_ratio(params) -> float:
    """Bytes saved on the cross-pod hop: bf16 (2B) -> int8 (1B) + scale."""
    total = sum(p.size for p in jax.tree.leaves(params))
    return (2.0 * total) / (1.0 * total + 4.0 * len(jax.tree.leaves(params)))
