"""AdamW with warmup+cosine schedule and global-norm clipping, pure JAX.

Optimizer state shards exactly like the parameters (ZeRO): m/v inherit the
param PartitionSpecs, so FSDP over ('pod','data') applies to the full
(2 + 4 + 4) bytes/param footprint.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


# ---- 8-bit optimizer states (bitsandbytes-style block-wise quantization) ----
#
# AdamW m/v at f32 cost 8 B/param — at kimi-k2 scale (1.04T params) that is
# 20.4 GB/device on 512 chips: over HBM on its own.  Block-wise int8 states
# (block along the last dim, f32 scale per block) cut the optimizer footprint
# 4x; the quantized tensors keep the parameter's shape so every sharding rule
# applies unchanged.

_QBLOCK = 256


def _q8_block(x):
    *lead, last = x.shape
    pad = (-last) % _QBLOCK
    xp = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    xb = xp.reshape(*lead, (last + pad) // _QBLOCK, _QBLOCK)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, last + pad)[..., :last], scale[..., 0]


def _dq8_block(q, scale):
    *lead, last = q.shape
    pad = (-last) % _QBLOCK
    qp = jnp.pad(q, [(0, 0)] * len(lead) + [(0, pad)])
    qb = qp.reshape(*lead, (last + pad) // _QBLOCK, _QBLOCK).astype(jnp.float32)
    x = qb * scale[..., None]
    return x.reshape(*lead, last + pad)[..., :last]


def init_opt_state_8bit(params):
    def zq(p):
        q, s = _q8_block(jnp.zeros(p.shape, jnp.float32))
        return {"q": q, "scale": s}

    return {
        "m": jax.tree.map(zq, params),
        "v": jax.tree.map(zq, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update_8bit(cfg: AdamWConfig, params, grads, state):
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale_g = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mq, vq):
        g = g.astype(jnp.float32) * scale_g
        m = cfg.b1 * _dq8_block(mq["q"], mq["scale"]) + (1 - cfg.b1) * g
        v = cfg.b2 * _dq8_block(vq["q"], vq["scale"]) + (1 - cfg.b2) * jnp.square(g)
        step = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay * p.astype(jnp.float32))
        nm_q, nm_s = _q8_block(m)
        nv_q, nv_s = _q8_block(v)
        return new_p.astype(p.dtype), {"q": nm_q, "scale": nm_s}, {"q": nv_q, "scale": nv_s}

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(state["m"])
    leaves_v = treedef.flatten_up_to(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in zip(leaves_p, leaves_g, leaves_m, leaves_v)]
    unf = lambda i: jax.tree.unflatten(treedef, [o[i] for o in outs])
    return unf(0), {"m": unf(1), "v": unf(2), "count": count}, {"grad_norm": gnorm, "lr": lr}


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, params, grads, state):
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, {"grad_norm": gnorm, "lr": lr}
