from .optimizer import AdamWConfig, adamw_update, init_opt_state, schedule  # noqa: F401
from .train_step import TrainState, init_train_state, make_eval_step, make_train_step  # noqa: F401
from .compress import compress_grads, compression_ratio, init_error_state  # noqa: F401
