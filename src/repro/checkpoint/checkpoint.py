"""Checkpointing: atomic pytree save/restore with async writes and
elastic resharding on load.

Layout: <dir>/step_<N>/ { manifest.json, arrays.npz } written to a temp dir
and atomically renamed — a crash mid-write never corrupts the latest
checkpoint.  ``restore`` places leaves onto any mesh via target shardings, so
a run checkpointed on 512 chips restarts on 256 (elastic scaling: the mesh is
an argument, not a property of the checkpoint).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree, *, keep_last: int = 3) -> str:
    """Blocking atomic save.  Returns the checkpoint path."""
    flat, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = {}
    manifest = {"step": step, "time": time.time(), "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            manifest["leaves"][key] = {"dtype": "bfloat16"}
            arr = arr.astype(np.float32)
        else:
            manifest["leaves"][key] = {"dtype": str(arr.dtype)}
        arrays[key.replace(_SEP, "__")] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


class AsyncCheckpointer:
    """Overlap checkpoint writes with training (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree),
            kwargs={"keep_last": self.keep_last}, daemon=True,
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template, *, step: int | None = None, shardings=None):
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of NamedShardings (same structure) — leaves
    are placed directly onto the target mesh (elastic reshard-on-load).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))

    flat_t, treedef = _flatten(template)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    leaves = []
    for key, tmpl in flat_t.items():
        arr = arrays[key.replace(_SEP, "__")]
        dtype = manifest["leaves"][key]["dtype"]
        arr = arr.astype(jnp.bfloat16 if dtype == "bfloat16" else dtype)
        if key in flat_s:
            leaves.append(jax.device_put(arr, flat_s[key]))
        else:
            leaves.append(jnp.asarray(arr))
    # tree_unflatten wants leaves in treedef order == flatten order
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
