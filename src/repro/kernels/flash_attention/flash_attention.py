"""Pallas TPU kernel: causal / sliding-window GQA flash attention (forward).

Online-softmax tiling (Flash-Attention style, adapted to TPU): grid
(B, Hq, nQ, nK) with the KV dimension fastest so the output block is
revisited consecutively; running max / denominator / accumulator live in
VMEM scratch in f32.  Block shapes default to 128x128 — MXU-aligned on the
v5e target and (128x128x4B) x ~6 buffers ≈ 400 KB of VMEM, far under budget;
block_k scales to 512 for long-context prefill without spilling.

Fully-masked tiles (future tiles under causality, tiles behind the sliding
window) are skipped with ``pl.when`` — for long_500k local attention this is
what turns O(S^2) into O(S x window).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,  # [1,1,bq,D], [1,1,bk,D], [1,1,bk,D], [1,1,bq,D]
    acc_ref, m_ref, l_ref,       # scratch: [bq,D] f32, [bq,1] f32, [bq,1] f32
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    kv_offset: int,
):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # tile-level skip tests (absolute positions; q is right-aligned to kv end)
    q_lo = iq * block_q + kv_offset
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi                    # not entirely in the future
    if window > 0:
        live &= k_hi > q_lo - window            # not entirely behind the window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)               # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, bk]

        rows = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_lo
        cols = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1) + k_lo
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # [bq, bk]
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                    # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # [B, Hq, S, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    B, Hq, S, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = float(scale if scale is not None else D ** -0.5)

    pad_q = (-S) % block_q
    pad_k = (-Skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq, Skvp = S + pad_q, Skv + pad_k
    nq, nk = Sq // block_q, Skvp // block_k
    kv_offset = Skv - S  # right-align q positions to the kv end (decode/prefill)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            window=window,
            block_q=block_q,
            block_k=block_k,
            kv_offset=kv_offset,
        ),
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik, g=G: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik, g=G: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :S]
