"""Attention entry points.

``flash_attention`` — the Pallas kernel (TPU target; interpret-mode on CPU).
``chunked_attention`` — same online-softmax math as a lax.scan over KV
chunks: differentiable, SPMD-partitionable, remat-friendly.  Models use this
path inside pjit (a pallas_call does not SPMD-partition automatically across
the 512-device mesh); the kernel is the single-device hot-spot implementation
and is validated against the same oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import attention_ref

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "block_q", "block_k")
)
def flash_attention(q, k, v, *, causal=True, window=0, scale=None, block_q=128, block_k=128):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=_INTERPRET,
    )


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "chunk", "unroll")
)
def chunked_attention(q, k, v, *, causal=True, window=0, scale=None, chunk=512,
                      unroll=False):
    """Online-softmax attention, scanned over KV chunks.

    q [B,Hq,S,D], k/v [B,Hkv,Skv,D] (Skv >= S, q right-aligned).  Peak live
    logits are [B,Hq,S,chunk] — bounded regardless of Skv.  ``unroll``
    replaces the scan with a Python loop (exact cost_analysis accounting for
    the roofline measurement pass).
    """
    B, Hq, S, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    scale_ = scale if scale is not None else D ** -0.5

    pad = (-Skv) % chunk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (Skv + pad) // chunk
    kc = kp.reshape(B, Hkv, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)
    vc = vp.reshape(B, Hkv, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)

    qf = q.astype(jnp.float32) * scale_
    q_pos = jnp.arange(S) + (Skv - S)

    def step(carry, inputs):
        m, l, acc = carry
        kci, vci, c0 = inputs
        kg = jnp.repeat(kci, G, axis=1).astype(jnp.float32)   # [B,Hq,chunk,D]
        vg = jnp.repeat(vci, G, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhsd,bhtd->bhst", qf, kg)             # [B,Hq,S,chunk]
        kv_pos = c0 + jnp.arange(chunk)
        mask = kv_pos[None, :] < Skv
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhst,bhtd->bhsd", p, vg)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hq, S, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hq, S, 1), jnp.float32)
    acc0 = jnp.zeros((B, Hq, S, D), jnp.float32)
    offsets = jnp.arange(n_chunks) * chunk
    if unroll:
        carry = (m0, l0, acc0)
        for i in range(n_chunks):
            carry, _ = step(carry, (kc[i], vc[i], offsets[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kc, vc, offsets))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "chunk", "q_block", "unroll"),
)
def qblock_attention(q, k, v, *, causal=True, window=0, scale=None, chunk=512,
                     q_block=1024, unroll=False):
    """Two-level flash schedule in jnp: outer loop over q blocks, inner
    online-softmax loop over kv chunks, with causal/window *block skipping*
    (the Pallas kernel's schedule, expressed as HLO).

    vs ``chunked_attention`` this (a) halves causal attention FLOPs by
    skipping fully-masked tiles and (b) shrinks the softmax carry traffic
    from [B,H,S,D] per kv step to [B,H,q_block,D] per tile — the §Perf
    memory-term lever for long-context prefill.
    """
    B, Hq, S, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    scale_ = scale if scale is not None else D ** -0.5

    pad_q = (-S) % q_block
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    nq = (S + pad_q) // q_block
    pad_k = (-Skv) % chunk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nk = (Skv + pad_k) // chunk
    kc = kp.reshape(B, Hkv, nk, chunk, D)
    vc = vp.reshape(B, Hkv, nk, chunk, D)
    off = Skv - S  # q right-aligned

    def q_tile(iq, q_blk):
        q_lo = iq * q_block + off
        q_pos = q_lo + jnp.arange(q_block)
        qf = q_blk.astype(jnp.float32) * scale_

        def kv_step(carry, ik):
            m, l, acc = carry
            kci = jax.lax.dynamic_index_in_dim(kc, ik, 2, keepdims=False)
            vci = jax.lax.dynamic_index_in_dim(vc, ik, 2, keepdims=False)
            kg = jnp.repeat(kci, G, axis=1).astype(jnp.float32)
            vg = jnp.repeat(vci, G, axis=1).astype(jnp.float32)
            s = jnp.einsum("bhsd,bhtd->bhst", qf, kg)
            kv_pos = ik * chunk + jnp.arange(chunk)
            mask = kv_pos[None, :] < Skv
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            if window > 0:
                mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, None], s, -jnp.inf)
            m_cur = jnp.max(s, -1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
            alpha = jnp.exp(m - m_new)
            return (m_new, l * alpha + p.sum(-1, keepdims=True),
                    acc * alpha + jnp.einsum("bhst,bhtd->bhsd", p, vg)), None

        m0 = jnp.full((B, Hq, q_block, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hq, q_block, 1), jnp.float32)
        a0 = jnp.zeros((B, Hq, q_block, D), jnp.float32)
        # tile skipping: causal upper bound / window lower bound
        q_hi = q_lo + q_block - 1
        ik_hi = min((int(q_hi) // chunk) + 1, nk) if causal else nk
        ik_lo = max((int(q_lo) - window + 1) // chunk, 0) if window > 0 else 0
        if unroll:
            carry = (m0, l0, a0)
            for ik in range(ik_lo, ik_hi):
                carry, _ = kv_step(carry, ik)
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), jnp.arange(ik_lo, ik_hi)
            )
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    tiles = [q_tile(iq, qp[:, :, iq * q_block:(iq + 1) * q_block]) for iq in range(nq)]
    out = jnp.concatenate(tiles, axis=2)
    return out[:, :, :S]


def decode_attention(q, k, v, *, window=0, kv_len=None, scale=None):
    """Single-token decode: q [B,Hq,1,D] against a [B,Hkv,Skv,D] cache.

    ``kv_len`` (i32[B] or scalar) masks the still-empty tail of the cache;
    ``window`` restricts to the last ``window`` live positions.
    """
    B, Hq, _, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    scale_ = scale if scale is not None else D ** -0.5
    kg = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vg = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32) * scale_, kg)  # [B,Hq,1,Skv]
    pos = jnp.arange(Skv)[None, None, None, :]
    if kv_len is None:
        live = jnp.ones((1, 1, 1, Skv), bool)
    else:
        kl = jnp.asarray(kv_len).reshape(-1, 1, 1, 1)
        live = pos < kl
        if window > 0:
            live &= pos >= kl - window
    s = jnp.where(live, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vg).astype(q.dtype)


__all__ = ["flash_attention", "chunked_attention", "decode_attention", "attention_ref"]
