"""Dense pure-jnp oracle for (causal | sliding-window) GQA attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0, scale: float | None = None):
    """q [B,Hq,S,D], k/v [B,Hkv,Skv,D] -> [B,Hq,S,D].

    window > 0 keeps only kv in (q_pos - window, q_pos] (local attention);
    softmax in f32 regardless of input dtype.
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    Skv = k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    kq = jnp.repeat(k, G, axis=1)
    vq = jnp.repeat(v, G, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kq.astype(jnp.float32))
    logits = logits * scale

    q_pos = jnp.arange(S)[:, None] + (Skv - S)  # right-aligned when Skv > S
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window > 0:
        mask &= kv_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhst,bhtd->bhsd", p, vq.astype(jnp.float32)).astype(q.dtype)
