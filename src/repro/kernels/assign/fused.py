"""Pallas TPU kernel: fused candidate-set assignment (sparse top-k path).

The dense ``assign.py`` kernel ranks a full ``f32[N, E]`` score tile per
block.  In sparse top-k mode (engine ``topk=``, DESIGN.md §12) the per-job
score row is already compacted to ``K`` candidate sites — ``f32[N, K]``
scores plus an ``i32[N, K]`` site index with sentinel ``E`` marking empty
slots.  This kernel fuses the remaining pipeline — candidate rank, site
pick, and capacity-respecting FIFO admission — in one pass, so the dense
``[N, E]`` masked-score intermediate of ``make_capacity_assign`` never
materializes: per block only the tiny ``[bn, K]`` tiles and the one-hot
admission tile touch VMEM.

Semantics (k=1 FIFO admission, same contract as ``assign.py``):
  - per row, the best valid candidate wins; ties break to the *lowest slot*,
    which equals the dense lowest-site-id tie-break because the engine's
    candidate rows are sorted ascending by site id (``sparse.build_candidates``),
  - admission consumes per-site capacity in item order via a weighted prefix
    sum, with a ``used[1, E]`` VMEM carry across the sequential grid,
  - claims accumulate whether or not admitted (FIFO head-of-line blocking,
    matching the engine's start phase and ``ref.assign_ref``).

With candidates = all feasible sites (``k >= S``) this is bit-for-bit the
dense ``make_capacity_assign`` pick — the property ``tests/test_fused_assign``
checks against the jnp oracle and the dense kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fused_kernel(
    scores_ref,  # [bn, Kp] f32 VMEM: candidate scores (NEG_INF pad)
    cand_ref,    # [bn, Kp] i32 VMEM: candidate site ids (sentinel >= n_sites)
    sizes_ref,   # [bn, 1]  f32 VMEM
    caps_ref,    # [1, Ep]  f32 VMEM (same block every step)
    site_ref,    # [bn, 1]  i32 out
    admit_ref,   # [bn, 1]  i32 out (bool as int32)
    used_ref,    # [1, Ep]  f32 scratch: per-site units consumed so far
    *,
    n_sites: int,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        used_ref[...] = jnp.zeros_like(used_ref)

    sc = scores_ref[...]
    cd = cand_ref[...]
    bn, Kp = sc.shape
    caps = caps_ref[...]  # [1, Ep]
    Ep = caps.shape[-1]
    sz = sizes_ref[...]  # [bn, 1]

    # rank: best valid candidate per row, ties to the lowest slot (= lowest
    # site id, candidate rows are sorted ascending)
    valid = cd < n_sites
    v = jnp.where(valid, sc, NEG_INF)
    best_val = jnp.max(v, axis=-1, keepdims=True)  # [bn, 1]
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (bn, Kp), 1)
    slot = jnp.min(jnp.where(v >= best_val, iota_k, Kp), axis=-1, keepdims=True)
    site = jnp.sum(jnp.where(iota_k == slot, cd, 0), axis=-1, keepdims=True)  # [bn,1]
    ok = best_val > NEG_INF / 2

    # capacity-respecting FIFO pick: scatter to the site lane, prefix-sum
    # claims in item order, admit under cap with the cross-block used carry
    iota_e = jax.lax.broadcasted_iota(jnp.int32, (bn, Ep), 1)
    onehot = (iota_e == site) & ok  # [bn, Ep]
    w = jnp.where(onehot, sz, 0.0)
    cum_excl = jnp.cumsum(w, axis=0) - w
    used = used_ref[...]
    pos = jnp.sum(jnp.where(onehot, cum_excl + used, 0.0), axis=-1, keepdims=True)
    cap_at = jnp.sum(jnp.where(onehot, caps, 0.0), axis=-1, keepdims=True)
    admit = ok & (pos + sz <= cap_at + 1e-6)
    used_ref[...] = used + jnp.sum(w, axis=0, keepdims=True)  # FIFO claims

    site_ref[:, 0] = jnp.where(ok, site, -1)[:, 0]
    admit_ref[:, 0] = admit.astype(jnp.int32)[:, 0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fused_assign_pallas(
    scores_k: jax.Array,  # f32[N, K] candidate scores
    cand: jax.Array,      # i32[N, K] candidate site ids (sentinel >= E)
    sizes: jax.Array,     # f32[N]
    caps: jax.Array,      # f32[E]
    *,
    block_n: int = 256,
    interpret: bool = False,
):
    N, K = scores_k.shape
    E = caps.shape[0]
    nb = -(-N // block_n)
    pad_n = nb * block_n - N
    # lane-align both the candidate axis and the site axis; padded slots are
    # sentinel candidates, padded sites have cap 0 and are never picked
    pad_k = (-K) % 128
    pad_e = (-E) % 128
    Ep = E + pad_e
    scores_p = jnp.pad(
        scores_k.astype(jnp.float32), ((0, pad_n), (0, pad_k)), constant_values=NEG_INF
    )
    cand_p = jnp.pad(cand.astype(jnp.int32), ((0, pad_n), (0, pad_k)), constant_values=E)
    sizes_p = jnp.pad(sizes.astype(jnp.float32), ((0, pad_n),))[:, None]
    caps_p = jnp.pad(caps.astype(jnp.float32), ((0, pad_e),))[None, :]
    Kp = K + pad_k

    out_shape = (
        jax.ShapeDtypeStruct((nb * block_n, 1), jnp.int32),
        jax.ShapeDtypeStruct((nb * block_n, 1), jnp.int32),
    )
    out_spec = pl.BlockSpec((block_n, 1), lambda i: (i, 0))
    site, admit = pl.pallas_call(
        functools.partial(_fused_kernel, n_sites=E),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, Kp), lambda i: (i, 0)),
            pl.BlockSpec((block_n, Kp), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, Ep), lambda i: (0, 0)),
        ],
        out_specs=(out_spec, out_spec),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((1, Ep), jnp.float32)],
        interpret=interpret,
    )(scores_p, cand_p, sizes_p, caps_p)
    return site[:N, 0], admit[:N, 0].astype(bool)


def fused_assign_ref(scores_k, cand, sizes, caps, *, block_n: int = 256):
    """jnp oracle with identical block-sequential semantics (see module doc).

    Returns ``(site i32[N], admit bool[N])``; ``site`` is -1 when no valid
    candidate exists.
    """
    N, K = scores_k.shape
    E = caps.shape[0]
    scores_k = scores_k.astype(jnp.float32)
    sizes = sizes.astype(jnp.float32)
    caps = caps.astype(jnp.float32)

    valid = cand < E
    v = jnp.where(valid, scores_k, NEG_INF)
    best_slot = jnp.argmax(v, axis=-1)  # first max = lowest slot = lowest site
    site = jnp.take_along_axis(cand, best_slot[:, None], axis=-1)[:, 0]
    ok = jnp.take_along_axis(v, best_slot[:, None], axis=-1)[:, 0] > NEG_INF / 2
    site_c = jnp.clip(site, 0, E - 1).astype(jnp.int32)

    nb = -(-N // block_n)
    pad = nb * block_n - N
    site_b = jnp.pad(site_c, ((0, pad),)).reshape(nb, block_n)
    ok_b = jnp.pad(ok, ((0, pad),)).reshape(nb, block_n)
    sz_b = jnp.pad(sizes, ((0, pad),)).reshape(nb, block_n)

    def block_step(used, blk):
        st, okb, szb = blk  # [bn] each
        iota = jnp.arange(E)[None, :]
        onehot = (iota == st[:, None]) & okb[:, None]
        w = onehot * szb[:, None]
        cum_excl = jnp.cumsum(w, axis=0) - w
        pos = (cum_excl * onehot).sum(-1) + used[st]
        admit = okb & (pos + szb <= caps[st] + 1e-6)
        # claims accumulate whether or not admitted: FIFO head-of-line
        used = used + w.sum(0)
        return used, admit

    used0 = jnp.zeros((E,), jnp.float32)
    _, admit_b = jax.lax.scan(block_step, used0, (site_b, ok_b, sz_b))
    admit = admit_b.reshape(nb * block_n)[:N]
    return jnp.where(ok, site, -1).astype(jnp.int32), admit & ok
