"""Jitted wrappers: the assignment kernel as (a) a simulator dispatch
combinator and (b) an MoE routing primitive."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .assign import assign_pallas
from .fused import fused_assign_pallas, fused_assign_ref
from .ref import assign_ref

# interpret=True on CPU (this container); compiled Mosaic on real TPU.
_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("k", "block_n", "use_kernel"))
def assign(scores, sizes, caps, *, k: int = 1, block_n: int = 256, use_kernel: bool = True):
    """Capacity-constrained greedy assignment (see assign.py for semantics)."""
    if use_kernel:
        return assign_pallas(scores, sizes, caps, k=k, block_n=block_n, interpret=_INTERPRET)
    return assign_ref(scores, sizes, caps, k=k, block_n=block_n)


def make_capacity_assign(
    jobs_cores: jax.Array | None = None, *, use_kernel: bool | None = None, block_n: int = 256
):
    """Build an engine-compatible ``Policy.assign`` fn: jobs -> sites under
    free-core capacity; jobs beyond capacity stay QUEUED at the main server.

    ``use_kernel=None`` (the default) resolves by backend: the compiled
    Mosaic kernel on TPU, the jnp oracle elsewhere (pallas interpret mode
    inside the engine's while_loop is CPU-slow).  Pass an explicit bool to
    override either way — e.g. ``True`` on CPU runs the kernel in interpret
    mode, the CI smoke configuration (``bench_assign_kernel --tiny``).
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"

    def assign_fn(scores, queued, feasible, sites):
        NEG = jnp.float32(-1e30)
        masked = jnp.where(feasible & queued[:, None], scores, NEG)
        sizes = jnp.ones((scores.shape[0],), jnp.float32) if jobs_cores is None else (
            jobs_cores.astype(jnp.float32)
        )
        sizes = jnp.where(queued, sizes, 0.0)
        caps = jnp.where(sites.active, sites.free_cores, 0).astype(jnp.float32)
        idx, gate, admit, pos = assign(
            masked, sizes, caps, k=1, block_n=block_n, use_kernel=use_kernel
        )
        ok = admit[:, 0] & queued
        return jnp.where(ok, idx[:, 0], -1), ok

    return assign_fn


@functools.partial(jax.jit, static_argnames=("block_n", "use_kernel"))
def fused_topk_assign(scores_k, cand, sizes, caps, *, block_n: int = 256, use_kernel: bool = True):
    """Fused candidate-set rank + capacity pick (see fused.py for semantics)."""
    if use_kernel:
        return fused_assign_pallas(
            scores_k, cand, sizes, caps, block_n=block_n, interpret=_INTERPRET
        )
    return fused_assign_ref(scores_k, cand, sizes, caps, block_n=block_n)


def make_fused_capacity_assign(
    jobs_cores: jax.Array | None = None, *, use_kernel: bool | None = None, block_n: int = 256
):
    """Build an engine-compatible ``Policy.assign_cand`` fn for sparse top-k
    mode (engine ``topk=``): rank the per-job candidate set and admit under
    free-core capacity in one fused pass, without ever materializing the
    dense ``[J, S]`` masked-score matrix that ``make_capacity_assign`` builds.

    With candidates covering all feasible sites (``topk >= S``) the result is
    bit-for-bit equal to the dense ``make_capacity_assign`` path.  Backend
    dispatch matches ``make_capacity_assign``: ``use_kernel=None`` runs the
    Mosaic kernel on TPU and the jnp oracle elsewhere; an explicit ``True``
    on CPU runs the kernel in interpret mode (the CI smoke configuration).
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"

    def assign_cand(scores_k, queued, feas_k, cand, sites):
        S = sites.capacity
        cand_eff = jnp.where(feas_k & queued[:, None], cand, S).astype(jnp.int32)
        sizes = jnp.ones((scores_k.shape[0],), jnp.float32) if jobs_cores is None else (
            jobs_cores.astype(jnp.float32)
        )
        sizes = jnp.where(queued, sizes, 0.0)
        caps = jnp.where(sites.active, sites.free_cores, 0).astype(jnp.float32)
        site, admit = fused_topk_assign(
            scores_k, cand_eff, sizes, caps, block_n=block_n, use_kernel=use_kernel
        )
        ok = admit & queued
        return jnp.where(ok, site, -1), ok

    return assign_cand


@functools.partial(jax.jit, static_argnames=("k", "capacity", "use_kernel", "block_n"))
def moe_route(router_logits, *, k: int, capacity: int, use_kernel: bool = True, block_n: int = 256):
    """Token->expert routing for the MoE layer.

    router_logits f32[T, E] -> (expert i32[T,k], combine f32[T,k],
    slot i32[T,k], keep bool[T,k]) where ``slot`` is the token's position in
    its expert's capacity buffer.  Combine weights are renormalised over kept
    slots (Switch/GShard convention).
    """
    T, E = router_logits.shape
    sizes = jnp.ones((T,), jnp.float32)
    caps = jnp.full((E,), float(capacity), jnp.float32)
    idx, gate, admit, pos = assign(
        router_logits, sizes, caps, k=k, block_n=block_n, use_kernel=use_kernel
    )
    keep = admit
    combine = gate * keep
    norm = jnp.maximum(combine.sum(-1, keepdims=True), 1e-9)
    combine = combine / norm * gate.sum(-1, keepdims=True).clip(0.0, 1.0)
    slot = pos.astype(jnp.int32)
    return idx, combine, slot, keep
