"""Pallas TPU kernel: capacity-constrained greedy assignment.

The CGSim ``assignJob`` hot loop (jobs x sites) and the MoE router
(tokens x experts) are the same computation: score every item against every
bin, pick the best feasible bin per slot, admit under per-bin capacity
(DESIGN.md §3).  SimGrid walks pointers; on TPU we tile the score matrix
through VMEM and keep a per-bin ``used`` accumulator in scratch across the
sequential grid.

Tiling: grid = (N // block_n,); each step owns a [block_n, E] score tile.
E (bins: <=256 sites, <=512 experts) fits one VMEM tile, so only items are
tiled; the per-bin carry makes admission exact across tiles.  block_n and E
are padded to multiples of 128 to stay MXU/VPU aligned on the v5e target:
a 256x512 f32 tile is 512 KB — far inside the ~16 MB VMEM budget even with
the mask copy and outputs.

Semantics match ``ref.assign_ref`` exactly (same block-sequential order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _assign_kernel(
    scores_ref,  # [bn, E] f32 VMEM
    sizes_ref,   # [bn, 1] f32 VMEM
    caps_ref,    # [1, E]  f32 VMEM (same block every step)
    idx_ref,     # [bn, k] i32 out
    gate_ref,    # [bn, k] f32 out
    admit_ref,   # [bn, k] i32 out (bool as int32)
    pos_ref,     # [bn, k] f32 out
    used_ref,    # [1, E]  f32 scratch: per-bin units consumed so far
    *,
    k: int,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        used_ref[...] = jnp.zeros_like(used_ref)

    s = scores_ref[...]
    bn, E = s.shape
    sz = sizes_ref[...]  # [bn, 1]
    caps = caps_ref[...]  # [1, E]
    iota_e = jax.lax.broadcasted_iota(jnp.int32, (bn, E), 1)

    # row softmax over feasible bins (gate values for chosen bins)
    feas = s > NEG_INF / 2
    m = jnp.max(jnp.where(feas, s, -jnp.inf), axis=-1, keepdims=True)
    p = jnp.where(feas, jnp.exp(s - m), 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    gates = p / denom

    masked = s
    used = used_ref[...]  # [1, E]
    for slot in range(k):
        best_val = jnp.max(masked, axis=-1, keepdims=True)        # [bn, 1]
        is_best = masked >= best_val
        idx = jnp.min(jnp.where(is_best, iota_e, E), axis=-1, keepdims=True)  # [bn,1]
        ok = best_val > NEG_INF / 2                                # [bn, 1]
        onehot = (iota_e == idx) & ok                              # [bn, E]
        w = jnp.where(onehot, sz, 0.0)                             # [bn, E]
        cum_excl = jnp.cumsum(w, axis=0) - w                       # [bn, E]
        pos = jnp.sum(jnp.where(onehot, cum_excl + used, 0.0), axis=-1, keepdims=True)
        admit = ok & (pos + sz <= jnp.sum(jnp.where(onehot, caps, 0.0), -1, keepdims=True) + 1e-6)
        used = used + jnp.sum(w, axis=0, keepdims=True)            # FIFO claims
        gate = jnp.sum(jnp.where(onehot, gates, 0.0), -1, keepdims=True)

        idx_ref[:, slot] = jnp.where(ok, idx, -1)[:, 0]
        gate_ref[:, slot] = jnp.where(ok, gate, 0.0)[:, 0]
        admit_ref[:, slot] = admit.astype(jnp.int32)[:, 0]
        pos_ref[:, slot] = jnp.where(ok, pos, 0.0)[:, 0]
        masked = jnp.where(onehot, NEG_INF, masked)

    used_ref[...] = used


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def assign_pallas(
    scores: jax.Array,  # f32[N, E]
    sizes: jax.Array,   # f32[N]
    caps: jax.Array,    # f32[E]
    *,
    k: int = 1,
    block_n: int = 256,
    interpret: bool = False,
):
    N, E = scores.shape
    nb = -(-N // block_n)
    pad_n = nb * block_n - N
    # lane-align E for the VPU; padded bins are infeasible (-inf, cap 0)
    pad_e = (-E) % 128
    Ep = E + pad_e
    scores_p = jnp.pad(
        scores.astype(jnp.float32), ((0, pad_n), (0, pad_e)), constant_values=NEG_INF
    )
    sizes_p = jnp.pad(sizes.astype(jnp.float32), ((0, pad_n),))[:, None]
    caps_p = jnp.pad(caps.astype(jnp.float32), ((0, pad_e),))[None, :]

    out_shape = (
        jax.ShapeDtypeStruct((nb * block_n, k), jnp.int32),
        jax.ShapeDtypeStruct((nb * block_n, k), jnp.float32),
        jax.ShapeDtypeStruct((nb * block_n, k), jnp.int32),
        jax.ShapeDtypeStruct((nb * block_n, k), jnp.float32),
    )
    out_spec = pl.BlockSpec((block_n, k), lambda i: (i, 0))
    idx, gate, admit, pos = pl.pallas_call(
        functools.partial(_assign_kernel, k=k),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, Ep), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, Ep), lambda i: (0, 0)),
        ],
        out_specs=(out_spec, out_spec, out_spec, out_spec),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((1, Ep), jnp.float32)],
        interpret=interpret,
    )(scores_p, sizes_p, caps_p)
    clip = lambda x: x[:N]
    return clip(idx), clip(gate), clip(admit).astype(bool), clip(pos)
