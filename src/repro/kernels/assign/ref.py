"""Pure-jnp oracle for capacity-constrained greedy assignment.

Semantics ("block-sequential greedy", DESIGN.md §3): items are processed in
blocks of ``block_n`` in array order; within a block, slot s of *all* block
items is resolved before slot s+1 (slot-major), and admission consumes
capacity in item order via a weighted prefix sum.  With ``block_n >= N`` this
is exactly GShard slot-major routing; with ``k == 1`` it is exact FIFO
admission regardless of block size (the simulator dispatch case).

Inputs
  scores  f32[N, E]  raw policy/router logits; -inf marks infeasible pairs
  sizes   f32[N]     capacity units an item consumes (1 for tokens, cores for jobs)
  caps    f32[E]     per-bin capacity in the same units
Outputs
  bin_idx i32[N, k]  chosen bin per slot (-1 if infeasible)
  gate    f32[N, k]  softmax(scores) value of the chosen bin
  admit   bool[N, k] admitted under capacity
  pos     f32[N, k]  units consumed in the chosen bin *before* this item
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def assign_ref(scores, sizes, caps, *, k: int = 1, block_n: int = 256):
    N, E = scores.shape
    scores = scores.astype(jnp.float32)
    sizes = sizes.astype(jnp.float32)
    caps = caps.astype(jnp.float32)

    # row softmax over feasible bins only
    feas = scores > NEG_INF / 2
    m = jnp.max(jnp.where(feas, scores, -jnp.inf), axis=-1, keepdims=True)
    p = jnp.where(feas, jnp.exp(scores - m), 0.0)
    denom = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    gates_full = p / denom

    nb = -(-N // block_n)
    pad = nb * block_n - N
    scores_p = jnp.pad(scores, ((0, pad), (0, 0)), constant_values=NEG_INF)
    sizes_p = jnp.pad(sizes, ((0, pad),))
    gates_p = jnp.pad(gates_full, ((0, pad), (0, 0)))
    scores_b = scores_p.reshape(nb, block_n, E)
    sizes_b = sizes_p.reshape(nb, block_n)
    gates_b = gates_p.reshape(nb, block_n, E)

    def block_step(used, blk):
        s, sz, g = blk  # [bn, E], [bn], [bn, E]
        masked = s
        outs = []
        for _ in range(k):
            best_val = jnp.max(masked, axis=-1)
            iota = jnp.arange(E)[None, :]
            is_best = masked >= best_val[:, None]
            idx = jnp.min(jnp.where(is_best, iota, E), axis=-1)  # first argmax
            ok = best_val > NEG_INF / 2
            onehot = (iota == idx[:, None]) & ok[:, None]
            w = onehot * sz[:, None]
            cum_excl = jnp.cumsum(w, axis=0) - w  # [bn, E] units before me per bin
            pos = (cum_excl * onehot).sum(-1) + used[idx]  # at my bin + block carry
            admit = ok & (pos + sz <= caps[idx] + 1e-6)
            # claims accumulate whether or not admitted: FIFO head-of-line
            # blocking, the same semantics as the engine's start phase
            used = used + w.sum(0)
            gate = jnp.take_along_axis(g, idx[:, None], axis=-1)[:, 0]
            outs.append((jnp.where(ok, idx, -1), gate * ok, admit, pos * ok))
            masked = jnp.where(onehot, NEG_INF, masked)
        stack = lambda i: jnp.stack([o[i] for o in outs], axis=-1)
        return used, (stack(0).astype(jnp.int32), stack(1), stack(2), stack(3))

    used0 = jnp.zeros((E,), jnp.float32)
    _, (bin_idx, gate, admit, pos) = jax.lax.scan(
        block_step, used0, (scores_b, sizes_b, gates_b)
    )
    unblk = lambda x: x.reshape(nb * block_n, k)[:N]
    return unblk(bin_idx), unblk(gate), unblk(admit), unblk(pos)
