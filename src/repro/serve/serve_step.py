"""Serving steps: prefill a prompt batch, decode one token for the whole
batch.  These are the programs the decode_*/long_* dry-run cells lower."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.model import Model


def make_prefill_step(model: Model):
    def prefill_step(params, batch, cache):
        """batch tokens [B, S_prompt] -> (next-token logits [B,1,V], cache)."""
        return model.prefill(params, batch, cache)

    return prefill_step


def make_decode_step(model: Model, *, sample: bool = False, temperature: float = 1.0):
    def decode_step(params, token, cache, rng=None):
        """token i32[B,1] -> (next token i32[B,1], logits, cache)."""
        logits, cache = model.decode(params, token, cache)
        if sample and rng is not None:
            nxt = jax.random.categorical(rng, logits[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return nxt, logits, cache

    return decode_step


def generate(model: Model, params, batch, *, max_new: int, cache_len: int, rng=None):
    """Greedy/sampled generation loop (host-side; each step is jitted)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    cache = model.init_cache(B, cache_len)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model, sample=rng is not None))
    logits, cache = prefill(params, batch, cache)
    cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [cur]
    for i in range(max_new - 1):
        step_rng = jax.random.fold_in(rng, i) if rng is not None else None
        cur, logits, cache = decode(params, cur, cache, step_rng)
        out.append(cur)
    return jnp.concatenate(out, axis=1)
