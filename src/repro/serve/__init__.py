from .serve_step import generate, make_decode_step, make_prefill_step  # noqa: F401
