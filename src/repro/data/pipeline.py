"""Deterministic synthetic token pipeline.

Production shape without production data: an infinite, seeded, host-sharded
token stream.  ``batch_at(step)`` is a pure function of (seed, step, shard),
so restart-after-failure resumes bit-identically (checkpoint stores only the
step counter), and every data-parallel host reads a disjoint shard.

The generator produces Zipfian token draws with document boundaries (BOS) and
a repeated-ngram structure so losses actually decrease during the examples'
short training runs.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class DataConfig(NamedTuple):
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    bos_id: int = 1
    mean_doc_len: int = 512
    zipf_a: float = 1.2


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    p = ranks ** -cfg.zipf_a
    return (p / p.sum()).astype(np.float64)


class TokenPipeline:
    """Host-side numpy generation (cheap), device batches on demand."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide over hosts")
        self.cfg = cfg
        self._probs = _zipf_probs(cfg)
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id])
        )
        B, S = self.local_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(B, S), p=self._probs)
        # structure: periodic bigram echo (learnable signal)
        toks[:, 2::2] = toks[:, 1:-1:2]
        # document boundaries
        n_docs = max(1, S // cfg.mean_doc_len)
        for b in range(B):
            cuts = rng.choice(S, size=n_docs, replace=False)
            toks[b, cuts] = cfg.bos_id
        return {"tokens": jnp.asarray(toks, jnp.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def prefetch(it: Iterator[dict], depth: int = 2) -> Iterator[dict]:
    """Thread-backed prefetcher overlapping host generation with device step."""
    import queue
    import threading

    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    threading.Thread(target=worker, daemon=True).start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
