from .pipeline import DataConfig, TokenPipeline, prefetch  # noqa: F401
