"""Terminal dashboard CLI: render or tail a ``watch()`` NDJSON frame stream.

Usage:
    python -m repro.monitor run.ndjson              # render a finished run
    python -m repro.monitor --follow run.ndjson     # tail a live run (Fig. 5)

The stream is produced by ``core.monitor.watch(..., sink=NDJSONSink(path))``
in any other process; this command only ever reads the file, so the dashboard
is fully decoupled from the simulation it observes.
"""
from __future__ import annotations

import argparse
import sys

from repro.core.monitor import follow_stream


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.monitor", description=__doc__.splitlines()[0]
    )
    ap.add_argument("stream", help="NDJSON frame stream written by monitor.watch")
    ap.add_argument(
        "--follow", action="store_true",
        help="keep tailing the file as it grows (live dashboard)",
    )
    ap.add_argument("--every", type=int, default=1, help="render every Nth frame")
    ap.add_argument(
        "--no-clear", action="store_true",
        help="append frames instead of redrawing in place",
    )
    ap.add_argument(
        "--timeout", type=float, default=None,
        help="with --follow: give up after this many idle seconds",
    )
    args = ap.parse_args(argv)
    try:
        shown = follow_stream(
            args.stream,
            follow=args.follow,
            every=max(args.every, 1),
            clear=not args.no_clear,
            timeout_s=args.timeout,
        )
    except FileNotFoundError:
        print(f"no such stream: {args.stream}", file=sys.stderr)
        return 2
    except KeyboardInterrupt:
        return 130
    if shown == 0:
        print("(no frames in stream)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
