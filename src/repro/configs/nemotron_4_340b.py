"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
"""
from ..models.config import ModelConfig
from .shapes import CellPlan

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_head=192,
    d_ff=73728,
    mlp_act="relu2",
    vocab_size=256000,
)

SMOKE = CONFIG.replace(
    name="nemotron-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=256, vocab_size=512,
)

PLANS = {
    "train_4k": CellPlan(microbatches=8, seq_shard=True),
    "prefill_32k": CellPlan(),
    "decode_32k": CellPlan(),
}
SKIPS = {"long_500k": "pure full attention (quadratic); no sub-quadratic path"}
