"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768 (attn-free), vocab=50280, ssm_state=128.
"""
from ..models.config import ModelConfig
from .shapes import CellPlan

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab_size=50280,
    d_ff=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    notes="pure SSM; sub-quadratic -> runs long_500k",
)

SMOKE = CONFIG.replace(
    name="mamba2-smoke", n_layers=2, d_model=128, vocab_size=512,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=32,
)

PLANS = {
    "train_4k": CellPlan(microbatches=1),
    "prefill_32k": CellPlan(),
    "decode_32k": CellPlan(),
    "long_500k": CellPlan(notes="constant-size SSM state; cache is O(1)"),
}
SKIPS: dict[str, str] = {}
