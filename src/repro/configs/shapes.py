"""Assigned input shapes (one set shared by the LM-family pool) and the
per-(arch x shape) execution plan (microbatching, activation sharding)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class CellPlan:
    """Per-(arch x shape) parallel execution plan on the production mesh."""

    microbatches: int = 1        # grad-accum steps inside train_step
    seq_shard: bool = False      # shard the residual stream's seq dim over
                                 # 'model' at layer boundaries (SP-lite)
    shard_cache_len: bool = True  # shard KV-cache positions over 'model'
    decode_cache_len: int | None = None  # override cache buffer (e.g. window)
    opt_8bit: bool = False       # block-wise int8 optimizer states
    notes: str = ""


def default_plan(kind: str) -> CellPlan:
    return CellPlan()
