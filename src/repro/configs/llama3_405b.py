"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
"""
from ..models.config import ModelConfig
from .shapes import CellPlan

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_head=128,
    d_ff=53248,
    mlp_act="swiglu",
    vocab_size=128256,
    rope_theta=5e5,
)

SMOKE = CONFIG.replace(
    name="llama3-smoke", n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
    d_head=16, d_ff=384, vocab_size=512,
)

PLANS = {
    # 1 seq per DP shard per microbatch; SP-lite shards the residual stream's
    # seq dim over 'model' at scan boundaries -> ~1 GB of saved activations
    "train_4k": CellPlan(microbatches=8, seq_shard=True),
    "prefill_32k": CellPlan(),
    "decode_32k": CellPlan(),
}
SKIPS = {"long_500k": "pure full attention (quadratic); no sub-quadratic path"}
