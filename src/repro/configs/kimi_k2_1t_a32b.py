"""kimi-k2-1t-a32b [moe] — trillion-param MoE [arXiv:2501.kimi2 per brief].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048, 384 experts top-8,
vocab=163840.  ~1.04T parameters, ~32B active per token.

This is the paper-representative cell: token->expert capacity routing is the
CGSim assignJob problem (DESIGN.md §3) and uses the same assignment kernel
semantics.
"""
from ..models.config import ModelConfig
from .shapes import CellPlan

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=2048,
    mlp_act="swiglu",
    n_experts=384,
    top_k=8,
    capacity_factor=1.25,
    router_groups=32,  # = DP shards on the production mesh
    vocab_size=163840,
)

SMOKE = CONFIG.replace(
    name="kimi-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=64, n_experts=8, top_k=2, router_groups=2, vocab_size=512,
)

PLANS = {
    "train_4k": CellPlan(microbatches=8, seq_shard=True),
    "prefill_32k": CellPlan(),
    "decode_32k": CellPlan(),
}
SKIPS = {"long_500k": "pure full attention (quadratic); no sub-quadratic path"}
