"""Architecture registry: ``--arch <id>`` resolves here.

Each module exports CONFIG (exact public config), SMOKE (reduced same-family
config for CPU tests), PLANS ({shape: CellPlan}) and SKIPS ({shape: reason}).
"""
from __future__ import annotations

from . import (
    deepseek_7b,
    granite_moe_1b_a400m,
    internvl2_26b,
    kimi_k2_1t_a32b,
    llama3_405b,
    mamba2_130m,
    nemotron_4_340b,
    qwen2_5_32b,
    recurrentgemma_2b,
    whisper_small,
)
from .shapes import SHAPES, CellPlan, ShapeSpec  # noqa: F401

_MODULES = {
    "mamba2-130m": mamba2_130m,
    "qwen2.5-32b": qwen2_5_32b,
    "deepseek-7b": deepseek_7b,
    "llama3-405b": llama3_405b,
    "nemotron-4-340b": nemotron_4_340b,
    "internvl2-26b": internvl2_26b,
    "whisper-small": whisper_small,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "recurrentgemma-2b": recurrentgemma_2b,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str):
    return _MODULES[arch].CONFIG


def get_smoke(arch: str):
    return _MODULES[arch].SMOKE


def get_plan(arch: str, shape: str) -> CellPlan:
    return _MODULES[arch].PLANS.get(shape, CellPlan())


def get_skips(arch: str) -> dict[str, str]:
    return dict(_MODULES[arch].SKIPS)


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) cells minus documented skips (DESIGN.md §6)."""
    cells = []
    for arch, mod in _MODULES.items():
        for shape in SHAPES:
            if shape not in mod.SKIPS:
                cells.append((arch, shape))
    return cells
