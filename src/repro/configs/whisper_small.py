"""whisper-small [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

12 encoder + 12 decoder layers, d_model=768 12H d_ff=3072 vocab=51865.
``input_specs`` provides 1500 precomputed frame embeddings (the mel+conv
frontend stub).
"""
from ..models.config import ModelConfig
from .shapes import CellPlan

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    qkv_bias=True,
    d_ff=3072,
    mlp_act="gelu",
    vocab_size=51865,
    n_frames=1500,
)

SMOKE = CONFIG.replace(
    name="whisper-smoke", n_layers=2, n_enc_layers=2, n_dec_layers=2,
    d_model=128, n_heads=4, n_kv_heads=4, d_head=32, d_ff=256,
    vocab_size=512, n_frames=32,
)

PLANS = {
    "train_4k": CellPlan(microbatches=1),
    "prefill_32k": CellPlan(),
    "decode_32k": CellPlan(),
}
SKIPS = {"long_500k": "full-attention enc-dec; no sub-quadratic path"}
