"""qwen2.5-32b [dense] — GQA + QKV bias [hf:Qwen/Qwen2.5-*].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
"""
from ..models.config import ModelConfig
from .shapes import CellPlan

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    qkv_bias=True,
    d_ff=27648,
    mlp_act="swiglu",
    vocab_size=152064,
    rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    name="qwen2.5-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=256, vocab_size=512,
)

PLANS = {
    "train_4k": CellPlan(microbatches=4),
    "prefill_32k": CellPlan(),
    "decode_32k": CellPlan(),
}
SKIPS = {"long_500k": "pure full attention (quadratic); no sub-quadratic path"}
