"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].

Backbone only (per brief): 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553.  The vision frontend is a stub: ``input_specs`` provides
``patch_embeds`` [B, 256, d_model] spliced over the first token positions.
"""
from ..models.config import ModelConfig
from .shapes import CellPlan

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    mlp_act="swiglu",
    vocab_size=92553,
    n_patches=256,
)

SMOKE = CONFIG.replace(
    name="internvl2-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=256, vocab_size=512, n_patches=8,
)

PLANS = {
    "train_4k": CellPlan(microbatches=4),
    "prefill_32k": CellPlan(),
    "decode_32k": CellPlan(),
}
SKIPS = {"long_500k": "pure full attention (quadratic); no sub-quadratic path"}
