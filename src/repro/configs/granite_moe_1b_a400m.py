"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) expert d_ff=512, 32e top-8, vocab=49155.
"""
from ..models.config import ModelConfig
from .shapes import CellPlan

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    mlp_act="swiglu",
    n_experts=32,
    top_k=8,
    capacity_factor=1.25,
    router_groups=32,
    vocab_size=49155,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="granite-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=64, n_experts=4, top_k=2, router_groups=2, vocab_size=512,
)

PLANS = {
    "train_4k": CellPlan(microbatches=1),
    "prefill_32k": CellPlan(),
    "decode_32k": CellPlan(),
}
SKIPS = {"long_500k": "pure full attention (quadratic); no sub-quadratic path"}
