"""deepseek-7b [dense] — llama-arch [arXiv:2401.02954].

30L d_model=4096 32H (GQA kv=32, i.e. MHA) d_ff=11008 vocab=102400.
"""
from ..models.config import ModelConfig
from .shapes import CellPlan

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=11008,
    mlp_act="swiglu",
    vocab_size=102400,
)

SMOKE = CONFIG.replace(
    name="deepseek-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_head=32, d_ff=256, vocab_size=512,
)

PLANS = {
    "train_4k": CellPlan(microbatches=4),
    "prefill_32k": CellPlan(),
    "decode_32k": CellPlan(),
}
SKIPS = {"long_500k": "pure full attention (quadratic); no sub-quadratic path"}
