"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 1:2 [arXiv:2402.19427].

26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680 vocab=256000, window=2048.
Griffin pattern: (rec, rec, att) repeating -> 8 full groups + 2 recurrent.
"""
from ..models.config import ModelConfig
from .shapes import CellPlan

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    mlp_act="geglu",
    vocab_size=256000,
    window=2048,
    block_pattern=("rec", "rec", "att"),
    rnn_width=2560,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="recurrentgemma-smoke", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=1, d_head=32, d_ff=256, vocab_size=512, window=32,
    block_pattern=("rec", "rec", "att"), rnn_width=128,
)

PLANS = {
    "train_4k": CellPlan(microbatches=2),
    "prefill_32k": CellPlan(),
    "decode_32k": CellPlan(),
    # decode only ever touches the last `window` positions: rolling cache
    "long_500k": CellPlan(decode_cache_len=2048,
                          notes="window-bounded rolling KV + O(1) LRU state"),
}
SKIPS: dict[str, str] = {}
