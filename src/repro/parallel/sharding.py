"""Sharding rules: parameter path -> PartitionSpec on the production mesh.

Mapping (DESIGN.md §7):
  DP    batch over ('pod', 'data')
  FSDP  parameter d_model-ish dims over ('pod', 'data') (ZeRO-3; XLA inserts
        the per-layer all-gathers under the scan)
  TP    head / ff / vocab dims over 'model' (Megatron)
  EP    expert dim over 'model'
  SP    residual-stream seq dim over 'model' at scan boundaries (opt-in)

Single-pod meshes simply lack the 'pod' axis; every helper resolves axis
names against the mesh it is given.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes(mesh) -> tuple:
    return fsdp_axes(mesh)


def _strip_stacked(path_names: list[str], shape: tuple) -> bool:
    """Params under seg*/k* (or whisper enc/dec) carry a leading layer dim."""
    return any(n.startswith("seg") for n in path_names) or any(
        n in ("enc", "dec") for n in path_names
    )


def _validate_spec(spec: P, shape: tuple, mesh) -> P:
    """Drop axes whose mesh extent does not divide the dim (e.g. mamba's
    concatenated in_proj dim, whisper's 1500-frame cross cache)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        out.append(entry if shape[i] % extent == 0 else None)
    return P(*out)


def param_spec(path_names: list[str], shape: tuple, mesh) -> P:
    """PartitionSpec for one parameter leaf."""
    name = path_names[-1]
    if name in ("q", "scale") and len(path_names) >= 2:
        name = path_names[-2]  # 8-bit optimizer states shard like the param
    F = fsdp_axes(mesh) or None
    M = "model" if "model" in mesh.axis_names else None
    stacked = _strip_stacked(path_names, shape)
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape
    nd = len(core)

    def spec(*dims):
        return P(*lead, *dims)

    if name in ("embed", "lm_head", "pos_dec"):
        return P(M, F)  # [V, d] never stacked
    if name == "router":  # [d, E] — small, replicate over model for locality
        return spec(F, None) if nd == 2 else spec(None)
    if name in ("w_gate", "w_up") and nd == 3:  # experts [E, d, ff]
        return spec(M, F, None)
    if name == "w_down" and nd == 3:            # experts [E, ff, d]
        return spec(M, None, F)
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w_x"):
        return spec(F, M)                        # [d, out]
    if name in ("wo", "w_down", "out_proj", "w_out"):
        return spec(M, F)                        # [in, d]
    if name in ("w_rg", "w_ig"):                 # rglru [w, w]
        return spec(F, None)
    if name == "conv_w":                         # [K, C]
        return spec(None, F)
    if name in ("bq", "bk", "bv"):
        return spec(M)
    # norms, scalar gains, conv bias, A_log, D, dt_bias, lam, ...
    return spec(*(None,) * nd)


def params_shardings(params, mesh: Mesh):
    def assign(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        spec = _validate_spec(param_spec(names, leaf.shape, mesh), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, params)


def batch_shardings(batch_like, mesh: Mesh):
    B = batch_axes(mesh) or None

    def assign(leaf):
        nd = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
        return NamedSharding(mesh, P(B, *(None,) * (nd - 1)))

    return jax.tree.map(assign, batch_like)


def cache_shardings(cache, mesh: Mesh, *, shard_len: bool = True, batch="auto"):
    """KV caches: [L, B, H, S, D] -> (None, DP, None, 'model', None).
    Recurrent states: [L, B, ...] -> (None, DP, ...).

    ``batch``: DP axes tuple, None (replicate batch, e.g. global_batch=1), or
    "auto" (all of pod/data)."""
    B = (batch_axes(mesh) or None) if batch == "auto" else batch
    M = "model" if ("model" in mesh.axis_names and shard_len) else None

    def assign(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        nd = len(leaf.shape)
        if names[-1] in ("k", "v", "cross_k", "cross_v") and nd == 5:
            spec = P(None, B, None, M, None)
        elif names[-1] == "len" or nd == 0:
            spec = P()
        else:
            # stacked recurrent states [L, B, ...]
            spec = P(None, B, *(None,) * (nd - 2))
        return NamedSharding(mesh, _validate_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, cache)


def gather_fsdp(layer_params, mesh_axes=None):
    """Constrain per-layer params to their spec with the FSDP axes dropped:
    the ZeRO-3 all-gather happens HERE (small, per layer), and the 'model'
    (TP/EP) sharding is preserved so SPMD never replicates full weights into
    the matmuls (the 13.3 GB/layer pathology, EXPERIMENTS.md §Perf)."""
    axes = mesh_axes or ambient_axis_names()
    if "model" not in axes:
        return layer_params

    mesh = jax.sharding.get_abstract_mesh()

    def fix(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        spec = param_spec(names, leaf.shape, mesh)
        dropped = P(*[
            ("model" if e == "model" or (isinstance(e, tuple) and "model" in e) else None)
            for e in spec
        ])
        dropped = _validate_spec(dropped, leaf.shape, mesh)
        return jax.lax.with_sharding_constraint(leaf, dropped)

    return jax.tree_util.tree_map_with_path(fix, layer_params)


def ambient_axis_names() -> tuple:
    """Axis names of the mesh active inside the current jit trace ('' if none)."""
    try:
        m = jax.sharding.get_abstract_mesh()
        return tuple(m.axis_names) if m is not None else ()
    except Exception:
        return ()


def maybe_shard_seq(x):
    """SP-lite: constrain [B, S, d] to (DP, 'model', None) when a mesh with a
    'model' axis is ambient (no-op otherwise) — used at scan boundaries."""
    axes = ambient_axis_names()
    if "model" not in axes:
        return x
    B = tuple(a for a in ("pod", "data") if a in axes) or None
    return jax.lax.with_sharding_constraint(x, P(B, "model", None))


def constrain_batch(x):
    axes = ambient_axis_names()
    if not axes:
        return x
    B = tuple(a for a in ("pod", "data") if a in axes) or None
    nd = x.ndim
    return jax.lax.with_sharding_constraint(x, P(B, *(None,) * (nd - 1)))
