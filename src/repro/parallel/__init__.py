from .sharding import (  # noqa: F401
    batch_shardings,
    cache_shardings,
    constrain_batch,
    fsdp_axes,
    maybe_shard_seq,
    param_spec,
    params_shardings,
)
