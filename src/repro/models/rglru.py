"""Griffin / RecurrentGemma recurrent block (RG-LRU, arXiv:2402.19427).

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) with
a_t = exp(-c * softplus(Λ) * r_t), r_t/i_t sigmoid gates.  Training uses
``jax.lax.associative_scan`` over time; decode is the O(1) update.  The block
wraps the LRU in the Griffin shape: two input branches (GeLU gate x conv+LRU)
-> output projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import causal_conv1d, causal_conv1d_step, dense_init

_C = 8.0  # Griffin's fixed recurrence sharpness


def init_rglru(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    w = cfg.rnn_width
    ks = jax.random.split(key, 6)
    # Λ init so that a^c is uniform in [0.9, 0.999] (paper appendix)
    u = jax.random.uniform(ks[4], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # inv-softplus of -log(u)/c
    return {
        "w_x": dense_init(ks[0], d, w, dtype=dtype),          # recurrent branch
        "w_gate": dense_init(ks[1], d, w, dtype=dtype),       # GeLU branch
        "conv_w": (jax.random.normal(ks[2], (4, w)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_rg": dense_init(ks[3], w, w, scale=w ** -0.5, dtype=dtype),  # recurrence gate
        "b_rg": jnp.zeros((w,), jnp.float32),
        "w_ig": dense_init(ks[5], w, w, scale=w ** -0.5, dtype=dtype),  # input gate
        "b_ig": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "w_out": dense_init(
            jax.random.fold_in(key, 7), w, d, scale=w ** -0.5 / (2 * cfg.n_layers) ** 0.5, dtype=dtype
        ),
    }


def _gates(p, xb):
    r = jax.nn.sigmoid(xb.astype(jnp.float32) @ p["w_rg"].astype(jnp.float32) + p["b_rg"])
    i = jax.nn.sigmoid(xb.astype(jnp.float32) @ p["w_ig"].astype(jnp.float32) + p["b_ig"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * xb.astype(jnp.float32))


def rglru_forward(p, x, cfg: ModelConfig):
    """x [B, S, d] -> (y [B, S, d], cache with final hidden + conv tail)."""
    xb = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    K = p["conv_w"].shape[0]
    pre = jnp.pad(xb, ((0, 0), (K - 1, 0), (0, 0)))
    conv_tail = pre[:, -(K - 1):, :]
    xb = causal_conv1d(xb, p["conv_w"], p["conv_b"])
    a, u = _gates(p, xb)  # [B, S, w] each (f32)

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, u1 * a2 + u2

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y, {"conv": conv_tail, "h": h[:, -1]}


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, 3, cfg.rnn_width), dtype),
        "h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
    }


def rglru_decode(p, x_t, cfg: ModelConfig, cache):
    """x_t [B, 1, d]."""
    xb = (x_t[:, 0] @ p["w_x"])
    gate = jax.nn.gelu(x_t[:, 0] @ p["w_gate"])
    xb, conv_state = causal_conv1d_step(xb, cache["conv"], p["conv_w"], p["conv_b"])
    a, u = _gates(p, xb)
    h = a * cache["h"] + u
    y = ((h.astype(x_t.dtype) * gate) @ p["w_out"])[:, None, :]
    return y, {"conv": conv_state, "h": h}
