"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel audio frontend is a STUB per the brief: ``frames`` arrive as
precomputed [B, T_frames, d_model] embeddings (input_specs provides them).
Encoder: bidirectional attention blocks.  Decoder: causal self-attention +
cross-attention + GELU MLP, with learned positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .transformer import maybe_scan
from .attention import (
    attention_bidir,
    attention_decode,
    attention_prefill,
    attention_train,
    cross_attention,
    encode_cross_kv,
    init_attention,
    init_kv_cache,
)
from .config import ModelConfig
from .layers import embed_init, layernorm
from .mlp import init_mlp, mlp_forward

AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_drop_frac")


def _ln_init(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _ln(x, p, eps):
    return layernorm(x, p["w"], p["b"], eps=eps)


def init_params(rng, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(rng, 8)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": _ln_init(d, dtype),
            "attn": init_attention(k1, cfg, dtype),
            "ln2": _ln_init(d, dtype),
            "mlp": init_mlp(k2, cfg, dtype=dtype),
        }

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": _ln_init(d, dtype),
            "self_attn": init_attention(k1, cfg, dtype),
            "ln2": _ln_init(d, dtype),
            "cross_attn": init_attention(k2, cfg, dtype),
            "ln3": _ln_init(d, dtype),
            "mlp": init_mlp(k3, cfg, dtype=dtype),
        }

    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_dec_layers)
    return {
        "embed": embed_init(ks[2], cfg.vocab_size, d, dtype),
        "pos_dec": embed_init(ks[3], 4096, d, dtype),  # learned decoder positions
        "enc": jax.vmap(enc_block)(enc_keys),
        "dec": jax.vmap(dec_block)(dec_keys),
        "enc_norm": _ln_init(d, dtype),
        "dec_norm": _ln_init(d, dtype),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames [B, T, d] (stub frontend output) -> encoder states."""
    x = frames.astype(jnp.dtype(cfg.dtype))

    def body(x, p):
        h = attention_bidir(p["attn"], _ln(x, p["ln1"], cfg.norm_eps), cfg)
        x = x + h
        x = x + mlp_forward(p["mlp"], _ln(x, p["ln2"], cfg.norm_eps), cfg)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = maybe_scan(body_fn, x, params["enc"], cfg, cfg.n_enc_layers)
    return _ln(x, params["enc_norm"], cfg.norm_eps)


def _decoder_pos_embed(params, tokens, start):
    S = tokens.shape[1]
    pos = start + jnp.arange(S)
    return params["pos_dec"][jnp.clip(pos, 0, params["pos_dec"].shape[0] - 1)]


def forward(params, cfg: ModelConfig, tokens, frames):
    """Teacher-forced: encode frames, decode tokens -> (logits, aux)."""
    from ..parallel.sharding import constrain_batch

    enc = encode(params, cfg, frames)
    x = constrain_batch(params["embed"][tokens] + _decoder_pos_embed(params, tokens, 0))

    def body(x, p):
        x = x + attention_train(
            p["self_attn"], _ln(x, p["ln1"], cfg.norm_eps), cfg, rope=False
        )
        kv = encode_cross_kv(p["cross_attn"], enc, cfg)
        x = x + cross_attention(p["cross_attn"], _ln(x, p["ln2"], cfg.norm_eps), kv, cfg)
        x = x + mlp_forward(p["mlp"], _ln(x, p["ln3"], cfg.norm_eps), cfg)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = maybe_scan(body_fn, x, params["dec"], cfg, cfg.n_dec_layers)
    x = _ln(x, params["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    aux = {k: jnp.zeros(()) for k in AUX_KEYS}
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch["tokens"], batch["frames"])
    targets = batch["tokens"][:, 1:]
    logits = logits[:, :-1]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = (logz - gold).mean()
    return loss, dict(aux, nll=loss)


# ---------------------------------------------------------------- serving ---


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.n_dec_layers
    self_c = init_kv_cache(cfg, batch, max_len, dtype)
    cross_shape = (batch, cfg.n_kv_heads, cfg.n_frames, cfg.d_head)
    return {
        "len": jnp.zeros((), jnp.int32),
        "self": jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L, *a.shape)).copy(), self_c),
        "cross_k": jnp.zeros((L, *cross_shape), dtype),
        "cross_v": jnp.zeros((L, *cross_shape), dtype),
    }


def prefill(params, cfg: ModelConfig, tokens, cache, frames):
    """Encode audio, precompute per-layer cross K/V, prefill decoder self-KV."""
    enc = encode(params, cfg, frames)
    x = params["embed"][tokens] + _decoder_pos_embed(params, tokens, 0)

    def body(x, pc):
        p, self_cache = pc
        h, new_self = attention_prefill(
            p["self_attn"], _ln(x, p["ln1"], cfg.norm_eps), cfg, self_cache, start=0, rope=False
        )
        x = x + h
        ck, cv = encode_cross_kv(p["cross_attn"], enc, cfg)
        x = x + cross_attention(p["cross_attn"], _ln(x, p["ln2"], cfg.norm_eps), (ck, cv), cfg)
        x = x + mlp_forward(p["mlp"], _ln(x, p["ln3"], cfg.norm_eps), cfg)
        return x, (new_self, ck, cv)

    x, (new_self, cks, cvs) = maybe_scan(body, x, (params["dec"], cache["self"]), cfg, cfg.n_dec_layers)
    x = _ln(x, params["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:], params["embed"]).astype(jnp.float32)
    return logits, {
        "len": jnp.asarray(tokens.shape[1], jnp.int32),
        "self": new_self,
        "cross_k": cks,
        "cross_v": cvs,
    }


def decode_step(params, cfg: ModelConfig, token, cache):
    kv_len = cache["len"]
    x = params["embed"][token] + _decoder_pos_embed(params, token, kv_len)

    def body(x, pc):
        p, self_cache, ck, cv = pc
        # whisper uses learned absolute positions, not rope
        h, new_self = attention_decode(
            p["self_attn"], _ln(x, p["ln1"], cfg.norm_eps), cfg, self_cache, kv_len, rope=False
        )
        x = x + h
        x = x + cross_attention(p["cross_attn"], _ln(x, p["ln2"], cfg.norm_eps), (ck, cv), cfg)
        x = x + mlp_forward(p["mlp"], _ln(x, p["ln3"], cfg.norm_eps), cfg)
        return x, new_self

    x, new_self = maybe_scan(
        body, x, (params["dec"], cache["self"], cache["cross_k"], cache["cross_v"]),
        cfg, cfg.n_dec_layers,
    )
    x = _ln(x, params["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    return logits, dict(cache, self=new_self, len=kv_len + 1)
