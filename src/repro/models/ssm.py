"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), TPU-adapted.

Training uses the chunked SSD algorithm: intra-chunk terms are dense matmuls
(MXU-friendly quadratic-in-chunk blocks), inter-chunk state passing is a
short ``lax.scan`` over S/chunk steps.  Decode is the O(1) recurrent update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import causal_conv1d, causal_conv1d_step, dense_init, rmsnorm


def init_ssm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    ns = cfg.ssm_state
    ng = cfg.ssm_groups
    nh = cfg.n_ssm_heads
    conv_ch = di + 2 * ng * ns
    ks = jax.random.split(key, 5)
    dt = jnp.exp(
        jax.random.uniform(ks[3], (nh,)) * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)
    )
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ng * ns + nh, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv-softplus
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[4], di, d, scale=di ** -0.5 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
    }


def _split_proj(z_all, cfg: ModelConfig):
    di, ns, ng, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.n_ssm_heads
    z, xBC, dt = jnp.split(z_all, [di, 2 * di + 2 * ng * ns], axis=-1)
    return z, xBC, dt  # dt [..., nh]


def _segsum(a):
    """a [..., l] -> [..., l, l]: sum of a over (j, i] for i >= j else -inf."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(l)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan(x, dt, A_log, B, C, *, chunk: int, unroll: bool = False):
    """Chunked SSD.

    x [b, l, h, p]; dt [b, l, h] (post-softplus); B, C [b, l, g, n].
    Returns y [b, l, h, p] and the final state [b, h, p, n].
    """
    b, l, h, p_ = x.shape
    g = B.shape[2]
    n = B.shape[3]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (l + pad) // chunk
    # head -> group map: heads split evenly over groups
    rep = h // g

    def group(t):  # [b, l, g, n] -> [b, nc, chunk, h, n]
        t = t.reshape(b, nc, chunk, g, n)
        return jnp.repeat(t, rep, axis=3)

    xc = x.reshape(b, nc, chunk, h, p_)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc, Cc = group(B), group(C)

    xbar = xc * dtc[..., None]                       # dt-scaled input
    dA = -jnp.exp(A_log)[None, None, None, :] * dtc  # [b,nc,chunk,h] (negative)
    dA_t = dA.transpose(0, 1, 3, 2)                  # [b,nc,h,chunk]
    dA_cum = jnp.cumsum(dA_t, axis=-1)

    # 1) intra-chunk (quadratic within chunk — the "attention-like" term)
    L = jnp.exp(_segsum(dA_t))                       # [b,nc,h,chunk,chunk]
    scores = jnp.einsum("bcihn,bcjhn->bchij", Cc, Bc)
    y_diag = jnp.einsum("bchij,bchij,bcjhp->bcihp", scores, L, xbar)

    # 2) per-chunk states
    decay_tail = jnp.exp(dA_cum[..., -1:] - dA_cum)  # [b,nc,h,chunk]
    states = jnp.einsum("bcjhn,bchj,bcjhp->bchpn", Bc, decay_tail, xbar)

    # 3) inter-chunk recurrence (sequential over nc chunks)
    chunk_decay = jnp.exp(dA_cum[..., -1])           # [b,nc,h]

    def step(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((b, h, p_, n), jnp.float32)
    xs = (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
          chunk_decay.transpose(1, 0, 2))
    if unroll:
        carry, outs = s0, []
        for i in range(nc):
            carry, y = step(carry, (xs[0][i], xs[1][i]))
            outs.append(y)
        final, s_prevs = carry, jnp.stack(outs)
    else:
        final, s_prevs = jax.lax.scan(step, s0, xs)
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)       # [b,nc,h,p,n]

    # 4) state -> output within each chunk
    decay_in = jnp.exp(dA_cum)                       # [b,nc,h,chunk]
    y_off = jnp.einsum("bcihn,bchpn,bchi->bcihp", Cc, s_prevs.astype(x.dtype), decay_in)

    y = (y_diag + y_off).reshape(b, l + pad, h, p_)[:, :l]
    return y, final


def ssm_forward(p, x, cfg: ModelConfig):
    """Training/prefill forward.  x [B, S, d] -> (y, cache) where cache holds
    the final SSM state and the conv tail (decode can continue from it)."""
    B, S, _ = x.shape
    nh, ph = cfg.n_ssm_heads, cfg.ssm_head_dim
    ng, ns = cfg.ssm_groups, cfg.ssm_state
    z_all = x @ p["in_proj"]
    z, xBC, dt_raw = _split_proj(z_all, cfg)
    K = cfg.ssm_conv
    pre = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    conv_tail = pre[:, -(K - 1):, :] if K > 1 else pre[:, :0, :]
    xBC = causal_conv1d(xBC, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    xs, Bv, Cv = jnp.split(xBC, [cfg.d_inner, cfg.d_inner + ng * ns], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    y, final = ssd_scan(
        xs.reshape(B, S, nh, ph),
        dt,
        p["A_log"],
        Bv.reshape(B, S, ng, ns),
        Cv.reshape(B, S, ng, ns),
        chunk=cfg.ssm_chunk,
        unroll=not cfg.scan_layers,
    )
    y = y + p["D"][None, None, :, None] * xs.reshape(B, S, nh, ph)
    y = y.reshape(B, S, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm_w"], eps=cfg.norm_eps)
    return (y @ p["out_proj"]).astype(x.dtype), {"conv": conv_tail, "state": final}


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }


def ssm_decode(p, x_t, cfg: ModelConfig, cache):
    """One-token recurrent update.  x_t [B, 1, d]."""
    B = x_t.shape[0]
    nh, ph, ng, ns = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    z_all = (x_t[:, 0] @ p["in_proj"])
    z, xBC, dt_raw = _split_proj(z_all, cfg)
    xBC, conv_state = causal_conv1d_step(xBC, cache["conv"], p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    xs, Bv, Cv = jnp.split(xBC, [cfg.d_inner, cfg.d_inner + ng * ns], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B, nh]
    xh = xs.reshape(B, nh, ph).astype(jnp.float32)
    rep = nh // ng
    Bh = jnp.repeat(Bv.reshape(B, ng, ns), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cv.reshape(B, ng, ns), rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dt)                 # [B, nh]
    state = cache["state"] * dA[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xh, Bh, dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + p["D"][None, :, None] * xh
    y = y.reshape(B, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_w"], eps=cfg.norm_eps)
    out = (y.astype(x_t.dtype) @ p["out_proj"]).astype(x_t.dtype)[:, None, :]
    return out, {"conv": conv_state, "state": state}
