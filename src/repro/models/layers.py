"""Shared primitive layers: norms, rotary embeddings, linear init."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, d_in, d_out, *, scale: float | None = None, dtype=jnp.float32):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def rmsnorm(x, w, *, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def layernorm(x, w, b, *, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, *, theta: float = 10000.0):
    """x [..., S, D] with positions i32[S] or [B, S]."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)
    ang = positions.astype(jnp.float32)[..., :, None] * inv[None, :]  # [.., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over head dims: x is [B, H, S, D]; ang is [S, D/2] or [B, S, D/2]
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.stack([xr1, xr2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def causal_conv1d(x, w, b=None):
    """Depthwise causal 1-D conv.  x [B, L, C], w [K, C] -> [B, L, C]."""
    K, C = w.shape
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],  # [K, 1, C]
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C,
    )
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(x.dtype)


def causal_conv1d_step(x_t, conv_state, w, b=None):
    """One decode step.  x_t [B, C]; conv_state [B, K-1, C] (oldest first)."""
    K, C = w.shape
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [B, K, C]
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        out = out + b.astype(jnp.float32)
    new_state = window[:, 1:]
    return out.astype(x_t.dtype), new_state
