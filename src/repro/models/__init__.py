"""LM workload layer: architecture families used by the grid simulator's
workload model and the multi-pod dry-run (DESIGN.md §4)."""
from .config import ModelConfig  # noqa: F401
from .model import Model, build_model, param_count  # noqa: F401
