"""Model facade: one API over every architecture family.

    m = build_model(cfg)
    params = m.init(rng)
    logits, aux = m.forward(params, batch)
    loss, metrics = m.loss(params, batch)
    cache = m.init_cache(batch_size, max_len)
    logits, cache = m.prefill(params, batch, cache)
    logits, cache = m.decode(params, token, cache)

``batch`` is a dict: tokens [B,S] always; frames [B,T,d] for encdec (audio
stub); patch_embeds [B,P,d] for vlm (vision stub).
"""
from __future__ import annotations

from typing import NamedTuple

import jax

from . import encdec, transformer
from .config import ModelConfig


class Model(NamedTuple):
    cfg: ModelConfig
    init: callable
    forward: callable
    loss: callable
    init_cache: callable
    prefill: callable
    decode: callable


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda rng: encdec.init_params(rng, cfg),
            forward=lambda p, b: encdec.forward(p, cfg, b["tokens"], b["frames"]),
            loss=lambda p, b: encdec.loss_fn(p, cfg, b),
            init_cache=lambda bs, ml: encdec.init_cache(cfg, bs, ml),
            prefill=lambda p, b, c: encdec.prefill(p, cfg, b["tokens"], c, b["frames"]),
            decode=lambda p, tok, c: encdec.decode_step(p, cfg, tok, c),
        )
    return Model(
        cfg=cfg,
        init=lambda rng: transformer.init_params(rng, cfg),
        forward=lambda p, b: transformer.forward(p, cfg, b["tokens"], b.get("patch_embeds")),
        loss=lambda p, b: transformer.loss_fn(p, cfg, b),
        init_cache=lambda bs, ml: transformer.init_cache(cfg, bs, ml),
        prefill=lambda p, b, c: transformer.prefill(
            p, cfg, b["tokens"], c, b.get("patch_embeds")
        ),
        decode=lambda p, tok, c: transformer.decode_step(p, cfg, tok, c),
    )


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
