"""Model configuration shared by every architecture in the pool."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    # attention (ignored by pure-SSM layers)
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int = 0                # sliding-window size for local attention
    # mlp
    d_ff: int = 0
    mlp_act: str = "swiglu"        # swiglu | geglu | gelu | relu2
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_groups: int = 1         # independent routing groups (= DP shards)
    # ssm (mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (griffin/recurrentgemma)
    block_pattern: tuple = ()      # e.g. ("rec", "rec", "att") repeated
    rnn_width: int = 0
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    n_frames: int = 1500           # encoder positions fed by the audio stub
    # vlm
    n_patches: int = 0             # patch embeddings spliced over the prefix
    # parallelism
    seq_shard: bool = False        # SP-lite: shard residual seq over 'model'
                                   # at scan boundaries (set by the cell plan)
    explicit_fsdp_gather: bool = True  # materialize the ZeRO-3 gather per
                                   # layer with TP sharding preserved
    scan_layers: bool = True       # lax.scan over stacked layers (HLO size
                                   # depth-independent); False unrolls, which
                                   # the roofline pass uses for exact per-op
                                   # cost_analysis (scan bodies count once)
    # numerics / structure
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: bool = True
    attn_chunk: int = 512          # KV chunk for the scanned attention
    attention_impl: str = "chunked"  # chunked | qblock (flash schedule)
    attn_q_block: int = 1024       # q tile for attention_impl=qblock
    notes: str = ""

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ---- parameter / flop accounting (roofline MODEL_FLOPS) -----------------

    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)

        def attn_params():
            qd = self.n_heads * self.d_head
            kd = self.n_kv_heads * self.d_head
            return d * qd + 2 * d * kd + qd * d

        def mlp_params(ff):
            mats = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            return mats * d * ff

        per_layer = 0
        if self.family in ("dense", "vlm"):
            per_layer = attn_params() + mlp_params(self.d_ff)
            layers = self.n_layers
        elif self.family == "moe":
            per_layer = attn_params() + self.n_experts * mlp_params(self.d_ff) + d * self.n_experts
            layers = self.n_layers
        elif self.family == "ssm":
            di, ns = self.d_inner, self.ssm_state
            conv_ch = di + 2 * self.ssm_groups * ns
            per_layer = (
                d * (2 * di + 2 * self.ssm_groups * ns + self.n_ssm_heads)
                + conv_ch * self.ssm_conv
                + di * d
            )
            layers = self.n_layers
        elif self.family == "hybrid":
            rec = 2 * d * self.rnn_width + self.rnn_width * d + 3 * self.rnn_width
            att = attn_params()
            pattern = self.block_pattern or ("rec",)
            n_rec = sum(1 for i in range(self.n_layers) if pattern[i % len(pattern)] == "rec")
            n_att = self.n_layers - n_rec
            per_layer = 0
            layers = 1
            per_layer = n_rec * (rec + mlp_params(self.d_ff)) + n_att * (att + mlp_params(self.d_ff))
        elif self.family == "encdec":
            enc = attn_params() + mlp_params(self.d_ff)
            dec = 2 * attn_params() + mlp_params(self.d_ff)
            per_layer = 0
            layers = 1
            per_layer = self.n_enc_layers * enc + self.n_dec_layers * dec
        return emb + layers * per_layer

    def active_param_count(self) -> int:
        """Params touched per token (= param_count for non-MoE)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        mats = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        dense = self.param_count() - self.n_layers * self.n_experts * mats * d * self.d_ff
        return dense + self.n_layers * self.top_k * mats * d * self.d_ff

    def model_flops_per_token(self, *, backward: bool = True) -> float:
        """6*N_active (train) or 2*N_active (inference) per token."""
        n = self.active_param_count()
        return (6.0 if backward else 2.0) * n
